"""CrossRoI quickstart: offline RoI optimization + online evaluation.

  PYTHONPATH=src python examples/quickstart.py

Generates the synthetic 5-camera intersection (the paper's AI-City-S02
structure), profiles 60 s to build cross-camera RoI masks, then evaluates
the online phase on the next 60 s against the full-frame baseline.
"""
import time

from repro.core import (OfflineConfig, OnlineConfig, full_frame_offline,
                        run_offline, run_online)
from repro.core.scene import SceneConfig, generate_scene


def main():
    t0 = time.time()
    scene = generate_scene(SceneConfig(duration_s=120, seed=0))
    n_det = sum(len(f) for f in scene.detections)
    print(f"scene: {len(scene.vehicles)} vehicles, {n_det} detections, "
          f"5 cameras ({time.time()-t0:.1f}s)")

    # offline phase: noisy ReID -> filters -> association -> set cover
    off = run_offline(scene, OfflineConfig(profile_frames=600,
                                           solver="exact"))
    print(f"offline: |M| = {len(off.mask)}/{off.universe.num_tiles} tiles "
          f"({off.fleet_density:.0%} of fleet pixels), "
          f"solver={off.solve.method} optimal={off.solve.optimal}, "
          f"filters removed {off.filter_stats.fn_removed} FN / decoupled "
          f"{off.filter_stats.fp_decoupled} FP")

    # online phase vs baseline
    m = run_online(scene, off, OnlineConfig(), 600, 1200)
    base = run_online(scene, full_frame_offline(scene),
                      OnlineConfig(roi_inference=False), 600, 1200)
    print(f"\n{'':12s}{'accuracy':>10s}{'net Mbps':>10s}{'latency s':>11s}"
          f"{'server Hz':>11s}")
    print(f"{'baseline':12s}{base.accuracy:10.4f}{base.network_mbps:10.2f}"
          f"{base.latency_s:11.3f}{base.server_hz:11.1f}")
    print(f"{'crossroi':12s}{m.accuracy:10.4f}{m.network_mbps:10.2f}"
          f"{m.latency_s:11.3f}{m.server_hz:11.1f}")
    print(f"\nnetwork -{1-m.network_mbps/base.network_mbps:.0%} "
          f"latency -{1-m.latency_s/base.latency_s:.0%} "
          f"(paper: 42-65% / 25-34%)")


if __name__ == "__main__":
    main()
