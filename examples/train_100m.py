"""Training driver: a ~100M-param dense model for a few hundred steps, with
a mid-run injected fault to demonstrate checkpoint/restore.

  PYTHONPATH=src python examples/train_100m.py [--steps 300] [--tiny]

(--tiny drops to the 0.1M smoke config for a fast CI-style run; the
default 100M config takes a few CPU-minutes for 300 steps.)
"""
import argparse
import tempfile
import time

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.distributed.fault import FaultInjector
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    if args.tiny:
        cfg = get_config("h2o-danube3-4b", smoke=True)
    else:
        # ~100M-param llama-family config (danube3 shape, scaled down)
        cfg = get_config("h2o-danube3-4b").replace(
            num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=32000)
    n = cfg.param_count()
    print(f"model: {n/1e6:.1f}M params ({cfg.num_layers}L d={cfg.d_model})")

    tcfg = TrainConfig(learning_rate=6e-4, warmup_steps=20,
                       total_steps=args.steps)
    with tempfile.TemporaryDirectory() as workdir:
        t0 = time.time()
        report = train(cfg, tcfg, steps=args.steps,
                       batch_shape=(args.batch, args.seq),
                       workdir=workdir, ckpt_every=max(args.steps // 6, 1),
                       injector=FaultInjector((args.steps // 2,)),
                       log_every=max(args.steps // 10, 1))
        dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"\nloss {report.losses[0]:.3f} -> {report.final_loss:.3f} "
          f"over {report.steps_run} steps ({report.restarts} restart); "
          f"{toks/dt:.0f} tok/s on CPU")
    assert report.final_loss < report.losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
