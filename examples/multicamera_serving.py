"""End-to-end driver: multi-camera video analytics served by the framework.

  PYTHONPATH=src python examples/multicamera_serving.py

The paper's full online pipeline, wired through every layer of the stack:
  1. offline phase computes cross-camera RoI masks (core/)
  2. the camera stream pipeline emits per-segment patch tokens + keep-lists
     derived from the masks (data/streams.py)
  3. the RoI detector runs SBNet-style sparse conv on active tiles
     (serving/detector.py -> kernels/roi_conv, interpret mode on CPU)
  4. the serving engine prefills the *packed* fleet patch stream through a
     (smoke) VLM backbone — the CrossRoI technique as token sparsity —
     and decodes a short analytics summary (serving/engine.py)
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ServeConfig
from repro.configs.registry import get_config
from repro.core import OfflineConfig, OnlineConfig, run_offline, run_online
from repro.core.scene import SceneConfig, generate_scene
from repro.data.streams import CameraStreamPipeline
from repro.models.params import init_params
from repro.serving.detector import DetectorConfig, RoIDetector
from repro.serving.engine import ServingEngine


def main():
    t0 = time.time()
    scene = generate_scene(SceneConfig(duration_s=90, seed=0))
    off = run_offline(scene, OfflineConfig(profile_frames=600))
    print(f"offline masks: {off.fleet_density:.0%} of fleet pixels kept "
          f"({time.time()-t0:.1f}s)")

    # --- detector on RoI tiles (one frame, camera 1) -----------------------
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(1))
    grid = np.kron(off.cam_grids[0], np.ones((4, 4), bool))[:33, :60]
    frame = jnp.asarray(np.random.default_rng(0).normal(
        size=(grid.shape[0] * 16, grid.shape[1] * 16, 3)), jnp.float32)
    t1 = time.time()
    heat = det.forward(frame, grid)
    print(f"RoI detector: frame {frame.shape[:2]}, density "
          f"{grid.mean():.0%}, est speedup "
          f"{det.speedup_estimate(float(grid.mean())):.2f}x "
          f"({time.time()-t1:.1f}s interpret-mode)")

    # --- packed VLM prefill over the fleet stream --------------------------
    cfg = get_config("internvl2-26b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, ServeConfig(roi_sparsity=True), params)
    pipe = CameraStreamPipeline(scene, off, patch_dim=cfg.frontend_dim)
    seg = next(pipe.segments(600, 610))
    toks, keep = pipe.fleet_tokens(seg, frame=0)
    # pad to the engine block; patch streams enter via the VLM frontend
    res = engine.roi_prefill(jnp.asarray(toks, jnp.bfloat16),
                             jnp.asarray(keep), block=128)
    print(f"packed prefill: {res.n_kept}/{res.n_total} fleet patch tokens "
          f"({res.compute_fraction:.0%} of dense compute)")
    nxt = jnp.argmax(res.logits[:, -1], -1)
    out, _ = engine.decode_tokens(res.caches, nxt, res.n_kept, 6)
    print(f"decoded analytics tokens: {out[0].tolist()}")

    # --- whole-system accounting -------------------------------------------
    m = run_online(scene, off, OnlineConfig(), 600, 900)
    print(f"\nsystem: accuracy {m.accuracy:.4f}, network "
          f"{m.network_mbps:.1f} Mbps, server {m.server_hz:.0f} Hz, "
          f"latency {m.latency_s:.2f} s   (total {time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
