"""Offline-phase deep dive: watch each CrossRoI stage do its work.

  PYTHONPATH=src python examples/offline_roi_masks.py

Shows: raw ReID error structure (Table 2), filter effects, the association
table, exact-vs-greedy set cover, tile grouping, and an ASCII render of
each camera's mask.
"""
import numpy as np

from repro.core import setcover
from repro.core.association import TileUniverse, build_association_table
from repro.core.filters import FilterConfig, apply_filters
from repro.core.grouping import group_tiles
from repro.core.reid import ReIDNoiseConfig, characterize_pairwise, \
    run_noisy_reid
from repro.core.scene import SceneConfig, generate_scene


def main():
    scene = generate_scene(SceneConfig(duration_s=60, seed=0))
    records = run_noisy_reid(scene, ReIDNoiseConfig(), 0, 600)
    counts = characterize_pairwise(records, 5)
    print("raw ReID (src=C1):  TP   FP   FN   TN")
    for d in range(1, 5):
        tp, fp, fn, tn = counts[0, d]
        print(f"  C1->C{d+1}:        {tp:4d} {fp:4d} {fn:4d} {tn:4d}")

    cleaned, stats = apply_filters(records, 5, FilterConfig())
    print(f"\nfilters: {stats.fp_decoupled} FP decoupled, "
          f"{stats.fn_removed} FN removed")

    universe = TileUniverse.build(scene.cameras)
    tab = build_association_table(cleaned, universe)
    multi = sum(1 for c in tab.constraints if len(c) > 1)
    print(f"association table: {len(tab.constraints)} constraints, "
          f"{multi} with cross-camera choice")

    g = setcover.solve(tab, "greedy")
    e = setcover.solve(tab, "exact")
    print(f"set cover: greedy |M|={len(g.mask)}  "
          f"exact |M|={len(e.mask)} (LB={e.lower_bound:.0f}, "
          f"optimal={e.optimal}, {e.nodes} nodes, {e.wall_s:.1f}s)")

    for cam in scene.cameras:
        grid = universe.cam_mask_grid(cam.cam_id, e.mask)
        groups = group_tiles(grid)
        print(f"\nC{cam.cam_id+1} mask: {int(grid.sum())} tiles -> "
              f"{len(groups)} groups")
        for row in grid:
            print("  " + "".join("#" if v else "." for v in row))


if __name__ == "__main__":
    main()
