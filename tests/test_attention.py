"""Blockwise attention vs. naive reference, across masks/windows/offsets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import (blockwise_attention, decode_attention,
                                 repeat_kv)


def naive_attention(q, k, v, *, causal=True, window=0, q_offset=0):
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Skv)[None, :]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))


def _rand(key, B=2, Sq=64, Skv=64, H=4, D=16):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, Sq, H, D))
    k = jax.random.normal(kk, (B, Skv, H, D))
    v = jax.random.normal(kv, (B, Skv, H, D))
    return q, k, v


@pytest.mark.parametrize("qb,kc", [(16, 16), (8, 32), (64, 64), (16, 8)])
def test_causal_matches_naive(qb, kc):
    q, k, v = _rand(jax.random.PRNGKey(0))
    out = blockwise_attention(q, k, v, causal=True, q_block=qb, kv_chunk=kc)
    ref = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_bidirectional_matches_naive():
    q, k, v = _rand(jax.random.PRNGKey(1), Sq=48, Skv=80)
    out = blockwise_attention(q, k, v, causal=False, q_block=16, kv_chunk=16)
    ref = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window,qb", [(16, 8), (24, 8), (32, 16), (8, 8)])
def test_banded_matches_naive(window, qb):
    q, k, v = _rand(jax.random.PRNGKey(2), Sq=64, Skv=64)
    out = blockwise_attention(q, k, v, causal=True, window=window, q_block=qb)
    ref = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_causal_skip_matches_naive():
    q, k, v = _rand(jax.random.PRNGKey(3))
    out = blockwise_attention(q, k, v, causal=True, q_block=16, kv_chunk=16,
                              causal_skip=True)
    ref = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_softcap():
    q, k, v = _rand(jax.random.PRNGKey(4), Sq=32, Skv=32)
    out = blockwise_attention(q, k, v, causal=True, softcap=5.0, q_block=8)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (16 ** 0.5)
    s = 5.0 * jnp.tanh(s / 5.0)
    mask = jnp.tril(jnp.ones((32, 32), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_decode_matches_naive_last_row():
    key = jax.random.PRNGKey(5)
    q, k, v = _rand(key, Sq=33, Skv=33)
    full = naive_attention(q, k, v, causal=True)
    out = decode_attention(q[:, -1:], k, v, cache_len=33)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(full[:, -1:]), atol=2e-5, rtol=2e-5)


def test_decode_windowed():
    key = jax.random.PRNGKey(6)
    q, k, v = _rand(key, Sq=40, Skv=40)
    full = naive_attention(q, k, v, causal=True, window=8)
    out = decode_attention(q[:, -1:], k, v, cache_len=40, window=8)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(full[:, -1:]), atol=2e-5, rtol=2e-5)


def test_gqa_repeat():
    key = jax.random.PRNGKey(7)
    B, S, KH, G, D = 2, 16, 2, 3, 8
    q = jax.random.normal(key, (B, S, KH * G, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KH, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KH, D))
    out = blockwise_attention(q, repeat_kv(k, G), repeat_kv(v, G), q_block=8)
    # manual per-group
    ref = naive_attention(q, repeat_kv(k, G), repeat_kv(v, G))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_packed_positions():
    """Explicit non-contiguous positions (RoI-packed prefill)."""
    key = jax.random.PRNGKey(8)
    q, k, v = _rand(key, Sq=32, Skv=32)
    # positions with gaps (as after CrossRoI token dropping)
    pos = jnp.sort(jax.random.choice(key, 64, (32,), replace=False))
    pos_b = jnp.broadcast_to(pos[None], (2, 32)).astype(jnp.int32)
    out = blockwise_attention(q, k, v, causal=True, q_block=8,
                              q_positions=pos_b, kv_positions=pos_b)
    qpos, kpos = pos[:, None], pos[None, :]
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (16 ** 0.5)
    s = jnp.where((qpos >= kpos)[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=2e-5, rtol=2e-5)
