"""One-launch fleet backbone: the fused layer-stack megakernel, coalesced
rim halos, the cross-group super-launch, and the per-grid digest cache.

The contract everywhere is BIT-identity with the per-layer / per-group
chain (``roi_conv_packed`` rounds, per-group ``fleet_forward``): the
fused path changes the dispatch structure, never the math.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fleet.runtime import fleet_inference_step
from repro.kernels import ops, ref
from repro.serving.detector import DetectorConfig, RoIDetector


def _rng(seed=0):
    return np.random.default_rng(seed)


def _mk_group(rng, shapes, t, ensure=True):
    grids = [rng.random(s) < 0.45 for s in shapes]
    if ensure:
        for g in grids:
            g[min(1, g.shape[0] - 1), min(1, g.shape[1] - 1)] = True
    frames = [jnp.asarray(rng.normal(size=(gy * t, gx * t, 3)),
                          jnp.float32) for gy, gx in shapes]
    return frames, grids


# ---------------------------------------------------------------------------
# the megakernel alone: bitwise vs the per-layer packed chain
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chans", [(3, 4, 6, 6, 5), (3, 8), (3, 5, 7)])
def test_stack_kernel_bitwise_vs_per_layer_chain(chans):
    """roi_conv_stack == relu(roi_conv_packed(...)) rounds, bit for bit,
    including ragged channel widths across layers."""
    rng = _rng(1)
    th = tw = 8
    grids = [rng.random((4, 5)) < 0.5, rng.random((3, 3)) < 0.4]
    grids[0][1, 1] = True
    grids[1][:] = False
    grids[1][2, 2] = True                  # isolated single-tile camera
    idx, _ = ops.fleet_indices(grids)
    nbr = jnp.asarray(ops.fleet_neighbor_table(grids))
    idx = jnp.asarray(idx)
    x = jnp.asarray(rng.normal(size=(2, 4 * th, 5 * tw, 3)), jnp.float32)
    ws = [jnp.asarray(rng.normal(size=(3, 3, ci, co)) * 0.3, jnp.float32)
          for ci, co in zip(chans[:-1], chans[1:])]

    legacy = jax.nn.relu(ops.roi_conv_fleet(x, ws[0], idx, th, tw))
    p0 = ops.roi_conv_entry(x, ws[0], idx, th, tw)
    assert (np.asarray(p0) == np.asarray(legacy)).all(), \
        "entry kernel must equal relu(roi_conv_fleet)"
    if len(ws) == 1:
        return
    for w in ws[1:]:
        legacy = jnp.asarray(jax.nn.relu(ops.roi_conv_packed(legacy, w,
                                                             nbr)))
    fused = ops.roi_conv_stack(p0, ws[1:], nbr)
    assert (np.asarray(fused) == np.asarray(legacy)).all(), \
        "megakernel must be bit-identical to the per-layer chain"


def test_assemble_rims_matches_oracle():
    """The vectorized rim assembly (the seed of the megakernel's
    coalesced halos) equals the scatter-loop oracle row for row on every
    real slot."""
    from repro.kernels.roi_conv import assemble_rims
    rng = _rng(2)
    th = tw = 8
    grids = [rng.random((4, 4)) < 0.6, rng.random((3, 5)) < 0.5]
    grids[0][2, 2] = True
    grids[1][1, 1] = True
    idx_np, _ = ops.fleet_indices(grids)
    nbr_np = ops.fleet_neighbor_table(grids)
    n = idx_np.shape[0]
    packed = jnp.asarray(rng.normal(size=(n, th, tw, 4)), jnp.float32)
    rt, rb, rl, rr = [np.asarray(r) for r in
                      assemble_rims(packed, jnp.asarray(nbr_np))]
    ert, erb, erl, err_ = ref.rims_of_packed(packed, nbr_np)
    np.testing.assert_array_equal(rt, ert[:n])
    np.testing.assert_array_equal(rb, erb[:n])
    np.testing.assert_array_equal(rl, erl[:n])
    np.testing.assert_array_equal(rr, err_[:n])


@pytest.mark.parametrize("block", [1, 3, 16, 256])
def test_stack_block_raggedness_bitwise(block):
    """Any tile-block size (including non-dividing and over-sized ones)
    keeps the megakernel bit-identical to the per-layer chain."""
    rng = _rng(3)
    th = tw = 8
    grid = rng.random((5, 7)) < 0.45
    grid[2, 3] = True
    idx = ops.mask_to_indices(grid)
    nbr = jnp.asarray(ops.neighbor_table(idx, grid.shape))
    n = idx.shape[0]
    packed = jax.nn.relu(
        jnp.asarray(rng.normal(size=(n, th, tw, 4)), jnp.float32))
    ws = [jnp.asarray(rng.normal(size=(3, 3, 4, 6)) * 0.2, jnp.float32),
          jnp.asarray(rng.normal(size=(3, 3, 6, 5)) * 0.2, jnp.float32)]
    fused = ops.roi_conv_stack(packed, ws, nbr, block=block)
    legacy = packed
    for w in ws:
        legacy = jax.nn.relu(ops.roi_conv_packed(legacy, w, nbr))
    assert (np.asarray(fused) == np.asarray(legacy)).all()


# ---------------------------------------------------------------------------
# detector paths: fused == per-layer == per-camera
# ---------------------------------------------------------------------------

def test_roi_forward_bitwise_vs_per_layer_path():
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    rng = _rng(4)
    t = det.cfg.tile
    grid = rng.random((5, 6)) < 0.5
    grid[2, 2] = True
    x = jnp.asarray(rng.normal(size=(5 * t, 6 * t, 3)), jnp.float32)
    fused = det.roi_forward(x, grid)
    layers = det.roi_forward_layers(x, grid)
    assert (np.asarray(fused) == np.asarray(layers)).all()


def test_roi_forward_empty_mask_no_launches():
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    t = det.cfg.tile
    x = jnp.ones((3 * t, 3 * t, 3), jnp.float32)
    with ops.count_kernels() as c:
        out = det.roi_forward(x, np.zeros((3, 3), bool))
    assert sum(c.values()) == 0
    assert out.shape == (3 * t, 3 * t, det.head.shape[-1])
    assert float(jnp.abs(out).max()) == 0.0


def test_fleet_forward_bitwise_vs_per_layer_fleet():
    """Unequal frame sizes + an empty-mask camera + a single-tile camera:
    the fused chain equals the per-layer fleet chain bit for bit."""
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(1))
    rng = _rng(5)
    t = det.cfg.tile
    shapes = [(4, 5), (3, 4), (2, 2), (5, 3)]
    frames, grids = _mk_group(rng, shapes, t)
    grids[2][:] = False                     # empty-mask camera
    grids[3][:] = False
    grids[3][4, 1] = True                   # single-tile camera
    fused = det.fleet_forward(frames, grids)
    layers = det.fleet_forward_layers(frames, grids)
    for o, l in zip(fused, layers):
        assert (np.asarray(o) == np.asarray(l)).all()
    # the empty-mask camera ships an all-zero head map
    assert float(jnp.abs(fused[2]).max()) == 0.0


# ---------------------------------------------------------------------------
# the cross-group super-launch
# ---------------------------------------------------------------------------

def test_superlaunch_tables_flatten_groups_leak_free():
    rng = _rng(6)
    per_group = [[rng.random((3, 4)) < 0.6 for _ in range(2)],
                 [rng.random((2, 5)) < 0.6 for _ in range(3)],
                 [np.zeros((3, 3), bool)]]
    per_group[2][0][1, 1] = True
    idx, nbr, tile_off, cam_starts = ops.superlaunch_tables(per_group)
    flat = [g for gs in per_group for g in gs]
    np.testing.assert_array_equal(cam_starts, [0, 2, 5, 6])
    assert idx.shape[0] == tile_off[-1] == nbr.shape[0]
    # per flat camera: slots stay inside the camera's own range
    for ci in range(len(flat)):
        sl = nbr[tile_off[ci]:tile_off[ci + 1]]
        ok = (sl == -1) | ((sl >= tile_off[ci]) & (sl < tile_off[ci + 1]))
        assert ok.all(), f"flat camera {ci} halo leaks"
        sub = idx[tile_off[ci]:tile_off[ci + 1]]
        assert (sub[:, 0] == ci).all()
        np.testing.assert_array_equal(sub[:, 1:],
                                      ops.mask_to_indices(flat[ci]))


def test_superlaunch_bitwise_vs_per_group_ragged():
    """Ragged group sizes (1, 2 and 4 cameras), unequal canvases, an
    empty-mask camera and a single-tile group: the one-launch fleet step
    is bit-identical to per-group fleet_forward."""
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(2))
    rng = _rng(7)
    t = det.cfg.tile
    frames, grids = {}, {}
    frames[0], grids[0] = _mk_group(rng, [(4, 5), (3, 4)], t)
    frames[1], grids[1] = _mk_group(rng, [(2, 3)], t)
    grids[1][0][:] = False
    grids[1][0][0, 0] = True                # single-tile group
    frames[2], grids[2] = _mk_group(rng, [(5, 3), (3, 3), (2, 6), (4, 4)],
                                    t)
    grids[2][1][:] = False                  # empty-mask camera
    outs, counts = fleet_inference_step(det, frames, grids)
    assert sum(counts.values()) <= 3
    assert counts["roi_conv_entry"] == 1
    assert counts["roi_conv_stack"] == 1
    assert counts["sbnet_scatter_fleet"] == 1
    for gid in frames:
        per_group = det.fleet_forward(frames[gid], grids[gid])
        for a, b in zip(outs[gid], per_group):
            assert a.shape == b.shape
            assert (np.asarray(a) == np.asarray(b)).all(), \
                f"group {gid}: super-launch diverged from per-group chain"


def test_superlaunch_dispatches_independent_of_k_and_n():
    """The dispatch count stays ≤3 as K grows and for a deeper stack."""
    rng = _rng(8)
    for n_layers, K in [(1, 2), (2, 3), (4, 5)]:
        det = RoIDetector(DetectorConfig(
            channels=(8,) * n_layers), jax.random.PRNGKey(3))
        t = det.cfg.tile
        frames, grids = {}, {}
        for gid in range(K):
            frames[gid], grids[gid] = _mk_group(rng, [(2, 3), (3, 2)], t)
        outs, counts = fleet_inference_step(det, frames, grids)
        assert sum(counts.values()) <= 3
        assert counts["roi_conv_entry"] == 1
        assert counts["roi_conv_stack"] == (1 if n_layers > 1 else 0)
        assert len(outs) == K


def test_empty_fleet_launches_nothing():
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    t = det.cfg.tile
    frames = {0: [jnp.zeros((2 * t, 2 * t, 3), jnp.float32)]}
    grids = {0: [np.zeros((2, 2), bool)]}
    outs, counts = fleet_inference_step(det, frames, grids)
    assert sum(counts.values()) == 0
    assert float(jnp.abs(outs[0][0]).max()) == 0.0


# ---------------------------------------------------------------------------
# per-grid digest cache (the fleet cache-key cost fix)
# ---------------------------------------------------------------------------

def test_fleet_cache_key_hashes_each_grid_once():
    """Repeated fleet_forward with the same grid objects must not
    re-serialize any grid: the digest memo absorbs the key cost and the
    table cache reports hits."""
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    rng = _rng(9)
    t = det.cfg.tile
    frames, grids = _mk_group(rng, [(3, 4), (4, 3)], t)
    det.fleet_forward(frames, grids)
    assert det.grid_hash_computes == 2
    assert det.fleet_cache_hits == 0
    for _ in range(3):
        det.fleet_forward(frames, grids)
    assert det.grid_hash_computes == 2, \
        "cache hits must not re-serialize grids"
    assert det.fleet_cache_hits == 3
    # equal content in a NEW array object: one fresh digest, but the
    # table cache still hits (content-keyed)
    grids2 = [g.copy() for g in grids]
    det.fleet_forward(frames, grids2)
    assert det.grid_hash_computes == 4
    assert det.fleet_cache_hits == 4


def test_grid_digest_guard_catches_inplace_mutation():
    """Mutating a memoized grid in place (popcount-changing, the normal
    case) must re-hash and produce fresh tables, not stale ones."""
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    rng = _rng(11)
    t = det.cfg.tile
    grid = np.zeros((3, 3), bool)
    grid[1, 1] = True
    x = jnp.asarray(rng.normal(size=(3 * t, 3 * t, 3)), jnp.float32)
    det.roi_forward(x, grid)
    grid[0, 0] = True                      # in-place mask update
    mutated = np.asarray(det.roi_forward(x, grid))
    fresh = np.asarray(det.roi_forward(x, grid.copy()))
    np.testing.assert_array_equal(mutated, fresh)
    assert np.abs(mutated[:t, :t]).max() > 0.0   # new tile is live


def test_digest_memo_capacity_scales_with_fleet():
    """A fleet wider than the default memo must still hit the digest
    memo on the second step (no per-step re-serialization)."""
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    rng = _rng(12)
    t = det.cfg.tile
    n_cams = 80                            # > the 64-entry default cap
    grids = [rng.random((2, 2)) < 0.7 for _ in range(n_cams)]
    for g in grids:
        g[0, 0] = True
    frames = [jnp.zeros((2 * t, 2 * t, 3), jnp.float32)] * n_cams
    det.fleet_forward(frames, grids)
    assert det.grid_hash_computes == n_cams
    det.fleet_forward(frames, grids)
    assert det.grid_hash_computes == n_cams, \
        "second step must not re-serialize any grid"
    assert det.fleet_cache_hits == 1


def test_mask_cache_digest_reuse_single_camera():
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    rng = _rng(10)
    t = det.cfg.tile
    grid = rng.random((3, 3)) < 0.6
    grid[1, 1] = True
    x = jnp.asarray(rng.normal(size=(3 * t, 3 * t, 3)), jnp.float32)
    det.roi_forward(x, grid)
    h = det.grid_hash_computes
    det.roi_forward(x, grid)
    det.roi_forward(x, grid)
    assert det.grid_hash_computes == h
    assert det.mask_cache_hits == 2
