"""Per-architecture smoke tests (reduced configs) + decode consistency.

Each assigned arch instantiates its SMOKE config, runs one forward/train
step on CPU (shape + finiteness assertions), and then checks that
prefill-then-decode reproduces the full-forward logits at the same position
— the strictest test of KV-cache / recurrent-state handling.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, ShapeCell, get_config
from repro.models.model import (decode_step, init_cache, input_specs,
                                make_batch, prefill, train_loss)
from repro.models.params import count_params, init_params

CELL = ShapeCell("smoke_train", 64, 2, "train")
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch, smoke=True)
            params = init_params(cfg, KEY)
            batch = make_batch(cfg, CELL, KEY)
            cache[arch] = (cfg, params, batch)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch, arch_state):
    cfg, params, batch = arch_state(arch)
    loss, metrics = jax.jit(
        lambda p, b: train_loss(p, cfg, b, remat=False))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"
    assert count_params(params) > 0
    # one grad step must stay finite
    g = jax.jit(jax.grad(lambda p, b: train_loss(p, cfg, b, remat=False)[0])
                )(params, batch)
    flat = jax.tree.leaves(g)
    assert all(bool(jnp.all(jnp.isfinite(x.astype(jnp.float32)))) for x in flat), \
        f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch, arch_state):
    """logits(decode @ pos S) == logits(full forward @ pos S)."""
    cfg, params, batch = arch_state(arch)
    B = batch["tokens"].shape[0]
    S = batch["tokens"].shape[1]
    total = S + (batch["patches"].shape[1] if cfg.family == "vlm" else 0)
    pb = {k: v for k, v in batch.items() if k != "labels"}

    # prefill on tokens[:-1], then decode tokens[-1]; compare against a
    # prefill over the full sequence (last-position logits).
    pb_head = dict(pb)
    pb_head["tokens"] = pb["tokens"][:, :-1]
    caches = init_cache(cfg, B, max_seq=total + 8)
    logits_head, caches = jax.jit(
        lambda p, b, c: prefill(p, cfg, b, c))(params, pb_head, caches)
    pos = total - 1
    if cfg.family == "encdec":
        pos = pb_head["tokens"].shape[1]
    logits_dec, _ = jax.jit(
        lambda p, t, c, pp: decode_step(p, cfg, t, c, pp))(
            params, pb["tokens"][:, -1:], caches, pos)

    caches_full = init_cache(cfg, B, max_seq=total + 8)
    logits_full, _ = jax.jit(
        lambda p, b, c: prefill(p, cfg, b, c))(params, pb, caches_full)

    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full, np.float32),
        atol=5e-2, rtol=5e-2)


@pytest.mark.parametrize("arch", ["h2o-danube3-4b", "gemma3-27b"])
def test_ring_buffer_cache_consistency(arch, arch_state):
    """SWA archs with ring-buffer caches shorter than the sequence still
    reproduce full-forward logits (window semantics preserved)."""
    cfg, params, batch = arch_state(arch)
    B = batch["tokens"].shape[0]
    S = batch["tokens"].shape[1]
    assert cfg.window_size < S  # ring buffer genuinely wraps
    pb = {"tokens": batch["tokens"][:, :-1]}
    caches = init_cache(cfg, B, max_seq=S + 8)
    _, caches = jax.jit(lambda p, b, c: prefill(p, cfg, b, c))(
        params, pb, caches)
    logits_dec, _ = jax.jit(
        lambda p, t, c, pp: decode_step(p, cfg, t, c, pp))(
            params, batch["tokens"][:, -1:], caches, S - 1)
    caches_full = init_cache(cfg, B, max_seq=S + 8)
    logits_full, _ = jax.jit(lambda p, b, c: prefill(p, cfg, b, c))(
        params, {"tokens": batch["tokens"]}, caches_full)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full, np.float32), atol=5e-2, rtol=5e-2)


def test_param_count_matches_analytic():
    """Analytic ModelConfig.param_count tracks the real tree within 2%."""
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        real = count_params(init_params(cfg, KEY))
        approx = cfg.param_count()
        assert abs(real - approx) / real < 0.02, \
            f"{arch}: real={real} analytic={approx}"
