"""Observability subsystem: default-off no-ops, span recording and the
Chrome-trace export schema (golden 2-step fleet run with overlapping
async host/device spans), the typed metrics registry, canonical
kernel-counter-name enforcement, SLO panels, and the transport
empty-distribution guards."""
import json
import os
import re

import numpy as np
import pytest

import jax

from repro import obs
from repro.fleet import fleet_reuse_step
from repro.fleet.sharded import AsyncShardedPipeline, ShardedSuperlaunch
from repro.kernels import ops
from repro.launch.mesh import make_fleet_mesh
from repro.net.batcher import (TransportStats, empty_transport,
                               merge_transport, simulate_transport)
from repro.obs import export, metrics, slo, trace
from repro.serving.detector import (DetectorConfig, PackedActivationCache,
                                    RoIDetector)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _obs_clean():
    """Every test leaves observability off and empty (tier-1 default)."""
    obs.configure(enabled=False, reset=True)
    yield
    obs.configure(enabled=False, reset=True)


@pytest.fixture(scope="module")
def small_det():
    return RoIDetector(DetectorConfig(tile=8, channels=(4, 6)),
                       jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# default-off: zero spans, zero metric values, zero device dispatches
# ---------------------------------------------------------------------------

def test_disabled_by_default_records_nothing():
    assert not obs.is_enabled()
    n0 = trace.span_count()
    with trace.span("x", a=1):
        with trace.span("y"):
            pass
    trace.begin("dev").end()
    assert trace.span_count() == n0
    c = metrics.counter("t_disabled_counter")
    c.inc(5)
    g = metrics.gauge("t_disabled_gauge")
    g.set(3.0)
    h = metrics.histogram("t_disabled_hist")
    h.observe(1.0)
    assert c.total() == 0 and g.value() == 0.0 and h.count() == 0


def test_enabled_context_is_scoped():
    with obs.enabled():
        assert obs.is_enabled()
        with trace.span("scoped"):
            pass
    assert not obs.is_enabled()
    assert any(e[0] == "scoped" for e in trace.events())


# ---------------------------------------------------------------------------
# typed registry semantics
# ---------------------------------------------------------------------------

def test_registry_type_and_label_safety():
    c = metrics.counter("t_typed", labels=("camera", "group"))
    with pytest.raises(ValueError):          # same name, different type
        metrics.gauge("t_typed", labels=("camera", "group"))
    with pytest.raises(ValueError):          # same name, different labels
        metrics.counter("t_typed", labels=("camera",))
    assert metrics.counter("t_typed", labels=("camera", "group")) is c
    with obs.enabled():
        c.inc(2, camera="c0", group="g1")
        with pytest.raises(ValueError):      # undeclared label set
            c.inc(1, camera="c0")
    assert c.value(camera="c0", group="g1") == 2


def test_snapshot_shape_and_reset():
    with obs.enabled():
        metrics.counter("t_snap_c", labels=("k",)).inc(3, k="a")
        metrics.histogram("t_snap_h").observe(1.0)
        metrics.histogram("t_snap_h").observe(3.0)
    snap = metrics.REGISTRY.snapshot()
    assert snap["t_snap_c"]["type"] == "counter"
    assert snap["t_snap_c"]["values"] == [
        {"labels": {"k": "a"}, "value": 3}]
    hv = snap["t_snap_h"]["values"][0]["value"]
    assert hv["count"] == 2 and hv["sum"] == 4.0 and hv["p50"] == 2.0
    json.dumps(snap)                         # serializable as-is
    metrics.REGISTRY.reset()
    assert metrics.REGISTRY.get("t_snap_c").total() == 0


# ---------------------------------------------------------------------------
# canonical kernel-counter names (satellite: typo'd names fail loudly)
# ---------------------------------------------------------------------------

def test_record_dispatch_rejects_unknown_names():
    # typo'd names built by concatenation so the literal scan below
    # doesn't flag this test's own fixtures
    typo = "sbnet_gather" + "r"
    with pytest.raises(ValueError, match=typo):
        ops.record_dispatch(typo)
    before = ops.KERNEL_COUNTS["sbnet_gather"]
    with pytest.raises(ValueError):
        ops.record_dispatch("tile_" + "delta_gte")
    assert ops.KERNEL_COUNTS["sbnet_gather"] == before


def test_kernel_dispatch_mirror_bitmatches_legacy_counter():
    with obs.enabled():
        obs.configure(reset=True)
        with ops.count_kernels() as region:
            ops.record_dispatch("roi_conv_entry")
            ops.record_dispatch("roi_conv_stack")
            ops.record_dispatch("sbnet_scatter_fleet", 2)
        assert metrics.kernel_counts() == dict(region)


# string literals that match the kernel-name grammar but are benchmark
# panel keys or exported API names, not dispatch counters — anything
# else outside KERNEL_NAMES is a typo and fails the scan below
PANEL_KEYS = frozenset({
    "tile_delta_dispatches", "tile_delta_bit_exact",
    "tile_delta_static_frac", "roi_conv_interior_err",
    "roi_conv_checked_tiles", "roi_conv_batched",
    # ops.__all__ export: the canvas-reference gate variant dispatches
    # under the ONE "tile_delta_gate" counter (structurally the same
    # gate), so its function name is not itself a counter
    "tile_delta_gate_canvas",
})

_KNAME = re.compile(
    r"[\"'](sbnet_[a-z_]+|tile_delta[a-z_]*|roi_conv[a-z_]*"
    r"|roi_attention[a-z_]*)[\"']")


def _scan_literals(*dirnames):
    found = set()
    for d in dirnames:
        for root, _, files in os.walk(os.path.join(REPO, d)):
            for fn in files:
                if fn.endswith(".py"):
                    with open(os.path.join(root, fn)) as f:
                        found |= set(_KNAME.findall(f.read()))
    return found


def test_counter_names_in_tests_and_benchmarks_are_canonical():
    """Every kernel-counter-shaped string asserted anywhere in tests/
    benchmarks/src comes from the ONE canonical frozenset (or the known
    panel-key allowlist) — a typo'd counter name fails here instead of
    silently counting zero."""
    found = _scan_literals("tests", "benchmarks", "src")
    assert found >= {"tile_delta_gate", "roi_conv_entry"}  # scan sanity
    stray = found - metrics.KERNEL_NAMES - PANEL_KEYS
    assert not stray, f"non-canonical kernel counter names: {stray}"


def test_every_canonical_name_has_a_dispatch_site():
    pat = re.compile(r"record_dispatch\(\s*[\"']([a-z_]+)[\"']")
    found = set()
    for root, _, files in os.walk(os.path.join(REPO, "src")):
        for fn in files:
            if fn.endswith(".py"):
                with open(os.path.join(root, fn)) as f:
                    found |= set(pat.findall(f.read()))
    assert found == metrics.KERNEL_NAMES


# ---------------------------------------------------------------------------
# golden trace-export schema (satellite: 2-step fleet run)
# ---------------------------------------------------------------------------

def _intervals(doc, name):
    return [(e["ts"], e["ts"] + e["dur"])
            for e in doc["traceEvents"] if e.get("name") == name]


def test_two_step_fleet_trace_is_wellformed_chrome_json(small_det,
                                                        tmp_path):
    """A 2-step async-pipeline fleet run exports valid Chrome
    ``trace_event`` JSON: pid/tid/ts/dur/name/args on every span, spans
    on one thread properly nested or disjoint, and the step-1 host-plan
    span OVERLAPPING the step-0 device-compute span (the pipeline's
    host/device overlap made visible)."""
    det = small_det
    rng = np.random.default_rng(0)
    grids = {0: [rng.random((3, 4)) < 0.6], 1: [rng.random((2, 3)) < 0.7]}
    frames = [{g: [rng.random((a.shape[0] * 8, a.shape[1] * 8, 3)
                              ).astype(np.float32) for a in gs]
               for g, gs in grids.items()} for _ in range(2)]
    rt = ShardedSuperlaunch(det, grids, make_fleet_mesh(1))
    pipe = AsyncShardedPipeline(rt, rt.make_cache())
    with obs.enabled():
        obs.configure(reset=True)
        for f in frames:
            pipe.submit(f)
        pipe.drain()
        path = tmp_path / "trace.json"
        doc = export.chrome_trace(str(path))

    with open(path) as f:
        on_disk = json.load(f)
    assert on_disk == doc
    evs = doc["traceEvents"]
    xs = [e for e in evs if e["ph"] == "X"]
    assert xs, "no spans recorded"
    for e in xs:                      # golden field schema
        assert set(e) >= {"ph", "pid", "tid", "ts", "dur", "name", "args"}
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert isinstance(e["args"], dict)
    assert any(e["ph"] == "M" and e["name"] == "process_name"
               for e in evs)
    # same-thread spans nest or are disjoint (never partially overlap)
    by_tid = {}
    for e in xs:
        by_tid.setdefault(e["tid"], []).append(
            (e["ts"], e["ts"] + e["dur"]))
    for spans in by_tid.values():
        for i, (a0, a1) in enumerate(spans):
            for b0, b1 in spans[i + 1:]:
                disjoint = a1 <= b0 or b1 <= a0
                nested = (a0 <= b0 and b1 <= a1) or (b0 <= a0 and a1 <= b1)
                assert disjoint or nested, (spans,)
    # both pipeline step spans present on their own tracks...
    hosts = {e["args"]["step"]: (e["ts"], e["ts"] + e["dur"])
             for e in xs if e["name"] == "host_plan"}
    devs = {e["args"]["step"]: (e["ts"], e["ts"] + e["dur"])
            for e in xs if e["name"] == "device_compute"}
    assert set(hosts) == {0, 1} and set(devs) == {0, 1}
    # ...and step 1's host planning ran INSIDE step 0's device window
    h0, h1 = hosts[1]
    d0, d1 = devs[0]
    assert max(h0, d0) < min(h1, d1), (hosts, devs)
    # the device track is a separate named row
    dev_tid = next(e["tid"] for e in xs if e["name"] == "device_compute")
    assert dev_tid >= trace.TRACK_TID_BASE
    assert any(e["ph"] == "M" and e["name"] == "thread_name"
               and e["tid"] == dev_tid
               and e["args"]["name"] == "device" for e in evs)


# ---------------------------------------------------------------------------
# fleet-step metrics capture (quantities previously dropped on the floor)
# ---------------------------------------------------------------------------

def test_fleet_reuse_step_records_tiles_cache_and_span(small_det):
    det = small_det
    rng = np.random.default_rng(1)
    grids = {0: [rng.random((3, 3)) < 0.8]}
    f0 = {0: [rng.random((24, 24, 3)).astype(np.float32)]}
    cache = PackedActivationCache()
    with obs.enabled():
        obs.configure(reset=True)
        _, c0, s0 = fleet_reuse_step(det, f0, grids, cache)   # cold
        _, c1, s1 = fleet_reuse_step(det, f0, grids, cache)   # all-static
    tiles = {k[0]: v for k, v in metrics.TILES.items()}
    assert tiles["total"] == s0.total_tiles + s1.total_tiles
    assert tiles["computed"] == s0.computed + s1.computed
    ev = {k[0]: v for k, v in metrics.CACHE_EVENTS.items()}
    assert ev["step"] == 2 and ev["cold_step"] == 1
    # the warm step served every non-recomputed tile from the cache
    assert ev["hit"] == s1.total_tiles - s1.computed
    assert metrics.CHANGED_FRACTION.value() == 0.0   # latest step static
    names = [e[0] for e in trace.events()]
    assert names.count("fleet_reuse_step") == 2
    # dispatch mirror stayed bit-compatible across both steps
    assert metrics.kernel_counts() == dict(c0 + c1)


# ---------------------------------------------------------------------------
# transport empty-distribution guards (satellite: zero-frame == 0.0)
# ---------------------------------------------------------------------------

def test_zero_frame_transport_stats_are_zero_not_nan():
    ts = empty_transport(3)
    assert ts.p50_s == 0.0 and ts.p99_s == 0.0 and ts.mean_s == 0.0
    assert ts.straggler_frac == 0.0 and ts.shed_bytes == 0.0
    for k in ts.parts:
        assert ts.part_p99(k) == 0.0
    assert ts.parts_mean() == {k: 0.0 for k in ts.parts}
    assert ts.frames_sent.shape == (3,)


def test_simulate_transport_degenerate_shapes_return_zero_stats():
    class _Cam:                       # never touched on the guard path
        cam_id = 0
    # no cameras at all (the (0, S) max-reduction used to raise)
    ts = simulate_transport([], [], None, np.zeros(0), None,
                            1.0, 10, 5, 10.0, 40.0, 100.0, 1e7)
    assert ts.latency_s.size == 0 and ts.p50_s == 0.0 and ts.p99_s == 0.0
    # cameras but a zero-segment window
    ts2 = simulate_transport([_Cam()], [0], None, np.zeros(1), None,
                             1.0, 10, 0, 10.0, 40.0, 100.0, 1e7)
    assert ts2.p50_s == 0.0 and ts2.part_p99("wait") == 0.0
    assert ts2.frames_sent.shape == (1,)


def test_merge_transport_empty_and_roundtrip():
    assert merge_transport([]).p99_s == 0.0
    m = merge_transport([empty_transport(1), empty_transport(2)])
    assert m.p50_s == 0.0 and m.frames_sent.shape == (3,)


# ---------------------------------------------------------------------------
# SLO panels
# ---------------------------------------------------------------------------

def _fake_transport():
    lat = np.linspace(0.1, 1.0, 100)
    parts = {k: lat / 5 for k in ("wait", "encode", "network",
                                  "batching", "inference")}
    return TransportStats(latency_s=lat, parts=parts,
                          frame_cam=np.zeros(100, np.int64),
                          bytes_total=6e6, bytes_base=1e7,
                          frames_sent=np.full(4, 25, np.int64),
                          straggler_frames=5, deadline_hits=3,
                          quality_min=0.8, shed_halo_bytes=3e6,
                          shed_body_bytes=1e6)


def test_fleet_slo_report_aggregates_and_serializes():
    steps = [slo.StepReport(step=i, wall_s=0.1 + 0.01 * i,
                            total_tiles=100, changed_tiles=20 + i,
                            computed_tiles=30 + i, launched_tiles=32,
                            cold=(i == 0), dispatches={"roi_conv_entry": 1})
             for i in range(4)]
    ts = _fake_transport()
    rep = slo.FleetSLOReport.build(steps=steps, transport=ts,
                                   accuracy_floor=0.97,
                                   accuracy_mean=0.99, n_windows=30)
    assert rep.p50_delay_s == pytest.approx(ts.p50_s)
    assert rep.p99_delay_s == pytest.approx(ts.p99_s)
    assert rep.deadline_hit_rate == pytest.approx(3 / 30)
    assert rep.shed_bytes == pytest.approx(4e6)
    assert rep.changed_tile_fraction == pytest.approx(
        sum(20 + i for i in range(4)) / 400)
    assert rep.steps[0].compute_fraction == pytest.approx(0.30)
    d = rep.to_dict()
    json.dumps(d)
    assert d["n_steps"] == 4 and len(d["steps"]) == 4
    assert d["part_p99_s"].keys() == ts.parts.keys()
    assert d["accuracy_floor"] == 0.97


def test_step_report_from_reuse_duck_types_sharded_stats():
    class _S:                          # ShardedReuseStats-shaped
        total_tiles, raw_changed, computed, launched = 10, 4, 6, 8
        cold_shards = 1
    r = slo.StepReport.from_reuse(2, 0.5, {"tile_delta_gate": 1}, _S())
    assert r.cold and r.changed_fraction == 0.4
