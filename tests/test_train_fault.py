"""Fault-tolerant training: checkpoint/restart continuation is bit-exact,
straggler monitor fires, int8 grad compression numerics stay close."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.data.lm import SyntheticLM
from repro.distributed.fault import FaultInjector, StragglerMonitor
from repro.train.loop import train


CFG = get_config("h2o-danube3-4b", smoke=True)
TCFG = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=12,
                   seed=0)


class _CycledLM(SyntheticLM):
    """Replays a small fixed batch set: 12 smoke steps on ever-fresh markov
    data sit at the noise floor (the band structure needs thousands of
    steps to generalize), so loss-decrease contracts use a revisited
    stream where optimization progress is actually observable."""

    def batch(self, step, **kw):
        return super().batch(step % 4, **kw)


def _cycled():
    return _CycledLM(CFG.vocab_size, 64, 4, seed=0)


def test_loss_decreases():
    rep = train(CFG, TCFG, steps=12, batch_shape=(4, 64), data=_cycled(),
                verbose=False)
    assert rep.steps_run == 12
    assert rep.losses[-1] < rep.losses[0]


def test_fault_restore_is_bit_exact(tmp_path):
    clean = train(CFG, TCFG, steps=10, batch_shape=(4, 64), verbose=False)
    faulted = train(CFG, TCFG, steps=10, batch_shape=(4, 64),
                    workdir=str(tmp_path), ckpt_every=4,
                    injector=FaultInjector((7,)), verbose=False)
    assert faulted.restarts == 1
    # deterministic data replay + deterministic compute => same trajectory
    assert np.allclose(clean.losses[-1], faulted.losses[-1], rtol=1e-5), \
        (clean.losses[-1], faulted.losses[-1])


def test_fault_without_checkpointing_raises():
    from repro.distributed.fault import InjectedFault
    with pytest.raises(InjectedFault):
        train(CFG, TCFG, steps=10, batch_shape=(4, 64),
              injector=FaultInjector((3,)), verbose=False)


def test_microbatch_matches_full_batch():
    t1 = train(CFG, TCFG, steps=3, batch_shape=(4, 64), verbose=False)
    tcfg2 = dataclasses.replace(TCFG, microbatch=2)
    t2 = train(CFG, tcfg2, steps=3, batch_shape=(4, 64), verbose=False)
    # same data, grads averaged over microbatches: trajectories agree
    assert np.allclose(t1.losses[0], t2.losses[0], rtol=1e-4)
    assert np.allclose(t1.losses[-1], t2.losses[-1], rtol=2e-2)


def test_int8_grad_compression_tracks_fp32():
    """int8-quantized grads must track the uncompressed trajectory: final
    loss within 5% after 12 steps (per-row scaling keeps error ~0.4%)."""
    tcfg = dataclasses.replace(TCFG, grad_compression="int8")
    comp = train(CFG, tcfg, steps=12, batch_shape=(4, 64), data=_cycled(),
                 verbose=False)
    clean = train(CFG, TCFG, steps=12, batch_shape=(4, 64), data=_cycled(),
                  verbose=False)
    assert comp.losses[-1] < comp.losses[0]          # it does train
    assert comp.losses[-1] < clean.losses[-1] * 1.05


def test_straggler_monitor():
    mon = StragglerMonitor(window=10, tolerance=2.0, min_samples=3)
    import time
    for i in range(5):
        mon.start()
        time.sleep(0.01)
        assert not mon.stop(i)
    mon.start()
    time.sleep(0.1)           # 10x the median: flagged
    assert mon.stop(5)
    assert len(mon.events) == 1
