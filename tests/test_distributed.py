"""Distributed correctness at small device counts.

Device-count-dependent tests run in subprocesses (XLA locks the platform
device count at first init; the main test process stays single-device).
Each subprocess script asserts internally and exits nonzero on failure.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_config
from repro.distributed.shardings import param_pspecs
from repro.models.params import param_specs

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# sharding spec units (no devices needed)
# ---------------------------------------------------------------------------

def test_param_pspecs_tp_roles():
    cfg = get_config("deepseek-67b")
    specs = param_specs(cfg)
    ps = param_pspecs(cfg, specs, "tp")
    assert ps["blocks_wq"] == P(None, None, "model")
    assert ps["blocks_wo"] == P(None, "model", None)
    assert ps["blocks_w2"] == P(None, "model", None)
    assert ps["embed"] == P("model", None)
    assert ps["final_norm"] == P()


def test_param_pspecs_fsdp_adds_data_axis():
    cfg = get_config("deepseek-67b")
    specs = param_specs(cfg)
    ps = param_pspecs(cfg, specs, "fsdp")
    spec = ps["blocks_w1"]
    flat = [a for entry in spec if entry is not None
            for a in (entry if isinstance(entry, tuple) else (entry,))]
    assert "model" in flat and "data" in flat


def test_param_pspecs_expert_sharding():
    cfg = get_config("qwen3-moe-235b-a22b")
    ps = param_pspecs(cfg, param_specs(cfg), "tp")
    assert ps["blocks_moe_wg"] == P(None, "model", None, None)


def test_param_pspecs_indivisible_vocab_replicates():
    cfg = get_config("whisper-small")           # vocab 51865
    ps = param_pspecs(cfg, param_specs(cfg), "tp")
    assert ps["embed"] == P()


# ---------------------------------------------------------------------------
# multi-device subprocess tests
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import TrainConfig
    from repro.configs.registry import get_config
    from repro.data.lm import SyntheticLM
    from repro.train.loop import make_train_step, init_state

    cfg = get_config("h2o-danube3-4b", smoke=True)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=5,
                       sharding_mode="fsdp")
    data = SyntheticLM(cfg.vocab_size, 64, 4, seed=0)

    # single device
    s0 = init_state(cfg, tcfg, jax.random.PRNGKey(0))
    f0 = make_train_step(cfg, tcfg)
    losses0 = []
    for i in range(3):
        s0, m = f0(s0, data.batch(i))
        losses0.append(float(m["loss"]))

    # 2x4 mesh
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    s1 = init_state(cfg, tcfg, jax.random.PRNGKey(0), mesh)
    f1 = make_train_step(cfg, tcfg, mesh)
    losses1 = []
    for i in range(3):
        s1, m = f1(s1, data.batch(i))
        losses1.append(float(m["loss"]))
    np.testing.assert_allclose(losses0, losses1, rtol=2e-2), (losses0, losses1)
    print("OK", losses0, losses1)
    """)


@pytest.mark.slow
def test_moe_shard_map_matches_dense_oracle():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.registry import get_config
    from repro.models.moe import moe_layer
    from repro.distributed.shardings import make_dist

    cfg = get_config("deepseek-moe-16b", smoke=True)
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 32, D)) * 0.3, jnp.float32)
    rw = jnp.asarray(rng.normal(size=(D, E)) * 0.2, jnp.float32)
    wg = jnp.asarray(rng.normal(size=(E, D, F)) * 0.05, jnp.float32)
    wu = jnp.asarray(rng.normal(size=(E, D, F)) * 0.05, jnp.float32)
    wd = jnp.asarray(rng.normal(size=(E, F, D)) * 0.05, jnp.float32)

    y0, aux0, _ = moe_layer(x, rw, wg, wu, wd, cfg, None)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    dist = make_dist(mesh)
    assert dist.manual_moe
    y1, aux1, _ = jax.jit(lambda *a: moe_layer(*a, cfg, dist))(
        x, rw, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(float(aux0), float(aux1), rtol=1e-5)
    print("OK moe match")
    """)


@pytest.mark.slow
def test_int8_allreduce_on_dp_mesh():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.compression import int8_allreduce_mean

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    g_all = rng.normal(size=(8, 64, 32)).astype(np.float32)
    # per-shard grads: shard over data
    g = jax.device_put(jnp.asarray(g_all.reshape(8 * 64, 32)),
                       NamedSharding(mesh, P("data", None)))
    out = int8_allreduce_mean({"w": g}, mesh, {"w": P("data", None)})
    # each shard's value ~= mean over shards of its own (identity here:
    # psum over data of a data-sharded tensor reduces per-shard blocks?)
    # contract: quantize/dequantize error < 2%
    print("OK int8 allreduce ran", jax.tree.leaves(out)[0].shape)
    """)


@pytest.mark.slow
def test_debug_mesh_dryrun_decode():
    _run("""
    import jax
    from repro.configs.base import ShapeCell
    from repro.configs.registry import get_config
    from repro.launch.steps import build_decode
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("h2o-danube3-4b", smoke=True)
    cell = ShapeCell("d", 512, 8, "decode")
    fn, args, _ = build_decode(cfg, cell, mesh)
    c = fn.lower(*args).compile()
    assert c.memory_analysis().temp_size_in_bytes >= 0
    print("OK debug-mesh decode compiled")
    """)
