"""Stay-packed execution path: structural + edge-case contracts.

Covers the packed-resident conv chain (one gather, N packed layers, one
scatter), neighbor-table halo correctness, causal block skipping in the
packed-prefill attention, pack/unpack degenerate keeps, and the batched
group decode in the serving engine.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.roi_attention import PAD_POS
from repro.serving.detector import DetectorConfig, RoIDetector


def _rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# pack / unpack edge cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S", [16, 31, 32, 48, 65])
def test_pack_unpack_all_false(S):
    x = jnp.asarray(_rng(S).normal(size=(S, 3)), jnp.float32)
    keep = jnp.zeros(S, bool)
    packed, positions, n_kept = ops.pack_tokens(x, keep, block=32)
    assert int(n_kept) == 0
    assert packed.shape[0] % 32 == 0
    assert (np.asarray(positions) == int(PAD_POS)).all()
    restored = ops.unpack_tokens(packed, positions, S)
    np.testing.assert_array_equal(np.asarray(restored), np.zeros((S, 3)))


@pytest.mark.parametrize("S", [16, 31, 32, 48, 65])
def test_pack_unpack_all_true(S):
    x = jnp.asarray(_rng(S + 1).normal(size=(S, 3)), jnp.float32)
    keep = jnp.ones(S, bool)
    packed, positions, n_kept = ops.pack_tokens(x, keep, block=32)
    assert int(n_kept) == S
    np.testing.assert_array_equal(np.asarray(packed[:S]), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(positions[:S]), np.arange(S))
    assert (np.asarray(positions[S:]) == int(PAD_POS)).all()
    restored = ops.unpack_tokens(packed, positions, S)
    np.testing.assert_array_equal(np.asarray(restored), np.asarray(x))


def test_pack_non_multiple_block_positions_monotone():
    S = 45                                     # not a multiple of 32
    rng = _rng(7)
    x = jnp.asarray(rng.normal(size=(S, 2)), jnp.float32)
    keep = jnp.asarray(rng.random(S) < 0.5)
    packed, positions, n_kept = ops.pack_tokens(x, keep, block=32)
    assert packed.shape[0] == 64
    real = np.asarray(positions[:int(n_kept)])
    assert (np.diff(real) > 0).all(), "kept rows must stay in original order"


# ---------------------------------------------------------------------------
# packed-resident conv
# ---------------------------------------------------------------------------

def test_roi_conv_packed_matches_scatter_oracle():
    """Packed chain == scatter-to-zeros -> conv -> gather, any mask."""
    rng = _rng(1)
    grid = rng.random((5, 7)) < 0.45
    grid[2, 3] = True
    idx = ops.mask_to_indices(grid)
    nbr = jnp.asarray(ops.neighbor_table(idx, grid.shape))
    th = tw = 8
    packed = jnp.asarray(rng.normal(size=(idx.shape[0], th, tw, 4)),
                         jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 6)) * 0.2, jnp.float32)
    out = ops.roi_conv_packed(packed, w, nbr)
    expect = ref.roi_conv_packed(packed, jnp.asarray(idx), grid.shape, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-4)


def test_roi_conv_packed_interior_matches_dense():
    """Interior tile (all 8 neighbors active): packed == dense conv."""
    rng = _rng(2)
    grid = np.zeros((4, 4), bool)
    grid[0:3, 0:3] = True                      # (1,1) is interior
    idx = ops.mask_to_indices(grid)
    nbr = jnp.asarray(ops.neighbor_table(idx, grid.shape))
    th = tw = 8
    packed = jnp.asarray(rng.normal(size=(idx.shape[0], th, tw, 4)),
                         jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 4)) * 0.3, jnp.float32)
    # dense oracle over the scattered frame
    base = jnp.zeros((32, 32, 4), jnp.float32)
    full = ref.sbnet_scatter(packed, jnp.asarray(idx), base, th, tw)
    dense = jax.lax.conv_general_dilated(
        full[None], w, (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    out = ops.roi_conv_packed(packed, w, nbr)
    slot = {(int(y), int(x)): i for i, (y, x) in enumerate(idx)}
    i11 = slot[(1, 1)]
    np.testing.assert_allclose(np.asarray(out[i11]),
                               np.asarray(dense[8:16, 8:16]), atol=1e-4)


def test_roi_conv_packed_isolated_tile_zero_halo():
    """A tile with NO active neighbors sees an all-zero halo."""
    rng = _rng(3)
    grid = np.zeros((3, 3), bool)
    grid[1, 1] = True
    idx = ops.mask_to_indices(grid)
    nbr_np = ops.neighbor_table(idx, grid.shape)
    assert (nbr_np == -1).all()
    th = tw = 8
    packed = jnp.asarray(rng.normal(size=(1, th, tw, 3)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 3, 5)) * 0.3, jnp.float32)
    out = ops.roi_conv_packed(packed, w, jnp.asarray(nbr_np))
    # oracle: zero-pad the lone tile and convolve
    xp = jnp.pad(packed[0], ((1, 1), (1, 1), (0, 0)))
    expect = jax.lax.conv_general_dilated(
        xp[None], w, (1, 1), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(expect),
                               atol=1e-4)


def test_neighbor_table_frame_boundary():
    """Corner tile: off-frame neighbors are -1, in-frame active ones map
    to their packed slots."""
    grid = np.ones((2, 2), bool)
    idx = ops.mask_to_indices(grid)            # row-major: (0,0)(0,1)(1,0)(1,1)
    nbr = ops.neighbor_table(idx, grid.shape)
    # tile (0,0): NW,N,NE,W off-frame; E=(0,1) slot 1, SW off, S=(1,0) slot 2,
    # SE=(1,1) slot 3
    np.testing.assert_array_equal(nbr[0], [-1, -1, -1, -1, 1, -1, 2, 3])
    # tile (1,1): NW=(0,0) slot 0, N=(0,1)... mirrored
    np.testing.assert_array_equal(nbr[3], [0, 1, -1, 2, -1, -1, -1, -1])


# ---------------------------------------------------------------------------
# one gather / one scatter structure of the detector stack
# ---------------------------------------------------------------------------

def test_roi_forward_one_gather_one_scatter():
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    rng = _rng(4)
    gy, gx = 5, 6
    grid = np.zeros((gy, gx), bool)
    grid[1:4, 1:5] = True
    x = jnp.asarray(rng.normal(size=(gy * 16, gx * 16, 3)), jnp.float32)
    ops.KERNEL_COUNTS.clear()
    roi = det.roi_forward(x, grid)
    counts = dict(ops.KERNEL_COUNTS)
    assert counts.get("roi_conv_entry", 0) == 1      # the (fused) gather
    assert counts.get("roi_conv_stack", 0) == 1      # ALL remaining layers
    assert counts.get("sbnet_scatter", 0) == 1       # the scatter
    assert counts.get("sbnet_gather", 0) == 0        # no per-layer re-slice
    assert counts.get("roi_conv_packed", 0) == 0     # no per-layer launches
    assert sum(counts.values()) <= 3                 # constant dispatches
    # packed output matches the dense path on interior tiles to <= 1e-4
    dense = det.dense_forward(x)
    t = det.cfg.tile
    checked = 0
    for ty in range(1, gy - 1):
        for tx in range(1, gx - 1):
            if grid[ty - 1:ty + 2, tx - 1:tx + 2].all():
                a = np.asarray(dense[ty * t:(ty + 1) * t,
                                     tx * t:(tx + 1) * t])
                b = np.asarray(roi[ty * t:(ty + 1) * t, tx * t:(tx + 1) * t])
                assert np.abs(a - b).max() <= 1e-4
                checked += 1
    assert checked >= 2
    # non-RoI regions stay zero
    for ty in range(gy):
        for tx in range(gx):
            if not grid[ty, tx]:
                blk = np.asarray(roi[ty * t:(ty + 1) * t,
                                     tx * t:(tx + 1) * t])
                assert np.abs(blk).max() == 0.0


def test_roi_forward_matches_legacy_scatter_chain():
    """The packed chain must equal the old per-layer scatter/gather chain
    on EVERY tile (inactive-neighbor halos are zero in both regimes)."""
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(1))
    rng = _rng(5)
    gy, gx = 4, 5
    grid = rng.random((gy, gx)) < 0.5
    grid[2, 2] = True
    x = jnp.asarray(rng.normal(size=(gy * 16, gx * 16, 3)), jnp.float32)
    roi = det.roi_forward(x, grid)
    # legacy chain: per-layer fused conv + full-frame scatter
    idx = jnp.asarray(ops.mask_to_indices(grid))
    t = det.cfg.tile
    xl = x
    for w in det.weights:
        packed = ops.roi_conv(xl, w, idx, t, t)
        packed = jax.nn.relu(packed)
        base = jnp.zeros(x.shape[:2] + (w.shape[-1],), packed.dtype)
        xl = ops.sbnet_scatter(packed, idx, base)
    legacy = xl @ det.head
    np.testing.assert_allclose(np.asarray(roi), np.asarray(legacy),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# causal block skipping
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("keep_frac", [0.25, 0.6])
def test_block_skip_bitwise_equal(keep_frac):
    rng = _rng(6)
    S, H, D, bq, bk = 256, 2, 32, 32, 32
    q = jnp.asarray(rng.normal(size=(S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(S, H, D)), jnp.float32)
    n_kept = int(keep_frac * S)
    pos = np.full(S, int(PAD_POS), np.int32)
    pos[:n_kept] = np.sort(rng.choice(4 * S, n_kept, replace=False))
    pos = jnp.asarray(pos)
    out_skip, visited = ops.roi_attention(q, k, v, pos, block_q=bq,
                                          block_k=bk, causal_skip=True,
                                          return_stats=True)
    out_full = ops.roi_attention(q, k, v, pos, block_q=bq, block_k=bk,
                                 causal_skip=False)
    # bitwise equality on real rows
    assert (np.asarray(out_skip[:n_kept])
            == np.asarray(out_full[:n_kept])).all()
    # and against the dense reference
    expect = ref.roi_attention(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out_skip[:n_kept]),
                               np.asarray(expect[:n_kept]), atol=2e-5)
    # visited counts match the host-side bound and skip the dead blocks
    vis = np.asarray(visited)
    bound = ops.attention_visit_bound(np.asarray(pos), bq, bk)
    for h in range(H):
        np.testing.assert_array_equal(vis[h], bound)
    nq, nk = S // bq, S // bk
    visited_frac = vis[0].sum() / (nq * nk)
    exhaustive_frac = 1.0
    assert visited_frac < 0.3 * exhaustive_frac if keep_frac <= 0.25 \
        else visited_frac < 0.75


def test_block_skip_quarter_keep_tracks_lower_triangle():
    """Acceptance: at 25% keep, visited blocks ~ the causal lower-tri
    fraction of the real prefix, not the full quadratic walk."""
    rng = _rng(8)
    S, H, D, bq, bk = 512, 1, 16, 64, 64
    n_kept = S // 4
    pos = np.full(S, int(PAD_POS), np.int32)
    pos[:n_kept] = np.arange(n_kept) * 3          # monotone original order
    q = jnp.asarray(rng.normal(size=(S, H, D)), jnp.float32)
    out, visited = ops.roi_attention(q, q, q, jnp.asarray(pos), block_q=bq,
                                     block_k=bk, return_stats=True)
    vis = np.asarray(visited)[0]
    nq, nk = S // bq, S // bk
    real_q = -(-n_kept // bq)
    lower_tri = real_q * (real_q + 1) // 2
    assert vis.sum() == lower_tri                 # exact causal prefix
    assert vis.sum() / (nq * nk) <= 0.10          # vs 1.0 exhaustive


def test_block_skip_all_padding_stream():
    """keep = all-False: every k-block is dead; kernel visits nothing."""
    S, H, D = 128, 1, 16
    pos = jnp.full((S,), int(PAD_POS), jnp.int32)
    q = jnp.ones((S, H, D), jnp.float32)
    out, visited = ops.roi_attention(q, q, q, pos, block_q=64, block_k=64,
                                     return_stats=True)
    assert int(np.asarray(visited).sum()) == 0
    assert float(jnp.abs(out).max()) == 0.0


# ---------------------------------------------------------------------------
# cost model flows through
# ---------------------------------------------------------------------------

def test_server_model_amortized_overhead():
    from repro.core.pipeline import ServerModel
    sm = ServerModel()
    assert sm.sbnet_overhead == pytest.approx(sm.io_round_trip
                                              / sm.num_layers)
    assert sm.sbnet_overhead <= 0.30 / sm.num_layers
    # packed regime beats the per-layer regime at every sub-switch density
    legacy = ServerModel(num_layers=1)
    for d in (0.1, 0.3, 0.5):
        assert sm.speedup(d) > legacy.speedup(d)
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    assert det.speedup_estimate(0.2) == pytest.approx(sm.speedup(0.2))
