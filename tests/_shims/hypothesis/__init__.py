"""Minimal, deterministic stand-in for the ``hypothesis`` library.

The container image does not ship ``hypothesis`` and installing packages is
off-limits, so ``conftest.py`` puts this shim on ``sys.path`` *only when the
real library is absent*.  It implements the tiny slice of the API the test
suite uses — ``given``/``settings`` plus the ``integers``/``floats``/
``sets``/``composite`` strategies and ``hypothesis.extra.numpy`` arrays —
as a seeded-RNG example sampler.  Properties are exercised on
``max_examples`` deterministic samples (seed = example index), so failures
reproduce exactly across runs; there is no shrinking.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import numpy as _np

__version__ = "0.0-repro-shim"


class Strategy:
    """A sampleable value source: ``example(rng)`` -> concrete value."""

    def __init__(self, sample: Callable[[_np.random.Generator], Any],
                 label: str = "strategy"):
        self._sample = sample
        self._label = label

    def example(self, rng: _np.random.Generator) -> Any:
        return self._sample(rng)

    def __repr__(self):  # pragma: no cover - debug aid
        return f"<shim {self._label}>"


class strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> Strategy:
        return Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            f"integers({min_value}, {max_value})")

    @staticmethod
    def floats(min_value: float, max_value: float) -> Strategy:
        return Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)),
            f"floats({min_value}, {max_value})")

    @staticmethod
    def booleans() -> Strategy:
        return Strategy(lambda rng: bool(rng.integers(0, 2)), "booleans()")

    @staticmethod
    def lists(elements: Strategy, min_size: int = 0,
              max_size: int | None = None) -> Strategy:
        def sample(rng):
            hi = min_size + 8 if max_size is None else max_size
            size = min_size if hi == min_size \
                else int(rng.integers(min_size, hi + 1))
            return [elements.example(rng) for _ in range(size)]
        return Strategy(sample, f"lists(min={min_size}, max={max_size})")

    @staticmethod
    def sets(elements: Strategy, min_size: int = 0,
             max_size: int | None = None) -> Strategy:
        def sample(rng):
            size = min_size if max_size is None or max_size == min_size \
                else int(rng.integers(min_size, max_size + 1))
            out: set = set()
            # rejection-sample until the set reaches the requested size;
            # bounded attempts keep pathological element spaces from hanging
            for _ in range(200 * max(size, 1)):
                if len(out) >= size:
                    break
                out.add(elements.example(rng))
            return out
        return Strategy(sample, f"sets(min={min_size}, max={max_size})")

    @staticmethod
    def composite(fn: Callable) -> Callable[..., Strategy]:
        @functools.wraps(fn)
        def factory(*args, **kwargs) -> Strategy:
            def sample(rng):
                return fn(lambda strat: strat.example(rng), *args, **kwargs)
            return Strategy(sample, f"composite({fn.__name__})")
        return factory


# module-style alias so ``from hypothesis import strategies as st`` works
st = strategies


def settings(max_examples: int = 20, deadline=None, **_ignored):
    """Record run parameters on the (possibly already-wrapped) test fn."""
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strats: Strategy, **kw_strats: Strategy):
    def deco(fn):
        # NOTE: no functools.wraps — pytest must see a zero-arg signature,
        # otherwise it treats the property arguments as fixtures.
        def wrapper():
            n = getattr(wrapper, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples", 20))
            for i in range(n):
                rng = _np.random.default_rng(0xC0FFEE + i)
                vals = [s.example(rng) for s in strats]
                kwvals = {k: s.example(rng) for k, s in kw_strats.items()}
                try:
                    fn(*vals, **kwvals)
                except Exception as e:  # noqa: BLE001 - annotate and re-raise
                    raise AssertionError(
                        f"property failed on shim example {i}: "
                        f"args={vals!r} kwargs={kwvals!r}") from e
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


__all__ = ["given", "settings", "strategies", "st", "Strategy"]
