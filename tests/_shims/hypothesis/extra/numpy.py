"""Shim for ``hypothesis.extra.numpy``: ``arrays`` + ``array_shapes``."""
from __future__ import annotations

import numpy as _np

from hypothesis import Strategy


def array_shapes(min_dims: int = 1, max_dims: int = 3, min_side: int = 1,
                 max_side: int = 10) -> Strategy:
    def sample(rng):
        nd = int(rng.integers(min_dims, max_dims + 1))
        return tuple(int(rng.integers(min_side, max_side + 1))
                     for _ in range(nd))
    return Strategy(sample, "array_shapes")


def arrays(dtype, shape) -> Strategy:
    dt = _np.dtype(dtype)

    def sample(rng):
        shp = shape.example(rng) if isinstance(shape, Strategy) else shape
        if dt == _np.bool_:
            return rng.random(shp) < rng.uniform(0.1, 0.9)
        if _np.issubdtype(dt, _np.integer):
            info = _np.iinfo(dt)
            lo, hi = max(info.min, -1000), min(info.max, 1000)
            return rng.integers(lo, hi + 1, size=shp).astype(dt)
        return rng.normal(size=shp).astype(dt)
    return Strategy(sample, f"arrays({dt}, ...)")
