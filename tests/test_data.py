"""Data pipeline: determinism, shard slicing, learnable structure."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data.lm import SyntheticLM


def test_batches_deterministic():
    d1 = SyntheticLM(1000, 64, 8, seed=3)
    d2 = SyntheticLM(1000, 64, 8, seed=3)
    for step in (0, 1, 17):
        a, b = d1.batch(step), d2.batch(step)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))


def test_steps_differ():
    d = SyntheticLM(1000, 64, 8, seed=0)
    a, b = d.batch(0), d.batch(1)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(b["tokens"]))


def test_labels_are_shifted_tokens():
    d = SyntheticLM(1000, 64, 4, seed=1)
    b = d.batch(0)
    # tokens[t+1] == labels[t] by construction
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_markov_band_structure():
    d = SyntheticLM(1000, 128, 8, seed=2, band=16)
    b = d.batch(0)
    toks = np.asarray(b["tokens"])
    steps = (toks[:, 1:] - toks[:, :-1]) % 1000
    steps = np.minimum(steps, 1000 - steps)
    # outside the repeated span, consecutive tokens stay within the band
    frac_in_band = float((steps <= 16).mean())
    assert frac_in_band > 0.7


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 50))
def test_shard_slices_are_disjoint_partitions(step):
    """Property: sharded batches tile the global batch (replay invariant)."""
    full = SyntheticLM(500, 32, 8, seed=4).batch(step)
    parts = [SyntheticLM(500, 32, 8, seed=4).batch(step, shard=s,
                                                   num_shards=4)
             for s in range(4)]
    for p in parts:
        assert p["tokens"].shape == (2, 32)
    # determinism across shards: same shard twice is identical
    again = SyntheticLM(500, 32, 8, seed=4).batch(step, shard=2,
                                                  num_shards=4)
    np.testing.assert_array_equal(np.asarray(parts[2]["tokens"]),
                                  np.asarray(again["tokens"]))
