"""AdamW, schedule, clipping."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.optim.adamw import (adamw_init, adamw_update, clip_by_global_norm,
                               cosine_schedule)


def test_adamw_minimizes_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, weight_decay=0.0,
                       warmup_steps=5, total_steps=200)
    target = jnp.asarray(np.random.default_rng(0).normal(size=(4, 4)))
    params = {"w": jnp.zeros((4, 4))}
    state = adamw_init(params)

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(150):
        grads = jax.grad(loss_fn)(params)
        params, state, _ = adamw_update(params, grads, state, tcfg)
    assert float(loss_fn(params)) < 1e-2


def test_schedule_warmup_and_decay():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(cosine_schedule(tcfg, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] < lrs[2]
    assert abs(lrs[2] - 1e-3) < 1e-9          # peak at end of warmup
    assert lrs[3] < lrs[2]
    assert abs(lrs[4] - 1e-4) < 1e-9          # floor = 0.1 * peak


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0), "b": jnp.full((10,), 10.0)}
    clipped, gn = clip_by_global_norm(g, max_norm=1.0)
    assert abs(float(gn) - np.sqrt(2000.0)) < 1e-3
    total = sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(clipped))
    assert abs(total - 1.0) < 1e-4


def test_weight_decay_mask_skips_1d():
    tcfg = TrainConfig(learning_rate=0.0, weight_decay=1.0)
    # lr=0: params must not move regardless of decay
    params = {"w": jnp.ones((3, 3)), "norm": jnp.ones((3,))}
    state = adamw_init(params)
    grads = jax.tree.map(jnp.zeros_like, params)
    new_p, _, _ = adamw_update(params, grads, state, tcfg)
    assert jnp.allclose(new_p["w"], params["w"])
    assert jnp.allclose(new_p["norm"], params["norm"])
