"""Persistent output canvas: changed-only scatter == composite scatter
(property, incl. empty/full extremes and a drift re-solve shrinking the
active set mid-sequence), canvas-resident references bit-equivalent to
the packed-window oracle at every threshold, zero-copy all-static
steps, per-tile epoch tracking, and per-tile-class gate thresholds."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from hypothesis import given, settings, strategies as st

from repro.fleet import sharded_fleet_step
from repro.fleet.runtime import fleet_reuse_step
from repro.fleet.sharded import ShardedSuperlaunch
from repro.kernels import ops
from repro.launch.mesh import make_fleet_mesh
from repro.serving.detector import (DetectorConfig, N_TILE_CLASSES,
                                    PackedActivationCache, RoIDetector,
                                    TILE_CLASS_BODY, TILE_CLASS_HALO,
                                    gate_changed_rows, ref_advance_rows,
                                    tile_class_rows)


def _rng(seed):
    return np.random.default_rng(seed)


def _ragged_fleet_idx(rng, t):
    """A ragged multi-camera fleet: per-camera grid shapes differ, the
    shared canvas is sized at the maxima.  Returns (idx (n, 3) int32,
    canvas shape (C, H, W))."""
    n_cams = int(rng.integers(2, 5))
    shapes = [(int(rng.integers(1, 4)), int(rng.integers(1, 4)))
              for _ in range(n_cams)]
    rows = []
    for cam, (gy, gx) in enumerate(shapes):
        g = rng.random((gy, gx)) < 0.7
        g[0, 0] = True                          # never an empty camera
        for ty in range(gy):
            for tx in range(gx):
                if g[ty, tx]:
                    rows.append((cam, ty, tx))
    H = max(gy for gy, _ in shapes) * t
    W = max(gx for _, gx in shapes) * t
    return np.asarray(rows, np.int32), (n_cams, H, W)


# ---------------------------------------------------------------------------
# property: changed-only scatter == full composite scatter, bit for bit
# ---------------------------------------------------------------------------

@given(st.integers(0, 10 ** 6))
@settings(max_examples=25, deadline=None)
def test_changed_scatter_matches_composite_property(seed):
    """For any ragged fleet and any changed subset (empty and full
    included), scattering ONLY the changed rows onto the previous
    canvas is bit-identical to composite-scattering the full updated
    tile set into zeros — the unchanged-tile passthrough contract."""
    rng = _rng(seed)
    t, A = 4, 3
    idx, (C, H, W) = _ragged_fleet_idx(rng, t)
    n = idx.shape[0]
    zeros = jnp.zeros((C, H, W, A), jnp.float32)
    heads_old = rng.normal(size=(n, t, t, A)).astype(np.float32)
    canvas_old = ops.sbnet_scatter_fleet(jnp.asarray(heads_old),
                                         jnp.asarray(idx), zeros)
    # changed subset: forced empty / full on some seeds, random otherwise
    if seed % 5 == 0:
        changed = np.zeros(n, bool)
    elif seed % 5 == 1:
        changed = np.ones(n, bool)
    else:
        changed = rng.random(n) < rng.uniform(0.1, 0.9)
    heads_new = heads_old.copy()
    heads_new[changed] = rng.normal(
        size=(int(changed.sum()), t, t, A)).astype(np.float32)
    with ops.count_kernels() as c:
        inc = ops.sbnet_scatter_changed(jnp.asarray(heads_new[changed]),
                                        jnp.asarray(idx[changed]),
                                        canvas_old)
    full = ops.sbnet_scatter_fleet(jnp.asarray(heads_new),
                                   jnp.asarray(idx), zeros)
    np.testing.assert_array_equal(np.asarray(inc), np.asarray(full))
    if not changed.any():
        # empty compute set: ZERO dispatches, the canvas passes through
        assert sum(c.values()) == 0, dict(c)
        assert inc is canvas_old
    else:
        assert c["sbnet_scatter_changed"] == 1, dict(c)


def test_empty_compute_set_short_circuits_to_zero_dispatches():
    """``sbnet_scatter_changed``/``sbnet_scatter_fleet``/
    ``roi_conv_entry`` with an empty row set launch NOTHING — no
    dispatch recorded, no kernel built."""
    t, A = 4, 3
    base = jnp.ones((2, 2 * t, 2 * t, A), jnp.float32)
    x = jnp.zeros((2, 2 * t, 2 * t, 3), jnp.float32)
    w = jnp.zeros((3, 3, 3, A), jnp.float32)
    empty_rows = jnp.zeros((0, t, t, A), jnp.float32)
    empty_idx = jnp.zeros((0, 3), jnp.int32)
    with ops.count_kernels() as c:
        out_ch = ops.sbnet_scatter_changed(empty_rows, empty_idx, base)
        out_fl = ops.sbnet_scatter_fleet(empty_rows, empty_idx, base)
        out_cv = ops.roi_conv_entry(x, w, empty_idx, t, t)
    assert sum(c.values()) == 0, dict(c)
    assert out_ch is base and out_fl is base
    assert out_cv.shape == (0, t, t, A)


# ---------------------------------------------------------------------------
# canvas-resident references == packed-window oracle, every threshold
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def det():
    return RoIDetector(DetectorConfig(tile=8, channels=(4, 6)),
                       jax.random.PRNGKey(0))


def _mk_fleet(rng, t, spec):
    """spec: {gid: [grid shapes]} -> (frames, grids)."""
    grids, frames = {}, {}
    for gid, shapes in spec.items():
        gs, fs = [], []
        for gy, gx in shapes:
            g = rng.random((gy, gx)) < 0.7
            g[0, 0] = True
            gs.append(g)
            fs.append(rng.normal(size=(gy * t, gx * t, 3)
                                 ).astype(np.float32))
        grids[gid], frames[gid] = gs, fs
    return frames, grids


def _as_jnp(frames):
    return {g: [jnp.asarray(f) for f in fs] for g, fs in frames.items()}


@pytest.mark.parametrize("threshold", [0.0, 40.0, 1e9])
def test_ref_modes_bit_equal_at_every_threshold(det, threshold):
    """Canvas-resident references + epoch tracking serve BIT-identical
    heads to the legacy packed-window path at exact (0), lossy (40
    bytes) and everything-reused (1e9) thresholds, over a trace whose
    motion stays in tile interiors (the regime where the two reference
    layouts are defined to agree) plus all-static repeats."""
    t = det.cfg.tile
    rng = _rng(3)
    frames, grids = _mk_fleet(rng, t, {0: [(3, 4), (2, 2)], 1: [(4, 3)]})
    c_canvas = PackedActivationCache(ref_mode="canvas")
    c_packed = PackedActivationCache(ref_mode="packed")
    cur = frames
    for step in range(6):
        if step % 3 == 2:
            pass                                # all-static repeat
        else:
            cur = {g: [f.copy() for f in fs] for g, fs in cur.items()}
            gid = int(rng.integers(2))
            f = cur[gid][0]
            ty = int(rng.integers(f.shape[0] // t))
            tx = int(rng.integers(f.shape[1] // t))
            # interior bump: the tile's rim pixels stay bit-static
            f[ty * t + 2:ty * t + t - 2,
              tx * t + 2:tx * t + t - 2, :] += \
                rng.normal(size=(t - 4, t - 4, 3)).astype(np.float32)
        got_c, _, st_c = fleet_reuse_step(det, _as_jnp(cur), grids,
                                          c_canvas, threshold)
        got_p, _, st_p = fleet_reuse_step(det, _as_jnp(cur), grids,
                                          c_packed, threshold)
        assert (st_c.raw_changed, st_c.computed) == \
            (st_p.raw_changed, st_p.computed)
        for gid in grids:
            for a, b in zip(got_c[gid], got_p[gid]):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b))
        if threshold == 0.0 and step > 0:
            # threshold 0 == full recompute, bit for bit
            for gid in grids:
                legacy = det.fleet_forward_layers(
                    [jnp.asarray(f) for f in cur[gid]], grids[gid])
                for a, b in zip(got_c[gid], legacy):
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))


def test_epoch_tracking_advances_only_refreshed_tiles(det):
    t = det.cfg.tile
    rng = _rng(4)
    frames, grids = _mk_fleet(rng, t, {0: [(3, 3)]})
    cache = PackedActivationCache()
    fleet_reuse_step(det, _as_jnp(frames), grids, cache)  # cold seed
    assert (cache.epoch_np == 0).all()
    # scalar threshold 0: every reference advances every step
    fleet_reuse_step(det, _as_jnp(frames), grids, cache, 0.0)
    full_epoch = cache.steps
    assert (cache.epoch_np == full_epoch).all()
    # lossy threshold, one changed tile: ONLY its epoch moves
    thr = 40.0
    cur = {0: [frames[0][0].copy()]}
    cur[0][0][2:t - 2, 2:t - 2, :] += 50.0                # tile (0, 0)
    _, _, st = fleet_reuse_step(det, _as_jnp(cur), grids, cache, thr)
    moved = cache.epoch_np == cache.steps
    kept = cache.epoch_np == full_epoch
    assert 1 <= st.raw_changed <= st.changed_out < st.total_tiles
    # refreshed rows == the dilated changed-OUTPUT set, nothing more
    assert moved.sum() == st.changed_out and kept.sum() == \
        cache.epoch_np.size - st.changed_out
    # all-static step under the lossy gate: no epoch moves, 0 bytes
    _, counts, st2 = fleet_reuse_step(det, _as_jnp(cur), grids, cache,
                                      thr)
    assert st2.computed == 0 and st2.canvas_bytes == 0
    assert not (cache.epoch_np == cache.steps).any()


def test_canvas_bytes_proportional_to_changed(det):
    t = det.cfg.tile
    rng = _rng(5)
    frames, grids = _mk_fleet(rng, t, {0: [(4, 4)]})
    cache = PackedActivationCache()
    _, _, st0 = fleet_reuse_step(det, _as_jnp(frames), grids, cache)
    tile_bytes = t * t * int(det.head.shape[-1]) * 4
    assert st0.canvas_bytes == st0.total_tiles * tile_bytes  # cold seed
    cur = {0: [frames[0][0].copy()]}
    cur[0][0][1, 1, :] += 9.0
    _, _, st1 = fleet_reuse_step(det, _as_jnp(cur), grids, cache)
    assert 0 < st1.canvas_bytes == st1.changed_out * tile_bytes
    assert st1.canvas_bytes < st0.canvas_bytes
    assert cache.canvas_bytes_total == st0.canvas_bytes + st1.canvas_bytes


# ---------------------------------------------------------------------------
# per-tile-class gate thresholds
# ---------------------------------------------------------------------------

def test_tile_class_rows_body_vs_halo():
    g = np.ones((3, 3), bool)
    _, nbr, _, _ = ops.superlaunch_tables([[g]])
    cls = tile_class_rows(np.asarray(nbr))
    assert cls.shape == (9,)
    assert set(np.unique(cls)) <= {TILE_CLASS_BODY, TILE_CLASS_HALO}
    assert (cls == TILE_CLASS_BODY).sum() == 1      # only the center
    assert (cls == TILE_CLASS_HALO).sum() == 8      # the boundary ring


def test_per_tile_class_thresholds_route_by_class():
    """A (C, 2) [body, halo] threshold table gates body and halo rows
    against different bars, and ``ref_advance_rows`` follows the same
    split; 2-D thresholds without a class vector are rejected."""
    stats = np.zeros((4, 8), np.int64)
    stats[:, 5] = 100                       # GATE_WIN_BYTES estimate
    cam = np.zeros(4, np.int64)
    cls = np.array([TILE_CLASS_BODY, TILE_CLASS_BODY,
                    TILE_CLASS_HALO, TILE_CLASS_HALO])
    thr = np.array([[1e6, 10.0]])           # body never, halo always
    changed = gate_changed_rows(stats, thr, cam, cls)
    np.testing.assert_array_equal(changed,
                                  [False, False, True, True])
    adv = ref_advance_rows(thr, cam, changed, cls)
    np.testing.assert_array_equal(adv, changed)
    # exact gate for one class: its rows advance regardless of change
    thr0 = np.array([[0.0, 1e6]])
    adv0 = ref_advance_rows(thr0, cam, np.zeros(4, bool), cls)
    np.testing.assert_array_equal(adv0, [True, True, False, False])
    with pytest.raises(ValueError):
        gate_changed_rows(stats, thr, cam, None)
    assert thr.shape[1] == N_TILE_CLASSES


# ---------------------------------------------------------------------------
# drift re-solve shrinking the active set mid-sequence
# ---------------------------------------------------------------------------

def test_drift_shrink_does_not_leak_stale_canvas(det):
    """Mid-sequence a re-solve SHRINKS one group's mask.  The removed
    tiles' canvas bytes must not leak into served heads on either path:
    the single-device cache reseeds on the key change; the sharded
    runtime wipes the owning shard's canvas plane."""
    t = det.cfg.tile
    rng = _rng(6)
    frames, grids = _mk_fleet(rng, t, {0: [(3, 4)], 1: [(3, 3)]})
    # single-device: key change -> cold reseed on the new grids
    cache = PackedActivationCache()
    fleet_reuse_step(det, _as_jnp(frames), grids, cache)
    fleet_reuse_step(det, _as_jnp(frames), grids, cache)
    small = {0: [grids[0][0].copy()], 1: [g.copy() for g in grids[1]]}
    small[0][0][1:, :] = False                  # drop most of group 0
    small[0][0][0, 0] = True
    got, _, st = fleet_reuse_step(det, _as_jnp(frames), small, cache)
    assert st.cold
    legacy = det.fleet_forward_layers(
        [jnp.asarray(f) for f in frames[0]], small[0])
    np.testing.assert_array_equal(np.asarray(got[0][0]),
                                  np.asarray(legacy[0]))
    # a removed tile's head region is exactly zero (no stale bytes)
    assert (np.asarray(got[0][0])[2 * t:3 * t, :t] == 0).all()

    # sharded: rebuild_group + shard-exact canvas invalidation, then the
    # changed-only scatter keeps matching the full-recompute reference
    rt = ShardedSuperlaunch(det, grids, make_fleet_mesh(1))
    scache = rt.make_cache()
    sharded_fleet_step(rt, frames, scache, 0.0)
    sharded_fleet_step(rt, frames, scache, 0.0)
    scache.invalidate_group(0)
    rt.rebuild_group(0, small[0], scache)
    new_grids = {0: small[0], 1: grids[1]}
    got_s, _, stats = sharded_fleet_step(rt, frames, scache, 0.0)
    ref = det.superlaunch_forward(frames, new_grids)
    for gid in new_grids:
        for i in range(len(new_grids[gid])):
            np.testing.assert_array_equal(np.asarray(ref[gid][i]),
                                          got_s[gid][i])
    assert (got_s[0][0][2 * t:3 * t, :t] == 0).all()
    # ...and warm steps after the shrink stay bit-exact too
    cur = {g: [f.copy() for f in fs] for g, fs in frames.items()}
    cur[1][0][2:t - 2, 2:t - 2, :] += 7.0
    got_s2, counts, _ = sharded_fleet_step(rt, cur, scache, 0.0)
    ref2 = det.superlaunch_forward(cur, new_grids)
    for gid in new_grids:
        for i in range(len(new_grids[gid])):
            np.testing.assert_array_equal(np.asarray(ref2[gid][i]),
                                          got_s2[gid][i])
    assert counts.get("sbnet_scatter_changed", 0) == 1, dict(counts)
