"""Set-cover RoI optimization: paper worked example + solver cross-checks."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.association import AssociationTable, Region, TileUniverse
from repro.core.geometry import Camera
from repro.core import setcover


def _universe_2cam():
    # two 6x4 = 24-tile cameras as in paper Figure 2 (tiles 1..24 -> 0..23)
    P = np.eye(3, 4)
    cams = [Camera(0, 6 * 64, 4 * 64, P), Camera(1, 6 * 64, 4 * 64, P)]
    return TileUniverse.build(cams)


def _tiles(cam, *one_based):
    """Paper's 1-based tile ids -> global ids (cam offset + 0-based)."""
    return frozenset((cam * 24) + (t - 1) for t in one_based)


def paper_table1() -> AssociationTable:
    """The exact association lookup-table of paper Table 1 / Figure 2."""
    uni = _universe_2cam()
    constraints = [
        # O1 appears in both cameras
        [Region(0, _tiles(0, 9, 10, 15, 16)), Region(1, _tiles(1, 7, 8, 13, 14))],
        [Region(0, _tiles(0, 3, 4, 9, 10))],        # O2
        [Region(0, _tiles(0, 4, 5, 10, 11))],       # O3
        [Region(0, _tiles(0, 11))],                 # O4
        [Region(1, _tiles(1, 2, 8))],               # O5
        [Region(1, _tiles(1, 3))],                  # O6
        [Region(1, _tiles(1, 3, 9))],               # O7
    ]
    keys = [(0, k) for k in range(1, 8)]
    return AssociationTable(uni, constraints, keys)


EXPECTED_MASK = (_tiles(0, 3, 4, 5, 9, 10, 11, 15, 16)
                 | _tiles(1, 2, 3, 8, 9))  # §3.3: the 12-tile optimum


@pytest.mark.parametrize("method", ["greedy", "exact", "milp"])
def test_paper_worked_example(method):
    table = paper_table1()
    res = setcover.solve(table, method)
    # the paper's optimum has 12 tiles; O1 covered via its C1 appearance
    assert len(res.mask) == 12
    assert res.mask == EXPECTED_MASK


def test_exact_is_certified_optimal():
    res = setcover.solve(paper_table1(), "exact")
    assert res.optimal
    assert len(res.mask) >= res.lower_bound - 1e-6


def _satisfies(mask, constraints):
    return all(any(r.tiles <= mask for r in regions) for regions in constraints)


@st.composite
def random_instance(draw):
    n_tiles = draw(st.integers(6, 30))
    n_cons = draw(st.integers(1, 12))
    constraints = []
    for _ in range(n_cons):
        n_regions = draw(st.integers(1, 3))
        regions = []
        for _ in range(n_regions):
            size = draw(st.integers(1, 5))
            tiles = draw(st.sets(st.integers(0, n_tiles - 1),
                                 min_size=size, max_size=size))
            regions.append(Region(0, frozenset(tiles)))
        constraints.append(regions)
    return constraints


@settings(max_examples=40, deadline=None)
@given(random_instance())
def test_solvers_agree_and_satisfy(constraints):
    uni = _universe_2cam()
    table = AssociationTable(uni, constraints, [(0, i) for i in
                                                range(len(constraints))])
    g = setcover.solve(table, "greedy")
    e = setcover.solve(table, "exact")
    m = setcover.solve(table, "milp")
    for res in (g, e, m):
        assert _satisfies(res.mask, constraints), res.method
    assert len(e.mask) <= len(g.mask)
    assert len(e.mask) == len(m.mask)       # both exact
    assert len(e.mask) >= e.lower_bound - 1e-6


def test_preprocess_forces_singletons():
    cons = [[Region(0, frozenset({1, 2}))],
            [Region(0, frozenset({2, 3})), Region(0, frozenset({9}))]]
    core = setcover.preprocess(cons)
    assert core.forced == {1, 2}
    # second constraint still open with residuals {3} vs {9}
    assert len(core.constraints) == 1
    assert sorted(map(len, core.constraints[0])) == [1, 1]


def test_preprocess_dedups_and_drops_dominated():
    r = Region(0, frozenset({1, 2}))
    r_sup = Region(0, frozenset({1, 2, 3}))
    other = Region(0, frozenset({7}))
    cons = [[r, r_sup, other], [r, other], [other, r]]
    core = setcover.preprocess(cons)
    # all three dedup to one constraint; the superset region is dropped
    total = len(core.constraints)
    assert total == 1
    assert frozenset({1, 2}) in core.constraints[0]
    assert frozenset({1, 2, 3}) not in core.constraints[0]
