"""Fault injection, liveness, failover, and outage-pricing tests.

Covers the chaos layer's contracts at unit granularity (the end-to-end
recovery numbers live in ``benchmarks/bench_chaos.py``):

* ``net.links.outage_effective`` — zero-bandwidth segments price to
  FINITE FIFO departures with backlog carried across the outage, and
  the transform is bit-identical to its input when no zeros exist.
* ``net.batcher.DeadlineGroupFormer`` — a dead fleet slice (every
  expected camera missing at the deadline) releases WITHOUT forming a
  launch, in both plain and reuse mode, and marks every camera late so
  eventual arrivals ride a catch-up release as stragglers.
* ``net.batcher.HeartbeatMonitor`` — timeout detection, exponential
  backoff retry accounting, instant restore on a beat.
* ``fleet.faults`` — schedule validation, injector identity when off,
  frozen-vs-static liveness discrimination, failover re-solve
  semantics (drop dead tiles, never silently fold holes), and the
  drift adapter's mask-listener reentrancy guard under the sharded
  invalidation fan-out.
* sentinel + history schema — the chaos recovery bounds are absolute
  rules and schema v2 carries the chaos headline.
"""
import numpy as np
import pytest

from repro.core.pipeline import OfflineConfig, run_offline
from repro.core.scene import SceneConfig, generate_scene
from repro.fleet.drift import (DriftAdapter, DriftConfig,
                               wire_shard_invalidation)
from repro.fleet.faults import (FaultEvent, FaultInjector, FaultSchedule,
                                LivenessConfig, LivenessMonitor,
                                degraded_coverage, failover_resolve,
                                flat_cam_index, per_camera_changed,
                                uplink_episodes)
from repro.kernels.tile_delta import GATE_WIN_EXACT, STATS_WIDTH
from repro.net.batcher import (DeadlineGroupFormer, HeartbeatConfig,
                               HeartbeatMonitor)
from repro.net.links import (CongestionEpisode, fifo_departures,
                             outage_effective, queue_wait)


# ---------------------------------------------------------------------------
# links: outage pricing
# ---------------------------------------------------------------------------

def test_outage_effective_is_noop_without_zeros():
    rng = np.random.default_rng(0)
    C, S, seg = 3, 8, 1.0
    # arrivals sit at or after their segment close, as in the simulator
    arr = (np.arange(S) + 1.0) * seg + rng.uniform(0, 0.3, (C, S))
    bw = rng.uniform(1e5, 1e6, (C, S))
    eff_arr, eff_bw, restore = outage_effective(arr, bw, seg, 5e5)
    np.testing.assert_array_equal(eff_arr, arr)
    np.testing.assert_array_equal(eff_bw, bw)
    assert (restore <= arr).all()


def test_outage_effective_finite_departures_through_zero_bw():
    C, S, seg = 2, 10, 1.0
    arr = np.tile((np.arange(S) + 1.0) * seg, (C, 1))
    bw = np.full((C, S), 1e6)
    bw[0, 3:6] = 0.0               # mid-window outage on camera 0
    load = np.full((C, S), 2e5)
    eff_arr, eff_bw, restore = outage_effective(arr, bw, seg, 1e6)
    assert (eff_bw > 0).all()
    # outage segments cannot start before the restoring segment opens
    assert (eff_arr[0, 3:6] >= 6.0 * seg - 1e-12).all()
    np.testing.assert_array_equal(restore[0, 3:6], 6.0 * seg)
    # untouched row passes through bit-identically
    np.testing.assert_array_equal(eff_arr[1], arr[1])
    np.testing.assert_array_equal(eff_bw[1], bw[1])

    dep = fifo_departures(eff_arr, load / eff_bw)
    assert np.isfinite(dep).all()
    assert (np.diff(dep, axis=-1) >= 0).all()          # FIFO order holds
    assert (dep[0, 3:6] >= 6.0 * seg).all()            # drain after restore
    assert (queue_wait(eff_arr, load / eff_bw) >= -1e-9).all()


def test_outage_effective_fallback_prices_tail_outage():
    C, S, seg = 1, 6, 1.0
    arr = ((np.arange(S) + 1.0) * seg)[None, :]
    bw = np.full((C, S), 1e6)
    bw[0, 4:] = 0.0                # outage runs past the window end
    fallback = 2.5e5
    eff_arr, eff_bw, restore = outage_effective(arr, bw, seg, fallback)
    np.testing.assert_array_equal(eff_bw[0, 4:], fallback)
    np.testing.assert_array_equal(restore[0, 4:], S * seg)
    assert (eff_arr[0, 4:] == S * seg).all()
    dep = fifo_departures(eff_arr, np.full((C, S), 1e5) / eff_bw)
    assert np.isfinite(dep).all()


def test_transport_window_finite_under_full_outage_episode():
    from repro.obs.loadgen import LoadgenConfig, transport_window

    for rc in (False, True):
        cfg = LoadgenConfig(rate_control=rc)
        ts = transport_window(cfg, 4, "episode:0.0", 0.9)
        assert ts.latency_s.size > 0
        assert np.isfinite(ts.latency_s).all()
        assert np.isfinite(ts.p99_s)


# ---------------------------------------------------------------------------
# batcher: dead fleet slice + heartbeat
# ---------------------------------------------------------------------------

class _CountingDet:
    def __init__(self):
        self.calls = 0

    def fleet_forward(self, frames, grids):
        self.calls += 1
        return [("head", i) for i in range(len(frames))]

    def fleet_forward_reuse(self, frames, grids, cache, threshold):
        raise AssertionError("reuse launch formed on an empty release")


def test_former_dead_slice_releases_without_launch():
    det = _CountingDet()
    former = DeadlineGroupFormer(det, [0, 1, 2], deadline_s=0.5)
    rel = former.force_release(10.0)
    assert rel.cams == [] and rel.outputs == {} and rel.deadline_hit
    assert rel.straggler_cams == []
    assert det.calls == 0
    # every expected camera is now late: eventual arrivals are stragglers
    assert former._late == {0, 1, 2}

    for cam in (0, 1, 2):
        rel2 = former.offer(11.0, cam, f"f{cam}", f"g{cam}")
    assert rel2 is not None and rel2.cams == [0, 1, 2]
    assert sorted(rel2.straggler_cams) == [0, 1, 2]
    assert det.calls == 1
    assert former._late == set()       # catch-up release clears the slate


def test_former_dead_slice_in_reuse_mode_skips_wave_replay():
    det = _CountingDet()
    former = DeadlineGroupFormer(det, [0, 1], deadline_s=0.5,
                                 reuse_cache=object())
    # retained state for every camera makes _reuse_ready() report True on
    # an empty pending set — the empty-cams guard must win, not the wave
    # replay (whose max() over zero queues would crash)
    former._retained = {0: ("f0", "g0"), 1: ("f1", "g1")}
    rel = former.force_release(3.0)
    assert rel.cams == [] and rel.outputs == {}
    assert det.calls == 0


def test_heartbeat_timeout_backoff_and_restore():
    cfg = HeartbeatConfig(interval_s=1.0, timeout_beats=3.0,
                          backoff_base_s=0.5, backoff_factor=2.0,
                          backoff_max_s=8.0)
    hb = HeartbeatMonitor([0, 1], cfg, t0=0.0)
    for t in (1.0, 2.0):
        hb.beat(t, 0)
        hb.beat(t, 1)
        assert hb.poll(t) == []
    # camera 1 stops beating after t=2; camera 0 stays alive
    for t in (3.0, 4.0):
        hb.beat(t, 0)
        assert hb.poll(t) == []
    hb.beat(5.0, 0)
    assert hb.poll(5.0) == [1]          # 5.0 - 2.0 >= timeout_s (3.0)
    assert hb.detect_latency(1) == pytest.approx(3.0)
    assert 1 in hb.dead and 0 not in hb.dead

    # backoff: first retry at 5.5, then +1.0, +2.0, +4.0 ... capped at 8
    hb.beat(10.0, 0)                    # camera 0 keeps beating
    hb.poll(10.0)
    retry_ts = [t for t, cam, kind in hb.events
                if cam == 1 and kind == "retry"]
    assert retry_ts == pytest.approx([5.5, 6.5, 8.5])
    assert hb.retries[1] == 3

    assert hb.beat(11.0, 1) is True     # arrival restores instantly
    assert 1 not in hb.dead and hb.retries[1] == 0
    assert (11.0, 1, "restored") in hb.events
    assert np.isnan(hb.detect_latency(0))


# ---------------------------------------------------------------------------
# fault scripting + injection
# ---------------------------------------------------------------------------

def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent("meteor", 0, 1)
    with pytest.raises(ValueError):
        FaultEvent("freeze", 5, 5)
    e = FaultEvent("freeze", 2, 4)
    assert not e.active(1) and e.active(2) and e.active(3) \
        and not e.active(4)


def test_fault_schedule_off_and_random_reproducible():
    assert FaultSchedule((), enabled=True).off
    assert FaultSchedule((FaultEvent("noise", 0, 1),), enabled=False).off
    a = FaultSchedule.random(7, 5, steps=20, n_groups=3,
                             cams_per_group=4, n_shards=2)
    b = FaultSchedule.random(7, 5, steps=20, n_groups=3,
                             cams_per_group=4, n_shards=2)
    assert a.events == b.events and len(a.events) == 5
    assert not a.off


def _frames(step_seed, gids=(0,), cams=2, shape=(4, 4, 3)):
    rng = np.random.default_rng(step_seed)
    return {g: [rng.normal(size=shape).astype(np.float32)
                for _ in range(cams)] for g in gids}


def test_injector_off_returns_same_object():
    frames = _frames(0)
    for schedule in (None, FaultSchedule(()),
                     FaultSchedule((FaultEvent("freeze", 0, 2),),
                                   enabled=False)):
        inj = FaultInjector(schedule)
        assert inj.off
        assert inj.apply(0, frames) is frames
        assert inj.blacked_out(0) == set()
        assert inj.injected_steps == 0


def test_injector_freeze_retains_last_clean_frame():
    sched = FaultSchedule((FaultEvent("freeze", 1, 3, gid=0, cam=1),))
    inj = FaultInjector(sched)
    f0, f1, f2 = _frames(0), _frames(1), _frames(2)
    out0 = inj.apply(0, f0)
    assert out0 is f0                   # no event active yet
    out1 = inj.apply(1, f1)
    assert out1 is not f1
    # frozen camera re-emits its last clean (step-0) content
    np.testing.assert_array_equal(out1[0][1], f0[0][1])
    # the untouched camera keeps frame identity (bit-static gate exact)
    assert out1[0][0] is f1[0][0]
    out2 = inj.apply(2, f2)
    np.testing.assert_array_equal(out2[0][1], f0[0][1])
    assert inj.injected_steps == 2


def test_injector_blackout_and_noise_determinism():
    sched = FaultSchedule((FaultEvent("blackout", 1, 2, gid=0, cam=0),
                           FaultEvent("noise", 1, 2, gid=0, cam=1,
                                      amp=0.5)))
    f0, f1 = _frames(0), _frames(1)
    a = FaultInjector(sched, seed=3)
    b = FaultInjector(sched, seed=3)
    for inj in (a, b):
        inj.apply(0, {g: list(fs) for g, fs in f0.items()})
    assert a.blacked_out(1) == {(0, 0)} and a.blacked_out(0) == set()
    oa = a.apply(1, {g: list(fs) for g, fs in f1.items()})
    ob = b.apply(1, {g: list(fs) for g, fs in f1.items()})
    np.testing.assert_array_equal(oa[0][0], f0[0][0])   # blackout freezes
    assert not np.array_equal(oa[0][1], f1[0][1])       # noise corrupts
    np.testing.assert_array_equal(oa[0][1], ob[0][1])   # ... seeded


def test_uplink_episodes_map_to_zero_bw_segments():
    sched = FaultSchedule((FaultEvent("uplink", 2, 5, gid=0, cam=1),
                           FaultEvent("blackout", 1, 3, gid=1, cam=0),
                           FaultEvent("freeze", 0, 2, gid=0, cam=0)))
    flat = {(0, 0): 0, (0, 1): 1, (1, 0): 2}
    eps = uplink_episodes(sched, 1.5, flat)
    assert len(eps) == 2                # freeze is not a transport fault
    by_cam = {ep.cams[0]: ep for ep in eps}
    assert by_cam[1].factor == 0.0
    assert (by_cam[1].t0_s, by_cam[1].t1_s) == (3.0, 7.5)
    assert (by_cam[2].t0_s, by_cam[2].t1_s) == (1.5, 4.5)
    assert uplink_episodes(None, 1.0, flat) == ()
    # unmapped cameras are skipped, not crashed on
    assert uplink_episodes(
        FaultSchedule((FaultEvent("uplink", 0, 1, gid=9, cam=9),)),
        1.0, flat) == ()


def test_flat_cam_index_matches_dict_order():
    grids = {3: [None, None], 1: [None, None, None]}
    flat = flat_cam_index(grids)
    assert flat == {(3, 0): 0, (3, 1): 1, (1, 0): 2, (1, 1): 3, (1, 2): 4}


# ---------------------------------------------------------------------------
# liveness: frozen vs genuinely static
# ---------------------------------------------------------------------------

def test_per_camera_changed_counts_gate_rows():
    cam_of_row = np.array([0, 0, 1, 1])
    # cold step (no stats): every row counts as changed
    np.testing.assert_array_equal(
        per_camera_changed(None, 0.0, cam_of_row, 3), [2, 2, 0])
    stats = np.zeros((4, STATS_WIDTH), np.int32)
    stats[0, GATE_WIN_EXACT] = 3
    stats[3, GATE_WIN_EXACT] = 1
    np.testing.assert_array_equal(
        per_camera_changed(stats, 0.0, cam_of_row, 3), [1, 1, 0])


def _liveness(n=2, **kw):
    return LivenessMonitor(n, LivenessConfig(
        freeze_window=3, min_expected_rate=0.5, min_occupancy=3, **kw))


def test_liveness_confirms_frozen_active_camera():
    mon = _liveness()
    for step in range(5):                       # both cameras active
        assert mon.update(step, np.array([4, 5])) == []
    for step in range(5, 9):                    # camera 1 goes quiet
        newly = mon.update(step, np.array([4, 0]))
        if step < 7:
            assert newly == []
        elif step == 7:                         # 3rd quiet step confirms
            assert newly == [1]
    assert mon.confirmed == {1}
    assert mon.detect_latency_steps(1, 5) == 2
    assert mon.detect_latency_steps(0, 5) == -1
    assert mon.suspect_at[1] == 5


def test_liveness_never_confirms_genuinely_static_camera():
    mon = _liveness()
    for step in range(20):                      # camera 1 quiet from birth
        assert mon.update(step, np.array([4, 0])) == []
    assert mon.confirmed == set()


def test_liveness_occupancy_channel_confirms_without_gate_history():
    mon = _liveness()
    # no gate history for camera 1, but the drift window says traffic
    # flows through it — the occupancy channel confirms
    for step in range(3):
        newly = mon.update(step, np.array([4, 0]), occupancy={1: 5})
    assert newly == [1] and mon.confirmed == {1}


def test_liveness_recovery_discards_confirmation():
    mon = _liveness()
    for step in range(5):
        mon.update(step, np.array([4, 4]))
    for step in range(5, 8):
        mon.update(step, np.array([4, 0]))
    assert mon.confirmed == {1}
    assert mon.update(8, np.array([4, 2])) == []
    assert mon.confirmed == set() and 1 not in mon.confirmed_at


# ---------------------------------------------------------------------------
# failover re-solve + degraded coverage (scene fixtures)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def scene():
    return generate_scene(SceneConfig(duration_s=80, seed=2))


@pytest.fixture(scope="module")
def offline(scene):
    return run_offline(scene, OfflineConfig(profile_frames=300,
                                            solver="greedy"))


def _warm_adapter(scene, offline, t0=300, t1=380):
    ad = DriftAdapter(scene, offline,
                      DriftConfig(confirm_frames=10 ** 9))
    for t in range(t0, t1):
        ad.observe(t, scene.detections[t])
    return ad


def _owned_tiles(ad, cam):
    lo, hi = ad.universe.offsets[cam], ad.universe.offsets[cam + 1]
    return {g for g in ad.mask if lo <= g < hi}


def test_failover_resolve_drops_dead_tiles_and_reports_holes(
        scene, offline):
    ad = _warm_adapter(scene, offline)
    occ = ad.occupancy_by_camera()
    dead = max(occ, key=occ.get)                # busiest camera dies
    owned = _owned_tiles(ad, dead)
    assert owned, "fixture must give the dead camera mask tiles"
    regions = [r for _, _, r in ad._regions]
    expect_total = len(regions)
    expect_holes = sum(1 for r in regions if set(r) == {dead})
    calls = []
    ad.add_mask_listener(lambda a: calls.append(a))

    ev = failover_resolve(ad, [dead], t=380)
    assert ev.dead_cams == (dead,)
    assert ev.tiles_dropped == len(owned)
    assert not _owned_tiles(ad, dead)           # mask holds no dead tiles
    assert ev.constraints == expect_total - expect_holes
    assert ev.uncoverable == expect_holes
    assert ev.uncovered_fraction == pytest.approx(
        expect_holes / max(expect_total, 1))
    assert calls == [ad]                        # listener fired exactly once
    # bookkeeping mirrors a drift re-solve
    assert not ad._window and ad._last_resolve_t == 380
    # every surviving camera's grid matches the re-solved mask
    for c in ad.cameras:
        np.testing.assert_array_equal(
            ad.cam_grids[c.cam_id],
            ad.universe.cam_mask_grid(c.cam_id, ad.mask))


def test_failover_all_cameras_dead_reports_everything_uncovered(
        scene, offline):
    ad = _warm_adapter(scene, offline)
    total = len(ad._regions)
    assert total > 0
    ev = failover_resolve(ad, [c.cam_id for c in ad.cameras], t=380)
    assert ev.constraints == 0 and ev.uncoverable == total
    assert ev.uncovered_fraction == pytest.approx(1.0)
    assert ad.mask == set()                     # nothing left to serve from


def test_degraded_coverage_separates_genuine_holes(scene, offline):
    ad = _warm_adapter(scene, offline)
    dets = scene.detections[380]
    cov0, coverable0, total0 = degraded_coverage(ad, dets, [])
    assert coverable0 == total0 >= cov0         # no dead cams: no holes
    n_obj = len({d.obj for d in dets})
    assert total0 == n_obj

    dead = max(ad.occupancy_by_camera(), key=ad.occupancy_by_camera().get)
    cov1, coverable1, total1 = degraded_coverage(ad, dets, [dead])
    assert total1 == total0
    assert cov1 <= coverable1 <= total1
    holes = total1 - coverable1
    only_dead = sum(1 for o in {d.obj for d in dets}
                    if {d.cam for d in dets if d.obj == o} == {dead})
    assert holes == only_dead


class _FakeShardCache:
    def __init__(self):
        self.invalidated = []

    def invalidate_group(self, gid):
        self.invalidated.append(gid)


class _FakeRuntime:
    def __init__(self):
        self.rebuilt = []

    def rebuild_group(self, gid, grids, cache=None):
        self.rebuilt.append((gid, len(grids)))


def test_mask_listener_reentrancy_under_shard_invalidation(scene, offline):
    ad0 = _warm_adapter(scene, offline, t1=340)
    ad1 = _warm_adapter(scene, offline, t1=340)
    cache, runtime = _FakeShardCache(), _FakeRuntime()
    wire_shard_invalidation({0: ad0, 1: ad1}, cache, runtime)
    # a listener that re-enters the fan-out mid-flight (the shard
    # rebuild path can feed back into mask mutation within one step)
    ad0.add_mask_listener(lambda a: a._notify_mask_update())

    # both adapters fire in the same step; each gid invalidates ONCE
    ad0._notify_mask_update()
    ad1._notify_mask_update()
    assert cache.invalidated == [0, 1]
    assert runtime.rebuilt == [(0, len(scene.cameras)),
                               (1, len(scene.cameras))]

    # a real failover drives the same chain, still exactly once
    dead = ad0.cameras[0].cam_id
    failover_resolve(ad0, [dead], t=340)
    assert cache.invalidated == [0, 1, 0]
    assert runtime.rebuilt[-1] == (0, len(scene.cameras))


# ---------------------------------------------------------------------------
# sentinel rules + history schema v2 + SLO plumbing
# ---------------------------------------------------------------------------

def test_sentinel_chaos_rules_are_absolute():
    from repro.obs.sentinel import rule_for

    for metric in ("chaos.mttr_steps", "mttr_steps"):
        rule = rule_for(metric)
        assert rule.absolute_only and rule.abs_floor == 1.5
    assert rule_for("chaos.detect_latency_steps").abs_floor == 2.5
    rule = rule_for("chaos.uncovered_frac_p99")
    assert rule.absolute_only and rule.abs_floor == 0.05


def test_sentinel_self_test_flags_mttr_regression(tmp_path):
    from repro.obs.sentinel import self_test

    res = self_test(history_path=str(tmp_path / "none.jsonl"))
    assert res["clean_pass"] and res["slowdown_flagged"]
    assert res["noise_band_pass"] and res["mttr_flagged"]


def test_history_schema_v2_chaos_block():
    import benchmarks.common as common

    rec = {"schema": 2, "ts": "t", "git_sha": "s", "mode": "full",
           "panels": ["chaos"], "headline_walls": {"w": 1.0},
           "chaos": {"mttr_steps": 3.0, "uncovered_frac_p99": 0.0}}
    assert common.validate_history_record(rec) == []
    v1 = {k: v for k, v in rec.items() if k != "chaos"}
    v1["schema"] = 1
    assert common.validate_history_record(v1) == []

    bad_bool = dict(rec, chaos={"mttr_steps": True})
    assert any("chaos" in p for p in
               common.validate_history_record(bad_bool))
    bad_shape = dict(rec, chaos=[1.0])
    assert any("chaos" in p for p in
               common.validate_history_record(bad_shape))
    bad_frontier = dict(rec, frontier={"p99": "fast"})
    assert any("frontier" in p for p in
               common.validate_history_record(bad_frontier))


def test_slo_report_carries_uncovered_fraction():
    from repro.obs.slo import FleetSLOReport

    rep = FleetSLOReport.build(uncovered_frac=[0.0, 0.0, 0.0, 0.2])
    assert rep.uncovered_frac_mean == pytest.approx(0.05)
    assert rep.uncovered_frac_p99 == pytest.approx(
        np.percentile([0.0, 0.0, 0.0, 0.2], 99))
    d = rep.to_dict()
    assert "uncovered_frac_mean" in d and "uncovered_frac_p99" in d
    assert FleetSLOReport.build().uncovered_frac_p99 == 0.0


# ---------------------------------------------------------------------------
# chaos drive: fault-free bit-identity on the fleet path (tier-1 scale)
# ---------------------------------------------------------------------------

def test_drive_chaos_fault_free_is_bit_identical_to_drive_fleet():
    import jax

    from repro.fleet.faults import drive_chaos
    from repro.obs.loadgen import (LoadgenConfig, drive_fleet, make_grids,
                                   make_frame_trace)
    from repro.serving.detector import (DetectorConfig,
                                        PackedActivationCache, RoIDetector)

    cfg = LoadgenConfig(steps=3, grid_shape=(3, 4))
    det = RoIDetector(DetectorConfig(tile=8, channels=(4, 6)),
                      jax.random.PRNGKey(0))
    grids = make_grids(cfg, 1, 2)
    frames = make_frame_trace(cfg, grids, 0.5)

    _, ref_out, ref_total = drive_fleet(
        det, frames, grids, PackedActivationCache(), keep_outputs=True)
    _, out, total, detections = drive_chaos(
        det, frames, grids, PackedActivationCache(), schedule=None,
        monitor=LivenessMonitor(2), keep_outputs=True)
    assert detections == {}
    assert total == ref_total                  # identical dispatch counter
    assert len(out) == len(ref_out)
    for a, b in zip(ref_out, out):
        assert sorted(a) == sorted(b)
        for gid in a:
            for ha, hb in zip(a[gid], b[gid]):
                np.testing.assert_array_equal(np.asarray(ha),
                                              np.asarray(hb))
