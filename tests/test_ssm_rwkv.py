"""Chunked SSD / WKV vs. step-by-step recurrent oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import ssd_chunked, ssd_step
from repro.models.rwkv import wkv_chunked, wkv_step


def ssd_naive(xh, dt, A_log, Bc, Cc):
    B, S, H, P = xh.shape
    N = Bc.shape[-1]
    state = jnp.zeros((B, H, N, P))
    ys = []
    for t in range(S):
        y, state = ssd_step(state, xh[:, t:t+1], dt[:, t:t+1], A_log,
                            Bc[:, t:t+1], Cc[:, t:t+1])
        ys.append(y)
    return jnp.concatenate(ys, axis=1), state


@pytest.mark.parametrize("chunk", [1, 4, 8, 32])
def test_ssd_chunked_matches_recurrence(chunk):
    key = jax.random.PRNGKey(0)
    B, S, H, P, N = 2, 32, 3, 4, 5
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A_log = jax.random.normal(ks[2], (H,)) * 0.5
    Bc = jax.random.normal(ks[3], (B, S, N))
    Cc = jax.random.normal(ks[4], (B, S, N))
    y, st = ssd_chunked(xh, dt, A_log, Bc, Cc, chunk)
    y_ref, st_ref = ssd_naive(xh, dt, A_log, Bc, Cc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               atol=1e-4, rtol=1e-4)


def test_ssd_init_state_carries():
    key = jax.random.PRNGKey(1)
    B, S, H, P, N = 1, 16, 2, 4, 3
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A_log = jax.random.normal(ks[2], (H,)) * 0.5
    Bc = jax.random.normal(ks[3], (B, S, N))
    Cc = jax.random.normal(ks[4], (B, S, N))
    y_all, st_all = ssd_chunked(xh, dt, A_log, Bc, Cc, 4)
    y1, st1 = ssd_chunked(xh[:, :8], dt[:, :8], A_log, Bc[:, :8], Cc[:, :8], 4)
    y2, st2 = ssd_chunked(xh[:, 8:], dt[:, 8:], A_log, Bc[:, 8:], Cc[:, 8:], 4,
                          init_state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_all),
                               atol=1e-4, rtol=1e-4)


def wkv_naive(r, k, v, lw, u):
    B, S, H, P = r.shape
    state = jnp.zeros((B, H, P, P))
    outs = []
    for t in range(S):
        o, state = wkv_step(state, r[:, t:t+1], k[:, t:t+1], v[:, t:t+1],
                            lw[:, t:t+1], u)
        outs.append(o)
    return jnp.concatenate(outs, axis=1), state


@pytest.mark.parametrize("S", [16, 31, 32, 48])
def test_wkv_chunked_matches_recurrence(S):
    key = jax.random.PRNGKey(2)
    B, H, P = 2, 2, 4
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, S, H, P))
    k = jax.random.normal(ks[1], (B, S, H, P))
    v = jax.random.normal(ks[2], (B, S, H, P))
    lw = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H, P)))  # <=0
    u = jax.random.normal(ks[4], (H, P))
    out, st = wkv_chunked(r, k, v, lw, u)
    ref, st_ref = wkv_naive(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               atol=1e-4, rtol=1e-4)


def test_wkv_state_carries():
    key = jax.random.PRNGKey(3)
    B, S, H, P = 1, 32, 2, 4
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, S, H, P))
    k = jax.random.normal(ks[1], (B, S, H, P))
    v = jax.random.normal(ks[2], (B, S, H, P))
    lw = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H, P)))
    u = jax.random.normal(ks[4], (H, P))
    out_all, st_all = wkv_chunked(r, k, v, lw, u)
    o1, s1 = wkv_chunked(r[:, :16], k[:, :16], v[:, :16], lw[:, :16], u)
    o2, s2 = wkv_chunked(r[:, 16:], k[:, 16:], v[:, 16:], lw[:, 16:], u,
                         init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(out_all), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(st_all),
                               atol=1e-4, rtol=1e-4)
