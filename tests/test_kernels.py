"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties.

All kernels run in interpret mode (CPU container); the contracts are the
ref.py semantics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.roi_attention import PAD_POS


def _rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# sbnet gather / scatter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("th,tw,H,W,C", [
    (8, 8, 32, 40, 4),
    (16, 32, 64, 96, 8),
    (32, 32, 128, 128, 16),
])
def test_sbnet_gather_sweep(dtype, th, tw, H, W, C):
    rng = _rng(th * tw)
    x = jnp.asarray(rng.normal(size=(H, W, C)), dtype)
    ty, tx = H // th, W // tw
    all_tiles = [(y, x_) for y in range(ty) for x_ in range(tx)]
    sel = rng.choice(len(all_tiles), size=min(5, len(all_tiles)),
                     replace=False)
    idx = jnp.asarray(np.array([all_tiles[i] for i in sel], np.int32))
    out = ops.sbnet_gather(x, idx, th, tw)
    expect = ref.sbnet_gather(x, idx, th, tw)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sbnet_scatter_roundtrip(dtype):
    rng = _rng(3)
    H, W, C, th, tw = 96, 96, 8, 32, 32
    x = jnp.asarray(rng.normal(size=(H, W, C)), dtype)
    idx = jnp.asarray(np.array([[0, 0], [2, 2], [1, 0]], np.int32))
    packed = ops.sbnet_gather(x, idx, th, tw)
    base = jnp.zeros((H, W, C), dtype)
    out = ops.sbnet_scatter(packed, idx, base)
    expect = ref.sbnet_scatter(packed, idx, base, th, tw)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32))
    # gathered tiles land back exactly; non-active tiles stay base
    np.testing.assert_allclose(np.asarray(out[:32, :32], np.float32),
                               np.asarray(x[:32, :32], np.float32))
    assert float(jnp.abs(out[:32, 32:64].astype(jnp.float32)).max()) == 0.0


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_sbnet_gather_property(seed):
    """Property: gather output tile i == x at the tile rect, any tile set."""
    rng = _rng(seed)
    th, tw, C = 8, 16, 4
    ty, tx = int(rng.integers(2, 5)), int(rng.integers(2, 5))
    H, W = ty * th, tx * tw
    x = jnp.asarray(rng.normal(size=(H, W, C)), jnp.float32)
    n = int(rng.integers(1, ty * tx + 1))
    flat = rng.choice(ty * tx, size=n, replace=False)
    idx = jnp.asarray(np.stack([flat // tx, flat % tx], 1).astype(np.int32))
    out = ops.sbnet_gather(x, idx, th, tw)
    for i in range(n):
        y0, x0 = int(idx[i, 0]) * th, int(idx[i, 1]) * tw
        np.testing.assert_array_equal(np.asarray(out[i]),
                                      np.asarray(x[y0:y0 + th, x0:x0 + tw]))


# ---------------------------------------------------------------------------
# roi conv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 0.15)])
@pytest.mark.parametrize("th,tw,Cin,Cout", [
    (8, 8, 4, 8),
    (16, 16, 8, 8),
    (32, 32, 3, 16),
])
def test_roi_conv_sweep(dtype, tol, th, tw, Cin, Cout):
    rng = _rng(th + Cin)
    H, W = th * 3, tw * 4
    x = jnp.asarray(rng.normal(size=(H, W, Cin)), dtype)
    w = jnp.asarray(rng.normal(size=(3, 3, Cin, Cout)) * 0.2, dtype)
    idx = jnp.asarray(np.array([[0, 0], [1, 2], [2, 3], [1, 1]], np.int32))
    out = ops.roi_conv(x, w, idx, th, tw)
    expect = ref.roi_conv(x, w, idx, th, tw)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_roi_conv_interior_tile_matches_dense():
    """An interior active tile must equal the dense conv exactly (halo
    correctness)."""
    rng = _rng(9)
    th = tw = 16
    x = jnp.asarray(rng.normal(size=(48, 48, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 4)) * 0.3, jnp.float32)
    idx = jnp.asarray(np.array([[1, 1]], np.int32))
    out = ops.roi_conv(x, w, idx, th, tw)[0]
    dense = jax.lax.conv_general_dilated(
        x[None], w, (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(dense[16:32, 16:32]), atol=2e-4)


def test_roi_conv_batched():
    rng = _rng(11)
    x = jnp.asarray(rng.normal(size=(2, 32, 32, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 8)) * 0.2, jnp.float32)
    idx = jnp.asarray(np.array([[0, 0], [1, 1]], np.int32))
    out = ops.roi_conv_batched(x, w, idx, 16, 16)
    assert out.shape == (2, 2, 16, 16, 8)
    for b in range(2):
        expect = ref.roi_conv(x[b], w, idx, 16, 16)
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(expect),
                                   atol=1e-4)


# ---------------------------------------------------------------------------
# roi attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 0.05)])
@pytest.mark.parametrize("S,H,D,bq,bk", [
    (128, 2, 32, 64, 64),
    (256, 4, 64, 128, 128),
    (256, 1, 128, 64, 128),
])
def test_roi_attention_sweep(dtype, tol, S, H, D, bq, bk):
    rng = _rng(S + D)
    q = jnp.asarray(rng.normal(size=(S, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(S, H, D)), dtype)
    v = jnp.asarray(rng.normal(size=(S, H, D)), dtype)
    n_kept = int(0.8 * S)
    pos = np.full(S, PAD_POS, np.int32)
    pos[:n_kept] = np.sort(rng.choice(4 * S, n_kept, replace=False))
    pos = jnp.asarray(pos)
    out = ops.roi_attention(q, k, v, pos, block_q=bq, block_k=bk)
    expect = ref.roi_attention(q, k, v, pos)
    np.testing.assert_allclose(
        np.asarray(out[:n_kept], np.float32),
        np.asarray(expect[:n_kept], np.float32), atol=tol, rtol=tol)


def test_roi_attention_equals_causal_when_dense():
    """With keep=all and positions=arange, packed attention == plain causal."""
    rng = _rng(21)
    S, H, D = 128, 2, 32
    q = jnp.asarray(rng.normal(size=(S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(S, H, D)), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    out = ops.roi_attention(q, k, v, pos, block_q=64, block_k=64)
    # plain causal reference
    logits = jnp.einsum("qhd,khd->hqk", q, k) / np.sqrt(D)
    mask = jnp.tril(jnp.ones((S, S), bool))
    logits = jnp.where(mask[None], logits, -1e30)
    expect = jnp.einsum("hqk,khd->qhd", jax.nn.softmax(logits, axis=-1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(10, 200))
def test_pack_unpack_roundtrip(seed, S):
    rng = _rng(seed)
    x = jnp.asarray(rng.normal(size=(S, 3)), jnp.float32)
    keep = jnp.asarray(rng.random(S) < 0.6)
    packed, positions, n_kept = ops.pack_tokens(x, keep, block=64)
    assert packed.shape[0] % 64 == 0
    assert int(n_kept) == int(keep.sum())
    # kept rows are a stable-order prefix
    kept_rows = np.asarray(x)[np.asarray(keep)]
    np.testing.assert_array_equal(np.asarray(packed[:int(n_kept)]),
                                  kept_rows)
    restored = ops.unpack_tokens(packed, positions, S)
    expect = np.where(np.asarray(keep)[:, None], np.asarray(x), 0.0)
    np.testing.assert_array_equal(np.asarray(restored), expect)
