"""Sharded checkpoint save/restore: roundtrip, crash safety, GC."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (CheckpointManager, load_checkpoint,
                                   save_checkpoint)


def _tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "meta": {"step_count": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    trees = _tree()
    save_checkpoint(d, 3, trees)
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), trees)
    step, out = load_checkpoint(d, template)
    assert step == 3
    for g in trees:
        for a, b in zip(jax.tree.leaves(trees[g]), jax.tree.leaves(out[g])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_torn_checkpoint_ignored(tmp_path):
    d = str(tmp_path)
    trees = _tree()
    save_checkpoint(d, 1, trees)
    save_checkpoint(d, 2, trees)
    os.remove(os.path.join(d, "step_000002", "COMMIT"))  # simulate crash
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), trees)
    step, _ = load_checkpoint(d, template)
    assert step == 1


def test_manager_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.latest_step() == 4
    kept = sorted(os.listdir(str(tmp_path)))
    assert kept == ["step_000003", "step_000004"]


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    trees = _tree()
    mgr.save(5, trees)
    mgr.wait()
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), trees)
    step, out = mgr.restore(template)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(trees["params"]["w"]))


def test_shape_mismatch_raises(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    bad = _tree()
    bad["params"]["w"] = jnp.zeros((2, 2))
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), bad)
    with pytest.raises(AssertionError):
        load_checkpoint(d, template)
