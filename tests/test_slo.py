"""SLO frontier harness: loadgen drives == inline drives (zero added
dispatches), frontier monotonicity, history record schema, and the
regression sentinel's classification rules + self-test."""
import collections
import json
import os

import jax
import numpy as np
import pytest

from benchmarks.common import (HISTORY_SCHEMA_VERSION,
                               validate_history_record)
from repro.fleet.runtime import fleet_reuse_step
from repro.kernels import ops
from repro.obs import loadgen, sentinel
from repro.serving.detector import (DetectorConfig, PackedActivationCache,
                                    RoIDetector)


@pytest.fixture(scope="module")
def det():
    return RoIDetector(DetectorConfig(tile=8, channels=(4,)),
                       jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def cfg():
    return loadgen.LoadgenConfig(steps=3, channels=(4,),
                                 grid_shape=(3, 4))


# ---------------------------------------------------------------------------
# loadgen: the harness is the production loop
# ---------------------------------------------------------------------------

def test_drive_fleet_adds_zero_dispatches(det, cfg):
    """drive_fleet must issue bit-identical kernel dispatch Counters to
    an inline fleet_reuse_step loop over the same trace."""
    grids = loadgen.make_grids(cfg, 1, 2)
    frames_list = loadgen.make_frame_trace(cfg, grids, 0.5)

    inline = collections.Counter()
    with ops.count_kernels() as region:
        cache = PackedActivationCache()
        for frames in frames_list:
            fleet_reuse_step(det, frames, grids, cache)
    inline = collections.Counter(region)

    with ops.count_kernels() as region:
        reports, _, counts = loadgen.drive_fleet(
            det, frames_list, grids, PackedActivationCache())
    assert collections.Counter(region) == inline
    assert counts == inline
    assert len(reports) == len(frames_list)
    assert reports[0].cold and not reports[1].cold


def test_drive_fleet_outputs_match_exact_at_threshold_zero(det, cfg):
    grids = loadgen.make_grids(cfg, 1, 2)
    frames_list = loadgen.make_frame_trace(cfg, grids, 0.5)
    _, outs, _ = loadgen.drive_fleet(det, frames_list, grids,
                                     PackedActivationCache(),
                                     keep_outputs=True)
    floor, mean = loadgen.accuracy_vs_exact(det, frames_list, grids, outs)
    assert floor == 1.0 and mean == 1.0      # threshold 0 is bit-exact


def test_frame_trace_static_fraction_semantics(cfg):
    grids = loadgen.make_grids(cfg, 1, 2)
    frozen = loadgen.make_frame_trace(cfg, grids, 1.0)
    for step in frozen[1:]:                  # fully static: bit-equal
        for cam in range(2):
            np.testing.assert_array_equal(step[0][cam], frozen[0][0][cam])
    moving = loadgen.make_frame_trace(cfg, grids, 0.0)
    assert any(not np.array_equal(moving[1][0][c], moving[0][0][c])
               for c in range(2))


def test_transport_monotone_in_scripted_severity(cfg):
    """The frontier sanity property --slo gates on: deeper scripted
    congestion cannot lower the p99 response delay."""
    p99 = [loadgen.transport_window(cfg, 4, c, 0.75).p99_s
           for c in ("none", "episode:0.6", "episode:0.3")]
    assert p99[0] <= p99[1] + 1e-9 <= p99[2] + 2e-9, p99
    with pytest.raises(ValueError):
        loadgen.link_for(cfg, "bogus:1.0")


def test_run_point_emits_full_slo_report(det, cfg):
    point = loadgen.SweepPoint(1, 2, "episode:0.5", 0.5)
    res = loadgen.run_point(cfg, det, point)
    assert res["point"]["n_cameras"] == 2
    slo = res["slo"]
    for key in ("p50_delay_s", "p99_delay_s", "part_p99_s",
                "deadline_hit_rate", "bytes_total", "shed_bytes",
                "accuracy_floor", "changed_tile_fraction",
                "compute_tile_fraction", "cache", "steps"):
        assert key in slo, key
    assert slo["n_steps"] == cfg.steps
    assert slo["accuracy_floor"] == 1.0
    assert point.severity == pytest.approx(0.5)
    assert loadgen.SweepPoint(1, 2, "trace:x").severity == -1.0


# ---------------------------------------------------------------------------
# history record schema
# ---------------------------------------------------------------------------

def _valid_record():
    return {"schema": HISTORY_SCHEMA_VERSION, "ts": "2026-01-01T00:00:00",
            "git_sha": "abc123def456", "mode": "slo",
            "panels": ["slo"], "headline_walls": {"x.wall_s": 0.1},
            "frontier": {"p99_delay_worst_s": 1.2}}


def test_history_validator_accepts_valid():
    assert validate_history_record(_valid_record()) == []
    rec = _valid_record()
    del rec["frontier"]                       # frontier is optional
    assert validate_history_record(rec) == []


@pytest.mark.parametrize("mutate", [
    lambda r: r.pop("git_sha"),
    lambda r: r.pop("schema"),
    lambda r: r.update(schema=0),
    lambda r: r.update(headline_walls={"x": "fast"}),
    lambda r: r.update(headline_walls={"x": True}),
    lambda r: r.update(panels=[3]),
    lambda r: r.update(frontier="yes"),
    lambda r: r.update(frontier={"m": None}),
])
def test_history_validator_rejects_malformed(mutate):
    rec = _valid_record()
    mutate(rec)
    assert validate_history_record(rec) != []


def test_history_validator_rejects_non_dict():
    assert validate_history_record(["not", "a", "dict"]) != []


# ---------------------------------------------------------------------------
# sentinel
# ---------------------------------------------------------------------------

def _hist(tmp_path, records):
    p = tmp_path / "hist.jsonl"
    with open(p, "w") as f:
        for r in records:
            f.write(json.dumps(r) + "\n")
    return str(p)


def _rec(sha, walls):
    return {"schema": 1, "ts": "t", "git_sha": sha, "mode": "m",
            "panels": [], "headline_walls": walls}


BASE = {"reuse.step_wall_s": 0.10, "obs.overhead_frac": 0.017}


def test_sentinel_clean_history_passes(tmp_path):
    recs = [_rec(f"s{i}", BASE) for i in range(4)]
    rep = sentinel.analyze_path(_hist(tmp_path, recs))
    assert rep.status == "ok" and not rep.has_regression
    assert "clean" in rep.render()


def test_sentinel_flags_2x_wall_slowdown(tmp_path):
    recs = [_rec(f"s{i}", BASE) for i in range(3)]
    recs.append(_rec("head", {"reuse.step_wall_s": 0.20,
                              "obs.overhead_frac": 0.017}))
    rep = sentinel.analyze_path(_hist(tmp_path, recs))
    assert rep.has_regression
    assert [f.metric for f in rep.regressions] == ["reuse.step_wall_s"]
    out = rep.render()
    assert "reuse.step_wall_s" in out and "REGRESSION" in out
    assert "+0.1" in out                      # the delta is printed


def test_sentinel_min_of_reps_within_sha(tmp_path):
    """A SHA's noisy rep is absorbed by the per-SHA min: one slow record
    next to a fast one at head must not flag."""
    recs = [_rec(f"s{i}", BASE) for i in range(3)]
    recs.append(_rec("head", {"reuse.step_wall_s": 0.30}))   # noisy rep
    recs.append(_rec("head", {"reuse.step_wall_s": 0.10}))   # clean rep
    rep = sentinel.analyze_path(_hist(tmp_path, recs))
    assert not rep.has_regression


def test_sentinel_median_of_reps_for_absolute_metrics(tmp_path):
    """Absolute-only metrics have two-sided noise: a single garbage rep
    (e.g. overhead_frac -0.20 from a CPU-contended run) must not latch
    into a historical SHA's value via a min and flag a healthy head."""
    recs = [_rec(f"s{i}", BASE) for i in range(2)]
    for frac in (-0.012, -0.197, 0.007):      # one polluted rep
        recs.append(_rec("s2", {"reuse.step_wall_s": 0.10,
                                "obs.overhead_frac": frac}))
    recs.append(_rec("head", {"reuse.step_wall_s": 0.10,
                              "obs.overhead_frac": 0.0002}))
    rep = sentinel.analyze_path(_hist(tmp_path, recs))
    assert not rep.has_regression


def test_sentinel_median_baseline_robust_to_one_fast_outlier(tmp_path):
    """One historically-fast SHA cannot poison the baseline: the median
    of the window, not the min, is the comparison point."""
    walls = [0.10, 0.02, 0.10, 0.11]          # one freak-fast SHA
    recs = [_rec(f"s{i}", {"reuse.step_wall_s": w})
            for i, w in enumerate(walls)]
    recs.append(_rec("head", {"reuse.step_wall_s": 0.11}))
    rep = sentinel.analyze_path(_hist(tmp_path, recs))
    assert not rep.has_regression


def test_sentinel_noise_band_never_flags_overhead_frac(tmp_path):
    """The known ±2%-per-arm obs-overhead band (worst absolute swing
    0.04, including sign flips through zero) must never trip the
    absolute-only rule."""
    for head_val in (-0.022, 0.019, 0.017 + 0.04):
        recs = [_rec(f"s{i}", BASE) for i in range(3)]
        recs.append(_rec("head", {"reuse.step_wall_s": 0.10,
                                  "obs.overhead_frac": head_val}))
        rep = sentinel.analyze_path(_hist(tmp_path, recs))
        assert not rep.has_regression, head_val
    # a real structural regression (overhead jumps to 10%) DOES flag
    recs = [_rec(f"s{i}", BASE) for i in range(3)]
    recs.append(_rec("head", {"reuse.step_wall_s": 0.10,
                              "obs.overhead_frac": 0.10}))
    rep = sentinel.analyze_path(_hist(tmp_path, recs))
    assert rep.has_regression
    assert rep.regressions[0].metric == "obs.overhead_frac"


def test_sentinel_skips_pre_schema_records_with_warning(tmp_path):
    pre = {"ts": "t", "git_sha": "old", "mode": "m", "panels": [],
           "headline_walls": {"reuse.step_wall_s": 0.01}}   # no schema
    recs = [pre] + [_rec(f"s{i}", BASE) for i in range(3)] \
        + [_rec("head", BASE)]
    path = _hist(tmp_path, recs)
    records, warnings = sentinel.load_history(path)
    assert len(records) == 4
    assert any("pre-schema" in w for w in warnings)
    rep = sentinel.analyze_path(path)
    assert not rep.has_regression             # 0.01 never entered baseline
    assert any("pre-schema" in w for w in rep.skipped)


def test_sentinel_degenerate_histories(tmp_path):
    rep = sentinel.analyze_path(str(tmp_path / "missing.jsonl"))
    assert rep.status == "no_data" and not rep.has_regression
    rep = sentinel.analyze_path(_hist(tmp_path, [_rec("only", BASE)]))
    assert rep.status == "no_baseline" and not rep.has_regression
    assert "no prior SHA" in rep.render()


def test_sentinel_frontier_metrics_gated(tmp_path):
    recs = [dict(_rec(f"s{i}", BASE),
                 frontier={"p99_delay_worst_s": 1.0}) for i in range(3)]
    recs.append(dict(_rec("head", BASE),
                     frontier={"p99_delay_worst_s": 2.5}))
    rep = sentinel.analyze_path(_hist(tmp_path, recs))
    assert rep.has_regression
    assert rep.regressions[0].metric == "frontier.p99_delay_worst_s"


def test_sentinel_improvement_classified(tmp_path):
    recs = [_rec(f"s{i}", BASE) for i in range(3)]
    recs.append(_rec("head", {"reuse.step_wall_s": 0.05,
                              "obs.overhead_frac": 0.017}))
    rep = sentinel.analyze_path(_hist(tmp_path, recs))
    assert not rep.has_regression
    assert any(f.classification == "improvement" for f in rep.findings)


def test_sentinel_self_test_passes_on_real_history():
    """The gate's own self-test: injected 2x slowdown flagged, clean +
    noise-band copies pass — against the repo's actual history file."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    res = sentinel.self_test(os.path.join(repo, "BENCH_history.jsonl"))
    assert res["clean_pass"] and res["slowdown_flagged"] \
        and res["noise_band_pass"]
    assert res["flagged_metrics"]
