"""Sharded fleet serving: shard-plan balance, mesh=(1,) bit-identity to
the single-device super-launch, per-shard dispatch ceilings, async
pipeline parity, per-shard drift invalidation, and per-context kernel
counters under threads.

Multi-device cases run in subprocesses (XLA locks the host platform
device count at first init); everything else uses an in-process
1-device fleet mesh — bit-identity there is the base case the
multi-shard subprocess extends."""
import os
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

import jax

from hypothesis import given, settings, strategies as st

from repro.fleet import sharded_fleet_step, wire_shard_invalidation
from repro.fleet.sharded import AsyncShardedPipeline, ShardedSuperlaunch
from repro.kernels import ops
from repro.launch.mesh import make_fleet_mesh
from repro.net.batcher import DeadlineGroupFormer
from repro.net.encoder import gate_threshold_schedule
from repro.serving.detector import (DetectorConfig, PackedActivationCache,
                                    RoIDetector, ShardedActivationCache)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 2, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, \
        f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# shard planning (host-only: no mesh, no kernels)
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(0, 40), min_size=1, max_size=24),
       st.integers(1, 8))
@settings(max_examples=60, deadline=None)
def test_shard_plan_balance_property(tile_counts, n_shards):
    """LPT bound: max shard load <= mean + the largest single group —
    and the plan is a partition (every group exactly once)."""
    grids = [[np.ones((1, t), bool)] if t else [np.zeros((1, 1), bool)]
             for t in tile_counts]
    plan = ops.shard_plan(grids, n_shards)
    assert plan.n_groups == len(tile_counts)
    assert sorted(sum((plan.shard_groups(s) for s in range(n_shards)), [])
                  ) == list(range(len(tile_counts)))
    loads = plan.shard_tiles
    assert int(loads.sum()) == sum(tile_counts)
    if sum(tile_counts):
        assert loads.max() <= loads.sum() / n_shards + max(tile_counts)
        assert plan.imbalance >= 1.0


def test_shard_plan_rejects_zero_shards():
    with pytest.raises(ValueError):
        ops.shard_plan([[np.ones((1, 1), bool)]], 0)


# ---------------------------------------------------------------------------
# mesh=(1,) sharded path == single-device super-launch, bit for bit
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_det():
    return RoIDetector(DetectorConfig(tile=8, channels=(4, 6)),
                       jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def ragged_grids():
    rng = np.random.default_rng(0)
    return {0: [rng.random((3, 4)) < 0.6, rng.random((2, 2)) < 0.9],
            1: [rng.random((4, 3)) < 0.5],
            2: [np.zeros((2, 3), bool)],          # empty group
            3: [rng.random((3, 3)) < 0.7, np.ones((1, 4), bool)]}


def _trace(grids, steps, seed=7):
    """Frames with per-camera static repeats sprinkled in."""
    rng = np.random.default_rng(seed)
    out, prev = [], None
    for s in range(steps):
        f = {}
        for gid, gs in grids.items():
            f[gid] = [prev[gid][i] if (s > 0 and (s + gid + i) % 3 == 0)
                      else rng.random((g.shape[0] * 8, g.shape[1] * 8, 3)
                                      ).astype(np.float32)
                      for i, g in enumerate(gs)]
        prev = f
        out.append(f)
    return out


def test_mesh1_bit_identical_with_dispatch_ceiling(small_det, ragged_grids):
    """The sharded step on a 1-device mesh reproduces
    ``superlaunch_forward_reuse`` bit for bit over a ragged trace (cold
    start, warm deltas, static repeats, an empty group) while
    ``sharded_fleet_step`` asserts the 1-gate + ≤3-conv per-shard
    dispatch structure every step."""
    det, grids = small_det, ragged_grids
    rt = ShardedSuperlaunch(det, grids, make_fleet_mesh(1))
    scache = rt.make_cache()
    pcache = PackedActivationCache()
    for f in _trace(grids, 5):
        ref, _ = det.superlaunch_forward_reuse(f, grids, pcache, 0.0)
        got, counts, stats = sharded_fleet_step(rt, f, scache, 0.0)
        assert counts["tile_delta_gate"] == 1
        assert sum(v for k, v in counts.items()
                   if k != "tile_delta_gate") <= 3
        for gid in grids:
            for i in range(len(grids[gid])):
                np.testing.assert_array_equal(np.asarray(ref[gid][i]),
                                              got[gid][i])
    assert scache.steps == 5 and scache.cold_steps == 1
    assert 0 < scache.compute_fraction


def test_mesh1_all_static_step_is_gate_only(small_det, ragged_grids):
    det, grids = small_det, ragged_grids
    rt = ShardedSuperlaunch(det, grids, make_fleet_mesh(1))
    cache = rt.make_cache()
    f = _trace(grids, 1)[0]
    sharded_fleet_step(rt, f, cache, 0.0)
    _, counts, stats = sharded_fleet_step(rt, f, cache, 0.0)  # same frames
    assert stats.computed == 0 and stats.k_max == 0
    assert dict(counts) == {"tile_delta_gate": 1}
    assert stats.canvas_bytes == 0 and cache.canvas_bytes_last == 0


def test_mesh1_step_full_matches_superlaunch(small_det, ragged_grids):
    det, grids = small_det, ragged_grids
    rt = ShardedSuperlaunch(det, grids, make_fleet_mesh(1))
    f = _trace(grids, 1)[0]
    ref = det.superlaunch_forward(f, grids)
    got = rt.step_full(f)
    for gid in grids:
        for i in range(len(grids[gid])):
            np.testing.assert_array_equal(np.asarray(ref[gid][i]),
                                          got[gid][i])


def test_empty_fleet_launches_nothing(small_det):
    grids = {0: [np.zeros((2, 2), bool)], 1: [np.zeros((1, 3), bool)]}
    rt = ShardedSuperlaunch(small_det, grids, make_fleet_mesh(1))
    cache = rt.make_cache()
    f = {0: [np.zeros((16, 16, 3), np.float32)],
         1: [np.zeros((8, 24, 3), np.float32)]}
    got, counts, stats = sharded_fleet_step(rt, f, cache, 0.0)
    assert dict(counts) == {} and stats.total_tiles == 0
    assert got[0][0].shape == (16, 16, small_det.head.shape[-1])
    assert not got[0][0].any()


def test_async_pipeline_bit_identical_and_overlapped(small_det,
                                                     ragged_grids):
    """Pipelined submits return the same bits as the synchronous path
    and actually overlap host planning with in-flight device steps."""
    det, grids = small_det, ragged_grids
    rt = ShardedSuperlaunch(det, grids, make_fleet_mesh(1))
    pipe = AsyncShardedPipeline(rt, rt.make_cache())
    pcache = PackedActivationCache()
    trace = _trace(grids, 6)
    for f in trace:
        pipe.submit(f)
    outs = pipe.drain()
    assert [s for s, _, _ in outs] == list(range(6))
    for (sid, got, _), f in zip(outs, trace):
        ref, _ = det.superlaunch_forward_reuse(f, grids, pcache, 0.0)
        for gid in grids:
            for i in range(len(grids[gid])):
                np.testing.assert_array_equal(np.asarray(ref[gid][i]),
                                              got[gid][i])
    # every submit after the first plans while a step is in flight
    assert pipe.overlap_fraction > 0.5
    assert len(pipe.latencies) == 6 and pipe.p99_latency_s > 0


# ---------------------------------------------------------------------------
# per-shard drift invalidation
# ---------------------------------------------------------------------------

class _FakeCam:
    def __init__(self, cam_id):
        self.cam_id = cam_id


class _FakeAdapter:
    """The DriftAdapter listener surface (add_mask_listener + cam_grids
    + cameras), minus the drift monitor."""

    def __init__(self, grids):
        self.cameras = [_FakeCam(i) for i in range(len(grids))]
        self.cam_grids = {i: g.copy() for i, g in enumerate(grids)}
        self._fns = []

    def add_mask_listener(self, fn):
        self._fns.append(fn)

    def resolve(self):                     # a mask mutation lands
        for fn in self._fns:
            fn(self)


def test_invalidation_targets_exactly_the_owning_shard():
    grids = [[np.ones((1, 3), bool)], [np.ones((1, 5), bool)],
             [np.ones((1, 4), bool)]]
    plan = ops.shard_plan(grids, 2)
    cache = ShardedActivationCache(plan, gids=[10, 11, 12])
    cache.valid[:] = True
    adapters = {11: _FakeAdapter(grids[1])}
    wire_shard_invalidation(adapters, cache)
    adapters[11].resolve()
    owner = cache.owner_shard(11)
    assert not cache.valid[owner]
    assert cache.valid[1 - owner]
    assert cache.shard_invalidations[owner] == 1
    assert cache.shard_invalidations[1 - owner] == 0
    cache.invalidate()                      # fleet-wide listener form
    assert not cache.valid.any()


def test_rebuild_group_keeps_bits_and_recomputes_cold(small_det,
                                                      ragged_grids):
    """A re-solve that grows one group's mask rebuilds the sharded
    tables, forces exactly one cold recompute, and the next step is
    bit-identical to the plain super-launch on the NEW grids."""
    det = small_det
    grids = {g: [a.copy() for a in gs] for g, gs in ragged_grids.items()}
    rt = ShardedSuperlaunch(det, grids, make_fleet_mesh(1))
    cache = rt.make_cache()
    trace = _trace(grids, 3)
    for f in trace[:2]:
        sharded_fleet_step(rt, f, cache, 0.0)
    ad = _FakeAdapter(grids[1])
    wire_shard_invalidation({1: ad}, cache, runtime=rt)
    ad.cam_grids[0][:] = True               # the re-solved (grown) mask
    ad.resolve()
    assert not cache.valid[cache.owner_shard(1)]
    assert rt.grids[1][0].all()
    new_grids = {**grids, 1: [ad.cam_grids[0]]}
    got, counts, stats = sharded_fleet_step(rt, trace[2], cache, 0.0)
    assert stats.cold_shards == 1
    ref = det.superlaunch_forward(trace[2], new_grids)
    gid = 1
    for i in range(len(new_grids[gid])):
        np.testing.assert_array_equal(np.asarray(ref[gid][i]),
                                      got[gid][i])


# ---------------------------------------------------------------------------
# per-context kernel counters under concurrency (satellite: ops counters)
# ---------------------------------------------------------------------------

def test_count_kernels_regions_are_thread_isolated():
    """Concurrent count_kernels regions never see each other's
    dispatches (the contextvar stack is per-thread), a main-thread
    region never absorbs worker bumps, and the global counter sees
    everything — the invariants the async sharded pipeline and
    subprocess-free concurrent benches rely on."""
    ops.KERNEL_COUNTS.clear()
    errs, done = [], []
    gate = threading.Barrier(4)
    # distinct CANONICAL names (record_dispatch validates against
    # obs.metrics.KERNEL_NAMES), one per worker thread
    names = ["sbnet_gather", "roi_conv", "tile_delta", "roi_attention"]

    def worker(name, n):
        try:
            with ops.count_kernels() as region:
                gate.wait(timeout=30)     # all regions live at once
                for _ in range(n):
                    ops.record_dispatch(name)
            assert dict(region) == {name: n}, region
            done.append(name)
        except Exception as e:            # pragma: no cover
            errs.append(e)

    ts = [threading.Thread(target=worker, args=(names[i], 50 + i))
          for i in range(4)]
    with ops.count_kernels() as outer:
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    assert not errs and len(done) == 4
    # worker regions are invisible to the main thread's region...
    assert dict(outer) == {}
    # ...but the global counter accumulated every thread's dispatches
    for i in range(4):
        assert ops.KERNEL_COUNTS[names[i]] == 50 + i


# ---------------------------------------------------------------------------
# per-camera gate thresholds (satellite: rate-controller schedule)
# ---------------------------------------------------------------------------

def test_per_camera_thresholds_gate_only_shedded_cameras(small_det):
    """A raised per-camera threshold suppresses relaunches for small
    deltas on THAT camera only; threshold-0 cameras keep the exact
    gate."""
    det = small_det
    grids = [np.ones((2, 2), bool), np.ones((2, 2), bool)]
    rng = np.random.default_rng(3)
    f0 = [rng.random((16, 16, 3)).astype(np.float32) for _ in range(2)]
    cache = PackedActivationCache()
    det.fleet_forward_reuse(f0, grids, cache, 0.0)
    # tiny per-pixel nudge on both cameras; cam 1 gets a huge threshold
    f1 = [f + np.float32(1e-3) for f in f0]
    thr = np.array([0.0, 1e9])
    _, stats = det.fleet_forward_reuse(f1, grids, cache, thr)
    assert stats.raw_changed == 4          # only cam 0's tiles relaunch
    # schedule shape: quality 1.0 keeps the exact gate, shedding raises
    q = np.array([[1.0, 1.0], [0.5, 0.9]])
    sched = gate_threshold_schedule(q, tile=8, n_channels=3)
    assert sched[0] == 0.0 and sched[1] > 0.0


# ---------------------------------------------------------------------------
# straggler fold gating (satellite: capture-segment references)
# ---------------------------------------------------------------------------

def test_straggler_fold_capture_gating_launches_fewer_tiles(small_det):
    """Folded late segments gated against their CAPTURE-segment
    reference (capture-order waves) launch no more tiles than gating
    them against the already-advanced current reference."""
    det = small_det
    grids = [np.ones((2, 2), bool) for _ in range(3)]
    rng = np.random.default_rng(2)
    base = [rng.random((16, 16, 3)).astype(np.float32) for _ in range(3)]

    def frame(cam, t):
        f = base[cam].copy()
        f[(t % 3) * 4:(t % 3) * 4 + 4] += 0.5      # small moving stripe
        return f

    def run(fold_gate):
        gf = DeadlineGroupFormer(det, [0, 1, 2], deadline_s=0.5,
                                 reuse_cache=PackedActivationCache(),
                                 fold_gate=fold_gate)
        t, rels = 0.0, []
        for step in range(6):
            if step % 2 == 1:         # cam 2 catches up with TWO segments
                for tt in (step - 1, step):
                    r = gf.offer(t, 2, frame(2, tt), grids[2])
                    t += 0.01
                    if r:
                        rels.append(r)
            for cam in (0, 1):
                r = gf.offer(t, cam, frame(cam, step), grids[cam])
                t += 0.01
                if r:
                    rels.append(r)
            if step % 2 == 0:
                r = gf.poll(t + 1.0)  # deadline fires without cam 2
                if r:
                    rels.append(r)
        return gf, sum(r.folded_frames for r in rels)

    gf_cap, folded_cap = run("capture")
    gf_cur, folded_cur = run("current")
    assert folded_cap == folded_cur > 0
    assert gf_cap.reclaimed_launches > 0
    assert gf_cap.reuse_launched_tiles < gf_cur.reuse_launched_tiles
    with pytest.raises(ValueError):
        DeadlineGroupFormer(det, [0], 0.1, fold_gate="bogus")


# ---------------------------------------------------------------------------
# multi-shard subprocess: bit-exactness + warm-shard survival (slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_two_shard_bit_exact_and_warm_survival():
    _run("""
        import numpy as np, jax
        from repro.serving.detector import (RoIDetector, DetectorConfig,
                                            PackedActivationCache)
        from repro.fleet import sharded_fleet_step
        from repro.fleet.sharded import ShardedSuperlaunch
        from repro.launch.mesh import make_fleet_mesh

        assert len(jax.devices()) == 2
        rng = np.random.default_rng(0)
        det = RoIDetector(DetectorConfig(tile=8, channels=(4, 6)),
                          jax.random.PRNGKey(0))
        grids = {0: [rng.random((3, 4)) < 0.6, rng.random((2, 2)) < 0.9],
                 1: [rng.random((4, 3)) < 0.5],
                 2: [np.zeros((2, 3), bool)],
                 3: [rng.random((3, 3)) < 0.7, np.ones((1, 4), bool)]}
        mesh = make_fleet_mesh(2)
        rt = ShardedSuperlaunch(det, grids, mesh)
        assert len(set(rt.plan.assignment)) == 2
        cache = rt.make_cache()
        pc = PackedActivationCache()
        prev = None
        for step in range(4):
            f = {}
            for gid, gs in grids.items():
                f[gid] = [prev[gid][i]
                          if (step > 0 and (step + gid + i) % 3 == 0)
                          else rng.random((g.shape[0] * 8,
                                           g.shape[1] * 8, 3)
                                          ).astype(np.float32)
                          for i, g in enumerate(gs)]
            prev = f
            ref, _ = det.superlaunch_forward_reuse(f, grids, pc, 0.0)
            got, counts, stats = sharded_fleet_step(rt, f, cache, 0.0)
            assert counts["tile_delta_gate"] == 1
            assert sum(v for k, v in counts.items()
                       if k != "tile_delta_gate") <= 3
            for gid in grids:
                for i in range(len(grids[gid])):
                    assert np.array_equal(np.asarray(ref[gid][i]),
                                          got[gid][i]), (step, gid, i)
        # invalidate one group: only its shard goes cold next step
        gid = 1
        cache.invalidate_group(gid)
        f = prev
        _, _, stats = sharded_fleet_step(rt, f, cache, 0.0)
        assert stats.cold_shards == 1
        print("2-shard OK")
        """)
