"""Temporal delta-gated inference: the reuse gate kernel, changed-set
dilation, compact super-launches, the persistent packed-activation cache,
and the blocked entry/scatter walks.

The contract everywhere is BIT-identity with full recompute at threshold
0: the reuse path changes which tiles are convolved, never the math of
any tile whose value is used.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fleet import fleet_reuse_step
from repro.kernels import ops, ref
from repro.serving.detector import (DetectorConfig, PackedActivationCache,
                                    RoIDetector)


def _rng(seed=0):
    return np.random.default_rng(seed)


def _fleet_pack(rng, shapes, density=0.5):
    grids = [rng.random(s) < density for s in shapes]
    for g in grids:
        g[min(1, g.shape[0] - 1), min(1, g.shape[1] - 1)] = True
    idx, _ = ops.fleet_indices(grids)
    nbr = ops.fleet_neighbor_table(grids)
    return grids, idx, nbr


# ---------------------------------------------------------------------------
# the gate kernel: bit-exact window + body pricing in one dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qstep", [8.0, 2.0, 16.0])
def test_tile_delta_gate_bit_exact_vs_reference(qstep):
    rng = _rng(1)
    th = tw = 8
    grids, idx, _ = _fleet_pack(rng, [(4, 5), (3, 3)])
    cur = rng.normal(size=(2, 4 * th, 5 * tw, 3)).astype(np.float32)
    prev = cur + (rng.random(cur.shape) < 0.02) * \
        rng.normal(size=cur.shape).astype(np.float32) * 20
    prev = prev.astype(np.float32)
    pad = ((0, 0), (1, 1), (1, 1), (0, 0))
    cur_p = jnp.asarray(np.pad(cur, pad))
    ref_win = ops.gather_windows(jnp.asarray(np.pad(prev, pad)),
                                 jnp.asarray(idx), th, tw)
    stats, wins = ops.tile_delta_gate(cur_p, ref_win, jnp.asarray(idx),
                                      th, tw, qstep=qstep)
    expect = ref.tile_delta_gate(cur, prev, idx, th, tw, qstep=qstep)
    np.testing.assert_array_equal(np.asarray(stats), expect)
    # the windows output IS the current packed windows (the reference
    # advance source)
    np.testing.assert_array_equal(
        np.asarray(wins),
        np.asarray(ops.gather_windows(cur_p, jnp.asarray(idx), th, tw)))


def test_tile_delta_gate_body_cols_match_tile_delta():
    """Cols 0..3 of the gate stats equal ``tile_delta`` on the unpadded
    per-camera frame — the rate controller can threshold the shared
    dispatch with unchanged semantics."""
    rng = _rng(2)
    th = tw = 8
    grids, idx, _ = _fleet_pack(rng, [(3, 4), (4, 3)])
    cur = rng.normal(size=(2, 4 * th, 4 * tw, 3)).astype(np.float32)
    prev = (cur + rng.normal(size=cur.shape) * 5).astype(np.float32)
    gate = ref.tile_delta_gate(cur, prev, idx, th, tw)
    for c, g in enumerate(grids):
        ii = ops.mask_to_indices(g)
        body = ref.tile_delta(cur[c], prev[c], ii, th, tw)
        np.testing.assert_array_equal(gate[idx[:, 0] == c][:, :4],
                                      body[:, :4])


def test_tile_delta_gate_sees_inactive_neighbor_halo_change():
    """A pixel flip in an INACTIVE tile adjacent to an active tile must
    register through the active tile's haloed window — the body view
    alone would miss it and the entry conv would serve a stale tile."""
    th = tw = 8
    grid = np.zeros((3, 3), bool)
    grid[1, 1] = True                      # single active tile
    idx, _ = ops.fleet_indices([grid])
    cur = np.zeros((1, 3 * th, 3 * tw, 2), np.float32)
    prev = cur.copy()
    prev[0, th - 1, tw + 3, 0] = 7.0       # inactive N tile, bottom row
    pad = ((0, 0), (1, 1), (1, 1), (0, 0))
    ref_win = ops.gather_windows(jnp.asarray(np.pad(prev, pad)),
                                 jnp.asarray(idx), th, tw)
    out, _ = ops.tile_delta_gate(jnp.asarray(np.pad(cur, pad)), ref_win,
                                 jnp.asarray(idx), th, tw)
    out = np.asarray(out)
    assert out[0, ops.GATE_WIN_EXACT] == 1     # window sees it
    assert out[0, 1] == 0                      # body nnz does not


# ---------------------------------------------------------------------------
# changed-set dilation + compaction
# ---------------------------------------------------------------------------

def test_dilate_changed_matches_grid_morphology():
    """Neighbor-table dilation == 3x3 morphological dilation on the tile
    grid, restricted to active tiles (the only tiles that exist)."""
    rng = _rng(3)
    grid = rng.random((9, 11)) < 0.6
    grid[4, 5] = True
    idx = ops.mask_to_indices(grid)
    nbr = ops.neighbor_table(idx, grid.shape)
    raw = rng.random(idx.shape[0]) < 0.1
    got = ops.dilate_changed(raw, nbr)
    g = np.zeros(grid.shape, bool)
    g[idx[raw][:, 0], idx[raw][:, 1]] = True
    gp = np.pad(g, 1)
    dil = np.zeros_like(g)
    for dy in (0, 1, 2):
        for dx in (0, 1, 2):
            dil |= gp[dy:dy + g.shape[0], dx:dx + g.shape[1]]
    np.testing.assert_array_equal(got, dil[idx[:, 0], idx[:, 1]])


def test_reuse_sets_growth_and_nesting():
    rng = _rng(4)
    grid = rng.random((10, 10)) < 0.7
    grid[5, 5] = True
    idx = ops.mask_to_indices(grid)
    nbr = ops.neighbor_table(idx, grid.shape)
    raw = np.zeros(idx.shape[0], bool)
    raw[np.nonzero((idx[:, 0] == 5) & (idx[:, 1] == 5))[0]] = True
    changed, compute = ops.reuse_sets(raw, nbr, n_layers=3)
    assert (raw <= changed).all() and (changed <= compute).all()
    # changed = raw dilated N-1 times, compute = changed dilated N-1 more
    d = raw
    for _ in range(2):
        d = ops.dilate_changed(d, nbr)
    np.testing.assert_array_equal(changed, d)
    for _ in range(2):
        d = ops.dilate_changed(d, nbr)
    np.testing.assert_array_equal(compute, d)
    # a 1-layer net needs no dilation at all (entry reads the frame)
    c1, e1 = ops.reuse_sets(raw, nbr, n_layers=1)
    np.testing.assert_array_equal(c1, raw)
    np.testing.assert_array_equal(e1, raw)


def test_compact_tables_remap_and_zero_halo():
    rng = _rng(5)
    grids, idx, nbr = _fleet_pack(rng, [(4, 4), (3, 5)])
    n = idx.shape[0]
    keep = rng.random(n) < 0.5
    keep[0] = True
    cidx, cnbr = ops.compact_tables(idx, nbr, keep)
    k = int(keep.sum())
    assert cidx.shape == (k, 3) and cnbr.shape == (k, 8)
    np.testing.assert_array_equal(cidx, idx[keep])
    kept_slots = np.nonzero(keep)[0]
    for r, slot in enumerate(kept_slots):
        for j in range(8):
            src = nbr[slot, j]
            if src < 0 or not keep[src]:
                assert cnbr[r, j] == -1      # dropped donor -> zero halo
            else:
                assert kept_slots[cnbr[r, j]] == src


# ---------------------------------------------------------------------------
# choose_block: VMEM-budgeted tile-block sizing
# ---------------------------------------------------------------------------

def test_choose_block_default_budget_and_floors():
    # the 16 MiB default recovers the calibrated interpret-mode 128 for
    # the YOLO-lite shapes — the old hardcoded constant, now derived
    assert ops.choose_block(16, 16, 16, 3) == 128
    assert ops.choose_block(16, 16, 16, 3, vmem_bytes=1024) == 1
    last = 0
    for mb in (1, 2, 4, 8, 16, 32):
        b = ops.choose_block(16, 16, 16, 3, vmem_bytes=mb << 20)
        assert b >= max(last, 1)
        last = b
    # wider channels shrink the block
    assert ops.choose_block(16, 16, 64, 3) < ops.choose_block(16, 16, 8, 3)
    # detector wires it through
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    assert det.block == 128
    det_small = RoIDetector(DetectorConfig(vmem_budget_bytes=1 << 20),
                            jax.random.PRNGKey(0))
    assert 1 <= det_small.block < det.block


# ---------------------------------------------------------------------------
# blocked entry + blocked scatter: bit-identical to the per-tile walks
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block", [2, 3, 16, 256])
def test_blocked_entry_bitwise_vs_per_tile(block):
    rng = _rng(6)
    th = tw = 8
    grids, idx, _ = _fleet_pack(rng, [(4, 5), (3, 3)])
    x = jnp.asarray(rng.normal(size=(2, 4 * th, 5 * tw, 3)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 3, 6)) * 0.3, jnp.float32)
    base = ops.roi_conv_entry(x, w, jnp.asarray(idx), th, tw, block=1)
    out = ops.roi_conv_entry(x, w, jnp.asarray(idx), th, tw, block=block)
    assert (np.asarray(out) == np.asarray(base)).all()


@pytest.mark.parametrize("block", [2, 5, 64])
def test_blocked_scatter_bitwise_vs_per_tile(block):
    """Including the repeat-last padding contract: duplicate stores must
    rewrite identical bytes, never corrupt a neighbor."""
    rng = _rng(7)
    th = tw = 8
    grids, idx, _ = _fleet_pack(rng, [(4, 5), (3, 3)])
    n = idx.shape[0]
    packed = jnp.asarray(rng.normal(size=(n, th, tw, 6)), jnp.float32)
    base = jnp.asarray(rng.normal(size=(2, 4 * th, 5 * tw, 6)),
                       jnp.float32)
    legacy = ops.sbnet_scatter_fleet(packed, jnp.asarray(idx), base,
                                     block=1)
    out = ops.sbnet_scatter_fleet(packed, jnp.asarray(idx), base,
                                  block=block)
    assert (np.asarray(out) == np.asarray(legacy)).all()


# ---------------------------------------------------------------------------
# the delta-gated fleet step: bit-identity, dispatch structure, leaks
# ---------------------------------------------------------------------------

def _mk_fleet(rng, det, group_shapes, density=0.5):
    t = det.cfg.tile
    frames, grids = {}, {}
    for gid, shapes in enumerate(group_shapes):
        grids[gid] = [rng.random(s) < density for s in shapes]
        for g in grids[gid]:
            g[min(1, g.shape[0] - 1), min(1, g.shape[1] - 1)] = True
        frames[gid] = [np.asarray(rng.normal(size=(gy * t, gx * t, 3)),
                                  np.float32) for gy, gx in shapes]
    return frames, grids


def _as_jnp(frames):
    return {g: [jnp.asarray(f) for f in fs] for g, fs in frames.items()}


def test_reuse_threshold0_bitwise_on_ragged_fleet_trace():
    """The acceptance contract: over a trace of sparse changes on a
    ragged multi-group fleet, every step's outputs are bit-identical to
    ``fleet_forward_layers`` full recompute, while convolving only the
    dilated changed sets."""
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    rng = _rng(8)
    frames, grids = _mk_fleet(rng, det,
                              [[(4, 5), (3, 4)], [(2, 3)], [(5, 3),
                                                            (3, 3)]])
    grids[1][0][:] = False
    grids[1][0][0, 0] = True               # single-tile group
    cache = PackedActivationCache()
    cur = frames
    computed = []
    for step in range(5):
        outs, counts, st = fleet_reuse_step(det, _as_jnp(cur), grids,
                                            cache)
        for gid in grids:
            legacy = det.fleet_forward_layers(
                [jnp.asarray(f) for f in cur[gid]], grids[gid])
            for a, b in zip(outs[gid], legacy):
                assert (np.asarray(a) == np.asarray(b)).all(), \
                    f"step {step} group {gid} diverged from full recompute"
        computed.append(st.computed)
        # next frame: flip a couple of pixels in one camera of one group
        cur = {g: [f.copy() for f in fs] for g, fs in cur.items()}
        gid = int(rng.integers(len(grids)))
        cam = int(rng.integers(len(cur[gid])))
        f = cur[gid][cam]
        f[int(rng.integers(f.shape[0])), int(rng.integers(f.shape[1])),
          :] += 9.0
    assert st.total_tiles > 0
    assert computed[0] == st.total_tiles       # cold step = full
    assert all(c < st.total_tiles for c in computed[1:]), computed
    assert cache.compute_fraction < 1.0


def test_all_static_frame_dispatches_gate_only():
    """Zero-copy static step: the persistent canvas is served as-is —
    the gate is the ONLY launch and not one canvas byte is written."""
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    rng = _rng(9)
    frames, grids = _mk_fleet(rng, det, [[(3, 4), (4, 3)]])
    cache = PackedActivationCache()
    fleet_reuse_step(det, _as_jnp(frames), grids, cache)   # cold seed
    outs, counts, st = fleet_reuse_step(det, _as_jnp(frames), grids,
                                        cache)
    assert st.computed == 0 and st.raw_changed == 0
    assert dict(counts) == {"tile_delta_gate": 1}
    assert st.canvas_bytes == 0 and cache.canvas_bytes_last == 0
    # and a third static step stays that way
    outs, counts, st = fleet_reuse_step(det, _as_jnp(frames), grids,
                                        cache)
    assert dict(counts) == {"tile_delta_gate": 1}
    assert st.canvas_bytes == 0


def test_dilation_never_leaks_across_cameras_or_groups():
    """A changed tile on a camera's edge must not pull any other
    camera's tiles into the compute set (the neighbor table has no
    cross-camera slots), and outputs stay bit-exact everywhere."""
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(1))
    rng = _rng(10)
    t = det.cfg.tile
    # two groups; every tile active so adjacency would leak if it could
    frames, grids = _mk_fleet(rng, det, [[(3, 4), (3, 4)], [(4, 3)]],
                              density=2.0)
    cache = PackedActivationCache()
    fleet_reuse_step(det, _as_jnp(frames), grids, cache)
    # flip a pixel in camera 0's bottom-right corner tile (grid edge)
    cur = {g: [f.copy() for f in fs] for g, fs in frames.items()}
    cur[0][0][3 * t - 1, 4 * t - 1, 0] += 11.0
    outs, counts, st = fleet_reuse_step(det, _as_jnp(cur), grids, cache)
    assert st.computed > 0
    # the compute set stayed inside flat camera 0
    n0 = int(np.count_nonzero(grids[0][0]))
    assert st.computed <= n0, "dilation leaked past the changed camera"
    for gid in grids:
        legacy = det.fleet_forward_layers(
            [jnp.asarray(f) for f in cur[gid]], grids[gid])
        for a, b in zip(outs[gid], legacy):
            assert (np.asarray(a) == np.asarray(b)).all()


def test_reuse_positive_threshold_reuses_more():
    """A lossy threshold can only shrink the compute set; the gate stats
    stay available for the rate controller either way."""
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    rng = _rng(11)
    frames, grids = _mk_fleet(rng, det, [[(4, 5)]])
    small = {0: [frames[0][0] + (rng.random(frames[0][0].shape) < 0.001
                                 ).astype(np.float32) * 0.5]}
    cache0 = PackedActivationCache()
    fleet_reuse_step(det, _as_jnp(frames), grids, cache0)
    _, _, st0 = fleet_reuse_step(det, _as_jnp(small), grids, cache0,
                                 threshold=0.0)
    cache1 = PackedActivationCache()
    fleet_reuse_step(det, _as_jnp(frames), grids, cache1)
    _, _, st1 = fleet_reuse_step(det, _as_jnp(small), grids, cache1,
                                 threshold=10 ** 6)
    assert st1.computed <= st0.computed
    assert st1.computed == 0                   # huge threshold: all reused
    assert st0.gate_stats is not None and st1.gate_stats is not None


def test_gate_stats_shared_with_rate_controller_single_dispatch():
    """The satellite contract: one delta dispatch per step serves both
    the reuse gate and the encoder's static-tile calibration — no
    ``tile_delta`` launch rides along."""
    from repro.net import static_fraction_from_stats, tile_static_fraction
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    rng = _rng(12)
    t = det.cfg.tile
    frames, grids = _mk_fleet(rng, det, [[(3, 4), (4, 4)]])
    cache = PackedActivationCache()
    fleet_reuse_step(det, _as_jnp(frames), grids, cache)
    cur = {0: [f.copy() for f in frames[0]]}
    cur[0][0][5, 5, :] += 30.0
    with ops.count_kernels() as c:
        outs, counts, st = fleet_reuse_step(det, _as_jnp(cur), grids,
                                            cache)
        frac = static_fraction_from_stats(st.gate_stats, 3, t)
        # per-camera slices work too (fleet packing is camera-major)
        idx = cache.idx_np
        frac0 = static_fraction_from_stats(st.gate_stats[idx[:, 0] == 0],
                                           3, t)
    assert c["tile_delta_gate"] == 1
    assert c.get("tile_delta", 0) == 0
    assert 0.0 <= frac0 <= 1.0 and frac > 0.5  # mostly-static frame
    # the stats= passthrough of tile_static_fraction skips the kernel
    with ops.count_kernels() as c2:
        f2 = tile_static_fraction(np.asarray(cur[0][0]),
                                  np.asarray(frames[0][0]), grids[0][0],
                                  t, stats=st.gate_stats[idx[:, 0] == 0])
    assert sum(c2.values()) == 0 and f2 == frac0


# ---------------------------------------------------------------------------
# cache lifecycle: ring bound, invalidation, drift re-solve
# ---------------------------------------------------------------------------

def test_cache_invalidate_recomputes_and_reference_advances():
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    rng = _rng(13)
    frames, grids = _mk_fleet(rng, det, [[(3, 3)]])
    cache = PackedActivationCache()
    for _ in range(4):
        fleet_reuse_step(det, _as_jnp(frames), grids, cache)
    assert cache.cold_steps == 1 and cache.ref_canvas is not None
    cache.invalidate()
    assert cache.packed is None and cache.invalidations == 1
    assert cache.ref_canvas is None and cache.canvas is None
    _, counts, st = fleet_reuse_step(det, _as_jnp(frames), grids, cache)
    assert st.cold and st.computed == st.total_tiles
    assert counts.get("tile_delta_gate", 0) == 0


def test_lossy_threshold_drift_accumulates_against_reference():
    """Under a lossy threshold the gate's reference only advances at
    refreshed tiles, so sub-threshold per-step drift ACCUMULATES and
    eventually trips the gate — it cannot creep into the cache
    unboundedly one sub-threshold step at a time."""
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    rng = _rng(16)
    frames, grids = _mk_fleet(rng, det, [[(3, 3)]])
    thr = 40.0                                  # bytes, lossy gate
    cache = PackedActivationCache()
    fleet_reuse_step(det, _as_jnp(frames), grids, cache, threshold=thr)
    cur = frames
    tripped = 0
    for step in range(12):
        # one tile drifts a little every step; each single-step delta
        # prices under the threshold, the accumulated delta does not
        cur = {0: [cur[0][0].copy()]}
        cur[0][0][20:24, 20:24, :] += 2.0
        _, _, st = fleet_reuse_step(det, _as_jnp(cur), grids, cache,
                                    threshold=thr)
        tripped += st.raw_changed
    assert tripped >= 1, \
        "accumulated sub-threshold drift never tripped the lossy gate"


def test_mask_change_misses_content_key():
    """A changed grid (what a drift re-solve produces) must force a full
    recompute even without an explicit invalidate call."""
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    rng = _rng(14)
    frames, grids = _mk_fleet(rng, det, [[(3, 4)]])
    cache = PackedActivationCache()
    fleet_reuse_step(det, _as_jnp(frames), grids, cache)
    _, _, st = fleet_reuse_step(det, _as_jnp(frames), grids, cache)
    assert not st.cold
    grown = {0: [grids[0][0].copy()]}
    grown[0][0][0, 3] = not grown[0][0][0, 3]
    _, _, st = fleet_reuse_step(det, _as_jnp(frames), grown, cache)
    assert st.cold and st.computed == st.total_tiles


def test_drift_resolve_invalidates_cache_and_next_step_recomputes():
    """The drift adapter's mask listeners invalidate registered caches on
    every re-solve, so the step after a mask mutation recomputes fully
    (belt and braces on top of the content key, and countable)."""
    from repro.core.pipeline import OfflineConfig, run_offline
    from repro.core.scene import SceneConfig, generate_scene
    from repro.fleet.drift import DriftAdapter
    scene = generate_scene(SceneConfig(duration_s=25, seed=5))
    off = run_offline(scene, OfflineConfig(profile_frames=150,
                                           solver="greedy"))
    adapter = DriftAdapter(scene, off)
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    cache = PackedActivationCache()
    adapter.add_mask_listener(lambda _: cache.invalidate())
    # the cache serves a (small, synthetic) fleet; the adapter maintains
    # the masks — the listener is the coupling under test
    rng = _rng(15)
    frames, grids = _mk_fleet(rng, det, [[(3, 3), (3, 4)]])
    fleet_reuse_step(det, _as_jnp(frames), grids, cache)
    _, _, st = fleet_reuse_step(det, _as_jnp(frames), grids, cache)
    assert not st.cold
    # a warm re-solve (empty residual window here: the mask itself does
    # not grow, but cam_grids are regenerated) must notify the listeners
    adapter._resolve(t=999)
    assert len(adapter.events) == 1
    assert cache.invalidations == 1 and cache.packed is None
    _, _, st = fleet_reuse_step(det, _as_jnp(frames), grids, cache)
    assert st.cold and st.computed == st.total_tiles
