"""End-to-end CrossRoI pipeline invariants on a reduced scene."""
import numpy as np
import pytest

from repro.core import (FilterConfig, OfflineConfig, OnlineConfig,
                        full_frame_offline, run_offline, run_online,
                        tune_and_run)
from repro.core.compression import CodecModel, fit_boundary_constant, \
    TABLE3_SIZES_MB, TABLE3_RESOLUTIONS, TABLE3_SETTINGS, _tiling_tile_area
from repro.core.reid import ReIDNoiseConfig, characterize_pairwise, \
    run_noisy_reid
from repro.core.scene import SceneConfig, default_cameras, generate_scene


@pytest.fixture(scope="module")
def scene():
    # 90 s scene: 60 s profile window (paper's choice) + 30 s eval window
    return generate_scene(SceneConfig(duration_s=90, seed=7))


@pytest.fixture(scope="module")
def offline(scene):
    return run_offline(scene, OfflineConfig(profile_frames=600,
                                            solver="greedy"))


def test_scene_structure(scene):
    n_det = sum(len(f) for f in scene.detections)
    assert n_det > 3000
    cams_seen = {d.cam for fr in scene.detections for d in fr}
    assert cams_seen == set(range(5))
    # overlap exists: some object visible in >= 2 cameras at once
    overlap = any(
        len({d.cam for d in fr if d.obj == o}) >= 2
        for fr in scene.detections for o in {d.obj for d in fr})
    assert overlap


def test_reid_error_structure_matches_table2(scene):
    """Observation O2: TN > FN and TP > FP per pair; FN is substantial."""
    rec = run_noisy_reid(scene, ReIDNoiseConfig(), 0, 600)
    counts = characterize_pairwise(rec, 5)
    checked = 0
    for s in range(5):
        for d in range(5):
            if s == d:
                continue
            tp, fp, fn, tn = counts[s, d]
            if tp + fn < 80:   # pair barely overlaps (e.g. opposite legs
                continue       # whose views share only the core box); skip
            assert tn > fn, (s, d, counts[s, d])
            assert tp > fp, (s, d, counts[s, d])
            assert fn > 0
            checked += 1
    assert checked >= 6


def test_reid_deterministic(scene):
    a = run_noisy_reid(scene, ReIDNoiseConfig(seed=3), 0, 100)
    b = run_noisy_reid(scene, ReIDNoiseConfig(seed=3), 0, 100)
    assert [(r.cam, r.t, r.rid) for r in a] == [(r.cam, r.t, r.rid)
                                                for r in b]


def test_offline_mask_guarantee(scene, offline):
    """The paper's Eq-2 guarantee: every profiled constraint keeps >= 1
    fully-covered appearance region."""
    for regions in offline.table.constraints:
        assert any(r.tiles <= offline.mask for r in regions)


def test_offline_mask_nontrivial(scene, offline):
    assert 0 < len(offline.mask) < offline.universe.num_tiles
    assert 0.05 < offline.fleet_density < 0.95


def test_online_beats_baseline(scene, offline):
    m = run_online(scene, offline, OnlineConfig(), 600, 900)
    base = full_frame_offline(scene)
    mb = run_online(scene, base, OnlineConfig(roi_inference=False), 600, 900)
    assert m.accuracy > 0.97
    assert mb.accuracy == 1.0
    assert m.network_mbps < mb.network_mbps
    assert m.latency_s < mb.latency_s
    assert m.server_hz >= mb.server_hz


def test_filters_shrink_mask_vs_nofilters(scene, offline):
    off_nf = run_offline(scene, OfflineConfig(
        profile_frames=600, solver="greedy",
        filters=FilterConfig(enabled=False)))
    assert len(offline.mask) <= len(off_nf.mask)


def test_no_merging_costs_more_network(scene, offline):
    off_nm = run_offline(scene, OfflineConfig(profile_frames=600,
                                              solver="greedy",
                                              merge_tiles=False))
    m = run_online(scene, offline, OnlineConfig(), 600, 900)
    m_nm = run_online(scene, off_nm, OnlineConfig(), 600, 900)
    assert m_nm.network_mbps > m.network_mbps


def test_segment_length_tradeoff(scene, offline):
    """Fig 11: longer segments -> less network, more latency."""
    nets, lats = [], []
    for seg in (0.5, 1.0, 2.0, 4.0):
        m = run_online(scene, offline, OnlineConfig(segment_s=seg), 600, 900)
        nets.append(m.network_mbps)
        lats.append(m.latency_s)
    assert nets == sorted(nets, reverse=True)
    assert lats == sorted(lats)


def test_reducto_integration(scene, offline):
    """Table 4 structure: lower target -> more frames cut, less network;
    target 1.0 degenerates to plain CrossRoI."""
    r100 = tune_and_run(scene, offline, 1.0, OnlineConfig(),
                        profile=(0, 600), evalw=(600, 900))
    r85 = tune_and_run(scene, offline, 0.85, OnlineConfig(),
                       profile=(0, 600), evalw=(600, 900))
    assert r100.metrics.frames_reduced == 0
    assert r85.metrics.frames_reduced > 0
    assert r85.metrics.network_mbps <= r100.metrics.network_mbps
    assert r85.achieved >= 0.80   # holds near its target out-of-window


# ---------------------------------------------------------------------------
# codec model calibration (paper Table 3)
# ---------------------------------------------------------------------------

def test_codec_fit_reproduces_table3():
    for cam in range(5):
        k = fit_boundary_constant(cam)
        assert k > 0
        res = TABLE3_RESOLUTIONS[cam]
        full_a = res[0] * res[1]
        s0 = TABLE3_SIZES_MB[cam][0]
        for setting, s in zip(TABLE3_SETTINGS[1:], TABLE3_SIZES_MB[cam][1:]):
            a = _tiling_tile_area(res, setting)
            pred = s0 * (1 + k / np.sqrt(a)) / (1 + k / np.sqrt(full_a))
            assert abs(pred - s) / s < 0.04   # within 4% of the paper row


def test_codec_monotonic_in_tile_area():
    codec = CodecModel.calibrated(default_cameras())
    full = codec.region_bytes(0, 1920 * 1080, 10)
    halves = 2 * codec.region_bytes(0, 1920 * 1080 / 2, 10)
    quarters = 4 * codec.region_bytes(0, 1920 * 1080 / 4, 10)
    assert full < halves < quarters
