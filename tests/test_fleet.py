"""Fleet subsystem: topology isolation, vectorized runtime parity, packed
group launches, online drift adaptation, and kernel-count isolation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import setcover
from repro.core.association import TileUniverse, build_association_table
from repro.core.pipeline import (OfflineConfig, OnlineConfig, run_offline,
                                 run_online)
from repro.core.reid import ReIDNoiseConfig, run_noisy_reid
from repro.core.scene import SceneConfig, generate_scene
from repro.fleet import (DriftConfig, FleetConfig, GroupSpec, build_fleet,
                         cross_group_leakage, fleet_inference_step,
                         run_adaptive_online, run_fleet_offline,
                         run_fleet_online)
from repro.kernels import ops
from repro.serving.detector import DetectorConfig, RoIDetector


@pytest.fixture(scope="module")
def fleet():
    return build_fleet(FleetConfig(
        groups=[GroupSpec("uniform", seed=3),
                GroupSpec("rush_hour", seed=11)],
        duration_s=45))


@pytest.fixture(scope="module")
def offlines(fleet):
    cfg = OfflineConfig(profile_frames=300, solver="greedy")
    return run_fleet_offline(fleet, cfg).per_group


# ---------------------------------------------------------------------------
# topology: per-group isolation + zero cross-group correlation
# ---------------------------------------------------------------------------

def test_groups_bit_identical_to_isolation(fleet, offlines):
    """A fleet group's offline result must be bit-identical to running the
    single-intersection pipeline on the same (profile, seed) alone."""
    g = fleet.groups[1]
    iso_scene = generate_scene(SceneConfig(
        duration_s=45, seed=11, spawn_profile="rush_hour"))
    iso = run_offline(iso_scene,
                      OfflineConfig(profile_frames=300, solver="greedy"))
    assert iso.mask == offlines[1].mask
    for c in g.scene.cameras:
        np.testing.assert_array_equal(iso.cam_grids[c.cam_id],
                                      offlines[1].cam_grids[c.cam_id])
    # and the raw detections are identical too (translation invariance)
    a = [(d.cam, d.t, d.obj, d.bbox.as_vec().tolist())
         for fr in g.scene.detections for d in fr]
    b = [(d.cam, d.t, d.obj, d.bbox.as_vec().tolist())
         for fr in iso_scene.detections for d in fr]
    assert a == b


def test_zero_cross_group_visibility(fleet):
    """At the default 600 m spacing no vehicle of one group projects an
    above-threshold box into another group's cameras."""
    assert cross_group_leakage(fleet, frame_step=50) == 0


def test_zero_cross_group_correlation_entries(fleet):
    """Association built over the MERGED fleet (global camera ids) keeps
    every constraint's candidate regions inside one group."""
    cams_flat = fleet.all_cameras()
    # reindex cameras to their global ids so the universe spans the fleet
    from dataclasses import replace
    cams_global = [replace(c, cam_id=i) for i, c in enumerate(cams_flat)]
    universe = TileUniverse.build(cams_global)
    C = fleet.cams_per_group
    records = []
    rid_base = 0
    for g in fleet.groups:
        recs = run_noisy_reid(g.scene, ReIDNoiseConfig(), 0, 300)
        for r in recs:
            records.append(type(r)(fleet.global_cam(g.gid, r.cam), r.t,
                                   r.bbox, r.rid + rid_base,
                                   r.obj + rid_base))
        rid_base += 10_000_000
    table = build_association_table(records, universe)
    assert table.constraints, "merged fleet table should not be empty"
    for regions in table.constraints:
        groups_seen = {r.cam // C for r in regions}
        assert len(groups_seen) == 1, \
            f"constraint spans groups {groups_seen}"


def test_traffic_profiles_shape_spawn_rates():
    mk = lambda prof: generate_scene(SceneConfig(
        duration_s=60, seed=4, spawn_profile=prof))
    n_uniform = len(mk("uniform").vehicles)
    n_sparse = len(mk("sparse").vehicles)
    n_rush = len(mk("rush_hour").vehicles)
    assert n_sparse < 0.6 * n_uniform
    assert n_rush > n_sparse
    # scripted shift: post-shift spawns come from the shifted entries
    sc = generate_scene(SceneConfig(
        duration_s=60, seed=4, entry_weights=(0.5, 0.5, 0.0, 0.0),
        shift_at_s=30.0, shift_entry_weights=(0.0, 0.0, 0.5, 0.5)))
    pre = {v.entry for v in sc.vehicles if v.t0 < 30.0}
    post = {v.entry for v in sc.vehicles if v.t0 >= 30.0}
    assert pre <= {"N", "S"} and post <= {"E", "W"}


# ---------------------------------------------------------------------------
# vectorized fleet online runtime
# ---------------------------------------------------------------------------

def test_fleet_online_matches_single_group_runs(fleet, offlines):
    """The all-cameras-at-once evaluation must reproduce run_online on
    each group exactly: same flags -> same accuracy, same network model ->
    same bytes (to fp round-off)."""
    fm = run_fleet_online(fleet, offlines, OnlineConfig(), 300, 450)
    for g, m in zip(fleet.groups, fm.per_group):
        ref = run_online(g.scene, offlines[g.gid], OnlineConfig(), 300, 450)
        assert m.accuracy == ref.accuracy
        assert m.missed == ref.missed
        np.testing.assert_array_equal(m.missed_per_t, ref.missed_per_t)
        assert m.network_mbps == pytest.approx(ref.network_mbps, rel=1e-9)
        assert m.server_hz == ref.server_hz
        assert m.camera_fps == ref.camera_fps
        assert m.latency_s == pytest.approx(ref.latency_s, rel=1e-12)
    # aggregates are consistent with the per-group rows
    assert fm.accuracy_min == min(m.accuracy for m in fm.per_group)
    assert fm.network_mbps_total == pytest.approx(
        sum(m.network_mbps for m in fm.per_group))
    assert fm.fleet_server_hz < min(m.server_hz for m in fm.per_group)


def test_fleet_online_strict_threshold(fleet, offlines):
    fm = run_fleet_online(fleet, offlines,
                         OnlineConfig(coverage_thresh=1.0), 300, 450)
    for g, m in zip(fleet.groups, fm.per_group):
        ref = run_online(g.scene, offlines[g.gid],
                         OnlineConfig(coverage_thresh=1.0), 300, 450)
        assert m.accuracy == ref.accuracy


def test_fleet_4x5_end_to_end():
    """Acceptance: a 4-group x 5-camera fleet completes end-to-end; each
    group's accuracy >= the single-group baseline; every step runs ONE
    packed conv launch per group (not per camera)."""
    fleet = build_fleet(FleetConfig(
        groups=[GroupSpec("uniform", seed=21), GroupSpec("sparse", seed=22),
                GroupSpec("rush_hour", seed=23),
                GroupSpec("bursty", seed=24)],
        duration_s=30))
    assert fleet.num_groups == 4 and fleet.num_cameras == 20
    offs = run_fleet_offline(
        fleet, OfflineConfig(profile_frames=200, solver="greedy"))
    fm = run_fleet_online(fleet, offs.per_group, OnlineConfig(), 200, 300)
    for g, m in zip(fleet.groups, fm.per_group):
        base = run_online(g.scene, offs.per_group[g.gid], OnlineConfig(),
                          200, 300)
        assert m.accuracy >= base.accuracy

    # kernel-level steps: ONE cross-group super-launch for the WHOLE
    # fleet — entry + layer-stack megakernel + scatter, ≤3 dispatches
    # regardless of the group count, asserted inside the step
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    t = det.cfg.tile
    grids = {g.gid: [rng.random((3, 4)) < 0.5 for _ in range(5)]
             for g in fleet.groups}
    for gs in grids.values():          # ensure non-empty masks
        for gg in gs:
            gg[1, 1] = True
    for step in range(2):
        frames = {g.gid: [jnp.asarray(
            rng.normal(size=(3 * t, 4 * t, 3)), jnp.float32)
            for _ in range(5)] for g in fleet.groups}
        outs, counts = fleet_inference_step(det, frames, grids)
        assert counts["roi_conv_entry"] == 1
        assert counts["roi_conv_stack"] == 1
        assert counts["sbnet_scatter_fleet"] == 1
        assert sum(counts.values()) <= 3
        assert set(outs) == set(grids)


def test_fleet_forward_matches_per_camera():
    """The cross-camera batcher is bit-compatible with per-camera
    roi_forward on every camera, including mixed frame sizes."""
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(1))
    rng = np.random.default_rng(5)
    t = det.cfg.tile
    shapes = [(4, 5), (3, 4), (5, 3), (4, 4), (2, 6)]
    grids = [rng.random(s) < 0.45 for s in shapes]
    for g in grids:
        g[1, 1] = True
    frames = [jnp.asarray(rng.normal(size=(gy * t, gx * t, 3)), jnp.float32)
              for gy, gx in shapes]
    outs = det.fleet_forward(frames, grids)
    for f, g, o in zip(frames, grids, outs):
        ref = det.roi_forward(f, g)
        assert o.shape == ref.shape
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                                   atol=1e-5)


def test_fleet_neighbor_table_never_leaks():
    """Halo slots of camera c must stay inside camera c's packed range."""
    rng = np.random.default_rng(9)
    grids = [rng.random((4, 6)) < 0.6 for _ in range(4)]
    idx, offsets = ops.fleet_indices(grids)
    nbr = ops.fleet_neighbor_table(grids)
    assert idx.shape[0] == offsets[-1] == nbr.shape[0]
    for ci in range(len(grids)):
        sl = nbr[offsets[ci]:offsets[ci + 1]]
        ok = (sl == -1) | ((sl >= offsets[ci]) & (sl < offsets[ci + 1]))
        assert ok.all(), f"camera {ci} halo leaks across cameras"
    # per-camera slot ranges hold exactly that camera's tiles, in
    # mask_to_indices order
    for ci, g in enumerate(grids):
        sub = idx[offsets[ci]:offsets[ci + 1]]
        assert (sub[:, 0] == ci).all()
        np.testing.assert_array_equal(sub[:, 1:], ops.mask_to_indices(g))


# ---------------------------------------------------------------------------
# kernel-count isolation
# ---------------------------------------------------------------------------

def test_count_kernels_snapshot_restore():
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    grid = np.ones((3, 3), bool)
    x = jnp.asarray(rng.normal(size=(48, 48, 3)), jnp.float32)
    ops.KERNEL_COUNTS.clear()
    det.roi_forward(x, grid)               # pollute the global counter
    polluted = dict(ops.KERNEL_COUNTS)
    with ops.count_kernels() as inner:
        det.roi_forward(x, grid)
    # the region saw exactly one stack, regardless of prior pollution
    assert inner["roi_conv_entry"] == 1
    assert inner["sbnet_scatter"] == 1
    assert inner["roi_conv_stack"] == 1
    # and the global counter now reflects outer + inner work
    assert ops.KERNEL_COUNTS["roi_conv_entry"] == \
        polluted["roi_conv_entry"] + 1
    # nesting: inner regions isolate, outer still totals
    with ops.count_kernels() as outer_c:
        det.roi_forward(x, grid)
        with ops.count_kernels() as nested:
            det.roi_forward(x, grid)
        assert nested["roi_conv_entry"] == 1
    assert outer_c["roi_conv_entry"] == 2


# ---------------------------------------------------------------------------
# warm-started set cover + online drift adaptation
# ---------------------------------------------------------------------------

def test_solve_warm_consistency(fleet, offlines):
    g = fleet.groups[0]
    records = run_noisy_reid(g.scene, ReIDNoiseConfig(), 0, 300)
    from repro.core.filters import FilterConfig, apply_filters
    cleaned, _ = apply_filters(records, len(g.scene.cameras),
                               FilterConfig())
    universe = offlines[0].universe
    table = build_association_table(cleaned, universe)
    cold = setcover.solve_greedy(table)
    # seeding with the cold solution is a fixed point: nothing to add
    warm_same = setcover.solve_warm(table, cold.mask)
    assert warm_same.mask == cold.mask
    # seeding with a subset still satisfies every constraint and keeps
    # the seed
    seed = frozenset(list(cold.mask)[: len(cold.mask) // 2])
    warm = setcover.solve_warm(table, seed)
    assert seed <= warm.mask
    for regions in table.constraints:
        assert any(r.tiles <= warm.mask for r in regions)
    # empty seed degenerates to the cold greedy mask exactly
    assert setcover.solve_warm(table, frozenset()).mask == cold.mask


def test_drift_adapter_recovers_after_traffic_shift():
    """Acceptance: a scripted traffic shift (N/S profiling -> E/W online)
    drops coverage; the adapter fires ONE warm re-solve and coverage over
    the remaining stream recovers to >= 95%."""
    scfg = SceneConfig(duration_s=80, seed=2,
                       entry_weights=(0.5, 0.5, 0.0, 0.0),
                       shift_at_s=40.0,
                       shift_entry_weights=(0.0, 0.0, 0.5, 0.5))
    scene = generate_scene(scfg)
    off = run_offline(scene, OfflineConfig(profile_frames=300,
                                           solver="greedy"))
    res = run_adaptive_online(scene, off, 300, 800, DriftConfig())
    # before the shift bites, the profiled mask covers the stream
    assert res.coverage_between(300, 400) >= 0.95
    assert res.resolves == 1, \
        f"expected exactly one warm re-solve, got {res.adapter.events}"
    ev = res.adapter.events[0]
    assert ev.coverage_before < 0.95          # the monitor saw the drift
    assert ev.tiles_added > 0                 # and the mask actually grew
    assert res.coverage_between(ev.t + 1, 800) >= 0.95
    # residuals drove the growth toward uncovered tiles only
    assert ev.t >= 400                        # fired after the shift


def test_drift_adapter_quiet_on_stationary_traffic(fleet, offlines):
    """No shift -> no re-solve: the profiled mask keeps covering."""
    g = fleet.groups[0]
    res = run_adaptive_online(g.scene, offlines[0], 300, 450, DriftConfig())
    assert res.resolves == 0
    assert res.coverage_between(300, 450) >= 0.95


# ---------------------------------------------------------------------------
# Reducto keep masks through the fleet runtime (forward-fill semantics)
# ---------------------------------------------------------------------------

def test_fleet_keep_masks_match_single_group_runs(fleet, offlines):
    """frame_keep[gid] flows through accuracy (last-streamed-result
    forward fill) AND transport (filtered frames_sent) exactly like
    run_online with the same per-camera masks."""
    from repro.core.reducto import keep_masks_for_threshold
    fk = {g.gid: keep_masks_for_threshold(g.scene, offlines[g.gid], 0.02,
                                          300, 450, use_mask=True)
          for g in fleet.groups}
    fm = run_fleet_online(fleet, offlines, OnlineConfig(), 300, 450,
                          frame_keep=fk)
    total_reduced = 0
    for g, m in zip(fleet.groups, fm.per_group):
        ref = run_online(g.scene, offlines[g.gid],
                         OnlineConfig(frame_keep=fk[g.gid]), 300, 450)
        assert m.accuracy == ref.accuracy
        assert m.missed == ref.missed
        np.testing.assert_array_equal(m.missed_per_t, ref.missed_per_t)
        assert m.network_mbps == pytest.approx(ref.network_mbps, rel=1e-9)
        assert m.latency_s == pytest.approx(ref.latency_s, rel=1e-12)
        assert m.frames_reduced == ref.frames_reduced > 0
        total_reduced += ref.frames_reduced
    assert fm.frames_reduced == total_reduced


def test_fleet_rejects_single_scene_keep_field(fleet, offlines):
    with pytest.raises(ValueError):
        run_fleet_online(fleet, offlines,
                         OnlineConfig(frame_keep={0: np.ones(10, bool)}),
                         300, 450)


def test_fleet_simulated_transport_merges_distributions(fleet, offlines):
    """transport="simulated" yields per-group distributions whose merge is
    the fleet-wide population; per-group means still equal the analytic
    values in the uncongested limit."""
    fa = run_fleet_online(fleet, offlines, OnlineConfig(), 300, 450)
    fs = run_fleet_online(fleet, offlines,
                          OnlineConfig(transport="simulated"), 300, 450)
    assert fs.transport is not None and fa.transport is None
    n = 0
    for ma, ms in zip(fa.per_group, fs.per_group):
        assert ms.transport is not None
        assert ms.latency_s == pytest.approx(ma.latency_s, rel=1e-9)
        assert ms.accuracy == ma.accuracy
        n += ms.transport.latency_s.size
    assert fs.transport.latency_s.size == n
    assert fs.transport.p99_s >= fs.transport.p50_s


# ---------------------------------------------------------------------------
# scheduled shrink re-solves (low-traffic windows)
# ---------------------------------------------------------------------------

def test_shrink_resolve_drops_stale_tiles_without_regressing():
    """Machinery: after traffic shifts away from the profiled corridors, a
    low-traffic-window shrink re-solve adopts a smaller mask, never
    regresses buffered coverage, and the breach monitor still guards the
    shrunk mask (self-healing grow)."""
    scfg = SceneConfig(duration_s=80, seed=2,
                       entry_weights=(0.5, 0.5, 0.0, 0.0),
                       shift_at_s=40.0,
                       shift_entry_weights=(0.0, 0.0, 0.5, 0.5))
    scene = generate_scene(scfg)
    from repro.core.pipeline import OfflineConfig as OC
    off = run_offline(scene, OC(profile_frames=300, solver="greedy"))
    cfg = DriftConfig(shrink_enabled=True, shrink_low_rate=100.0,
                      shrink_cooldown_frames=150,
                      shrink_profile_frames=250)
    res = run_adaptive_online(scene, off, 300, 800, cfg)
    ad = res.adapter
    adopted = [e for e in ad.shrink_events if e.adopted]
    assert adopted, "at least one shrink must fire on this schedule"
    for e in ad.shrink_events:
        assert e.coverage_after >= e.coverage_before - 1e-12
        if e.adopted:
            assert e.mask_after < e.mask_before
        else:
            assert e.mask_after == e.mask_before
    # post-shift stream still covered (grow re-solve may assist)
    assert res.coverage_between(650, 800) >= 0.95


def test_shrink_gated_by_traffic_rate(fleet, offlines):
    """Stationary, busy traffic: the low-rate gate keeps shrink silent."""
    g = fleet.groups[0]
    cfg = DriftConfig(shrink_enabled=True, shrink_low_rate=0.01,
                      shrink_profile_frames=100)
    res = run_adaptive_online(g.scene, offlines[0], 300, 450, cfg)
    assert res.adapter.shrinks == 0
    assert all(not e.adopted for e in res.adapter.shrink_events)


def test_fleet_partial_keep_dict_treats_missing_as_unfiltered(fleet,
                                                              offlines):
    """A frame_keep dict covering only SOME cameras of a group: missing
    cameras are unfiltered in accuracy AND transport (this used to
    KeyError in the byte model after the accuracy pass succeeded)."""
    n = 150
    partial = {0: {0: np.ones(n, bool)}}       # group 0, camera 0 only
    fm = run_fleet_online(fleet, offlines, OnlineConfig(), 300, 450,
                          frame_keep=partial)
    ref = run_fleet_online(fleet, offlines, OnlineConfig(), 300, 450)
    for m, r in zip(fm.per_group, ref.per_group):
        assert m.accuracy == r.accuracy        # all-True mask = no filter
        assert m.network_mbps == pytest.approx(r.network_mbps)
