"""Serving engine: RoI-packed prefill correctness + batched decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ServeConfig
from repro.configs.registry import get_config
from repro.models.params import init_params
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("h2o-danube3-4b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return ServingEngine(cfg, ServeConfig(max_batch=4, roi_sparsity=True),
                         params)


def test_roi_prefill_keep_all_matches_dense(engine):
    """keep=all packing is the identity: logits match plain prefill."""
    S = 96
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, engine.cfg.vocab_size, S), jnp.int32)
    res = engine.roi_prefill(toks, jnp.ones(S, bool), block=32)
    assert res.n_kept == S
    logits_dense, _ = engine.prefill({"tokens": toks[None]}, max_seq=S)
    np.testing.assert_allclose(
        np.asarray(res.logits[0, -1], np.float32),
        np.asarray(logits_dense[0, -1], np.float32), atol=2e-2)


def test_roi_prefill_compute_fraction(engine):
    S = 128
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, engine.cfg.vocab_size, S), jnp.int32)
    keep = jnp.asarray(rng.random(S) < 0.4)
    res = engine.roi_prefill(toks, keep, block=32)
    assert res.n_kept == int(keep.sum())
    assert res.compute_fraction < 0.6


def test_roi_prefill_matches_pruned_prompt(engine):
    """Packing kept tokens == prefilling the pruned prompt at the same
    positions: last-token logits must agree (the packed-prefill contract)."""
    S = 64
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, engine.cfg.vocab_size, S), jnp.int32)
    keep = np.zeros(S, bool)
    keep[rng.choice(S, 40, replace=False)] = True
    keep[-1] = True   # keep the last token so "last logits" align
    res = engine.roi_prefill(toks, jnp.asarray(keep), block=32)
    # oracle: run the kept subsequence densely with original positions
    kept_toks = toks[np.nonzero(keep)[0]]
    kept_pos = jnp.asarray(np.nonzero(keep)[0], jnp.int32)
    from repro.models import model as M
    caches = M.init_cache(engine.cfg, 1, 64)
    logits, _ = M.prefill(engine.params, engine.cfg,
                          {"tokens": kept_toks[None]}, caches,
                          positions=kept_pos[None])
    np.testing.assert_allclose(
        np.asarray(res.logits[0, -1], np.float32),
        np.asarray(logits[0, -1], np.float32), atol=2e-2)


def test_serve_batched_requests(engine):
    rng = np.random.default_rng(3)
    reqs = []
    for i in range(5):
        toks = rng.integers(0, engine.cfg.vocab_size, 48).astype(np.int32)
        keep = rng.random(48) < 0.7 if i % 2 else None
        reqs.append(Request(i, tokens=toks, keep=keep, max_new_tokens=4))
    out = engine.serve(reqs, greedy_steps=4)
    assert set(out) == set(range(5))
    for toks in out.values():
        assert toks.shape == (4,)
        assert (toks >= 0).all() and (toks < engine.cfg.vocab_size).all()


def test_group_decode_matches_sequential(engine):
    """Batched group decode must produce exactly the tokens the one-by-one
    decode loop produced (same caches, same greedy argmax chain)."""
    rng = np.random.default_rng(7)
    S, steps = 40, 4
    prompts = [rng.integers(0, engine.cfg.vocab_size, S).astype(np.int32)
               for _ in range(3)]
    max_seq = S + steps
    caches_list, firsts, starts, seq_out = [], [], [], []
    for toks in prompts:
        logits, caches = engine.prefill({"tokens": jnp.asarray(toks)[None]},
                                        max_seq=max_seq)
        first = jnp.argmax(logits[:, -1], -1)
        caches_list.append(caches)
        firsts.append(first)
        starts.append(S)
        toks_seq, _ = engine.decode_tokens(caches, first, S, steps)
        seq_out.append(toks_seq[0])
    group_out, _ = engine.decode_tokens_group(caches_list, firsts, starts,
                                              steps)
    for gi in range(3):
        np.testing.assert_array_equal(group_out[gi], seq_out[gi])


def test_serve_mixed_group_roi_and_dense(engine):
    """RoI-packed and dense requests share one decode batch (different
    start positions ride the vmap) and still match per-request serving."""
    rng = np.random.default_rng(8)
    reqs = []
    for i in range(4):
        toks = rng.integers(0, engine.cfg.vocab_size, 48).astype(np.int32)
        keep = rng.random(48) < 0.7 if i % 2 else None
        reqs.append(Request(i, tokens=toks, keep=keep, max_new_tokens=3))
    out_batched = engine.serve(reqs, greedy_steps=3)
    # singleton groups: forces the per-request path
    out_single = {}
    for r in reqs:
        out_single.update(engine.serve([r], greedy_steps=3))
    for rid in out_batched:
        np.testing.assert_array_equal(out_batched[rid], out_single[rid])


def test_serve_mixed_decode_budgets(engine):
    """Requests with different max_new_tokens share one lockstep group:
    caches must be sized for the GROUP's step count, or the longer-budget
    requests' KV writes clamp onto the cache end (silent corruption)."""
    rng = np.random.default_rng(9)
    long_prompt = rng.integers(0, engine.cfg.vocab_size, 96).astype(np.int32)
    short_prompt = rng.integers(0, engine.cfg.vocab_size, 8).astype(np.int32)
    reqs = [Request(0, tokens=short_prompt, max_new_tokens=6),
            Request(1, tokens=long_prompt, max_new_tokens=2)]
    out = engine.serve(reqs, greedy_steps=6)
    assert out[0].shape == (6,) and out[1].shape == (2,)
    for r in reqs:
        single = engine.serve([r], greedy_steps=6)
        np.testing.assert_array_equal(out[r.rid], single[r.rid])


def test_serve_persistent_ring_no_restack(engine):
    """ROADMAP open item: serve() must reuse one persistent group cache
    ring across flushes — no per-flush jnp.stack of per-request caches
    (counter stays flat) and no ring rebuild once the geometry is seen."""
    rng = np.random.default_rng(11)

    def mkreqs(rid0):
        reqs = []
        for i in range(4):
            toks = rng.integers(0, engine.cfg.vocab_size, 48).astype(np.int32)
            keep = rng.random(48) < 0.7 if i % 2 else None
            reqs.append(Request(rid0 + i, tokens=toks, keep=keep,
                                max_new_tokens=3))
        return reqs

    stacks0 = engine.cache_stack_count
    out1 = engine.serve(mkreqs(0), greedy_steps=3)      # 1 flush of 4
    rebuilds_after_first = engine.ring_rebuilds
    out2 = engine.serve(mkreqs(10), greedy_steps=3)     # same geometry
    out3 = engine.serve(mkreqs(20), greedy_steps=3)
    assert engine.cache_stack_count == stacks0, \
        "serve() must not stack per-request caches"
    assert engine.ring_rebuilds == rebuilds_after_first, \
        "steady-state flushes must reuse the ring"
    assert len(out1) == len(out2) == len(out3) == 4
    # ring reuse must not leak state between flushes: identical prompts in
    # a fresh flush decode to identical tokens
    fixed = np.arange(40).astype(np.int32) % engine.cfg.vocab_size
    a = engine.serve([Request(0, tokens=fixed, max_new_tokens=4)],
                     greedy_steps=4)[0]
    b = engine.serve([Request(0, tokens=fixed, max_new_tokens=4)],
                     greedy_steps=4)[0]
    np.testing.assert_array_equal(a, b)


def test_decode_continues_prefill(engine):
    """Greedy decode after prefill is self-consistent: feeding the argmax
    token back advances the distribution deterministically."""
    S = 40
    toks = jnp.asarray(np.random.default_rng(4).integers(
        0, engine.cfg.vocab_size, S), jnp.int32)
    logits, caches = engine.prefill({"tokens": toks[None]}, max_seq=S + 8)
    first = jnp.argmax(logits[:, -1], -1)
    out1, _ = engine.decode_tokens(caches, first, S, 3)
    logits2, caches2 = engine.prefill({"tokens": toks[None]}, max_seq=S + 8)
    out2, _ = engine.decode_tokens(caches2, jnp.argmax(logits2[:, -1], -1),
                                   S, 3)
    np.testing.assert_array_equal(out1, out2)


def test_serve_deadline_matches_serve_outputs(engine):
    """The deadline former changes WHEN batches launch, never what they
    decode: every request's tokens equal the plain serve() output, and
    the accounting sees completes, deadline releases, and stragglers."""
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(6):
        toks = rng.integers(0, engine.cfg.vocab_size, 48).astype(np.int32)
        reqs.append(Request(rid=i, tokens=toks, max_new_tokens=4,
                            group=i % 2, arrival_s=0.3 * i))
    base = engine.serve([Request(r.rid, r.tokens, max_new_tokens=4)
                         for r in reqs], greedy_steps=4)
    res, rep = engine.serve_deadline(reqs, group_sizes={0: 3, 1: 3},
                                     deadline_s=0.5, greedy_steps=4)
    assert sorted(res) == sorted(base)
    for rid in res:
        np.testing.assert_array_equal(res[rid], base[rid])
    # arrivals at 0.3s spacing with a 0.5s deadline: every flush is cut
    # by the deadline and later group members are stragglers
    assert rep.deadline_flushes > 0
    assert rep.straggler_requests > 0
    assert rep.complete_flushes + rep.deadline_flushes >= 2
    for r in reqs:
        assert rep.release_s[r.rid] >= r.arrival_s
        assert rep.wait_s(r) <= 0.5 + 1e-9


def test_serve_deadline_complete_groups_release_immediately(engine):
    """Groups that fill before the deadline release at the completing
    arrival (zero added wait for the last member)."""
    rng = np.random.default_rng(8)
    reqs = []
    for i in range(4):
        toks = rng.integers(0, engine.cfg.vocab_size, 32).astype(np.int32)
        reqs.append(Request(rid=i, tokens=toks, max_new_tokens=2,
                            group=0, arrival_s=0.01 * i))
    res, rep = engine.serve_deadline(reqs, group_sizes={0: 4},
                                     deadline_s=5.0, greedy_steps=2)
    assert rep.complete_flushes == 1
    assert rep.deadline_flushes == 0
    assert rep.straggler_requests == 0
    assert rep.wait_s(reqs[-1]) == 0.0
    assert len(res) == 4


def test_serve_deadline_straggler_quota(engine):
    """Stragglers are bounded by the seats a deadline flush left empty:
    members of the NEXT cycle are not counted late."""
    rng = np.random.default_rng(9)
    mk = lambda i, t: Request(
        rid=i, tokens=rng.integers(0, engine.cfg.vocab_size,
                                   32).astype(np.int32),
        max_new_tokens=2, group=0, arrival_s=t)
    # A alone misses the 1.0s deadline; B is its cycle's straggler; B+C
    # then form a fresh complete batch, and D drains at end of stream.
    reqs = [mk(0, 0.0), mk(1, 1.5), mk(2, 1.6), mk(3, 1.7)]
    res, rep = engine.serve_deadline(reqs, group_sizes={0: 2},
                                     deadline_s=1.0, greedy_steps=2)
    assert len(res) == 4
    assert rep.deadline_flushes == 2           # A alone + end-of-stream D
    assert rep.complete_flushes == 1           # the B+C cycle completes
    assert rep.straggler_requests == 1         # B only, never C or D
    assert rep.release_s[0] == pytest.approx(1.0)
    assert rep.release_s[1] == rep.release_s[2] == pytest.approx(1.6)
