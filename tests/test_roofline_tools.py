"""Launch-layer units: HLO collective parsing, roofline fits, memory
estimator, auto-microbatch policy, stream pipeline."""
import numpy as np
import pytest

from repro.configs.base import SHAPES, ShapeCell
from repro.configs.registry import get_config
from repro.launch import roofline as R


# ---------------------------------------------------------------------------
# collective-bytes HLO parsing
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
  %all-reduce.1 = f32[128,1024]{1,0} all-reduce(%dot), channel_id=1
  %ag = bf16[64,512]{1,0} all-gather(%p0), dimensions={0}
  %rs.3 = bf16[32,512]{1,0} reduce-scatter(%x), dimensions={0}
  %a2a = (f32[16,8]{1,0}, f32[16,8]{1,0}) all-to-all(%a, %b)
  %cp = u32[4,4]{1,0} collective-permute(%y)
  %dot.5 = f32[10,10]{1,0} dot(%a, %b)
"""


def test_collective_bytes_kinds():
    out = R.collective_bytes(HLO_SAMPLE)
    assert out["all-reduce"] == 128 * 1024 * 4 * 2       # ring: 2x
    assert out["all-gather"] == 64 * 512 * 2
    assert out["reduce-scatter"] == 32 * 512 * 2
    assert out["all-to-all"] == 2 * 16 * 8 * 4
    assert out["collective-permute"] == 4 * 4 * 4
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_collective_bytes_ignores_compute():
    assert R.collective_bytes("%d = f32[8,8]{1,0} dot(%a, %b)")["total"] == 0


# ---------------------------------------------------------------------------
# calibration fits
# ---------------------------------------------------------------------------

def test_extrapolate_linear():
    # cost(L) = 7L + 3
    assert R.extrapolate(10, 17, 1, 2, 10) == pytest.approx(73)


def test_calib_depth_structures():
    g = get_config("gemma3-27b")
    l1, l2 = R.calib_depths(g)
    assert l1 == g.global_every and l2 == 2 * g.global_every
    z = get_config("zamba2-2.7b")
    l1, l2 = R.calib_depths(z)
    assert l1 % z.attn_every == 0
    m = get_config("deepseek-moe-16b")
    l1, l2 = R.calib_depths(m)
    assert l1 > m.first_dense_layers


def test_with_depth_preserves_structure():
    cfg = get_config("gemma3-27b")
    small = R.with_depth(cfg, cfg.global_every)
    assert small.num_layers == cfg.global_every
    w = get_config("whisper-small")
    ws = R.with_depth(w, 2)
    assert ws.encoder_layers == 2 and ws.decoder_layers == 2


def test_model_flops_modes():
    cfg = get_config("deepseek-67b")
    train = R.model_flops_for(cfg, SHAPES["train_4k"])
    prefill = R.model_flops_for(cfg, SHAPES["prefill_32k"])
    decode = R.model_flops_for(cfg, SHAPES["decode_32k"])
    assert train == pytest.approx(6 * cfg.param_count() * 256 * 4096, rel=.01)
    assert prefill == pytest.approx(2 * cfg.param_count() * 32 * 32768,
                                    rel=.01)
    assert decode == pytest.approx(2 * cfg.param_count() * 128, rel=.01)


def test_moe_model_flops_use_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    assert cfg.active_param_count() < 0.2 * cfg.param_count()
    f = R.model_flops_for(cfg, SHAPES["train_4k"])
    assert f == pytest.approx(6 * cfg.active_param_count() * 256 * 4096,
                              rel=.01)


# ---------------------------------------------------------------------------
# stream pipeline
# ---------------------------------------------------------------------------

def test_stream_pipeline_keep_matches_masks():
    from repro.core import OfflineConfig, run_offline
    from repro.core.scene import SceneConfig, generate_scene
    from repro.data.streams import CameraStreamPipeline
    scene = generate_scene(SceneConfig(duration_s=40, seed=1))
    off = run_offline(scene, OfflineConfig(profile_frames=300,
                                           solver="greedy"))
    pipe = CameraStreamPipeline(scene, off, patch_dim=8)
    seg = next(pipe.segments(300, 310))
    assert 0.0 < seg.keep_fraction < 1.0
    toks, keep = pipe.fleet_tokens(seg, 0)
    assert toks.shape[0] == keep.shape[0]
    n_tiles = sum(int(g.size) for g in off.cam_grids.values())
    assert toks.shape[0] == n_tiles
    n_mask = sum(int(g.sum()) for g in off.cam_grids.values())
    assert int(keep.sum()) == n_mask
