"""Edge-to-server streaming runtime: analytic<->simulated equivalence,
uplink FIFO/congestion behavior, tile_delta kernel exactness, rate
control, deadline batching + stragglers, and the header-accounting fix."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.grouping import TileGroup
from repro.core.compression import CodecModel
from repro.core.pipeline import (OfflineConfig, OnlineConfig,
                                 full_frame_offline, online_system_metrics,
                                 run_offline, run_online,
                                 segment_network_bytes)
from repro.core.scene import SceneConfig, generate_scene
from repro.kernels import ops, ref
from repro.net import (DeadlineGroupFormer, LinkConfig, NetConfig,
                       RateControlConfig, UplinkTrace, bandwidth_traces,
                       default_congestion_trace, fifo_departures,
                       load_bundled_trace, tile_static_fraction)
from repro.serving.detector import DetectorConfig, RoIDetector


@pytest.fixture(scope="module")
def scene():
    return generate_scene(SceneConfig(duration_s=40, seed=1))


@pytest.fixture(scope="module")
def offline(scene):
    return run_offline(scene, OfflineConfig(profile_frames=200,
                                            solver="greedy"))


@pytest.fixture(scope="module")
def fullframe(scene):
    return full_frame_offline(scene)


# ---------------------------------------------------------------------------
# analytic <-> simulated equivalence (the uncongested limit)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(bw=st.floats(8.0, 5000.0), rtt=st.floats(0.0, 80.0),
       seg=st.floats(0.5, 3.0))
def test_simulated_converges_to_analytic(bw, rtt, seg):
    """Zero jitter, no congestion, no shedding, infinite deadline: the
    simulated per-frame MEAN latency and total bytes must match the
    analytic formula within 1e-6 relative."""
    scene = test_simulated_converges_to_analytic._scene
    offline = test_simulated_converges_to_analytic._offline
    a = online_system_metrics(
        scene.cameras, offline,
        OnlineConfig(segment_s=seg, bandwidth_mbps=bw, rtt_ms=rtt),
        10.0, 200)
    s = online_system_metrics(
        scene.cameras, offline,
        OnlineConfig(segment_s=seg, bandwidth_mbps=bw, rtt_ms=rtt,
                     transport="simulated"),
        10.0, 200)
    assert abs(s[3] - a[3]) <= 1e-6 * a[3], (s[3], a[3])     # latency
    assert abs(s[5] - a[5]) <= 1e-6 * a[5], (s[5], a[5])     # bytes
    np.testing.assert_array_equal(s[6], a[6])                # frames_sent
    assert s[7] is not None and a[7] is None


@pytest.fixture(scope="module", autouse=True)
def _bind_property_fixtures(scene, offline):
    # the hypothesis shim calls property functions with zero pytest
    # fixtures; hand them the module fixtures via attributes
    test_simulated_converges_to_analytic._scene = scene
    test_simulated_converges_to_analytic._offline = offline


def test_infinite_bandwidth_limit(scene, offline):
    """bandwidth -> inf: transmission vanishes, rtt/2 survives, and the
    two paths still agree exactly."""
    cfg_a = OnlineConfig(bandwidth_mbps=float("inf"), rtt_ms=20.0)
    cfg_s = OnlineConfig(bandwidth_mbps=float("inf"), rtt_ms=20.0,
                         transport="simulated")
    a = online_system_metrics(scene.cameras, offline, cfg_a, 10.0, 200)
    s = online_system_metrics(scene.cameras, offline, cfg_s, 10.0, 200)
    assert abs(s[3] - a[3]) <= 1e-6 * a[3]
    assert s[7].parts_mean()["network"] == pytest.approx(20.0 / 2e3)


def test_simulated_through_run_online(scene, offline):
    """run_online carries the distribution; accuracy is untouched by the
    transport model."""
    m_a = run_online(scene, offline, OnlineConfig(), 200, 400)
    m_s = run_online(scene, offline, OnlineConfig(transport="simulated"),
                     200, 400)
    assert m_s.accuracy == m_a.accuracy
    assert m_s.transport is not None and m_a.transport is None
    assert m_s.latency_s == pytest.approx(m_a.latency_s, rel=1e-9)
    assert m_s.latency_p99_s > m_s.latency_p50_s
    # per-frame parts telescope to the total latency
    ts = m_s.transport
    total = sum(ts.parts[k] for k in ts.parts)
    np.testing.assert_allclose(total, ts.latency_s, rtol=1e-12)


def test_unknown_transport_rejected(scene, offline):
    with pytest.raises(ValueError):
        online_system_metrics(scene.cameras, offline,
                              OnlineConfig(transport="nope"), 10.0, 100)


# ---------------------------------------------------------------------------
# links: FIFO closed form, jitter, congestion
# ---------------------------------------------------------------------------

def test_fifo_departures_closed_form_matches_recursion():
    rng = np.random.default_rng(0)
    for _ in range(20):
        S = int(rng.integers(1, 40))
        arr = np.cumsum(rng.uniform(0.0, 2.0, S))
        tx = rng.uniform(0.0, 3.0, (3, S))
        dep = fifo_departures(np.broadcast_to(arr, (3, S)), tx)
        for c in range(3):
            d = -np.inf
            for s in range(S):
                d = max(arr[s], d) + tx[c, s]
                assert dep[c, s] == pytest.approx(d)


def test_congestion_inflates_tail(scene, fullframe):
    base = OnlineConfig(transport="simulated")
    cong = OnlineConfig(transport="simulated", net=NetConfig(
        link=LinkConfig(congestion=default_congestion_trace(20.0))))
    ts0 = online_system_metrics(scene.cameras, fullframe, base,
                                10.0, 200)[7]
    ts1 = online_system_metrics(scene.cameras, fullframe, cong,
                                10.0, 200)[7]
    assert ts1.p50_s > ts0.p50_s
    assert ts1.p99_s > ts0.p99_s
    # congestion backs up the link, not the batcher's other parts
    assert ts1.parts_mean()["network"] > 3 * ts0.parts_mean()["network"]


def test_roi_beats_full_frame_under_congestion(scene, offline, fullframe):
    """Acceptance: CrossRoI masks cut p50 response delay >= 20% vs
    full-frame streaming under the default congestion trace."""
    net = NetConfig(link=LinkConfig(congestion=default_congestion_trace(
        20.0)))
    cfg = OnlineConfig(transport="simulated", net=net)
    roi = online_system_metrics(scene.cameras, offline, cfg, 10.0, 200)[7]
    ff = online_system_metrics(scene.cameras, fullframe, cfg, 10.0, 200)[7]
    assert roi.p50_s <= 0.8 * ff.p50_s
    assert roi.p99_s < ff.p99_s


def test_jitter_perturbs_but_preserves_mean_load(scene, offline):
    cfg = OnlineConfig(transport="simulated", net=NetConfig(
        link=LinkConfig(jitter_std=0.5, seed=7)))
    ts = online_system_metrics(scene.cameras, offline, cfg, 10.0, 200)[7]
    base = online_system_metrics(scene.cameras, offline,
                                 OnlineConfig(transport="simulated"),
                                 10.0, 200)[7]
    assert ts.bytes_total == pytest.approx(base.bytes_total)  # load same
    assert ts.latency_s.mean() >= base.latency_s.mean()       # queues hurt


# ---------------------------------------------------------------------------
# tile_delta kernel (the rate controller's on-device feed)
# ---------------------------------------------------------------------------

def test_tile_delta_bit_exact_vs_reference():
    rng = np.random.default_rng(3)
    for th, tw, C, q in [(8, 8, 3, 8.0), (16, 16, 3, 4.0), (8, 16, 1, 16.0)]:
        H, W = th * 5, tw * 4
        cur = rng.normal(scale=50, size=(H, W, C)).astype(np.float32)
        prev = cur + rng.normal(scale=7, size=(H, W, C)).astype(np.float32)
        prev[:th] = cur[:th]                       # one static tile row
        grid = rng.random((5, 4)) < 0.8
        grid[0, 0] = True
        idx = ops.mask_to_indices(grid)
        out = np.asarray(ops.tile_delta(jnp.asarray(cur), jnp.asarray(prev),
                                        jnp.asarray(idx), th, tw, qstep=q))
        expect = ref.tile_delta(cur, prev, idx, th, tw, qstep=q)
        np.testing.assert_array_equal(out, expect)


def test_tile_delta_dispatch_counted():
    rng = np.random.default_rng(4)
    cur = rng.normal(size=(32, 32, 3)).astype(np.float32)
    idx = ops.mask_to_indices(np.ones((2, 2), bool))
    with ops.count_kernels() as c:
        ops.tile_delta(jnp.asarray(cur), jnp.asarray(cur),
                       jnp.asarray(idx), 16, 16)
    assert c["tile_delta"] == 1


def test_tile_delta_static_tile_prices_near_zero():
    cur = np.random.default_rng(5).normal(
        scale=60, size=(16, 16, 3)).astype(np.float32)
    idx = np.array([[0, 0]], np.int32)
    out = np.asarray(ops.tile_delta(jnp.asarray(cur), jnp.asarray(cur),
                                    jnp.asarray(idx), 16, 16))
    nbytes, nnz, runs, sabs = out[0, :4]
    assert nnz == 0 and sabs == 0
    assert runs == 16                   # one run per scan row
    assert nbytes == (runs * ops.RUN_BITS + 7) // 8


def test_tile_delta_halo_bit_exact_vs_reference():
    rng = np.random.default_rng(13)
    for th, tw, C, q in [(8, 8, 3, 8.0), (16, 16, 3, 4.0), (8, 16, 1, 16.0)]:
        H, W = th * 5, tw * 4
        cur = rng.normal(scale=50, size=(H, W, C)).astype(np.float32)
        prev = cur + rng.normal(scale=7, size=(H, W, C)).astype(np.float32)
        prev[:th] = cur[:th]                       # one static tile row
        grid = rng.random((5, 4)) < 0.8
        grid[0, 0] = True
        idx = ops.mask_to_indices(grid)
        out = np.asarray(ops.tile_delta_halo(
            jnp.asarray(cur), jnp.asarray(prev), jnp.asarray(idx), th, tw,
            qstep=q))
        expect = ref.tile_delta_halo(cur, prev, idx, th, tw, qstep=q)
        np.testing.assert_array_equal(out, expect)


def test_tile_delta_halo_static_ring_prices_run_tokens_only():
    """A fully static ring prices exactly 4 zero-run tokens (one per
    strip: top row, bottom row, left col, right col)."""
    cur = np.random.default_rng(14).normal(
        scale=60, size=(16, 16, 3)).astype(np.float32)
    idx = np.array([[0, 0]], np.int32)
    out = np.asarray(ops.tile_delta_halo(jnp.asarray(cur),
                                         jnp.asarray(cur),
                                         jnp.asarray(idx), 16, 16))
    nbytes, nnz, runs, sabs = out[0, :4]
    assert nnz == 0 and sabs == 0
    assert runs == 4
    assert nbytes == (runs * ops.RUN_BITS + 7) // 8
    # a moving interior leaves the halo ring estimate untouched
    moved = cur.copy()
    moved[1:-1, 1:-1] += 100.0
    out2 = np.asarray(ops.tile_delta_halo(jnp.asarray(moved),
                                          jnp.asarray(cur),
                                          jnp.asarray(idx), 16, 16))
    np.testing.assert_array_equal(out2, out)


def test_tile_halo_static_fraction_feeds_controller():
    from repro.net import tile_halo_static_fraction
    rng = np.random.default_rng(15)
    t = 16
    cur = rng.normal(scale=60, size=(4 * t, 4 * t, 3)).astype(np.float32)
    prev = cur.copy()
    prev[:2 * t] += rng.normal(scale=30,
                               size=(2 * t, 4 * t, 3)).astype(np.float32)
    grid = np.ones((4, 4), bool)
    with ops.count_kernels() as c:
        frac = tile_static_fraction(jnp.asarray(cur), jnp.asarray(prev),
                                    grid, t)
        hfrac = tile_halo_static_fraction(jnp.asarray(cur),
                                          jnp.asarray(prev), grid, t)
    assert c["tile_delta"] == 1 and c["tile_delta_halo"] == 1
    assert frac == pytest.approx(0.5)
    assert hfrac == pytest.approx(0.5)   # bottom-half rings static too


def test_rate_control_sheds_halo_before_body(scene, fullframe):
    """The shed mass decomposes into halo-first tiers: with no sheddable
    body the whole shed comes from halo rings; adding static body mass
    sheds MORE total but the halo tier is consumed first, and the tiers
    telescope to shed_bytes exactly."""
    link = LinkConfig(congestion=default_congestion_trace(20.0))
    halo_only = OnlineConfig(transport="simulated", net=NetConfig(
        link=link, rate_control=RateControlConfig(enabled=True)))
    ts_h = online_system_metrics(scene.cameras, fullframe, halo_only,
                                 10.0, 200)[7]
    assert ts_h.shed_bytes > 0
    assert ts_h.shed_halo_bytes == pytest.approx(ts_h.shed_bytes)
    assert ts_h.shed_body_bytes == 0.0
    both = OnlineConfig(transport="simulated", net=NetConfig(
        link=link, rate_control=RateControlConfig(enabled=True,
                                                  static_fraction=0.4)))
    ts_b = online_system_metrics(scene.cameras, fullframe, both,
                                 10.0, 200)[7]
    assert ts_b.shed_halo_bytes + ts_b.shed_body_bytes == \
        pytest.approx(ts_b.shed_bytes)
    assert ts_b.shed_body_bytes > 0
    assert ts_b.shed_halo_bytes >= ts_h.shed_halo_bytes * 0.5
    # halo_static_fraction gates the halo tier
    gated = OnlineConfig(transport="simulated", net=NetConfig(
        link=link, rate_control=RateControlConfig(
            enabled=True, halo_static_fraction=0.0, static_fraction=0.4)))
    ts_g = online_system_metrics(scene.cameras, fullframe, gated,
                                 10.0, 200)[7]
    assert ts_g.shed_halo_bytes == 0.0
    assert ts_g.shed_body_bytes == pytest.approx(ts_g.shed_bytes)


def test_tile_static_fraction_feeds_controller():
    rng = np.random.default_rng(6)
    t = 16
    cur = rng.normal(scale=60, size=(4 * t, 4 * t, 3)).astype(np.float32)
    prev = cur.copy()
    prev[:2 * t] += rng.normal(scale=30,
                               size=(2 * t, 4 * t, 3)).astype(np.float32)
    grid = np.ones((4, 4), bool)
    with ops.count_kernels() as c:
        frac = tile_static_fraction(jnp.asarray(cur), jnp.asarray(prev),
                                    grid, t)
    assert c["tile_delta"] == 1
    assert frac == pytest.approx(0.5)   # bottom half static


# ---------------------------------------------------------------------------
# rate control
# ---------------------------------------------------------------------------

def test_rate_control_inert_without_backlog(scene, offline):
    rc = RateControlConfig(enabled=True, static_fraction=0.5)
    ts = online_system_metrics(
        scene.cameras, offline,
        OnlineConfig(transport="simulated", net=NetConfig(rate_control=rc)),
        10.0, 200)[7]
    base = online_system_metrics(scene.cameras, offline,
                                 OnlineConfig(transport="simulated"),
                                 10.0, 200)[7]
    assert ts.shed_bytes == 0.0
    assert ts.quality_min == 1.0
    assert ts.latency_s.mean() == pytest.approx(base.latency_s.mean())


def test_rate_control_sheds_under_congestion(scene, fullframe):
    link = LinkConfig(congestion=default_congestion_trace(20.0))
    plain = OnlineConfig(transport="simulated", net=NetConfig(link=link))
    shed = OnlineConfig(transport="simulated", net=NetConfig(
        link=link, rate_control=RateControlConfig(enabled=True,
                                                  static_fraction=0.4)))
    ts0 = online_system_metrics(scene.cameras, fullframe, plain,
                                10.0, 200)[7]
    ts1 = online_system_metrics(scene.cameras, fullframe, shed,
                                10.0, 200)[7]
    assert ts1.shed_bytes > 0
    assert ts1.quality_min < 1.0
    assert ts1.bytes_total < ts0.bytes_total
    assert ts1.p50_s < ts0.p50_s        # shedding drains the backlog


# ---------------------------------------------------------------------------
# deadline batching + stragglers
# ---------------------------------------------------------------------------

def test_deadline_counts_stragglers(scene, fullframe):
    link = LinkConfig(jitter_std=0.4, seed=3,
                      congestion=default_congestion_trace(20.0))
    loose = OnlineConfig(transport="simulated",
                         net=NetConfig(link=link))
    tight = OnlineConfig(transport="simulated",
                         net=NetConfig(link=link, deadline_s=0.8))
    ts_loose = online_system_metrics(scene.cameras, fullframe, loose,
                                     10.0, 200)[7]
    ts_tight = online_system_metrics(scene.cameras, fullframe, tight,
                                     10.0, 200)[7]
    assert ts_loose.straggler_frames == 0 and ts_loose.deadline_hits == 0
    assert ts_tight.deadline_hits > 0
    assert ts_tight.straggler_frames > 0
    assert 0.0 < ts_tight.straggler_frac < 1.0
    # every frame is still served exactly once
    assert ts_tight.latency_s.size == ts_loose.latency_s.size


def test_deadline_group_former_single_launch_per_release():
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    t = det.cfg.tile
    grids = [rng.random((3, 4)) < 0.5 for _ in range(3)]
    for g in grids:
        g[1, 1] = True
    frames = [jnp.asarray(rng.normal(size=(3 * t, 4 * t, 3)), jnp.float32)
              for _ in range(3)]
    former = DeadlineGroupFormer(det, expected_cams=[0, 1, 2],
                                 deadline_s=0.5)
    with ops.count_kernels() as c:
        assert former.offer(0.00, 0, frames[0], grids[0]) is None
        assert former.offer(0.10, 1, frames[1], grids[1]) is None
        rel = former.poll(0.60)          # deadline fires without camera 2
    assert rel is not None and rel.deadline_hit
    assert rel.cams == [0, 1] and rel.straggler_cams == []
    assert c["roi_conv_entry"] == 1
    assert c["roi_conv_stack"] == 1      # every remaining layer, fused
    assert c["sbnet_scatter_fleet"] == 1
    with ops.count_kernels() as c2:
        rel2 = former.offer(0.70, 2, frames[2], grids[2])
        assert rel2 is None              # group incomplete, deadline fresh
        rel2 = former.poll(1.30)
    assert rel2 is not None
    assert rel2.cams == [2] and rel2.straggler_cams == [2]
    assert former.straggler_count == 1
    assert c2["roi_conv_entry"] == 1     # stragglers still one launch chain
    # a straggler catch-up launch must NOT mark the punctual cameras
    # late: the next complete cycle reports zero stragglers
    for cam in (0, 1, 2):
        rel3 = former.offer(1.5 + 0.01 * cam, cam, frames[cam], grids[cam])
    assert rel3 is not None and rel3.cams == [0, 1, 2]
    assert rel3.straggler_cams == []
    assert former.straggler_count == 1   # unchanged
    # per-camera outputs match the per-camera forward exactly
    np.testing.assert_allclose(
        np.asarray(rel.outputs[0]),
        np.asarray(det.roi_forward(frames[0], grids[0])), atol=1e-5)


# ---------------------------------------------------------------------------
# header accounting fix (empty-mask cameras ship nothing)
# ---------------------------------------------------------------------------

def test_empty_mask_camera_ships_nothing(scene):
    cams = scene.cameras
    codec = CodecModel.calibrated(cams, 10.0)
    full = {c.cam_id: [TileGroup(0, 0, c.tiles_y, c.tiles_x)]
            for c in cams}
    bytes_all, sent_all = segment_network_bytes(cams, full, codec, None,
                                                10, 10)
    empty0 = dict(full)
    empty0[cams[0].cam_id] = []
    bytes_e, sent_e = segment_network_bytes(cams, empty0, codec, None,
                                            10, 10)
    # no body, no halo, no container headers, and NO phantom frames
    bytes_rest, sent_rest = segment_network_bytes(
        cams[1:], {c.cam_id: full[c.cam_id] for c in cams[1:]}, codec,
        None, 10, 10)
    assert bytes_e == pytest.approx(bytes_rest, rel=1e-12)
    assert sent_e[0] == 0
    np.testing.assert_array_equal(sent_e[1:], sent_rest)
    assert bytes_e < bytes_all
    # zero-area groups behave exactly like no groups
    zero0 = dict(full)
    zero0[cams[0].cam_id] = [TileGroup(0, 0, 0, 0)]
    bytes_z, sent_z = segment_network_bytes(cams, zero0, codec, None,
                                            10, 10)
    assert bytes_z == pytest.approx(bytes_e, rel=1e-12)
    assert sent_z[0] == 0


def test_simulated_transport_with_empty_mask_and_keep(scene):
    """Worst-case plumbing: an empty-mask camera + Reducto keep masks +
    rate control + deadline all at once stays finite, ships zero frames
    for the empty camera, and excludes it from the batcher."""
    off = run_offline(scene, OfflineConfig(profile_frames=150,
                                           solver="greedy"))
    off.cam_groups[0] = []
    off.cam_grids[0][:] = False
    net = NetConfig(
        link=LinkConfig(congestion=default_congestion_trace(15.0)),
        rate_control=RateControlConfig(enabled=True, static_fraction=0.3),
        deadline_s=1.0)
    keep = {c.cam_id: (np.arange(150) % 2 == 0) for c in scene.cameras}
    ts = online_system_metrics(
        scene.cameras, off, OnlineConfig(transport="simulated", net=net),
        10.0, 150, keep)[7]
    assert np.isfinite(ts.latency_s).all()
    assert ts.frames_sent[0] == 0
    assert not (ts.frame_cam == 0).any()
    assert ts.latency_s.size == ts.frames_sent.sum()


def test_deadline_group_former_never_drops_superseded_frames():
    """Legacy (fold_stragglers=False): a camera offering its next segment
    while the batch is pending forces the batch out (superseded release)
    instead of silently dropping the older frame."""
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(2))
    rng = np.random.default_rng(11)
    t = det.cfg.tile
    grid = np.zeros((2, 2), bool)
    grid[0, 0] = True
    mk = lambda: jnp.asarray(rng.normal(size=(2 * t, 2 * t, 3)),
                             jnp.float32)
    former = DeadlineGroupFormer(det, expected_cams=[0, 1],
                                 deadline_s=10.0, fold_stragglers=False)
    f0a, f0b = mk(), mk()
    assert former.offer(0.0, 0, f0a, grid) is None
    rel = former.offer(0.2, 0, f0b, grid)      # same camera, next segment
    assert rel is not None and rel.superseded
    assert rel.cams == [0]
    np.testing.assert_allclose(np.asarray(rel.outputs[0]),
                               np.asarray(det.roi_forward(f0a, grid)),
                               atol=1e-5)      # the OLDER frame was served
    rel2 = former.offer(0.3, 1, mk(), grid)    # group completes normally
    assert rel2 is not None and not rel2.superseded
    assert rel2.cams == [0, 1]


def test_straggler_fold_reclaims_launch():
    """Default folding: a straggler segment whose camera moved on rides
    the NEXT release's packed super-launch as an extra entry — no
    superseded force-out, no solo late launch, one launch chain
    reclaimed, and no frame is ever dropped."""
    det = RoIDetector(DetectorConfig(), jax.random.PRNGKey(2))
    rng = np.random.default_rng(12)
    t = det.cfg.tile
    grids = [rng.random((3, 4)) < 0.5 for _ in range(2)]
    for g in grids:
        g[1, 1] = True
    mk = lambda: jnp.asarray(rng.normal(size=(3 * t, 4 * t, 3)),
                             jnp.float32)
    former = DeadlineGroupFormer(det, expected_cams=[0, 1],
                                 deadline_s=10.0)
    f0a, f0b, f1 = mk(), mk(), mk()
    assert former.offer(0.0, 0, f0a, grids[0]) is None
    # same camera, next segment: with folding this does NOT force the
    # batch out — both segments queue for the next release
    assert former.offer(0.2, 0, f0b, grids[0]) is None
    assert former.reclaimed_launches == 1
    with ops.count_kernels() as c:
        rel = former.offer(0.3, 1, f1, grids[1])   # group completes
    assert rel is not None and not rel.superseded
    assert rel.cams == [0, 1]
    # all three segments (two of camera 0) served by ONE launch chain
    assert c["roi_conv_entry"] == 1 and c["roi_conv_stack"] == 1 \
        and c["sbnet_scatter_fleet"] == 1
    assert rel.folded_frames == 1
    np.testing.assert_allclose(np.asarray(rel.folded_outputs[0][0]),
                               np.asarray(det.roi_forward(f0a, grids[0])),
                               atol=1e-5)      # the older folded segment
    np.testing.assert_allclose(np.asarray(rel.outputs[0]),
                               np.asarray(det.roi_forward(f0b, grids[0])),
                               atol=1e-5)      # the newest holds the slot
    np.testing.assert_allclose(np.asarray(rel.outputs[1]),
                               np.asarray(det.roi_forward(f1, grids[1])),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# real uplink trace replay
# ---------------------------------------------------------------------------


def test_constant_trace_matches_analytic(scene, offline):
    """A constant-valued trace at the analytic bandwidth is the
    uncongested limit: the replay path must reproduce the analytic
    latency formula < 1e-6 relative (same property the scripted-episode
    path pinned in PR 3)."""
    cfg_a = OnlineConfig()
    trace = UplinkTrace(np.array([0.0]),
                        np.array([cfg_a.bandwidth_mbps]), "const")
    cfg_s = OnlineConfig(transport="simulated",
                         net=NetConfig(link=LinkConfig(trace=trace)))
    a = online_system_metrics(scene.cameras, offline, cfg_a, 10.0, 200)
    s = online_system_metrics(scene.cameras, offline, cfg_s, 10.0, 200)
    assert abs(s[3] - a[3]) <= 1e-6 * a[3], (s[3], a[3])
    assert abs(s[5] - a[5]) <= 1e-6 * a[5], (s[5], a[5])


def test_short_trace_wraps_deterministically():
    """A trace shorter than the simulation horizon replays periodically:
    sample(t) == sample(t + k * duration) exactly, and two simulations
    over the same wrapped trace are bit-identical."""
    trace = UplinkTrace(np.arange(5.0), np.array([20., 5., 30., 8., 12.]))
    assert trace.duration_s == 5.0
    t = np.linspace(0.0, 4.99, 37)
    for k in (1, 2, 7):
        np.testing.assert_array_equal(trace.sample(t),
                                      trace.sample(t + k * 5.0))
    # piecewise-constant hold: mid-interval equals the left sample
    assert trace.sample(np.array([1.5]))[0] == 5.0
    assert trace.sample(np.array([6.5]))[0] == 5.0     # wrapped
    # horizon (30 segments) far past the 5 s trace: deterministic runs
    load = np.full((3, 30), 1e5)
    bw1 = bandwidth_traces(LinkConfig(trace=trace), 999.0, load, 1.0)
    bw2 = bandwidth_traces(LinkConfig(trace=trace), 999.0, load, 1.0)
    np.testing.assert_array_equal(bw1, bw2)
    # the constant bandwidth argument is ignored when a trace is set
    bw3 = bandwidth_traces(LinkConfig(trace=trace), 1.0, load, 1.0)
    np.testing.assert_array_equal(bw1, bw3)


def test_share_semantics_under_trace_budget():
    """Proportional/equal share semantics are identical whether the
    per-segment budget comes from the constant bandwidth or a trace:
    proportional shares sum to the budget, equal gives budget/C."""
    rng = np.random.default_rng(3)
    C, S = 4, 8
    load = rng.uniform(1e4, 1e6, size=(C, S))
    trace = UplinkTrace(np.arange(float(S)),
                        rng.uniform(5.0, 40.0, size=S))
    close = (np.arange(S) + 1.0) * 1.0
    budget = trace.sample(close) * 1e6 / 8.0                    # (S,)

    prop = bandwidth_traces(LinkConfig(share="proportional",
                                       trace=trace), 30.0, load, 1.0)
    np.testing.assert_allclose(prop.sum(axis=0), budget, rtol=1e-12)
    np.testing.assert_allclose(prop / budget[None, :],
                               load / load.sum(0, keepdims=True),
                               rtol=1e-12)

    eq = bandwidth_traces(LinkConfig(share="equal", trace=trace),
                          30.0, load, 1.0)
    np.testing.assert_allclose(eq, np.broadcast_to(budget / C, (C, S)),
                               rtol=1e-12)

    # constant-valued trace == constant bandwidth argument, both modes
    const = UplinkTrace(np.array([0.0]), np.array([30.0]))
    for share in ("proportional", "equal"):
        via_trace = bandwidth_traces(LinkConfig(share=share, trace=const),
                                     1.0, load, 1.0)
        via_const = bandwidth_traces(LinkConfig(share=share), 30.0,
                                     load, 1.0)
        np.testing.assert_allclose(via_trace, via_const, rtol=1e-12)


def test_congestion_episodes_multiply_on_trace():
    """Scripted episodes stay available as the synthetic fallback and
    compose multiplicatively on top of a replayed trace."""
    trace = UplinkTrace(np.array([0.0]), np.array([16.0]))
    load = np.full((2, 6), 1e5)
    ep = default_congestion_trace(6.0, factor=0.25)
    plain = bandwidth_traces(LinkConfig(trace=trace), 1.0, load, 1.0)
    cong = bandwidth_traces(LinkConfig(trace=trace, congestion=ep),
                            1.0, load, 1.0)
    close = (np.arange(6) + 1.0) * 1.0
    hit = (close > ep[0].t0_s) & (close <= ep[0].t1_s)
    np.testing.assert_allclose(cong[:, hit], 0.25 * plain[:, hit])
    np.testing.assert_allclose(cong[:, ~hit], plain[:, ~hit])


def test_trace_scale_rescales_budget():
    trace = UplinkTrace(np.array([0.0]), np.array([10.0]))
    load = np.full((2, 4), 1e5)
    bw1 = bandwidth_traces(LinkConfig(trace=trace), 1.0, load, 1.0)
    bw2 = bandwidth_traces(LinkConfig(trace=trace, trace_scale=0.5),
                           1.0, load, 1.0)
    np.testing.assert_allclose(bw2, 0.5 * bw1)


def test_bundled_lte_trace_loads():
    trace = load_bundled_trace("lte_uplink")
    assert trace.t_s[0] == 0.0 and (np.diff(trace.t_s) > 0).all()
    assert (trace.mbps > 0).all()
    assert trace.duration_s > 60.0          # long enough for real sweeps
    with pytest.raises(FileNotFoundError):
        load_bundled_trace("no_such_trace")


def test_trace_validation_rejects_malformed():
    with pytest.raises(ValueError):
        UplinkTrace(np.array([1.0, 2.0]), np.array([5.0, 5.0]))  # t0 != 0
    with pytest.raises(ValueError):
        UplinkTrace(np.array([0.0, 0.0]), np.array([5.0, 5.0]))  # not inc
    with pytest.raises(ValueError):
        UplinkTrace(np.array([0.0, 1.0]), np.array([5.0]))       # shapes
