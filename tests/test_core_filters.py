"""Statistical filters: RANSAC regression + kernel SVM (own implementations)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.filters import (KernelSVM, RansacConfig, SVMConfig,
                                poly_features, ransac_regression)


def test_poly_features_shape():
    X = np.random.default_rng(0).normal(size=(10, 4))
    F = poly_features(X, 2)
    assert F.shape == (10, 1 + 4 + 10)  # bias + linear + upper-tri quad
    assert np.allclose(F[:, 0], 1.0)


def test_ransac_recovers_linear_map_with_outliers():
    rng = np.random.default_rng(1)
    n = 400
    src = rng.uniform(0, 1000, size=(n, 4))
    A = rng.normal(size=(4, 4)) * 0.5 + np.eye(4)
    dst = src @ A + rng.normal(scale=1.0, size=(n, 4))
    out_idx = rng.choice(n, 60, replace=False)
    dst[out_idx] += rng.uniform(300, 900, size=(60, 4))
    res = ransac_regression(src, dst, RansacConfig(theta=0.2))
    flagged = set(np.nonzero(~res.inlier)[0])
    assert set(out_idx) <= flagged            # every gross outlier caught
    assert len(flagged) <= 60 + int(0.1 * n)  # few true pairs sacrificed


def test_ransac_small_sample_passthrough():
    src = np.random.default_rng(0).normal(size=(5, 4))
    dst = src.copy()
    res = ransac_regression(src, dst, RansacConfig())
    assert res.inlier.all()
    assert res.coef is None


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.05, 0.5))
def test_ransac_clean_data_keeps_most(seed, noise):
    """Property: with no planted outliers, RANSAC keeps >=90% of samples."""
    rng = np.random.default_rng(seed)
    src = rng.uniform(0, 500, size=(200, 4))
    dst = src * 1.5 + 20 + rng.normal(scale=noise, size=(200, 4))
    res = ransac_regression(src, dst, RansacConfig(theta=0.2))
    assert res.inlier.mean() >= 0.9


def test_svm_separable_blobs():
    rng = np.random.default_rng(2)
    pos = rng.normal(loc=(300, 300, 120, 90), scale=25, size=(150, 4))
    neg = rng.normal(loc=(1200, 800, 60, 45), scale=25, size=(400, 4))
    X = np.concatenate([pos, neg])
    y = np.concatenate([np.ones(150), np.zeros(400)])
    svm = KernelSVM(SVMConfig(gamma=1e-4)).fit(X, y)
    pred = svm.predict(X)
    assert (pred[:150]).mean() > 0.97
    assert (~pred[150:]).mean() > 0.97


def test_svm_flags_fn_island_inside_positive_region():
    """Negatives embedded in the positive cluster must be classified
    positive (the FN-suspect mechanism the filter relies on)."""
    rng = np.random.default_rng(3)
    pos = rng.normal(loc=(300, 300, 120, 90), scale=30, size=(200, 4))
    fn = rng.normal(loc=(300, 300, 120, 90), scale=30, size=(60, 4))
    tn = rng.normal(loc=(1400, 900, 50, 40), scale=40, size=(500, 4))
    X = np.concatenate([pos, fn, tn])
    y = np.concatenate([np.ones(200), np.zeros(60), np.zeros(500)])
    svm = KernelSVM(SVMConfig(gamma=1e-4)).fit(X, y)
    pred = svm.predict(X)
    assert pred[200:260].mean() > 0.8     # FN island lands positive
    assert (~pred[260:]).mean() > 0.95    # far TNs stay negative


def test_svm_gamma_extremes():
    """Tiny gamma: smooth boundary, FN island absorbed. The non-linearity
    sweep (paper Fig 9) is exercised end-to-end in benchmarks."""
    rng = np.random.default_rng(4)
    pos = rng.normal(loc=(400, 400, 100, 80), scale=30, size=(150, 4))
    tn = rng.normal(loc=(1200, 700, 60, 50), scale=40, size=(300, 4))
    X = np.concatenate([pos, tn])
    y = np.concatenate([np.ones(150), np.zeros(300)])
    lo = KernelSVM(SVMConfig(gamma=1e-6)).fit(X, y)
    hi = KernelSVM(SVMConfig(gamma=1e-2)).fit(X, y)
    # both still separate the far blobs
    assert lo.predict(X[:150]).mean() > 0.9
    assert hi.predict(X[:150]).mean() > 0.9
