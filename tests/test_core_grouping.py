"""Tile grouping: largest-inscribed-rectangle DP + greedy merge (paper §4.3.2)."""
import numpy as np
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core.grouping import TileGroup, _largest_rectangle, group_tiles, \
    groups_cover


def test_paper_figure5_structure():
    """6x5 grid from Fig 5: an L/step-shaped RoI merges into 3 rectangles."""
    grid = np.zeros((5, 6), bool)
    grid[1:5, 0:3] = True      # 12-tile block (region 1 in the figure)
    grid[1:3, 3] = True        # 2-tile column
    grid[3:5, 4] = True        # 2-tile column elsewhere
    groups = group_tiles(grid)
    assert groups_cover(grid, groups)
    assert len(groups) == 3
    assert max(g.num_tiles for g in groups) == 12


def test_full_grid_single_group():
    grid = np.ones((7, 9), bool)
    groups = group_tiles(grid)
    assert len(groups) == 1
    assert groups[0] == TileGroup(0, 0, 7, 9)


def test_empty_grid():
    assert group_tiles(np.zeros((4, 4), bool)) == []


def test_largest_rectangle_histogram():
    grid = np.array([
        [1, 1, 0, 1],
        [1, 1, 1, 1],
        [1, 1, 1, 0],
    ], dtype=bool)
    area, g = _largest_rectangle(grid)
    assert area == 6
    assert (g.h, g.w) == (3, 2) and (g.y0, g.x0) == (0, 0)


@settings(max_examples=60, deadline=None)
@given(hnp.arrays(bool, hnp.array_shapes(min_dims=2, max_dims=2,
                                         min_side=1, max_side=14)))
def test_grouping_invariants(grid):
    """Property: groups exactly tile the mask, disjointly; count <= popcount;
    greedy's first rectangle is the global largest."""
    groups = group_tiles(grid)
    assert groups_cover(grid, groups)
    assert len(groups) <= int(grid.sum())
    if groups:
        area0, _ = _largest_rectangle(grid)
        assert groups[0].num_tiles == area0


@settings(max_examples=30, deadline=None)
@given(hnp.arrays(bool, (8, 8)))
def test_grouping_reduces_or_equals_tile_count(grid):
    """Merging never produces more groups than raw tiles (compression
    efficacy motivation, Table 3)."""
    groups = group_tiles(grid)
    assert sum(g.num_tiles for g in groups) == int(grid.sum())
