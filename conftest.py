"""Repo-level pytest bootstrap.

Two environment shims, both no-ops when the real thing is available:

* ``src`` goes on ``sys.path`` so ``PYTHONPATH=src`` is not required to
  collect the suite (the tier-1 command still sets it; CI and bare
  ``pytest`` runs get it for free).
* The container image has no ``hypothesis``; when the import would fail,
  ``tests/_shims`` (a deterministic mini sampler with the same API surface)
  is appended so the property tests still collect and run.
"""
import importlib.util
import os
import sys

_ROOT = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

if importlib.util.find_spec("hypothesis") is None:
    sys.path.insert(0, os.path.join(_ROOT, "tests", "_shims"))
