"""Fault tolerance: elastic re-meshing, straggler detection, fault injection.

Failure model at 1000+ nodes: a host dies or slows mid-run.  The recovery
path is launcher-level (the JAX SPMD program itself cannot drop a
participant mid-step): detect -> restore the latest checkpoint onto the
surviving device set (ElasticMesh picks the new shape) -> replay the data
stream deterministically from the restored step counter.  The train loop
wires these pieces together; tests/test_train_fault.py kills a run mid-step
with FaultInjector and asserts bit-exact continuation.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import numpy as np


@dataclass
class ElasticMesh:
    """Builds the largest usable mesh from an available device count.

    Keeps the model axis fixed (TP degree is a property of the model fit)
    and shrinks/grows the data axis; at multi-pod scale the pod axis drops
    to 1 before the data axis shrinks (pod loss degrades gracefully to
    single-pod).
    """
    model_parallel: int
    prefer_pods: int = 1

    def shape_for(self, n_devices: int) -> Tuple[Tuple[int, ...],
                                                 Tuple[str, ...]]:
        tp = self.model_parallel
        if n_devices < tp:
            raise RuntimeError(
                f"{n_devices} devices cannot fit model axis {tp}")
        rest = n_devices // tp
        if self.prefer_pods > 1 and rest % self.prefer_pods == 0 \
                and rest >= 2 * self.prefer_pods:
            return ((self.prefer_pods, rest // self.prefer_pods, tp),
                    ("pod", "data", "model"))
        return ((rest, tp), ("data", "model"))

    def build(self, devices: Optional[list] = None):
        devices = devices if devices is not None else jax.devices()
        shape, axes = self.shape_for(len(devices))
        n = int(np.prod(shape))
        return jax.make_mesh(shape, axes, devices=devices[:n])


@dataclass
class StragglerMonitor:
    """Per-step wall-time tracker with a robust deadline.

    deadline = median * tolerance over a sliding window; a step exceeding
    it is a straggler event.  At launcher level, persistent stragglers
    trigger the same restore-and-remesh path as failures (the slow host is
    excluded); in-process we record and surface them.
    """
    window: int = 50
    tolerance: float = 3.0
    min_samples: int = 5
    times: List[float] = field(default_factory=list)
    events: List[Tuple[int, float, float]] = field(default_factory=list)
    _t0: float = 0.0

    def start(self):
        self._t0 = time.monotonic()

    def stop(self, step: int) -> bool:
        dt = time.monotonic() - self._t0
        is_straggler = False
        if len(self.times) >= self.min_samples:
            deadline = float(np.median(self.times[-self.window:])) \
                * self.tolerance
            if dt > deadline:
                is_straggler = True
                self.events.append((step, dt, deadline))
        self.times.append(dt)
        return is_straggler

    @property
    def median_step_s(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0


class InjectedFault(RuntimeError):
    pass


@dataclass
class FaultInjector:
    """Deterministically raise at configured steps (tests/chaos drills)."""
    fail_at_steps: Tuple[int, ...] = ()
    fired: set = field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise InjectedFault(f"injected fault at step {step}")
