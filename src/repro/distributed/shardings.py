"""PartitionSpec assignment for every parameter in the zoo.

Pattern-matches the stable names emitted by models/params.py.  Three modes:

  tp       — Megatron-style tensor parallelism only (the paper-era baseline
             for the §Perf comparison): params replicated over data axes,
             contracted/expanded dims sharded over "model".
  fsdp     — TP over "model" + fully-sharded params/optimizer over "data"
             (the optimized default).
  fsdp_pod — same, but the FSDP axis spans ("pod", "data") on the
             multi-pod mesh.

MoE expert tensors shard experts over "model" (expert parallelism); GSPMD
inserts the dispatch all-to-alls.  Stacked layers carry a leading L dim that
always stays unsharded (it is scanned over).
"""
from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.dist import DistContext


# suffix-pattern rules: (regex on the trailing name, fn(ndim) -> dims role)
# roles: "col" = shard last dim on model; "row" = shard second-to-last on
# model; "expert" = shard expert dim; "rep" = replicated.
_RULES: Tuple[Tuple[str, str], ...] = (
    # order matters: expert/shared rules must fire before the generic
    # wg/w1 suffixes ("moe_wg" ends in "_wg" too)
    (r"(^|_)(moe_wg|moe_wu|moe_wd)$", "expert"),
    (r"(^|_)(shared_wg|shared_wu)$", "col"),
    (r"(^|_)shared_wd$", "row"),
    (r"(^|_)(wq|wk|wv|bq|bv)$", "col"),
    (r"(^|_)wo$", "row"),
    (r"(^|_)(w1|w3|b1|cmix_k|wr|wg)$", "col"),
    (r"(^|_)(w2|cmix_v)$", "row"),
    (r"(^|_)(m_in)$", "col"),
    (r"(^|_)(m_out)$", "row"),
    (r"(^|_)(embed|unembed)$", "vocab"),
    (r"(^|_)cmix_r$", "col"),
)


def _role(name: str) -> str:
    for pat, role in _RULES:
        if re.search(pat, name):
            return role
    return "rep"


def _spec_for(name: str, shape, mode: str, fsdp_axes, axis_size) -> P:
    """Build the PartitionSpec for one param, respecting divisibility."""
    role = _role(name) if mode != "dp_only" else "rep"
    ndim = len(shape)
    model = "model"
    dims = [None] * ndim

    def ok(i, axes) -> bool:
        return shape[i] % axis_size(axes) == 0

    if role == "col" and ndim >= 2 and ok(-1 % ndim + 0, model):
        dims[-1] = model
    elif role == "row" and ndim >= 2 and ok(ndim - 2, model):
        dims[-2] = model
    elif role == "expert" and ndim >= 3 and ok(ndim - 3, model):
        dims[-3] = model            # (L, E, d, F): experts over model
    elif role == "vocab" and ok(0, model):
        dims[0] = model             # (V, d): vocab-sharded
    # (indivisible cases — e.g. whisper's 51865 / internvl2's 92553 vocab —
    # fall through replicated on the model axis: Megatron-style vocab
    # padding is the alternative; replication costs < 1.2 GiB here)

    if mode in ("fsdp", "fsdp_pod", "dp_only"):
        # shard the largest remaining divisible dim over the data axes
        free = [i for i, d in enumerate(dims)
                if d is None and shape[i] % axis_size(fsdp_axes) == 0
                and shape[i] >= axis_size(fsdp_axes)]
        if free:
            tgt = max(free, key=lambda i: shape[i])
            dims[tgt] = fsdp_axes
    if all(d is None for d in dims):
        return P()
    return P(*dims)


def param_pspecs(cfg: ModelConfig, specs: Dict, mode: str = "tp",
                 multi_pod: bool = False,
                 mesh: Optional[Mesh] = None) -> Dict:
    """PartitionSpec tree matching a param (or optimizer-state) tree.

    With ``mesh`` given, divisibility is checked against the actual axis
    sizes; without it the production sizes (16 / 2x16) are assumed.
    """
    fsdp_axes = ("pod", "data") if multi_pod else ("data",)
    if mode == "fsdp_pod":
        multi_pod = True
        fsdp_axes = ("pod", "data")
    if mode == "dp_only":
        # pure data parallelism over the WHOLE mesh (TP=1): the model axis
        # joins the data axes; no tensor sharding roles apply — the lever
        # for collective-bound attention-free cells (§Perf A)
        fsdp_axes = ("pod", "data", "model") if multi_pod             else ("data", "model")

    def axis_size(axes) -> int:
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            if mesh is not None:
                n *= mesh.shape.get(a, 1)
            else:
                n *= {"pod": 2, "data": 16, "model": 16}[a]
        return n

    out = {}
    for name, v in specs.items():
        nd = len(v.shape)
        if nd <= 1 or min(v.shape) == 0:
            out[name] = P()
        else:
            out[name] = _spec_for(name, v.shape, mode, fsdp_axes, axis_size)
    return out


def _axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


def batch_pspec(multi_pod: bool = False) -> P:
    return P(("pod", "data") if multi_pod else ("data",))


def batch_pspecs_for(specs: Dict, mesh: Mesh,
                     multi_pod: bool = False) -> Dict:
    """Shard the leading (batch) dim of every input when divisible;
    fall back to sequence-dim sharding (SP) for batch=1 long-context."""
    b = ("pod", "data") if multi_pod else ("data",)
    dp = _axis_size(mesh, b)
    out = {}
    for k, v in specs.items():
        dims = [None] * len(v.shape)
        if v.shape and v.shape[0] % dp == 0 and v.shape[0] > 0:
            dims[0] = b
        elif len(v.shape) >= 2 and v.shape[1] % dp == 0:
            dims[1] = b            # (1, S, ...) long-context: shard S
        out[k] = P(*dims)
    return out


def cache_pspecs(cache, mesh: Mesh, multi_pod: bool = False,
                 kv_seq_shard: bool = False):
    """KV caches and recurrent states, shape-aware.

    kv (L, B, S, KH, Dh): B over data when divisible (else S takes data —
    the batch=1 long-context case, i.e. sequence parallelism); KH over
    model when divisible (GQA with few KV heads cannot split 16-way), else
    S over model (flash-decoding-style KV partitioning: GSPMD reduces the
    softmax stats across the axis).
    """
    b = ("pod", "data") if multi_pod else ("data",)
    dp = _axis_size(mesh, b)
    tp = _axis_size(mesh, "model")

    def one(leaf):
        shape = leaf.shape
        nd = len(shape)
        if nd >= 5:      # (L, B, S, KH, Dh)
            L, B, S, KH, Dh = shape[-5:]
            bdim = b if (B % dp == 0 and not kv_seq_shard) else None
            s_axes = [] if bdim is not None else list(b)
            hdim = "model" if KH % tp == 0 else None
            if hdim is None:
                s_axes.append("model")
            sdim = tuple(s_axes) if s_axes else None
            if sdim is not None and S % _axis_size(mesh, sdim) != 0:
                sdim = None     # give up: replicate sequence
            return P(None, bdim, sdim, hdim, None)
        if nd == 3:      # (L, B, S) position cache: follow the kv B/S split
            L, B, S = shape
            if B % dp == 0 and not kv_seq_shard:
                return P(None, b, None)
            return P(None, None, b if S % dp == 0 else None)
        if nd >= 2:      # recurrent states (L, B, H, ...) / conv (L, B, W, C)
            B = shape[1]
            bdim = b if B % dp == 0 else None
            dims = [None, bdim] + [None] * (nd - 2)
            # shard the widest trailing dim over model when divisible
            for i in range(nd - 1, 1, -1):
                if shape[i] % tp == 0 and shape[i] >= tp:
                    dims[i] = "model"
                    break
            return P(*dims)
        return P()

    return jax.tree.map(one, cache)


def make_dist(mesh: Optional[Mesh], auto_moe: bool = False,
              dp_only: bool = False) -> DistContext:
    if mesh is None:
        return DistContext(mesh=None)
    axes = ("pod", "data", "model") if dp_only else ("pod", "data")
    batch_axes = tuple(a for a in axes if a in mesh.shape)
    return DistContext(mesh=mesh, batch_axes=batch_axes,
                       model_axis="model" if not dp_only else "__none__",
                       auto_moe=auto_moe)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


# ---------------------------------------------------------------------------
# fleet-serving shardings (the sharded super-launch state)
# ---------------------------------------------------------------------------

def fleet_state_pspec() -> P:
    """PartitionSpec of every sharded fleet-state array: leading axis is
    the shard axis (stacked per-shard tables / activations / reference
    windows), everything else replicated-free per shard.  One spec fits
    all of them because ``fleet/sharded.py`` stacks per-shard state as
    (S, ...) with identical padded shapes."""
    from repro.launch.mesh import FLEET_AXIS
    return P(FLEET_AXIS)


def fleet_state_sharding(mesh: Mesh) -> NamedSharding:
    """NamedSharding placing (S, ...) stacked fleet state one-shard-per-
    device on a ``make_fleet_mesh`` mesh."""
    return NamedSharding(mesh, fleet_state_pspec())


def put_fleet_state(mesh: Mesh, tree):
    """device_put a pytree of (S, ...) stacked arrays onto the fleet
    mesh, shard axis split across devices (host tables go through here
    each step — the double-buffered table slots of the async pipeline)."""
    sh = fleet_state_sharding(mesh)
    return jax.tree.map(lambda a: jax.device_put(a, sh), tree)
