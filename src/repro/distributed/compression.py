"""Gradient compression for cross-data-axis reduction.

int8 per-tensor-scaled quantized all-reduce: grads are quantized to int8
with a per-tensor absmax scale, mean-reduced over the data axes in int32
(exact for <= 2^15 participants), then dequantized.  Cuts the DP gradient
all-reduce payload 4x vs fp32 / 2x vs bf16 at <0.5% relative error —
the classic large-cluster bandwidth trick (1-bit/8-bit Adam lineage).

Used by the train loop via shard_map when TrainConfig.grad_compression ==
"int8"; "none" leaves reduction to GSPMD's native psum.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-row (last-axis) absmax scaling: tensor-level scales are too
    coarse for spiky embedding grads; per-row adds only ~1/last_dim
    payload overhead."""
    if x.ndim >= 2:
        scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    else:
        scale = jnp.max(jnp.abs(x))
    scale = jnp.maximum(scale, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _allreduce_one(g: jax.Array, axes) -> jax.Array:
    q, scale = quantize_int8(g)
    # int32 sum is exact; scales are meaned in fp32
    qsum = jax.lax.psum(q.astype(jnp.int32), axes)
    ssum = jax.lax.psum(scale, axes)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axes)
    # mean of per-shard dequantized grads ~= (mean scale) * (mean q)
    return ((qsum.astype(jnp.float32) / n) * (ssum / n)).astype(g.dtype)


def int8_allreduce_mean(grads, mesh: Mesh, param_specs):
    """Mean-reduce a grad pytree over the data axes with int8 payload.

    grads enter *unreduced* (per-data-shard); param_specs gives each leaf's
    parameter sharding so the shard_map in/out specs preserve TP placement.
    """
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    def body(g):
        return jax.tree.map(lambda x: _allreduce_one(x, axes), g)

    return shard_map(
        body, mesh=mesh,
        in_specs=(param_specs,), out_specs=param_specs,
        check_vma=False)(grads)
