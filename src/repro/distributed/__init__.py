"""Distribution: sharding rules, collectives, compression, fault tolerance."""
from repro.distributed.shardings import (param_pspecs, batch_pspec,
                                         make_dist, cache_pspecs)
from repro.distributed.compression import (int8_allreduce_mean,
                                           quantize_int8, dequantize_int8)
from repro.distributed.fault import (ElasticMesh, StragglerMonitor,
                                     FaultInjector)

__all__ = ["param_pspecs", "batch_pspec", "make_dist", "cache_pspecs",
           "int8_allreduce_mean", "quantize_int8", "dequantize_int8",
           "ElasticMesh", "StragglerMonitor", "FaultInjector"]
