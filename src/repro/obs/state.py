"""The observability master switch.

One module-level flag shared by ``obs.trace`` and ``obs.metrics`` so a
single attribute load decides whether an instrumentation call does any
work.  Default **off**: tier-1 tests and production hot paths pay one
``if not state.enabled: return`` per call site and nothing else.  Flip
it through ``obs.configure`` (or the scoped ``obs.enabled()`` context
manager), never by assigning here directly from user code.
"""

enabled: bool = False
