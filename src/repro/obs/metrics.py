"""Typed metrics registry: counters / gauges / histograms with labels.

Generalizes ``kernels.ops.KERNEL_COUNTS`` (which stays — the
``kernel_dispatches`` counter family here receives the SAME bumps, so
snapshots bit-match the legacy counter) and gives the quantities the
subsystems already compute but drop on the floor a place to land:
changed-tile fractions, activation-cache hits/invalidations, bytes shed
by the rate controller, batcher backlog depth, deadline hit counts,
per-shard load, drift-breach windows.

Every instrument is a no-op while ``obs.state.enabled`` is False, so the
registry costs one attribute check per call site on the hot path.

IMPORT DISCIPLINE: ``kernels.ops`` imports :data:`KERNEL_NAMES` from
here to validate dispatch counter names, so this module (and everything
``repro.obs`` imports at module scope) must never import back into the
rest of ``repro`` — inputs from other subsystems arrive duck-typed.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.obs import state

# The ONE canonical set of kernel-dispatch counter names.  Every
# ``ops.record_dispatch`` call site, and every dispatch-count assertion
# in tests/benchmarks, must draw from this set — a typo'd name raises in
# ``record_dispatch`` (and fails the registry test) instead of silently
# counting zero forever.
KERNEL_NAMES = frozenset({
    "sbnet_gather", "sbnet_scatter", "sbnet_scatter_fleet",
    "sbnet_scatter_changed",
    "roi_conv", "roi_conv_packed", "roi_conv_fleet",
    "roi_conv_entry", "roi_conv_stack",
    "tile_delta", "tile_delta_gate", "tile_delta_halo",
    "roi_attention",
})

_LOCK = threading.Lock()


class _Metric:
    """Base: one named family; values keyed by the declared label tuple."""

    kind = "abstract"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames: Tuple[str, ...] = tuple(labelnames)
        self._values: Dict[Tuple, object] = {}

    def _key(self, labels: Dict[str, object]) -> Tuple:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"metric {self.name!r} declared labels "
                f"{sorted(self.labelnames)}, got {sorted(labels)}")
        return tuple(labels[ln] for ln in self.labelnames)

    def items(self) -> List[Tuple[Tuple, object]]:
        with _LOCK:
            return list(self._values.items())

    def clear(self) -> None:
        with _LOCK:
            self._values.clear()


class Counter(_Metric):
    """Monotonic accumulator (ints or float quantities like bytes)."""

    kind = "counter"

    def inc(self, n=1, **labels) -> None:
        if not state.enabled:
            return
        key = self._key(labels)
        with _LOCK:
            self._values[key] = self._values.get(key, 0) + n

    def value(self, **labels):
        return self._values.get(self._key(labels), 0)

    def total(self):
        with _LOCK:
            return sum(self._values.values())


class Gauge(_Metric):
    """Last-write-wins point-in-time value."""

    kind = "gauge"

    def set(self, v, **labels) -> None:
        if not state.enabled:
            return
        key = self._key(labels)
        with _LOCK:
            self._values[key] = float(v)

    def value(self, **labels):
        return self._values.get(self._key(labels), 0.0)


class Histogram(_Metric):
    """Full-sample distribution (count/sum/p50/p99 in snapshots)."""

    kind = "histogram"

    def observe(self, v, **labels) -> None:
        if not state.enabled:
            return
        key = self._key(labels)
        with _LOCK:
            self._values.setdefault(key, []).append(float(v))

    def count(self, **labels) -> int:
        return len(self._values.get(self._key(labels), ()))

    def percentile(self, q: float, **labels) -> float:
        vs = self._values.get(self._key(labels), ())
        return float(np.percentile(np.asarray(vs), q)) if len(vs) else 0.0


class Registry:
    """Get-or-create instrument store; re-registering a name with a
    different type or label set raises instead of shadowing."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}

    def _register(self, cls, name: str, help: str,
                  labelnames: Sequence[str]) -> _Metric:
        with _LOCK:
            m = self._metrics.get(name)
            if m is not None:
                if type(m) is not cls or m.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{m.kind}{m.labelnames}, cannot re-register as "
                        f"{cls.kind}{tuple(labelnames)}")
                return m
            m = cls(name, help, labelnames)
            self._metrics[name] = m
            return m

    def counter(self, name, help="", labels=()) -> Counter:
        return self._register(Counter, name, help, labels)

    def gauge(self, name, help="", labels=()) -> Gauge:
        return self._register(Gauge, name, help, labels)

    def histogram(self, name, help="", labels=()) -> Histogram:
        return self._register(Histogram, name, help, labels)

    def get(self, name: str) -> _Metric:
        return self._metrics[name]

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def reset(self) -> None:
        """Zero every instrument's values (registrations survive)."""
        for m in list(self._metrics.values()):
            m.clear()

    def snapshot(self) -> Dict[str, Dict]:
        """Serializable view: {name: {type, labels, values: [...]}} —
        histograms collapse to count/sum/min/max/p50/p99."""
        snap: Dict[str, Dict] = {}
        for name in self.names():
            m = self._metrics[name]
            vals = []
            for key, v in m.items():
                if m.kind == "histogram":
                    arr = np.asarray(v, float)
                    v = {"count": int(arr.size), "sum": float(arr.sum()),
                         "min": float(arr.min()) if arr.size else 0.0,
                         "max": float(arr.max()) if arr.size else 0.0,
                         "p50": float(np.percentile(arr, 50))
                         if arr.size else 0.0,
                         "p99": float(np.percentile(arr, 99))
                         if arr.size else 0.0}
                vals.append({"labels": dict(zip(m.labelnames, key)),
                             "value": v})
            snap[name] = {"type": m.kind, "labels": list(m.labelnames),
                          "values": vals}
        return snap


REGISTRY = Registry()


def counter(name, help="", labels=()) -> Counter:
    return REGISTRY.counter(name, help, labels)


def gauge(name, help="", labels=()) -> Gauge:
    return REGISTRY.gauge(name, help, labels)


def histogram(name, help="", labels=()) -> Histogram:
    return REGISTRY.histogram(name, help, labels)


# ---------------------------------------------------------------------------
# the core instrument families (declared once, bumped from the runtimes)
# ---------------------------------------------------------------------------

# ops.record_dispatch mirrors every bump here — bit-compatible with the
# legacy ops.KERNEL_COUNTS over the same window (see kernel_counts()).
KERNEL_DISPATCHES = REGISTRY.counter(
    "kernel_dispatches", "Pallas kernel launches by wrapper name",
    ("kernel",))

STEP_WALL = REGISTRY.histogram(
    "step_wall_s", "Wall time of one fleet step by runtime path",
    ("path",))

TILES = REGISTRY.counter(
    "fleet_tiles", "Per-step tile accounting: total / raw_changed / "
    "changed_dilated (post neighbor-dilation compute set) / computed / "
    "launched (padded)", ("kind",))

CHANGED_FRACTION = REGISTRY.gauge(
    "changed_tile_fraction", "raw gate-changed tiles / active tiles, "
    "latest step")

CACHE_EVENTS = REGISTRY.counter(
    "activation_cache_events", "PackedActivationCache traffic: step / "
    "cold_step / hit (tiles composited from cache) / invalidation",
    ("event",))

TRANSPORT_BYTES = REGISTRY.counter(
    "transport_bytes", "Wire accounting: base (un-shed) / shipped / "
    "shed_halo / shed_body", ("part",))

DEADLINE_EVENTS = REGISTRY.counter(
    "deadline_events", "Release accounting: release / deadline_hit / "
    "straggler_frame / frame", ("event",))

BACKLOG_DEPTH = REGISTRY.histogram(
    "backlog_depth", "Queued segments at each batcher release")

SERVE_EVENTS = REGISTRY.counter(
    "serve_events", "ServingEngine flushes: request / complete_flush / "
    "deadline_flush / straggler_request", ("event",))

SHARD_TILES = REGISTRY.gauge(
    "shard_computed_tiles", "Compute-set size per shard, latest step",
    ("shard",))

SHARD_IMBALANCE = REGISTRY.gauge(
    "shard_load_imbalance", "max/mean per-shard computed tiles, "
    "latest step")

DRIFT_EVENTS = REGISTRY.counter(
    "drift_events", "Drift monitor: breach_window / resolve / "
    "shrink_adopted / shrink_rejected", ("event",))

DRIFT_RESOLVE_WALL = REGISTRY.histogram(
    "drift_resolve_s", "Wall time of warm set-cover re-solves")

FAULT_EVENTS = REGISTRY.counter(
    "fault_events", "Fault lifecycle: injected / detected / failover / "
    "restored / shard_lost / shard_restored", ("event",))

HEARTBEAT_EVENTS = REGISTRY.counter(
    "heartbeat_events", "Transport heartbeat: dead / retry / restored",
    ("event",))

CANVAS_BYTES = REGISTRY.gauge(
    "canvas_bytes_written", "Bytes scattered into the persistent head-map "
    "canvas, latest step (0 on an all-static step)")

CANVAS_BYTES_TOTAL = REGISTRY.counter(
    "canvas_bytes_total", "Cumulative bytes scattered into the persistent "
    "head-map canvas across steps")

UNCOVERED_FRACTION = REGISTRY.gauge(
    "uncovered_fraction", "Degraded-mode coverage hole: fraction of "
    "ground-truth appearances no surviving camera's mask covers, "
    "latest step (0.0 when failover fully reassigned coverage)")


def kernel_counts() -> Dict[str, int]:
    """{kernel: launches} from the ``kernel_dispatches`` family — the
    bit-match surface against ``ops.KERNEL_COUNTS`` deltas over the same
    window (reset this registry at the window start)."""
    return {key[0]: v for key, v in KERNEL_DISPATCHES.items()}


# ---------------------------------------------------------------------------
# duck-typed recording helpers shared by the fleet runtimes
# ---------------------------------------------------------------------------

def observe_fleet_step(stats, wall_s: float, path: str) -> None:
    """Record one delta-gated fleet step's tile/cache accounting.

    ``stats`` is duck-typed over ``serving.detector.ReuseStats`` and
    ``fleet.sharded.ShardedReuseStats`` (total_tiles / raw_changed /
    changed_out / computed / launched, plus either ``cold`` or
    ``cold_shards`` and optionally ``per_shard_computed``)."""
    if not state.enabled:
        return
    STEP_WALL.observe(wall_s, path=path)
    total = int(stats.total_tiles)
    TILES.inc(total, kind="total")
    TILES.inc(int(stats.raw_changed), kind="raw_changed")
    TILES.inc(int(stats.changed_out), kind="changed_dilated")
    TILES.inc(int(stats.computed), kind="computed")
    TILES.inc(int(stats.launched), kind="launched")
    CHANGED_FRACTION.set(stats.raw_changed / total if total else 0.0)
    cold = bool(getattr(stats, "cold", False)) \
        or bool(getattr(stats, "cold_shards", 0))
    CACHE_EVENTS.inc(1, event="step")
    if cold:
        CACHE_EVENTS.inc(1, event="cold_step")
    else:
        CACHE_EVENTS.inc(total - int(stats.computed), event="hit")
    canvas_bytes = getattr(stats, "canvas_bytes", None)
    if canvas_bytes is not None:
        CANVAS_BYTES.set(float(canvas_bytes))
        CANVAS_BYTES_TOTAL.inc(float(canvas_bytes))
    per_shard = getattr(stats, "per_shard_computed", None)
    if per_shard:
        mean = sum(per_shard) / len(per_shard)
        for s, v in enumerate(per_shard):
            SHARD_TILES.set(v, shard=str(s))
        SHARD_IMBALANCE.set(max(per_shard) / mean if mean else 1.0)


def observe_transport(ts) -> None:
    """Record one ``simulate_transport`` window (duck-typed
    ``TransportStats``): wire bytes, shed composition, deadline hits,
    straggler frames."""
    if not state.enabled:
        return
    TRANSPORT_BYTES.inc(float(ts.bytes_base), part="base")
    TRANSPORT_BYTES.inc(float(ts.bytes_total), part="shipped")
    TRANSPORT_BYTES.inc(float(ts.shed_halo_bytes), part="shed_halo")
    TRANSPORT_BYTES.inc(float(ts.shed_body_bytes), part="shed_body")
    DEADLINE_EVENTS.inc(int(ts.deadline_hits), event="deadline_hit")
    DEADLINE_EVENTS.inc(int(ts.straggler_frames), event="straggler_frame")
    DEADLINE_EVENTS.inc(int(ts.latency_s.size), event="frame")
