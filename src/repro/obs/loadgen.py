"""Heavy-traffic load generation: the SLO frontier sweep harness.

The paper's headline numbers (42-65% network reduction, 25-34% delay
reduction at >99% accuracy) are one point; this module measures the
SURFACE.  A sweep grid spans

* **scale** — fleet size as groups x cameras-per-group,
* **congestion severity** — none, scripted ``CongestionEpisode``s at a
  given depth, or replay of a real cellular uplink trace
  (``net.links.UplinkTrace``),
* **traffic profile** — the static fraction of the fleet per step (how
  much of the scene moves, which is what delta-gated compute prices),
* **serve request rate** — Poisson arrivals into
  ``ServingEngine.serve_deadline``,

and each grid point drives the EXISTING runtimes — ``fleet.runtime.
fleet_reuse_step`` (or the sharded ``sharded_fleet_step``),
``net.batcher.simulate_transport``, ``serving.engine.serve_deadline`` —
exactly as production would, then folds the measurements into one
``obs.slo.FleetSLOReport`` per point.  ``benchmarks/bench_slo.py``
merges the resulting frontier panel into ``BENCH_kernels.json`` and the
headline frontier metrics into ``BENCH_history.jsonl``, where
``obs.sentinel`` watches them across commits.

The harness itself must be free: driving a runtime through
``drive_fleet`` adds ZERO kernel dispatches and < 2% wall overhead vs
an inline loop (the ``--slo`` smoke asserts both) — all it adds per
step is one ``StepReport`` dataclass.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.slo import FleetSLOReport, StepReport


# ---------------------------------------------------------------------------
# sweep grid
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SweepPoint:
    """One grid point of the frontier sweep.

    ``congestion`` is a severity spec: ``"none"``, ``"episode:<factor>"``
    (scripted shared-bottleneck episode over the middle half of the
    window at ``factor`` capacity — smaller = more severe), or
    ``"trace:<name>"`` (replay the bundled real uplink trace).
    ``static_fraction`` is the per-step fraction of fleet cameras that
    hold still (1.0 = frozen scene, delta-gating serves everything from
    cache).  ``faults`` is a seeded fault-schedule spec: ``"none"``
    (production — the drive is bit-identical to the fault-free path),
    ``"random:<n_events>:<seed>"`` (a reproducible random chaos script,
    ``fleet.faults.FaultSchedule.random``), or
    ``"<kind>:<gid>.<cam>@<t0>-<t1>"`` for one scripted camera fault
    (kind in blackout/freeze/noise)."""
    n_groups: int
    cams_per_group: int
    congestion: str = "none"
    static_fraction: float = 0.9
    faults: str = "none"

    @property
    def n_cameras(self) -> int:
        return self.n_groups * self.cams_per_group

    @property
    def severity(self) -> float:
        """Orderable congestion severity: 0 for none, 1 - factor for
        scripted episodes (deeper cut = more severe); traces are not on
        the scripted severity axis and return -1."""
        if self.congestion == "none":
            return 0.0
        if self.congestion.startswith("episode:"):
            return 1.0 - float(self.congestion.split(":", 1)[1])
        return -1.0

    def to_dict(self) -> Dict:
        return {"n_groups": self.n_groups,
                "cams_per_group": self.cams_per_group,
                "n_cameras": self.n_cameras,
                "congestion": self.congestion,
                "static_fraction": self.static_fraction,
                "faults": self.faults}


@dataclass
class LoadgenConfig:
    """Shared knobs of one sweep (everything a ``SweepPoint`` doesn't
    vary)."""
    steps: int = 6                     # fleet steps driven per point
    tile: int = 8
    channels: Tuple[int, ...] = (6, 8)
    grid_shape: Tuple[int, int] = (5, 6)
    density: float = 0.55
    seed: int = 0
    threshold: float = 0.0             # gate threshold (0 = bit-exact)
    qstep: float = 8.0
    # transport window per point
    segment_s: float = 1.0
    frames_per_seg: int = 10
    n_segs: int = 8
    bandwidth_mbps: float = 8.0        # shared budget (constant arm)
    rtt_ms: float = 40.0
    server_hz: float = 120.0
    pixels_per_s: float = 2e8
    deadline_s: float = 2.5
    trace_scale: float = 1.0
    rate_control: bool = True
    # synthetic per-camera packetization coefficients (bytes per
    # activity-weighted frame), matching the bench_obs transport window
    body_bytes: float = 3e4
    halo_bytes: float = 4e3
    header_bytes: float = 200.0
    mask_area_px: float = 2.5e5


def make_grids(cfg: LoadgenConfig, n_groups: int, cams: int
               ) -> Dict[int, List[np.ndarray]]:
    """Deterministic per-scale RoI tile grids (seeded by scale so the
    same scale point always compiles the same shapes)."""
    rng = np.random.default_rng(cfg.seed + 7919 * n_groups + 104729 * cams)
    grids: Dict[int, List[np.ndarray]] = {}
    for gid in range(n_groups):
        gs = [rng.random(cfg.grid_shape) < cfg.density for _ in range(cams)]
        for g in gs:
            g[1, 1] = True                       # never fully empty
        grids[gid] = gs
    return grids


def make_frame_trace(cfg: LoadgenConfig, grids: Dict[int, List[np.ndarray]],
                     static_fraction: float, steps: Optional[int] = None,
                     seed_offset: int = 0) -> List[Dict[int, List]]:
    """A ``steps``-long fleet frame trace where per step
    ``round((1 - static_fraction) * n_cameras)`` cameras (>= 1 unless the
    scene is fully frozen) receive one tile of fresh pixels and every
    other camera is bit-static — the traffic-profile axis the delta gate
    prices."""
    steps = steps if steps is not None else cfg.steps
    tile = cfg.tile
    rng = np.random.default_rng(cfg.seed + 1 + seed_offset)
    n_cams = sum(len(gs) for gs in grids.values())
    moves = 0 if static_fraction >= 1.0 else max(
        int(round((1.0 - static_fraction) * n_cams)), 1)
    frames = {g: [np.asarray(rng.normal(size=(gr.shape[0] * tile,
                                              gr.shape[1] * tile, 3)),
                             np.float32) for gr in gs]
              for g, gs in grids.items()}
    out = [frames]
    for _ in range(steps - 1):
        nxt = {g: [f.copy() for f in fs] for g, fs in frames.items()}
        for _ in range(moves):
            gid = int(rng.integers(len(grids)))
            cam = int(rng.integers(len(grids[gid])))
            gr = grids[gid][cam]
            ys, xs = np.nonzero(gr)
            j = int(rng.integers(len(ys)))
            y0, x0 = ys[j] * tile, xs[j] * tile
            nxt[gid][cam][y0:y0 + tile, x0:x0 + tile] = \
                rng.normal(size=(tile, tile, 3)).astype(np.float32)
        out.append(nxt)
        frames = nxt
    return out


# ---------------------------------------------------------------------------
# runtime drivers (zero added dispatches: one StepReport per step, no more)
# ---------------------------------------------------------------------------

def drive_fleet(det, frames_list: Sequence[Dict[int, List]],
                grids: Dict[int, List[np.ndarray]], cache,
                threshold: float = 0.0, qstep: float = 8.0,
                keep_outputs: bool = False):
    """Drive ``fleet.runtime.fleet_reuse_step`` over a frame trace.

    Returns (step reports, per-step outputs or [], total dispatch
    Counter).  This IS the production loop — the only instrumentation is
    the per-step wall clock and ``StepReport`` construction, so the
    dispatch Counter is identical to an inline drive and the wall
    overhead is sub-2% (asserted by the ``--slo`` smoke)."""
    import collections

    from repro.fleet.runtime import fleet_reuse_step

    reports: List[StepReport] = []
    outputs = []
    total: collections.Counter = collections.Counter()
    for i, frames in enumerate(frames_list):
        t0 = time.perf_counter()
        outs, counts, stats = fleet_reuse_step(det, frames, grids, cache,
                                               threshold, qstep)
        reports.append(StepReport.from_reuse(
            i, time.perf_counter() - t0, counts, stats))
        total += counts
        if keep_outputs:
            outputs.append(outs)
    return reports, outputs, total


def drive_sharded(runtime, frames_list: Sequence[Dict[int, List]], cache,
                  threshold: float = 0.0, keep_outputs: bool = False):
    """Same contract as ``drive_fleet`` over a
    ``fleet.sharded.ShardedSuperlaunch`` (one SPMD program per
    dispatch; the per-shard dispatch ceiling is asserted inside
    ``sharded_fleet_step`` every step)."""
    import collections

    from repro.fleet.runtime import sharded_fleet_step

    reports: List[StepReport] = []
    outputs = []
    total: collections.Counter = collections.Counter()
    for i, frames in enumerate(frames_list):
        t0 = time.perf_counter()
        outs, counts, stats = sharded_fleet_step(runtime, frames, cache,
                                                 threshold)
        reports.append(StepReport.from_reuse(
            i, time.perf_counter() - t0, counts, stats))
        total += counts
        if keep_outputs:
            outputs.append(outs)
    return reports, outputs, total


def accuracy_vs_exact(det, frames_list: Sequence[Dict[int, List]],
                      grids: Dict[int, List[np.ndarray]],
                      reuse_outputs: Sequence[Dict[int, List]],
                      tol: float = 1e-2) -> Tuple[float, float]:
    """(floor, mean) fraction of head-map entries within ``tol`` of the
    exact (threshold-0 full) super-launch, per step — the query-accuracy
    axis of the frontier.  Runs OUTSIDE the timed drive (it re-runs the
    exact forward, which is extra work by definition)."""
    per_step = []
    for frames, outs in zip(frames_list, reuse_outputs):
        exact = det.superlaunch_forward(frames, grids)
        ok = n = 0
        for gid in exact:
            for a, b in zip(outs[gid], exact[gid]):
                a = np.asarray(a)
                b = np.asarray(b)
                ok += int(np.count_nonzero(np.abs(a - b) <= tol))
                n += a.size
        per_step.append(ok / max(n, 1))
    if not per_step:
        return 1.0, 1.0
    return float(np.min(per_step)), float(np.mean(per_step))


def faults_for(cfg: LoadgenConfig, point: SweepPoint):
    """Resolve a ``SweepPoint.faults`` spec into a
    ``fleet.faults.FaultSchedule`` (None for ``"none"`` — the injector
    then never touches the frames and the drive stays bit-identical to
    the production loop)."""
    from repro.fleet.faults import FaultEvent, FaultSchedule

    spec = point.faults
    if spec == "none":
        return None
    if spec.startswith("random:"):
        _, n_events, seed = spec.split(":")
        return FaultSchedule.random(
            int(seed) + cfg.seed, int(n_events), cfg.steps,
            point.n_groups, point.cams_per_group)
    kind, rest = spec.split(":", 1)
    target, window = rest.split("@")
    gid, cam = (int(x) for x in target.split("."))
    t0, t1 = (int(x) for x in window.split("-"))
    return FaultSchedule((FaultEvent(kind, t0, t1, gid=gid, cam=cam),))


# ---------------------------------------------------------------------------
# transport leg
# ---------------------------------------------------------------------------

def link_for(cfg: LoadgenConfig, congestion: str):
    """Resolve a ``SweepPoint.congestion`` spec into a ``LinkConfig``."""
    from repro.net.links import (CongestionEpisode, LinkConfig,
                                 load_bundled_trace)

    if congestion == "none":
        return LinkConfig()
    if congestion.startswith("episode:"):
        factor = float(congestion.split(":", 1)[1])
        window_s = cfg.n_segs * cfg.segment_s
        return LinkConfig(congestion=(CongestionEpisode(
            0.25 * window_s, 0.75 * window_s, factor),))
    if congestion.startswith("trace:"):
        name = congestion.split(":", 1)[1]
        return LinkConfig(trace=load_bundled_trace(name),
                          trace_scale=cfg.trace_scale)
    raise ValueError(f"unknown congestion spec {congestion!r}")


def transport_window(cfg: LoadgenConfig, n_cameras: int, congestion: str,
                     static_fraction: float):
    """Price one online window for ``n_cameras`` cameras sharing the
    budget under the point's congestion — synthetic per-camera
    packetization coefficients (no scene fixture needed), rate control
    fed by the point's static fraction.  Congestion grows naturally with
    scale: the budget is shared, the load is per-camera."""
    from repro.net.batcher import NetConfig, simulate_transport
    from repro.net.encoder import CameraCoefficients, RateControlConfig

    C = n_cameras
    coef = CameraCoefficients(
        body=np.full(C, cfg.body_bytes), halo=np.full(C, cfg.halo_bytes),
        headers=np.full(C, cfg.header_bytes),
        has_mask=np.ones(C, bool))
    net = NetConfig(
        link=link_for(cfg, congestion),
        rate_control=RateControlConfig(enabled=cfg.rate_control,
                                       static_fraction=static_fraction),
        deadline_s=cfg.deadline_s)
    return simulate_transport(
        [None] * C, None, None, np.full(C, cfg.mask_area_px), None,
        cfg.segment_s, cfg.frames_per_seg, cfg.n_segs, cfg.bandwidth_mbps,
        cfg.rtt_ms, cfg.server_hz, cfg.pixels_per_s, net=net, coef=coef)


# ---------------------------------------------------------------------------
# one grid point end-to-end
# ---------------------------------------------------------------------------

def run_point(cfg: LoadgenConfig, det, point: SweepPoint,
              grids: Optional[Dict[int, List[np.ndarray]]] = None,
              frames_list: Optional[Sequence[Dict[int, List]]] = None,
              cache=None, measure_accuracy: bool = True) -> Dict:
    """Drive every runtime at one grid point and fold the measurements
    into a ``FleetSLOReport``.  ``grids``/``frames_list``/``cache`` can
    be passed in to share fixtures (and jit caches) across points of the
    same scale."""
    from repro.serving.detector import PackedActivationCache

    if grids is None:
        grids = make_grids(cfg, point.n_groups, point.cams_per_group)
    if frames_list is None:
        frames_list = make_frame_trace(cfg, grids, point.static_fraction)
    if cache is None:
        cache = PackedActivationCache()

    schedule = faults_for(cfg, point)
    fault_info = None
    t0 = time.perf_counter()
    if schedule is None:
        reports, outputs, counts = drive_fleet(
            det, frames_list, grids, cache, cfg.threshold, cfg.qstep,
            keep_outputs=measure_accuracy)
    else:
        from repro.fleet.faults import (LivenessMonitor, drive_chaos,
                                        flat_cam_index)

        monitor = LivenessMonitor(len(flat_cam_index(grids)))
        reports, outputs, counts, detected = drive_chaos(
            det, frames_list, grids, cache, cfg.threshold, cfg.qstep,
            schedule=schedule, monitor=monitor,
            keep_outputs=measure_accuracy, seed=cfg.seed)
        fault_info = {"events": len(schedule.events),
                      "detected": {int(k): list(map(int, v))
                                   for k, v in detected.items()}}
    drive_wall = time.perf_counter() - t0

    if measure_accuracy:
        # against the exact forward on the TRUE (clean) frames — under
        # an active fault window this measures degraded-mode accuracy
        acc_floor, acc_mean = accuracy_vs_exact(det, frames_list, grids,
                                                outputs)
    else:
        acc_floor = acc_mean = 1.0

    ts = transport_window(cfg, point.n_cameras, point.congestion,
                          point.static_fraction)
    report = FleetSLOReport.build(
        steps=reports, transport=ts, accuracy_floor=acc_floor,
        accuracy_mean=acc_mean, cache=cache, n_windows=cfg.n_segs)
    out = {"point": point.to_dict(), "drive_wall_s": drive_wall,
           "dispatches": dict(counts), "slo": report.to_dict()}
    if fault_info is not None:
        out["faults"] = fault_info
    return out


def sweep(cfg: LoadgenConfig, det_factory, points: Sequence[SweepPoint],
          measure_accuracy: bool = True, log=None) -> List[Dict]:
    """Run a full grid.  Points are grouped by scale so each scale
    builds its grids/detector fixtures once (sweeping congestion and
    static fraction re-uses the compiled shapes); a fresh activation
    cache per point keeps points independent."""
    by_scale: Dict[Tuple[int, int], List[SweepPoint]] = {}
    for p in points:
        by_scale.setdefault((p.n_groups, p.cams_per_group), []).append(p)
    results: List[Dict] = []
    for (n_groups, cams), pts in by_scale.items():
        det = det_factory()
        grids = make_grids(cfg, n_groups, cams)
        traces: Dict[float, Sequence] = {}
        for p in pts:
            if p.static_fraction not in traces:
                traces[p.static_fraction] = make_frame_trace(
                    cfg, grids, p.static_fraction)
            if log:
                log(f"loadgen point {p.to_dict()}")
            results.append(run_point(
                cfg, det, p, grids=grids,
                frames_list=traces[p.static_fraction],
                measure_accuracy=measure_accuracy))
    return results


# ---------------------------------------------------------------------------
# serve-rate leg (ServingEngine.serve_deadline under Poisson arrivals)
# ---------------------------------------------------------------------------

def drive_serve(engine, rate_hz: float, n_requests: int = 24,
                n_groups: int = 2, group_size: int = 3,
                deadline_s: float = 0.5, prompt_len: int = 32,
                greedy_steps: int = 2, seed: int = 0) -> Dict:
    """Drive ``ServingEngine.serve_deadline`` with a Poisson request
    stream at ``rate_hz`` (requests round-robin across ``n_groups``
    camera groups) and report the serve-side SLO panel: batching-wait
    p50/p99, deadline vs complete flush mix, straggler requests."""
    from repro.serving.engine import Request

    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, n_requests))
    reqs = [Request(rid=i,
                    tokens=rng.integers(0, engine.cfg.vocab_size,
                                        prompt_len).astype(np.int32),
                    max_new_tokens=greedy_steps, group=i % n_groups,
                    arrival_s=float(arrivals[i]))
            for i in range(n_requests)]
    t0 = time.perf_counter()
    results, rep = engine.serve_deadline(
        reqs, group_sizes={g: group_size for g in range(n_groups)},
        deadline_s=deadline_s, greedy_steps=greedy_steps)
    wall = time.perf_counter() - t0
    waits = np.asarray([rep.wait_s(r) for r in reqs])
    flushes = rep.complete_flushes + rep.deadline_flushes
    return {"rate_hz": float(rate_hz), "n_requests": n_requests,
            "served": len(results),
            "wait_p50_s": float(np.percentile(waits, 50)),
            "wait_p99_s": float(np.percentile(waits, 99)),
            "wait_mean_s": float(waits.mean()),
            "complete_flushes": rep.complete_flushes,
            "deadline_flushes": rep.deadline_flushes,
            "deadline_flush_frac": rep.deadline_flushes / max(flushes, 1),
            "straggler_requests": rep.straggler_requests,
            "serve_wall_s": wall}
