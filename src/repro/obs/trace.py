"""Lightweight span tracing for the gate→launch→transport→serve path.

``span(name, **args)`` is a context manager stamping monotonic
(``time.perf_counter_ns``) begin/duration pairs into a process-wide
event list; ``begin(name, track=...)`` returns a handle for work whose
completion is observed later than its start — the async pipeline opens a
``device_compute`` span at dispatch and ends it at the ``collect()``
fence, so host-plan and device spans visibly overlap on separate
timeline tracks without adding a single sync point.

Thread-safety mirrors ``ops.count_kernels``: events carry the emitting
thread's tid (host threads get small stable ids; named tracks get their
own reserved tid range), appends take one lock, and a disabled tracer
returns a shared null object — zero allocation beyond the kwargs dict,
zero device dispatches ever.  Export with ``obs.export.chrome_trace``.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Tuple

from repro.obs import state

_LOCK = threading.Lock()
# finished spans: (name, tid, t0_ns, dur_ns, args)
_EVENTS: List[Tuple[str, int, int, int, dict]] = []
_HOST_TIDS: Dict[int, Tuple[int, str]] = {}   # thread ident -> (tid, name)
_TRACK_TIDS: Dict[str, int] = {}              # track name -> tid
TRACK_TID_BASE = 1000                         # host tids stay below this


class _NullSpan:
    """Shared do-nothing span/handle returned while tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args):
        pass

    def end(self, **args):
        pass


NULL_SPAN = _NullSpan()


def _host_tid() -> int:
    ident = threading.get_ident()
    ent = _HOST_TIDS.get(ident)
    if ent is None:
        with _LOCK:
            ent = _HOST_TIDS.setdefault(
                ident, (len(_HOST_TIDS) + 1,
                        threading.current_thread().name))
    return ent[0]


def _track_tid(track: str) -> int:
    tid = _TRACK_TIDS.get(track)
    if tid is None:
        with _LOCK:
            tid = _TRACK_TIDS.setdefault(
                track, TRACK_TID_BASE + len(_TRACK_TIDS))
    return tid


class Span:
    """``with span("gate", step=t):`` — closed on the emitting thread."""

    __slots__ = ("name", "args", "_t0")

    def __init__(self, name: str, args: dict):
        self.name = name
        self.args = args
        self._t0 = 0

    def set(self, **args) -> None:
        self.args.update(args)

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter_ns() - self._t0
        ev = (self.name, _host_tid(), self._t0, dur, self.args)
        with _LOCK:
            _EVENTS.append(ev)
        return False


class AsyncSpan:
    """begin()/end() span on a named track — for in-flight device work
    whose completion is only observed at an existing fence."""

    __slots__ = ("name", "args", "track", "_t0", "_done")

    def __init__(self, name: str, track: str, args: dict):
        self.name = name
        self.track = track
        self.args = args
        self._done = False
        self._t0 = time.perf_counter_ns()

    def end(self, **args) -> None:
        if self._done:
            return
        self._done = True
        dur = time.perf_counter_ns() - self._t0
        self.args.update(args)
        ev = (self.name, _track_tid(self.track), self._t0, dur, self.args)
        with _LOCK:
            _EVENTS.append(ev)


def span(name: str, **args):
    """Open a host-thread span; no-op shared object when disabled."""
    if not state.enabled:
        return NULL_SPAN
    return Span(name, args)


def begin(name: str, track: str = "device", **args):
    """Start an async span on ``track`` NOW; close it with
    ``handle.end()`` wherever the completion is already observed."""
    if not state.enabled:
        return NULL_SPAN
    return AsyncSpan(name, track, args)


def events() -> List[Tuple[str, int, int, int, dict]]:
    with _LOCK:
        return list(_EVENTS)


def span_count() -> int:
    return len(_EVENTS)


def clear() -> None:
    with _LOCK:
        _EVENTS.clear()


def thread_names() -> Dict[int, str]:
    """{tid: display name} for every host thread and named track seen."""
    with _LOCK:
        out = {tid: name for tid, name in _HOST_TIDS.values()}
        out.update({tid: trk for trk, tid in _TRACK_TIDS.items()})
    return out
