"""Perf regression sentinel over ``BENCH_history.jsonl``.

Every ``benchmarks/run.py`` smoke appends one record per run — git SHA,
headline walls, and (since schema v1) the SLO frontier metrics.  This
module turns that stream into a CI gate:

* **interleaved min-of-reps** — a SHA usually has several records (the
  smokes re-run per mode); the per-SHA value of each wall is the MIN
  across its records, the same noise treatment the benches apply to
  their own rep loops.  Absolute-only metrics (two-sided noise) use
  the per-SHA MEDIAN instead, so one contended-run outlier cannot
  latch into the baseline.
* **median-of-window baseline** — the head SHA (latest in file order)
  compares against the MEDIAN of the previous ``window`` SHAs' mins, so
  one noisy historical run cannot poison the baseline.
* **relative-threshold + absolute-floor rules** — a wall regresses only
  if it grew by both ``rel_threshold`` (default 30%, CI-runner noise is
  real) AND ``abs_floor`` seconds.  Fraction/rate metrics (e.g. the obs
  overhead_frac, which legitimately wobbles in a ±2% band around zero)
  use an ABSOLUTE-ONLY rule: relative deltas off a near-zero baseline
  are meaningless, so only an absolute move above the floor counts.

Records that predate the versioned schema (no ``"schema"`` key) are
skipped with a warning, never crashed on.  ``self_test`` fabricates a
temp history with an injected 2x wall slowdown and asserts the sentinel
flags it while passing the clean copy — the gate proves itself before
gating anything.
"""
from __future__ import annotations

import json
import os
import tempfile
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

#: minimum record schema version this sentinel understands
SCHEMA_VERSION = 1

#: metric-name suffixes priced with the absolute-only rule (near-zero
#: baselines make relative thresholds meaningless)
_ABSOLUTE_ONLY_SUFFIXES = ("_frac", "_fraction", "_rate", "_reduction",
                           "_floor")

#: named absolute rules, matched on the metric's LEAF name (after the
#: last "."): chaos-recovery bounds the suffix table cannot express.
#: ``mttr_steps`` is step-valued with a small-integer healthy baseline
#: (relative thresholds off "1 step" are meaningless; one extra step of
#: recovery IS the regression).  ``uncovered_frac_p99`` would match the
#: suffix table anyway, but its floor is tighter: any sustained coverage
#: hole above 5% is an incident, regardless of the baseline.
_ABSOLUTE_METRIC_RULES: Dict[str, "MetricRule"] = {}


@dataclass(frozen=True)
class MetricRule:
    """How one metric's head-vs-baseline delta is judged."""
    rel_threshold: float      # relative growth that counts (walls)
    abs_floor: float          # AND the absolute move must exceed this
    absolute_only: bool       # ignore rel_threshold (fractions/rates)
    lower_is_better: bool = True

    def describe(self) -> str:
        if self.absolute_only:
            return f"|delta| > {self.abs_floor:g} (absolute)"
        return (f"delta > {self.rel_threshold:.0%} rel "
                f"and > {self.abs_floor:g} abs")


def rule_for(metric: str) -> MetricRule:
    """Default rule table: named absolute rules first (matched on the
    leaf name, so ``chaos.mttr_steps`` finds ``mttr_steps``); then
    seconds-valued walls get relative + floor; fraction/rate metrics get
    absolute-only with a 0.05 floor — wide enough that the known ±2%
    obs-overhead noise band (worst in-band swing 0.04) can never trip
    it, tight enough that a real structural regression (overhead jumping
    to 10%) does."""
    named = _ABSOLUTE_METRIC_RULES.get(metric.rsplit(".", 1)[-1])
    if named is not None:
        return named
    if metric.endswith(_ABSOLUTE_ONLY_SUFFIXES):
        return MetricRule(rel_threshold=0.0, abs_floor=0.05,
                          absolute_only=True)
    return MetricRule(rel_threshold=0.30, abs_floor=0.010,
                      absolute_only=False)


_ABSOLUTE_METRIC_RULES.update({
    # recovery must stay within ~2 steps of the baseline; a 2x MTTR on
    # a 2-step baseline moves by 2.0 > 1.5 and is flagged
    "mttr_steps": MetricRule(rel_threshold=0.0, abs_floor=1.5,
                             absolute_only=True),
    "detect_latency_steps": MetricRule(rel_threshold=0.0, abs_floor=2.5,
                                       absolute_only=True),
    "freeze_detect_latency_steps": MetricRule(rel_threshold=0.0,
                                              abs_floor=2.5,
                                              absolute_only=True),
    "uncovered_frac_p99": MetricRule(rel_threshold=0.0, abs_floor=0.05,
                                     absolute_only=True),
    # higher-is-better recovery metrics: a DROP past the floor is the
    # regression (the suffix/wall tables would price these backwards)
    "coverage_restored_ratio": MetricRule(rel_threshold=0.0,
                                          abs_floor=0.05,
                                          absolute_only=True,
                                          lower_is_better=False),
    "degraded_accuracy_floor": MetricRule(rel_threshold=0.0,
                                          abs_floor=0.05,
                                          absolute_only=True,
                                          lower_is_better=False),
    # persistent-canvas contract: an all-static step writes ZERO canvas
    # bytes — any sustained nonzero value means a regression re-enabled
    # full-canvas (or any) writes on static steps, so the floor is half
    # a byte; and the mean per-step canvas traffic may not quietly grow
    # past a sustained 64 KiB/step (static tiles being rewritten) — a
    # byte count with two-sided run-to-run jitter, so absolute-only
    # with MEDIAN per-SHA reduction
    "static_canvas_bytes": MetricRule(rel_threshold=0.0, abs_floor=0.5,
                                      absolute_only=True),
    "canvas_bytes_per_step": MetricRule(rel_threshold=0.0,
                                        abs_floor=65536.0,
                                        absolute_only=True),
})


@dataclass
class Finding:
    metric: str
    baseline: float
    head: float
    classification: str       # regression | improvement | ok
    rule: MetricRule

    @property
    def delta(self) -> float:
        return self.head - self.baseline

    @property
    def rel(self) -> float:
        denom = abs(self.baseline)
        return self.delta / denom if denom > 1e-12 else float("inf")


@dataclass
class SentinelReport:
    head_sha: str = ""
    baseline_shas: List[str] = field(default_factory=list)
    findings: List[Finding] = field(default_factory=list)
    skipped: List[str] = field(default_factory=list)   # warnings
    status: str = "ok"        # ok | regression | no_baseline | no_data

    @property
    def has_regression(self) -> bool:
        return self.status == "regression"

    @property
    def regressions(self) -> List[Finding]:
        return [f for f in self.findings
                if f.classification == "regression"]

    def render(self) -> str:
        lines = []
        for w in self.skipped:
            lines.append(f"warning: {w}")
        if self.status == "no_data":
            lines.append("sentinel: no schema-valid history records — "
                         "nothing to gate")
            return "\n".join(lines)
        if self.status == "no_baseline":
            lines.append(f"sentinel: head {self.head_sha} has no prior "
                         f"SHA to compare against — pass (no baseline)")
            return "\n".join(lines)
        lines.append(f"sentinel: head {self.head_sha} vs median of "
                     f"{len(self.baseline_shas)} prior SHA(s) "
                     f"{self.baseline_shas}")
        rows = [("metric", "baseline", "head", "delta", "rel", "verdict")]
        order = {"regression": 0, "improvement": 1, "ok": 2}
        for f in sorted(self.findings,
                        key=lambda f: (order[f.classification], f.metric)):
            rel = ("-" if f.rule.absolute_only or not np.isfinite(f.rel)
                   else f"{f.rel:+.1%}")
            rows.append((f.metric, f"{f.baseline:.4g}", f"{f.head:.4g}",
                         f"{f.delta:+.4g}", rel, f.classification))
        widths = [max(len(r[i]) for r in rows) for i in range(6)]
        for r in rows:
            lines.append("  ".join(c.ljust(w) for c, w in zip(r, widths)))
        n_reg = len(self.regressions)
        lines.append(f"sentinel verdict: "
                     f"{'REGRESSION' if n_reg else 'clean'}"
                     + (f" ({n_reg} metric(s))" if n_reg else ""))
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# history loading / per-SHA reduction
# ---------------------------------------------------------------------------

def load_history(path: str) -> Tuple[List[Dict], List[str]]:
    """Parse BENCH_history.jsonl into (schema-valid records, warnings).
    Pre-schema records and unparseable lines are skipped with a warning,
    never a crash — history files outlive schema changes."""
    records: List[Dict] = []
    warnings: List[str] = []
    if not os.path.exists(path):
        return records, [f"{path}: no history file"]
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                warnings.append(f"{path}:{i}: unparseable line skipped")
                continue
            if not isinstance(rec, dict) or "schema" not in rec:
                warnings.append(
                    f"{path}:{i}: pre-schema record "
                    f"(sha {rec.get('git_sha', '?')}) skipped")
                continue
            if not isinstance(rec.get("schema"), int) \
                    or rec["schema"] < 1 \
                    or not isinstance(rec.get("git_sha"), str) \
                    or not isinstance(rec.get("headline_walls"), dict):
                warnings.append(f"{path}:{i}: malformed record skipped")
                continue
            records.append(rec)
    return records, warnings


def _record_metrics(rec: Dict) -> Dict[str, float]:
    """Flat {metric: value} view of one record: headline walls plus the
    frontier block (already flat, prefixed for namespacing)."""
    out: Dict[str, float] = {}
    for k, v in rec.get("headline_walls", {}).items():
        if isinstance(v, (int, float)):
            out[k] = float(v)
    for k, v in rec.get("frontier", {}).items():
        if isinstance(v, (int, float)):
            out[f"frontier.{k}"] = float(v)
    for k, v in rec.get("chaos", {}).items():
        if isinstance(v, (int, float)):
            out[f"chaos.{k}"] = float(v)
    for k, v in rec.get("canvas", {}).items():
        if isinstance(v, (int, float)):
            out[f"canvas.{k}"] = float(v)
    return out


def reduce_by_sha(records: Sequence[Dict]
                  ) -> List[Tuple[str, Dict[str, float]]]:
    """File-ordered (sha, per-metric reduction over that SHA's records).

    Walls reduce by MIN — rep noise is one-sided slow, so the min is
    the achievable cost, the same treatment the benches apply to their
    own rep loops.  Absolute-only metrics (fractions, counts, signed
    overheads) reduce by MEDIAN instead: their noise is two-sided, and
    a min would latch the worst outlier (e.g. an ``overhead_frac`` of
    -0.2 from a CPU-contended run poisoning every later baseline).
    """
    order: List[str] = []
    reps: Dict[str, Dict[str, List[float]]] = {}
    for rec in records:
        sha = rec["git_sha"]
        if sha not in reps:
            order.append(sha)
            reps[sha] = {}
        for k, v in _record_metrics(rec).items():
            reps[sha].setdefault(k, []).append(v)
    out: List[Tuple[str, Dict[str, float]]] = []
    for sha in order:
        reduced = {}
        for k, vals in reps[sha].items():
            if rule_for(k).absolute_only:
                reduced[k] = float(np.median(vals))
            else:
                reduced[k] = min(vals)
        out.append((sha, reduced))
    return out


# ---------------------------------------------------------------------------
# analysis
# ---------------------------------------------------------------------------

def analyze(records: Sequence[Dict], window: int = 5,
            warnings: Sequence[str] = ()) -> SentinelReport:
    """Head (latest SHA) vs median-of-window baseline, rule per metric."""
    rep = SentinelReport(skipped=list(warnings))
    shas = reduce_by_sha(records)
    if not shas:
        rep.status = "no_data"
        return rep
    head_sha, head = shas[-1]
    rep.head_sha = head_sha
    base_window = shas[max(0, len(shas) - 1 - window):-1]
    if not base_window:
        rep.status = "no_baseline"
        return rep
    rep.baseline_shas = [s for s, _ in base_window]
    for metric in sorted(head):
        past = [m[metric] for _, m in base_window if metric in m]
        if not past:
            continue                      # metric is new at head: no gate
        baseline = float(np.median(past))
        rule = rule_for(metric)
        f = Finding(metric=metric, baseline=baseline, head=head[metric],
                    classification="ok", rule=rule)
        worse = f.delta if rule.lower_is_better else -f.delta
        if rule.absolute_only:
            if worse > rule.abs_floor:
                f.classification = "regression"
            elif worse < -rule.abs_floor:
                f.classification = "improvement"
        else:
            if worse > rule.abs_floor and \
                    worse > rule.rel_threshold * abs(baseline):
                f.classification = "regression"
            elif worse < -rule.abs_floor and \
                    worse < -rule.rel_threshold * abs(baseline):
                f.classification = "improvement"
        rep.findings.append(f)
    rep.status = "regression" if rep.regressions else "ok"
    return rep


def analyze_path(path: str, window: int = 5) -> SentinelReport:
    records, warnings = load_history(path)
    return analyze(records, window=window, warnings=warnings)


# ---------------------------------------------------------------------------
# self-test: the gate proves itself before gating anything
# ---------------------------------------------------------------------------

def _synthetic_head() -> Dict[str, float]:
    return {"stack.stack_kernel_wall_s": 0.065,
            "reuse.reuse_step_wall_s": 0.13,
            "obs.wall_enabled_s": 0.033,
            "obs.overhead_frac": 0.017}


def _mk_record(sha: str, walls: Dict[str, float]) -> Dict:
    return {"schema": SCHEMA_VERSION, "ts": "1970-01-01T00:00:00+0000",
            "git_sha": sha, "mode": "selftest", "panels": [],
            "headline_walls": dict(walls)}


def self_test(history_path: Optional[str] = None, window: int = 5
              ) -> Dict[str, bool]:
    """Build temp histories from the newest real record (synthetic
    fixture when the real history has no schema-valid records yet) and
    assert the three contractual behaviors:

    * a clean head (identical walls) passes,
    * an injected 2x slowdown on every wall is flagged as a regression
      with the metric named,
    * a head whose ``obs.overhead_frac`` moved by the known ±2%
      measurement band (0.04 absolute worst case) is NOT flagged,
    * an injected 2x MTTR (``chaos.mttr_steps`` 2 -> 4 while every wall
      holds) is flagged BY NAME — the chaos recovery bound proves
      itself before gating,
    * an injected static-step canvas write (``canvas.static_canvas_bytes``
      0 -> one full changed-step's bytes, i.e. a regression re-enabling
      canvas writes on all-static steps, while every wall holds) is
      flagged BY NAME — the zero-copy contract proves itself before
      gating.
    """
    walls: Dict[str, float] = {}
    if history_path:
        records, _ = load_history(history_path)
        shas = reduce_by_sha(records)
        if shas:
            walls = {k: v for k, v in shas[-1][1].items()
                     if not rule_for(k).absolute_only}
            walls["obs.overhead_frac"] = \
                shas[-1][1].get("obs.overhead_frac", 0.017)
    if not walls:
        walls = _synthetic_head()

    base = [_mk_record(f"base{i:04d}", walls) for i in range(3)]
    clean = base + [_mk_record("head-clean", walls)]
    slow = base + [_mk_record("head-slow", {
        k: (v * 2.0 if not rule_for(k).absolute_only else v)
        for k, v in walls.items()})]
    noisy = base + [_mk_record("head-noisy", {
        k: (v + 0.04 if k == "obs.overhead_frac" else v)
        for k, v in walls.items()})]
    chaos_walls = dict(walls, **{"chaos.mttr_steps": 2.0,
                                 "chaos.uncovered_frac_p99": 0.0})
    chaos_base = [_mk_record(f"cbase{i:04d}", chaos_walls)
                  for i in range(3)]
    mttr = chaos_base + [_mk_record("head-mttr", dict(
        chaos_walls, **{"chaos.mttr_steps": 4.0}))]
    canvas_walls = dict(walls, **{"canvas.canvas_bytes_per_step": 1.05e5,
                                  "canvas.static_canvas_bytes": 0.0})
    canvas_base = [_mk_record(f"vbase{i:04d}", canvas_walls)
                   for i in range(3)]
    canvas = canvas_base + [_mk_record("head-canvas", dict(
        canvas_walls, **{"canvas.static_canvas_bytes": 1.05e5}))]

    def run_case(recs: List[Dict]) -> SentinelReport:
        with tempfile.NamedTemporaryFile("w", suffix=".jsonl",
                                         delete=False) as f:
            tmp = f.name
            for r in recs:
                f.write(json.dumps(r) + "\n")
        try:
            return analyze_path(tmp, window=window)
        finally:
            os.unlink(tmp)

    rep_clean = run_case(clean)
    rep_slow = run_case(slow)
    rep_noisy = run_case(noisy)
    rep_mttr = run_case(mttr)
    rep_canvas = run_case(canvas)

    assert not rep_clean.has_regression, \
        f"sentinel self-test: clean history flagged\n{rep_clean.render()}"
    assert rep_slow.has_regression, \
        f"sentinel self-test: 2x slowdown NOT flagged\n{rep_slow.render()}"
    assert all("wall" in f.metric or f.metric.endswith("_s")
               for f in rep_slow.regressions) and rep_slow.regressions, \
        "sentinel self-test: regression must name the slowed metric"
    assert not rep_noisy.has_regression, \
        f"sentinel self-test: ±2% obs-overhead noise band flagged\n" \
        f"{rep_noisy.render()}"
    mttr_flagged = [f.metric for f in rep_mttr.regressions]
    assert mttr_flagged == ["chaos.mttr_steps"], \
        f"sentinel self-test: 2x MTTR must be flagged by name (and " \
        f"nothing else), got {mttr_flagged}\n{rep_mttr.render()}"
    canvas_flagged = [f.metric for f in rep_canvas.regressions]
    assert canvas_flagged == ["canvas.static_canvas_bytes"], \
        f"sentinel self-test: static-step canvas writes must be flagged " \
        f"by name (and nothing else), got {canvas_flagged}\n" \
        f"{rep_canvas.render()}"
    return {"clean_pass": not rep_clean.has_regression,
            "slowdown_flagged": rep_slow.has_regression,
            "noise_band_pass": not rep_noisy.has_regression,
            "mttr_flagged": rep_mttr.has_regression,
            "static_canvas_flagged": rep_canvas.has_regression,
            "flagged_metrics": [f.metric for f in rep_slow.regressions]}
