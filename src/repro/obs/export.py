"""Chrome/Perfetto ``trace_event`` export of the recorded spans.

``chrome_trace(path)`` writes the standard JSON object format
(https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU):
one ``"X"`` complete event per finished span (pid/tid/ts/dur in
microseconds, args passed through), plus ``"M"`` metadata naming the
process and every thread/track.  Load the file in ``chrome://tracing``
or https://ui.perfetto.dev — host threads and the async ``device``
track render as separate rows, so the pipeline's host-plan/device
overlap is directly visible.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.obs import trace


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    item = getattr(v, "item", None)       # numpy scalars
    if item is not None:
        try:
            return item()
        except Exception:
            pass
    return str(v)


def trace_events() -> List[Dict]:
    """The ``traceEvents`` list: metadata first, then every span as an
    ``"X"`` complete event with ts rebased to the earliest span."""
    evs = trace.events()
    pid = os.getpid()
    out: List[Dict] = [{"ph": "M", "pid": pid, "tid": 0,
                        "name": "process_name",
                        "args": {"name": "repro-fleet"}}]
    for tid, name in sorted(trace.thread_names().items()):
        out.append({"ph": "M", "pid": pid, "tid": tid,
                    "name": "thread_name", "args": {"name": name}})
    t_base = min((e[2] for e in evs), default=0)
    for name, tid, t0, dur, args in evs:
        out.append({"ph": "X", "cat": "repro", "pid": pid, "tid": tid,
                    "ts": (t0 - t_base) / 1e3, "dur": dur / 1e3,
                    "name": name,
                    "args": {k: _jsonable(v) for k, v in args.items()}})
    return out


def chrome_trace(path: Optional[str] = None) -> Dict:
    """Build (and optionally write) the Chrome-trace JSON document."""
    doc = {"traceEvents": trace_events(), "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w") as f:
            json.dump(doc, f)
    return doc
