"""SLO panels: per-step and fleet-level service-objective reports.

``StepReport`` snapshots one delta-gated fleet step (wall, tile
accounting, dispatch structure); ``FleetSLOReport`` aggregates a run —
p50/p99 response delay and per-part p99s (reusing ``TransportStats``'
part accounting), deadline hit rate, bytes shed by composition,
accuracy floor, changed-tile fraction, activation-cache traffic — into
one serializable panel that ``benchmarks/run.py`` merges into
``BENCH_kernels.json``.  This is the measurement substrate for ROADMAP
item 5: every future PR can report its effect as a point on this panel
instead of a one-off print.

Inputs arrive duck-typed (``TransportStats``, ``ReuseStats`` /
``ShardedReuseStats``, ``PackedActivationCache``) — this module never
imports the subsystems it summarizes, so everything in ``repro`` may
import it freely.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclass
class StepReport:
    """One fleet step's accounting."""
    step: int
    wall_s: float
    total_tiles: int
    changed_tiles: int          # raw gate-changed
    computed_tiles: int         # post-dilation compute set
    launched_tiles: int         # padded launch rows (honest GEMM work)
    cold: bool
    dispatches: Dict[str, int] = field(default_factory=dict)
    # bytes scattered into the persistent head canvas (0 on all-static
    # steps — the zero-copy contract the sentinel watches)
    canvas_bytes: int = 0

    @property
    def changed_fraction(self) -> float:
        return self.changed_tiles / max(self.total_tiles, 1)

    @property
    def compute_fraction(self) -> float:
        return self.computed_tiles / max(self.total_tiles, 1)

    @classmethod
    def from_reuse(cls, step: int, wall_s: float, counts,
                   stats) -> "StepReport":
        """Build from ``fleet_reuse_step`` / ``sharded_fleet_step``
        outputs (stats duck-typed over ReuseStats/ShardedReuseStats)."""
        cold = bool(getattr(stats, "cold", False)) \
            or bool(getattr(stats, "cold_shards", 0))
        return cls(step=step, wall_s=float(wall_s),
                   total_tiles=int(stats.total_tiles),
                   changed_tiles=int(stats.raw_changed),
                   computed_tiles=int(stats.computed),
                   launched_tiles=int(stats.launched),
                   cold=cold, dispatches=dict(counts),
                   canvas_bytes=int(getattr(stats, "canvas_bytes", 0)))

    def to_dict(self) -> Dict:
        return {"step": self.step, "wall_s": self.wall_s,
                "total_tiles": self.total_tiles,
                "changed_tiles": self.changed_tiles,
                "computed_tiles": self.computed_tiles,
                "launched_tiles": self.launched_tiles,
                "changed_fraction": self.changed_fraction,
                "compute_fraction": self.compute_fraction,
                "cold": self.cold, "dispatches": self.dispatches,
                "canvas_bytes": self.canvas_bytes}


@dataclass
class FleetSLOReport:
    """Run-level SLO panel."""
    steps: List[StepReport] = field(default_factory=list)
    # response delay (from the transport simulation)
    p50_delay_s: float = 0.0
    p99_delay_s: float = 0.0
    mean_delay_s: float = 0.0
    part_p99_s: Dict[str, float] = field(default_factory=dict)
    # deadline / straggler accounting
    deadline_hits: int = 0
    deadline_hit_rate: float = 0.0
    straggler_frac: float = 0.0
    # network bytes
    bytes_total: float = 0.0
    bytes_base: float = 0.0
    shed_bytes: float = 0.0
    shed_halo_bytes: float = 0.0
    shed_body_bytes: float = 0.0
    quality_min: float = 1.0
    # accuracy
    accuracy_floor: float = 1.0
    accuracy_mean: float = 1.0
    # compute
    changed_tile_fraction: float = 0.0
    compute_tile_fraction: float = 0.0
    step_wall_p50_s: float = 0.0
    step_wall_p99_s: float = 0.0
    # persistent-canvas traffic: mean bytes scattered per step, and the
    # bytes-written-vs-changed-fraction ratio (bytes per changed tile —
    # flat when writes scale with change, inflated when static tiles
    # are being rewritten)
    canvas_bytes_per_step: float = 0.0
    canvas_bytes_per_changed_tile: float = 0.0
    cache: Dict[str, float] = field(default_factory=dict)
    # degraded-mode coverage (fault failover): fraction of ground-truth
    # appearances NO surviving camera's mask covers — 0.0 in healthy
    # operation, explicitly nonzero when failover could not reassign a
    # dead camera's coverage (never silently zero: the chaos harness
    # feeds the per-step series in)
    uncovered_frac_mean: float = 0.0
    uncovered_frac_p99: float = 0.0

    @classmethod
    def build(cls, steps: Sequence[StepReport] = (),
              transport=None, accuracy_floor: float = 1.0,
              accuracy_mean: float = 1.0, cache=None,
              n_windows: int = 0,
              uncovered_frac: Sequence[float] = ()) -> "FleetSLOReport":
        """Aggregate a run.  ``transport`` is a duck-typed
        ``TransportStats`` (or None); ``cache`` a duck-typed
        ``PackedActivationCache``/``ShardedActivationCache``;
        ``n_windows`` the number of deadline-scoped release windows
        (segments), for the hit-rate denominator."""
        rep = cls(steps=list(steps), accuracy_floor=float(accuracy_floor),
                  accuracy_mean=float(accuracy_mean))
        if transport is not None:
            rep.p50_delay_s = float(transport.p50_s)
            rep.p99_delay_s = float(transport.p99_s)
            rep.mean_delay_s = float(transport.mean_s)
            rep.part_p99_s = {k: float(transport.part_p99(k))
                              for k in transport.parts}
            rep.deadline_hits = int(transport.deadline_hits)
            rep.deadline_hit_rate = (transport.deadline_hits / n_windows
                                     if n_windows else 0.0)
            rep.straggler_frac = float(transport.straggler_frac)
            rep.bytes_total = float(transport.bytes_total)
            rep.bytes_base = float(transport.bytes_base)
            rep.shed_bytes = float(transport.shed_bytes)
            rep.shed_halo_bytes = float(transport.shed_halo_bytes)
            rep.shed_body_bytes = float(transport.shed_body_bytes)
            rep.quality_min = float(transport.quality_min)
        if rep.steps:
            total = sum(s.total_tiles for s in rep.steps)
            rep.changed_tile_fraction = \
                sum(s.changed_tiles for s in rep.steps) / max(total, 1)
            rep.compute_tile_fraction = \
                sum(s.computed_tiles for s in rep.steps) / max(total, 1)
            walls = np.asarray([s.wall_s for s in rep.steps])
            rep.step_wall_p50_s = float(np.percentile(walls, 50))
            rep.step_wall_p99_s = float(np.percentile(walls, 99))
            cbytes = sum(s.canvas_bytes for s in rep.steps)
            rep.canvas_bytes_per_step = cbytes / len(rep.steps)
            changed = sum(s.changed_tiles for s in rep.steps)
            rep.canvas_bytes_per_changed_tile = cbytes / max(changed, 1)
        if len(uncovered_frac):
            uf = np.asarray(uncovered_frac, np.float64)
            rep.uncovered_frac_mean = float(uf.mean())
            rep.uncovered_frac_p99 = float(np.percentile(uf, 99))
        if cache is not None:
            rep.cache = {
                "steps": int(cache.steps),
                "cold_steps": int(cache.cold_steps),
                "invalidations": int(cache.invalidations),
                "launched_tiles": int(cache.launched_tiles),
                "total_tiles": int(cache.total_tiles),
                "compute_fraction": float(cache.compute_fraction),
            }
        return rep

    def to_dict(self) -> Dict:
        d = {k: getattr(self, k) for k in (
            "p50_delay_s", "p99_delay_s", "mean_delay_s", "part_p99_s",
            "deadline_hits", "deadline_hit_rate", "straggler_frac",
            "bytes_total", "bytes_base", "shed_bytes", "shed_halo_bytes",
            "shed_body_bytes", "quality_min", "accuracy_floor",
            "accuracy_mean", "changed_tile_fraction",
            "compute_tile_fraction", "step_wall_p50_s", "step_wall_p99_s",
            "canvas_bytes_per_step", "canvas_bytes_per_changed_tile",
            "cache", "uncovered_frac_mean", "uncovered_frac_p99")}
        d["n_steps"] = len(self.steps)
        d["steps"] = [s.to_dict() for s in self.steps]
        return d
