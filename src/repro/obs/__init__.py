"""Fleet observability: tracing, typed metrics, SLO panels.

Three pieces, all default-OFF and zero-dispatch by construction (host
timestamps around already-existing sync points only — no
``block_until_ready`` is ever added to a hot path):

* ``obs.trace`` — monotonic-clock span API (``with
  obs.trace.span("gate", step=t): ...``), thread/contextvar-safe like
  ``ops.count_kernels``; async begin/end handles put in-flight device
  work on its own timeline track.  Export with
  ``obs.export.chrome_trace(path)`` and open in chrome://tracing or
  Perfetto.
* ``obs.metrics`` — typed counters/gauges/histograms with labels.
  ``kernel_dispatches`` mirrors ``ops.KERNEL_COUNTS`` bit-for-bit;
  the canonical ``KERNEL_NAMES`` frozenset makes typo'd counter names
  fail loudly.
* ``obs.slo`` — ``StepReport``/``FleetSLOReport`` panels
  (p50/p99 delay, deadline hit rate, bytes shed, accuracy floor,
  changed-tile fraction) that ``benchmarks/run.py`` merges into
  ``BENCH_kernels.json``.

On top of the panels sit the heavy-traffic harness (``obs.loadgen`` —
SLO frontier sweeps over fleet scale x congestion x traffic profile x
serve rate, driving the production runtimes with zero added dispatches)
and the CI gate that watches the resulting history stream
(``obs.sentinel`` — git-SHA-aware regression detection with
noise-robust min-of-reps / median-of-window baselines).

Switch it on with ``obs.configure(enabled=True)`` (or scoped:
``with obs.enabled(): ...``); ``configure(reset=True)`` clears the
recorded spans and metric values.
"""
from __future__ import annotations

import contextlib

from repro.obs import (export, loadgen, metrics, sentinel,  # noqa: F401
                       slo, state, trace)


def configure(enabled=None, reset: bool = False) -> bool:
    """Set the global observability switch and/or reset recorded data.

    ``configure(enabled=True)`` turns span recording and metric updates
    on (default off — tier-1 tests and production paths pay one boolean
    check per call site).  ``configure(reset=True)`` clears the span
    buffer and zeroes every registered metric (registrations survive).
    Returns the resulting enabled state."""
    if enabled is not None:
        state.enabled = bool(enabled)
    if reset:
        trace.clear()
        metrics.REGISTRY.reset()
    return state.enabled


def is_enabled() -> bool:
    return state.enabled


@contextlib.contextmanager
def enabled(flag: bool = True):
    """Scoped enable/disable: ``with obs.enabled(): run_step()``."""
    prev = state.enabled
    state.enabled = bool(flag)
    try:
        yield
    finally:
        state.enabled = prev
