"""Batched serving engine with RoI-sparsified prefill.

The CrossRoI insight applied to transformer serving: when a request's
prompt is a multi-camera patch stream (VLM) or any multi-stream ingestion
with cross-stream redundancy, the offline set-cover mask gives a keep-list.
The engine packs kept tokens into a dense prefix (kernels/ops.pack_tokens),
prefills ONLY the packed tokens (compute drops ~proportionally to the
mask), and decodes against the packed KV cache — attention stays correct
because positions travel with the tokens (RoPE is applied at original
positions; causality follows original order).

Plain text serving works through the same engine with roi_sparsity=False.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.kernels import ops as kops
from repro.models import model as M
from repro.models.dist import DistContext


@dataclass
class Request:
    rid: int
    tokens: Optional[np.ndarray] = None          # (S,) int32 prompt
    patches: Optional[np.ndarray] = None         # (S_img, D) VLM stream
    keep: Optional[np.ndarray] = None            # (S,) bool RoI keep-list
    max_new_tokens: int = 16


@dataclass
class RoIPrefillResult:
    logits: jax.Array
    caches: Any
    n_kept: int
    n_total: int

    @property
    def compute_fraction(self) -> float:
        return self.n_kept / max(self.n_total, 1)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, scfg: ServeConfig, params: Dict,
                 dist: Optional[DistContext] = None):
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self.dist = dist
        self._decode = jax.jit(
            lambda p, t, c, pos: M.decode_step(p, cfg, t, c, pos, dist=dist))
        self._prefill = jax.jit(
            lambda p, b, c, pos, last=None: M.prefill(
                p, cfg, b, c, dist=dist, positions=pos, last_index=last))

    # -- plain prefill -----------------------------------------------------
    def prefill(self, batch: Dict, max_seq: Optional[int] = None):
        B = next(iter(batch.values())).shape[0]
        max_seq = max_seq or self.scfg.max_seq
        caches = M.init_cache(self.cfg, B, max_seq)
        return self._prefill(self.params, batch, caches, None)

    # -- RoI-sparsified prefill ---------------------------------------------
    def roi_prefill(self, tokens: jax.Array, keep: jax.Array,
                    block: int = 128) -> RoIPrefillResult:
        """tokens: (S,) or (S, D) stream; keep: (S,) bool.  Packs kept
        tokens, prefills the packed prefix with original positions."""
        S = tokens.shape[0]
        packed, positions, n_kept = kops.pack_tokens(tokens, keep, block)
        Sp = packed.shape[0]
        # positions carry PAD_POS on padding rows: padded keys are never
        # attended (pos_q >= pos_k fails), padded queries produce garbage
        # rows that are discarded, and decode masks cache slots >= n_kept.
        if packed.ndim == 1:
            batch = {"tokens": packed[None]}
        else:
            # patch stream: embed via the VLM frontend path
            batch = {"tokens": jnp.zeros((1, 0), jnp.int32),
                     "patches": packed[None]}
        caches = M.init_cache(self.cfg, 1, max(Sp, 1))
        logits, caches = self._prefill(self.params, batch, caches,
                                       positions[None], n_kept - 1)
        return RoIPrefillResult(logits, caches, int(n_kept), S)

    # -- decode -------------------------------------------------------------
    def decode_tokens(self, caches, first_token: jax.Array, start_pos: int,
                      n_steps: int) -> Tuple[np.ndarray, Any]:
        B = first_token.shape[0]
        out = []
        tok = first_token.reshape(B, 1)
        for i in range(n_steps):
            logits, caches = self._decode(self.params, tok, caches,
                                          start_pos + i)
            tok = jnp.argmax(logits[:, -1], axis=-1).reshape(B, 1)
            out.append(np.asarray(tok))
        return np.concatenate(out, axis=1), caches

    # -- batched request driver ----------------------------------------------
    def serve(self, requests: List[Request], greedy_steps: int = 8
              ) -> Dict[int, np.ndarray]:
        """Simple batched serving: group requests to max_batch, prefill
        each group (RoI-packed when a keep-list is present), then decode
        greedily.  Returns {rid: generated tokens}."""
        results: Dict[int, np.ndarray] = {}
        group: List[Request] = []

        def flush():
            if not group:
                return
            for r in group:   # per-request packing (ragged keep-lists)
                if r.keep is not None and self.scfg.roi_sparsity:
                    res = self.roi_prefill(jnp.asarray(r.tokens),
                                           jnp.asarray(r.keep))
                    first = jnp.argmax(res.logits[:, -1], -1)
                    toks, _ = self.decode_tokens(
                        res.caches, first, res.n_kept,
                        min(r.max_new_tokens, greedy_steps))
                else:
                    batch = {"tokens": jnp.asarray(r.tokens)[None]}
                    logits, caches = self.prefill(
                        batch, max_seq=len(r.tokens) + r.max_new_tokens)
                    first = jnp.argmax(logits[:, -1], -1)
                    toks, _ = self.decode_tokens(
                        caches, first, len(r.tokens),
                        min(r.max_new_tokens, greedy_steps))
                results[r.rid] = toks[0]
            group.clear()

        for r in requests:
            group.append(r)
            if len(group) >= self.scfg.max_batch:
                flush()
        flush()
        return results
