"""Batched serving engine with RoI-sparsified prefill and batched decode.

The CrossRoI insight applied to transformer serving: when a request's
prompt is a multi-camera patch stream (VLM) or any multi-stream ingestion
with cross-stream redundancy, the offline set-cover mask gives a keep-list.
The engine packs kept tokens into a dense prefix (kernels/ops.pack_tokens),
prefills ONLY the packed tokens (compute drops ~proportionally to the
mask), and decodes against the packed KV cache — attention stays correct
because positions travel with the tokens (RoPE is applied at original
positions; causality follows original order).

Decode is batched across the request group: prefills stay per-request
(keep-lists are ragged), but every request's caches are allocated at the
group-common ``max_seq``, stacked into one pytree, and each greedy step is
ONE jit'd vmapped dispatch for the whole group instead of a Python loop of
per-request dispatches.  Per-request start positions ride along as a
vmapped scalar, so RoI-packed (start = n_kept) and dense (start = S)
requests share the same batch.

Plain text serving works through the same engine with roi_sparsity=False.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.kernels import ops as kops
from repro.models import model as M
from repro.models.dist import DistContext
from repro.obs import metrics as obs_metrics, trace as obs_trace


@dataclass
class Request:
    rid: int
    tokens: Optional[np.ndarray] = None          # (S,) int32 prompt
    patches: Optional[np.ndarray] = None         # (S_img, D) VLM stream
    keep: Optional[np.ndarray] = None            # (S,) bool RoI keep-list
    max_new_tokens: int = 16
    # deadline-batched serving (serve_deadline): which camera group the
    # request belongs to, and when it arrived at the server
    group: Optional[int] = None
    arrival_s: float = 0.0


@dataclass
class ServeReport:
    """Accounting from ``serve_deadline``: how request groups formed."""
    complete_flushes: int = 0        # group reached its expected size
    deadline_flushes: int = 0        # released early by the deadline
    straggler_requests: int = 0      # arrived after their group released
    release_s: Dict[int, float] = field(default_factory=dict)  # rid -> t

    def wait_s(self, req: "Request") -> float:
        """Batching delay this request paid in the group former."""
        return self.release_s[req.rid] - req.arrival_s


@dataclass
class RoIPrefillResult:
    logits: jax.Array
    caches: Any
    n_kept: int
    n_total: int

    @property
    def compute_fraction(self) -> float:
        return self.n_kept / max(self.n_total, 1)


def _round_up(x: int, block: int) -> int:
    return -(-x // block) * block


def ring_donate_argnums(*argnums: int) -> Tuple[int, ...]:
    """The donation idiom shared by every persistent device-resident ring
    in the system (the engine's group-cache ring, the detector's head-map
    canvas): donate the named positional args so on hardware the update
    is in-place (O(written) traffic, not O(buffer)), but donate NOTHING
    on CPU — the CPU backend ignores donation and warns, and tests read
    pre-update buffers the donation would have poisoned."""
    return () if jax.default_backend() == "cpu" else tuple(argnums)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, scfg: ServeConfig, params: Dict,
                 dist: Optional[DistContext] = None):
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self.dist = dist
        self._decode = jax.jit(
            lambda p, t, c, pos: M.decode_step(p, cfg, t, c, pos, dist=dist))
        self._prefill = jax.jit(
            lambda p, b, c, pos, last=None: M.prefill(
                p, cfg, b, c, dist=dist, positions=pos, last_index=last))
        # group decode: vmap over stacked (per-request B=1) caches, tokens,
        # and scalar positions -> one dispatch per step for the whole group
        self._decode_group = jax.jit(
            lambda p, t, c, pos: jax.vmap(
                lambda tb, cb, pb: M.decode_step(p, cfg, tb, cb, pb,
                                                 dist=dist),
                in_axes=(0, 0, 0))(t, c, pos))
        # persistent group cache ring: one stacked (G, ...) cache pytree
        # reused across flushes, so serve() never jnp.stack's per-request
        # caches.  Stale slot contents are harmless: decode only attends
        # cache rows at positions written by THIS request's prefill/decode
        # chain (rows past the current position are masked).  Slot writes
        # go through one jit'd dynamic-update with the ring donated, so on
        # hardware the update is in-place (O(slot) traffic per request,
        # not O(ring)); CPU ignores donation and falls back to a copy.
        donate = ring_donate_argnums(0)
        self._ring_write = jax.jit(
            lambda ring, slot, gi: jax.tree.map(
                lambda full, s: jax.lax.dynamic_update_index_in_dim(
                    full, s.astype(full.dtype), gi, 0), ring, slot),
            donate_argnums=donate)
        self._ring = None
        self._ring_sig: Optional[Tuple[int, int]] = None
        self.ring_rebuilds = 0          # ring (re)allocations — steady
        #                                 state stays flat across flushes
        self.cache_stack_count = 0      # per-flush jnp.stack's (legacy
        #                                 path only; serve() must not bump)

    # -- plain prefill -----------------------------------------------------
    def prefill(self, batch: Dict, max_seq: Optional[int] = None,
                caches=None):
        """``caches`` (optional) supplies a preallocated cache pytree —
        serve() passes a slot of the persistent group ring instead of
        allocating per request."""
        B = next(iter(batch.values())).shape[0]
        max_seq = max_seq or self.scfg.max_seq
        if caches is None:
            caches = M.init_cache(self.cfg, B, max_seq)
        return self._prefill(self.params, batch, caches, None)

    # -- RoI-sparsified prefill ---------------------------------------------
    def roi_prefill(self, tokens: jax.Array, keep: jax.Array,
                    block: int = 128,
                    max_seq: Optional[int] = None,
                    caches=None) -> RoIPrefillResult:
        """tokens: (S,) or (S, D) stream; keep: (S,) bool.  Packs kept
        tokens, prefills the packed prefix with original positions.
        ``max_seq`` sizes the KV cache (>= packed length; decode masks
        slots past the current position, so oversized caches are safe —
        the group driver uses this to give every request the same cache
        shape)."""
        S = tokens.shape[0]
        packed, positions, n_kept = kops.pack_tokens(tokens, keep, block)
        Sp = packed.shape[0]
        # positions carry PAD_POS on padding rows: padded keys are never
        # attended (pos_q >= pos_k fails), padded queries produce garbage
        # rows that are discarded, and decode masks cache slots >= n_kept.
        if packed.ndim == 1:
            batch = {"tokens": packed[None]}
        else:
            # patch stream: embed via the VLM frontend path
            batch = {"tokens": jnp.zeros((1, 0), jnp.int32),
                     "patches": packed[None]}
        if caches is None:
            caches = M.init_cache(self.cfg, 1, max(max_seq or Sp, Sp, 1))
        logits, caches = self._prefill(self.params, batch, caches,
                                       positions[None], n_kept - 1)
        return RoIPrefillResult(logits, caches, int(n_kept), S)

    # -- decode -------------------------------------------------------------
    def decode_tokens(self, caches, first_token: jax.Array, start_pos: int,
                      n_steps: int) -> Tuple[np.ndarray, Any]:
        B = first_token.shape[0]
        out = []
        tok = first_token.reshape(B, 1)
        for i in range(n_steps):
            logits, caches = self._decode(self.params, tok, caches,
                                          start_pos + i)
            tok = jnp.argmax(logits[:, -1], axis=-1).reshape(B, 1)
            out.append(np.asarray(tok))
        return np.concatenate(out, axis=1), caches

    def decode_tokens_group(self, caches_list: List[Any],
                            first_tokens: List[jax.Array],
                            start_pos: List[int],
                            n_steps: int) -> Tuple[np.ndarray, Any]:
        """Greedy-decode G same-cache-shape requests together.

        caches_list: per-request cache pytrees (B=1, identical shapes —
        allocate prefills at a group-common max_seq).  Returns (G, n_steps)
        tokens; one jit'd dispatch per step serves the whole group.

        Legacy entry point: stacks the per-request caches on every call
        (counted in ``cache_stack_count``).  ``serve`` avoids this by
        prefilling straight into the persistent group ring."""
        self.cache_stack_count += 1
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches_list)
        return self._decode_stacked(caches, first_tokens, start_pos,
                                    n_steps)

    def _decode_stacked(self, caches, first_tokens, start_pos,
                        n_steps: int) -> Tuple[np.ndarray, Any]:
        tok = jnp.stack([jnp.asarray(t).reshape(1, 1)
                         for t in first_tokens])            # (G, 1, 1)
        pos0 = jnp.asarray(start_pos, jnp.int32)            # (G,)
        out = []
        for i in range(n_steps):
            logits, caches = self._decode_group(self.params, tok, caches,
                                                pos0 + i)
            tok = jnp.argmax(logits[:, :, -1], axis=-1)[..., None]  # (G,1,1)
            out.append(np.asarray(tok[:, :, 0]))
        return np.concatenate(out, axis=1), caches

    # -- persistent group cache ring ------------------------------------------
    def _ensure_ring(self, G: int, max_seq: int):
        """(Re)allocate the stacked group cache only when the flush needs a
        wider group or a longer sequence than the ring already holds —
        steady-state flushes reuse the same buffers with zero stacking."""
        sig = (G, max_seq)
        if (self._ring is None or self._ring_sig[0] != G
                or self._ring_sig[1] < max_seq):
            slot = M.init_cache(self.cfg, 1, max_seq, abstract=True)
            self._ring = jax.tree.map(
                lambda s: jnp.zeros((G,) + s.shape, s.dtype), slot)
            self._ring_sig = sig
            self.ring_rebuilds += 1
        return self._ring

    # -- batched request driver ----------------------------------------------
    def serve(self, requests: List[Request], greedy_steps: int = 8
              ) -> Dict[int, np.ndarray]:
        """Batched serving: group requests to max_batch, prefill each
        request (RoI-packed when a keep-list is present — keep-lists are
        ragged, so packing stays per-request) INTO a slot of the persistent
        group cache ring, then greedy-decode the whole group in lockstep
        with one vmapped dispatch per step.  The ring survives across
        flushes: no per-flush cache allocation and no per-request
        ``jnp.stack`` — ``cache_stack_count`` stays flat and
        ``ring_rebuilds`` only moves when the group geometry grows.
        Returns {rid: generated tokens}."""
        results: Dict[int, np.ndarray] = {}
        group: List[Request] = []
        with obs_trace.span("serve", requests=len(requests)):
            obs_metrics.SERVE_EVENTS.inc(len(requests), event="request")
            for r in requests:
                group.append(r)
                if len(group) >= self.scfg.max_batch:
                    self._flush_group(group, greedy_steps, results)
                    group = []
            self._flush_group(group, greedy_steps, results)
        return results

    def _flush_group(self, group: List[Request], greedy_steps: int,
                     results: Dict[int, np.ndarray]) -> None:
        """Prefill every request of ``group`` into the persistent ring and
        greedy-decode the batch in lockstep (shared by ``serve`` and the
        deadline former)."""
        if not group:
            return
        pack_block = 128
        steps = [min(r.max_new_tokens, greedy_steps) for r in group]
        gsteps = max(steps)
        # group-common cache length: every request's packed/dense
        # prompt plus the GROUP's decode step count fits (lockstep
        # decode runs gsteps for everyone; a shorter per-request
        # budget must not let KV writes clamp onto the cache end)
        need = []
        for r in group:
            if r.keep is not None and self.scfg.roi_sparsity:
                need.append(_round_up(len(r.tokens), pack_block) + gsteps)
            else:
                need.append(len(r.tokens) + gsteps)
        with obs_trace.span("serve_flush", batch=len(group),
                            decode_steps=gsteps):
            ring = self._ensure_ring(len(group), max(need))

            firsts, starts = [], []
            for gi, r in enumerate(group):   # ragged per-request packing
                slot = jax.tree.map(lambda x: x[gi], ring)
                if r.keep is not None and self.scfg.roi_sparsity:
                    res = self.roi_prefill(jnp.asarray(r.tokens),
                                           jnp.asarray(r.keep),
                                           block=pack_block, caches=slot)
                    new_slot = res.caches
                    firsts.append(jnp.argmax(res.logits[:, -1], -1))
                    starts.append(res.n_kept)
                else:
                    batch = {"tokens": jnp.asarray(r.tokens)[None]}
                    logits, new_slot = self.prefill(batch, caches=slot)
                    firsts.append(jnp.argmax(logits[:, -1], -1))
                    starts.append(len(r.tokens))
                ring = self._ring_write(ring, new_slot, gi)
            toks, ring = self._decode_stacked(ring, firsts, starts, gsteps)
        self._ring = ring                 # keep buffers for next flush
        for gi, (r, ns) in enumerate(zip(group, steps)):
            results[r.rid] = toks[gi, :ns]

    # -- deadline-based group forming ------------------------------------------
    def serve_deadline(self, requests: List[Request],
                       group_sizes: Dict[int, int],
                       deadline_s: float, greedy_steps: int = 8
                       ) -> Tuple[Dict[int, np.ndarray], ServeReport]:
        """Deadline-based group former over a timestamped request stream —
        the ``repro.net.batcher`` release policy at the serving layer.

        Requests carry ``(group, arrival_s)``; a group flushes the moment
        its ``group_sizes[gid]`` members are pending, or when its oldest
        pending member has waited ``deadline_s`` (measured against the
        stream clock, which advances with each arrival).  Members that
        show up after their batch left are stragglers: they ride the
        group's next flush and are counted in the report.  Each flush is
        one lockstep batch through the persistent cache ring, identical
        to ``serve``'s."""
        results: Dict[int, np.ndarray] = {}
        report = ServeReport()
        pending: Dict[int, List[Request]] = {}
        # after a deadline flush releases k of a group's N members, the
        # next (N - k) arrivals of that group are the stragglers of THAT
        # cycle — members beyond them belong to the next batch and are
        # not late.  A complete flush clears the quota.
        late_quota: Dict[int, int] = {}

        def flush(gid: int, now: float, by_deadline: bool) -> None:
            members = pending.pop(gid, [])
            if not members:
                return
            obs_metrics.BACKLOG_DEPTH.observe(len(members))
            obs_metrics.SERVE_EVENTS.inc(
                1, event="deadline_flush" if by_deadline
                else "complete_flush")
            self._flush_group(members, greedy_steps, results)
            for r in members:
                report.release_s[r.rid] = now
            if by_deadline:
                report.deadline_flushes += 1
                late_quota[gid] = (group_sizes.get(gid,
                                                   self.scfg.max_batch)
                                   - len(members))
            else:
                report.complete_flushes += 1
                late_quota[gid] = 0

        with obs_trace.span("serve_deadline", requests=len(requests)):
            obs_metrics.SERVE_EVENTS.inc(len(requests), event="request")
            for r in sorted(requests, key=lambda r: r.arrival_s):
                now = r.arrival_s
                # deadlines that expired while the stream was quiet
                for gid in list(pending):
                    oldest = min(m.arrival_s for m in pending[gid])
                    if now - oldest >= deadline_s:
                        flush(gid, oldest + deadline_s, by_deadline=True)
                gid = r.group if r.group is not None else -1
                if late_quota.get(gid, 0) > 0:
                    report.straggler_requests += 1
                    obs_metrics.SERVE_EVENTS.inc(1,
                                                 event="straggler_request")
                    late_quota[gid] -= 1
                pending.setdefault(gid, []).append(r)
                if len(pending[gid]) >= group_sizes.get(
                        gid, self.scfg.max_batch):
                    flush(gid, now, by_deadline=False)
            for gid in list(pending):
                oldest = min(m.arrival_s for m in pending[gid])
                flush(gid, oldest + deadline_s, by_deadline=True)
        return results, report
