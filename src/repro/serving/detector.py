"""RoI-YOLO-lite: a small conv detector running on active tiles only.

The online-phase server model (paper §4.4), with the packed representation
persistent across the whole stack AND the whole launch chain fused to a
constant number of dispatches: layer 0 is the fused gather+conv+relu
entry kernel (``roi_conv_entry`` reads haloed windows straight from the
stacked frames — the *one* gather — and emits coalesced rim halos),
layers 1..N-1 run inside ONE ``roi_conv_stack`` megakernel (grid over
(layer, tile), double-buffered activations/rims, per-layer weight
prefetch), and a *single* scatter materializes the full-frame head maps.
Every RoI forward — one camera, one group, or the WHOLE FLEET via
``superlaunch_forward`` — is exactly 3 dispatches (2 for a 1-layer
stack), independent of camera count, group count and layer count.  The
old SBNet formulation paid a full-frame scatter + HBM re-slice per layer;
the per-layer packed chain still exists as ``roi_forward_layers`` /
``fleet_forward_layers`` (the bit-identical A/B baseline).

``fleet_forward_reuse`` adds the TEMPORAL axis: one ``tile_delta_gate``
pricing dispatch thresholds each active tile's haloed entry window
against the previous frame, the changed set is dilated per layer
(``ops.reuse_sets``) and compacted into the launch tables, and unchanged
tiles composite from a persistent ``PackedActivationCache`` — compute
proportional to scene motion, bit-identical at threshold 0.

Dense fallback (the paper loads both models and routes large-RoI frames to
dense YOLO) selected by the density switch.

FLOP/byte accounting drives the speedup model used in the system
benchmarks:
  dense cost      ~ H*W * sum(9*Cin*Cout)
  packed roi cost ~ n_active*th*tw * sum(9*Cin*Cout)
                    + (gather + scatter bytes) / N_layers   (amortized)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# the I/O tax constant lives with the system cost model (ServerModel);
# re-exported here because the detector's speedup_estimate is the
# kernel-side mirror of that model
from repro.core.pipeline import IO_ROUND_TRIP_OVERHEAD
from repro.kernels import ops as kops


@dataclass
class DetectorConfig:
    channels: Tuple[int, ...] = (8, 16, 16)   # conv stack (YOLO-lite)
    tile: int = 16                            # feature-map tile (TPU block)
    num_anchors: int = 2
    switch_density: float = 0.70
    # VMEM budget the entry/stack/scatter tile-block is sized against
    # (ops.choose_block); 16 MiB = one TPU core's VMEM.
    vmem_budget_bytes: int = 16 * 2 ** 20


@dataclass
class ReuseStats:
    """Per-step accounting of the delta-gated (temporal reuse) path."""
    total_tiles: int               # active tiles across the fleet
    raw_changed: int               # tiles whose haloed input window changed
    changed_out: int               # ... dilated once per packed layer (the
    #                                tiles whose final output may differ)
    computed: int                  # compact-set tiles (changed_out + the
    #                                zero-halo margin) — the semantic
    #                                quantity the dilation bound describes;
    #                                0 = all-static, gate-only step
    launched: int                  # tiles the launch ACTUALLY convolved:
    #                                ``computed`` padded to its power-of-
    #                                two shape bucket (inert rows are real
    #                                GEMM work — honest perf accounting
    #                                uses this one)
    cold: bool                     # cache miss: full recompute, no gate
    # the step's shared tile_delta_gate stats rows ((n, STATS_WIDTH)
    # int32 in fleet packing order, None on a cold step) — hand these to
    # net/encoder.static_fraction_from_stats so the rate controller
    # prices static tiles WITHOUT a second delta dispatch.  At threshold
    # 0 the references hold the previous frame, so the body cols are
    # exactly ``tile_delta(cur, prev)``; under a LOSSY threshold they
    # are deltas vs each tile's LAST-REFRESH content instead — the same
    # change measure the reuse decision itself uses (a tile priced
    # static is one whose content still matches what its cached
    # activations were built from; content oscillating back to that
    # reference prices low even if it moved in between)
    gate_stats: Optional[np.ndarray] = None
    # bytes scattered into the persistent head-map canvas this step:
    # n_written_tiles * th * tw * head_ch * itemsize.  0 on an all-static
    # step (no scatter launch at all); the full active set on a cold
    # step.  Padding rewrites of the last real tile are NOT counted —
    # they land on already-written bytes.
    canvas_bytes: int = 0


TILE_CLASS_BODY = 0      # interior tile: full 8-neighbor ring active
TILE_CLASS_HALO = 1      # boundary tile: >= 1 neighbor missing (zero halo)
N_TILE_CLASSES = 2


def tile_class_rows(nbr_np) -> np.ndarray:
    """Static per-tile class vector from the fleet neighbor table:
    TILE_CLASS_HALO for tiles with any missing (inactive or off-frame)
    neighbor — the rows whose entry windows carry synthesized zero halo
    and sit on the RoI boundary — else TILE_CLASS_BODY.  Feeds the
    per-tile-class gate-threshold schedule
    (``net.encoder.gate_threshold_schedule(halo_gain=...)``)."""
    nbr = np.asarray(nbr_np)
    if nbr.size == 0:
        return np.zeros((nbr.shape[0],), np.int64)
    return np.where((nbr < 0).any(axis=1), TILE_CLASS_HALO,
                    TILE_CLASS_BODY).astype(np.int64)


def _per_row_threshold(thr: np.ndarray, cam_of_row,
                       class_of_row) -> np.ndarray:
    """(C,) per-camera or (C, n_classes) per-camera-per-tile-class
    threshold table -> (n,) per-row thresholds."""
    if thr.ndim == 1:
        return thr[np.asarray(cam_of_row)]
    if class_of_row is None:
        raise ValueError(
            "per-tile-class thresholds (2-D) need class_of_row "
            "(see tile_class_rows)")
    return thr[np.asarray(cam_of_row), np.asarray(class_of_row)]


def gate_changed_rows(stats, threshold, cam_of_row,
                      class_of_row=None) -> np.ndarray:
    """Host-side gate thresholding shared by the single-device and the
    sharded reuse paths: (n, STATS_WIDTH) ``tile_delta_gate`` stats rows
    -> (n,) bool raw-changed mask.

    ``threshold`` is a scalar, a PER-CAMERA (C,) array indexed by
    ``cam_of_row`` (the idx table's camera column), or a PER-CAMERA,
    PER-TILE-CLASS (C, n_classes) array additionally indexed by
    ``class_of_row`` (``tile_class_rows``: body vs halo/boundary rows)
    — the rate controller's gate-threshold schedule raises thresholds
    on cameras it is already shedding, and the tile-class axis lets it
    hold boundary tiles (whose zero-halo windows price noisier) to a
    different bar than interiors.  A threshold <= 0 selects the exact
    bitwise change count for those rows (bit-identical reuse); a
    positive threshold gates on the quantized window byte estimate."""
    s = np.asarray(stats)
    thr = np.asarray(threshold, np.float64)
    if thr.ndim == 0:
        if float(thr) <= 0:
            return s[:, kops.GATE_WIN_EXACT] > 0
        return s[:, kops.GATE_WIN_BYTES] > float(thr)
    per_row = _per_row_threshold(thr, cam_of_row, class_of_row)
    return np.where(per_row <= 0, s[:, kops.GATE_WIN_EXACT] > 0,
                    s[:, kops.GATE_WIN_BYTES] > per_row)


def ref_advance_rows(threshold, cam_of_row, changed,
                     class_of_row=None) -> Optional[np.ndarray]:
    """Which reference rows advance to the current content this step:
    ``None`` = every row (the scalar threshold <= 0 fast path — one
    wholesale assignment, previous-frame semantics), else a (n,) bool
    mask — exact-gated rows always advance, lossy-gated rows advance
    only when refreshed so sub-threshold drift accumulates against each
    tile's own reference (see PackedActivationCache).  With a
    (C, n_classes) threshold table the exact/lossy split is per
    (camera, tile-class) row, mirroring ``gate_changed_rows``."""
    thr = np.asarray(threshold, np.float64)
    if thr.ndim == 0:
        return None if float(thr) <= 0 else np.asarray(changed, bool)
    per_row = _per_row_threshold(thr, cam_of_row, class_of_row)
    return (per_row <= 0) | np.asarray(changed, bool)


class PackedActivationCache:
    """Per-fleet persistent packed-activation cache for temporal reuse.

    Holds the final conv layer's packed (n, th, tw, C_last) activations
    for EVERY active tile of the fleet, the persistent HEAD-MAP CANVAS
    (``canvas``, (C, H, W, A) head-space, device-resident across steps
    — warm steps scatter only this step's changed tiles into it, an
    all-static step writes 0 canvas bytes with no scatter launch), and
    the delta gate's reference content in one of two modes:

    * ``ref_mode="canvas"`` (default): a second padded canvas
      (``ref_canvas``, (C, H+2, W+2, 3), same shape as the stacked
      frames the gate reads) holding each tile's window content as of
      its last refresh, plus an (n,) per-tile refresh-EPOCH vector
      advanced by ``ref_advance_rows`` — no per-tile window duplication
      (packed windows store every overlap rim twice, ~1.3x the canvas
      bytes on halo-heavy masks).  Reference advancement writes the
      advanced rows' FULL haloed window regions from the current frame,
      so overlap writes between simultaneously-advanced neighbors carry
      identical content; at threshold <= 0 the wholesale assignment is
      a free alias of the current padded frame (previous-frame
      semantics, bit-identical to the packed mode by construction).
    * ``ref_mode="packed"``: the legacy PACKED per-tile windows
      (``ref_win``, (n, th+2, tw+2, 3)) — each tile's reference is
      private, so one tile's advance can never alias a neighbor's
      reference through the window overlap.  Kept as the semantics
      oracle the canvas mode is asserted bit-exact against at every
      threshold (tests/test_canvas.py).

    Under a lossy threshold only refreshed rows advance in either mode,
    so each tile's sub-threshold drift ACCUMULATES against its own
    reference and trips the gate once it crosses the threshold instead
    of creeping into the cache unboundedly.  Content-keyed on the
    fleet's grid digests and canvas shape, so any mask change — a drift
    re-solve, a shrink adoption, a different camera set — misses the
    key and forces a full recompute (cold scatter rebuilds the canvas
    from zeros: stale canvas content can never leak across a re-solve);
    ``invalidate`` is the explicit hook ``fleet/drift.DriftAdapter``
    mask listeners call for the same effect (belt and braces: the
    digest key alone already invalidates)."""

    def __init__(self, ref_mode: str = "canvas"):
        if ref_mode not in ("canvas", "packed"):
            raise ValueError(f"unknown ref_mode {ref_mode!r}")
        self.ref_mode = ref_mode
        self.key: Optional[tuple] = None
        self.packed: Optional[jax.Array] = None   # (n, th, tw, C_last)
        self.canvas: Optional[jax.Array] = None   # (C, H, W, A) head maps
        self.ref_win: Optional[jax.Array] = None  # (n, th+2, tw+2, 3)
        self.ref_canvas: Optional[jax.Array] = None  # (C, H+2, W+2, 3)
        self.epoch_np: Optional[np.ndarray] = None   # (n,) last refresh
        self.idx_np: Optional[np.ndarray] = None  # (n, 3) static tables
        self.nbr_np: Optional[np.ndarray] = None  # (n, 8)
        self.cls_np: Optional[np.ndarray] = None  # (n,) tile_class_rows
        self.invalidations = 0
        self.steps = 0
        self.cold_steps = 0
        self.launched_tiles = 0
        self.total_tiles = 0
        self.canvas_bytes_last = 0
        self.canvas_bytes_total = 0

    def invalidate(self) -> None:
        """Drop all cached state; the next reuse step recomputes fully."""
        self.key = None
        self.packed = None
        self.canvas = None
        self.ref_win = None
        self.ref_canvas = None
        self.epoch_np = None
        self.idx_np = None
        self.nbr_np = None
        self.cls_np = None
        self.invalidations += 1

    @property
    def compute_fraction(self) -> float:
        """Lifetime convolved-tile fraction vs full recompute (padding
        rows included — they are real launched GEMM work)."""
        return self.launched_tiles / max(self.total_tiles, 1)


class ShardedActivationCache:
    """The ``PackedActivationCache`` sharded along the group axis.

    State for ``fleet/sharded.ShardedSuperlaunch``: the packed final-
    layer activations and per-tile reference windows live as (S, n_max,
    ...) STACKED arrays, shard axis split over the fleet mesh
    (``distributed.shardings.fleet_state_sharding``), padded rows
    pointing at a sacrificial camera slot so SPMD shapes stay uniform
    across ragged shards.  Validity is PER SHARD: a drift re-solve on
    one group invalidates only the owning shard (``invalidate_group``,
    fan-out wired by ``fleet/drift.wire_shard_invalidation``), and the
    next sharded step recomputes that shard's rows while every other
    shard keeps serving warm — the single-device cache would have gone
    fleet-wide cold on the same event.  Mixed cold/warm shards run in
    the SAME SPMD program: a cold shard's rows are simply all marked
    raw-changed on the host side."""

    def __init__(self, plan: "kops.ShardPlan", gids=None):
        self.plan = plan
        self.gids = list(gids) if gids is not None else None
        self.valid = np.zeros(plan.n_shards, bool)
        self.packed = None      # (S, n_max, th, tw, C_last) mesh-sharded
        self.ref_win = None     # (S, n_max, th+2, tw+2, 3) mesh-sharded
        self.canvas = None      # (S, F_max+1, H, W, A) persistent heads
        self.ref_canvas = None  # (S, F_max+1, H+2, W+2, 3) references
        self.epoch_np = None    # (S, n_max) per-tile last-refresh step
        self.canvas_bytes_last = 0
        self.canvas_bytes_total = 0
        self.invalidations = 0
        self.shard_invalidations = np.zeros(plan.n_shards, np.int64)
        self.steps = 0
        self.cold_steps = 0          # steps with >= 1 cold shard
        self.launched_tiles = 0
        self.total_tiles = 0

    def owner_shard(self, group) -> int:
        """Shard owning ``group`` (a gid when the cache was built with
        ``gids``, else a plan position)."""
        pos = self.gids.index(group) if self.gids is not None else int(group)
        return int(self.plan.assignment[pos])

    def invalidate_group(self, group) -> None:
        """Mark ONLY the shard owning ``group`` cold; every other
        shard's cached rows stay valid and keep serving."""
        s = self.owner_shard(group)
        self.valid[s] = False
        self.shard_invalidations[s] += 1
        self.invalidations += 1

    def invalidate(self, _adapter=None) -> None:
        """Fleet-wide drop (the PackedActivationCache-compatible hook);
        accepts and ignores a DriftAdapter argument so it can be
        registered as a mask listener directly."""
        self.valid[:] = False
        self.packed = None
        self.ref_win = None
        self.canvas = None
        self.ref_canvas = None
        self.epoch_np = None
        self.invalidations += 1

    @property
    def compute_fraction(self) -> float:
        """Lifetime convolved-tile fraction vs full recompute (padding
        rows included — they are real launched GEMM work)."""
        return self.launched_tiles / max(self.total_tiles, 1)


@jax.jit
def _head_rows(packed: jax.Array, head: jax.Array) -> jax.Array:
    """Apply the 1x1 head to packed tiles PRE-scatter: (n, th, tw, C) @
    (C, A) -> (n, th, tw, A).  The head is a per-pixel dot product, so
    head-then-scatter is bit-identical to scatter-then-head — which is
    what lets the persistent canvas hold HEAD-space values and a warm
    step write only the changed tiles' head rows (pure jnp, not a
    counted kernel dispatch, like ``ops.gather_windows``)."""
    n, th, tw, c = packed.shape
    return (packed.reshape(n * th * tw, c) @ head).reshape(
        n, th, tw, head.shape[-1])


def _window_region_mask(idx_rows, t: int, shape) -> np.ndarray:
    """(m, 3) advanced (cam, ty, tx) rows -> bool (C, H+2, W+2, 1) mask
    over their haloed window regions on the padded reference canvas
    (broadcasts over channels).  Host-built from the static tables —
    overlapping window writes are safe because every advanced region is
    filled from the SAME current frame."""
    m = np.zeros(tuple(shape[:3]) + (1,), bool)
    for cam, ty, tx in np.asarray(idx_rows):
        m[cam, ty * t:ty * t + t + 2, tx * t:tx * t + t + 2, 0] = True
    return m


def _advance_refs(cache: "PackedActivationCache", xp: jax.Array,
                  adv: Optional[np.ndarray], windows: Optional[jax.Array],
                  t: int) -> None:
    """Advance the gate references per ``ref_advance_rows``'s verdict and
    stamp the per-tile refresh epochs.  ``adv is None`` = every row: in
    canvas mode that is a FREE alias of the current padded frame (the
    threshold <= 0 previous-frame fast path); a partial advance writes
    the advanced rows' full window regions via one masked select."""
    step = cache.steps
    if cache.ref_mode == "packed":
        if adv is None:
            cache.ref_win = windows
        elif adv.any():
            rows = jnp.asarray(np.nonzero(adv)[0])
            cache.ref_win = cache.ref_win.at[rows].set(windows[rows])
    else:
        if adv is None:
            cache.ref_canvas = xp
        elif adv.any():
            mask = _window_region_mask(cache.idx_np[adv], t,
                                       cache.ref_canvas.shape)
            cache.ref_canvas = jnp.where(jnp.asarray(mask), xp,
                                         cache.ref_canvas)
    if adv is None:
        cache.epoch_np[:] = step
    elif adv.any():
        cache.epoch_np[adv] = step


class RoIDetector:
    """params: conv stack + 1x1 head; built for (H, W, 3) frames."""

    def __init__(self, cfg: DetectorConfig, key: jax.Array):
        self.cfg = cfg
        chans = (3,) + cfg.channels
        self.weights: List[jax.Array] = []
        for i, (ci, co) in enumerate(zip(chans[:-1], chans[1:])):
            k = jax.random.fold_in(key, i)
            w = jax.random.normal(k, (3, 3, ci, co), jnp.float32)
            self.weights.append(w / np.sqrt(9 * ci))
        kh = jax.random.fold_in(key, 99)
        # head: objectness + 4 bbox regressors per anchor
        self.head = jax.random.normal(
            kh, (chans[-1], cfg.num_anchors * 5), jnp.float32) \
            / np.sqrt(chans[-1])
        # per-mask static cache: grid digest -> (idx2, idx3, nbr) arrays
        self._mask_cache: Dict[bytes, Tuple[jax.Array, jax.Array,
                                            jax.Array]] = {}
        # per-group static cache: digest tuple -> (idx3, nbr) arrays
        self._fleet_cache: Dict[tuple, Tuple[jax.Array, jax.Array]] = {}
        # per-grid digest memo: id(grid) -> (grid ref, popcount, digest).
        # Grids are packbits-serialized ONCE per array object, not once
        # per call — the fleet cache key on a hit is K dict lookups, not
        # K serializations.  Grids are treated as immutable (offline
        # re-solves produce fresh arrays); the strong ref pins the id and
        # a popcount guard re-hashes if a caller mutates one in place.
        # Capacity scales with the largest fleet offered (_fleet_tables),
        # so big fleets never thrash the memo back to per-call hashing.
        self._grid_digests: Dict[int, Tuple[np.ndarray, int, bytes]] = {}
        self._digest_cap = 64
        self.grid_hash_computes = 0       # digest serializations performed
        self.mask_cache_hits = 0
        self.fleet_cache_hits = 0
        # tile-block for the blocked walks, sized against the VMEM budget
        # (closes the "calibrate block vs VMEM" item; the old hardcoded
        # interpret-mode default was 128)
        self.block = kops.choose_block(
            cfg.tile, cfg.tile, max(chans), len(cfg.channels),
            cfg.vmem_budget_bytes)
        # entry/scatter block: on hardware the blocked walks are the
        # point (larger coalesced DMAs, fewer grid steps), but under the
        # interpreter their in-kernel load/store loops lose to the
        # per-tile BlockSpec pipeline — keep entry/scatter per-tile
        # there so the PR-4 super-launch wall clock does not regress.
        # The stack megakernel keeps its block everywhere (it always had
        # one), and the gate stays blocked in both modes: its batched
        # stats make one grid step per block a measured win even
        # interpreted.
        self.chain_block = 1 if kops.INTERPRET else self.block
        # whether the persistent head canvas is donated to the changed-
        # only scatter (resolved lazily from the serving engine's shared
        # ring-donation idiom: in-place off-CPU, copy on CPU)
        self._donate_canvas_flag: Optional[bool] = None

    def _donate_canvas(self) -> bool:
        """Donate the head-canvas buffer to ``sbnet_scatter_changed``?
        Same rule as ``ServingEngine``'s group-cache ring
        (``engine.ring_donate_argnums``): donate off-CPU so the warm-step
        canvas update is in-place (O(changed) traffic), never on CPU
        (donation is ignored there and tests read pre-step canvases)."""
        if self._donate_canvas_flag is None:
            from repro.serving.engine import ring_donate_argnums
            self._donate_canvas_flag = bool(ring_donate_argnums(0))
        return self._donate_canvas_flag

    # -- dense path ----------------------------------------------------------
    def dense_forward(self, x: jax.Array) -> jax.Array:
        for w in self.weights:
            x = jax.nn.relu(jax.lax.conv_general_dilated(
                x[None], w, (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))[0])
        return x @ self.head

    # -- static-table caches ---------------------------------------------------
    def _grid_digest(self, grid) -> bytes:
        """Content digest of one RoI grid, serialized at most once per
        array object (cache keys used to packbits every grid on every
        call, cache hit or not).  A popcount guard catches in-place
        mutation of a memoized grid (an exact-swap mutation that keeps
        the popcount would evade it — produce fresh arrays instead)."""
        pop = int(np.count_nonzero(grid))
        hit = self._grid_digests.get(id(grid))
        if hit is not None and hit[0] is grid and hit[1] == pop:
            return hit[2]
        g = np.asarray(grid, bool)
        self.grid_hash_computes += 1
        digest = np.packbits(g).tobytes() + bytes(str(g.shape), "ascii")
        while len(self._grid_digests) >= self._digest_cap:
            self._grid_digests.pop(next(iter(self._grid_digests)))
        self._grid_digests[id(grid)] = (grid, pop, digest)
        return digest

    def _mask_tables(self, grid: np.ndarray):
        key = self._grid_digest(grid)
        hit = self._mask_cache.get(key)
        if hit is None:
            idx_np = kops.mask_to_indices(grid)
            idx3 = np.concatenate([np.zeros((idx_np.shape[0], 1), np.int32),
                                   idx_np], axis=1)
            hit = (jnp.asarray(idx_np), jnp.asarray(idx3),
                   jnp.asarray(kops.neighbor_table(idx_np, grid.shape)))
            # masks change rarely (offline re-solves); a small FIFO keeps
            # a long-lived server from pinning every mask ever seen
            while len(self._mask_cache) >= 8:
                self._mask_cache.pop(next(iter(self._mask_cache)))
            self._mask_cache[key] = hit
        else:
            self.mask_cache_hits += 1
        return hit

    def _fleet_tables(self, grids):
        # never let one fleet-sized key sweep smaller entries out of the
        # digest memo: keep room for two full fleets' worth of grids
        self._digest_cap = max(self._digest_cap, 2 * len(grids))
        key = tuple(self._grid_digest(g) for g in grids)
        hit = self._fleet_cache.get(key)
        if hit is None:
            idx_np, _ = kops.fleet_indices(grids)
            hit = (jnp.asarray(idx_np),
                   jnp.asarray(kops.fleet_neighbor_table(grids)))
            while len(self._fleet_cache) >= 8:
                self._fleet_cache.pop(next(iter(self._fleet_cache)))
            self._fleet_cache[key] = hit
        else:
            self.fleet_cache_hits += 1
        return hit

    # -- RoI path -------------------------------------------------------------
    def _stack_chain(self, x: jax.Array, idx3: jax.Array,
                     nbr: jax.Array) -> jax.Array:
        """The fused launch chain over stacked frames: entry kernel, then
        the layer-stack megakernel.  2 dispatches for any layer count
        > 1, 1 for a single-layer net."""
        t = self.cfg.tile
        packed = kops.roi_conv_entry(x, self.weights[0], idx3, t, t,
                                     block=self.chain_block)
        if len(self.weights) > 1:
            packed = kops.roi_conv_stack(packed, self.weights[1:], nbr,
                                         block=self.block)
        return packed

    def roi_forward(self, x: jax.Array, grid: np.ndarray) -> jax.Array:
        """x: (H, W, 3); grid: bool tile mask at self.cfg.tile granularity.
        Returns the full-frame head map with non-RoI regions zero.

        Stay-packed, constant-dispatch execution: ONE entry kernel (the
        gather fused into the first conv), ONE layer-stack megakernel for
        every remaining layer, ONE scatter — 3 dispatches total,
        independent of the layer count."""
        idx, idx3, nbr = self._mask_tables(grid)
        if idx.shape[0] == 0:             # empty mask: nothing to launch
            return jnp.zeros(x.shape[:2] + (self.head.shape[-1],), x.dtype)
        packed = self._stack_chain(x[None], idx3, nbr)
        base = jnp.zeros(x.shape[:2] + (packed.shape[-1],), packed.dtype)
        full = kops.sbnet_scatter(packed, idx, base)   # the scatter
        return full @ self.head

    def roi_forward_layers(self, x: jax.Array, grid: np.ndarray
                           ) -> jax.Array:
        """The per-layer packed chain (one ``roi_conv_packed`` dispatch
        per layer after the fused gather) — kept as the bit-identical A/B
        baseline for the megakernel; K×(N+1)-dispatch regime."""
        t = self.cfg.tile
        idx, _, nbr = self._mask_tables(grid)
        packed = None
        for li, w in enumerate(self.weights):
            if li == 0:
                # the gather: haloed windows sliced straight off the frame
                packed = kops.roi_conv(x, w, idx, t, t)
            else:
                packed = kops.roi_conv_packed(packed, w, nbr)
            packed = jax.nn.relu(packed)
        base = jnp.zeros(x.shape[:2] + (packed.shape[-1],), packed.dtype)
        full = kops.sbnet_scatter(packed, idx, base)
        return full @ self.head

    # -- fleet (multi-camera group / whole-fleet) path ------------------------
    def _stack_frames(self, frames, grids):
        t = self.cfg.tile
        canvas_h = max(max(f.shape[0] for f in frames),
                       max(g.shape[0] * t for g in grids))
        canvas_w = max(max(f.shape[1] for f in frames),
                       max(g.shape[1] * t for g in grids))
        return jnp.stack([jnp.pad(f, ((0, canvas_h - f.shape[0]),
                                      (0, canvas_w - f.shape[1]), (0, 0)))
                          for f in frames]), canvas_h, canvas_w

    def fleet_forward(self, frames: List[jax.Array],
                      grids: List[np.ndarray]) -> List[jax.Array]:
        """Any number of cameras, ≤3 dispatches total: frames (one
        (H, W, 3) per camera, any sizes) are stacked on a common zero
        canvas and the whole set's active tiles run as ONE fused
        gather+conv entry, ONE layer-stack megakernel (cross-camera
        neighbor table — halos cannot leak between cameras), and ONE
        scatter.  Returns the per-camera full-frame head maps, each
        bit-compatible with ``roi_forward(frame, grid)`` on that camera
        alone.  Cameras with empty masks get all-zero head maps and cost
        no launches of their own."""
        idx, nbr = self._fleet_tables(grids)
        if idx.shape[0] == 0:             # whole set empty: no launches
            return [jnp.zeros(f.shape[:2] + (self.head.shape[-1],),
                              f.dtype) for f in frames]
        x, canvas_h, canvas_w = self._stack_frames(frames, grids)
        packed = self._stack_chain(x, idx, nbr)
        base = jnp.zeros((len(frames), canvas_h, canvas_w,
                          packed.shape[-1]), packed.dtype)
        full = kops.sbnet_scatter_fleet(packed, idx, base,
                                        block=self.chain_block)
        heads = full @ self.head
        return [heads[c, :f.shape[0], :f.shape[1]]
                for c, f in enumerate(frames)]

    def fleet_forward_layers(self, frames: List[jax.Array],
                             grids: List[np.ndarray]) -> List[jax.Array]:
        """Per-layer fleet chain (1 + (N-1) + 1 dispatches per call) —
        the bit-identical A/B baseline for the fused path."""
        t = self.cfg.tile
        idx, nbr = self._fleet_tables(grids)
        x, canvas_h, canvas_w = self._stack_frames(frames, grids)
        packed = None
        for li, w in enumerate(self.weights):
            if li == 0:
                packed = kops.roi_conv_fleet(x, w, idx, t, t)
            else:
                packed = kops.roi_conv_packed(packed, w, nbr)
            packed = jax.nn.relu(packed)
        base = jnp.zeros((len(frames), canvas_h, canvas_w,
                          packed.shape[-1]), packed.dtype)
        full = kops.sbnet_scatter_fleet(packed, idx, base)
        heads = full @ self.head
        return [heads[c, :f.shape[0], :f.shape[1]]
                for c, f in enumerate(frames)]

    def superlaunch_forward(self, frames: Dict[int, List[jax.Array]],
                            grids: Dict[int, List[np.ndarray]]
                            ) -> Dict[int, List[jax.Array]]:
        """The cross-group super-launch: EVERY camera of EVERY group in
        one fleet-flat launch chain — ≤3 dispatches for the whole fleet,
        independent of group count and layer count.  Group boundaries are
        just camera boundaries in the flat (flat_cam, ty, tx) index
        space, so per-camera slot offsets keep halos leak-free across
        cameras and groups alike (``_fleet_tables`` builds and caches the
        flat tables; ``ops.superlaunch_tables`` is the equivalent
        standalone builder).  Returns {gid: per-camera head maps}, each
        bit-identical to ``fleet_forward(frames[gid], grids[gid])`` on
        that group alone."""
        gids = list(frames)
        flat_frames = [f for g in gids for f in frames[g]]
        flat_grids = [gr for g in gids for gr in grids[g]]
        heads = self.fleet_forward(flat_frames, flat_grids)
        out, pos = {}, 0
        for g in gids:
            out[g] = heads[pos:pos + len(frames[g])]
            pos += len(frames[g])
        return out

    # -- temporal reuse (delta-gated) path ------------------------------------
    def fleet_forward_reuse(self, frames: List[jax.Array],
                            grids: List[np.ndarray],
                            cache: PackedActivationCache,
                            threshold: float = 0.0,
                            qstep: float = 8.0
                            ) -> Tuple[List[jax.Array], ReuseStats]:
        """``fleet_forward`` with compute proportional to CHANGED tiles.

        One shared ``tile_delta_gate`` dispatch prices every active
        tile's haloed entry window against the cached previous frame; a
        tile is *changed* when its window byte estimate exceeds
        ``threshold`` (at threshold <= 0 the exact bitwise change count
        gates instead, making reuse BIT-IDENTICAL to full recompute).
        ``threshold`` may also be a PER-CAMERA array (one entry per
        flattened camera, see ``gate_changed_rows``) — the rate
        controller's gate-threshold schedule raises thresholds only on
        cameras it is already shedding, and cameras left at <= 0 keep
        exact-gated bit-identity.
        The changed set is dilated once per packed layer into the
        changed-OUTPUT set, once more per layer into the compute margin
        (``ops.reuse_sets``), compacted into the superlaunch tables
        (``ops.compact_tables``) and run through the blocked entry +
        stack chain; unchanged tiles keep their bytes in the PERSISTENT
        head-map canvas (written by the step that last computed them),
        and one ``sbnet_scatter_changed`` writes ONLY the refreshed
        tiles' head rows into it — both sides of a step are O(changed)
        bytes.  An all-static frame dispatches the gate ALONE: no conv,
        no scatter, 0 canvas bytes written.  A cache miss (first frame,
        mask re-solve, canvas change) recomputes fully and seeds the
        cache + canvas from zeros.  ``threshold`` may also be a
        (C, N_TILE_CLASSES) per-camera-per-tile-class table (body vs
        halo rows, see ``tile_class_rows``)."""
        t = self.cfg.tile
        idx, nbr = self._fleet_tables(grids)
        n = int(idx.shape[0])
        if n == 0:                        # whole fleet empty: no launches
            return ([jnp.zeros(f.shape[:2] + (self.head.shape[-1],),
                               f.dtype) for f in frames],
                    ReuseStats(0, 0, 0, 0, 0, cold=False))
        x, canvas_h, canvas_w = self._stack_frames(frames, grids)
        xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
        key = (tuple(self._grid_digest(g) for g in grids),
               len(frames), canvas_h, canvas_w)
        n_layers = self.num_conv_layers
        cache.steps += 1
        cache.total_tiles += n
        A = self.head.shape[-1]
        tile_bytes = t * t * A * jnp.dtype(self.head.dtype).itemsize
        cold = (cache.key != key or cache.packed is None
                or cache.canvas is None
                or (cache.ref_win is None if cache.ref_mode == "packed"
                    else cache.ref_canvas is None))
        if cold:
            # miss: mask/canvas changed (or first frame) — recompute all
            # tiles through the fused chain, seed the cache tables and
            # rebuild the head canvas from zeros (stale canvas content
            # can never survive a re-solve)
            cache.key = key
            cache.packed = self._stack_chain(x, idx, nbr)
            if cache.ref_mode == "packed":
                cache.ref_win = kops.gather_windows(xp, idx, t, t)
                cache.ref_canvas = None
            else:
                cache.ref_canvas = xp      # free alias, full advance
                cache.ref_win = None
            cache.idx_np = np.asarray(idx)
            cache.nbr_np = np.asarray(nbr)
            cache.cls_np = tile_class_rows(cache.nbr_np)
            cache.epoch_np = np.zeros(n, np.int64)
            base = jnp.zeros((len(frames), canvas_h, canvas_w, A),
                             self.head.dtype)
            cache.canvas = kops.sbnet_scatter_fleet(
                _head_rows(cache.packed, self.head), idx, base,
                block=self.chain_block)
            cache.cold_steps += 1
            cache.launched_tiles += n
            stats = ReuseStats(n, n, n, n, n, cold=True,
                               canvas_bytes=n * tile_bytes)
        else:
            if cache.ref_mode == "packed":
                gate, windows = kops.tile_delta_gate(
                    xp, cache.ref_win, idx, t, t, qstep=qstep,
                    block=self.block)
            else:
                gate = kops.tile_delta_gate_canvas(
                    xp, cache.ref_canvas, idx, t, t, qstep=qstep,
                    block=self.block)
                windows = None
            s = np.asarray(gate)
            # exact gate (threshold <= 0, possibly per camera / class):
            # quantization rounds small deltas to zero and even an
            # all-zero delta prices its run tokens, so bit-identity keys
            # on the raw bitwise comparison
            raw = gate_changed_rows(s, threshold, cache.idx_np[:, 0],
                                    cache.cls_np)
            changed, compute = kops.reuse_sets(raw, cache.nbr_np,
                                               n_layers)
            n_changed = int(changed.sum())
            if n_changed:
                cidx, cnbr = kops.compact_tables(cache.idx_np,
                                                 cache.nbr_np, compute)
                k = cidx.shape[0]
                # pad the ragged compact set up to the next power of two
                # with inert repeats (idx) / -1 neighbors, so the jit
                # caches key on log-many bucketed shapes, not every |E|
                # (waste < 2x; the padding rows are real GEMM work and
                # are accounted as ``launched``)
                k_pad = 1
                while k_pad < k:
                    k_pad *= 2
                if k_pad > k:
                    cidx = np.concatenate(
                        [cidx, np.broadcast_to(cidx[-1:],
                                               (k_pad - k, 3))])
                    cnbr = np.concatenate(
                        [cnbr, np.full((k_pad - k, 8), -1, np.int32)])
                fresh = self._stack_chain(x, jnp.asarray(cidx),
                                          jnp.asarray(cnbr))
                # only the changed-OUTPUT rows graduate to the cache —
                # margin rows absorbed the zero-halo error and their
                # cached values are still exact
                slots = np.nonzero(compute)[0]
                upd = changed[slots]
                fresh_rows = fresh[jnp.asarray(np.nonzero(upd)[0])]
                cache.packed = cache.packed.at[
                    jnp.asarray(slots[upd])].set(fresh_rows)
                # ... and only those rows' head tiles hit the canvas:
                # O(changed) write bytes, pow-of-two repeat-last padding
                # so the scatter jit buckets like the conv chain (padding
                # stores rewrite the last real tile's bytes in place)
                scidx = cache.idx_np[slots[upd]]
                ph = _head_rows(fresh_rows, self.head)
                m = scidx.shape[0]
                m_pad = 1
                while m_pad < m:
                    m_pad *= 2
                if m_pad > m:
                    scidx = np.concatenate(
                        [scidx, np.broadcast_to(scidx[-1:],
                                                (m_pad - m, 3))])
                    ph = jnp.concatenate(
                        [ph, jnp.broadcast_to(
                            ph[-1:], (m_pad - m,) + ph.shape[1:])])
                cache.canvas = kops.sbnet_scatter_changed(
                    ph, jnp.asarray(scidx), cache.canvas,
                    block=self.chain_block, donate=self._donate_canvas())
                cache.launched_tiles += k_pad
                stats = ReuseStats(n, int(raw.sum()), n_changed, k,
                                   k_pad, cold=False, gate_stats=s,
                                   canvas_bytes=m * tile_bytes)
                # advance the references of the REFRESHED tiles —
                # packed mode row-for-row from the gate's own windows
                # output, canvas mode by masked window-region writes
                # from the current frame (threshold 0 advances every
                # row: previous-frame semantics, one free assignment)
                adv = ref_advance_rows(threshold, cache.idx_np[:, 0],
                                       changed, cache.cls_np)
                _advance_refs(cache, xp, adv, windows, t)
            else:
                # ALL-STATIC: the gate dispatch is the whole step — no
                # conv, no scatter, the canvas is served as-is with 0
                # bytes written
                adv = ref_advance_rows(threshold, cache.idx_np[:, 0],
                                       np.zeros(n, bool), cache.cls_np)
                _advance_refs(cache, xp, adv, windows, t)
                stats = ReuseStats(n, int(raw.sum()), 0, 0, 0,
                                   cold=False, gate_stats=s,
                                   canvas_bytes=0)
        cache.canvas_bytes_last = stats.canvas_bytes
        cache.canvas_bytes_total += stats.canvas_bytes
        heads = cache.canvas
        return ([heads[c, :f.shape[0], :f.shape[1]]
                 for c, f in enumerate(frames)], stats)

    def superlaunch_forward_reuse(self, frames: Dict[int, List[jax.Array]],
                                  grids: Dict[int, List[np.ndarray]],
                                  cache: PackedActivationCache,
                                  threshold: float = 0.0,
                                  qstep: float = 8.0):
        """Delta-gated cross-group super-launch: every camera of every
        group in one compact launch chain (see ``superlaunch_forward``
        for the flattening contract).  Returns ({gid: head maps},
        ReuseStats)."""
        gids = list(frames)
        flat_frames = [f for g in gids for f in frames[g]]
        flat_grids = [gr for g in gids for gr in grids[g]]
        heads, stats = self.fleet_forward_reuse(flat_frames, flat_grids,
                                                cache, threshold, qstep)
        out, pos = {}, 0
        for g in gids:
            out[g] = heads[pos:pos + len(frames[g])]
            pos += len(frames[g])
        return out, stats

    def forward(self, x: jax.Array, grid: Optional[np.ndarray]) -> jax.Array:
        if grid is None or grid.mean() >= self.cfg.switch_density:
            return self.dense_forward(x)
        return self.roi_forward(x, grid)

    # -- cost model -------------------------------------------------------------
    @property
    def num_conv_layers(self) -> int:
        return len(self.cfg.channels)

    def flops(self, H: int, W: int, density: float = 1.0) -> float:
        chans = (3,) + self.cfg.channels
        per_px = sum(2 * 9 * ci * co for ci, co in zip(chans[:-1], chans[1:]))
        per_px += 2 * chans[-1] * self.cfg.num_anchors * 5
        return H * W * density * per_px

    def io_overhead_per_layer(
            self, round_trip: float = IO_ROUND_TRIP_OVERHEAD) -> float:
        """Gather/scatter byte tax amortized over the conv stack: the packed
        chain pays one round-trip for N layers, so the per-layer overhead is
        round_trip / N (the old per-layer regime paid round_trip / 1)."""
        return round_trip / max(self.num_conv_layers, 1)

    def speedup_estimate(self, density: float,
                         round_trip: float = IO_ROUND_TRIP_OVERHEAD) -> float:
        """Structural speedup (FLOP ratio with the amortized gather/scatter
        byte tax): matches the ServerModel constant used by the system
        pipeline."""
        if density >= self.cfg.switch_density:
            return 1.0
        return 1.0 / (self.io_overhead_per_layer(round_trip) + density)
