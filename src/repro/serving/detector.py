"""RoI-YOLO-lite: a small conv detector running on active tiles only.

The online-phase server model (paper §4.4): a YOLO-style backbone where
every conv layer runs through the fused roi_conv Pallas kernel over the
RoI-active tiles.  Dense fallback (the paper loads both models and routes
large-RoI frames to dense YOLO) selected by the density switch.

FLOP accounting drives the speedup model used in the system benchmarks:
  dense cost  ~ H*W * sum(9*Cin*Cout)
  roi cost    ~ n_active*th*tw * sum(9*Cin*Cout)  + gather/scatter bytes
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops


@dataclass
class DetectorConfig:
    channels: Tuple[int, ...] = (8, 16, 16)   # conv stack (YOLO-lite)
    tile: int = 16                            # feature-map tile (TPU block)
    num_anchors: int = 2
    switch_density: float = 0.70


class RoIDetector:
    """params: conv stack + 1x1 head; built for (H, W, 3) frames."""

    def __init__(self, cfg: DetectorConfig, key: jax.Array):
        self.cfg = cfg
        chans = (3,) + cfg.channels
        self.weights: List[jax.Array] = []
        for i, (ci, co) in enumerate(zip(chans[:-1], chans[1:])):
            k = jax.random.fold_in(key, i)
            w = jax.random.normal(k, (3, 3, ci, co), jnp.float32)
            self.weights.append(w / np.sqrt(9 * ci))
        kh = jax.random.fold_in(key, 99)
        # head: objectness + 4 bbox regressors per anchor
        self.head = jax.random.normal(
            kh, (chans[-1], cfg.num_anchors * 5), jnp.float32) \
            / np.sqrt(chans[-1])

    # -- dense path ----------------------------------------------------------
    def dense_forward(self, x: jax.Array) -> jax.Array:
        for w in self.weights:
            x = jax.nn.relu(jax.lax.conv_general_dilated(
                x[None], w, (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))[0])
        return x @ self.head

    # -- RoI path -------------------------------------------------------------
    def roi_forward(self, x: jax.Array, grid: np.ndarray) -> jax.Array:
        """x: (H, W, 3); grid: bool tile mask at self.cfg.tile granularity.
        Returns the full-frame head map with non-RoI regions zero."""
        t = self.cfg.tile
        idx = jnp.asarray(kops.mask_to_indices(grid))
        for li, w in enumerate(self.weights):
            packed = kops.roi_conv(x, w, idx, t, t)
            packed = jax.nn.relu(packed)
            base = jnp.zeros(x.shape[:2] + (w.shape[-1],), packed.dtype)
            # scatter back so the next layer's halos see neighbor tiles
            x = kops.sbnet_scatter(packed, idx, base)
        return x @ self.head

    def forward(self, x: jax.Array, grid: Optional[np.ndarray]) -> jax.Array:
        if grid is None or grid.mean() >= self.cfg.switch_density:
            return self.dense_forward(x)
        return self.roi_forward(x, grid)

    # -- cost model -------------------------------------------------------------
    def flops(self, H: int, W: int, density: float = 1.0) -> float:
        chans = (3,) + self.cfg.channels
        per_px = sum(2 * 9 * ci * co for ci, co in zip(chans[:-1], chans[1:]))
        per_px += 2 * chans[-1] * self.cfg.num_anchors * 5
        return H * W * density * per_px

    def speedup_estimate(self, density: float,
                         gather_overhead: float = 0.30) -> float:
        """Structural speedup (FLOP ratio with gather/scatter byte tax):
        matches the ServerModel constant used by the system pipeline."""
        if density >= self.cfg.switch_density:
            return 1.0
        return 1.0 / (gather_overhead + density)
