"""RoI-YOLO-lite: a small conv detector running on active tiles only.

The online-phase server model (paper §4.4), with the packed representation
persistent across the whole stack: layer 0 is the fused gather+conv kernel
(roi_conv reads haloed windows straight from the frame — the *one* gather),
layers 1..N-1 are packed-resident (roi_conv_packed pulls halo strips from
neighbor tiles via the offline neighbor table), and a *single* scatter at
the end materializes the full-frame head map.  The old SBNet formulation
paid a full-frame scatter + HBM re-slice per layer; this one pays the
round-trip once for the whole stack.

Dense fallback (the paper loads both models and routes large-RoI frames to
dense YOLO) selected by the density switch.

FLOP/byte accounting drives the speedup model used in the system
benchmarks:
  dense cost      ~ H*W * sum(9*Cin*Cout)
  packed roi cost ~ n_active*th*tw * sum(9*Cin*Cout)
                    + (gather + scatter bytes) / N_layers   (amortized)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# the I/O tax constant lives with the system cost model (ServerModel);
# re-exported here because the detector's speedup_estimate is the
# kernel-side mirror of that model
from repro.core.pipeline import IO_ROUND_TRIP_OVERHEAD
from repro.kernels import ops as kops


@dataclass
class DetectorConfig:
    channels: Tuple[int, ...] = (8, 16, 16)   # conv stack (YOLO-lite)
    tile: int = 16                            # feature-map tile (TPU block)
    num_anchors: int = 2
    switch_density: float = 0.70


class RoIDetector:
    """params: conv stack + 1x1 head; built for (H, W, 3) frames."""

    def __init__(self, cfg: DetectorConfig, key: jax.Array):
        self.cfg = cfg
        chans = (3,) + cfg.channels
        self.weights: List[jax.Array] = []
        for i, (ci, co) in enumerate(zip(chans[:-1], chans[1:])):
            k = jax.random.fold_in(key, i)
            w = jax.random.normal(k, (3, 3, ci, co), jnp.float32)
            self.weights.append(w / np.sqrt(9 * ci))
        kh = jax.random.fold_in(key, 99)
        # head: objectness + 4 bbox regressors per anchor
        self.head = jax.random.normal(
            kh, (chans[-1], cfg.num_anchors * 5), jnp.float32) \
            / np.sqrt(chans[-1])
        # per-mask static cache: mask bytes -> (idx, nbr) device arrays
        self._mask_cache: Dict[bytes, Tuple[jax.Array, jax.Array]] = {}
        # per-group static cache: fleet mask bytes -> (idx3, nbr) arrays
        self._fleet_cache: Dict[bytes, Tuple[jax.Array, jax.Array]] = {}

    # -- dense path ----------------------------------------------------------
    def dense_forward(self, x: jax.Array) -> jax.Array:
        for w in self.weights:
            x = jax.nn.relu(jax.lax.conv_general_dilated(
                x[None], w, (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))[0])
        return x @ self.head

    # -- RoI path -------------------------------------------------------------
    def _mask_tables(self, grid: np.ndarray):
        key = np.packbits(np.asarray(grid, bool)).tobytes() + bytes(
            str(grid.shape), "ascii")
        hit = self._mask_cache.get(key)
        if hit is None:
            idx_np = kops.mask_to_indices(grid)
            hit = (jnp.asarray(idx_np),
                   jnp.asarray(kops.neighbor_table(idx_np, grid.shape)))
            # masks change rarely (offline re-solves); a small FIFO keeps
            # a long-lived server from pinning every mask ever seen
            while len(self._mask_cache) >= 8:
                self._mask_cache.pop(next(iter(self._mask_cache)))
            self._mask_cache[key] = hit
        return hit

    def roi_forward(self, x: jax.Array, grid: np.ndarray) -> jax.Array:
        """x: (H, W, 3); grid: bool tile mask at self.cfg.tile granularity.
        Returns the full-frame head map with non-RoI regions zero.

        Stay-packed execution: ONE gather (fused into the first conv), N
        packed-resident conv layers, ONE scatter — no full-frame
        materialization between layers."""
        t = self.cfg.tile
        idx, nbr = self._mask_tables(grid)
        packed = None
        for li, w in enumerate(self.weights):
            if li == 0:
                # the gather: haloed windows sliced straight off the frame
                packed = kops.roi_conv(x, w, idx, t, t)
            else:
                packed = kops.roi_conv_packed(packed, w, nbr)
            packed = jax.nn.relu(packed)
        base = jnp.zeros(x.shape[:2] + (packed.shape[-1],), packed.dtype)
        full = kops.sbnet_scatter(packed, idx, base)   # the scatter
        return full @ self.head

    # -- fleet (multi-camera group) path --------------------------------------
    def _fleet_tables(self, grids):
        key = b"".join(np.packbits(np.asarray(g, bool)).tobytes()
                       + bytes(str(g.shape), "ascii") for g in grids)
        hit = self._fleet_cache.get(key)
        if hit is None:
            idx_np, _ = kops.fleet_indices(grids)
            hit = (jnp.asarray(idx_np),
                   jnp.asarray(kops.fleet_neighbor_table(grids)))
            while len(self._fleet_cache) >= 8:
                self._fleet_cache.pop(next(iter(self._fleet_cache)))
            self._fleet_cache[key] = hit
        return hit

    def fleet_forward(self, frames: List[jax.Array],
                      grids: List[np.ndarray]) -> List[jax.Array]:
        """One camera group, one launch per stage: frames (one (H, W, 3)
        per camera, any sizes) are stacked on a common zero canvas and the
        whole group's active tiles run as ONE fused gather+conv, ONE
        roi_conv_packed per remaining layer (cross-camera neighbor table —
        halos cannot leak between cameras), and ONE scatter.  Returns the
        per-camera full-frame head maps, each bit-compatible with
        ``roi_forward(frame, grid)`` on that camera alone."""
        t = self.cfg.tile
        canvas_h = max(max(f.shape[0] for f in frames),
                       max(g.shape[0] * t for g in grids))
        canvas_w = max(max(f.shape[1] for f in frames),
                       max(g.shape[1] * t for g in grids))
        x = jnp.stack([jnp.pad(f, ((0, canvas_h - f.shape[0]),
                                   (0, canvas_w - f.shape[1]), (0, 0)))
                       for f in frames])
        idx, nbr = self._fleet_tables(grids)
        packed = None
        for li, w in enumerate(self.weights):
            if li == 0:
                packed = kops.roi_conv_fleet(x, w, idx, t, t)
            else:
                packed = kops.roi_conv_packed(packed, w, nbr)
            packed = jax.nn.relu(packed)
        base = jnp.zeros((len(frames), canvas_h, canvas_w,
                          packed.shape[-1]), packed.dtype)
        full = kops.sbnet_scatter_fleet(packed, idx, base)
        heads = full @ self.head
        return [heads[c, :f.shape[0], :f.shape[1]]
                for c, f in enumerate(frames)]

    def forward(self, x: jax.Array, grid: Optional[np.ndarray]) -> jax.Array:
        if grid is None or grid.mean() >= self.cfg.switch_density:
            return self.dense_forward(x)
        return self.roi_forward(x, grid)

    # -- cost model -------------------------------------------------------------
    @property
    def num_conv_layers(self) -> int:
        return len(self.cfg.channels)

    def flops(self, H: int, W: int, density: float = 1.0) -> float:
        chans = (3,) + self.cfg.channels
        per_px = sum(2 * 9 * ci * co for ci, co in zip(chans[:-1], chans[1:]))
        per_px += 2 * chans[-1] * self.cfg.num_anchors * 5
        return H * W * density * per_px

    def io_overhead_per_layer(
            self, round_trip: float = IO_ROUND_TRIP_OVERHEAD) -> float:
        """Gather/scatter byte tax amortized over the conv stack: the packed
        chain pays one round-trip for N layers, so the per-layer overhead is
        round_trip / N (the old per-layer regime paid round_trip / 1)."""
        return round_trip / max(self.num_conv_layers, 1)

    def speedup_estimate(self, density: float,
                         round_trip: float = IO_ROUND_TRIP_OVERHEAD) -> float:
        """Structural speedup (FLOP ratio with the amortized gather/scatter
        byte tax): matches the ServerModel constant used by the system
        pipeline."""
        if density >= self.cfg.switch_density:
            return 1.0
        return 1.0 / (self.io_overhead_per_layer(round_trip) + density)
