from repro.serving.engine import ServingEngine, Request, RoIPrefillResult
from repro.serving.detector import (PackedActivationCache, ReuseStats,
                                    RoIDetector)

__all__ = ["ServingEngine", "Request", "RoIPrefillResult", "RoIDetector",
           "PackedActivationCache", "ReuseStats"]
