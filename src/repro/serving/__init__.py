from repro.serving.engine import ServingEngine, Request, RoIPrefillResult
from repro.serving.detector import RoIDetector

__all__ = ["ServingEngine", "Request", "RoIPrefillResult", "RoIDetector"]
