import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers and
compiles on the production meshes, and extract roofline inputs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-67b \
      --shape train_4k [--multi-pod] [--sharding fsdp] [--calibrate]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
      --out results.json

Per cell it prints compiled.memory_analysis() (fits-per-device proof) and
cost_analysis() (FLOPs/bytes for §Roofline), plus the collective schedule
parsed from the partitioned HLO.  --calibrate adds the two-point
layer-count compiles that undo XLA's scan-body-once cost counting
(launch/roofline.py).
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs.base import SHAPES, TrainConfig
from repro.configs.registry import ARCH_IDS, all_cells, cell_is_applicable, \
    get_config
from repro.launch import roofline as R
from repro.launch.mesh import CHIPS_PER_POD, HBM_PER_CHIP, \
    make_production_mesh
from repro.launch.steps import build_cell


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             sharding_mode: str = "tp", calibrate: bool = False,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    cell = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 2 * CHIPS_PER_POD if multi_pod else CHIPS_PER_POD
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if sharding_mode == "auto":
        # paper-era TP baseline when it fits; FSDP upgrade when TP-only
        # parameter replication cannot fit 16 GiB/chip (e.g. 235B MoE)
        from repro.launch.memory import estimate_cell
        from repro.launch.steps import auto_microbatch
        k0 = auto_microbatch(cfg, cell, mesh, multi_pod) \
            if cell.kind == "train" else 1
        est0 = estimate_cell(cfg, cell, mesh, multi_pod, "tp",
                             microbatch=k0)
        sharding_mode = "tp" if est0["fits"] else \
            ("fsdp_pod" if multi_pod else "fsdp")
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "sharding": sharding_mode, "ok": False}
    t0 = time.time()

    fn, args, _ = build_cell(cfg, cell, mesh, multi_pod, sharding_mode)
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    coll = R.collective_bytes(compiled.as_text())
    per_dev = (mem.argument_size_in_bytes + mem.output_size_in_bytes
               + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    # analytic TPU-side estimate (XLA:CPU float-normalization inflates the
    # measured numbers with f32 upcast buffers that do not exist on TPU)
    from repro.launch.memory import estimate_cell
    from repro.launch.steps import auto_microbatch
    k = auto_microbatch(cfg, cell, mesh, multi_pod) \
        if cell.kind == "train" else 1
    est = estimate_cell(cfg, cell, mesh, multi_pod, sharding_mode,
                        microbatch=k)
    rec.update(
        ok=True, lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        flops_per_dev=float(ca.get("flops", 0.0)),
        bytes_per_dev=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=coll, arg_bytes=mem.argument_size_in_bytes,
        temp_bytes=mem.temp_size_in_bytes, out_bytes=mem.output_size_in_bytes,
        alias_bytes=mem.alias_size_in_bytes,
        mem_per_device=per_dev, microbatch=k,
        mem_estimate=round(est["total"]), fits=bool(est["fits"]),
        mem_breakdown={kk: round(v) for kk, v in est.items()
                       if kk not in ("total", "fits")},
    )

    if calibrate:
        l1, l2 = R.calib_depths(cfg)
        cal = {}
        for L in (l1, l2):
            ccfg = R.with_depth(cfg, L)
            cfn, cargs, _ = build_cell(ccfg, cell, mesh, multi_pod,
                                       sharding_mode)
            cc = cfn.lower(*cargs).compile()
            cca = cc.cost_analysis() or {}
            ccoll = R.collective_bytes(cc.as_text())
            cal[L] = {"flops": float(cca.get("flops", 0.0)),
                      "bytes": float(cca.get("bytes accessed", 0.0)),
                      "coll": float(ccoll["total"])}
        lf = R.full_depth(cfg)
        rec["calibrated"] = {
            "depths": [l1, l2], "full_depth": lf,
            "flops": R.extrapolate(cal[l1]["flops"], cal[l2]["flops"],
                                   l1, l2, lf),
            "bytes": R.extrapolate(cal[l1]["bytes"], cal[l2]["bytes"],
                                   l1, l2, lf),
            "coll": R.extrapolate(cal[l1]["coll"], cal[l2]["coll"],
                                  l1, l2, lf),
        }
        rec["model_flops"] = R.model_flops_for(cfg, cell)
        rec["chips"] = chips

    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name} ({sharding_mode})] "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s  "
              f"mem/dev est {est['total']/2**30:.2f} GiB "
              f"({'FITS' if rec['fits'] else 'OVER'}; "
              f"xla-cpu {per_dev/2**30:.1f})  "
              f"coll {coll['total']/2**20:.1f} MiB  mb={k}", flush=True)
        if calibrate:
            c = rec["calibrated"]
            print(f"    calibrated/dev: {c['flops']:.3e} FLOP "
                  f"{c['bytes']:.3e} B hbm  {c['coll']:.3e} B ici")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--sharding", default="auto",
                    choices=["auto", "tp", "fsdp", "fsdp_pod"])
    ap.add_argument("--calibrate", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch, cell, ok in all_cells(include_skips=True):
            if ok:
                cells.append((arch, cell.name))
            else:
                print(f"[skip] {arch} x {cell.name} "
                      f"(recorded skip: see DESIGN.md §Arch-applicability)")
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        if not cell_is_applicable(args.arch, args.shape):
            print(f"[skip] {args.arch} x {args.shape} is a recorded skip")
            return
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    results = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                # roofline calibration only on the single-pod mesh (the
                # multi-pod pass is the sharding-coherence proof)
                results.append(run_cell(arch, shape, multi_pod=mp,
                                        sharding_mode=args.sharding,
                                        calibrate=args.calibrate and not mp))
            except Exception as e:
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape,
                                "mesh": "2x16x16" if mp else "16x16",
                                "ok": False, "error": str(e)[:500]})
    n_ok = sum(r["ok"] for r in results)
    print(f"\n== {n_ok}/{len(results)} cells compiled ==")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
