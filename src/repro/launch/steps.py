"""Abstract step builders shared by dryrun.py and the roofline tool.

For every (arch, shape) cell this module produces the jitted-but-unlowered
step function plus ShapeDtypeStruct arguments and shardings:

  train cells   -> train_step(state, batch)
  prefill cells -> prefill_step(params, batch, caches)
  decode cells  -> serve_step(params, tokens, caches, pos)  (one new token
                   against a seq_len-deep KV cache, per the assignment)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell, TrainConfig
from repro.distributed.shardings import (batch_pspecs_for, cache_pspecs,
                                         make_dist, named, param_pspecs)
from repro.models import model as M
from repro.models.params import param_specs
from repro.optim.adamw import AdamWState, adamw_abstract
from repro.train.loop import TrainState, make_train_step


def _abstract_state(cfg: ModelConfig) -> TrainState:
    p = param_specs(cfg)
    return TrainState(p, adamw_abstract(p))


def auto_microbatch(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                    multi_pod: bool = False,
                    act_budget: float = 2 * 2 ** 30) -> int:
    """Gradient-accumulation factor so the per-device remat boundary
    activations (L x microbatch-tokens x d_model x 2B / dp) fit the budget.
    Returns a power-of-two divisor of the global batch."""
    dp = 1
    for a in (("pod", "data") if multi_pod else ("data",)):
        dp *= mesh.shape.get(a, 1)
    L = cfg.num_layers or (cfg.encoder_layers + cfg.decoder_layers)
    d = cfg.d_model
    per_k = L * cell.global_batch * cell.seq_len * d * 2 / dp
    k = 1
    while per_k / k > act_budget and k < cell.global_batch:
        k *= 2
    return k


def build_train(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                tcfg: Optional[TrainConfig] = None,
                multi_pod: bool = False):
    """Returns (jitted_fn, args, static_meta)."""
    tcfg = tcfg or TrainConfig()
    if tcfg.microbatch == 0:
        import dataclasses
        k = auto_microbatch(cfg, cell, mesh, multi_pod)
        tcfg = dataclasses.replace(tcfg, microbatch=k)
    step = make_train_step(cfg, tcfg, mesh, multi_pod)
    state = _abstract_state(cfg)
    batch = M.input_specs(cfg, cell)
    batch_sh = named(mesh, batch_pspecs_for(batch, mesh, multi_pod))
    # make_train_step already set state shardings; batch shardings ride in
    # via the arg shardings at lower time
    return step, (state, batch), {"batch_shardings": batch_sh}


def build_prefill(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                  multi_pod: bool = False, sharding_mode: str = "tp"):
    dist = make_dist(mesh)
    pspecs = param_pspecs(cfg, param_specs(cfg), sharding_mode, multi_pod,
                          mesh=mesh)
    params = param_specs(cfg)
    batch = M.input_specs(cfg, cell)
    caches = M.init_cache(cfg, cell.global_batch, cell.seq_len,
                          abstract=True)

    def prefill_step(p, b, c):
        return M.prefill(p, cfg, b, c, dist=dist)

    shardings = (named(mesh, pspecs),
                 named(mesh, batch_pspecs_for(batch, mesh, multi_pod)),
                 named(mesh, cache_pspecs(caches, mesh, multi_pod)))
    fn = jax.jit(prefill_step, in_shardings=shardings, donate_argnums=(2,))
    return fn, (params, batch, caches), {}


def build_decode(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                 multi_pod: bool = False, sharding_mode: str = "tp",
                 kv_seq_shard: bool = False):
    dist = make_dist(mesh)
    pspecs = param_pspecs(cfg, param_specs(cfg), sharding_mode, multi_pod,
                          mesh=mesh)
    params = param_specs(cfg)
    B, S = cell.global_batch, cell.seq_len
    caches = M.init_cache(cfg, B, S, abstract=True)
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)

    def serve_step(p, t, c, pos):
        return M.decode_step(p, cfg, t, c, pos, dist=dist)

    dp_axes = ("pod", "data") if multi_pod else ("data",)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape.get(a, 1)
    tok_spec = P(dp_axes, None) if B % dp == 0 else P(None, None)
    shardings = (named(mesh, pspecs),
                 NamedSharding(mesh, tok_spec),
                 named(mesh, cache_pspecs(caches, mesh, multi_pod,
                                          kv_seq_shard)),
                 None)
    fn = jax.jit(serve_step, in_shardings=shardings, donate_argnums=(2,))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return fn, (params, tokens, caches, pos), {}


def build_cell(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
               multi_pod: bool = False, sharding_mode: str = "tp",
               tcfg: Optional[TrainConfig] = None):
    if cell.kind == "train":
        tcfg = tcfg or TrainConfig(sharding_mode=sharding_mode)
        return build_train(cfg, cell, mesh, tcfg, multi_pod)
    if cell.kind == "prefill":
        return build_prefill(cfg, cell, mesh, multi_pod, sharding_mode)
    if cell.kind == "decode":
        kv_seq = cell.seq_len >= 200_000   # long-context: SP for the cache
        return build_decode(cfg, cell, mesh, multi_pod, sharding_mode,
                            kv_seq_shard=kv_seq)
    raise ValueError(cell.kind)
