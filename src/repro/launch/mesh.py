"""Production meshes.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod axis
is the slow (DCN/inter-pod ICI) dimension; batch shards over ("pod","data").

Functions, not module constants: importing this module must never touch
jax device state (smoke tests and benches run on 1 real CPU device; only
dryrun.py forces the 512-device platform).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 1):
    """Small mesh for in-test lowering on host platforms with few fake
    devices."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# fleet-serving mesh axis: camera groups shard over it (zero cross-group
# leakage by construction makes this axis embarrassingly parallel — the
# sharded super-launch has NO collectives on its hot path)
FLEET_AXIS = "shard"


def make_fleet_mesh(n_shards: int = 0):
    """1-D mesh over the ``"shard"`` axis for the sharded fleet runtime.

    ``n_shards`` = 0 uses every visible device.  On CPU hosts simulate
    multiple devices by exporting
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` BEFORE jax
    initializes (the tests/benches do this via subprocesses)."""
    avail = len(jax.devices())
    n = n_shards or avail
    if n > avail:
        raise ValueError(
            f"make_fleet_mesh({n_shards}): only {avail} device(s) visible; "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"before jax initializes to simulate more on CPU")
    return jax.make_mesh((n,), (FLEET_AXIS,))


# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
CHIPS_PER_POD = 256
HBM_PER_CHIP = 16 * 2 ** 30     # 16 GiB
