"""Production meshes.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod axis
is the slow (DCN/inter-pod ICI) dimension; batch shards over ("pod","data").

Functions, not module constants: importing this module must never touch
jax device state (smoke tests and benches run on 1 real CPU device; only
dryrun.py forces the 512-device platform).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 1):
    """Small mesh for in-test lowering on host platforms with few fake
    devices."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
CHIPS_PER_POD = 256
HBM_PER_CHIP = 16 * 2 ** 30     # 16 GiB
