import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing driver: the three chosen cells, each with an ordered
list of variants (paper-faithful baseline first, beyond-paper after).

Each variant re-runs the 4-point unrolled calibration
(launch/roofline_run.py) and reports the three roofline terms; the
EXPERIMENTS.md §Perf log records hypothesis -> predicted -> measured.

  PYTHONPATH=src python -m repro.launch.perf [--exp A|B|C] \
      --out results/perf.json
"""
import argparse
import dataclasses
import json
import traceback

from repro.configs.base import SHAPES, ShapeCell
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.roofline_run import run_cell


def _packed_cell(keep: float):
    def t(cell: ShapeCell) -> ShapeCell:
        s_kept = int(round(cell.seq_len * keep / 1024)) * 1024
        return ShapeCell(cell.name + f"_roi{keep:.2f}", s_kept,
                         cell.global_batch, cell.kind)
    return t


# experiment -> (arch, shape, [(label, kwargs), ...])
EXPERIMENTS = {
    # A: most collective-bound cell in the baseline table — rwkv6 train:
    # five TP activation all-reduces per layer dominate (74% of bound)
    "A": ("rwkv6-7b", "train_4k", [
        ("baseline_tp", {}),
        ("fsdp", dict(sharding_mode="fsdp")),
        ("fsdp+no_remat", dict(sharding_mode="fsdp",
                               tcfg_kwargs={"remat": "none"})),
        ("dp_only+no_remat", dict(sharding_mode="dp_only",
                                  tcfg_kwargs={"remat": "none"})),
    ]),
    # A2: the big dense train cell (memory-dominant, collective #2) —
    # the paper-era TP baseline vs beyond-paper sharding/attention changes
    "A2": ("deepseek-67b", "train_4k", [
        ("baseline_tp", {}),
        ("fsdp", dict(sharding_mode="fsdp")),
        ("tp+causal_skip", dict(tcfg_kwargs={"causal_skip": True})),
        ("fsdp+causal_skip", dict(sharding_mode="fsdp",
                                  tcfg_kwargs={"causal_skip": True})),
    ]),
    # B: the paper's own technique — VLM prefill over the fleet stream;
    # keep=0.42 is the measured set-cover fleet density
    "B": ("internvl2-26b", "prefill_32k", [
        ("baseline_dense", {}),
        ("roi_packed_0.42", dict(cell_transform=_packed_cell(0.42))),
        ("roi_packed_0.42+fsdp", dict(cell_transform=_packed_cell(0.42),
                                      sharding_mode="fsdp")),
    ]),
    # C: worst roofline fraction — decode against a 32k cache
    "C": ("deepseek-67b", "decode_32k", [
        ("baseline", {}),
        ("grouped_attn", dict(cfg_transform=lambda c: c.replace(
            decode_grouped_attn=True))),
        ("grouped+fp8_kv", dict(cfg_transform=lambda c: c.replace(
            decode_grouped_attn=True, kv_cache_dtype="float8_e4m3fn"))),
    ]),
}


def terms(rec):
    return (rec["flops_per_dev"] / PEAK_FLOPS_BF16,
            rec["hbm_bytes_per_dev"] / HBM_BW,
            rec["coll_bytes_per_dev"] / ICI_BW)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default=None, choices=list(EXPERIMENTS))
    ap.add_argument("--out", default="results/perf.json")
    args = ap.parse_args()
    exps = [args.exp] if args.exp else list(EXPERIMENTS)
    all_recs = []
    for e in exps:
        arch, shape, variants = EXPERIMENTS[e]
        print(f"\n=== experiment {e}: {arch} x {shape} ===", flush=True)
        base_bound = None
        for label, kw in variants:
            try:
                rec = run_cell(arch, shape, label=label, verbose=False, **kw)
            except Exception as ex:
                traceback.print_exc()
                all_recs.append({"exp": e, "label": label, "ok": False,
                                 "error": str(ex)[:300]})
                continue
            rec["exp"] = e
            tc, tm, tx = terms(rec)
            bound = max(tc, tm, tx)
            if base_bound is None:
                base_bound = bound
            dom = ("compute", "memory", "collective")[
                (tc, tm, tx).index(bound)]
            print(f"  {label:22s} c={tc:9.3e} m={tm:9.3e} x={tx:9.3e} "
                  f"dom={dom:10s} bound={bound:9.3e} "
                  f"({base_bound/bound:4.2f}x vs base)", flush=True)
            rec.update(t_compute=tc, t_memory=tm, t_collective=tx,
                       dominant=dom, bound=bound,
                       speedup_vs_base=base_bound / bound)
            all_recs.append(rec)
    with open(args.out, "w") as f:
        json.dump(all_recs, f, indent=1)
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
