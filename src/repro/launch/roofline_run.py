import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline calibration runner.

Per (arch x shape) cell, compiles FOUR fully-unrolled reduced variants —
(L1,S1) (L2,S1) (L1,S2) (L2,S2) with a reduced batch — where XLA's
cost_analysis is exact (models/unroll.py), then fits

  train/prefill:  per_layer(S) = c1*S + c2*S^2      (token-linear + attn)
  decode:         per_layer(S) = c0 + c1*S          (const + cache reads)

and extrapolates to the full depth/sequence/batch.  The same fit runs for
HLO flops, HLO bytes, and parsed collective bytes (per-layer collectives
inside the scan are otherwise counted once).

  PYTHONPATH=src python -m repro.launch.roofline_run --out results/roofline.json
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs.base import SHAPES, ShapeCell, TrainConfig
from repro.configs.registry import all_cells, get_config
from repro.launch import roofline as R
from repro.launch.mesh import CHIPS_PER_POD, make_production_mesh
from repro.launch.steps import build_cell
from repro.models import unroll as UR


def calib_seqs(cell: ShapeCell, cfg=None):
    if cfg is not None and cfg.family == "ssm" and cell.kind != "decode":
        # attention-free: cost is linear in S, and the chunked-wkv bodies
        # unroll per chunk — tiny S keeps the compile tractable
        return 256, 512
    if cell.kind == "train":
        return 1024, 2048
    if cell.kind == "prefill":
        return 2048, 4096
    if cell.seq_len >= 200_000:
        return 8192, 16384
    return 4096, 8192


def calib_batch(cell: ShapeCell, dp: int = 16) -> int:
    if cell.global_batch <= dp:
        return cell.global_batch
    return dp


def _cost(cfg, cell, mesh, sharding_mode, tcfg_kwargs=None):
    tcfg = TrainConfig(microbatch=1, sharding_mode=sharding_mode,
                       **(tcfg_kwargs or {})) \
        if cell.kind == "train" else None
    with UR.unrolled():
        fn, args, _ = build_cell(cfg, cell, mesh, False, sharding_mode,
                                 tcfg=tcfg)
        compiled = fn.lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    coll = R.collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll["total"])}


def _fit_and_extrapolate(c, l1, l2, s1, s2, lf, sf, bscale, kind,
                         affine: bool = False):
    """c[(L,S)] -> full-cell estimate for one metric.

    affine=True (attention-free archs): per_layer(S) = w + a*S — exact for
    linear-cost layers whose weight reads do not scale with S (the
    through-origin quadratic would extrapolate the constant term to
    negative curvature)."""
    out = {}
    for key in ("flops", "bytes", "coll"):
        pl_s1 = (c[(l2, s1)][key] - c[(l1, s1)][key]) / (l2 - l1)
        pl_s2 = (c[(l2, s2)][key] - c[(l1, s2)][key]) / (l2 - l1)
        head_s1 = max(c[(l1, s1)][key] - l1 * pl_s1, 0.0)
        if kind == "decode" or affine:
            # per_layer(S) = c0 + c1*S
            c1 = (pl_s2 - pl_s1) / (s2 - s1)
            c0 = pl_s1 - c1 * s1
            per_layer_full = c0 + c1 * sf
            head_full = head_s1          # decode head is S-independent
        else:
            # per_layer(S) = c1*S + c2*S^2
            # solve from two points
            a1, a2 = pl_s1 / s1, pl_s2 / s2
            c2_ = (a2 - a1) / (s2 - s1)
            c1 = a1 - c2_ * s1
            per_layer_full = c1 * sf + c2_ * sf * sf
            head_full = head_s1 * (sf / s1)   # embed/CE are token-linear
        out[key] = max(lf * per_layer_full + head_full, 0.0) * bscale
    return out


def run_cell(arch: str, shape_name: str, sharding_mode: str = "auto",
             verbose: bool = True, cfg_transform=None, tcfg_kwargs=None,
             cell_transform=None, label: str = "") -> dict:
    cfg = get_config(arch)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    cell = SHAPES[shape_name]
    if cell_transform is not None:
        cell = cell_transform(cell)
    mesh = make_production_mesh()
    if sharding_mode == "auto":
        from repro.launch.memory import estimate_cell
        from repro.launch.steps import auto_microbatch
        k0 = auto_microbatch(cfg, cell, mesh) if cell.kind == "train" else 1
        est0 = estimate_cell(cfg, cell, mesh, False, "tp", microbatch=k0)
        sharding_mode = "tp" if est0["fits"] else "fsdp"

    l1, l2 = R.calib_depths(cfg)
    s1, s2 = calib_seqs(cell, cfg)
    bcal = calib_batch(cell)
    t0 = time.time()
    c = {}
    for L in (l1, l2):
        for S in (s1, s2):
            ccfg = R.with_depth(cfg, L)
            ccell = ShapeCell(cell.name, S, bcal, cell.kind)
            c[(L, S)] = _cost(ccfg, ccell, mesh, sharding_mode,
                              tcfg_kwargs)
    lf = R.full_depth(cfg)
    bscale = cell.global_batch / bcal
    est = _fit_and_extrapolate(c, l1, l2, s1, s2, lf, cell.seq_len, bscale,
                               cell.kind, affine=cfg.family == "ssm")
    rec = {"arch": arch, "shape": shape_name, "sharding": sharding_mode,
           "label": label, "ok": True, "chips": CHIPS_PER_POD,
           "model_flops": R.model_flops_for(cfg, cell),
           "calib_points": {f"L{L}_S{S}": v for (L, S), v in c.items()},
           "flops_per_dev": est["flops"], "hbm_bytes_per_dev": est["bytes"],
           "coll_bytes_per_dev": est["coll"],
           "wall_s": round(time.time() - t0, 1)}
    if verbose:
        t = R.RooflineTerms(arch, shape_name, "16x16", est["flops"],
                            est["bytes"], est["coll"], {},
                            rec["model_flops"], CHIPS_PER_POD)
        print(t.row(), f"  ({rec['wall_s']}s)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    args = ap.parse_args()
    results = []
    cells = [(a, c.name) for a, c, ok in all_cells() if ok]
    if args.arch:
        wanted = set(args.arch.split(","))
        cells = [(a, s) for a, s in cells if a in wanted
                 and (not args.shape or s == args.shape)]
    for arch, shape in cells:
        try:
            results.append(run_cell(arch, shape))
        except Exception as e:
            traceback.print_exc()
            results.append({"arch": arch, "shape": shape, "ok": False,
                            "error": str(e)[:500]})
        with open(args.out, "w") as f:     # incremental: survive kills
            json.dump(results, f, indent=1)
    n_ok = sum(r["ok"] for r in results)
    print(f"\n{n_ok}/{len(results)} cells calibrated -> {args.out}")


if __name__ == "__main__":
    main()
