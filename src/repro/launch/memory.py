"""Analytic per-device HBM estimator for the dry-run cells.

Why not trust compiled.memory_analysis() alone: the container lowers for
XLA:CPU, whose float-normalization pass upcasts every bf16 dot to f32 and
materializes f32 copies of weight stacks and KV caches.  Those buffers do
not exist on TPU (native bf16 MXU), so the CPU numbers overstate HBM by up
to 2x.  This estimator prices exactly what the TPU program holds, from the
same PartitionSpecs the dry-run lowers with; EXPERIMENTS.md reports both.

Accounting (per device):
  params        — by param tree, divided by each leaf's shard count
  grads + opt   — train only: fp32 accumulator + m/v in the ZeRO sharding
  activations   — train: remat boundaries L x (B/k) x S x d x 2B / dp
  kv caches     — serve: cache tree, divided by shard counts
  workspace     — one transformer block's transient working set
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, ShapeCell
from repro.distributed.shardings import param_pspecs
from repro.launch.mesh import HBM_PER_CHIP
from repro.models import model as M
from repro.models.params import param_specs


def _shards(mesh: Mesh, spec) -> int:
    n = 1
    for entry in spec:
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            n *= mesh.shape.get(a, 1)
    return n


def _tree_bytes(specs: Dict, pspecs: Dict, mesh: Mesh,
                dtype_bytes=None) -> float:
    total = 0.0
    for k, v in specs.items():
        nb = dtype_bytes if dtype_bytes else v.dtype.itemsize
        total += float(np.prod(v.shape)) * nb / _shards(mesh, pspecs[k])
    return total


def estimate_cell(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                  multi_pod: bool = False, sharding_mode: str = "tp",
                  microbatch: int = 1) -> Dict[str, float]:
    specs = param_specs(cfg)
    pspecs = param_pspecs(cfg, specs, sharding_mode, multi_pod, mesh=mesh)
    opt_mode = "fsdp_pod" if multi_pod else "fsdp"
    ospecs = param_pspecs(cfg, specs, opt_mode, multi_pod, mesh=mesh)
    dp = 1
    for a in (("pod", "data") if multi_pod else ("data",)):
        dp *= mesh.shape.get(a, 1)

    out = {"params": _tree_bytes(specs, pspecs, mesh)}
    d = cfg.d_model
    L = cfg.num_layers or (cfg.encoder_layers + cfg.decoder_layers)

    if cell.kind == "train":
        out["grads_fp32"] = _tree_bytes(specs, ospecs, mesh, 4)
        out["opt_m_v"] = 2 * out["grads_fp32"]
        mb_tokens = cell.global_batch * cell.seq_len / max(microbatch, 1)
        out["act_boundaries"] = L * mb_tokens * d * 2 / dp
        # transient: one block's internals for the rematerialized backward
        width = max(cfg.d_ff, cfg.moe_d_ff * cfg.experts_per_token
                    + cfg.shared_d_ff, 1)
        out["workspace"] = mb_tokens * (2 * d + 2 * width) * 2 / dp
    else:
        from jax.sharding import PartitionSpec
        from jax.tree_util import tree_leaves
        from repro.distributed.shardings import cache_pspecs
        caches = M.init_cache(cfg, cell.global_batch, cell.seq_len,
                              abstract=True)
        kv_seq = cell.kind == "decode" and cell.seq_len >= 200_000
        cspecs = cache_pspecs(caches, mesh, multi_pod, kv_seq_shard=kv_seq)
        total = 0.0
        for leaf, sp in zip(tree_leaves(caches), tree_leaves(
                cspecs, is_leaf=lambda x: isinstance(x, PartitionSpec))):
            total += float(np.prod(leaf.shape)) * leaf.dtype.itemsize \
                / _shards(mesh, sp)
        out["kv_cache"] = total
        toks = cell.global_batch * (cell.seq_len if cell.kind == "prefill"
                                    else 1)
        out["workspace"] = max(toks * 4 * d * 2 / dp, 64 * 2 ** 20)

    out["total"] = sum(out.values())
    out["fits"] = out["total"] <= HBM_PER_CHIP
    return out
