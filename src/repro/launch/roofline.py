"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), all computed per device — verified
convention: compiled.cost_analysis() reports PER-DEVICE flops/bytes for an
SPMD-partitioned module, and compiled.as_text() is the per-device program:

    compute    = flops_per_device / PEAK_FLOPS_BF16
    memory     = hbm_bytes_per_device / HBM_BW
    collective = ici_bytes_per_device / ICI_BW

ici bytes = sum of collective-op result sizes in the partitioned HLO
(all-reduce counted twice: ring reduce-scatter + all-gather phases).

Scan calibration: XLA's cost model counts a lax.scan body ONCE, not
x trip-count.  Every trunk here scans over layers, so raw numbers omit
(L-1)/L of the work.  We therefore compile each cell at two small layer
counts, fit cost(L) = slope*L + intercept, and extrapolate to the full
depth.  Memory analysis (fits-per-device) always comes from the full-depth
compile.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeCell
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(.+?)\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes by collective kind, from partitioned HLO text."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        nbytes = _shape_bytes(m.group(1))   # result shape(s), incl. tuples
        if kind == "all-reduce":
            nbytes *= 2           # ring: reduce-scatter + all-gather phases
        out[kind] = out.get(kind, 0.0) + nbytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclass
class RooflineTerms:
    arch: str
    cell: str
    mesh: str
    flops: float                 # per device
    hbm_bytes: float
    coll_bytes: float
    coll_by_kind: Dict[str, float]
    model_flops: float           # analytic 6*N*D (global)
    chips: int
    calibrated: bool = True
    mem_per_device: float = 0.0  # arg+temp+output bytes (full compile)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / global HLO flops (remat/redundancy waste catch)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term bound that is useful model compute:
        (model_flops/chips/peak) / bound_time — the score we hillclimb."""
        ideal = self.model_flops / self.chips / PEAK_FLOPS_BF16
        return ideal / self.bound_time if self.bound_time else 0.0

    def row(self) -> str:
        return (f"{self.arch:22s} {self.cell:12s} {self.mesh:9s} "
                f"c={self.t_compute:9.3e} m={self.t_memory:9.3e} "
                f"x={self.t_collective:9.3e} dom={self.dominant:10s} "
                f"useful={self.useful_ratio:6.3f} "
                f"roof={self.roofline_fraction:6.3f}")


def model_flops_for(cfg: ModelConfig, cell: ShapeCell) -> float:
    """Analytic useful FLOPs per step: 6*N*D train, 2*N*D forward-only
    (D = tokens processed; decode: one token per sequence)."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        toks = cell.global_batch * cell.seq_len
        return 6.0 * n * toks
    if cell.kind == "prefill":
        toks = cell.global_batch * cell.seq_len
        return 2.0 * n * toks
    toks = cell.global_batch * 1
    return 2.0 * n * toks


# ---------------------------------------------------------------------------
# calibration depths per arch family (structure-respecting small configs)
# ---------------------------------------------------------------------------

def calib_depths(cfg: ModelConfig) -> Tuple[int, int]:
    if cfg.family in ("dense", "vlm") and cfg.global_every > 1:
        g = cfg.global_every
        return g, 2 * g
    if cfg.family == "moe" and cfg.first_dense_layers:
        f = cfg.first_dense_layers
        return f + 1, f + 3
    if cfg.family == "hybrid":
        a = cfg.attn_every
        return a, 2 * a
    if cfg.family == "encdec":
        return 2, 4            # encoder+decoder layers together
    return 1, 2


def with_depth(cfg: ModelConfig, L: int) -> ModelConfig:
    if cfg.family == "encdec":
        return cfg.replace(num_layers=2 * L, encoder_layers=L,
                           decoder_layers=L)
    if cfg.family == "hybrid":
        blocks = max(L // cfg.attn_every, 1)
        return cfg.replace(num_layers=L,
                           num_shared_attn_blocks=min(
                               cfg.num_shared_attn_blocks, blocks))
    return cfg.replace(num_layers=L)


def full_depth(cfg: ModelConfig) -> int:
    if cfg.family == "encdec":
        return cfg.encoder_layers
    return cfg.num_layers


def extrapolate(c1: float, c2: float, l1: int, l2: int, lf: int) -> float:
    slope = (c2 - c1) / max(l2 - l1, 1)
    intercept = c1 - slope * l1
    return max(slope * lf + intercept, 0.0)
