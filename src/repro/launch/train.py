"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube3-4b \
      --smoke --steps 50 --batch 8 --seq 256 [--workdir ckpts] \
      [--ckpt-every 20] [--fail-at 30]  [--mesh d,m]

--smoke uses the reduced config (CPU-runnable); the full configs are for
real pods.  --fail-at injects a fault to drill the restore path.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import TrainConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.distributed.fault import FaultInjector
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="h2o-danube3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--sharding", default="tp",
                    choices=["tp", "fsdp", "fsdp_pod"])
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--mesh", default=None,
                    help="data,model (requires enough devices)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 10, 1),
                       microbatch=args.microbatch,
                       sharding_mode=args.sharding,
                       grad_compression=args.grad_compression)
    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split(","))
        mesh = jax.make_mesh((d, m), ("data", "model"))
    injector = FaultInjector((args.fail_at,)) if args.fail_at else None
    report = train(cfg, tcfg, steps=args.steps,
                   batch_shape=(args.batch, args.seq), mesh=mesh,
                   workdir=args.workdir, ckpt_every=args.ckpt_every,
                   injector=injector)
    print(f"\nfinal loss {report.final_loss:.4f} over {report.steps_run} "
          f"steps; restarts={report.restarts}; "
          f"median step {report.median_step_s*1e3:.0f} ms; "
          f"stragglers={len(report.straggler_events)}")


if __name__ == "__main__":
    main()
