"""Serving launcher: batched requests against a (smoke) model, with the
CrossRoI RoI-sparsified prefill on multi-camera patch streams.

  PYTHONPATH=src python -m repro.launch.serve --arch internvl2-26b --smoke \
      --requests 4 --roi
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ServeConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.models.params import init_params
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="h2o-danube3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=192)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--roi", action="store_true",
                    help="RoI-sparsified prefill (keep-list packing)")
    ap.add_argument("--keep-frac", type=float, default=0.5)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, ServeConfig(max_batch=4,
                                            roi_sparsity=args.roi), params)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        toks = rng.integers(0, cfg.vocab_size,
                            args.prompt_len).astype(np.int32)
        keep = rng.random(args.prompt_len) < args.keep_frac if args.roi \
            else None
        reqs.append(Request(i, tokens=toks, keep=keep,
                            max_new_tokens=args.new_tokens))

    t0 = time.time()
    out = engine.serve(reqs, greedy_steps=args.new_tokens)
    dt = time.time() - t0
    for rid, toks in sorted(out.items()):
        print(f"req {rid}: {toks.tolist()}")
    n_tok = sum(len(t) for t in out.values())
    print(f"{n_tok} tokens in {dt:.2f}s "
          f"({'RoI-packed' if args.roi else 'dense'} prefill)")


if __name__ == "__main__":
    main()
