"""Sharded checkpointing with elastic restore.

Layout per step::

    <dir>/step_000123/
        MANIFEST.json     — step, tree structure, shapes/dtypes, mesh note
        arrays/<name>.npy — one file per leaf (full logical array)
        COMMIT            — written last; a step without it is torn and
                            ignored (crash-safe without atomic renames)

Design choices for the 1000+-node posture:
  * restore is *elastic*: arrays are loaded as full logical values and
    re-placed with the target mesh's NamedShardings — a different device
    count/mesh shape than the saver's is fine (re-mesh after failure).
  * save gathers per-leaf to host then writes; an async flag moves the
    write to a background thread (step N+1 overlaps the I/O of step N).
    On a real cluster each host would write only its addressable shards;
    the manifest/commit protocol is unchanged.
  * data pipeline needs no state: batches are a pure function of the step
    counter (data/lm.py), so the manifest's step is sufficient for replay.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[name] = leaf
    return flat


def save_checkpoint(directory: str, step: int, trees: Dict[str, Any]):
    """trees: {"params": ..., "opt": ..., ...} pytrees of jax/np arrays."""
    d = os.path.join(directory, f"step_{step:06d}")
    arrays = os.path.join(d, "arrays")
    os.makedirs(arrays, exist_ok=True)
    manifest = {"step": step, "groups": {}}
    for group, tree in trees.items():
        flat = _flatten(tree)
        names = {}
        for name, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            fname = f"{group}__{name.replace('/', '__')}.npy"
            dtype_name = str(arr.dtype)
            if arr.dtype.kind == "V" or "bfloat16" in dtype_name:
                # numpy cannot serialize bf16: store the raw uint16 view
                dtype_name = "bfloat16"
                arr = arr.view(np.uint16)
            np.save(os.path.join(arrays, fname), arr)
            names[name] = {"file": fname, "shape": list(arr.shape),
                           "dtype": dtype_name}
        manifest["groups"][group] = names
    with open(os.path.join(d, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(d, "COMMIT"), "w") as f:
        f.write("ok")


def _complete_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
                os.path.join(directory, name, "COMMIT")):
            steps.append(int(name.split("_")[1]))
    return sorted(steps)


def load_checkpoint(directory: str, template: Dict[str, Any],
                    step: Optional[int] = None,
                    shardings: Optional[Dict[str, Any]] = None
                    ) -> Tuple[int, Dict[str, Any]]:
    """Restore trees shaped like ``template``; optionally place each group
    with a NamedSharding tree (elastic re-mesh).  Returns (step, trees)."""
    steps = _complete_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no complete checkpoint under {directory}")
    step = steps[-1] if step is None else step
    d = os.path.join(directory, f"step_{step:06d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)
    out = {}
    for group, tmpl in template.items():
        names = manifest["groups"][group]
        flat_tmpl = _flatten(tmpl)
        shard_tree = _flatten(shardings[group]) if shardings and \
            shardings.get(group) is not None else None
        restored = {}
        for name, leaf in flat_tmpl.items():
            info = names[name]
            arr = np.load(os.path.join(d, "arrays", info["file"]))
            if info["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            want = np.asarray(jax.eval_shape(lambda: leaf)) \
                if not hasattr(leaf, "shape") else leaf
            assert tuple(arr.shape) == tuple(want.shape), \
                f"{group}/{name}: ckpt {arr.shape} vs template {want.shape}"
            if shard_tree is not None and name in shard_tree:
                restored[name] = jax.device_put(arr, shard_tree[name])
            else:
                restored[name] = jax.numpy.asarray(arr)
        # re-assemble using the template's structure
        treedef = jax.tree_util.tree_structure(tmpl)
        keys = list(_flatten(tmpl).keys())
        restored_leaves = [restored[k] for k in keys]
        out[group] = jax.tree_util.tree_unflatten(treedef, restored_leaves)
    return step, out


@dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_save: bool = True
    _thread: Optional[threading.Thread] = field(default=None, repr=False)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, trees: Dict[str, Any]):
        self.wait()
        # snapshot to host before returning (async only the file I/O)
        host = {g: jax.tree.map(lambda x: np.asarray(jax.device_get(x)), t)
                for g, t in trees.items()}

        def run():
            save_checkpoint(self.directory, step, host)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()
        else:
            run()

    def restore(self, template, shardings=None, step=None):
        self.wait()
        return load_checkpoint(self.directory, template, step, shardings)

    def latest_step(self) -> Optional[int]:
        self.wait()
        steps = _complete_steps(self.directory)
        return steps[-1] if steps else None

    def _gc(self):
        steps = _complete_steps(self.directory)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:06d}"),
                          ignore_errors=True)
