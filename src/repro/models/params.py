"""Parameter trees: one declarative builder emits either real initialized
arrays (smoke tests / examples) or ShapeDtypeStructs (dry-run lowering).

Layer stacks carry a leading L dim for lax.scan. Naming is stable and is what
``distributed/shardings.py`` pattern-matches to assign PartitionSpecs.
"""
from __future__ import annotations

import math
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.rwkv import LORA_DECAY, LORA_MIX
from repro.models import ssm as ssm_mod

Creator = Callable[[str, tuple, jnp.dtype, float], object]


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Block param groups
# ---------------------------------------------------------------------------

def _attn_block(cfg: ModelConfig, mk: Creator, L: int, prefix: str,
                biases: bool = False, qk_norm: bool = False) -> Dict:
    d, dt = cfg.d_model, _dt(cfg)
    qd, kvd = cfg.q_dim, cfg.kv_dim
    p = {
        f"{prefix}wq": mk(f"{prefix}wq", (L, d, qd), dt, d),
        f"{prefix}wk": mk(f"{prefix}wk", (L, d, kvd), dt, d),
        f"{prefix}wv": mk(f"{prefix}wv", (L, d, kvd), dt, d),
        f"{prefix}wo": mk(f"{prefix}wo", (L, qd, d), dt, qd),
    }
    if biases:
        p[f"{prefix}bq"] = mk(f"{prefix}bq", (L, qd), dt, 0)
        p[f"{prefix}bv"] = mk(f"{prefix}bv", (L, kvd), dt, 0)
        p[f"{prefix}bo"] = mk(f"{prefix}bo", (L, d), dt, 0)
    if qk_norm:
        p[f"{prefix}qnorm"] = mk(f"{prefix}qnorm", (L, cfg.head_dim), jnp.float32, -1)
        p[f"{prefix}knorm"] = mk(f"{prefix}knorm", (L, cfg.head_dim), jnp.float32, -1)
    return p


def _glu_mlp_block(cfg: ModelConfig, mk: Creator, L: int, ff: int,
                   prefix: str = "") -> Dict:
    d, dt = cfg.d_model, _dt(cfg)
    return {
        f"{prefix}w1": mk(f"{prefix}w1", (L, d, ff), dt, d),
        f"{prefix}w3": mk(f"{prefix}w3", (L, d, ff), dt, d),
        f"{prefix}w2": mk(f"{prefix}w2", (L, ff, d), dt, ff),
    }


def _gelu_mlp_block(cfg: ModelConfig, mk: Creator, L: int, prefix: str) -> Dict:
    d, ff, dt = cfg.d_model, cfg.d_ff, _dt(cfg)
    return {
        f"{prefix}w1": mk(f"{prefix}w1", (L, d, ff), dt, d),
        f"{prefix}b1": mk(f"{prefix}b1", (L, ff), dt, 0),
        f"{prefix}w2": mk(f"{prefix}w2", (L, ff, d), dt, ff),
        f"{prefix}b2": mk(f"{prefix}b2", (L, d), dt, 0),
    }


def _norms(cfg: ModelConfig, mk: Creator, L: int, names, biases=False) -> Dict:
    d = cfg.d_model
    p = {}
    for n in names:
        p[n] = mk(n, (L, d), jnp.float32, -1)
        if biases:
            p[n + "_b"] = mk(n + "_b", (L, d), jnp.float32, 0)
    return p


def _dense_stack(cfg: ModelConfig, mk: Creator, L: int,
                 qk_norm: bool = False) -> Dict:
    p = {}
    p.update(_attn_block(cfg, mk, L, "", qk_norm=qk_norm))
    p.update(_glu_mlp_block(cfg, mk, L, cfg.d_ff))
    p.update(_norms(cfg, mk, L, ["ln1", "ln2"]))
    return p


def _moe_stack(cfg: ModelConfig, mk: Creator, L: int) -> Dict:
    d, dt = cfg.d_model, _dt(cfg)
    E, Fe = cfg.num_experts, cfg.moe_d_ff
    p = {}
    p.update(_attn_block(cfg, mk, L, "", qk_norm=cfg.name.startswith("qwen3")))
    p.update(_norms(cfg, mk, L, ["ln1", "ln2"]))
    p["router"] = mk("router", (L, d, E), jnp.float32, d)
    p["moe_wg"] = mk("moe_wg", (L, E, d, Fe), dt, d)
    p["moe_wu"] = mk("moe_wu", (L, E, d, Fe), dt, d)
    p["moe_wd"] = mk("moe_wd", (L, E, Fe, d), dt, Fe)
    if cfg.num_shared_experts:
        Fs = cfg.shared_d_ff
        p["shared_wg"] = mk("shared_wg", (L, d, Fs), dt, d)
        p["shared_wu"] = mk("shared_wu", (L, d, Fs), dt, d)
        p["shared_wd"] = mk("shared_wd", (L, Fs, d), dt, Fs)
    return p


def _mamba_stack(cfg: ModelConfig, mk: Creator, L: int) -> Dict:
    d, dt = cfg.d_model, _dt(cfg)
    inner, N, H = cfg.ssm_inner, cfg.ssm_state_dim, cfg.ssm_num_heads
    cd = ssm_mod.conv_dim(cfg)
    return {
        "m_in": mk("m_in", (L, d, 2 * inner + 2 * N + H), dt, d),
        "m_conv_w": mk("m_conv_w", (L, cfg.ssm_conv_width, cd), jnp.float32, cfg.ssm_conv_width),
        "m_conv_b": mk("m_conv_b", (L, cd), jnp.float32, 0),
        "m_A_log": mk("m_A_log", (L, H), jnp.float32, -2),  # special init
        "m_D": mk("m_D", (L, H), jnp.float32, -1),
        "m_dt_bias": mk("m_dt_bias", (L, H), jnp.float32, 0),
        "m_norm": mk("m_norm", (L, inner), jnp.float32, -1),
        "m_out": mk("m_out", (L, inner, d), dt, inner),
        "m_ln": mk("m_ln", (L, d), jnp.float32, -1),
    }


def _rwkv_stack(cfg: ModelConfig, mk: Creator, L: int) -> Dict:
    d, dt = cfg.d_model, _dt(cfg)
    H, P = cfg.ssm_num_heads, cfg.ssm_head_dim
    F = cfg.d_ff
    return {
        "ln1_w": mk("ln1_w", (L, d), jnp.float32, -1),
        "ln2_w": mk("ln2_w", (L, d), jnp.float32, -1),
        "maa_x": mk("maa_x", (L, d), jnp.float32, 0),
        "maa_w1": mk("maa_w1", (L, d, 5 * LORA_MIX), dt, d),
        "maa_w2": mk("maa_w2", (L, 5, LORA_MIX, d), dt, LORA_MIX),
        "maa_wkvrg": mk("maa_wkvrg", (L, 5, d), jnp.float32, 0),
        "decay_base": mk("decay_base", (L, d), jnp.float32, -2),
        "decay_w1": mk("decay_w1", (L, d, LORA_DECAY), dt, d),
        "decay_w2": mk("decay_w2", (L, LORA_DECAY, d), dt, LORA_DECAY),
        "u": mk("u", (L, H, P), jnp.float32, 0),
        "wr": mk("wr", (L, d, d), dt, d),
        "wk": mk("wk", (L, d, d), dt, d),
        "wv": mk("wv", (L, d, d), dt, d),
        "wg": mk("wg", (L, d, d), dt, d),
        "wo": mk("wo", (L, d, d), dt, d),
        "gn_w": mk("gn_w", (L, d), jnp.float32, -1),
        "cmix_mu_k": mk("cmix_mu_k", (L, d), jnp.float32, 0),
        "cmix_mu_r": mk("cmix_mu_r", (L, d), jnp.float32, 0),
        "cmix_k": mk("cmix_k", (L, d, F), dt, d),
        "cmix_v": mk("cmix_v", (L, F, d), dt, F),
        "cmix_r": mk("cmix_r", (L, d, d), dt, d),
    }


# ---------------------------------------------------------------------------
# Family trees
# ---------------------------------------------------------------------------

def param_tree(cfg: ModelConfig, mk: Creator) -> Dict:
    d, dt, V = cfg.d_model, _dt(cfg), cfg.vocab_size
    p: Dict = {"embed": mk("embed", (V, d), dt, 1.0)}
    if not cfg.tie_embeddings:
        p["unembed"] = mk("unembed", (V, d), dt, d)
    p["final_norm"] = mk("final_norm", (d,), jnp.float32, -1)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        if cfg.global_every > 1:  # gemma3-style local/global pattern
            n_super = cfg.num_layers // cfg.global_every
            n_local_per = cfg.global_every - 1
            n_trail = cfg.num_layers - n_super * cfg.global_every
            local = {f"local_{k}": v for k, v in
                     _dense_stack(cfg, mk, n_super * n_local_per).items()}
            glob = {f"global_{k}": v for k, v in
                    _dense_stack(cfg, mk, n_super).items()}
            p.update(local)
            p.update(glob)
            if n_trail:
                p.update({f"trail_{k}": v for k, v in
                          _dense_stack(cfg, mk, n_trail).items()})
        else:
            p.update({f"blocks_{k}": v for k, v in
                      _dense_stack(cfg, mk, cfg.num_layers).items()})
        if cfg.frontend == "vit_patch":
            p["frontend_w"] = mk("frontend_w", (cfg.frontend_dim, d), dt, cfg.frontend_dim)
            p["frontend_b"] = mk("frontend_b", (d,), dt, 0)
    elif fam == "moe":
        nd = cfg.first_dense_layers
        if nd:
            dense = _dense_stack(cfg, mk, nd)
            p.update({f"dense_{k}": v for k, v in dense.items()})
        p.update({f"blocks_{k}": v for k, v in
                  _moe_stack(cfg, mk, cfg.num_layers - nd).items()})
    elif fam == "ssm":
        p.update({f"blocks_{k}": v for k, v in
                  _rwkv_stack(cfg, mk, cfg.num_layers).items()})
        p["ln_in"] = mk("ln_in", (d,), jnp.float32, -1)  # rwkv pre-ln
    elif fam == "hybrid":
        p.update({f"blocks_{k}": v for k, v in
                  _mamba_stack(cfg, mk, cfg.num_layers).items()})
        nb = cfg.num_shared_attn_blocks
        shared = {}
        shared.update(_attn_block(cfg, mk, nb, "sa_"))
        shared.update(_glu_mlp_block(cfg, mk, nb, cfg.d_ff, "sa_"))
        shared.update(_norms(cfg, mk, nb, ["sa_ln1", "sa_ln2"]))
        p.update(shared)
    elif fam == "encdec":
        enc = {}
        enc.update(_attn_block(cfg, mk, cfg.encoder_layers, "e_", biases=True))
        enc.update(_gelu_mlp_block(cfg, mk, cfg.encoder_layers, "e_mlp_"))
        enc.update(_norms(cfg, mk, cfg.encoder_layers, ["e_ln1", "e_ln2"], biases=True))
        dec = {}
        dec.update(_attn_block(cfg, mk, cfg.decoder_layers, "d_", biases=True))
        dec.update(_attn_block(cfg, mk, cfg.decoder_layers, "x_", biases=True))
        dec.update(_gelu_mlp_block(cfg, mk, cfg.decoder_layers, "d_mlp_"))
        dec.update(_norms(cfg, mk, cfg.decoder_layers,
                          ["d_ln1", "d_ln2", "d_ln3"], biases=True))
        p.update(enc)
        p.update(dec)
        p["enc_final_norm_b"] = mk("enc_final_norm_b", (d,), jnp.float32, 0)
        p["enc_final_norm"] = mk("enc_final_norm", (d,), jnp.float32, -1)
        p["final_norm_b"] = mk("final_norm_b", (d,), jnp.float32, 0)
        p["dec_pos"] = mk("dec_pos", (cfg.max_target_len, d), dt, 1.0)
        if cfg.frontend == "conv_audio":
            p["frontend_w"] = mk("frontend_w", (cfg.frontend_dim, d), dt, cfg.frontend_dim)
            p["frontend_b"] = mk("frontend_b", (d,), dt, 0)
    else:
        raise ValueError(fam)
    return p


# ---------------------------------------------------------------------------
# Creators
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    """Real initialization (truncated-normal fan-in; norms to 1, biases to 0).

    scale semantics of the builder's 4th arg:
      -1 -> ones (norm weights); 0 -> zeros (biases/mix offsets);
      -2 -> family-specific special (A_log / decay bases);
       n>0 -> normal with std 1/sqrt(n) (fan-in).
    """
    leaves: Dict = {}
    counter = [0]

    def mk(name, shape, dtype, scale):
        counter[0] += 1
        k = jax.random.fold_in(key, counter[0])
        if scale == -1:
            return jnp.ones(shape, dtype)
        if scale == 0:
            return jnp.zeros(shape, dtype)
        if scale == -2:
            if name == "m_A_log":
                # A in [1, 16] (mamba2 default)
                u = jax.random.uniform(k, shape, jnp.float32, 1.0, 16.0)
                return jnp.log(u)
            if name == "decay_base":
                # rwkv6 decay init: spread across channels
                n = shape[-1]
                ramp = jnp.arange(n, dtype=jnp.float32) / max(n - 1, 1)
                base = -6.0 + 5.0 * ramp  # log(-log w) range
                return jnp.broadcast_to(base, shape)
            return jnp.zeros(shape, jnp.float32)
        std = 1.0 / math.sqrt(max(scale, 1.0)) if scale > 1 else 0.02
        return (jax.random.truncated_normal(k, -2.0, 2.0, shape, jnp.float32)
                * std).astype(dtype)

    return param_tree(cfg, mk)


def param_specs(cfg: ModelConfig) -> Dict:
    """ShapeDtypeStruct tree for AOT lowering (no allocation)."""
    def mk(name, shape, dtype, scale):
        return jax.ShapeDtypeStruct(shape, dtype)
    return param_tree(cfg, mk)


def count_params(tree: Dict) -> int:
    return sum(int(np.prod(v.shape)) for v in jax.tree.leaves(tree))
