"""Mamba2 (SSD) blocks — chunked parallel scan for train/prefill, O(1)-state
recurrent step for decode.

Math (per head h, head dim P, state dim N, ngroups=1):
    a_t     = exp(dt_t * A_h)                      (scalar decay per head/step)
    state_t = a_t * state_{t-1} + dt_t * B_t (x) x_t^T    state: (N, P)
    y_t     = C_t . state_t + D_h * x_t

Chunked computation (chunk Q): intra-chunk is a masked attention-like matmul
M[t,s] = (C_t.B_s) * exp(la_t - la_s) * dt_s (s <= t, exponent always <= 0 so
it is numerically safe), inter-chunk carries the (B,H,N,P) state through a
lax.scan. All SSD math runs in f32.

Sharding: heads over the model axis (in_proj column-parallel, out_proj
row-parallel -> one psum per block, Megatron-style), batch over data axes.
Because B/C are shared across heads (ngroups=1) they are computed from a
replicated slice of the projection.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import unroll as UR

from repro.configs.base import ModelConfig
from repro.models.layers import rmsnorm


class MambaState(NamedTuple):
    ssm: jax.Array   # (B, H, N, P) f32
    conv: jax.Array  # (B, cw-1, conv_dim) — FIR tail for the causal conv


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.ssm_inner + 2 * cfg.ssm_state_dim


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal FIR conv. x: (B,S,Cd); w: (cw, Cd); b: (Cd,).
    ``tail``: (B, cw-1, Cd) carry-in from the previous segment (decode).
    Returns (y (B,S,Cd), new_tail)."""
    B, S, Cd = x.shape
    cw = w.shape[0]
    if tail is None:
        tail = jnp.zeros((B, cw - 1, Cd), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = jnp.zeros((B, S, Cd), jnp.float32)
    for i in range(cw):  # cw is 4: cheap shifted adds, no conv primitive needed
        y = y + xp[:, i:i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    y = y + b.astype(jnp.float32)
    new_tail = xp[:, S:S + cw - 1] if cw > 1 else tail
    return jax.nn.silu(y).astype(x.dtype), new_tail


def ssd_chunked(xh: jax.Array, dt: jax.Array, A_log: jax.Array,
                Bc: jax.Array, Cc: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None):
    """Chunked SSD. xh: (B,S,H,P); dt: (B,S,H) f32 (post-softplus);
    A_log: (H,); Bc/Cc: (B,S,N). Returns (y (B,S,H,P) f32, final_state)."""
    B, S, H, P = xh.shape
    N = Bc.shape[-1]
    chunk = max(1, min(chunk, S))
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    a = (dt * (-jnp.exp(A_log.astype(jnp.float32)))[None, None, :])  # (B,S,H) <= 0

    xr = xh.astype(jnp.float32).reshape(B, nc, chunk, H, P)
    dtr = dt.reshape(B, nc, chunk, H)
    ar = a.reshape(B, nc, chunk, H)
    Br = Bc.astype(jnp.float32).reshape(B, nc, chunk, N)
    Cr = Cc.astype(jnp.float32).reshape(B, nc, chunk, N)

    if init_state is None:
        init_state = jnp.zeros((B, H, N, P), jnp.float32)

    tril = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))

    def body(state, xs):
        xq, dtq, aq, Bq, Cq = xs  # leading dim = B (scan over chunks)
        la = jnp.cumsum(aq, axis=1)  # (B,Q,H) inclusive
        # intra-chunk: M[t,s,h] = (C_t.B_s) exp(la_t - la_s) dt_s  (s<=t)
        # mask the exponent BEFORE exp: masked (s>t) pairs have positive
        # exponents that overflow and would poison gradients through where.
        CB = jnp.einsum("btn,bsn->bts", Cq, Bq)
        expo = la[:, :, None, :] - la[:, None, :, :]  # (B,t,s,H)
        expo = jnp.where(tril[None, :, :, None], expo, -jnp.inf)
        M = CB[..., None] * jnp.exp(expo) * dtq[:, None, :, :]
        y_intra = jnp.einsum("btsh,bshp->bthp", M, xq)
        # inter-chunk: y_inter[t] = exp(la_t) * C_t . state
        y_inter = jnp.einsum("btn,bhnp,bth->bthp", Cq, state, jnp.exp(la))
        # state update
        w_in = jnp.exp(la[:, -1:, :] - la) * dtq  # (B,Q,H)
        state_add = jnp.einsum("bsn,bshp,bsh->bhnp", Bq, xq, w_in)
        state_new = state * jnp.exp(la[:, -1, :])[:, :, None, None] + state_add
        return state_new, y_intra + y_inter

    state, ys = UR.scan(
        body, init_state,
        (xr.transpose(1, 0, 2, 3, 4), dtr.transpose(1, 0, 2, 3),
         ar.transpose(1, 0, 2, 3), Br.transpose(1, 0, 2, 3),
         Cr.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y, state


def ssd_step(state: jax.Array, xh: jax.Array, dt: jax.Array, A_log: jax.Array,
             Bc: jax.Array, Cc: jax.Array):
    """Single-token SSD step. xh: (B,1,H,P); dt: (B,1,H); Bc/Cc: (B,1,N).
    state: (B,H,N,P). Returns (y (B,1,H,P) f32, new_state)."""
    a = jnp.exp(dt[:, 0] * (-jnp.exp(A_log.astype(jnp.float32)))[None, :])  # (B,H)
    upd = jnp.einsum("bn,bhp,bh->bhnp", Bc[:, 0].astype(jnp.float32),
                     xh[:, 0].astype(jnp.float32), dt[:, 0])
    state_new = state * a[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", Cc[:, 0].astype(jnp.float32), state_new)
    return y[:, None], state_new


# ---------------------------------------------------------------------------
# Full Mamba2 block (projections + conv + SSD + gate + norm)
# ---------------------------------------------------------------------------

def mamba2_block(x: jax.Array, p: dict, cfg: ModelConfig,
                 state: Optional[MambaState] = None,
                 single_step: bool = False):
    """x: (B,S,D). p keys: in_proj (D, 2*inner+2N+H), conv_w (cw, inner+2N),
    conv_b, A_log (H,), D_skip (H,), dt_bias (H,), norm_w (inner,),
    out_proj (inner, D). Returns (y, new_state)."""
    B, S, D = x.shape
    inner, N, H = cfg.ssm_inner, cfg.ssm_state_dim, cfg.ssm_num_heads
    P = cfg.ssm_head_dim

    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :inner]
    xbc = zxbcdt[..., inner: inner + inner + 2 * N]
    dt_raw = zxbcdt[..., inner + inner + 2 * N:]

    tail = state.conv if state is not None else None
    xbc, new_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], tail)
    xc = xbc[..., :inner]
    Bc = xbc[..., inner: inner + N]
    Cc = xbc[..., inner + N:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    xh = xc.reshape(B, S, H, P)

    prev = state.ssm if state is not None else None
    if single_step:
        assert prev is not None
        y, new_ssm = ssd_step(prev, xh, dt, p["A_log"], Bc, Cc)
    else:
        y, new_ssm = ssd_chunked(xh, dt, p["A_log"], Bc, Cc, cfg.ssm_chunk,
                                 init_state=prev)
    y = y + p["D_skip"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(B, S, inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], MambaState(new_ssm, new_tail)
