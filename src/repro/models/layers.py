"""Shared transformer building blocks.

Everything is functional: params are plain dicts of jnp arrays, layer stacks
carry a leading ``L`` dim and run under ``jax.lax.scan`` so the HLO stays small
enough to compile 95-layer models against a 512-device mesh in seconds.

Attention is blockwise online-softmax (never materializes S x S):
  - outer scan over query blocks, inner scan over KV chunks, f32 accumulators;
  - ``window > 0`` switches to *banded* attention: each query block
    dynamic-slices only the KV range it can see, so sliding-window layers
    spend O(S * window) FLOPs, not O(S^2) masked.

GQA note: callers repeat K/V to the full head count before calling attention
(``repeat_kv``). With tp > num_kv_heads the (KH, G) split dims are not
divisible by the mesh axis and XLA SPMD inserts replication collectives; the
full-H layout keeps scores cleanly sharded on heads. The KV *cache* still
stores only KH heads.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import unroll as UR

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_table(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """(sin, cos) tables from integer positions; shape (..., head_dim/2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (B, S, H, D); sin/cos: (B, S, D/2) or (S, D/2)."""
    if sin.ndim == 2:
        sin, cos = sin[None, :, None, :], cos[None, :, None, :]
    else:
        sin, cos = sin[:, :, None, :], cos[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, KH, D) -> (B, S, KH*groups, D)."""
    if groups == 1:
        return k
    B, S, KH, D = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, KH, groups, D)).reshape(
        B, S, KH * groups, D)


# ---------------------------------------------------------------------------
# Blockwise attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _scores(q, k, softcap):
    """q: (B, Qb, H, D) f32 (pre-scaled); k: (B, Kc, H, D) -> (B, H, Qb, Kc)."""
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    return s


def _online_update(carry, kc, vc, qb, mask, softcap):
    """Online-softmax update for one KV chunk.
    carry m,l: (B,H,Qb); acc: (B,H,Qb,D); mask: (B,Qb,Kc) bool."""
    m, l, acc = carry
    s = _scores(qb, kc.astype(jnp.float32), softcap)
    s = jnp.where(mask[:, None, :, :], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l_new = l * alpha + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bhqk,bkhd->bhqd", p, vc.astype(jnp.float32))
    return (m_new, l_new, acc * alpha[..., None] + pv)


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_block: int = 512,
    kv_chunk: int = 1024,
    q_offset=0,
    q_positions: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    causal_skip: bool = False,
) -> jax.Array:
    """Memory-bounded attention. q: (B,Sq,H,D); k,v: (B,Skv,H,D) (full heads).

    window > 0  -> banded: each query block dynamic-slices only its visible KV
                   range (true O(S*window) FLOPs).
    causal_skip -> beyond-paper perf variant: per-query-block inner loops are
                   unrolled with exactly ceil(visible/kv_chunk) trips, removing
                   the ~2x masked-FLOP waste of the rectangular scan. Requires
                   default positions (no packing) and Sq == Skv.
    """
    B, Sq, H, D = q.shape
    _, Skv, _, _ = k.shape
    scale = 1.0 / (D ** 0.5)
    orig_dtype = q.dtype

    if q_positions is None:
        q_positions = jnp.arange(Sq)[None, :] + jnp.asarray(q_offset).reshape(-1, 1)
        q_positions = jnp.broadcast_to(q_positions, (B, Sq)).astype(jnp.int32)
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(
            jnp.arange(Skv)[None, :], (B, Skv)).astype(jnp.int32)

    q_block = max(min(q_block, Sq), 1)
    while Sq % q_block:
        q_block //= 2
    n_qb = Sq // q_block

    qr = (q.astype(jnp.float32) * scale).reshape(B, n_qb, q_block, H, D)
    qpos_r = q_positions.reshape(B, n_qb, q_block)

    if window > 0:
        span = window + q_block  # static KV slice length per query block
        pad = span
        kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        kvpos_p = jnp.pad(kv_positions, ((0, 0), (pad, 0)), constant_values=-1)

        def qblock_body(_, xs):
            qb, qpos, qb_idx = xs
            start = (qb_idx + 1) * q_block  # == qb_start - window + pad
            kc = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
            kpos = jax.lax.dynamic_slice_in_dim(kvpos_p, start, span, axis=1)
            mask = (
                (kpos[:, None, :] >= 0)
                & (qpos[:, :, None] >= kpos[:, None, :])
                & (kpos[:, None, :] > qpos[:, :, None] - window)
            )
            m0 = jnp.full((B, H, q_block), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, H, q_block), jnp.float32)
            a0 = jnp.zeros((B, H, q_block, D), jnp.float32)
            m, l, acc = _online_update((m0, l0, a0), kc, vc, qb, mask, softcap)
            out = acc / jnp.maximum(l[..., None], 1e-30)
            return None, out.transpose(0, 2, 1, 3)  # (B, Qb, H, D)

        _, outs = UR.scan(
            qblock_body, None,
            (qr.transpose(1, 0, 2, 3, 4), qpos_r.transpose(1, 0, 2),
             jnp.arange(n_qb)))
        return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D).astype(orig_dtype)

    kv_chunk = max(min(kv_chunk, Skv), 1)
    while Skv % kv_chunk:
        kv_chunk //= 2
    n_kc = Skv // kv_chunk
    kr = k.reshape(B, n_kc, kv_chunk, H, D)
    vr = v.reshape(B, n_kc, kv_chunk, H, D)
    kpos_r = kv_positions.reshape(B, n_kc, kv_chunk)

    if causal_skip and causal and Sq == Skv:
        # Unrolled query blocks; block i scans only its first visible chunks.
        outs = []
        for i in range(n_qb):
            qb = qr[:, i]
            qpos = qpos_r[:, i]
            hi = ((i + 1) * q_block + kv_chunk - 1) // kv_chunk  # chunks needed

            def kv_body(carry, kxs):
                kc, vc, kpos = kxs
                mask = (kpos[:, None, :] >= 0) & (
                    qpos[:, :, None] >= kpos[:, None, :])
                return _online_update(carry, kc, vc, qb, mask, softcap), None

            m0 = jnp.full((B, H, q_block), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, H, q_block), jnp.float32)
            a0 = jnp.zeros((B, H, q_block, D), jnp.float32)
            (m, l, acc), _ = UR.scan(
                kv_body, (m0, l0, a0),
                (kr[:, :hi].transpose(1, 0, 2, 3, 4),
                 vr[:, :hi].transpose(1, 0, 2, 3, 4),
                 kpos_r[:, :hi].transpose(1, 0, 2)))
            out = acc / jnp.maximum(l[..., None], 1e-30)
            outs.append(out.transpose(0, 2, 1, 3))
        return jnp.concatenate(outs, axis=1).astype(orig_dtype)

    def qblock_body(_, xs):
        qb, qpos = xs

        def kv_body(carry, kxs):
            kc, vc, kpos = kxs
            mask = kpos[:, None, :] >= 0
            if causal:
                mask = mask & (qpos[:, :, None] >= kpos[:, None, :])
            return _online_update(carry, kc, vc, qb, mask, softcap), None

        m0 = jnp.full((B, H, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_block), jnp.float32)
        a0 = jnp.zeros((B, H, q_block, D), jnp.float32)
        (m, l, acc), _ = UR.scan(
            kv_body, (m0, l0, a0),
            (kr.transpose(1, 0, 2, 3, 4), vr.transpose(1, 0, 2, 3, 4),
             kpos_r.transpose(1, 0, 2)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.transpose(0, 2, 1, 3)

    _, outs = UR.scan(
        qblock_body, None,
        (qr.transpose(1, 0, 2, 3, 4), qpos_r.transpose(1, 0, 2)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D).astype(orig_dtype)


def decode_attention_grouped(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len,
    *,
    window: int = 0,
    softcap: float = 0.0,
    constrain=None,
) -> jax.Array:
    """GQA decode WITHOUT materializing repeat_kv: q is regrouped to
    (B, 1, KH, G, D) and contracted against the KH-headed cache directly.
    Cuts attention HBM reads by the group factor G (8x for 64q/8kv heads)
    — the §Perf decode optimization; identical math to decode_attention."""
    B, _, H, D = q.shape
    _, Smax, KH, _ = k_cache.shape
    G = H // KH
    scale = 1.0 / (D ** 0.5)
    clen = jnp.asarray(cache_len).reshape(-1, 1)
    kpos = jnp.arange(Smax)[None, :]
    valid = kpos < clen
    if window > 0:
        valid = valid & (kpos > clen - 1 - window)
    qg = (q.astype(jnp.float32) * scale).reshape(B, KH, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    if constrain is not None:
        s = constrain(s.reshape(B, H, 1, Smax)).reshape(B, KH, G, Smax)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, D).astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len,
    *,
    window: int = 0,
    softcap: float = 0.0,
    constrain=None,
) -> jax.Array:
    """Single-step decode attention over a cache (full heads).

    q: (B, 1, H, D); k_cache/v_cache: (B, Smax, H, D); cache_len: scalar or
    (B,) count of valid positions (incl. the newly written token).
    """
    B, _, H, D = q.shape
    _, Smax, _, _ = k_cache.shape
    scale = 1.0 / (D ** 0.5)
    clen = jnp.asarray(cache_len).reshape(-1, 1)
    kpos = jnp.arange(Smax)[None, :]
    valid = kpos < clen
    if window > 0:
        valid = valid & (kpos > clen - 1 - window)
    qf = q.astype(jnp.float32) * scale
    s = _scores(qf, k_cache, softcap)  # (B, H, 1, Smax); f32 accum
    if constrain is not None:
        # flash-decoding layout: keep logits sharded along the cache's
        # sequence shards; softmax stats reduce across the axis (GSPMD
        # inserts the tiny all-reduce) instead of re-sharding the cache
        # to heads, which would replicate the whole KV (involuntary
        # full-remat blowup).
        s = constrain(s)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bhqd", p, v_cache,
                   preferred_element_type=jnp.float32)
    return o.transpose(0, 2, 1, 3).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def glu_mlp(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array,
            act: str = "silu") -> jax.Array:
    """SwiGLU / GeGLU: act(x@w1) * (x@w3) @ w2."""
    h = x @ w1
    g = x @ w3
    if act in ("silu", "swiglu"):
        h = jax.nn.silu(h)
    else:  # gelu_glu
        h = jax.nn.gelu(h, approximate=True)
    return (h * g) @ w2


def gelu_mlp(x: jax.Array, w1, b1, w2, b2) -> jax.Array:
    """Plain GELU MLP with biases (whisper-style)."""
    h = jax.nn.gelu(x @ w1 + b1, approximate=True)
    return h @ w2 + b2


# ---------------------------------------------------------------------------
# Positional embeddings (whisper)
# ---------------------------------------------------------------------------

def sinusoid_positions(length: int, dim: int) -> jax.Array:
    log_timescale = jnp.log(10_000.0) / (dim // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(dim // 2, dtype=jnp.float32))
    scaled = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


# ---------------------------------------------------------------------------
# Softmax cross-entropy
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits: (..., V); labels: (...) int. Mean NLL in f32."""
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
