"""Unified model API over all architecture families.

  train_loss(params, cfg, batch, ...)        -> (loss, metrics)
  init_cache(cfg, batch, max_seq, abstract)  -> cache pytree (zeros or SDS)
  prefill(params, cfg, batch, caches, ...)   -> (last_logits, caches)
  decode_step(params, cfg, tokens, caches, pos, ...) -> (logits, caches)
  input_specs(cfg, shape_cell)               -> batch of ShapeDtypeStructs

Batch schemas:
  dense/moe/ssm/hybrid: {tokens (B,S) i32, labels (B,S) i32}
  vlm:    {tokens (B,S_txt), patches (B,S_img,Fd), labels (B,S_txt)}
          with S_img = S // 2 (multi-camera patch slots, CrossRoI target)
  encdec: {frames (B,S,Fd), tokens (B,T), labels (B,T)}
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import forward as F
from repro.models import layers as L
from repro.models.dist import DistContext
from repro.models.params import param_specs, init_params
from repro.models.rwkv import LORA_MIX  # noqa: F401  (re-export convenience)
from repro.models.ssm import conv_dim


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    sds = jax.ShapeDtypeStruct
    if cfg.family == "vlm":
        s_img = S // 2
        s_txt = S - s_img
        d = {"tokens": sds((B, s_txt), i32),
             "patches": sds((B, s_img, cfg.frontend_dim), bf16)}
        if cell.kind == "train":
            d["labels"] = sds((B, s_txt), i32)
        return d
    if cfg.family == "encdec":
        T = min(cfg.max_target_len, S)
        d = {"frames": sds((B, S, cfg.frontend_dim), bf16),
             "tokens": sds((B, T), i32)}
        if cell.kind == "train":
            d["labels"] = sds((B, T), i32)
        return d
    d = {"tokens": sds((B, S), i32)}
    if cell.kind == "train":
        d["labels"] = sds((B, S), i32)
    return d


def make_batch(cfg: ModelConfig, cell_or_shapes, key: jax.Array) -> Dict:
    """Random concrete batch matching input_specs (smoke tests/examples)."""
    specs = input_specs(cfg, cell_or_shapes) \
        if isinstance(cell_or_shapes, ShapeCell) else cell_or_shapes
    out = {}
    for i, (name, s) in enumerate(sorted(specs.items())):
        k = jax.random.fold_in(key, i)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab_size, s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype)
    return out


# ---------------------------------------------------------------------------
# embedding front
# ---------------------------------------------------------------------------

def _front(params, cfg: ModelConfig, batch) -> jax.Array:
    if cfg.family == "vlm":
        tok = F._embed(params, cfg, batch["tokens"])
        patch = batch["patches"] @ params["frontend_w"] + params["frontend_b"]
        return jnp.concatenate([patch.astype(tok.dtype), tok], axis=1)
    return F._embed(params, cfg, batch["tokens"])


# ---------------------------------------------------------------------------
# train loss
# ---------------------------------------------------------------------------

def train_loss(params, cfg: ModelConfig, batch, *, dist: Optional[DistContext]
               = None, remat: bool = True, causal_skip: bool = False):
    metrics: Dict[str, jax.Array] = {}
    if cfg.family == "encdec":
        memory = F.encoder_trunk(params, cfg, batch["frames"], remat=remat)
        x, _ = F.decoder_trunk(params, cfg, batch["tokens"], memory,
                               remat=remat)
        x = L.layernorm(x, params["final_norm"], params["final_norm_b"],
                        cfg.norm_eps)
        loss = F.chunked_ce(params, cfg, x, batch["labels"])
        return loss, metrics

    x = _front(params, cfg, batch)
    x = F.shard_act(x, dist, None, None)

    if cfg.family in ("dense", "vlm"):
        x, _ = F.dense_trunk(params, cfg, x, dist=dist, remat=remat,
                             causal_skip=causal_skip)
    elif cfg.family == "moe":
        x, _, aux, dropped = F.moe_trunk(params, cfg, x, dist=dist,
                                         remat=remat, causal_skip=causal_skip)
        metrics["moe_aux"] = aux
        metrics["moe_dropped"] = dropped
    elif cfg.family == "ssm":
        x, _ = F.rwkv_trunk(params, cfg, x, remat=remat)
    elif cfg.family == "hybrid":
        x, _, _ = F.hybrid_trunk(params, cfg, x, dist=dist, remat=remat)
    else:
        raise ValueError(cfg.family)

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.family == "vlm":
        s_txt = batch["tokens"].shape[1]
        x = x[:, -s_txt:]
    loss = F.chunked_ce(params, cfg, x, batch["labels"])
    if "moe_aux" in metrics:
        loss = loss + cfg.router_aux_coef * metrics["moe_aux"]
    return loss, metrics


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _kv_cache(shape, abstract, dtype=jnp.bfloat16):
    if abstract:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jnp.zeros(shape, dtype)


def init_cache(cfg: ModelConfig, B: int, max_seq: int, abstract: bool = False):
    """KV caches / recurrent states sized for a serving session.

    Sliding-window layers get ring buffers of length ``window`` — decode
    memory for SWA/local layers is O(window) regardless of context length.
    """
    KH, Dh = cfg.num_kv_heads, cfg.head_dim
    fam = cfg.family
    kv_dt = getattr(jnp, cfg.kv_cache_dtype)

    def kv(Lc, Smax):
        return (_kv_cache((Lc, B, Smax, KH, Dh), abstract, kv_dt),
                _kv_cache((Lc, B, Smax, KH, Dh), abstract, kv_dt))

    if fam in ("dense", "vlm"):
        if cfg.global_every > 1:
            n_super = cfg.num_layers // cfg.global_every
            n_lp = cfg.global_every - 1
            n_trail = cfg.num_layers - n_super * cfg.global_every
            W = min(cfg.window_size, max_seq)
            caches = {"local": kv(n_super * n_lp, W),
                      "global": kv(n_super, max_seq)}
            if n_trail:
                caches["trail"] = kv(n_trail, W)
            return caches
        Smax = min(cfg.window_size, max_seq) if cfg.window_size else max_seq
        return {"blocks": kv(cfg.num_layers, Smax)}
    if fam == "moe":
        caches = {"blocks": kv(cfg.num_layers - cfg.first_dense_layers, max_seq)}
        if cfg.first_dense_layers:
            caches["dense"] = kv(cfg.first_dense_layers, max_seq)
        return caches
    if fam == "ssm":
        Lc, D = cfg.num_layers, cfg.d_model
        H, P = cfg.ssm_num_heads, cfg.ssm_head_dim
        mkf = (lambda s: jax.ShapeDtypeStruct(s, jnp.float32)) if abstract \
            else (lambda s: jnp.zeros(s, jnp.float32))
        return (mkf((Lc, B, H, P, P)), mkf((Lc, B, D)), mkf((Lc, B, D)))
    if fam == "hybrid":
        Lc = cfg.num_layers
        H, N, P = cfg.ssm_num_heads, cfg.ssm_state_dim, cfg.ssm_head_dim
        cd = conv_dim(cfg)
        cw = cfg.ssm_conv_width
        n_apps = Lc // cfg.attn_every
        mkf = (lambda s: jax.ShapeDtypeStruct(s, jnp.float32)) if abstract \
            else (lambda s: jnp.zeros(s, jnp.float32))
        mkb = (lambda s: jax.ShapeDtypeStruct(s, jnp.bfloat16)) if abstract \
            else (lambda s: jnp.zeros(s, jnp.bfloat16))
        states = (mkf((Lc, B, H, N, P)), mkb((Lc, B, cw - 1, cd)))
        return {"states": states, "attn": kv(n_apps, max_seq)}
    if fam == "encdec":
        Tmax = cfg.max_target_len
        # cross KV sized to the encoder memory length; prefill overwrites
        # it with the real projections (decode-only dry-runs lower against
        # the abstract struct directly)
        cross = (_kv_cache((cfg.decoder_layers, B, max_seq, KH, Dh),
                           abstract),
                 _kv_cache((cfg.decoder_layers, B, max_seq, KH, Dh),
                           abstract))
        return {"self": kv(cfg.decoder_layers, Tmax), "cross": cross}
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, batch, caches, *,
            dist: Optional[DistContext] = None, positions=None,
            last_index=None):
    """Process the full prompt; fill caches; return last-position logits
    (or the logits at ``last_index`` — RoI-packed prompts end at the last
    *kept* row, not the last padded row)."""
    fam = cfg.family
    if fam == "encdec":
        memory = F.encoder_trunk(params, cfg, batch["frames"])
        xk, xv = F.cross_kv(params, cfg, memory)
        caches = dict(caches)
        caches["cross"] = (xk, xv)
        x, caches = F.decoder_trunk(params, cfg, batch["tokens"], memory,
                                    mode="prefill", caches=caches, pos=0)
        x = L.layernorm(x, params["final_norm"], params["final_norm_b"],
                        cfg.norm_eps)
        logits = F._unembed(params, cfg, x[:, -1:])
        return logits, caches

    x = _front(params, cfg, batch)
    x = F.shard_act(x, dist, None, None)
    if fam in ("dense", "vlm"):
        x, caches = F.dense_trunk(params, cfg, x, dist=dist, mode="prefill",
                                  caches=caches, positions=positions)
    elif fam == "moe":
        x, caches, _, _ = F.moe_trunk(params, cfg, x, dist=dist,
                                      mode="prefill", caches=caches,
                                      positions=positions)
    elif fam == "ssm":
        x, states = F.rwkv_trunk(params, cfg, x, mode="prefill",
                                 states=caches)
        caches = states
    elif fam == "hybrid":
        x, states, attn = F.hybrid_trunk(params, cfg, x, dist=dist,
                                         mode="prefill",
                                         states=caches["states"],
                                         caches=caches["attn"])
        caches = {"states": states, "attn": attn}
    else:
        raise ValueError(fam)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if last_index is None:
        xe = x[:, -1:]
    else:
        xe = jax.lax.dynamic_slice_in_dim(x, last_index, 1, axis=1)
    logits = F._unembed(params, cfg, xe)
    return logits, caches


def decode_step(params, cfg: ModelConfig, tokens, caches, pos, *,
                dist: Optional[DistContext] = None):
    """tokens: (B, 1) the token at absolute position ``pos`` (scalar)."""
    fam = cfg.family
    if fam == "encdec":
        memory = None  # cross KV already in caches
        x, caches = F.decoder_trunk(params, cfg, tokens, memory,
                                    mode="decode", caches=caches, pos=pos)
        x = L.layernorm(x, params["final_norm"], params["final_norm_b"],
                        cfg.norm_eps)
        return F._unembed(params, cfg, x), caches

    x = F._embed(params, cfg, tokens)
    if fam in ("dense", "vlm"):
        x, caches = F.dense_trunk(params, cfg, x, dist=dist, mode="decode",
                                  caches=caches, pos=pos)
    elif fam == "moe":
        x, caches, _, _ = F.moe_trunk(params, cfg, x, dist=dist,
                                      mode="decode", caches=caches, pos=pos)
    elif fam == "ssm":
        x, caches = F.rwkv_trunk(params, cfg, x, mode="decode", states=caches)
    elif fam == "hybrid":
        x, states, attn = F.hybrid_trunk(params, cfg, x, dist=dist,
                                         mode="decode",
                                         states=caches["states"],
                                         caches=caches["attn"], pos=pos)
        caches = {"states": states, "attn": attn}
    else:
        raise ValueError(fam)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return F._unembed(params, cfg, x), caches
