"""Forward passes for every architecture family.

Modes:
  train   — full-sequence causal loss (chunked CE so (B,S,V) never lives).
  prefill — fill KV caches / SSM states, return last-position logits.
  decode  — one token per sequence against the caches.

All layer stacks run under lax.scan (small HLO, fast 512-device compiles).
Sliding-window layers keep *ring-buffer* KV caches of size ``window`` so
decode memory for SWA archs is O(window), not O(seq) — this is what makes
h2o-danube3 / gemma3 long_500k cells fit.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import unroll as UR
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.dist import DistContext
from repro.models.moe import moe_layer
from repro.models.rwkv import RWKVState, rwkv6_block
from repro.models.ssm import MambaState, mamba2_block, conv_dim


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _sub(params: Dict, prefix: str) -> Dict:
    """Strip a key prefix: {'blocks_wq': a} -> {'wq': a}."""
    n = len(prefix)
    return {k[n:]: v for k, v in params.items() if k.startswith(prefix)}


def shard_act(x, dist: Optional[DistContext], *spec_tail):
    if dist is None or dist.mesh is None:
        return x
    spec = P(dist.batch_axes, *spec_tail)
    return jax.lax.with_sharding_constraint(x, NamedSharding(dist.mesh, spec))


def _maybe_remat(fn, remat: bool):
    return jax.checkpoint(fn) if remat else fn


def _embed(params, cfg: ModelConfig, tokens):
    return params["embed"][tokens]


def _unembed(params, cfg: ModelConfig, x):
    w = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return x @ w.T


def chunked_ce(params, cfg: ModelConfig, x, labels, chunk: int = 512):
    """Cross-entropy without materializing (B, S, V). x: (B,S,D)."""
    B, S, D = x.shape
    chunk = max(1, min(chunk, S))
    while S % chunk:
        chunk //= 2
    n = S // chunk
    xr = x.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    lr = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    def body(tot, xs):
        xc, lc = xs
        logits = _unembed(params, cfg, xc)
        return tot + L.cross_entropy(logits, lc) * (1.0 / n), None

    tot, _ = UR.scan(body, jnp.zeros((), jnp.float32), (xr, lr))
    return tot


# ---------------------------------------------------------------------------
# attention sub-block (dense / moe / shared attention)
# ---------------------------------------------------------------------------

def attn_sublayer(
    x, lp: Dict, cfg: ModelConfig, *,
    window: int = 0,
    rope_sincos=None,
    mode: str = "train",
    cache: Optional[Tuple] = None,  # (k_cache, v_cache) (B, Smax, KH, Dh)
    pos=0,
    causal: bool = True,
    kv_src=None,  # cross-attention source (B, S_kv, D)
    positions=None,
    causal_skip: bool = False,
    prefix: str = "",
    dist=None,
):
    """Returns (attn_out (B,S,qd), new_cache or None)."""
    B, S, D = x.shape
    H, KH, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KH

    def proj(name, src, heads):
        w = lp[prefix + "w" + name]
        y = src @ w
        b = lp.get(prefix + "b" + name)
        if b is not None:
            y = y + b
        return y.reshape(src.shape[0], src.shape[1], heads, Dh)

    q = proj("q", x, H)
    src = kv_src if kv_src is not None else x
    k = proj("k", src, KH)
    v = proj("v", src, KH)

    if prefix + "qnorm" in lp:
        q = L.rmsnorm(q, lp[prefix + "qnorm"], cfg.norm_eps)
        k = L.rmsnorm(k, lp[prefix + "knorm"], cfg.norm_eps)

    if rope_sincos is not None:
        sin_q, cos_q, sin_k, cos_k = rope_sincos
        q = L.apply_rope(q, sin_q, cos_q)
        k = L.apply_rope(k, sin_k, cos_k)

    new_cache = None
    # Decode caches with KH % tp != 0 are sequence-sharded over the model
    # axis (shardings.cache_pspecs); pin the attention to flash-decoding
    # layout so GSPMD reduces softmax stats instead of replicating the KV.
    constrain = None
    if (mode == "decode" and dist is not None and dist.mesh is not None
            and dist.tp > 1 and KH % dist.tp != 0):
        from jax.sharding import NamedSharding, PartitionSpec as P

        def constrain(s):  # s: (B, H, Q, Smax)
            bax = dist.batch_axes if s.shape[0] % dist.dp == 0 else None
            return jax.lax.with_sharding_constraint(
                s, NamedSharding(dist.mesh, P(bax, None, None, "model")))

    if mode == "decode":
        k_cache, v_cache = cache
        Smax = k_cache.shape[1]
        if window > 0 and Smax == window:  # ring buffer
            slot = jnp.mod(pos, window)
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                k_cache, k.astype(k_cache.dtype), slot, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                v_cache, v.astype(v_cache.dtype), slot, 1)
            # slot s holds position pos - ((pos - s) mod W); valid once >= 0.
            # (decode writes in position order, so per-slot positions are
            # analytic — no (L,B,S) position cache needed.)
            slots = jnp.arange(window)
            slot_pos = pos - jnp.mod(pos - slots, window)
            valid = slot_pos >= 0
            kf = L.repeat_kv(k_cache, G)
            vf = L.repeat_kv(v_cache, G)
            qf = q
            s = jnp.einsum("bqhd,bkhd->bhqk", qf.astype(jnp.float32) / (Dh ** 0.5),
                           kf.astype(jnp.float32))
            if constrain is not None:
                s = constrain(s)
            if cfg.logit_softcap > 0:
                s = cfg.logit_softcap * jnp.tanh(s / cfg.logit_softcap)
            s = jnp.where(valid[None, None, None, :], s, L.NEG_INF)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bhqk,bkhd->bhqd", p, vf.astype(jnp.float32))
            o = o.transpose(0, 2, 1, 3).astype(x.dtype)
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                k_cache, k.astype(k_cache.dtype), pos, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                v_cache, v.astype(v_cache.dtype), pos, 1)
            if cfg.decode_grouped_attn:
                o = L.decode_attention_grouped(
                    q, k_cache, v_cache, pos + 1, window=window,
                    softcap=cfg.logit_softcap, constrain=constrain)
            else:
                o = L.decode_attention(
                    q, L.repeat_kv(k_cache, G), L.repeat_kv(v_cache, G),
                    pos + 1, window=window, softcap=cfg.logit_softcap,
                    constrain=constrain)
        new_cache = (k_cache, v_cache)
    else:
        if mode == "prefill" and cache is not None:
            k_cache, v_cache = cache
            Smax = k_cache.shape[1]
            if window > 0 and Smax == window:
                take = min(window, S)
                idx = (jnp.arange(Smax) + max(S - take, 0)) % window
                k_cache = k_cache.at[:, idx[:take]].set(
                    k[:, -take:].astype(k_cache.dtype))
                v_cache = v_cache.at[:, idx[:take]].set(
                    v[:, -take:].astype(v_cache.dtype))
            else:
                k_cache = jax.lax.dynamic_update_slice_in_dim(
                    k_cache, k.astype(k_cache.dtype), 0, 1)
                v_cache = jax.lax.dynamic_update_slice_in_dim(
                    v_cache, v.astype(v_cache.dtype), 0, 1)
            new_cache = (k_cache, v_cache)
        o = L.blockwise_attention(
            q, L.repeat_kv(k, G), L.repeat_kv(v, G),
            causal=causal, window=window, softcap=cfg.logit_softcap,
            q_positions=positions, kv_positions=positions,
            causal_skip=causal_skip)
    o = o.reshape(B, S, H * Dh)
    out = o @ lp[prefix + "wo"]
    bo = lp.get(prefix + "bo")
    if bo is not None:
        out = out + bo
    return out, new_cache


def dense_block(x, lp, cfg: ModelConfig, *, window, rope_sincos, mode="train",
                cache=None, pos=0, positions=None, causal_skip=False,
                dist=None):
    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    a, new_cache = attn_sublayer(
        h, lp, cfg, window=window, rope_sincos=rope_sincos, mode=mode,
        cache=cache, pos=pos, positions=positions, causal_skip=causal_skip,
        dist=dist)
    x = x + a
    h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    x = x + L.glu_mlp(h, lp["w1"], lp["w3"], lp["w2"], act=cfg.act)
    return x, new_cache


def moe_block(x, lp, cfg: ModelConfig, dist, *, rope_sincos, mode="train",
              cache=None, pos=0, positions=None, causal_skip=False):
    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    a, new_cache = attn_sublayer(
        h, lp, cfg, window=0, rope_sincos=rope_sincos, mode=mode,
        cache=cache, pos=pos, positions=positions, causal_skip=causal_skip,
        dist=dist)
    x = x + a
    h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    shared = None
    if "shared_wg" in lp:
        shared = (lp["shared_wg"], lp["shared_wu"], lp["shared_wd"])
    y, aux, dropped = moe_layer(
        h, lp["router"], lp["moe_wg"], lp["moe_wu"], lp["moe_wd"],
        cfg, dist, shared=shared)
    return x + y, aux, dropped, new_cache


# ---------------------------------------------------------------------------
# rope tables
# ---------------------------------------------------------------------------

def _rope(cfg: ModelConfig, S: int, pos0=0, positions=None, theta=None):
    theta = theta or cfg.rope_theta
    if positions is None:
        positions = jnp.arange(S) + pos0
    sin, cos = L.rope_table(positions, cfg.head_dim, theta)
    return (sin, cos, sin, cos)


# ===========================================================================
# DENSE / VLM
# ===========================================================================

def dense_trunk(params, cfg: ModelConfig, x, *, dist, mode="train",
                caches=None, pos=0, positions=None, remat=False,
                causal_skip=False):
    """Runs the stacked dense blocks. caches: dict of stacked cache arrays.
    Returns (x, new_caches)."""
    B, S, D = x.shape
    new_caches = {} if caches is not None else None

    def run_stack(x, stack, n_layers, window, theta, cache_key):
        rope_sincos = _rope(cfg, S, pos0=pos, positions=positions, theta=theta)

        if mode == "train":
            body = _maybe_remat(
                lambda xx, lp: dense_block(
                    xx, lp, cfg, window=window, rope_sincos=rope_sincos,
                    positions=positions, causal_skip=causal_skip)[0], remat)
            x, _ = UR.scan(lambda xx, lp: (body(xx, lp), None), x, stack)
            return x
        ck, cv = caches[cache_key]

        def body(xx, xs):
            lp, k_c, v_c = xs
            y, nc = dense_block(
                xx, lp, cfg, window=window, rope_sincos=rope_sincos,
                mode=mode, cache=(k_c, v_c), pos=pos, positions=positions, dist=dist)
            return y, nc

        x, ncs = UR.scan(body, x, (stack, ck, cv))
        new_caches[cache_key] = ncs
        return x

    if cfg.global_every > 1:  # gemma3 pattern
        n_super = cfg.num_layers // cfg.global_every
        n_lp = cfg.global_every - 1
        n_trail = cfg.num_layers - n_super * cfg.global_every
        local = _sub(params, "local_")
        glob = _sub(params, "global_")
        local_r = jax.tree.map(
            lambda a: a.reshape((n_super, n_lp) + a.shape[1:]), local)
        rope_l = _rope(cfg, S, pos0=pos, positions=positions, theta=10_000.0)
        rope_g = _rope(cfg, S, pos0=pos, positions=positions,
                       theta=cfg.rope_theta)

        if mode == "train":
            def super_body(xx, xs):
                lstack, gp = xs

                def lbody(xx2, lp):
                    return _maybe_remat(
                        lambda a, b: dense_block(
                            a, b, cfg, window=cfg.window_size,
                            rope_sincos=rope_l, positions=positions,
                            causal_skip=causal_skip)[0], remat)(xx2, lp), None

                xx, _ = UR.scan(lbody, xx, lstack)
                xx = _maybe_remat(
                    lambda a, b: dense_block(
                        a, b, cfg, window=0, rope_sincos=rope_g,
                        positions=positions, causal_skip=causal_skip)[0],
                    remat)(xx, gp)
                return xx, None

            x, _ = UR.scan(super_body, x, (local_r, glob))
            if n_trail:
                trail = _sub(params, "trail_")

                def tbody(xx, lp):
                    return dense_block(
                        xx, lp, cfg, window=cfg.window_size,
                        rope_sincos=rope_l, positions=positions,
                        causal_skip=causal_skip)[0], None

                x, _ = UR.scan(tbody, x, trail)
            return x, None

        # prefill / decode with caches
        lk, lv = caches["local"]  # (n_local_total, B, W, KH, Dh)
        gk, gv = caches["global"]
        lk_r, lv_r = (a.reshape((n_super, n_lp) + a.shape[1:])
                      for a in (lk, lv))

        def super_body(xx, xs):
            lstack, lkc, lvc, gp, gkc, gvc = xs

            def lbody(xx2, xs2):
                lp2, k_c, v_c = xs2
                y, nc = dense_block(
                    xx2, lp2, cfg, window=cfg.window_size, rope_sincos=rope_l,
                    mode=mode, cache=(k_c, v_c), pos=pos,
                    positions=positions, dist=dist)
                return y, nc

            xx, lnc = UR.scan(lbody, xx, (lstack, lkc, lvc))
            xx, gnc = dense_block(
                xx, gp, cfg, window=0, rope_sincos=rope_g, mode=mode,
                cache=(gkc, gvc), pos=pos, positions=positions, dist=dist)
            return xx, (lnc, gnc)

        x, (lnc, gnc) = UR.scan(
            super_body, x, (local_r, lk_r, lv_r, glob, gk, gv))
        new_caches["local"] = tuple(
            a.reshape((n_super * n_lp,) + a.shape[2:]) for a in lnc)
        new_caches["global"] = gnc
        if n_trail:
            trail = _sub(params, "trail_")
            tk, tv = caches["trail"]

            def tbody(xx, xs):
                lp2, k_c, v_c = xs
                y, nc = dense_block(
                    xx, lp2, cfg, window=cfg.window_size, rope_sincos=rope_l,
                    mode=mode, cache=(k_c, v_c), pos=pos,
                    positions=positions, dist=dist)
                return y, nc

            x, tnc = UR.scan(tbody, x, (trail, tk, tv))
            new_caches["trail"] = tnc
        return x, new_caches

    # uniform stack
    stack = _sub(params, "blocks_")
    window = cfg.window_size
    rope_sc = _rope(cfg, S, pos0=pos, positions=positions)
    if mode == "train":
        body = _maybe_remat(
            lambda xx, lp: dense_block(
                xx, lp, cfg, window=window, rope_sincos=rope_sc,
                positions=positions, causal_skip=causal_skip)[0], remat)
        x, _ = UR.scan(lambda xx, lp: (body(xx, lp), None), x, stack)
        return x, None

    ck, cv = caches["blocks"]

    def body(xx, xs):
        lp, k_c, v_c = xs
        y, nc = dense_block(
            xx, lp, cfg, window=window, rope_sincos=rope_sc, mode=mode,
            cache=(k_c, v_c), pos=pos, positions=positions, dist=dist)
        return y, nc

    x, ncs = UR.scan(body, x, (stack, ck, cv))
    new_caches["blocks"] = ncs
    return x, new_caches


# ===========================================================================
# MOE trunk
# ===========================================================================

def moe_trunk(params, cfg: ModelConfig, x, *, dist, mode="train", caches=None,
              pos=0, positions=None, remat=False, causal_skip=False):
    B, S, D = x.shape
    rope_sc = _rope(cfg, S, pos0=pos, positions=positions)
    new_caches = {} if caches is not None else None
    aux_tot = jnp.zeros((), jnp.float32)
    drop_tot = jnp.zeros((), jnp.float32)

    nd = cfg.first_dense_layers
    if nd:
        dstack = _sub(params, "dense_")
        if mode == "train":
            body = _maybe_remat(
                lambda xx, lp: dense_block(
                    xx, lp, cfg, window=0, rope_sincos=rope_sc,
                    positions=positions, causal_skip=causal_skip)[0], remat)
            x, _ = UR.scan(lambda xx, lp: (body(xx, lp), None), x, dstack)
        else:
            ck, cv = caches["dense"]

            def dbody(xx, xs):
                lp, k_c, v_c = xs
                y, nc = dense_block(
                    xx, lp, cfg, window=0, rope_sincos=rope_sc, mode=mode,
                    cache=(k_c, v_c), pos=pos, positions=positions, dist=dist)
                return y, nc

            x, ncs = UR.scan(dbody, x, (dstack, ck, cv))
            new_caches["dense"] = ncs

    stack = _sub(params, "blocks_")
    if mode == "train":
        def body(carry, lp):
            xx, aux, drop = carry
            def blk(xx2, lp2):
                return moe_block(xx2, lp2, cfg, dist, rope_sincos=rope_sc,
                                 positions=positions, causal_skip=causal_skip)[:3]
            if remat:
                blk = jax.checkpoint(blk)
            y, a, dr = blk(xx, lp)
            return (y, aux + a, drop + dr), None

        (x, aux_tot, drop_tot), _ = UR.scan(
            body, (x, aux_tot, drop_tot), stack)
        return x, None, aux_tot, drop_tot

    ck, cv = caches["blocks"]

    def body(carry, xs):
        xx, aux, drop = carry
        lp, k_c, v_c = xs
        y, a, dr, nc = moe_block(
            xx, lp, cfg, dist, rope_sincos=rope_sc, mode=mode,
            cache=(k_c, v_c), pos=pos, positions=positions)
        return (y, aux + a, drop + dr), nc

    (x, aux_tot, drop_tot), ncs = UR.scan(
        body, (x, aux_tot, drop_tot), (stack, ck, cv))
    new_caches["blocks"] = ncs
    return x, new_caches, aux_tot, drop_tot


# ===========================================================================
# RWKV trunk
# ===========================================================================

def rwkv_trunk(params, cfg: ModelConfig, x, *, mode="train", states=None,
               remat=False):
    stack = _sub(params, "blocks_")
    x = L.rmsnorm(x, params["ln_in"], cfg.norm_eps)
    single = mode == "decode"

    if states is None:
        def body(xx, lp):
            fn = lambda a, b: rwkv6_block(a, b, cfg)[0]
            if remat:
                fn = jax.checkpoint(fn)
            return fn(xx, lp), None
        x, _ = UR.scan(body, x, stack)
        return x, None

    def body(xx, xs):
        lp, st = xs
        y, ns = rwkv6_block(xx, lp, cfg, state=RWKVState(*st),
                            single_step=single)
        return y, tuple(ns)

    x, ns = UR.scan(body, x, (stack, tuple(states)))
    return x, ns


# ===========================================================================
# HYBRID (zamba2) trunk
# ===========================================================================

def _mamba_pdict(lp: Dict) -> Dict:
    """Map stacked 'm_*' keys to mamba2_block parameter names."""
    return {"in_proj": lp["m_in"], "conv_w": lp["m_conv_w"],
            "conv_b": lp["m_conv_b"], "A_log": lp["m_A_log"],
            "D_skip": lp["m_D"], "dt_bias": lp["m_dt_bias"],
            "norm_w": lp["m_norm"], "out_proj": lp["m_out"]}

def hybrid_trunk(params, cfg: ModelConfig, x, *, dist, mode="train",
                 states=None, caches=None, pos=0, remat=False):
    """Mamba2 stack with a shared attention block every ``attn_every`` layers.
    states: (ssm (L,B,H,N,P), conv (L,B,cw-1,cd)); caches: attention KV for
    each shared-block application (n_apps stacked)."""
    B, S, D = x.shape
    n_apps = cfg.num_layers // cfg.attn_every
    per = cfg.attn_every
    stack = _sub(params, "blocks_")
    stack_r = jax.tree.map(lambda a: a.reshape((n_apps, per) + a.shape[1:]),
                           stack)
    shared = _sub(params, "sa_")
    nb = cfg.num_shared_attn_blocks
    rope_sc = _rope(cfg, S, pos0=pos)
    single = mode == "decode"

    def shared_at(i):
        """Alternating shared block params: gather block i % nb."""
        idx = i % nb
        return jax.tree.map(lambda a: a[idx], shared)

    train = states is None and caches is None
    if train:
        def super_body(xx, xs):
            mstack, app_idx = xs

            def mbody(xx2, lp):
                def blk(a, b):
                    h = L.rmsnorm(a, b["m_ln"], cfg.norm_eps)
                    y, _ = mamba2_block(h, _mamba_pdict(b), cfg)
                    return a + y
                if remat:
                    blk = jax.checkpoint(blk)
                return blk(xx2, lp), None

            xx, _ = UR.scan(mbody, xx, mstack)
            sp = shared_at(app_idx)
            h = L.rmsnorm(xx, sp["ln1"], cfg.norm_eps)
            a, _ = attn_sublayer(h, sp, cfg, rope_sincos=rope_sc, mode="train")
            xx = xx + a
            h = L.rmsnorm(xx, sp["ln2"], cfg.norm_eps)
            xx = xx + L.glu_mlp(h, sp["w1"], sp["w3"], sp["w2"], act=cfg.act)
            return xx, None

        x, _ = UR.scan(super_body, x, (stack_r, jnp.arange(n_apps)))
        return x, None, None

    ssm_s, conv_s = states  # (L,B,H,N,P), (L,B,cw-1,cd)
    ssm_r = ssm_s.reshape((n_apps, per) + ssm_s.shape[1:])
    conv_r = conv_s.reshape((n_apps, per) + conv_s.shape[1:])
    ck, cv = caches  # (n_apps, B, Smax, KH, Dh) x2

    def super_body(xx, xs):
        mstack, app_idx, sstack, cstack, k_c, v_c = xs

        def mbody(xx2, xs2):
            lp, st_s, st_c = xs2
            h = L.rmsnorm(xx2, lp["m_ln"], cfg.norm_eps)
            y, ns = mamba2_block(h, _mamba_pdict(lp), cfg,
                                 state=MambaState(st_s, st_c),
                                 single_step=single)
            return xx2 + y, (ns.ssm, ns.conv)

        xx, (nss, ncs) = UR.scan(mbody, xx, (mstack, sstack, cstack))
        sp = shared_at(app_idx)
        h = L.rmsnorm(xx, sp["ln1"], cfg.norm_eps)
        a, nc = attn_sublayer(h, sp, cfg, rope_sincos=rope_sc, mode=mode,
                              cache=(k_c, v_c), pos=pos, dist=dist)
        xx = xx + a
        h = L.rmsnorm(xx, sp["ln2"], cfg.norm_eps)
        xx = xx + L.glu_mlp(h, sp["w1"], sp["w3"], sp["w2"], act=cfg.act)
        return xx, (nss, ncs, nc)

    x, (nss, ncs, nc) = UR.scan(
        super_body, x, (stack_r, jnp.arange(n_apps), ssm_r, conv_r, ck, cv))
    new_states = (nss.reshape(ssm_s.shape), ncs.reshape(conv_s.shape))
    return x, new_states, nc


# ===========================================================================
# ENC-DEC (whisper) trunk
# ===========================================================================

def encoder_trunk(params, cfg: ModelConfig, frames, *, remat=False):
    """frames: (B, S, frontend_dim) precomputed conv-frontend embeddings."""
    x = frames @ params["frontend_w"] + params["frontend_b"]
    B, S, D = x.shape
    x = x + L.sinusoid_positions(S, D)[None].astype(x.dtype)
    stack = _sub(params, "e_")  # keys: wq/wk/wv/wo/bq/bv/bo, mlp_*, ln1*/ln2*

    def body(xx, lp):
        def blk(a, b):
            h = L.layernorm(a, b["ln1"], b["ln1_b"], cfg.norm_eps)
            o, _ = attn_sublayer(h, b, cfg, mode="train", causal=False)
            a = a + o
            h = L.layernorm(a, b["ln2"], b["ln2_b"], cfg.norm_eps)
            return a + L.gelu_mlp(h, b["mlp_w1"], b["mlp_b1"],
                                  b["mlp_w2"], b["mlp_b2"])
        if remat:
            blk = jax.checkpoint(blk)
        return blk(xx, lp), None

    x, _ = UR.scan(body, x, stack)
    return L.layernorm(x, params["enc_final_norm"], params["enc_final_norm_b"],
                       cfg.norm_eps)


def decoder_trunk(params, cfg: ModelConfig, tokens, memory, *, mode="train",
                  caches=None, pos=0, remat=False):
    """tokens: (B, T); memory: (B, S_enc, D) or precomputed cross KV."""
    x = _embed(params, cfg, tokens)
    B, T, D = x.shape
    pos_emb = jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, T, 0)
    x = x + pos_emb[None]
    dstack = _sub(params, "d_")  # self-attn + mlp_* + ln1/ln2/ln3 (+biases)
    xstack = _sub(params, "x_")  # cross-attn projections

    new_caches = {} if caches is not None else None

    if caches is None:
        def body(xx, lps):
            lp, xp = lps

            def blk(a, b, c):
                h = L.layernorm(a, b["ln1"], b["ln1_b"], cfg.norm_eps)
                o, _ = attn_sublayer(h, b, cfg, mode="train", causal=True)
                a = a + o
                h = L.layernorm(a, b["ln2"], b["ln2_b"], cfg.norm_eps)
                o, _ = attn_sublayer(h, c, cfg, mode="train", causal=False,
                                     kv_src=memory)
                a = a + o
                h = L.layernorm(a, b["ln3"], b["ln3_b"], cfg.norm_eps)
                return a + L.gelu_mlp(h, b["mlp_w1"], b["mlp_b1"],
                                      b["mlp_w2"], b["mlp_b2"])
            if remat:
                blk = jax.checkpoint(blk)
            return blk(xx, lp, xp), None

        x, _ = UR.scan(body, x, (dstack, xstack))
        return x, None

    sk, sv = caches["self"]
    xk, xv = caches["cross"]  # precomputed (L, B, S_enc, KH, Dh)

    def body(xx, xs):
        lp, xp, k_c, v_c, xkc, xvc = xs
        h = L.layernorm(xx, lp["ln1"], lp["ln1_b"], cfg.norm_eps)
        o, nc = attn_sublayer(h, lp, cfg, mode=mode, cache=(k_c, v_c),
                              pos=pos, causal=True)
        xx = xx + o
        h = L.layernorm(xx, lp["ln2"], lp["ln2_b"], cfg.norm_eps)
        # cross attention against precomputed KV
        H, KH, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        q = (h @ xp["wq"] + xp["bq"]).reshape(B, -1, H, Dh)
        o = L.decode_attention(q, L.repeat_kv(xkc, H // KH),
                               L.repeat_kv(xvc, H // KH), xkc.shape[1]) \
            if mode == "decode" else \
            L.blockwise_attention(q, L.repeat_kv(xkc, H // KH),
                                  L.repeat_kv(xvc, H // KH), causal=False)
        o = o.reshape(B, -1, H * Dh) @ xp["wo"] + xp["bo"]
        xx = xx + o
        h = L.layernorm(xx, lp["ln3"], lp["ln3_b"], cfg.norm_eps)
        xx = xx + L.gelu_mlp(h, lp["mlp_w1"], lp["mlp_b1"],
                             lp["mlp_w2"], lp["mlp_b2"])
        return xx, nc

    x, ncs = UR.scan(body, x, (dstack, xstack, sk, sv, xk, xv))
    new_caches["self"] = ncs
    new_caches["cross"] = (xk, xv)
    return x, new_caches


def cross_kv(params, cfg: ModelConfig, memory):
    """Precompute decoder cross-attention K/V for all layers from memory."""
    xstack = _sub(params, "x_")
    B, S, D = memory.shape
    KH, Dh = cfg.num_kv_heads, cfg.head_dim

    def body(_, xp):
        k = (memory @ xp["wk"]).reshape(B, S, KH, Dh)
        v = (memory @ xp["wv"] + xp["bv"]).reshape(B, S, KH, Dh)
        return None, (k, v)

    _, (ks, vs) = UR.scan(body, None, xstack)
    return ks, vs
