"""Mixture-of-Experts layer with expert parallelism over the model axis.

Design (production pattern, validated against a dense-sum oracle):
  - Router + top-k run in plain jnp: activations are sharded over the batch
    axes and replicated over the model axis at this point, so the router is
    collective-free.
  - Dispatch / expert-compute / combine run under ``jax.shard_map`` manual
    over *only* the model axis (batch axes stay automatic). Each model rank
    owns E/tp experts, builds an (E_local, C) slot buffer by capacity
    scatter, runs the grouped SwiGLU matmuls on the MXU, gathers per-token
    results, and contributes a partial sum; a single ``psum`` over the model
    axis completes the combine — identical collective cost to a Megatron
    row-parallel matmul.
  - No all-to-all: tokens are replicated over the model axis between layers
    (Megatron TP convention), so expert parallelism only needs the final
    reduction. The trade-off (replicated activations vs. A2A dispatch) is
    recorded in DESIGN.md and revisited in EXPERIMENTS.md §Perf.

Capacity: C = ceil(cf * k * S / E) per sequence. Overflowed tokens fall into
a drop bin and contribute zero (standard capacity-factor semantics); the drop
fraction is returned as a metric.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.models.dist import DistContext
from repro.models.layers import glu_mlp


def capacity(cfg: ModelConfig, seq_len: int) -> int:
    c = int(cfg.capacity_factor * cfg.experts_per_token * seq_len
            / max(cfg.num_experts, 1)) + 1
    return max(8, -(-c // 8) * 8) if seq_len > 8 else max(1, c)


def router_topk(x: jax.Array, router_w: jax.Array, k: int):
    """x: (B,S,D) -> (top_vals (B,S,k) f32 renormalized, top_idx (B,S,k) i32,
    aux load-balance loss scalar)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)
    top_vals = top_vals / jnp.maximum(
        jnp.sum(top_vals, axis=-1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * sum_e f_e * P_e
    E = router_w.shape[-1]
    ass = jax.nn.one_hot(top_idx, E, dtype=jnp.float32).sum(axis=2)  # (B,S,E)
    f = jnp.mean(ass, axis=(0, 1)) / k
    p = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f * p)
    return top_vals, top_idx, aux


def _dispatch_compute_combine(x, top_vals, top_idx, wg, wu, wd, *,
                              cap: int, e_offset, E_local: int, act: str):
    """Local-expert dispatch -> grouped SwiGLU -> gather-combine partial sum.

    x: (B,S,D); top_vals/top_idx: (B,S,K); wg/wu: (E_local,D,F); wd: (E_local,F,D).
    Returns (partial_out (B,S,D), dropped_frac scalar).
    """
    B, S, D = x.shape
    K = top_idx.shape[-1]
    local = (top_idx >= e_offset) & (top_idx < e_offset + E_local)
    li = jnp.where(local, top_idx - e_offset, E_local)  # E_local == overflow bin
    onehot = jax.nn.one_hot(li, E_local + 1, dtype=jnp.int32)  # (B,S,K,El+1)
    assign = onehot.sum(axis=2)  # (B,S,El+1)
    pos_before = jnp.cumsum(assign, axis=1) - assign
    slot = jnp.einsum("bske,bse->bsk", onehot, pos_before)  # (B,S,K)
    ok = local & (slot < cap)
    flat = jnp.where(ok, li * cap + slot, E_local * cap)
    b3 = jnp.arange(B)[:, None, None]
    buf_tok = jnp.full((B, E_local * cap + 1), S, jnp.int32)
    buf_tok = buf_tok.at[b3, flat].set(
        jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, K)), mode="drop")
    buf_tok = buf_tok[:, : E_local * cap].reshape(B, E_local, cap)
    xpad = jnp.concatenate([x, jnp.zeros((B, 1, D), x.dtype)], axis=1)
    xe = xpad[b3[..., 0][:, :, None], buf_tok]  # (B,El,C,D)
    h = jnp.einsum("becd,edf->becf", xe, wg)
    u = jnp.einsum("becd,edf->becf", xe, wu)
    if act in ("silu", "swiglu"):
        h = jax.nn.silu(h)
    else:
        h = jax.nn.gelu(h, approximate=True)
    y = jnp.einsum("becf,efd->becd", h * u, wd)
    ypad = jnp.concatenate(
        [y.reshape(B, E_local * cap, D), jnp.zeros((B, 1, D), y.dtype)], axis=1)
    yk = ypad[b3[..., 0][:, :, None], flat]  # (B,S,K,D)
    w = jnp.where(ok, top_vals, 0.0).astype(yk.dtype)
    out = jnp.einsum("bsk,bskd->bsd", w, yk)
    dropped = jnp.mean((local & ~ok).astype(jnp.float32))
    return out, dropped


def moe_layer(
    x: jax.Array,
    router_w: jax.Array,
    wg: jax.Array,
    wu: jax.Array,
    wd: jax.Array,
    cfg: ModelConfig,
    dist: Optional[DistContext],
    shared: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full MoE layer. Returns (y, aux_loss, dropped_frac).

    wg/wu: (E, D, F); wd: (E, F, D) — sharded over E on the model axis.
    ``shared``: optional (wg, wu, wd) of the always-on shared-expert MLP.
    """
    E, K = cfg.num_experts, cfg.experts_per_token
    B, S, D = x.shape
    cap = capacity(cfg, S)
    top_vals, top_idx, aux = router_topk(x, router_w, K)
    top_vals = top_vals.astype(x.dtype)

    dp_ok = dist is not None and dist.mesh is not None \
        and B % dist.dp == 0
    if dist is not None and dist.manual_moe and E % dist.tp == 0 \
            and dist.tp > 1 and dp_ok:
        # FULL-manual shard_map (batch axes explicit too): the
        # partially-manual variant (auto batch axes) trips an XLA:CPU
        # partitioner CHECK ("Invalid binary instruction opcode copy") on
        # the dispatch scatter; full-manual sidesteps it and is also the
        # cheaper program (no auto-propagation through the scatter).
        E_local = E // dist.tp
        maxis = dist.model_axis
        P_ = jax.sharding.PartitionSpec
        spec_x = P_(dist.batch_axes, None, None)
        all_axes = tuple(dist.batch_axes) + (maxis,)
        n_all = dist.dp * dist.tp

        def inner(xl, tvl, til, wgl, wul, wdl):
            rank = jax.lax.axis_index(maxis)
            out, dropped = _dispatch_compute_combine(
                xl, tvl, til, wgl, wul, wdl,
                cap=cap, e_offset=rank * E_local, E_local=E_local, act=cfg.act)
            return (jax.lax.psum(out, maxis),
                    jax.lax.psum(dropped, all_axes) / n_all)

        y, dropped = shard_map(
            inner,
            mesh=dist.mesh,
            in_specs=(spec_x, spec_x, spec_x,
                      P_(maxis), P_(maxis), P_(maxis)),
            out_specs=(spec_x, P_()),
            check_vma=False,
        )(x, top_vals, top_idx, wg, wu, wd)
    else:
        y, dropped = _dispatch_compute_combine(
            x, top_vals, top_idx, wg, wu, wd,
            cap=cap, e_offset=0, E_local=E, act=cfg.act)

    if shared is not None:
        sg, su, sd = shared
        y = y + glu_mlp(x, sg, su, sd, act=cfg.act)
    return y, aux, dropped
