"""RWKV6 ("Finch") — attention-free token mixing with data-dependent decay.

Recurrence per head (head dim P, state S: (P_key, P_value)):
    out_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T
with per-channel decay w_t = exp(-exp(w0 + lora_w(x_t))) in (0, 1).

Chunked parallel form: within a chunk of length Q the pairwise decay
exp(lw_{t-1} - lw_s) is materialized as a (B,Q,Q,H,P) tensor and contracted
with r and k. Exponents are differences of a cumsum of log-decay, which can
be strongly negative but are clamped: per-step log decay is bounded below at
``LOG_DECAY_CLAMP`` so Q * |clamp| stays under the f32 exp range. Channels
decaying faster than exp(clamp) per step are numerically dead after two
steps anyway (relative error < 1e-3); this is the standard chunked-RWKV
stabilization and is recorded in DESIGN.md.

Token shift (ddlerp) follows the RWKV6 structure: five mix coefficients from
a low-rank tanh MLP on the shifted-delta, plus a low-rank decay head.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import unroll as UR

from repro.configs.base import ModelConfig
from repro.models.layers import rmsnorm

LOG_DECAY_CLAMP = -5.0   # per-step; chunk 16 -> max |exponent| 80 < 88 (f32)
CHUNK = 16
LORA_MIX = 32
LORA_DECAY = 64


class RWKVState(NamedTuple):
    wkv: jax.Array      # (B, H, P, P) f32
    shift_t: jax.Array  # (B, D) last input of the token-mix sublayer
    shift_c: jax.Array  # (B, D) last input of the channel-mix sublayer


def _shift(x: jax.Array, last: Optional[jax.Array]) -> jax.Array:
    """x: (B,S,D) -> previous-token tensor, seeded by ``last`` or zeros."""
    B, S, D = x.shape
    first = jnp.zeros((B, 1, D), x.dtype) if last is None \
        else last[:, None, :].astype(x.dtype)
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _ddlerp(x, sx, p):
    """Data-dependent lerp producing the 5 mixed streams (w,k,v,r,g)."""
    xx = x + sx * p["maa_x"]
    delta = jnp.tanh(xx @ p["maa_w1"])  # (B,S,5*LORA)
    B, S, _ = delta.shape
    delta = delta.reshape(B, S, 5, LORA_MIX)
    deltas = jnp.einsum("bsfl,fld->bsfd", delta, p["maa_w2"])  # (B,S,5,D)
    base = p["maa_wkvrg"]  # (5, D)
    mixed = x[:, :, None, :] + sx[:, :, None, :] * (base[None, None] + deltas)
    return [mixed[:, :, i, :] for i in range(5)]


def wkv_chunked(r, k, v, lw, u, init_state=None):
    """r,k,v: (B,S,H,P); lw: (B,S,H,P) log-decay (<=0); u: (H,P).
    Returns (out (B,S,H,P) f32, final_state (B,H,P,P))."""
    B, S, H, P = r.shape
    Q = max(1, min(CHUNK, S))
    while S % Q:
        Q //= 2
    nc = S // Q

    r = r.astype(jnp.float32).reshape(B, nc, Q, H, P)
    k = k.astype(jnp.float32).reshape(B, nc, Q, H, P)
    v = v.astype(jnp.float32).reshape(B, nc, Q, H, P)
    lw = lw.reshape(B, nc, Q, H, P)
    if init_state is None:
        init_state = jnp.zeros((B, H, P, P), jnp.float32)
    strict = jnp.tril(jnp.ones((Q, Q), jnp.bool_), k=-1)

    def body(state, xs):
        rq, kq, vq, lwq = xs  # (B,Q,H,P)
        clw = jnp.cumsum(lwq, axis=1)  # inclusive
        # pairwise decay from s (exclusive) to t-1 (inclusive): clw_{t-1}-clw_s
        clw_tm1 = jnp.concatenate(
            [jnp.zeros_like(clw[:, :1]), clw[:, :-1]], axis=1)
        diff = clw_tm1[:, :, None] - clw[:, None, :, :]  # (B,t,s,H,P)
        E = jnp.exp(jnp.where(strict[None, :, :, None, None], diff, -jnp.inf))
        A = jnp.einsum("bthp,bshp,btshp->btsh", rq, kq, E)
        A = A + jnp.einsum("bthp,bthp->bth", rq, kq * u[None, None])[
            :, :, None, :] * jnp.eye(Q, dtype=jnp.float32)[None, :, :, None]
        out = jnp.einsum("btsh,bshp->bthp", A, vq)
        # inter-chunk: state contribution decayed to t-1
        out = out + jnp.einsum("bthp,bhpz->bthz", rq * jnp.exp(clw_tm1), state)
        # state update: S_new = diag(exp(clw_Q)) S + sum_s k_s exp(clw_Q-clw_s) v_s^T
        w_tail = jnp.exp(clw[:, -1:, :] - clw)  # (B,Q,H,P)
        state_new = state * jnp.exp(clw[:, -1])[..., None] \
            + jnp.einsum("bshp,bshz->bhpz", kq * w_tail, vq)
        return state_new, out

    state, outs = UR.scan(
        body, init_state,
        (r.transpose(1, 0, 2, 3, 4), k.transpose(1, 0, 2, 3, 4),
         v.transpose(1, 0, 2, 3, 4), lw.transpose(1, 0, 2, 3, 4)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P), state


def wkv_step(state, r, k, v, lw, u):
    """Single token. r,k,v,lw: (B,1,H,P); state: (B,H,P,P)."""
    r1, k1, v1 = (t[:, 0].astype(jnp.float32) for t in (r, k, v))
    w1 = jnp.exp(lw[:, 0])
    kv = jnp.einsum("bhp,bhz->bhpz", k1, v1)
    out = jnp.einsum("bhp,bhpz->bhz", r1, state + u[None] [..., None] * kv)
    state_new = state * w1[..., None] + kv
    return out[:, None], state_new


def rwkv6_block(x: jax.Array, p: dict, cfg: ModelConfig,
                state: Optional[RWKVState] = None,
                single_step: bool = False) -> Tuple[jax.Array, RWKVState]:
    """One RWKV6 layer (time mix + channel mix), pre-norm residual.

    p keys: ln1_w, ln2_w, maa_x, maa_w1 (D,5*LORA_MIX), maa_w2 (5,LORA_MIX,D),
    maa_wkvrg (5,D), decay_base (D,), decay_w1 (D,LORA_DECAY),
    decay_w2 (LORA_DECAY,D), u (H,P), wr/wk/wv/wg/wo (D,D), gn_w (D,),
    cmix_mu_k (D,), cmix_mu_r (D,), cmix_k (D,F), cmix_v (F,D), cmix_r (D,D).
    """
    B, S, D = x.shape
    H, P = cfg.ssm_num_heads, cfg.ssm_head_dim

    # ---- time mix ----------------------------------------------------------
    xn = rmsnorm(x, p["ln1_w"], cfg.norm_eps)
    last_t = state.shift_t if state is not None else None
    sx = _shift(xn, last_t) - xn
    mw, mk, mv, mr, mg = _ddlerp(xn, sx, p)

    lw = p["decay_base"].astype(jnp.float32) + jnp.tanh(
        mw.astype(jnp.float32) @ p["decay_w1"].astype(jnp.float32)
    ) @ p["decay_w2"].astype(jnp.float32)
    # decay = exp(-exp(lw)); log-decay = -exp(lw), clamped for chunk stability
    log_decay = jnp.clip(-jnp.exp(lw), LOG_DECAY_CLAMP, 0.0)
    log_decay = log_decay.reshape(B, S, H, P)

    r = (mr @ p["wr"]).reshape(B, S, H, P)
    k = (mk @ p["wk"]).reshape(B, S, H, P)
    v = (mv @ p["wv"]).reshape(B, S, H, P)
    g = jax.nn.silu(mg @ p["wg"])

    prev = state.wkv if state is not None else None
    if single_step:
        assert prev is not None
        out, new_wkv = wkv_step(prev, r, k, v, log_decay, p["u"])
    else:
        out, new_wkv = wkv_chunked(r, k, v, log_decay, p["u"], init_state=prev)
    out = out.reshape(B, S, D)
    # per-head group norm
    out = out.reshape(B, S, H, P)
    mu = jnp.mean(out, axis=-1, keepdims=True)
    var = jnp.var(out, axis=-1, keepdims=True)
    out = ((out - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(B, S, D)
    out = out * p["gn_w"].astype(jnp.float32)
    x = x + ((out.astype(x.dtype) * g.astype(x.dtype)) @ p["wo"]).astype(x.dtype)
    new_shift_t = xn[:, -1, :].astype(jnp.float32)

    # ---- channel mix --------------------------------------------------------
    xn2 = rmsnorm(x, p["ln2_w"], cfg.norm_eps)
    last_c = state.shift_c if state is not None else None
    sx2 = _shift(xn2, last_c) - xn2
    xk = (xn2 + sx2 * p["cmix_mu_k"]).astype(x.dtype)
    xr = (xn2 + sx2 * p["cmix_mu_r"]).astype(x.dtype)
    kc = jnp.square(jax.nn.relu(xk @ p["cmix_k"]))
    out_c = jax.nn.sigmoid(xr @ p["cmix_r"]) * (kc @ p["cmix_v"])
    x = x + out_c.astype(x.dtype)
    new_shift_c = xn2[:, -1, :].astype(jnp.float32)

    return x, RWKVState(new_wkv, new_shift_t, new_shift_c)
