"""Scan indirection for roofline calibration.

XLA's cost_analysis() counts a while-loop body ONCE, regardless of trip
count, so every scan-over-layers / scan-over-chunks model would report
~1/L of its FLOPs.  The roofline calibrator therefore lowers *unrolled*
reduced-size variants (small L, small S) where cost_analysis is exact, and
extrapolates analytically (launch/roofline.py).

Production code paths always take the lax.scan branch — ``unrolled()`` is
only entered by the calibration tool.
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

_UNROLL = False


@contextlib.contextmanager
def unrolled():
    global _UNROLL
    old = _UNROLL
    _UNROLL = True
    try:
        yield
    finally:
        _UNROLL = old


def active() -> bool:
    return _UNROLL


def scan(body, init, xs, length=None):
    if not _UNROLL:
        return jax.lax.scan(body, init, xs, length=length)
    n = length if xs is None else jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(int(n)):
        xi = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if not ys:
        return carry, None
    leaves = jax.tree.leaves(ys[0])
    if not leaves:          # ys are None / empty pytrees
        return carry, None
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return carry, stacked
