"""Distribution context threaded through model code.

Model functions are mesh-agnostic: they receive a ``DistContext`` that names
the batch axes (data parallel, possibly ("pod", "data")) and the model/tensor
axis. ``dist=None`` (or a context with no mesh) means single-device execution
— used by smoke tests and the CPU examples.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax


@dataclass(frozen=True)
class DistContext:
    mesh: Optional[jax.sharding.Mesh] = None
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    # GSPMD-auto expert parallelism instead of the explicit shard_map
    # dispatch.  The shard_map path is the production default; auto is the
    # fallback for backward-of-shard_map patterns that trip XLA:CPU's
    # partitioner (dry-run only — see DESIGN.md §6).
    auto_moe: bool = False

    @property
    def manual_moe(self) -> bool:
        """Whether MoE should run under shard_map over the model axis."""
        return (not self.auto_moe and self.mesh is not None
                and self.model_axis in self.mesh.shape)

    @property
    def tp(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape.get(self.model_axis, 1)

    @property
    def dp(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for ax in self.batch_axes:
            n *= self.mesh.shape[ax]
        return n


LOCAL = DistContext(mesh=None)
