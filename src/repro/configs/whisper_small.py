"""whisper-small — encoder-decoder audio model, conv frontend (STUB).
[arXiv:2212.04356; unverified]

The conv frontend is a stub per the assignment: ``input_specs()`` provides
precomputed frame embeddings (post-conv, frontend_dim == d_model upstream mel
projection output); the model owns a linear adapter + sinusoidal positions.
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="whisper-small",
    family="encdec",
    source="[arXiv:2212.04356; unverified]",
    num_layers=12,  # per side
    encoder_layers=12,
    decoder_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,  # MHA
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    act="gelu",
    max_target_len=448,
    frontend="conv_audio",
    frontend_dim=768,
    tie_embeddings=True,
    norm_eps=1e-5,
)

SMOKE = FULL.replace(
    name="whisper-small-smoke",
    num_layers=2,
    encoder_layers=2,
    decoder_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    max_target_len=32,
    frontend_dim=64,
)
