"""rwkv6-7b (Finch) — attention-free RNN with data-dependent decay.
[arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    source="[arXiv:2404.05892; hf]",
    num_layers=32,
    d_model=4096,
    num_heads=0,  # attention-free
    num_kv_heads=0,
    head_dim=0,
    d_ff=14336,
    vocab_size=65536,
    ssm_num_heads=64,  # rwkv6 heads: d_model / 64
    ssm_head_dim=64,
    ssm_chunk=256,
    tie_embeddings=False,
)

SMOKE = FULL.replace(
    name="rwkv6-7b-smoke",
    num_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=256,
    ssm_num_heads=4,
    ssm_head_dim=16,
    ssm_chunk=16,
)
