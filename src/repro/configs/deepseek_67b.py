"""deepseek-67b — dense llama-arch LM. [arXiv:2401.02954; hf]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="deepseek-67b",
    family="dense",
    source="[arXiv:2401.02954; hf]",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102400,
    rope_theta=10_000.0,
    tie_embeddings=False,
)

SMOKE = FULL.replace(
    name="deepseek-67b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
)
