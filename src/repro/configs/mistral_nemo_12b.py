"""mistral-nemo-12b — dense LM, 128k ctx, head_dim 128 (< d_model/num_heads).
[hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    source="[hf:mistralai/Mistral-Nemo-Base-2407; hf]",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
)

SMOKE = FULL.replace(
    name="mistral-nemo-12b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
)
