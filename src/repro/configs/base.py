"""Config dataclasses shared by every architecture in the zoo.

A single ``ModelConfig`` describes all families (dense / moe / ssm / hybrid /
encdec / vlm); family-specific fields default to 0/off. Shape cells
(``ShapeCell``) pair a config with one of the four assigned input shapes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    # identity ---------------------------------------------------------------
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    source: str = ""  # provenance note: [arXiv/hf ref; verification tier]

    # trunk ------------------------------------------------------------------
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0  # 0 => attention-free trunk
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    norm_eps: float = 1e-6
    rope_theta: float = 10_000.0
    act: str = "silu"  # silu (SwiGLU) | gelu (plain MLP, whisper)
    tie_embeddings: bool = True

    # attention pattern --------------------------------------------------------
    window_size: int = 0  # 0 => full attention everywhere
    global_every: int = 0  # gemma3: one global layer per this many layers
    logit_softcap: float = 0.0  # gemma-style attn logit soft-capping

    # moe ----------------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0  # per-(routed)-expert hidden dim
    num_shared_experts: int = 0
    shared_d_ff: int = 0  # total hidden dim of the shared-expert MLP
    first_dense_layers: int = 0  # deepseek-moe: leading dense layers
    router_aux_coef: float = 0.001
    capacity_factor: float = 1.25

    # ssm (mamba2 / rwkv6) -------------------------------------------------------
    ssm_state_dim: int = 0
    ssm_num_heads: int = 0
    ssm_head_dim: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    attn_every: int = 0  # zamba2: shared attention block every N ssm blocks
    num_shared_attn_blocks: int = 0  # zamba2: how many distinct shared blocks

    # encoder-decoder --------------------------------------------------------------
    encoder_layers: int = 0
    decoder_layers: int = 0
    max_target_len: int = 448

    # modality frontend (stub per assignment: input_specs() provides embeddings)
    frontend: str = "none"  # none | conv_audio | vit_patch
    frontend_dim: int = 0  # dim of precomputed frame/patch embeddings

    # numerics -------------------------------------------------------------------
    dtype: str = "bfloat16"
    # perf variants (EXPERIMENTS.md §Perf; defaults = paper-era baseline)
    decode_grouped_attn: bool = False  # GQA decode without repeat_kv blowup
    kv_cache_dtype: str = "bfloat16"   # | float8_e4m3fn (halves cache bytes)

    # --- derived -----------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def ssm_inner(self) -> int:
        return self.ssm_num_heads * self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # parameter counting (used for 6·N·D roofline cross-checks) -----------------
    def param_count(self) -> int:
        return sum(int(x) for x in _param_counts(self).values())

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        counts = _param_counts(self)
        total = sum(int(v) for v in counts.values())
        if self.num_experts and self.experts_per_token:
            routed = counts["moe_routed"]
            total -= int(routed)
            total += int(routed * self.experts_per_token / self.num_experts)
        return int(total)


def _param_counts(cfg: ModelConfig) -> dict:
    """Analytic per-component parameter counts; mirrors models/params.py init."""
    d = cfg.d_model
    counts: dict = {"embed": cfg.vocab_size * d}
    if not cfg.tie_embeddings:
        counts["unembed"] = cfg.vocab_size * d

    def attn_params() -> int:
        return d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d

    def mlp_params(ff: int) -> int:
        if cfg.act in ("silu", "gelu_glu"):  # GLU family: 3 mats, no bias
            return 3 * d * ff
        return 2 * d * ff + ff + d  # plain gelu mlp with biases (whisper)

    if cfg.family in ("dense", "vlm"):
        counts["attn"] = cfg.num_layers * attn_params()
        counts["mlp"] = cfg.num_layers * mlp_params(cfg.d_ff)
        counts["norms"] = cfg.num_layers * 2 * d + d
        if cfg.frontend == "vit_patch":
            counts["frontend_proj"] = cfg.frontend_dim * d + d
    elif cfg.family == "moe":
        n_moe = cfg.num_layers - cfg.first_dense_layers
        counts["attn"] = cfg.num_layers * attn_params()
        counts["dense_mlp"] = cfg.first_dense_layers * mlp_params(cfg.d_ff)
        counts["moe_routed"] = n_moe * cfg.num_experts * 3 * d * cfg.moe_d_ff
        counts["moe_shared"] = (
            n_moe * 3 * d * cfg.shared_d_ff if cfg.num_shared_experts else 0
        )
        counts["router"] = n_moe * d * cfg.num_experts
        counts["norms"] = cfg.num_layers * 2 * d + d
    elif cfg.family == "ssm":  # rwkv6
        lora_mix, lora_decay = 32, 64  # matches models/rwkv.py
        tmix = (5 * d * d                       # wr wk wv wg wo
                + 2 * 5 * lora_mix * d          # maa_w1 + maa_w2
                + 2 * lora_decay * d            # decay_w1 + decay_w2
                + 11 * d)                       # maa_x, wkvrg(5d), u, gn, ln1+2, decay_base
        cmix = 2 * d * cfg.d_ff + d * d + 2 * d
        counts["tmix"] = cfg.num_layers * tmix
        counts["cmix"] = cfg.num_layers * cmix
        counts["norms"] = 2 * d  # ln_in + final_norm
    elif cfg.family == "hybrid":  # zamba2
        inner = cfg.ssm_inner
        per_mamba = (
            d * (2 * inner + 2 * cfg.ssm_state_dim * (inner // cfg.ssm_head_dim or 1))
            + inner * d
            + 3 * inner  # conv/dt/norm-ish small terms folded
        )
        # mamba2 in/out proj dominate: in = d -> 2*inner + 2*ngroups*state + nheads
        nheads = cfg.ssm_num_heads
        per_mamba = d * (2 * inner + 2 * cfg.ssm_state_dim + nheads) + inner * d + inner
        counts["mamba"] = cfg.num_layers * per_mamba
        n_attn = cfg.num_shared_attn_blocks
        counts["shared_attn"] = n_attn * (attn_params() + mlp_params(cfg.d_ff))
        counts["norms"] = cfg.num_layers * 2 * d + d + n_attn * 2 * d
    elif cfg.family == "encdec":
        enc_l, dec_l = cfg.encoder_layers, cfg.decoder_layers
        n_attn = enc_l + 2 * dec_l
        counts["enc_attn"] = enc_l * attn_params()
        counts["enc_mlp"] = enc_l * mlp_params(cfg.d_ff)
        counts["dec_self_attn"] = dec_l * attn_params()
        counts["dec_cross_attn"] = dec_l * attn_params()
        counts["dec_mlp"] = dec_l * mlp_params(cfg.d_ff)
        counts["attn_biases"] = n_attn * (cfg.q_dim + cfg.kv_dim + d)
        counts["norms"] = 2 * ((enc_l * 2 + dec_l * 3) * d + 2 * d)  # w + b
        counts["dec_pos"] = cfg.max_target_len * d
        if cfg.frontend == "conv_audio":
            counts["frontend_proj"] = cfg.frontend_dim * d + d
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return counts


# ---------------------------------------------------------------------------
# Input-shape cells (assigned set; identical across archs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    """Knobs for the training loop / hillclimbing."""
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    microbatch: int = 0  # 0 => no gradient accumulation
    remat: str = "block"  # none | block | offloadable
    sharding_mode: str = "tp"  # tp (paper-era baseline) | fsdp | fsdp_pod
    grad_compression: str = "none"  # none | int8
    causal_skip: bool = False  # skip fully-masked attention chunks (perf)
    seed: int = 0


@dataclass(frozen=True)
class ServeConfig:
    """Knobs for the serving engine."""
    max_batch: int = 128
    max_seq: int = 32_768
    roi_sparsity: bool = False  # CrossRoI token-RoI packed prefill
    kv_seq_shard: bool = False  # shard KV cache sequence dim over the data axis
    decode_attn_impl: str = "full"  # full | banded (for SWA archs)
