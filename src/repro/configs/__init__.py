from repro.configs.base import ModelConfig, ShapeCell, SHAPES, TrainConfig, ServeConfig
from repro.configs.registry import (
    ARCH_IDS,
    LONG_CONTEXT_ARCHS,
    all_cells,
    cell_is_applicable,
    get_config,
)

__all__ = [
    "ModelConfig",
    "ShapeCell",
    "SHAPES",
    "TrainConfig",
    "ServeConfig",
    "ARCH_IDS",
    "LONG_CONTEXT_ARCHS",
    "all_cells",
    "cell_is_applicable",
    "get_config",
]
