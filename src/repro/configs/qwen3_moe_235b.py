"""qwen3-moe-235b-a22b — MoE LM: 128 experts, top-8, no shared experts.
[hf:Qwen/Qwen3-235B-A22B (scaled family ref Qwen3-30B-A3B); hf]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    source="[hf:Qwen/Qwen3-30B-A3B; hf]",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,  # kept for reference; routed expert hidden = moe_d_ff
    vocab_size=151936,
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=1536,
    num_shared_experts=0,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

SMOKE = FULL.replace(
    name="qwen3-moe-235b-a22b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=32,
    vocab_size=256,
    num_experts=8,
    experts_per_token=2,
    moe_d_ff=32,
    capacity_factor=4.0,  # effectively dropless at smoke scale
)
