"""Architecture registry: ``--arch <id>`` resolution + the CrossRoI app config."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, SHAPES, ShapeCell

_ARCH_MODULES = {
    "deepseek-67b": "repro.configs.deepseek_67b",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "h2o-danube3-4b": "repro.configs.h2o_danube3_4b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "whisper-small": "repro.configs.whisper_small",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)

# Sub-quadratic-capable archs run long_500k; pure full-attention archs skip it
# (DESIGN.md §Arch-applicability records the rationale per arch).
LONG_CONTEXT_ARCHS = {"gemma3-27b", "h2o-danube3-4b", "zamba2-2.7b", "rwkv6-7b"}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(_ARCH_MODULES[arch])
    return mod.SMOKE if smoke else mod.FULL


def cell_is_applicable(arch: str, shape_name: str) -> bool:
    """Whether a (arch x shape) dry-run cell runs or is a recorded skip."""
    if shape_name == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


def all_cells(include_skips: bool = False):
    """Yield (arch, ShapeCell, applicable) over the 40-cell assignment grid."""
    for arch in ARCH_IDS:
        for shape in SHAPES.values():
            ok = cell_is_applicable(arch, shape.name)
            if ok or include_skips:
                yield arch, shape, ok
