"""gemma3-27b — dense LM, 5:1 local:global sliding-window attention, 128k ctx.
[hf:google/gemma-3-1b-pt scaled per assignment; unverified]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="gemma3-27b",
    family="dense",
    source="[hf:google/gemma-3-*-pt; unverified]",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    window_size=1024,
    global_every=6,  # pattern: 5 local sliding-window layers then 1 global
    rope_theta=1_000_000.0,
    logit_softcap=0.0,
    act="gelu_glu",  # gemma uses GeGLU
)

SMOKE = FULL.replace(
    name="gemma3-27b-smoke",
    num_layers=7,  # exercises one full 6-layer pattern + 1 trailing local layer
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    window_size=32,
)
