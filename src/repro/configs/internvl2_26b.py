"""internvl2-26b — VLM: InternViT frontend (STUB) + InternLM2-20B backbone.
[arXiv:2404.16821; hf]

Per the assignment, the modality frontend is a stub: ``input_specs()`` provides
precomputed patch embeddings (frontend_dim-wide), and the model owns only the
projection into the backbone width. This is the arch most representative of the
paper's technique: cross-camera RoI masks drop redundant patches before the
backbone (see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    source="[arXiv:2404.16821; hf]",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    rope_theta=1_000_000.0,
    frontend="vit_patch",
    frontend_dim=3200,  # InternViT-6B output width
    tie_embeddings=False,
)

SMOKE = FULL.replace(
    name="internvl2-26b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    frontend_dim=48,
)
