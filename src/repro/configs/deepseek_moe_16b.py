"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed experts, top-6,
first layer dense. [arXiv:2401.06066; hf]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    source="[arXiv:2401.06066; hf]",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,  # MHA
    head_dim=128,
    d_ff=10944,  # dense first-layer MLP hidden
    vocab_size=102400,
    num_experts=64,
    experts_per_token=6,
    moe_d_ff=1408,
    num_shared_experts=2,
    shared_d_ff=2816,  # 2 shared experts x 1408
    first_dense_layers=1,
    tie_embeddings=False,
)

SMOKE = FULL.replace(
    name="deepseek-moe-16b-smoke",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    num_experts=8,
    experts_per_token=2,
    moe_d_ff=32,
    shared_d_ff=64,
    first_dense_layers=1,
    capacity_factor=4.0,  # effectively dropless at smoke scale
)
