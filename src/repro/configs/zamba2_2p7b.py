"""zamba2-2.7b — hybrid: Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]

54 Mamba2 blocks; a shared transformer block (attention + MLP, two distinct
parameter sets used alternately) is interleaved every ``attn_every`` blocks.
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="[arXiv:2411.15242; hf]",
    num_layers=54,  # mamba2 blocks
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,  # shared attention block is MHA
    head_dim=80,
    d_ff=10240,  # shared block MLP hidden
    vocab_size=32000,
    ssm_state_dim=64,
    ssm_num_heads=80,
    ssm_head_dim=64,  # inner = expand*d = 5120 = 80 heads x 64
    ssm_expand=2,
    ssm_chunk=256,
    attn_every=6,  # shared attn block after every 6 mamba blocks
    num_shared_attn_blocks=2,
)

SMOKE = FULL.replace(
    name="zamba2-2.7b-smoke",
    num_layers=6,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    ssm_state_dim=16,
    ssm_num_heads=8,
    ssm_head_dim=16,  # inner = 128 = 2*64
    ssm_chunk=16,
    attn_every=3,
)
