"""h2o-danube-3-4b — dense llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="h2o-danube3-4b",
    family="dense",
    source="[arXiv:2401.16818; unverified]",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    window_size=4096,  # mistral-style SWA on every layer
    rope_theta=10_000.0,
)

SMOKE = FULL.replace(
    name="h2o-danube3-4b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    window_size=32,
)
