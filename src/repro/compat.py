"""Version-tolerant wrappers over jax APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace, and its replication-check kwarg was renamed
(``check_rep`` -> ``check_vma``) in the same move.  Every in-repo caller
goes through :func:`shard_map` below so the rest of the codebase is
agnostic to which jax is installed.
"""
from __future__ import annotations

from typing import Any

import jax

if hasattr(jax, "shard_map"):                      # jax >= 0.6 style

    def shard_map(f, *, mesh, in_specs, out_specs,
                  check_vma: bool = False) -> Any:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)

else:                                              # jax 0.4.x style
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs,
                  check_vma: bool = False) -> Any:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)


__all__ = ["shard_map"]
