"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contracts: tests sweep shapes/dtypes and assert
the Pallas kernels (run in interpret mode on CPU) match these references.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# sbnet gather / scatter (tile granularity)
# ---------------------------------------------------------------------------

def sbnet_gather(x: jax.Array, idx: jax.Array, th: int, tw: int) -> jax.Array:
    """x: (H, W, C); idx: (n, 2) int32 tile coords (ty, tx).
    Returns packed (n, th, tw, C)."""
    def take(t):
        ty, tx = t[0], t[1]
        return jax.lax.dynamic_slice(
            x, (ty * th, tx * tw, 0), (th, tw, x.shape[-1]))
    return jax.vmap(take)(idx)


def sbnet_scatter(packed: jax.Array, idx: jax.Array, base: jax.Array,
                  th: int, tw: int) -> jax.Array:
    """Write packed tiles back into ``base`` at their tile positions.
    Tiles must be disjoint (guaranteed by mask construction)."""
    def body(i, acc):
        ty, tx = idx[i, 0], idx[i, 1]
        return jax.lax.dynamic_update_slice(
            acc, packed[i], (ty * th, tx * tw, 0))
    return jax.lax.fori_loop(0, idx.shape[0], body, base)


# ---------------------------------------------------------------------------
# roi conv (3x3, stride 1, same padding over the *full* frame, evaluated
# only on active tiles)
# ---------------------------------------------------------------------------

def roi_conv(x: jax.Array, w: jax.Array, idx: jax.Array,
             th: int, tw: int) -> jax.Array:
    """x: (H, W, Cin); w: (3, 3, Cin, Cout); idx: (n, 2) tile coords.
    Returns packed conv outputs (n, th, tw, Cout): identical to running a
    SAME conv over the whole frame then gathering the active tiles."""
    full = jax.lax.conv_general_dilated(
        x[None].astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    return sbnet_gather(full.astype(x.dtype), idx, th, tw)


def rims_of_packed(packed, nbr):
    """Oracle for the coalesced rim halos (kernels/roi_conv.py): assemble
    each tile's halo strips from the packed tensor + (n, 8) neighbor
    table.  Returns (rim_top (n+1, tw+2, C), rim_bot (n+1, tw+2, C),
    rim_left (n+1, th, C), rim_right (n+1, th, C)); slot n is the trash
    slot and positions with no active donor are zero (the same values the
    kernels' read-side masking produces — kernels may leave garbage there
    because consumers always mask by the neighbor table)."""
    import numpy as np
    packed = np.asarray(packed)
    nbr = np.asarray(nbr)
    n, th, tw, C = packed.shape
    rt = np.zeros((n + 1, tw + 2, C), packed.dtype)
    rb = np.zeros((n + 1, tw + 2, C), packed.dtype)
    rl = np.zeros((n + 1, th, C), packed.dtype)
    rr = np.zeros((n + 1, th, C), packed.dtype)

    def tgt(i, j):
        s = int(nbr[i, j])
        return s if s >= 0 else n

    for i in range(n):
        o = packed[i]
        rt[tgt(i, 6), 1:1 + tw] = o[th - 1]        # we are S's N donor
        rt[tgt(i, 7), 0] = o[th - 1, tw - 1]       # SE's NW corner donor
        rt[tgt(i, 5), tw + 1] = o[th - 1, 0]       # SW's NE corner donor
        rb[tgt(i, 1), 1:1 + tw] = o[0]             # N's S donor
        rb[tgt(i, 2), 0] = o[0, tw - 1]            # NE's SW corner donor
        rb[tgt(i, 0), tw + 1] = o[0, 0]            # NW's SE corner donor
        rl[tgt(i, 4)] = o[:, tw - 1]               # E's W donor
        rr[tgt(i, 3)] = o[:, 0]                    # W's E donor
    return rt, rb, rl, rr


def roi_conv_packed(packed: jax.Array, idx: jax.Array, grid_shape,
                    w: jax.Array) -> jax.Array:
    """Oracle for the packed-resident conv: scatter the packed tiles onto a
    zeroed full frame (inactive tiles = 0, exactly the zero-halo contract),
    run a SAME conv, gather the active tiles back."""
    n, th, tw, C = packed.shape
    H, W = grid_shape[0] * th, grid_shape[1] * tw
    base = jnp.zeros((H, W, C), packed.dtype)
    full = sbnet_scatter(packed, idx, base, th, tw)
    return roi_conv(full, w, idx, th, tw)


# ---------------------------------------------------------------------------
# roi attention (packed prefill with original-position causal mask)
# ---------------------------------------------------------------------------

def roi_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  positions: jax.Array, scale: float | None = None
                  ) -> jax.Array:
    """q,k,v: (S, H, D) packed (RoI-kept) tokens; positions: (S,) int32
    original positions (padding rows use position INT32_MAX for k-masking).
    Causal over original positions: query i attends key j iff
    positions[i] >= positions[j]."""
    S, H, D = q.shape
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    logits = jnp.einsum("qhd,khd->hqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = positions[:, None] >= positions[None, :]
    logits = jnp.where(mask[None], logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("hqk,khd->qhd", p / denom, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# tile delta + zero-run byte estimation
# ---------------------------------------------------------------------------

def tile_delta(cur, prev, idx, th: int, tw: int, qstep: float = 8.0,
               coef_bits: int = 6, run_bits: int = 10):
    """Numpy oracle for kernels/tile_delta.py — same integer math, same
    float32 quantization, same row-independent zero-run definition, so the
    Pallas kernel must match it BIT-EXACTLY.  Returns (n, 8) int32 rows of
    ``[byte_estimate, nnz, zero_runs, sum_abs_q, 0, 0, 0, 0]``."""
    import numpy as np
    cur = np.asarray(cur, np.float32)
    prev = np.asarray(prev, np.float32)
    idx = np.asarray(idx)
    out = np.zeros((idx.shape[0], 8), np.int32)
    for i, (ty, tx) in enumerate(idx):
        c = cur[ty * th:(ty + 1) * th, tx * tw:(tx + 1) * tw, :]
        p = prev[ty * th:(ty + 1) * th, tx * tw:(tx + 1) * tw, :]
        q = np.round((c - p) / np.float32(qstep)).astype(np.int32)
        z2 = (q == 0).reshape(th, -1)
        nnz = int((~z2).sum())
        left = np.concatenate([np.zeros((th, 1), bool), z2[:, :-1]], axis=1)
        runs = int((z2 & ~left).sum())
        sabs = int(np.abs(q).sum())
        out[i] = [(nnz * coef_bits + runs * run_bits + 7) // 8,
                  nnz, runs, sabs, 0, 0, 0, 0]
    return out


def tile_delta_gate(cur, prev, idx, th: int, tw: int, qstep: float = 8.0,
                    coef_bits: int = 6, run_bits: int = 10):
    """Numpy oracle for ``kernels/tile_delta.tile_delta_gate``: per active
    tile of a stacked fleet, the BODY delta stats (cols 0..3, identical
    to ``tile_delta`` on that camera) plus the HALOED-WINDOW stats the
    temporal reuse gate thresholds — col 4 the exact bitwise change count
    of the (th+2, tw+2, C) window, col 5 its quantized byte estimate.

    cur, prev: UNPADDED (C, H, W, Cin) stacked frames (the oracle applies
    the same zero padding the kernel's callers do); idx: (n, 3) int32
    (cam, ty, tx).  Bit-exact contract."""
    import numpy as np
    cur = np.asarray(cur, np.float32)
    prev = np.asarray(prev, np.float32)
    idx = np.asarray(idx)
    pad = ((0, 0), (1, 1), (1, 1), (0, 0))
    cur_p = np.pad(cur, pad)
    prev_p = np.pad(prev, pad)

    def stats(c, p):
        rows = c.shape[0]
        q = np.round((c - p) / np.float32(qstep)).astype(np.int32)
        z2 = (q == 0).reshape(rows, -1)
        nnz = int((~z2).sum())
        left = np.concatenate([np.zeros((rows, 1), bool), z2[:, :-1]],
                              axis=1)
        runs = int((z2 & ~left).sum())
        return ((nnz * coef_bits + runs * run_bits + 7) // 8, nnz, runs,
                int(np.abs(q).sum()))

    out = np.zeros((idx.shape[0], 8), np.int32)
    for i, (cam, ty, tx) in enumerate(idx):
        cw = cur_p[cam, ty * th:ty * th + th + 2,
                   tx * tw:tx * tw + tw + 2, :]
        pw = prev_p[cam, ty * th:ty * th + th + 2,
                    tx * tw:tx * tw + tw + 2, :]
        b = stats(cw[1:1 + th, 1:1 + tw], pw[1:1 + th, 1:1 + tw])
        w = stats(cw, pw)
        out[i] = [b[0], b[1], b[2], b[3], int((cw != pw).sum()), w[0],
                  0, 0]
    return out


def tile_delta_halo(cur, prev, idx, th: int, tw: int, qstep: float = 8.0,
                    coef_bits: int = 6, run_bits: int = 10):
    """Numpy oracle for ``kernels/tile_delta.tile_delta_halo``: delta
    stats of each tile's edge ring as 4 independent scan strips (top row,
    bottom row, left column, right column; corners in both a row and a
    column strip — the duplication is the halo cost).  Bit-exact
    contract, same stats row layout as ``tile_delta``."""
    import numpy as np
    cur = np.asarray(cur, np.float32)
    prev = np.asarray(prev, np.float32)
    idx = np.asarray(idx)
    out = np.zeros((idx.shape[0], 8), np.int32)
    for i, (ty, tx) in enumerate(idx):
        y0, x0 = ty * th, tx * tw
        strips = [(cur[y0, x0:x0 + tw], prev[y0, x0:x0 + tw]),
                  (cur[y0 + th - 1, x0:x0 + tw],
                   prev[y0 + th - 1, x0:x0 + tw]),
                  (cur[y0:y0 + th, x0], prev[y0:y0 + th, x0]),
                  (cur[y0:y0 + th, x0 + tw - 1],
                   prev[y0:y0 + th, x0 + tw - 1])]
        nnz = runs = sabs = 0
        for c, p in strips:
            q = np.round((c - p) / np.float32(qstep)).astype(np.int32)
            z = (q == 0).reshape(1, -1)
            nnz += int((~z).sum())
            left = np.concatenate([np.zeros((1, 1), bool), z[:, :-1]],
                                  axis=1)
            runs += int((z & ~left).sum())
            sabs += int(np.abs(q).sum())
        out[i] = [(nnz * coef_bits + runs * run_bits + 7) // 8,
                  nnz, runs, sabs, 0, 0, 0, 0]
    return out
