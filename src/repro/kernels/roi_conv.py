"""RoI-sparse 3x3 convolution as Pallas TPU kernels.

Two kernels implement the RoI-YOLO layer (paper §4.4):

``roi_conv`` — the *entry* layer: convolution evaluated only on active
tiles, reading straight from the full frame.  grid=(n_active,); per step
the kernel DMAs one *haloed* (th+2, tw+2, Cin) window from the padded
feature map in HBM (dynamic-start, static-size slice — a block DMA on
Mosaic), then computes the 3x3 conv as 9 shifted (th*tw, Cin) @ (Cin, Cout)
matmuls on the MXU.  This fuses SBNet's gather into the first conv.

``roi_conv_packed`` — every *subsequent* layer: consumes the previous
layer's packed (n, th, tw, C) output directly, so the sparse representation
never round-trips through a full-frame scatter between layers.  Halo rows/
columns come from neighbor tiles via an offline-computed (n, 8) neighbor
table (scalar-prefetched into SMEM): entry j holds the packed slot of the
j-th neighbor (NW, N, NE, W, E, SW, S, SE order) or -1 when that neighbor
is inactive/off-frame, in which case the halo strip is zero — exactly the
value the old scatter-into-zeros path produced, so the packed chain is
bit-compatible with the scatter/gather chain on every tile.

Keep th*tw and channel dims multiples of 128 for full MXU utilization;
both kernels are functional for any size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pltpu.TPUMemorySpace was renamed MemorySpace across jax versions
_MEMSPACE = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace

# neighbor-table column order: (dy, dx) offsets of the 8 surrounding tiles
NEIGHBOR_OFFSETS = ((-1, -1), (-1, 0), (-1, 1), (0, -1),
                    (0, 1), (1, -1), (1, 0), (1, 1))


def _conv3x3_tile(win: jax.Array, w_ref, th: int, tw: int,
                  cout: int) -> jax.Array:
    """(th+2, tw+2, Cin) haloed window -> (th, tw, Cout) via 9 MXU matmuls."""
    cin = win.shape[-1]
    acc = jnp.zeros((th * tw, cout), jnp.float32)
    for dy in range(3):
        for dx in range(3):
            patch = win[dy:dy + th, dx:dx + tw, :].reshape(th * tw, cin)
            acc += patch.astype(jnp.float32) @ w_ref[dy, dx].astype(
                jnp.float32)
    return acc.reshape(th, tw, cout)


def _roi_conv_kernel(idx_ref, x_ref, w_ref, o_ref, *, th: int, tw: int):
    i = pl.program_id(0)
    ty = idx_ref[i, 0]
    tx = idx_ref[i, 1]
    cout = o_ref.shape[-1]
    # haloed window from the (H+2, W+2, Cin) padded map
    win = pl.load(x_ref, (pl.ds(ty * th, th + 2), pl.ds(tx * tw, tw + 2),
                          slice(None)))
    o_ref[0] = _conv3x3_tile(win, w_ref, th, tw, cout).astype(o_ref.dtype)


def roi_conv(x: jax.Array, w: jax.Array, idx: jax.Array, th: int, tw: int,
             *, interpret: bool = True) -> jax.Array:
    """x: (H, W, Cin); w: (3, 3, Cin, Cout); idx: (n, 2) int32 tile coords.
    Returns packed SAME-conv outputs on active tiles: (n, th, tw, Cout)."""
    H, W, Cin = x.shape
    Cout = w.shape[-1]
    n = idx.shape[0]
    xp = jnp.pad(x, ((1, 1), (1, 1), (0, 0)))
    kernel = functools.partial(_roi_conv_kernel, th=th, tw=tw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            # whole padded map stays in ANY/HBM; the kernel slices windows
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
            pl.BlockSpec((3, 3, Cin, Cout), lambda i, idx_ref: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, th, tw, Cout),
                               lambda i, idx_ref: (i, 0, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, th, tw, Cout), x.dtype),
        interpret=interpret,
    )(idx, xp, w)


# ---------------------------------------------------------------------------
# packed-resident conv: halo strips fetched from neighbor tiles
# ---------------------------------------------------------------------------

def _halo_strip(p_ref, slot, ys, ny, xs, nx):
    """Load packed[slot, ys:ys+ny, xs:xs+nx, :]; zero when slot == -1.

    The load is issued at the clamped slot (so it is always in-bounds) and
    masked afterwards — data-dependent *suppression*, not data-dependent
    control flow, which keeps the DMA schedule static.
    """
    safe = jnp.maximum(slot, 0)
    strip = pl.load(p_ref, (pl.ds(safe, 1), pl.ds(ys, ny), pl.ds(xs, nx),
                            slice(None)))[0]
    return jnp.where(slot >= 0, strip, jnp.zeros_like(strip))


def _roi_conv_packed_kernel(nbr_ref, p_ref, w_ref, o_ref, *,
                            th: int, tw: int):
    i = pl.program_id(0)
    cout = o_ref.shape[-1]
    z = jnp.asarray(0, jnp.int32)

    center = pl.load(p_ref, (pl.ds(i, 1), pl.ds(z, th), pl.ds(z, tw),
                             slice(None)))[0]                 # (th, tw, C)

    # 8 halo strips, indexed by the prefetched neighbor table.  Each strip
    # is the 1-deep edge of the neighbor facing us: the N neighbor donates
    # its bottom row, the W neighbor its rightmost column, corners one px.
    nw = _halo_strip(p_ref, nbr_ref[i, 0], th - 1, 1, tw - 1, 1)  # (1,1,C)
    n_ = _halo_strip(p_ref, nbr_ref[i, 1], th - 1, 1, 0, tw)      # (1,tw,C)
    ne = _halo_strip(p_ref, nbr_ref[i, 2], th - 1, 1, 0, 1)       # (1,1,C)
    w_ = _halo_strip(p_ref, nbr_ref[i, 3], 0, th, tw - 1, 1)      # (th,1,C)
    e_ = _halo_strip(p_ref, nbr_ref[i, 4], 0, th, 0, 1)           # (th,1,C)
    sw = _halo_strip(p_ref, nbr_ref[i, 5], 0, 1, tw - 1, 1)       # (1,1,C)
    s_ = _halo_strip(p_ref, nbr_ref[i, 6], 0, 1, 0, tw)           # (1,tw,C)
    se = _halo_strip(p_ref, nbr_ref[i, 7], 0, 1, 0, 1)            # (1,1,C)

    top = jnp.concatenate([nw, n_, ne], axis=1)          # (1, tw+2, C)
    mid = jnp.concatenate([w_, center, e_], axis=1)      # (th, tw+2, C)
    bot = jnp.concatenate([sw, s_, se], axis=1)          # (1, tw+2, C)
    win = jnp.concatenate([top, mid, bot], axis=0)       # (th+2, tw+2, C)

    o_ref[0] = _conv3x3_tile(win, w_ref, th, tw, cout).astype(o_ref.dtype)


def _roi_conv_fleet_kernel(idx_ref, x_ref, w_ref, o_ref, *, th: int,
                           tw: int):
    i = pl.program_id(0)
    cam = idx_ref[i, 0]
    ty = idx_ref[i, 1]
    tx = idx_ref[i, 2]
    cout = o_ref.shape[-1]
    # haloed window from camera ``cam``'s padded (H+2, W+2, Cin) plane of
    # the stacked fleet tensor — cameras are separate leading-dim entries,
    # so a window can never read another camera's pixels
    win = pl.load(x_ref, (pl.ds(cam, 1), pl.ds(ty * th, th + 2),
                          pl.ds(tx * tw, tw + 2), slice(None)))[0]
    o_ref[0] = _conv3x3_tile(win, w_ref, th, tw, cout).astype(o_ref.dtype)


def roi_conv_fleet(x: jax.Array, w: jax.Array, idx: jax.Array, th: int,
                   tw: int, *, interpret: bool = True) -> jax.Array:
    """Cross-camera fused gather+conv: ONE launch for a whole camera group.

    x: (C, H, W, Cin) stacked (zero-padded to common H, W) camera frames;
    w: (3, 3, Cin, Cout); idx: (n, 3) int32 (cam, ty, tx) active-tile coords
    over ALL cameras.  Returns packed (n, th, tw, Cout) in idx order — the
    same packed tensor ``roi_conv`` would produce per camera, concatenated.
    Per-camera zero padding reproduces each camera's own SAME-conv frame
    boundary, so the output is bit-compatible with per-camera launches."""
    C, H, W, Cin = x.shape
    Cout = w.shape[-1]
    n = idx.shape[0]
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    kernel = functools.partial(_roi_conv_fleet_kernel, th=th, tw=tw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
            pl.BlockSpec((3, 3, Cin, Cout),
                         lambda i, idx_ref: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, th, tw, Cout),
                               lambda i, idx_ref: (i, 0, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, th, tw, Cout), x.dtype),
        interpret=interpret,
    )(idx, xp, w)


def roi_conv_packed(packed: jax.Array, w: jax.Array, nbr: jax.Array,
                    *, interpret: bool = True) -> jax.Array:
    """packed: (n, th, tw, Cin) previous layer's packed output;
    w: (3, 3, Cin, Cout); nbr: (n, 8) int32 neighbor slots (-1 = zero halo,
    NEIGHBOR_OFFSETS order).  Returns packed (n, th, tw, Cout) — the SAME
    conv each active tile would see on the scattered full frame where
    inactive tiles are zero."""
    n, th, tw, Cin = packed.shape
    Cout = w.shape[-1]
    kernel = functools.partial(_roi_conv_packed_kernel, th=th, tw=tw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            # packed tensor stays in ANY/HBM; the kernel pulls its own tile
            # plus 1-deep neighbor edge strips (the halo DMAs are tiny
            # compared to re-slicing a full frame per layer)
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
            pl.BlockSpec((3, 3, Cin, Cout), lambda i, nbr_ref: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, th, tw, Cout),
                               lambda i, nbr_ref: (i, 0, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, th, tw, Cout), packed.dtype),
        interpret=interpret,
    )(nbr, packed, w)
