"""RoI-sparse 3x3 convolution as Pallas TPU kernels.

Two kernels implement the RoI-YOLO layer (paper §4.4):

``roi_conv`` — the *entry* layer: convolution evaluated only on active
tiles, reading straight from the full frame.  grid=(n_active,); per step
the kernel DMAs one *haloed* (th+2, tw+2, Cin) window from the padded
feature map in HBM (dynamic-start, static-size slice — a block DMA on
Mosaic), then computes the 3x3 conv as 9 shifted (th*tw, Cin) @ (Cin, Cout)
matmuls on the MXU.  This fuses SBNet's gather into the first conv.

``roi_conv_packed`` — every *subsequent* layer: consumes the previous
layer's packed (n, th, tw, C) output directly, so the sparse representation
never round-trips through a full-frame scatter between layers.  Halo rows/
columns come from neighbor tiles via an offline-computed (n, 8) neighbor
table (scalar-prefetched into SMEM): entry j holds the packed slot of the
j-th neighbor (NW, N, NE, W, E, SW, S, SE order) or -1 when that neighbor
is inactive/off-frame, in which case the halo strip is zero — exactly the
value the old scatter-into-zeros path produced, so the packed chain is
bit-compatible with the scatter/gather chain on every tile.

Keep th*tw and channel dims multiples of 128 for full MXU utilization;
both kernels are functional for any size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.blocking import balanced_split, pad_repeat_last

# pltpu.TPUMemorySpace was renamed MemorySpace across jax versions
_MEMSPACE = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace

# neighbor-table column order: (dy, dx) offsets of the 8 surrounding tiles
NEIGHBOR_OFFSETS = ((-1, -1), (-1, 0), (-1, 1), (0, -1),
                    (0, 1), (1, -1), (1, 0), (1, 1))


def _conv3x3_tile(win: jax.Array, w_ref, th: int, tw: int,
                  cout: int) -> jax.Array:
    """(th+2, tw+2, Cin) haloed window -> (th, tw, Cout) via 9 MXU matmuls."""
    cin = win.shape[-1]
    acc = jnp.zeros((th * tw, cout), jnp.float32)
    for dy in range(3):
        for dx in range(3):
            patch = win[dy:dy + th, dx:dx + tw, :].reshape(th * tw, cin)
            acc += patch.astype(jnp.float32) @ w_ref[dy, dx].astype(
                jnp.float32)
    return acc.reshape(th, tw, cout)


def _roi_conv_kernel(idx_ref, x_ref, w_ref, o_ref, *, th: int, tw: int):
    i = pl.program_id(0)
    ty = idx_ref[i, 0]
    tx = idx_ref[i, 1]
    cout = o_ref.shape[-1]
    # haloed window from the (H+2, W+2, Cin) padded map
    win = pl.load(x_ref, (pl.ds(ty * th, th + 2), pl.ds(tx * tw, tw + 2),
                          slice(None)))
    o_ref[0] = _conv3x3_tile(win, w_ref, th, tw, cout).astype(o_ref.dtype)


def roi_conv(x: jax.Array, w: jax.Array, idx: jax.Array, th: int, tw: int,
             *, interpret: bool = True) -> jax.Array:
    """x: (H, W, Cin); w: (3, 3, Cin, Cout); idx: (n, 2) int32 tile coords.
    Returns packed SAME-conv outputs on active tiles: (n, th, tw, Cout)."""
    H, W, Cin = x.shape
    Cout = w.shape[-1]
    n = idx.shape[0]
    xp = jnp.pad(x, ((1, 1), (1, 1), (0, 0)))
    kernel = functools.partial(_roi_conv_kernel, th=th, tw=tw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            # whole padded map stays in ANY/HBM; the kernel slices windows
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
            pl.BlockSpec((3, 3, Cin, Cout), lambda i, idx_ref: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, th, tw, Cout),
                               lambda i, idx_ref: (i, 0, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, th, tw, Cout), x.dtype),
        interpret=interpret,
    )(idx, xp, w)


# ---------------------------------------------------------------------------
# packed-resident conv: halo strips fetched from neighbor tiles
# ---------------------------------------------------------------------------

def _halo_strip(p_ref, slot, ys, ny, xs, nx):
    """Load packed[slot, ys:ys+ny, xs:xs+nx, :]; zero when slot == -1.

    The load is issued at the clamped slot (so it is always in-bounds) and
    masked afterwards — data-dependent *suppression*, not data-dependent
    control flow, which keeps the DMA schedule static.
    """
    safe = jnp.maximum(slot, 0)
    strip = pl.load(p_ref, (pl.ds(safe, 1), pl.ds(ys, ny), pl.ds(xs, nx),
                            slice(None)))[0]
    return jnp.where(slot >= 0, strip, jnp.zeros_like(strip))


def _roi_conv_packed_kernel(nbr_ref, p_ref, w_ref, o_ref, *,
                            th: int, tw: int):
    i = pl.program_id(0)
    cout = o_ref.shape[-1]
    z = jnp.asarray(0, jnp.int32)

    center = pl.load(p_ref, (pl.ds(i, 1), pl.ds(z, th), pl.ds(z, tw),
                             slice(None)))[0]                 # (th, tw, C)

    # 8 halo strips, indexed by the prefetched neighbor table.  Each strip
    # is the 1-deep edge of the neighbor facing us: the N neighbor donates
    # its bottom row, the W neighbor its rightmost column, corners one px.
    nw = _halo_strip(p_ref, nbr_ref[i, 0], th - 1, 1, tw - 1, 1)  # (1,1,C)
    n_ = _halo_strip(p_ref, nbr_ref[i, 1], th - 1, 1, 0, tw)      # (1,tw,C)
    ne = _halo_strip(p_ref, nbr_ref[i, 2], th - 1, 1, 0, 1)       # (1,1,C)
    w_ = _halo_strip(p_ref, nbr_ref[i, 3], 0, th, tw - 1, 1)      # (th,1,C)
    e_ = _halo_strip(p_ref, nbr_ref[i, 4], 0, th, 0, 1)           # (th,1,C)
    sw = _halo_strip(p_ref, nbr_ref[i, 5], 0, 1, tw - 1, 1)       # (1,1,C)
    s_ = _halo_strip(p_ref, nbr_ref[i, 6], 0, 1, 0, tw)           # (1,tw,C)
    se = _halo_strip(p_ref, nbr_ref[i, 7], 0, 1, 0, 1)            # (1,1,C)

    top = jnp.concatenate([nw, n_, ne], axis=1)          # (1, tw+2, C)
    mid = jnp.concatenate([w_, center, e_], axis=1)      # (th, tw+2, C)
    bot = jnp.concatenate([sw, s_, se], axis=1)          # (1, tw+2, C)
    win = jnp.concatenate([top, mid, bot], axis=0)       # (th+2, tw+2, C)

    o_ref[0] = _conv3x3_tile(win, w_ref, th, tw, cout).astype(o_ref.dtype)


def _roi_conv_fleet_kernel(idx_ref, x_ref, w_ref, o_ref, *, th: int,
                           tw: int, fuse_relu: bool = False):
    i = pl.program_id(0)
    cam = idx_ref[i, 0]
    ty = idx_ref[i, 1]
    tx = idx_ref[i, 2]
    cout = o_ref.shape[-1]
    # haloed window from camera ``cam``'s padded (H+2, W+2, Cin) plane of
    # the stacked fleet tensor — cameras are separate leading-dim entries,
    # so a window can never read another camera's pixels
    win = pl.load(x_ref, (pl.ds(cam, 1), pl.ds(ty * th, th + 2),
                          pl.ds(tx * tw, tw + 2), slice(None)))[0]
    o = _conv3x3_tile(win, w_ref, th, tw, cout)
    if fuse_relu:
        o = jnp.maximum(o, 0.0)
    o_ref[0] = o.astype(o_ref.dtype)


def roi_conv_fleet(x: jax.Array, w: jax.Array, idx: jax.Array, th: int,
                   tw: int, *, interpret: bool = True) -> jax.Array:
    """Cross-camera fused gather+conv: ONE launch for a whole camera group.

    x: (C, H, W, Cin) stacked (zero-padded to common H, W) camera frames;
    w: (3, 3, Cin, Cout); idx: (n, 3) int32 (cam, ty, tx) active-tile coords
    over ALL cameras.  Returns packed (n, th, tw, Cout) in idx order — the
    same packed tensor ``roi_conv`` would produce per camera, concatenated.
    Per-camera zero padding reproduces each camera's own SAME-conv frame
    boundary, so the output is bit-compatible with per-camera launches."""
    return _fleet_conv_call(x, w, idx, th, tw, fuse_relu=False,
                            interpret=interpret)


def _fleet_conv_call(x, w, idx, th, tw, *, fuse_relu, interpret):
    """Shared launch for the fleet gather+conv (``roi_conv_fleet``) and
    the fused backbone's entry layer (``roi_conv_entry`` = same kernel
    with the ReLU fused in)."""
    C, H, W, Cin = x.shape
    Cout = w.shape[-1]
    n = idx.shape[0]
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    kernel = functools.partial(_roi_conv_fleet_kernel, th=th, tw=tw,
                               fuse_relu=fuse_relu)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
            pl.BlockSpec((3, 3, Cin, Cout),
                         lambda i, idx_ref: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, th, tw, Cout),
                               lambda i, idx_ref: (i, 0, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, th, tw, Cout), x.dtype),
        interpret=interpret,
    )(idx, xp, w)


# ---------------------------------------------------------------------------
# coalesced rim halos + the fused layer-stack megakernel
# ---------------------------------------------------------------------------
#
# ``roi_conv_packed`` fetches its halo as 8 masked strip/corner DMAs per
# tile per layer.  The fused path coalesces them: every layer *emits* the
# assembled halo strips — "rims" — its successor will read, so the next
# layer fetches the whole halo of a tile block in 4 contiguous loads:
#
#   rim_top[j]  (tw+2, C): the row above tile j  = [NW.br | N.bottom | NE.bl]
#   rim_bot[j]  (tw+2, C): the row below tile j  = [SW.tr | S.top    | SE.tl]
#   rim_left[j] (th,   C): the column left of j  =  W.rightmost column
#   rim_right[j](th,   C): the column right of j =  E.leftmost  column
#
# Emission is two-step so every store stays contiguous: a conv phase
# writes its block's own edge strips (top/bottom rows, left/right
# columns, producer-indexed), and an interleaved assembly phase gathers
# those edges donor-by-donor into the consumer-indexed rims above,
# zero-masking positions whose donor is inactive/off-frame (-1 in the
# neighbor table) — the same zero-halo contract as ``roi_conv_packed``.


def assemble_rims(packed: jax.Array, nbr: jax.Array):
    """Vectorized rim assembly (pure jnp — runs inside the stack launch,
    before the megakernel, to seed layer 0's rims from the entry layer's
    packed output).  Gathers each tile's halo strips from its donors'
    edges: returns (rim_top (n, tw+2, C), rim_bot (n, tw+2, C), rim_left
    (n, th, C), rim_right (n, th, C)); positions with no active donor are
    zero.  Row-for-row equal to ``ref.rims_of_packed``'s first n rows."""
    n, th, tw, c = packed.shape
    valid = nbr >= 0
    safe = jnp.clip(nbr, 0, max(n - 1, 0))

    def gat(edge, j):
        v = jnp.take(edge, safe[:, j], axis=0)
        return jnp.where(valid[:, j, None, None], v, jnp.zeros_like(v))

    be, te = packed[:, th - 1], packed[:, 0]              # (n, tw, C)
    le, re = packed[:, :, 0], packed[:, :, tw - 1]        # (n, th, C)
    # the row above tile j: [NW.bottom-right | N.bottom row | NE.bottom-left]
    rt = jnp.concatenate([gat(be, 0)[:, tw - 1:tw], gat(be, 1),
                          gat(be, 2)[:, 0:1]], axis=1)
    # the row below: [SW.top-right | S.top row | SE.top-left]
    rb = jnp.concatenate([gat(te, 5)[:, tw - 1:tw], gat(te, 6),
                          gat(te, 7)[:, 0:1]], axis=1)
    rl = gat(re, 3)                                       # W.rightmost col
    rr = gat(le, 4)                                       # E.leftmost col
    return rt, rb, rl, rr


def _roi_conv_entry_block_kernel(idx_ref, x_ref, w_ref, o_ref, *, th: int,
                                 tw: int, tb: int):
    """Blocked entry walk: one grid step gathers ``tb`` haloed windows
    (each a dynamic-start static-size block DMA off the stacked frames)
    and convolves them as ONE (tb*th*tw, Cin) GEMM per tap.  Output rows
    are independent dot products, so every tile's values are bitwise
    identical to the per-tile walk (``_roi_conv_fleet_kernel``)."""
    b = pl.program_id(0)
    cout = o_ref.shape[-1]
    wins = []
    for j in range(tb):
        cam = idx_ref[b * tb + j, 0]
        ty = idx_ref[b * tb + j, 1]
        tx = idx_ref[b * tb + j, 2]
        wins.append(pl.load(
            x_ref, (pl.ds(cam, 1), pl.ds(ty * th, th + 2),
                    pl.ds(tx * tw, tw + 2), slice(None)))[0])
    win = jnp.stack(wins)                       # (tb, th+2, tw+2, cin)
    cin = win.shape[-1]
    acc = jnp.zeros((tb * th * tw, cout), jnp.float32)
    for dy in range(3):
        for dx in range(3):
            patch = win[:, dy:dy + th, dx:dx + tw, :].reshape(
                tb * th * tw, cin)
            acc += patch.astype(jnp.float32) @ w_ref[dy, dx].astype(
                jnp.float32)
    o = jnp.maximum(acc, 0.0).reshape(tb, th, tw, cout)
    o_ref[...] = o.astype(o_ref.dtype)


def roi_conv_entry(x: jax.Array, w: jax.Array, idx: jax.Array, th: int,
                   tw: int, *, block: int = 1,
                   interpret: bool = True) -> jax.Array:
    """The fused backbone's entry layer: gather + 3x3 conv + ReLU in ONE
    launch for any number of cameras (and camera groups — the (n, 3)
    (flat_cam, ty, tx) index space is oblivious to how cameras are
    grouped).  x: (C, H, W, Cin) stacked frames; w: (3, 3, Cin, Cout);
    idx: (n, 3).  Returns relu'd packed (n, th, tw, Cout) — relu is
    idempotent, so callers may re-apply it bit-identically.  The packed
    output feeds ``roi_conv_stack`` for every remaining layer.

    ``block`` > 1 blocks the tile walk like the stack kernel: grid =
    (tile_block,), each step gathering ``block`` haloed windows and
    running (block*th*tw, Cin) GEMMs — fewer grid steps and larger
    coalesced gather DMAs, bit-identical to the per-tile walk (size it
    with ``ops.choose_block``).  The index list is padded up with
    repeats of its last row; the duplicate rows' outputs land past ``n``
    and are sliced off.

    An EMPTY tile set short-circuits to a zero-row packed tensor with no
    pallas_call at all — the per-tile walk used to form a grid=(0,)
    launch (and the blocked walk a padded >= 1-block launch) here."""
    n = idx.shape[0]
    if n == 0:
        return jnp.zeros((0, th, tw, w.shape[-1]), x.dtype)
    if block <= 1:
        return _fleet_conv_call(x, w, idx, th, tw, fuse_relu=True,
                                interpret=interpret)
    C, H, W, Cin = x.shape
    Cout = w.shape[-1]
    nb, tb, n_pad = balanced_split(n, block)
    idx_p = pad_repeat_last(idx, n_pad)
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    kernel = functools.partial(_roi_conv_entry_block_kernel, th=th, tw=tw,
                               tb=tb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_pad // tb,),
        in_specs=[
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
            pl.BlockSpec((3, 3, Cin, Cout),
                         lambda b, idx_ref: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, th, tw, Cout),
                               lambda b, idx_ref: (b, 0, 0, 0)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad, th, tw, Cout), x.dtype),
        interpret=interpret,
    )(idx_p, xp, w)
    return out[:n]


def _roi_conv_stack_kernel(nbr_ref, p0_ref, rt0, rb0, rl0, rr0, w_ref,
                           o_ref, act_ref, te_ref, be_ref, le_ref, re_ref,
                           rt_ref, rb_ref, rl_ref, rr_ref, *, th: int,
                           tw: int, chans, tb: int, n_pad: int):
    p = pl.program_id(0)
    b = pl.program_id(1)
    L = len(chans) - 1
    sel = (pl.ds(b * tb, tb),)
    nbrs = pl.load(nbr_ref, sel + (slice(None),))          # (tb, 8)
    valid = nbrs >= 0
    safe = jnp.clip(nbrs, 0, n_pad - 1)

    def conv_phase(lc: int):
        cin, cout = chans[lc], chans[lc + 1]
        cs = slice(0, cin)
        if lc == 0:
            center = p0_ref[...]               # (tb, th, tw, c0) block
            srcs = (rt0, rb0, rl0, rr0)
        else:
            center = pl.load(act_ref, sel + (slice(None), slice(None),
                                             cs))
            srcs = (rt_ref, rb_ref, rl_ref, rr_ref)
        # the whole block halo in 4 contiguous loads — the rims were
        # assembled (donor-gathered, zero-masked) by the previous phase,
        # vs 8 masked strip/corner DMAs per tile in roi_conv_packed
        top = pl.load(srcs[0], sel + (slice(None), cs))    # (tb, tw+2, ·)
        bot = pl.load(srcs[1], sel + (slice(None), cs))
        left = pl.load(srcs[2], sel + (slice(None), cs))   # (tb, th, ·)
        right = pl.load(srcs[3], sel + (slice(None), cs))
        mid = jnp.concatenate([left[:, :, None], center,
                               right[:, :, None]], axis=2)
        win = jnp.concatenate([top[:, None], mid, bot[:, None]],
                              axis=1)          # (tb, th+2, tw+2, cin)
        # w_ref's block is layer lc's (prefetched) weight plane; the
        # static slice recovers the layer's true channel widths.  The
        # block flattens into the GEMM M dimension — one
        # (tb*th*tw, cin) @ (cin, cout) per tap; output rows are
        # independent dot products, so each tile's values are bitwise
        # identical to ``roi_conv_packed``'s per-tile matmuls
        w = w_ref[0][:, :, :cin, :cout]
        acc = jnp.zeros((tb * th * tw, cout), jnp.float32)
        for dy in range(3):
            for dx in range(3):
                patch = win[:, dy:dy + th, dx:dx + tw, :].reshape(
                    tb * th * tw, cin)
                acc += patch.astype(jnp.float32) @ w[dy, dx].astype(
                    jnp.float32)
        o = jnp.maximum(acc, 0.0).reshape(tb, th, tw, cout).astype(
            p0_ref.dtype)
        if lc == L - 1:
            pl.store(o_ref, sel + (slice(None), slice(None),
                                   slice(None)), o)
        else:
            co = slice(0, cout)
            pl.store(act_ref, sel + (slice(None), slice(None), co), o)
            # emit this block's edge strips (contiguous stores) for the
            # interleaved rim-assembly phase
            pl.store(te_ref, sel + (slice(None), co), o[:, 0])
            pl.store(be_ref, sel + (slice(None), co), o[:, th - 1])
            pl.store(le_ref, sel + (slice(None), co), o[:, :, 0])
            pl.store(re_ref, sel + (slice(None), co), o[:, :, tw - 1])

    def assemble_phase(lc: int):
        # gather the block's rims for layer lc+1 from layer lc's edges
        # (the write side of the coalesced-halo scheme: donor gather +
        # zero masking happens ONCE here, so the conv phase reads clean
        # assembled strips)
        co = slice(0, chans[lc + 1])
        te = pl.load(te_ref, (slice(None), slice(None), co))
        be = pl.load(be_ref, (slice(None), slice(None), co))
        le = pl.load(le_ref, (slice(None), slice(None), co))
        re = pl.load(re_ref, (slice(None), slice(None), co))

        def gat(edge, j):
            v = jnp.take(edge, safe[:, j], axis=0)
            return jnp.where(valid[:, j, None, None], v,
                             jnp.zeros_like(v))

        rt = jnp.concatenate([gat(be, 0)[:, tw - 1:tw], gat(be, 1),
                              gat(be, 2)[:, 0:1]], axis=1)
        rb = jnp.concatenate([gat(te, 5)[:, tw - 1:tw], gat(te, 6),
                              gat(te, 7)[:, 0:1]], axis=1)
        pl.store(rt_ref, sel + (slice(None), co), rt)
        pl.store(rb_ref, sel + (slice(None), co), rb)
        pl.store(rl_ref, sel + (slice(None), co), gat(re, 3))
        pl.store(rr_ref, sel + (slice(None), co), gat(le, 4))

    # phase sequence: conv 0, assemble 0, conv 1, assemble 1, ..., conv L-1
    for pc in range(2 * L - 1):
        @pl.when(p == pc)
        def _(pc=pc):
            if pc % 2 == 0:
                conv_phase(pc // 2)
            else:
                assemble_phase(pc // 2)


def roi_conv_stack(packed: jax.Array, ws, nbr: jax.Array, *,
                   block: int = 128, interpret: bool = True) -> jax.Array:
    """The fused layer-stack megakernel: the ENTIRE packed conv chain
    (3x3 conv + ReLU per layer) in ONE ``pallas_call`` with grid =
    (phase, tile_block), replacing N-1 ``roi_conv_packed`` dispatches.

    packed: (n, th, tw, C0) the entry layer's (relu'd) packed output;
    ws: list of (3, 3, C_l, C_{l+1}) weights; nbr: (n, 8) neighbor table
    (``neighbor_table`` / ``fleet_neighbor_table``).  Returns the last
    layer's packed (n, th, tw, C_last), bit-identical to the per-layer
    ``relu(roi_conv_packed(...))`` chain:

    * the phase axis is OUTER and alternates conv / rim-assembly, so
      every tile of layer l (and its rim assembly) completes before
      layer l+1 starts — activations, edge strips and assembled rims
      persist across grid steps in ANY-space buffers;
    * each conv layer emits its block's edge strips (top/bottom (n, tw, C)
      rows, left/right (n, th, C) columns) with contiguous stores; the
      interleaved assembly phase gathers them into per-tile halo rims
      (top/bottom (n, tw+2, C), left/right (n, th, C), inactive donors
      zero-masked), which the NEXT layer fetches in 4 contiguous loads
      per tile block instead of 8 masked strip/corner DMAs per tile;
    * weights are stacked (L, 3, 3, Cmax_in, Cmax_out) and block-indexed
      by the phase's layer id, so Pallas's pipeline machinery prefetches
      layer l+1's weights while layer l computes;
    * ``block`` tiles are processed per grid step (padded up with inert
      -1-neighbor tiles), so the matmuls are (block*th*tw, C) MXU shapes.
    """
    n, th, tw, c0 = packed.shape
    chans = (c0,) + tuple(w.shape[-1] for w in ws)
    L = len(ws)
    if n == 0:
        return jnp.zeros((0, th, tw, chans[-1]), packed.dtype)
    tb = max(1, min(block, n))
    n_pad = -(-n // tb) * tb
    cmax_i = max(chans[:-1])
    cmax_o = max(chans[1:])
    wstack = jnp.stack([
        jnp.pad(w, ((0, 0), (0, 0), (0, cmax_i - w.shape[2]),
                    (0, cmax_o - w.shape[3]))) for w in ws])
    packed_p = jnp.pad(packed, ((0, n_pad - n), (0, 0), (0, 0), (0, 0)))
    nbr_p = jnp.pad(nbr, ((0, n_pad - n), (0, 0)), constant_values=-1)
    rims0 = assemble_rims(packed_p, nbr_p)
    # edge/rim/act buffers carry INTERMEDIATE layers only (the last
    # layer's output goes straight to o_ref; its rims are never built)
    c_mid = max(chans[1:-1]) if L > 1 else 1
    np_mid = n_pad if L > 1 else 1
    th_mid = th if L > 1 else 1
    tw_mid = tw if L > 1 else 1
    kernel = functools.partial(_roi_conv_stack_kernel, th=th, tw=tw,
                               chans=chans, tb=tb, n_pad=n_pad)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(2 * L - 1, n_pad // tb),
        in_specs=[
            pl.BlockSpec((tb, th, tw, c0),
                         lambda p, b, nbr_ref: (b, 0, 0, 0)),
        ] + [pl.BlockSpec(memory_space=_MEMSPACE.ANY)] * 4 + [
            pl.BlockSpec((1, 3, 3, cmax_i, cmax_o),
                         lambda p, b, nbr_ref: (p // 2, 0, 0, 0, 0)),
        ],
        out_specs=[pl.BlockSpec(memory_space=_MEMSPACE.ANY)] * 10,
    )
    dt = packed.dtype
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, th, tw, chans[-1]), dt),
            jax.ShapeDtypeStruct((np_mid, th_mid, tw_mid, c_mid), dt),
            jax.ShapeDtypeStruct((np_mid, tw_mid, c_mid), dt),  # top edge
            jax.ShapeDtypeStruct((np_mid, tw_mid, c_mid), dt),  # bottom
            jax.ShapeDtypeStruct((np_mid, th_mid, c_mid), dt),  # left
            jax.ShapeDtypeStruct((np_mid, th_mid, c_mid), dt),  # right
            jax.ShapeDtypeStruct((np_mid, tw_mid + 2, c_mid), dt),
            jax.ShapeDtypeStruct((np_mid, tw_mid + 2, c_mid), dt),
            jax.ShapeDtypeStruct((np_mid, th_mid, c_mid), dt),
            jax.ShapeDtypeStruct((np_mid, th_mid, c_mid), dt),
        ],
        interpret=interpret,
    )(nbr_p, packed_p, *rims0, wstack)
    return out[0][:n]


def roi_conv_packed(packed: jax.Array, w: jax.Array, nbr: jax.Array,
                    *, interpret: bool = True) -> jax.Array:
    """packed: (n, th, tw, Cin) previous layer's packed output;
    w: (3, 3, Cin, Cout); nbr: (n, 8) int32 neighbor slots (-1 = zero halo,
    NEIGHBOR_OFFSETS order).  Returns packed (n, th, tw, Cout) — the SAME
    conv each active tile would see on the scattered full frame where
    inactive tiles are zero."""
    n, th, tw, Cin = packed.shape
    Cout = w.shape[-1]
    kernel = functools.partial(_roi_conv_packed_kernel, th=th, tw=tw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            # packed tensor stays in ANY/HBM; the kernel pulls its own tile
            # plus 1-deep neighbor edge strips (the halo DMAs are tiny
            # compared to re-slicing a full frame per layer)
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
            pl.BlockSpec((3, 3, Cin, Cout), lambda i, nbr_ref: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, th, tw, Cout),
                               lambda i, nbr_ref: (i, 0, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, th, tw, Cout), packed.dtype),
        interpret=interpret,
    )(nbr, packed, w)
