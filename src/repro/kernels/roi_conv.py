"""RoI-sparse 3x3 convolution as a Pallas TPU kernel.

The RoI-YOLO layer (paper §4.4): convolution evaluated only on active tiles.
TPU formulation: grid=(n_active,); per step the kernel DMAs one *haloed*
(th+2, tw+2, Cin) window from the padded feature map in HBM (dynamic-start,
static-size slice — a block DMA on Mosaic), then computes the 3x3 conv as 9
shifted (th*tw, Cin) @ (Cin, Cout) matmuls on the MXU.  This replaces
SBNet's gather -> cuDNN conv -> scatter trio with one fused kernel and
keeps matmul operands MXU-aligned (pick th*tw and channel dims as multiples
of 128 for full utilization; functional for any size).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _roi_conv_kernel(idx_ref, x_ref, w_ref, o_ref, *, th: int, tw: int):
    i = pl.program_id(0)
    ty = idx_ref[i, 0]
    tx = idx_ref[i, 1]
    cin = x_ref.shape[-1]
    cout = o_ref.shape[-1]
    # haloed window from the (H+2, W+2, Cin) padded map
    win = pl.load(x_ref, (pl.ds(ty * th, th + 2), pl.ds(tx * tw, tw + 2),
                          slice(None)))
    acc = jnp.zeros((th * tw, cout), jnp.float32)
    for dy in range(3):
        for dx in range(3):
            patch = win[dy:dy + th, dx:dx + tw, :].reshape(th * tw, cin)
            acc += patch.astype(jnp.float32) @ w_ref[dy, dx].astype(
                jnp.float32)
    o_ref[0] = acc.reshape(th, tw, cout).astype(o_ref.dtype)


def roi_conv(x: jax.Array, w: jax.Array, idx: jax.Array, th: int, tw: int,
             *, interpret: bool = True) -> jax.Array:
    """x: (H, W, Cin); w: (3, 3, Cin, Cout); idx: (n, 2) int32 tile coords.
    Returns packed SAME-conv outputs on active tiles: (n, th, tw, Cout)."""
    H, W, Cin = x.shape
    Cout = w.shape[-1]
    n = idx.shape[0]
    xp = jnp.pad(x, ((1, 1), (1, 1), (0, 0)))
    import functools
    kernel = functools.partial(_roi_conv_kernel, th=th, tw=tw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            # whole padded map stays in ANY/HBM; the kernel slices windows
            pl.BlockSpec(memory_space=pltpu.MemorySpace.ANY),
            pl.BlockSpec((3, 3, Cin, Cout), lambda i, idx_ref: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, th, tw, Cout),
                               lambda i, idx_ref: (i, 0, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, th, tw, Cout), x.dtype),
        interpret=interpret,
    )(idx, xp, w)
