"""RoI-packed prefill attention as a Pallas TPU kernel.

The CrossRoI technique lifted to transformer serving (DESIGN.md §2): the
offline set-cover mask maps to a token keep-list; kept tokens are packed
into a dense prefix and prefilled in one pass.  Causality must follow the
tokens' *original* positions, so the kernel carries a positions vector and
masks with pos_q >= pos_k instead of the block-triangular structure.

Flash-attention structure: grid = (heads, q_blocks); the q block lives in
VMEM via BlockSpec; K/V stay in ANY/HBM and the kernel walks k-blocks with
dynamic-slice loads, maintaining the online-softmax running max/denominator.
Padding rows carry position INT32_MAX (never attended, never attending).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30
PAD_POS = jnp.iinfo(jnp.int32).max


def _roi_attn_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *,
                     block_k: int, scale: float):
    qi = pl.program_id(1)
    bq, D = q_ref.shape[1], q_ref.shape[2]
    S = k_ref.shape[1]
    q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
    pos_q = pos_ref[pl.ds(qi * bq, bq)]               # (bq,)

    nk = S // block_k

    def body(j, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (0, pl.ds(j * block_k, block_k), slice(None))
                    ).astype(jnp.float32)             # (bk, D)
        v = pl.load(v_ref, (0, pl.ds(j * block_k, block_k), slice(None))
                    ).astype(jnp.float32)
        pos_k = pos_ref[pl.ds(j * block_k, block_k)]
        s = q @ k.T                                   # (bq, bk)
        mask = pos_q[:, None] >= pos_k[None, :]
        s = jnp.where(mask, s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p @ v
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((bq, D), jnp.float32)
    m0 = jnp.full((bq,), _NEG, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nk, body, (acc0, m0, l0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


def roi_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  positions: jax.Array, *, block_q: int = 128,
                  block_k: int = 128, scale: float | None = None,
                  interpret: bool = True) -> jax.Array:
    """q,k,v: (S, H, D) packed tokens; positions: (S,) int32 original
    positions (padding = PAD_POS).  S must divide by block_q and block_k
    (ops.roi_attention pads).  Returns (S, H, D)."""
    S, H, D = q.shape
    assert S % block_q == 0 and S % block_k == 0
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    kernel = functools.partial(_roi_attn_kernel, block_k=block_k, scale=scale)
    # layout: (H, S, D) so heads are the leading grid axis
    qh = jnp.swapaxes(q, 0, 1)
    kh = jnp.swapaxes(k, 0, 1)
    vh = jnp.swapaxes(v, 0, 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(H, S // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, i, pos: (h, i, 0)),
            pl.BlockSpec((1, S, D), lambda h, i, pos: (h, 0, 0)),
            pl.BlockSpec((1, S, D), lambda h, i, pos: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda h, i, pos: (h, i, 0)),
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((H, S, D), q.dtype),
        interpret=interpret,
    )(positions, qh, kh, vh)
    return jnp.swapaxes(out, 0, 1)
