"""RoI-packed prefill attention as a Pallas TPU kernel.

The CrossRoI technique lifted to transformer serving (DESIGN.md §2): the
offline set-cover mask maps to a token keep-list; kept tokens are packed
into a dense prefix and prefilled in one pass.  Causality must follow the
tokens' *original* positions, so the kernel carries a positions vector and
masks with pos_q >= pos_k instead of the block-triangular structure.

Flash-attention structure: grid = (heads, q_blocks); the q block lives in
VMEM via BlockSpec; K/V stay in ANY/HBM and the kernel walks k-blocks with
dynamic-slice loads, maintaining the online-softmax running max/denominator.
Padding rows carry position INT32_MAX (never attended, never attending).

Causal block skipping: ``pack_tokens`` keeps kept rows in original order,
so positions are monotone over real rows with PAD_POS padding at the tail.
A scalar-prefetched per-k-block minimum-position vector bounds the k-loop
at the *last* k-block whose min position can be <= the q-block's max real
position — the standard flash-attention causal bound, which also skips
all-padding tail blocks (their min is PAD_POS).  Skipped blocks are ones
the exhaustive kernel fully masks, and a fully-masked block is an exact
no-op in the online softmax once any real block has been folded in
(alpha = 1, p = exp(-inf) = 0), so outputs on real rows are bitwise equal
to the exhaustive kernel.  The kernel also emits a per-(head, q-block)
visited-block count so the skip ratio is observable in tests/benchmarks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30
PAD_POS = jnp.iinfo(jnp.int32).max


def _roi_attn_kernel(pos_ref, kmin_ref, q_ref, k_ref, v_ref, o_ref, cnt_ref,
                     *, block_k: int, scale: float, causal_skip: bool):
    qi = pl.program_id(1)
    bq, D = q_ref.shape[1], q_ref.shape[2]
    S = k_ref.shape[1]
    q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
    pos_q = pos_ref[pl.ds(qi * bq, bq)]               # (bq,)

    nk = S // block_k

    if causal_skip:
        # visit k-blocks [0, hi): hi = 1 + last j with min(pos_k_j) <=
        # max(real pos_q).  Correct for any positions vector; for the
        # monotone packed layout it is exactly the causal prefix.  A
        # q-block of pure padding has no real rows -> hi = 0.
        real_q = pos_q != PAD_POS
        pos_q_max = jnp.max(jnp.where(real_q, pos_q, -1))

        def scan_last(j, h):
            return jnp.where(kmin_ref[j] <= pos_q_max, j + 1, h)
        hi = jax.lax.fori_loop(0, nk, scan_last, 0)
    else:
        hi = nk

    def body(j, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (pl.ds(0, 1), pl.ds(j * block_k, block_k),
                            slice(None)))[0].astype(jnp.float32)  # (bk, D)
        v = pl.load(v_ref, (pl.ds(0, 1), pl.ds(j * block_k, block_k),
                            slice(None)))[0].astype(jnp.float32)
        pos_k = pos_ref[pl.ds(j * block_k, block_k)]
        s = q @ k.T                                   # (bq, bk)
        mask = pos_q[:, None] >= pos_k[None, :]
        s = jnp.where(mask, s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p @ v
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((bq, D), jnp.float32)
    m0 = jnp.full((bq,), _NEG, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, hi, body, (acc0, m0, l0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)
    cnt_ref[0, 0] = jnp.asarray(hi, jnp.int32)


def block_min_positions(positions: jax.Array, block_k: int) -> jax.Array:
    """Per-k-block minimum original position, (S // block_k,) int32.

    Computed once per prefill on the host side of the kernel (the packed
    layout makes it positions[::block_k], but the segment-min form stays
    correct for arbitrary position vectors)."""
    S = positions.shape[0]
    return positions.reshape(S // block_k, block_k).min(axis=1)


def roi_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  positions: jax.Array, *, block_q: int = 128,
                  block_k: int = 128, scale: float | None = None,
                  causal_skip: bool = True, interpret: bool = True,
                  return_stats: bool = False):
    """q,k,v: (S, H, D) packed tokens; positions: (S,) int32 original
    positions (padding = PAD_POS).  S must divide by block_q and block_k
    (ops.roi_attention pads).  Returns (S, H, D), or
    ((S, H, D), visited (H, S // block_q) int32) with ``return_stats``."""
    S, H, D = q.shape
    assert S % block_q == 0 and S % block_k == 0
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    kmin = block_min_positions(positions, block_k)
    kernel = functools.partial(_roi_attn_kernel, block_k=block_k, scale=scale,
                               causal_skip=causal_skip)
    # layout: (H, S, D) so heads are the leading grid axis
    qh = jnp.swapaxes(q, 0, 1)
    kh = jnp.swapaxes(k, 0, 1)
    vh = jnp.swapaxes(v, 0, 1)
    nq = S // block_q
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(H, nq),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, i, pos, kmin: (h, i, 0)),
            pl.BlockSpec((1, S, D), lambda h, i, pos, kmin: (h, 0, 0)),
            pl.BlockSpec((1, S, D), lambda h, i, pos, kmin: (h, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, block_q, D), lambda h, i, pos, kmin: (h, i, 0)),
            pl.BlockSpec((1, 1), lambda h, i, pos, kmin: (h, i)),
        ),
    )

    out, visited = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((H, S, D), q.dtype),
                   jax.ShapeDtypeStruct((H, nq), jnp.int32)),
        interpret=interpret,
    )(positions, kmin, qh, kh, vh)
    out = jnp.swapaxes(out, 0, 1)
    if return_stats:
        return out, visited
    return out
