"""SBNet gather/scatter as Pallas TPU kernels (paper §4.4, TPU-adapted).

The paper's SBNet is a CUDA kernel: per-thread gather of active tile pixels
into a packed tensor, dense conv, then scatter back.  The TPU-native
formulation (DESIGN.md §2): the active-tile index list is *scalar-prefetched*
into SMEM and drives the BlockSpec index_map, so each grid step DMAs one
whole (th, tw, C) tile HBM->VMEM.  DMA granularity == tile granularity: no
per-element addressing (a VPU anti-pattern), and the packed output feeds the
MXU dense.

Both kernels are grid=(n_active,) with data-dependent block indexing — the
Pallas analogue of SBNet's tile-gather warp loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.blocking import balanced_split, pad_repeat_last

# pltpu.TPUMemorySpace was renamed MemorySpace across jax versions
_MEMSPACE = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace


def _gather_kernel(idx_ref, x_ref, o_ref):
    # x_ref block = the (th, tw, C) tile selected by idx_ref[i]; copy to
    # packed slot i.  The DMA is issued by the BlockSpec machinery.
    o_ref[0] = x_ref[...]


def sbnet_gather(x: jax.Array, idx: jax.Array, th: int, tw: int,
                 *, interpret: bool = True) -> jax.Array:
    """x: (H, W, C), idx: (n, 2) int32 tile coords -> packed (n, th, tw, C)."""
    H, W, C = x.shape
    n = idx.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((th, tw, C),
                         lambda i, idx_ref: (idx_ref[i, 0], idx_ref[i, 1], 0)),
        ],
        out_specs=pl.BlockSpec((1, th, tw, C),
                               lambda i, idx_ref: (i, 0, 0, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, th, tw, C), x.dtype),
        interpret=interpret,
    )(idx, x)


def sbnet_scatter(packed: jax.Array, idx: jax.Array, base: jax.Array,
                  *, interpret: bool = True) -> jax.Array:
    """packed: (n, th, tw, C) -> write tiles into ``base`` (H, W, C) at the
    tile positions in ``idx``; untouched regions keep base values (the
    output aliases ``base``)."""
    n, th, tw, C = packed.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, th, tw, C), lambda i, idx_ref: (i, 0, 0, 0)),
            # the base is only here to seed the aliased output; ANY keeps
            # the pipeline from DMAing the whole frame on every grid step
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
        ],
        out_specs=pl.BlockSpec((th, tw, C),
                               lambda i, idx_ref: (idx_ref[i, 0],
                                                   idx_ref[i, 1], 0)),
    )

    def kernel(idx_ref, p_ref, b_ref, o_ref):
        o_ref[...] = p_ref[0]

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(base.shape, base.dtype),
        input_output_aliases={2: 0},   # args: (idx, packed, base) -> out
        interpret=interpret,
    )(idx, packed, base)


def _scatter_fleet_block_kernel(idx_ref, p_ref, b_ref, o_ref, *, th: int,
                                tw: int, tb: int):
    """Blocked scatter walk: one grid step receives a whole (tb, th, tw,
    C) packed block as ONE contiguous DMA (the read-side analogue of the
    stack kernel's contiguous-store rim scheme) and fans it out with
    ``tb`` per-tile dynamic stores.  Padding rows repeat the last real
    (idx, tile) pair, so their stores rewrite identical bytes — no trash
    plane, no masked stores."""
    b = pl.program_id(0)
    blk = p_ref[...]                             # (tb, th, tw, C)
    for j in range(tb):
        cam = idx_ref[b * tb + j, 0]
        ty = idx_ref[b * tb + j, 1]
        tx = idx_ref[b * tb + j, 2]
        pl.store(o_ref, (pl.ds(cam, 1), pl.ds(ty * th, th),
                         pl.ds(tx * tw, tw), slice(None)),
                 blk[j][None])


def sbnet_scatter_fleet(packed: jax.Array, idx: jax.Array, base: jax.Array,
                        *, block: int = 1,
                        interpret: bool = True) -> jax.Array:
    """Cross-camera scatter: ONE launch materializes a whole camera group.

    packed: (n, th, tw, C); idx: (n, 3) int32 (cam, ty, tx); base:
    (num_cams, H, W, C) stacked frames.  Writes tile i into camera
    idx[i, 0]'s plane; untouched regions keep base values.

    ``block`` > 1 blocks the tile walk (grid = (tile_block,)): each step
    pulls ``block`` packed tiles in one contiguous load and issues their
    stores back-to-back — same per-tile write pattern, 1/block the grid
    steps.  Both index list and packed tensor are padded with repeats of
    their last row, so padding stores are idempotent rewrites of the last
    real tile (bit-identical to the per-tile walk by construction).

    An EMPTY tile set is a no-op: the base is returned untouched and no
    pallas_call is formed at all (the per-tile walk used to build a
    grid=(0,) launch here)."""
    n, th, tw, C = packed.shape
    if n == 0:
        return base
    if block > 1:
        nb, tb, n_pad = balanced_split(n, block)
        idx = pad_repeat_last(idx, n_pad)
        packed = pad_repeat_last(packed, n_pad)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_pad // tb,),
            in_specs=[
                pl.BlockSpec((tb, th, tw, C),
                             lambda b, idx_ref: (b, 0, 0, 0)),
                # aliased seed only — ANY avoids a whole-canvas DMA/step
                pl.BlockSpec(memory_space=_MEMSPACE.ANY),
            ],
            out_specs=pl.BlockSpec(memory_space=_MEMSPACE.ANY),
        )
        kernel = functools.partial(_scatter_fleet_block_kernel, th=th,
                                   tw=tw, tb=tb)
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct(base.shape, base.dtype),
            input_output_aliases={2: 0},   # (idx, packed, base) -> out
            interpret=interpret,
        )(idx, packed, base)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, th, tw, C), lambda i, idx_ref: (i, 0, 0, 0)),
            # aliased seed only — ANY avoids a whole-canvas DMA per step
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
        ],
        out_specs=pl.BlockSpec((1, th, tw, C),
                               lambda i, idx_ref: (idx_ref[i, 0],
                                                   idx_ref[i, 1],
                                                   idx_ref[i, 2], 0)),
    )

    def kernel(idx_ref, p_ref, b_ref, o_ref):
        o_ref[...] = p_ref[...]

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(base.shape, base.dtype),
        input_output_aliases={2: 0},   # args: (idx, packed, base) -> out
        interpret=interpret,
    )(idx, packed, base)


def sbnet_scatter_changed(packed: jax.Array, idx: jax.Array,
                          base: jax.Array, *, block: int = 1,
                          interpret: bool = True) -> jax.Array:
    """Changed-only scatter into a PERSISTENT canvas: O(changed) bytes.

    Same store machinery as ``sbnet_scatter_fleet`` (blocked walk,
    scalar-prefetched (cam, ty, tx) rows, aliased/donated base), but the
    contract is different: ``base`` is the PREVIOUS step's device-resident
    head-map canvas and ``packed``/``idx`` carry ONLY the tiles whose
    content changed this step.  Unchanged tiles pass through untouched —
    their canvas bytes were written by the step that last computed them —
    so the composite result is bit-identical to re-scattering the whole
    active set while writing ``n_changed`` tiles instead of ``n_active``.
    An empty changed set returns the canvas with zero launches (the
    all-static step writes 0 canvas bytes)."""
    return sbnet_scatter_fleet(packed, idx, base, block=block,
                               interpret=interpret)
