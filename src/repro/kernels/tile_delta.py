"""Per-tile temporal delta + quantized zero-run byte estimation (Pallas).

The edge rate controller (repro/net/encoder.py) needs to know, per RoI
tile, how many bytes the tile would cost to ship *this* frame — cheap,
static tiles are the ones whose quality can be shed under uplink backlog.
The estimator is the structural core of an inter-frame codec: quantize the
temporal delta, then price it as entropy-coded (nonzero coefficient,
zero-run) tokens.

One kernel, grid=(n_active,), scalar-prefetched tile index list exactly
like the sbnet gather: per grid step it DMAs the (th, tw, C) tile from the
current AND previous frame (both stay in ANY/HBM), computes

    q     = round((cur - prev) / qstep)            # int32 coefficients
    nnz   = #(q != 0)
    runs  = #(maximal zero runs)   per (th,) row of the (th, tw*C) layout
    bytes = ceil((nnz * coef_bits + runs * run_bits) / 8)

entirely in integer ops (bit-exact by construction against the numpy
reference in ``kernels/ref.py``), and writes one (8,) int32 stats row:
``[bytes, nnz, runs, sum|q|, 0, 0, 0, 0]`` (lane-padded).

Row-independent run counting (a zero run never joins across the th rows)
keeps the scan a pure shifted-compare on the VPU — no sequential carry —
and is the *definition* of the estimate, mirrored by the reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pltpu.TPUMemorySpace was renamed MemorySpace across jax versions
_MEMSPACE = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace

# entropy-coder token prices (bits): a nonzero coefficient token and a
# zero-run token.  Calibration constants, not tunables-per-call — keeping
# them static keeps the byte estimate an integer function of the tile.
COEF_BITS = 6
RUN_BITS = 10

STATS_WIDTH = 8          # output lane padding; cols 0..3 are live


def _tile_stats(cur: jax.Array, prev: jax.Array, qstep: float,
                coef_bits: int, run_bits: int) -> jax.Array:
    """(th, tw, C) pair -> (STATS_WIDTH,) int32 [bytes, nnz, runs, sum|q|]."""
    th = cur.shape[0]
    q = jnp.round((cur.astype(jnp.float32) - prev.astype(jnp.float32))
                  / qstep).astype(jnp.int32)
    z2 = (q == 0).reshape(th, -1)                   # (th, tw*C) scan rows
    nnz = jnp.sum((~z2).astype(jnp.int32))
    # a zero run starts where z is set and the previous lane (same row)
    # is not; the first lane of every row always starts a run if zero
    left = jnp.concatenate(
        [jnp.zeros((th, 1), bool), z2[:, :-1]], axis=1)
    runs = jnp.sum((z2 & ~left).astype(jnp.int32))
    sabs = jnp.sum(jnp.abs(q))
    nbytes = (nnz * coef_bits + runs * run_bits + 7) // 8
    out = jnp.zeros((STATS_WIDTH,), jnp.int32)
    return out.at[0].set(nbytes).at[1].set(nnz).at[2].set(runs) \
              .at[3].set(sabs)


def _tile_delta_kernel(idx_ref, cur_ref, prev_ref, o_ref, *, th: int,
                       tw: int, qstep: float, coef_bits: int,
                       run_bits: int):
    i = pl.program_id(0)
    ty = idx_ref[i, 0]
    tx = idx_ref[i, 1]
    sel = (pl.ds(ty * th, th), pl.ds(tx * tw, tw), slice(None))
    cur = pl.load(cur_ref, sel)
    prev = pl.load(prev_ref, sel)
    o_ref[0] = _tile_stats(cur, prev, qstep, coef_bits, run_bits)


def tile_delta(cur: jax.Array, prev: jax.Array, idx: jax.Array, th: int,
               tw: int, qstep: float = 8.0, coef_bits: int = COEF_BITS,
               run_bits: int = RUN_BITS, *,
               interpret: bool = True) -> jax.Array:
    """cur, prev: (H, W, C) frames; idx: (n, 2) int32 active-tile coords.
    Returns (n, STATS_WIDTH) int32 per-tile stats rows:
    ``[byte_estimate, nnz, zero_runs, sum_abs_q, 0...]``."""
    n = idx.shape[0]
    kernel = functools.partial(_tile_delta_kernel, th=th, tw=tw,
                               qstep=qstep, coef_bits=coef_bits,
                               run_bits=run_bits)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            # both frames stay in ANY/HBM; the kernel slices its own tile
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
        ],
        out_specs=pl.BlockSpec((1, STATS_WIDTH),
                               lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, STATS_WIDTH), jnp.int32),
        interpret=interpret,
    )(idx, cur, prev)


# ---------------------------------------------------------------------------
# halo-strip delta pricing (the boundary ring, not the tile body)
# ---------------------------------------------------------------------------

def _halo_strip_stats(cur, prev, qstep: float):
    """One strip pair -> (nnz, runs, sum|q|) with the strip as ONE scan
    row (a zero run never joins across strips)."""
    q = jnp.round((cur.astype(jnp.float32) - prev.astype(jnp.float32))
                  / qstep).astype(jnp.int32)
    z = (q == 0).reshape(1, -1)
    nnz = jnp.sum((~z).astype(jnp.int32))
    left = jnp.concatenate([jnp.zeros((1, 1), bool), z[:, :-1]], axis=1)
    runs = jnp.sum((z & ~left).astype(jnp.int32))
    return nnz, runs, jnp.sum(jnp.abs(q))


def _tile_delta_halo_kernel(idx_ref, cur_ref, prev_ref, o_ref, *, th: int,
                            tw: int, qstep: float, coef_bits: int,
                            run_bits: int):
    i = pl.program_id(0)
    y0 = idx_ref[i, 0] * th
    x0 = idx_ref[i, 1] * tw
    # the tile's edge ring as 4 strips: top row, bottom row, left column,
    # right column.  Corners sit in both a row and a column strip — that
    # duplication IS the halo cost of encoding rectangles independently.
    sels = [(pl.ds(y0, 1), pl.ds(x0, tw)),
            (pl.ds(y0 + th - 1, 1), pl.ds(x0, tw)),
            (pl.ds(y0, th), pl.ds(x0, 1)),
            (pl.ds(y0, th), pl.ds(x0 + tw - 1, 1))]
    nnz = runs = sabs = jnp.asarray(0, jnp.int32)
    for sel in sels:
        c = pl.load(cur_ref, sel + (slice(None),))
        p = pl.load(prev_ref, sel + (slice(None),))
        dn, dr, ds_ = _halo_strip_stats(c, p, qstep)
        nnz, runs, sabs = nnz + dn, runs + dr, sabs + ds_
    nbytes = (nnz * coef_bits + runs * run_bits + 7) // 8
    out = jnp.zeros((STATS_WIDTH,), jnp.int32)
    o_ref[0] = out.at[0].set(nbytes).at[1].set(nnz).at[2].set(runs) \
                  .at[3].set(sabs)


def tile_delta_halo(cur: jax.Array, prev: jax.Array, idx: jax.Array,
                    th: int, tw: int, qstep: float = 8.0,
                    coef_bits: int = COEF_BITS, run_bits: int = RUN_BITS,
                    *, interpret: bool = True) -> jax.Array:
    """Delta stats of each active tile's HALO RING (top/bottom rows +
    left/right columns, corners counted in both — the duplicated boundary
    pixels behind the codec model's ``k/sqrt(area)`` surcharge).  Same
    stats row layout as ``tile_delta``; bit-exact vs
    ``ref.tile_delta_halo``.  Lets the rate controller shed halo rows
    whose content is temporally static before touching whole tiles."""
    n = idx.shape[0]
    kernel = functools.partial(_tile_delta_halo_kernel, th=th, tw=tw,
                               qstep=qstep, coef_bits=coef_bits,
                               run_bits=run_bits)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
        ],
        out_specs=pl.BlockSpec((1, STATS_WIDTH),
                               lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, STATS_WIDTH), jnp.int32),
        interpret=interpret,
    )(idx, cur, prev)
