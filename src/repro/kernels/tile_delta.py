"""Per-tile temporal delta + quantized zero-run byte estimation (Pallas).

The edge rate controller (repro/net/encoder.py) needs to know, per RoI
tile, how many bytes the tile would cost to ship *this* frame — cheap,
static tiles are the ones whose quality can be shed under uplink backlog.
The estimator is the structural core of an inter-frame codec: quantize the
temporal delta, then price it as entropy-coded (nonzero coefficient,
zero-run) tokens.

One kernel, grid=(n_active,), scalar-prefetched tile index list exactly
like the sbnet gather: per grid step it DMAs the (th, tw, C) tile from the
current AND previous frame (both stay in ANY/HBM), computes

    q     = round((cur - prev) / qstep)            # int32 coefficients
    nnz   = #(q != 0)
    runs  = #(maximal zero runs)   per (th,) row of the (th, tw*C) layout
    bytes = ceil((nnz * coef_bits + runs * run_bits) / 8)

entirely in integer ops (bit-exact by construction against the numpy
reference in ``kernels/ref.py``), and writes one (8,) int32 stats row:
``[bytes, nnz, runs, sum|q|, 0, 0, 0, 0]`` (lane-padded).

Row-independent run counting (a zero run never joins across the th rows)
keeps the scan a pure shifted-compare on the VPU — no sequential carry —
and is the *definition* of the estimate, mirrored by the reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.blocking import balanced_split, pad_repeat_last

# pltpu.TPUMemorySpace was renamed MemorySpace across jax versions
_MEMSPACE = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace

# entropy-coder token prices (bits): a nonzero coefficient token and a
# zero-run token.  Calibration constants, not tunables-per-call — keeping
# them static keeps the byte estimate an integer function of the tile.
COEF_BITS = 6
RUN_BITS = 10

STATS_WIDTH = 8          # output lane padding; cols 0..3 are live

# ``tile_delta_gate`` stats-row columns.  Cols 0..3 are the BODY stats and
# match ``tile_delta`` / ``ref.tile_delta`` bit for bit (so the rate
# controller can threshold the shared dispatch exactly as before); cols
# 4..5 are the HALOED-WINDOW stats the temporal reuse gate thresholds.
GATE_BODY_BYTES = 0
GATE_BODY_NNZ = 1
GATE_BODY_RUNS = 2
GATE_BODY_SABS = 3
GATE_WIN_EXACT = 4       # exact count of (th+2, tw+2, C) positions that
#                          differ bitwise — the threshold-0 gate signal
GATE_WIN_BYTES = 5       # quantized zero-run byte estimate of the window


def _tile_stats(cur: jax.Array, prev: jax.Array, qstep: float,
                coef_bits: int, run_bits: int) -> jax.Array:
    """(th, tw, C) pair -> (STATS_WIDTH,) int32 [bytes, nnz, runs, sum|q|]."""
    th = cur.shape[0]
    q = jnp.round((cur.astype(jnp.float32) - prev.astype(jnp.float32))
                  / qstep).astype(jnp.int32)
    z2 = (q == 0).reshape(th, -1)                   # (th, tw*C) scan rows
    nnz = jnp.sum((~z2).astype(jnp.int32))
    # a zero run starts where z is set and the previous lane (same row)
    # is not; the first lane of every row always starts a run if zero
    left = jnp.concatenate(
        [jnp.zeros((th, 1), bool), z2[:, :-1]], axis=1)
    runs = jnp.sum((z2 & ~left).astype(jnp.int32))
    sabs = jnp.sum(jnp.abs(q))
    nbytes = (nnz * coef_bits + runs * run_bits + 7) // 8
    out = jnp.zeros((STATS_WIDTH,), jnp.int32)
    return out.at[0].set(nbytes).at[1].set(nnz).at[2].set(runs) \
              .at[3].set(sabs)


def _tile_delta_kernel(idx_ref, cur_ref, prev_ref, o_ref, *, th: int,
                       tw: int, qstep: float, coef_bits: int,
                       run_bits: int):
    i = pl.program_id(0)
    ty = idx_ref[i, 0]
    tx = idx_ref[i, 1]
    sel = (pl.ds(ty * th, th), pl.ds(tx * tw, tw), slice(None))
    cur = pl.load(cur_ref, sel)
    prev = pl.load(prev_ref, sel)
    o_ref[0] = _tile_stats(cur, prev, qstep, coef_bits, run_bits)


def tile_delta(cur: jax.Array, prev: jax.Array, idx: jax.Array, th: int,
               tw: int, qstep: float = 8.0, coef_bits: int = COEF_BITS,
               run_bits: int = RUN_BITS, *,
               interpret: bool = True) -> jax.Array:
    """cur, prev: (H, W, C) frames; idx: (n, 2) int32 active-tile coords.
    Returns (n, STATS_WIDTH) int32 per-tile stats rows:
    ``[byte_estimate, nnz, zero_runs, sum_abs_q, 0...]``."""
    n = idx.shape[0]
    kernel = functools.partial(_tile_delta_kernel, th=th, tw=tw,
                               qstep=qstep, coef_bits=coef_bits,
                               run_bits=run_bits)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            # both frames stay in ANY/HBM; the kernel slices its own tile
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
        ],
        out_specs=pl.BlockSpec((1, STATS_WIDTH),
                               lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, STATS_WIDTH), jnp.int32),
        interpret=interpret,
    )(idx, cur, prev)


# ---------------------------------------------------------------------------
# reuse-gate delta pricing (haloed input windows on the stacked fleet)
# ---------------------------------------------------------------------------
#
# The temporal reuse gate (serving/detector.fleet_forward_reuse) must know
# whether a tile's ENTRY-LAYER INPUT changed — that is the (th+2, tw+2)
# haloed window the fused gather+conv reads, not just the (th, tw) body:
# a pixel flip in an *inactive* neighbor tile changes an active tile's
# conv output through the 1-px halo, and only the window view sees it.
# One kernel prices both views per tile so the rate controller (body
# stats, cols 0..3, bit-compatible with ``tile_delta``) and the reuse
# gate (window stats, cols 4..5) share a single dispatch per fleet step.
# The current frame arrives zero-PADDED (C, H+2, W+2, Cin) so every
# window load is a static-size in-bounds slice (pad-ring deltas are 0-0;
# the numpy reference ``ref.tile_delta_gate`` mirrors the padding); the
# comparison side is a PACKED (n, th+2, tw+2, Cin) per-tile reference —
# each tile's window content as of ITS last refresh — and the kernel
# additionally emits the current windows so callers advance refreshed
# tiles' references with one on-device row update.


def _batched_stats(cur, prev, qstep: float, coef_bits: int,
                   run_bits: int):
    """(tb, rows, cols, C) window-pair block -> per-tile (bytes, nnz,
    runs) int32 vectors, the same integer math as ``_tile_stats`` with
    the tile axis batched (one VPU pass for the whole block instead of
    ``tb`` unrolled scans)."""
    tb, rows = cur.shape[0], cur.shape[1]
    q = jnp.round((cur.astype(jnp.float32) - prev.astype(jnp.float32))
                  / qstep).astype(jnp.int32)
    z2 = (q == 0).reshape(tb, rows, -1)
    nnz = jnp.sum((~z2).astype(jnp.int32), axis=(1, 2))
    left = jnp.concatenate(
        [jnp.zeros((tb, rows, 1), bool), z2[:, :, :-1]], axis=2)
    runs = jnp.sum((z2 & ~left).astype(jnp.int32), axis=(1, 2))
    nbytes = (nnz * coef_bits + runs * run_bits + 7) // 8
    return nbytes, nnz, runs, jnp.sum(jnp.abs(q), axis=(1, 2, 3))


def _tile_delta_gate_kernel(idx_ref, cur_ref, ref_ref, o_ref, w_ref, *,
                            th: int, tw: int, tb: int, qstep: float,
                            coef_bits: int, run_bits: int):
    b = pl.program_id(0)
    curs = []
    for j in range(tb):
        cam = idx_ref[b * tb + j, 0]
        ty = idx_ref[b * tb + j, 1]
        tx = idx_ref[b * tb + j, 2]
        # the haloed (th+2, tw+2, C) window: on the padded plane the
        # window of tile (ty, tx) starts at (ty*th, tx*tw)
        sel = (pl.ds(cam, 1), pl.ds(ty * th, th + 2),
               pl.ds(tx * tw, tw + 2), slice(None))
        curs.append(pl.load(cur_ref, sel)[0])
    cur = jnp.stack(curs)                    # (tb, th+2, tw+2, C)
    prev = ref_ref[...]                      # the block's PACKED refs
    body = _batched_stats(cur[:, 1:1 + th, 1:1 + tw],
                          prev[:, 1:1 + th, 1:1 + tw], qstep, coef_bits,
                          run_bits)
    # window stats: quantized byte estimate (rows = th+2 scan rows, same
    # row-independent run rule as the body) + the EXACT bitwise change
    # count the threshold-0 gate keys on (quantization rounds small
    # deltas to zero; bit-identity needs the raw comparison)
    win_bytes, _, _, _ = _batched_stats(cur, prev, qstep, coef_bits,
                                        run_bits)
    exact = jnp.sum((cur != prev).astype(jnp.int32), axis=(1, 2, 3))
    out = jnp.zeros((tb, STATS_WIDTH), jnp.int32)
    out = out.at[:, 0].set(body[0]).at[:, 1].set(body[1]) \
             .at[:, 2].set(body[2]).at[:, 3].set(body[3]) \
             .at[:, GATE_WIN_EXACT].set(exact) \
             .at[:, GATE_WIN_BYTES].set(win_bytes)
    o_ref[...] = out
    w_ref[...] = cur                         # current windows, packed


def tile_delta_gate(cur_p: jax.Array, ref_win: jax.Array, idx: jax.Array,
                    th: int, tw: int, qstep: float = 8.0,
                    coef_bits: int = COEF_BITS, run_bits: int = RUN_BITS,
                    *, block: int = 1, interpret: bool = True):
    """cur_p: (C, H+2, W+2, Cin) zero-padded stacked fleet frames;
    ref_win: (n, th+2, tw+2, Cin) PACKED per-tile reference windows (each
    tile's haloed window content as of that tile's last refresh — packed
    rows, not a canvas, so one tile's reference can never alias a
    neighbor's through the window overlap); idx: (n, 3) int32
    (cam, ty, tx) coords.  Returns (stats, windows): stats (n,
    STATS_WIDTH) int32 rows — cols 0..3 the BODY delta stats (equal to
    ``tile_delta`` when the references hold the previous frame), col 4
    the exact bitwise change count of the haloed window, col 5 its
    quantized byte estimate — and windows (n, th+2, tw+2, Cin), the
    CURRENT haloed windows, so callers advance references with a pure
    on-device ``.at[rows].set(windows[rows])`` (no second gather, no
    host round-trip).  Bit-exact vs ``ref.tile_delta_gate``.  ``block``
    > 1 blocks the walk exactly like the blocked entry kernel."""
    n = idx.shape[0]
    nb, tb, n_pad = balanced_split(n, block)
    idx = pad_repeat_last(idx, n_pad)
    ref_win = pad_repeat_last(ref_win, n_pad)
    Cin = cur_p.shape[-1]
    kernel = functools.partial(_tile_delta_gate_kernel, th=th, tw=tw,
                               tb=tb, qstep=qstep, coef_bits=coef_bits,
                               run_bits=run_bits)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_pad // tb,),
        in_specs=[
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
            pl.BlockSpec((tb, th + 2, tw + 2, Cin),
                         lambda b, idx_ref: (b, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, STATS_WIDTH), lambda b, idx_ref: (b, 0)),
            pl.BlockSpec((tb, th + 2, tw + 2, Cin),
                         lambda b, idx_ref: (b, 0, 0, 0)),
        ],
    )
    stats, wins = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, STATS_WIDTH), jnp.int32),
            jax.ShapeDtypeStruct((n_pad, th + 2, tw + 2, Cin),
                                 cur_p.dtype),
        ],
        interpret=interpret,
    )(idx, cur_p, ref_win)
    return stats[:n], wins[:n]


def _tile_delta_gate_canvas_kernel(idx_ref, cur_ref, refc_ref, o_ref, *,
                                   th: int, tw: int, tb: int, qstep: float,
                                   coef_bits: int, run_bits: int):
    b = pl.program_id(0)
    curs, prevs = [], []
    for j in range(tb):
        cam = idx_ref[b * tb + j, 0]
        ty = idx_ref[b * tb + j, 1]
        tx = idx_ref[b * tb + j, 2]
        sel = (pl.ds(cam, 1), pl.ds(ty * th, th + 2),
               pl.ds(tx * tw, tw + 2), slice(None))
        curs.append(pl.load(cur_ref, sel)[0])
        prevs.append(pl.load(refc_ref, sel)[0])
    cur = jnp.stack(curs)                    # (tb, th+2, tw+2, C)
    prev = jnp.stack(prevs)                  # reference windows, canvas
    body = _batched_stats(cur[:, 1:1 + th, 1:1 + tw],
                          prev[:, 1:1 + th, 1:1 + tw], qstep, coef_bits,
                          run_bits)
    win_bytes, _, _, _ = _batched_stats(cur, prev, qstep, coef_bits,
                                        run_bits)
    exact = jnp.sum((cur != prev).astype(jnp.int32), axis=(1, 2, 3))
    out = jnp.zeros((tb, STATS_WIDTH), jnp.int32)
    out = out.at[:, 0].set(body[0]).at[:, 1].set(body[1]) \
             .at[:, 2].set(body[2]).at[:, 3].set(body[3]) \
             .at[:, GATE_WIN_EXACT].set(exact) \
             .at[:, GATE_WIN_BYTES].set(win_bytes)
    o_ref[...] = out


def tile_delta_gate_canvas(cur_p: jax.Array, ref_c: jax.Array,
                           idx: jax.Array, th: int, tw: int,
                           qstep: float = 8.0, coef_bits: int = COEF_BITS,
                           run_bits: int = RUN_BITS, *, block: int = 1,
                           interpret: bool = True) -> jax.Array:
    """The gate with CANVAS-RESIDENT references: same pricing math as
    ``tile_delta_gate`` (identical stats columns, bit-exact when both
    views hold the same reference content), but the comparison side is a
    (C, H+2, W+2, Cin) reference CANVAS addressed through the same
    (cam, ty, tx) rows as the current frame — no packed (n, th+2, tw+2)
    duplication (~1.3x the canvas bytes) and no windows output at all:
    reference advancement writes window regions of the canvas from the
    current frame, so the kernel's write side is stats rows only.
    ``cur_p`` and ``ref_c`` have the SAME padded shape; per-tile refresh
    epochs are tracked host-side (serving/detector)."""
    n = idx.shape[0]
    nb, tb, n_pad = balanced_split(n, block)
    idx = pad_repeat_last(idx, n_pad)
    kernel = functools.partial(_tile_delta_gate_canvas_kernel, th=th,
                               tw=tw, tb=tb, qstep=qstep,
                               coef_bits=coef_bits, run_bits=run_bits)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_pad // tb,),
        in_specs=[
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
        ],
        out_specs=pl.BlockSpec((tb, STATS_WIDTH),
                               lambda b, idx_ref: (b, 0)),
    )
    stats = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad, STATS_WIDTH), jnp.int32),
        interpret=interpret,
    )(idx, cur_p, ref_c)
    return stats[:n]


# ---------------------------------------------------------------------------
# halo-strip delta pricing (the boundary ring, not the tile body)
# ---------------------------------------------------------------------------

def _halo_strip_stats(cur, prev, qstep: float):
    """One strip pair -> (nnz, runs, sum|q|) with the strip as ONE scan
    row (a zero run never joins across strips)."""
    q = jnp.round((cur.astype(jnp.float32) - prev.astype(jnp.float32))
                  / qstep).astype(jnp.int32)
    z = (q == 0).reshape(1, -1)
    nnz = jnp.sum((~z).astype(jnp.int32))
    left = jnp.concatenate([jnp.zeros((1, 1), bool), z[:, :-1]], axis=1)
    runs = jnp.sum((z & ~left).astype(jnp.int32))
    return nnz, runs, jnp.sum(jnp.abs(q))


def _tile_delta_halo_kernel(idx_ref, cur_ref, prev_ref, o_ref, *, th: int,
                            tw: int, qstep: float, coef_bits: int,
                            run_bits: int):
    i = pl.program_id(0)
    y0 = idx_ref[i, 0] * th
    x0 = idx_ref[i, 1] * tw
    # the tile's edge ring as 4 strips: top row, bottom row, left column,
    # right column.  Corners sit in both a row and a column strip — that
    # duplication IS the halo cost of encoding rectangles independently.
    sels = [(pl.ds(y0, 1), pl.ds(x0, tw)),
            (pl.ds(y0 + th - 1, 1), pl.ds(x0, tw)),
            (pl.ds(y0, th), pl.ds(x0, 1)),
            (pl.ds(y0, th), pl.ds(x0 + tw - 1, 1))]
    nnz = runs = sabs = jnp.asarray(0, jnp.int32)
    for sel in sels:
        c = pl.load(cur_ref, sel + (slice(None),))
        p = pl.load(prev_ref, sel + (slice(None),))
        dn, dr, ds_ = _halo_strip_stats(c, p, qstep)
        nnz, runs, sabs = nnz + dn, runs + dr, sabs + ds_
    nbytes = (nnz * coef_bits + runs * run_bits + 7) // 8
    out = jnp.zeros((STATS_WIDTH,), jnp.int32)
    o_ref[0] = out.at[0].set(nbytes).at[1].set(nnz).at[2].set(runs) \
                  .at[3].set(sabs)


def tile_delta_halo(cur: jax.Array, prev: jax.Array, idx: jax.Array,
                    th: int, tw: int, qstep: float = 8.0,
                    coef_bits: int = COEF_BITS, run_bits: int = RUN_BITS,
                    *, interpret: bool = True) -> jax.Array:
    """Delta stats of each active tile's HALO RING (top/bottom rows +
    left/right columns, corners counted in both — the duplicated boundary
    pixels behind the codec model's ``k/sqrt(area)`` surcharge).  Same
    stats row layout as ``tile_delta``; bit-exact vs
    ``ref.tile_delta_halo``.  Lets the rate controller shed halo rows
    whose content is temporally static before touching whole tiles."""
    n = idx.shape[0]
    kernel = functools.partial(_tile_delta_halo_kernel, th=th, tw=tw,
                               qstep=qstep, coef_bits=coef_bits,
                               run_bits=run_bits)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
            pl.BlockSpec(memory_space=_MEMSPACE.ANY),
        ],
        out_specs=pl.BlockSpec((1, STATS_WIDTH),
                               lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, STATS_WIDTH), jnp.int32),
        interpret=interpret,
    )(idx, cur, prev)
