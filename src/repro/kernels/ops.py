"""Public jit'd wrappers around the Pallas kernels.

Handles the framework-facing conveniences: mask -> index-list conversion,
neighbor-table construction for the packed-resident conv chain, padding to
hardware-aligned block counts, batching (vmap), and the interpret switch
(True on CPU; on a real TPU deployment set REPRO_PALLAS_INTERPRET=0).

Every public wrapper bumps ``KERNEL_COUNTS[name]`` *outside* the jit
boundary, so tests and benchmarks can assert structural properties of the
hot path — e.g. that an N-layer RoI conv stack performs exactly one gather
and one scatter (see serving/detector.RoIDetector.roi_forward).
"""
from __future__ import annotations

import collections
import contextlib
import contextvars
import functools
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.obs import metrics as obs_metrics
from repro.kernels.roi_attention import (PAD_POS, block_min_positions,
                                         roi_attention as _roi_attn)
from repro.kernels.roi_conv import (NEIGHBOR_OFFSETS, roi_conv as _roi_conv,
                                    roi_conv_entry as _roi_conv_entry,
                                    roi_conv_fleet as _roi_conv_fleet,
                                    roi_conv_packed as _roi_conv_packed,
                                    roi_conv_stack as _roi_conv_stack)
from repro.kernels.sbnet import sbnet_gather as _gather, \
    sbnet_scatter as _scatter, sbnet_scatter_changed as _scatter_changed, \
    sbnet_scatter_fleet as _scatter_fleet
from repro.kernels.tile_delta import (COEF_BITS, GATE_BODY_BYTES,
                                      GATE_WIN_BYTES, GATE_WIN_EXACT,
                                      RUN_BITS, STATS_WIDTH,
                                      tile_delta as _tile_delta,
                                      tile_delta_gate as _tile_delta_gate,
                                      tile_delta_gate_canvas as
                                      _tile_delta_gate_canvas,
                                      tile_delta_halo as _tile_delta_halo)

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"

# kernel-dispatch counter: wrapper name -> number of pallas_call launches
# issued from Python.  Process-lifetime totals; each launch is counted once
# regardless of jit caching.  Reset with KERNEL_COUNTS.clear() around a
# region of interest, or — the concurrency-safe way — open a
# ``count_kernels()`` region: regions live on a contextvar stack, so a
# dispatch issued from another thread or async task can NEVER leak into a
# region it is not lexically inside (the sharded fleet runtime and the
# async dispatch pipeline rely on this; the bare global is kept for the
# single-threaded consumers that predate them).
KERNEL_COUNTS: collections.Counter = collections.Counter()

_COUNT_LOCK = threading.Lock()
# per-context stack of open count_kernels() regions.  contextvars give
# thread- AND task-local isolation: a region opened on the main thread is
# invisible to dispatches made from a pipeline worker thread, and vice
# versa — which is exactly the trust property dispatch-ceiling assertions
# need under concurrent shard/async execution.
_COUNT_STACK: contextvars.ContextVar = contextvars.ContextVar(
    "repro_kernel_count_stack", default=())


def record_dispatch(name: str, n: int = 1) -> None:
    """Count ``n`` kernel launches under ``name``: bumps the process-wide
    ``KERNEL_COUNTS`` and every ``count_kernels()`` region open in THIS
    context.  Every public wrapper below calls this; runtimes that launch
    raw kernels themselves (the shard_map'd fleet step dispatches one SPMD
    program that runs the kernel once on every shard) call it directly so
    dispatch-structure assertions see their launches too.

    ``name`` must come from the canonical ``obs.metrics.KERNEL_NAMES``
    set — a typo'd counter name raises here instead of silently counting
    zero forever.  When observability is enabled the same bump lands on
    the ``obs`` ``kernel_dispatches`` counter family (label
    ``kernel=name``), bit-compatible with this module's counters over
    the same window."""
    if name not in obs_metrics.KERNEL_NAMES:
        raise ValueError(
            f"unknown kernel counter {name!r}: dispatch names must come "
            f"from obs.metrics.KERNEL_NAMES")
    with _COUNT_LOCK:
        KERNEL_COUNTS[name] += n
        for region in _COUNT_STACK.get():
            region[name] += n
    obs_metrics.KERNEL_DISPATCHES.inc(n, kernel=name)


@contextlib.contextmanager
def count_kernels():
    """Isolated dispatch-count region: ``with count_kernels() as c: ...``.

    ``c`` accumulates exactly the dispatches issued from inside the
    region *in this thread/async context* — counts from earlier work, or
    from other threads dispatching concurrently, cannot corrupt it.  The
    global ``KERNEL_COUNTS`` keeps accumulating independently (it is
    never cleared or restored here), and an enclosing region still
    observes every inner dispatch, so nesting composes.  ``c`` is live
    during the region and final at exit."""
    region: collections.Counter = collections.Counter()
    token = _COUNT_STACK.set(_COUNT_STACK.get() + (region,))
    try:
        yield region
    finally:
        _COUNT_STACK.reset(token)


def mask_to_indices(grid: np.ndarray) -> np.ndarray:
    """Bool (ty, tx) RoI grid -> (n, 2) int32 active-tile coords (static:
    computed offline from the RoI mask, exactly like SBNet's reduce_mask)."""
    ys, xs = np.nonzero(grid)
    return np.stack([ys, xs], axis=1).astype(np.int32)


def neighbor_table(idx: np.ndarray, grid_shape) -> np.ndarray:
    """(n, 2) active-tile coords -> (n, 8) int32 packed-slot neighbor table.

    Column j is the packed slot of the neighbor at NEIGHBOR_OFFSETS[j]
    (NW, N, NE, W, E, SW, S, SE), or -1 when that neighbor is inactive or
    off-frame — the packed conv kernel substitutes a zero halo there,
    matching what the scatter-into-zeros path would have produced.  Static:
    computed offline from the RoI mask, once per mask lifetime.
    """
    idx = np.asarray(idx)
    ty_max, tx_max = grid_shape
    slot = {(int(y), int(x)): i for i, (y, x) in enumerate(idx)}
    nbr = np.full((idx.shape[0], 8), -1, np.int32)
    for i, (y, x) in enumerate(idx):
        for j, (dy, dx) in enumerate(NEIGHBOR_OFFSETS):
            ny, nx = int(y) + dy, int(x) + dx
            if 0 <= ny < ty_max and 0 <= nx < tx_max:
                nbr[i, j] = slot.get((ny, nx), -1)
    return nbr


# ---------------------------------------------------------------------------
# fleet (multi-camera group) index plumbing
# ---------------------------------------------------------------------------

def fleet_indices(grids) -> "tuple[np.ndarray, np.ndarray]":
    """Per-camera bool grids -> one packed index space for the whole group.

    grids: sequence of (tiles_y, tiles_x) bool RoI grids, one per camera.
    Returns (idx (n, 3) int32 rows of (cam, ty, tx), offsets (C+1,) int64):
    camera c's tiles occupy packed slots [offsets[c], offsets[c+1]), in the
    same row-major order ``mask_to_indices`` would give per camera — so the
    fleet-packed tensor is the per-camera packed tensors concatenated."""
    rows = []
    offsets = np.zeros(len(grids) + 1, np.int64)
    for c, grid in enumerate(grids):
        ys, xs = np.nonzero(np.asarray(grid, bool))
        offsets[c + 1] = offsets[c] + ys.size
        rows.append(np.stack([np.full(ys.size, c), ys, xs], axis=1))
    idx = (np.concatenate(rows, axis=0) if rows
           else np.zeros((0, 3))).astype(np.int32)
    return idx, offsets


def fleet_neighbor_table(grids) -> np.ndarray:
    """(n, 8) neighbor table for the concatenated fleet packing.

    Each camera's table is built on its OWN grid (off-frame and inactive
    neighbors are -1) and its slots are shifted by the camera's packed
    offset — a tile's halo can therefore only ever reference slots of the
    same camera, so halos never leak across cameras by construction."""
    tables = []
    off = 0
    for grid in grids:
        grid = np.asarray(grid, bool)
        idx = mask_to_indices(grid)
        nbr = neighbor_table(idx, grid.shape)
        nbr[nbr >= 0] += off
        off += idx.shape[0]
        tables.append(nbr)
    if not tables:
        return np.zeros((0, 8), np.int32)
    return np.concatenate(tables, axis=0).astype(np.int32)


def superlaunch_tables(grids_per_group):
    """Fleet-flat index space over ALL groups' cameras — the super-launch.

    grids_per_group: sequence of per-group camera-grid lists.  Flattens
    every camera of every group into ONE (flat_cam, ty, tx) index space:
    returns (idx (n, 3) int32, nbr (n, 8) int32, tile_offsets (F+1,),
    cam_starts (K+1,)) where F is the flat camera count and group g's
    cameras are flat cams [cam_starts[g], cam_starts[g+1]).  Slot offsets
    are per flat camera (``fleet_neighbor_table``), so halos are leak-free
    across cameras AND across groups by construction — group boundaries
    are just camera boundaries in the flat space."""
    flat = [g for gs in grids_per_group for g in gs]
    idx, tile_offsets = fleet_indices(flat)
    nbr = fleet_neighbor_table(flat)
    cam_starts = np.cumsum([0] + [len(gs) for gs in grids_per_group]) \
        .astype(np.int64)
    return idx, nbr, tile_offsets, cam_starts


# ---------------------------------------------------------------------------
# shard planning: group -> device-shard assignment (placement-free)
# ---------------------------------------------------------------------------

class ShardPlan:
    """Placement-free assignment of camera groups to mesh shards.

    ``superlaunch_tables`` stays device-agnostic (flat tables over any
    group subset); the plan is the SEPARATE object that says which groups
    land on which shard.  Balanced by ACTIVE-TILE count, not group count
    — one busy intersection cannot straggle a shard behind the others —
    via longest-processing-time greedy (sort groups by tile count
    descending, place each on the least-loaded shard), which carries the
    classic LPT bound: max shard load <= mean load + max single-group
    load.  Groups keep their offered order WITHIN a shard, so per-shard
    flat tables are ``superlaunch_tables`` of an order-preserving
    subsequence."""

    def __init__(self, assignment: np.ndarray, tile_counts: np.ndarray,
                 n_shards: int):
        self.assignment = np.asarray(assignment, np.int64)   # (K,)
        self.tile_counts = np.asarray(tile_counts, np.int64)  # (K,)
        self.n_shards = int(n_shards)

    @property
    def n_groups(self) -> int:
        return int(self.assignment.shape[0])

    def shard_groups(self, s: int) -> "list[int]":
        """Group positions assigned to shard ``s``, in offered order."""
        return [int(i) for i in np.nonzero(self.assignment == s)[0]]

    @property
    def shard_tiles(self) -> np.ndarray:
        """(S,) active tiles per shard."""
        out = np.zeros(self.n_shards, np.int64)
        np.add.at(out, self.assignment, self.tile_counts)
        return out

    @property
    def imbalance(self) -> float:
        """max/mean shard tile load (1.0 = perfectly balanced)."""
        loads = self.shard_tiles
        mean = float(loads.mean()) if loads.size else 0.0
        return float(loads.max()) / mean if mean > 0 else 1.0


def shard_plan(grids_per_group, n_shards: int) -> ShardPlan:
    """Plan the group -> shard assignment for a sharded super-launch.

    grids_per_group: sequence of per-group camera-grid lists (the same
    argument ``superlaunch_tables`` takes).  Deterministic: ties broken
    by group position."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    tiles = np.array([sum(int(np.count_nonzero(np.asarray(g, bool)))
                          for g in gs) for gs in grids_per_group],
                     np.int64)
    order = np.argsort(-tiles, kind="stable")       # LPT: biggest first
    loads = np.zeros(n_shards, np.int64)
    assignment = np.zeros(tiles.shape[0], np.int64)
    for gi in order:
        s = int(np.argmin(loads))                   # least-loaded shard
        assignment[gi] = s
        loads[s] += tiles[gi]
    return ShardPlan(assignment, tiles, n_shards)


# ---------------------------------------------------------------------------
# temporal reuse: changed-set dilation + compaction (host-side, static)
# ---------------------------------------------------------------------------

def dilate_changed(changed: np.ndarray, nbr: np.ndarray) -> np.ndarray:
    """One morphological dilation of a per-tile bool set through the
    (n, 8) neighbor table: a tile joins the set when any of its in-table
    neighbors is in it.  The table never references another camera's
    slots (``fleet_neighbor_table`` offsets are per camera), so dilation
    respects camera — and therefore group — boundaries by construction."""
    changed = np.asarray(changed, bool)
    if changed.size == 0:
        return changed
    nbr = np.asarray(nbr)
    safe = np.clip(nbr, 0, changed.size - 1)
    return changed | (changed[safe] & (nbr >= 0)).any(axis=1)


def reuse_sets(raw_changed: np.ndarray, nbr: np.ndarray,
               n_layers: int) -> "tuple[np.ndarray, np.ndarray]":
    """The delta gate's receptive-field bookkeeping.  ``raw_changed``
    marks tiles whose ENTRY-LAYER INPUT (the haloed window) changed.
    Returns (changed_out, compute) bool masks:

    * ``changed_out`` — tiles whose FINAL-layer output may differ: the
      raw set dilated once per packed layer (each packed layer reads a
      1-tile halo, so change spreads one ring per layer; a reused tile
      is only bit-safe if its halo donors are static at every depth).
    * ``compute`` — the tiles the compact launch must convolve:
      ``changed_out`` dilated once more per packed layer.  The margin
      absorbs the zero-halo error of compaction: a compact neighbor
      table zero-halos active tiles outside the set, which corrupts the
      launch's OUTER rings only — after N-1 packed layers the corruption
      has walked N-1 tiles inward, so every ``changed_out`` tile (≥ N-1
      tiles from the compute boundary by construction) is bit-exact.
      Margin tiles are computed and DISCARDED (the cache keeps their
      old, still-valid values)."""
    changed = np.asarray(raw_changed, bool)
    for _ in range(max(n_layers - 1, 0)):
        changed = dilate_changed(changed, nbr)
    compute = changed
    for _ in range(max(n_layers - 1, 0)):
        compute = dilate_changed(compute, nbr)
    return changed, compute


def compact_tables(idx: np.ndarray, nbr: np.ndarray, keep: np.ndarray
                   ) -> "tuple[np.ndarray, np.ndarray]":
    """Compact the superlaunch tables to the kept tiles: returns
    (idx[keep], remapped (k, 8) neighbor table).  Kept neighbors are
    renumbered to compact slots; dropped or inactive neighbors become -1
    (zero halo) — the compaction the reuse margin is sized for."""
    idx = np.asarray(idx)
    nbr = np.asarray(nbr)
    keep = np.asarray(keep, bool)
    n = idx.shape[0]
    pos = np.full(n, -1, np.int64)
    pos[keep] = np.arange(int(keep.sum()))
    cnbr = np.where(nbr >= 0, pos[np.clip(nbr, 0, max(n - 1, 0))],
                    -1).astype(np.int32)
    return idx[keep].astype(np.int32), cnbr[keep]


def choose_block(th: int, tw: int, c: int, n_layers: int,
                 vmem_bytes: int = 16 * 2 ** 20,
                 dtype_bytes: int = 4) -> int:
    """Size the entry/stack/scatter ``block`` (tiles per grid step) from
    a VMEM budget instead of the hardcoded interpret-mode 128.

    Per resident tile the stack kernel's conv phase holds the assembled
    (th+2, tw+2, C) window, the center in/out activations, the four rim
    strips it reads and the four edge strips it stores; the weight plane
    is (3, 3, C, C) ×2 for the pipeline's layer-(l+1) prefetch (layer
    count does not change residency — weights are block-indexed by
    layer — but a 1-layer net has no stack weights at all).  The block
    is the largest power of two whose double-buffered footprint fits,
    floored at 1 so degenerate budgets still launch."""
    c = max(int(c), 1)
    weights = (2 if n_layers > 1 else 1) * 9 * c * c * dtype_bytes
    per_tile = ((th + 2) * (tw + 2)          # assembled haloed window
                + 2 * th * tw                # center in + out
                + 2 * (tw + 2) + 2 * th      # rim strips read
                + 2 * tw + 2 * th)           # edge strips stored
    per_tile *= c * dtype_bytes
    budget = int(vmem_bytes) - weights
    if budget < 2 * per_tile:
        return 1
    tb = budget // (2 * per_tile)            # double-buffered stages
    block = 1
    while block * 2 <= tb and block < 1024:
        block *= 2
    return block


# ---------------------------------------------------------------------------
# jit'd kernel entry points (private) + counting public wrappers
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("th", "tw", "interpret"))
def _sbnet_gather_jit(x, idx, th, tw, interpret=INTERPRET):
    return _gather(x, idx, th, tw, interpret=interpret)


def sbnet_gather(x: jax.Array, idx: jax.Array, th: int, tw: int,
                 interpret: bool = INTERPRET) -> jax.Array:
    """(H, W, C) + (n, 2) tile coords -> packed (n, th, tw, C)."""
    record_dispatch("sbnet_gather")
    return _sbnet_gather_jit(x, idx, th, tw, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _sbnet_scatter_jit(packed, idx, base, interpret=INTERPRET):
    return _scatter(packed, idx, base, interpret=interpret)


def sbnet_scatter(packed: jax.Array, idx: jax.Array, base: jax.Array,
                  interpret: bool = INTERPRET) -> jax.Array:
    """Packed tiles -> full map, untouched regions keep ``base`` values."""
    record_dispatch("sbnet_scatter")
    return _sbnet_scatter_jit(packed, idx, base, interpret)


@functools.partial(jax.jit, static_argnames=("th", "tw", "interpret"))
def _roi_conv_jit(x, w, idx, th, tw, interpret=INTERPRET):
    return _roi_conv(x, w, idx, th, tw, interpret=interpret)


def roi_conv(x: jax.Array, w: jax.Array, idx: jax.Array, th: int, tw: int,
             interpret: bool = INTERPRET) -> jax.Array:
    """Fused gather+3x3 conv on active tiles -> packed (n, th, tw, Cout)."""
    record_dispatch("roi_conv")
    return _roi_conv_jit(x, w, idx, th, tw, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _roi_conv_packed_jit(packed, w, nbr, interpret=INTERPRET):
    return _roi_conv_packed(packed, w, nbr, interpret=interpret)


def roi_conv_packed(packed: jax.Array, w: jax.Array, nbr: jax.Array,
                    interpret: bool = INTERPRET) -> jax.Array:
    """Packed-resident conv layer: (n, th, tw, Cin) -> (n, th, tw, Cout)
    with halos pulled from neighbor tiles (``neighbor_table``); no
    full-frame materialization between layers."""
    record_dispatch("roi_conv_packed")
    return _roi_conv_packed_jit(packed, w, nbr, interpret)


@functools.partial(jax.jit, static_argnames=("th", "tw", "interpret"))
def _roi_conv_fleet_jit(x, w, idx, th, tw, interpret=INTERPRET):
    return _roi_conv_fleet(x, w, idx, th, tw, interpret=interpret)


def roi_conv_fleet(x: jax.Array, w: jax.Array, idx: jax.Array, th: int,
                   tw: int, interpret: bool = INTERPRET) -> jax.Array:
    """Cross-camera fused gather+conv: (C, H, W, Cin) stacked frames +
    (n, 3) (cam, ty, tx) coords -> packed (n, th, tw, Cout) for the whole
    camera group in ONE launch (see ``fleet_indices``)."""
    record_dispatch("roi_conv_fleet")
    return _roi_conv_fleet_jit(x, w, idx, th, tw, interpret)


@functools.partial(jax.jit, static_argnames=("th", "tw", "block",
                                             "interpret"))
def _roi_conv_entry_jit(x, w, idx, th, tw, block=1, interpret=INTERPRET):
    return _roi_conv_entry(x, w, idx, th, tw, block=block,
                           interpret=interpret)


def roi_conv_entry(x: jax.Array, w: jax.Array, idx: jax.Array, th: int,
                   tw: int, block: int = 1,
                   interpret: bool = INTERPRET) -> jax.Array:
    """Fleet-flat fused gather+conv+relu over any number of cameras (and
    groups): (C, H, W, Cin) stacked frames + (n, 3) (flat_cam, ty, tx)
    coords -> relu'd packed (n, th, tw, Cout) — the fused backbone's
    entry layer, feeding ``roi_conv_stack``.  ``block`` > 1 blocks the
    tile walk (``choose_block`` sizes it against VMEM): ``block`` haloed
    windows gathered per grid step, one GEMM per tap per block,
    bit-identical to the per-tile walk.  An empty compute set is NOT a
    dispatch: zero tiles return an empty packed tensor with no launch
    formed and no counter bump."""
    if idx.shape[0] == 0:
        return jnp.zeros((0, th, tw, w.shape[-1]), x.dtype)
    record_dispatch("roi_conv_entry")
    return _roi_conv_entry_jit(x, w, idx, th, tw, int(block), interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _roi_conv_stack_jit(packed, ws, nbr, block, interpret=INTERPRET):
    return _roi_conv_stack(packed, ws, nbr, block=block,
                           interpret=interpret)


def roi_conv_stack(packed: jax.Array, ws, nbr: jax.Array,
                   block: int = 128,
                   interpret: bool = INTERPRET) -> jax.Array:
    """The fused layer-stack megakernel: the whole packed conv chain
    (conv + relu per layer, double-buffered activations + coalesced rim
    halos, weight prefetch for layer l+1 during layer l) in ONE dispatch
    — bit-identical to N-1 ``roi_conv_packed`` + relu rounds."""
    record_dispatch("roi_conv_stack")
    return _roi_conv_stack_jit(packed, tuple(ws), nbr, int(block),
                               interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _sbnet_scatter_fleet_jit(packed, idx, base, block=1,
                             interpret=INTERPRET):
    return _scatter_fleet(packed, idx, base, block=block,
                          interpret=interpret)


def sbnet_scatter_fleet(packed: jax.Array, idx: jax.Array, base: jax.Array,
                        block: int = 1,
                        interpret: bool = INTERPRET) -> jax.Array:
    """Cross-camera scatter: packed group tiles -> (C, H, W, Cout) stacked
    frames in ONE launch; untouched regions keep ``base`` values.
    ``block`` > 1 blocks the tile walk: ``block`` packed tiles arrive per
    grid step as one contiguous load, bit-identical to the per-tile
    walk.  An empty tile set is NOT a dispatch: ``base`` is returned
    untouched with no launch formed and no counter bump."""
    if packed.shape[0] == 0:
        return base
    record_dispatch("sbnet_scatter_fleet")
    return _sbnet_scatter_fleet_jit(packed, idx, base, int(block),
                                    interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _sbnet_scatter_changed_jit(packed, idx, base, block=1,
                               interpret=INTERPRET):
    return _scatter_changed(packed, idx, base, block=block,
                            interpret=interpret)


@functools.lru_cache(maxsize=1)
def _sbnet_scatter_changed_donated_jit():
    # donate_argnums touches the backend at trace time, so build lazily —
    # and only off-CPU callers ask for it (CPU jit rejects donation with a
    # warning, same constraint the serving engine's ring writer handles).
    return jax.jit(_scatter_changed,
                   static_argnames=("block", "interpret"),
                   donate_argnums=(2,))


def sbnet_scatter_changed(packed: jax.Array, idx: jax.Array,
                          base: jax.Array, block: int = 1,
                          interpret: bool = INTERPRET,
                          donate: bool = False) -> jax.Array:
    """Changed-only scatter into the PERSISTENT head-map canvas:
    ``base`` is the previous step's device-resident canvas, ``packed`` /
    ``idx`` carry ONLY this step's changed tiles, unchanged tiles pass
    through untouched — O(changed) canvas bytes per step, bit-identical
    to re-scattering the whole active set (``sbnet_scatter_fleet``)
    composed with the passthrough.  An all-static step (zero changed
    tiles) returns the canvas with NO launch and NO counter bump.
    ``donate=True`` donates the canvas buffer to the launch (in-place
    update, double-buffer-free) — caller must not reuse ``base`` after;
    only ask for it off-CPU (see ``serving.engine.ring_donate_argnums``)."""
    if packed.shape[0] == 0:
        return base
    record_dispatch("sbnet_scatter_changed")
    if donate:
        return _sbnet_scatter_changed_donated_jit()(
            packed, idx, base, block=int(block), interpret=interpret)
    return _sbnet_scatter_changed_jit(packed, idx, base, int(block),
                                      interpret)


@functools.partial(jax.jit, static_argnames=("th", "tw", "qstep",
                                             "coef_bits", "run_bits",
                                             "interpret"))
def _tile_delta_jit(cur, prev, idx, th, tw, qstep, coef_bits, run_bits,
                    interpret=INTERPRET):
    return _tile_delta(cur, prev, idx, th, tw, qstep, coef_bits, run_bits,
                       interpret=interpret)


def tile_delta(cur: jax.Array, prev: jax.Array, idx: jax.Array, th: int,
               tw: int, qstep: float = 8.0, coef_bits: int = COEF_BITS,
               run_bits: int = RUN_BITS,
               interpret: bool = INTERPRET) -> jax.Array:
    """Per-tile temporal delta stats for the edge rate controller:
    (H, W, C) frame pair + (n, 2) tile coords -> (n, STATS_WIDTH) int32
    rows of [byte_estimate, nnz, zero_runs, sum|q|, 0...] (bit-exact vs
    ``ref.tile_delta``)."""
    record_dispatch("tile_delta")
    return _tile_delta_jit(cur, prev, idx, th, tw, float(qstep),
                           int(coef_bits), int(run_bits), interpret)


@functools.partial(jax.jit, static_argnames=("th", "tw", "qstep",
                                             "coef_bits", "run_bits",
                                             "block", "interpret"))
def _tile_delta_gate_jit(cur_p, ref_win, idx, th, tw, qstep, coef_bits,
                         run_bits, block=1, interpret=INTERPRET):
    return _tile_delta_gate(cur_p, ref_win, idx, th, tw, qstep, coef_bits,
                            run_bits, block=block, interpret=interpret)


def tile_delta_gate(cur_p: jax.Array, ref_win: jax.Array, idx: jax.Array,
                    th: int, tw: int, qstep: float = 8.0,
                    coef_bits: int = COEF_BITS, run_bits: int = RUN_BITS,
                    block: int = 1, interpret: bool = INTERPRET):
    """The reuse gate's shared delta dispatch: (C, H+2, W+2, Cin)
    zero-padded stacked fleet frames + (n, th+2, tw+2, Cin) PACKED
    per-tile reference windows + (n, 3) (cam, ty, tx) coords ->
    (stats (n, STATS_WIDTH) int32, windows (n, th+2, tw+2, Cin)).
    Stats cols 0..3 are the BODY stats (identical to ``tile_delta`` when
    the references hold the previous frame, feeding the rate
    controller), col GATE_WIN_EXACT the exact bitwise change count of
    the haloed entry window, col GATE_WIN_BYTES its quantized byte
    estimate (bit-exact vs ``ref.tile_delta_gate``); ``windows`` holds
    the CURRENT haloed windows for on-device reference advancement.
    ONE launch per fleet step serves both the reuse gate and the
    encoder's static-tile calibration.  ``block`` > 1 blocks the
    pricing walk like the blocked entry kernel."""
    record_dispatch("tile_delta_gate")
    return _tile_delta_gate_jit(cur_p, ref_win, idx, th, tw, float(qstep),
                                int(coef_bits), int(run_bits),
                                int(block), interpret)


@functools.partial(jax.jit, static_argnames=("th", "tw", "qstep",
                                             "coef_bits", "run_bits",
                                             "block", "interpret"))
def _tile_delta_gate_canvas_jit(cur_p, ref_c, idx, th, tw, qstep,
                                coef_bits, run_bits, block=1,
                                interpret=INTERPRET):
    return _tile_delta_gate_canvas(cur_p, ref_c, idx, th, tw, qstep,
                                   coef_bits, run_bits, block=block,
                                   interpret=interpret)


def tile_delta_gate_canvas(cur_p: jax.Array, ref_c: jax.Array,
                           idx: jax.Array, th: int, tw: int,
                           qstep: float = 8.0, coef_bits: int = COEF_BITS,
                           run_bits: int = RUN_BITS, block: int = 1,
                           interpret: bool = INTERPRET) -> jax.Array:
    """The reuse gate against a CANVAS-RESIDENT reference: same stats
    rows as ``tile_delta_gate`` but the reference side is a second
    (C, H+2, W+2, Cin) padded canvas addressed through the same tile
    rows — no (n, th+2, tw+2) per-tile window duplication (~1.3x the
    canvas bytes on overlap-heavy masks) and no windows output (reference
    advancement writes canvas regions instead).  Counted under the same
    ``tile_delta_gate`` dispatch name: it IS the gate, structurally —
    per-step dispatch ceilings stay mode-independent."""
    record_dispatch("tile_delta_gate")
    return _tile_delta_gate_canvas_jit(cur_p, ref_c, idx, th, tw,
                                       float(qstep), int(coef_bits),
                                       int(run_bits), int(block),
                                       interpret)


@functools.partial(jax.jit, static_argnames=("th", "tw"))
def gather_windows(xp: jax.Array, idx: jax.Array, th: int,
                   tw: int) -> jax.Array:
    """Gather the packed (n, th+2, tw+2, Cin) haloed windows of the
    active tiles from a zero-padded (C, H+2, W+2, Cin) stacked canvas —
    the seed of the gate's per-tile reference windows (pure jnp table
    plumbing, not a counted kernel dispatch; warm steps advance
    references from the gate's own windows output instead)."""
    cin = xp.shape[-1]

    def take(row):
        return jax.lax.dynamic_slice(
            xp, (row[0], row[1] * th, row[2] * tw, 0),
            (1, th + 2, tw + 2, cin))[0]

    return jax.vmap(take)(idx)


@functools.partial(jax.jit, static_argnames=("th", "tw", "qstep",
                                             "coef_bits", "run_bits",
                                             "interpret"))
def _tile_delta_halo_jit(cur, prev, idx, th, tw, qstep, coef_bits,
                         run_bits, interpret=INTERPRET):
    return _tile_delta_halo(cur, prev, idx, th, tw, qstep, coef_bits,
                            run_bits, interpret=interpret)


def tile_delta_halo(cur: jax.Array, prev: jax.Array, idx: jax.Array,
                    th: int, tw: int, qstep: float = 8.0,
                    coef_bits: int = COEF_BITS, run_bits: int = RUN_BITS,
                    interpret: bool = INTERPRET) -> jax.Array:
    """Per-tile temporal delta stats of the HALO STRIPS (the tile's edge
    ring — the pixels duplicated into neighbors when rectangles encode
    independently): (n, STATS_WIDTH) int32 rows, bit-exact vs
    ``ref.tile_delta_halo``.  Feeds halo-first shedding in the edge rate
    controller."""
    record_dispatch("tile_delta_halo")
    return _tile_delta_halo_jit(cur, prev, idx, th, tw, float(qstep),
                                int(coef_bits), int(run_bits), interpret)


def roi_conv_batched(x: jax.Array, w: jax.Array, idx: jax.Array,
                     th: int, tw: int) -> jax.Array:
    """(B, H, W, Cin) -> (B, n, th, tw, Cout), shared active set."""
    record_dispatch("roi_conv")
    return jax.vmap(lambda xi: _roi_conv_jit(xi, w, idx, th, tw))(x)


def pack_tokens(x: jax.Array, keep: jax.Array, block: int = 128):
    """Pack kept rows of (S, ...) to a dense prefix padded to ``block``.

    keep: (S,) bool.  Returns (packed, positions, n_kept) where positions
    holds original indices (padding rows = PAD_POS).  Padded length is the
    smallest multiple of ``block`` >= S (static shape, jit-friendly).
    Kept rows stay in original order, so positions are monotone over real
    rows — the invariant the attention kernel's causal block skip uses.
    """
    S = x.shape[0]
    Sp = -(-S // block) * block
    order = jnp.argsort(~keep, stable=True)          # kept rows first
    n_kept = jnp.sum(keep.astype(jnp.int32))
    gathered = x[order]
    positions = jnp.where(jnp.arange(S) < n_kept, order, PAD_POS)
    pad = [(0, Sp - S)] + [(0, 0)] * (x.ndim - 1)
    packed = jnp.pad(gathered, pad)
    positions = jnp.pad(positions, (0, Sp - S), constant_values=PAD_POS)
    return packed, positions.astype(jnp.int32), n_kept


def unpack_tokens(packed: jax.Array, positions: jax.Array, S: int,
                  fill: float = 0.0) -> jax.Array:
    """Inverse of pack_tokens: scatter packed rows back to (S, ...)."""
    out = jnp.full((S,) + packed.shape[1:], fill, packed.dtype)
    # padding rows carry PAD_POS; route them out-of-bounds and drop, so
    # they can never collide with a real write
    pos = jnp.where(positions < S, positions, S)
    return out.at[pos].set(packed, mode="drop")


@functools.partial(jax.jit,
                   static_argnames=("block_q", "block_k", "causal_skip",
                                    "return_stats", "interpret"))
def _roi_attention_jit(q, k, v, positions, block_q=128, block_k=128,
                       causal_skip=True, return_stats=False,
                       interpret=INTERPRET):
    return _roi_attn(q, k, v, positions, block_q=block_q, block_k=block_k,
                     causal_skip=causal_skip, return_stats=return_stats,
                     interpret=interpret)


def roi_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  positions: jax.Array, block_q: int = 128,
                  block_k: int = 128, causal_skip: bool = True,
                  return_stats: bool = False,
                  interpret: bool = INTERPRET):
    """Packed-prefill attention over (S, H, D) with original-position
    causality.  S must already be block-padded (pack_tokens does this).
    ``causal_skip`` bounds the k-block walk at the causal frontier (exact:
    outputs on real rows are unchanged); ``return_stats`` additionally
    returns the (H, S // block_q) visited-k-block counts."""
    record_dispatch("roi_attention")
    return _roi_attention_jit(q, k, v, positions, block_q, block_k,
                              causal_skip, return_stats, interpret)


def attention_visit_bound(positions: np.ndarray, block_q: int = 128,
                          block_k: int = 128) -> np.ndarray:
    """Host-side mirror of the kernel's causal bound: visited k-blocks per
    q-block, (S // block_q,) int.  Useful for structural FLOP accounting
    without launching the kernel."""
    positions = np.asarray(positions)
    S = positions.shape[0]
    kmin = np.asarray(block_min_positions(positions, block_k))
    out = np.zeros(S // block_q, np.int64)
    for qi in range(S // block_q):
        pq = positions[qi * block_q:(qi + 1) * block_q]
        real = pq[pq != int(PAD_POS)]
        if real.size == 0:
            continue
        hits = np.nonzero(kmin <= real.max())[0]
        out[qi] = 0 if hits.size == 0 else int(hits[-1]) + 1
    return out


__all__ = ["mask_to_indices", "neighbor_table", "fleet_indices",
           "fleet_neighbor_table", "superlaunch_tables", "ShardPlan",
           "shard_plan", "record_dispatch", "dilate_changed",
           "reuse_sets", "compact_tables", "choose_block", "sbnet_gather",
           "sbnet_scatter", "sbnet_scatter_fleet", "sbnet_scatter_changed",
           "roi_conv", "roi_conv_entry", "roi_conv_fleet",
           "roi_conv_packed", "roi_conv_stack", "roi_conv_batched",
           "tile_delta", "tile_delta_gate", "tile_delta_gate_canvas",
           "gather_windows", "tile_delta_halo",
           "GATE_BODY_BYTES",
           "GATE_WIN_BYTES", "GATE_WIN_EXACT", "STATS_WIDTH", "pack_tokens",
           "unpack_tokens", "roi_attention", "attention_visit_bound",
           "block_min_positions", "KERNEL_COUNTS", "count_kernels",
           "PAD_POS", "ref"]
