"""Public jit'd wrappers around the Pallas kernels.

Handles the framework-facing conveniences: mask -> index-list conversion,
padding to hardware-aligned block counts, batching (vmap), and the
interpret switch (True on CPU; on a real TPU deployment set
REPRO_PALLAS_INTERPRET=0).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.roi_attention import PAD_POS, roi_attention as _roi_attn
from repro.kernels.roi_conv import roi_conv as _roi_conv
from repro.kernels.sbnet import sbnet_gather as _gather, \
    sbnet_scatter as _scatter

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def mask_to_indices(grid: np.ndarray) -> np.ndarray:
    """Bool (ty, tx) RoI grid -> (n, 2) int32 active-tile coords (static:
    computed offline from the RoI mask, exactly like SBNet's reduce_mask)."""
    ys, xs = np.nonzero(grid)
    return np.stack([ys, xs], axis=1).astype(np.int32)


@functools.partial(jax.jit, static_argnames=("th", "tw", "interpret"))
def sbnet_gather(x: jax.Array, idx: jax.Array, th: int, tw: int,
                 interpret: bool = INTERPRET) -> jax.Array:
    """(H, W, C) + (n, 2) tile coords -> packed (n, th, tw, C)."""
    return _gather(x, idx, th, tw, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sbnet_scatter(packed: jax.Array, idx: jax.Array, base: jax.Array,
                  interpret: bool = INTERPRET) -> jax.Array:
    """Packed tiles -> full map, untouched regions keep ``base`` values."""
    return _scatter(packed, idx, base, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("th", "tw", "interpret"))
def roi_conv(x: jax.Array, w: jax.Array, idx: jax.Array, th: int, tw: int,
             interpret: bool = INTERPRET) -> jax.Array:
    """Fused gather+3x3 conv on active tiles -> packed (n, th, tw, Cout)."""
    return _roi_conv(x, w, idx, th, tw, interpret=interpret)


def roi_conv_batched(x: jax.Array, w: jax.Array, idx: jax.Array,
                     th: int, tw: int) -> jax.Array:
    """(B, H, W, Cin) -> (B, n, th, tw, Cout), shared active set."""
    return jax.vmap(lambda xi: roi_conv(xi, w, idx, th, tw))(x)


def pack_tokens(x: jax.Array, keep: jax.Array, block: int = 128):
    """Pack kept rows of (S, ...) to a dense prefix padded to ``block``.

    keep: (S,) bool.  Returns (packed, positions, n_kept) where positions
    holds original indices (padding rows = PAD_POS).  Padded length is the
    smallest multiple of ``block`` >= S (static shape, jit-friendly).
    """
    S = x.shape[0]
    Sp = -(-S // block) * block
    order = jnp.argsort(~keep, stable=True)          # kept rows first
    n_kept = jnp.sum(keep.astype(jnp.int32))
    gathered = x[order]
    positions = jnp.where(jnp.arange(S) < n_kept, order, PAD_POS)
    pad = [(0, Sp - S)] + [(0, 0)] * (x.ndim - 1)
    packed = jnp.pad(gathered, pad)
    positions = jnp.pad(positions, (0, Sp - S), constant_values=PAD_POS)
    return packed, positions.astype(jnp.int32), n_kept


def unpack_tokens(packed: jax.Array, positions: jax.Array, S: int,
                  fill: float = 0.0) -> jax.Array:
    """Inverse of pack_tokens: scatter packed rows back to (S, ...)."""
    out = jnp.full((S,) + packed.shape[1:], fill, packed.dtype)
    # padding rows carry PAD_POS; route them out-of-bounds and drop, so
    # they can never collide with a real write
    pos = jnp.where(positions < S, positions, S)
    return out.at[pos].set(packed, mode="drop")


@functools.partial(jax.jit,
                   static_argnames=("block_q", "block_k", "interpret"))
def roi_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  positions: jax.Array, block_q: int = 128,
                  block_k: int = 128,
                  interpret: bool = INTERPRET) -> jax.Array:
    """Packed-prefill attention over (S, H, D) with original-position
    causality.  S must already be block-padded (pack_tokens does this)."""
    return _roi_attn(q, k, v, positions, block_q=block_q, block_k=block_k,
                     interpret=interpret)


__all__ = ["mask_to_indices", "sbnet_gather", "sbnet_scatter", "roi_conv",
           "roi_conv_batched", "pack_tokens", "unpack_tokens",
           "roi_attention", "PAD_POS", "ref"]
