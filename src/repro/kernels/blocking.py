"""Shared tile-block arithmetic for the blocked kernel walks.

Every blocked walk (``roi_conv_entry``, ``sbnet_scatter_fleet``,
``tile_delta_gate``) splits its ragged n-tile index space the same way:
as many grid steps as the VMEM cap demands, then equal-size blocks —
minimal padding (vs up to 2x duplicate tiles when n is just past a block
multiple) — with the pad rows repeating the LAST real row so duplicate
work is inert (entry/gate: duplicate outputs sliced off; scatter:
idempotent rewrites of the last tile).  One implementation keeps the
"bit-identical to the per-tile walk" contract from diverging per kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def balanced_split(n: int, block: int) -> "tuple[int, int, int]":
    """(num_blocks, tile_block, padded_n) for an n-tile walk capped at
    ``block`` tiles per grid step.  n == 0 yields (1, 1, 0)."""
    nb = -(-max(n, 1) // max(block, 1))
    tb = -(-max(n, 1) // nb)
    return nb, tb, (nb * tb if n else 0)


def pad_repeat_last(arr: jax.Array, n_pad: int) -> jax.Array:
    """Pad ``arr`` to ``n_pad`` leading rows by repeating its last row."""
    n = arr.shape[0]
    if n_pad <= n:
        return arr
    return jnp.concatenate(
        [arr, jnp.broadcast_to(arr[-1:], (n_pad - n,) + arr.shape[1:])])
