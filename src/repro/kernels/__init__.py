"""Pallas TPU kernels for the perf-critical RoI inference path.

sbnet.py          — SBNet gather/scatter, TPU-adapted (scalar-prefetch DMA)
roi_conv.py       — fused gather + 3x3 conv on active tiles (MXU matmuls)
roi_attention.py  — RoI-packed prefill flash attention (position causality)
ops.py            — jit'd public wrappers (mask->indices, padding, batching)
ref.py            — pure-jnp oracles (the semantics contracts for tests)
"""
