from repro.train.loop import TrainState, make_train_step, train

__all__ = ["TrainState", "make_train_step", "train"]
