"""Training loop: microbatched, sharded, fault-tolerant.

make_train_step builds the jitted SPMD step (grad accumulation by lax.scan,
remat inside the model trunks, optional int8 gradient quantization, AdamW).
train() is the launcher-level driver: checkpoint cadence, straggler
monitoring, fault injection, restore-and-continue on failure (elastic
re-mesh), deterministic data replay from the restored step counter.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, TrainConfig
from repro.checkpoint import CheckpointManager
from repro.data.lm import SyntheticLM
from repro.distributed.compression import quantize_int8, dequantize_int8
from repro.distributed.fault import (ElasticMesh, FaultInjector,
                                     InjectedFault, StragglerMonitor)
from repro.distributed.shardings import (batch_pspecs_for, make_dist, named,
                                         param_pspecs)
from repro.models.model import input_specs, train_loss
from repro.models.params import init_params, param_specs
from repro.optim.adamw import (AdamWState, adamw_init, adamw_update)


class TrainState(NamedTuple):
    params: Dict
    opt: AdamWState


def _qdq(g):
    q, s = quantize_int8(g)
    return dequantize_int8(q, s, g.dtype)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    mesh: Optional[Mesh] = None,
                    multi_pod: bool = False,
                    auto_moe: Optional[bool] = None) -> Callable:
    """Returns step(state, batch) -> (state, metrics); jitted + sharded.

    auto_moe: None picks the default — GSPMD-auto expert dispatch for MoE
    training (XLA:CPU's partitioner CHECK-fails on backward-of-shard_map at
    512 devices; on real TPU flip to the shard_map path), manual elsewhere.
    """
    if auto_moe is None:
        auto_moe = False
    dist = make_dist(mesh, auto_moe=auto_moe,
                     dp_only=tcfg.sharding_mode == "dp_only")
    use_remat = tcfg.remat != "none"

    def loss_fn(params, batch):
        loss, metrics = train_loss(params, cfg, batch, dist=dist,
                                   remat=use_remat,
                                   causal_skip=tcfg.causal_skip)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    # ZeRO gradient sharding: the fp32 grad accumulator lives in the
    # optimizer-state sharding (fully sharded over data x model), so each
    # microbatch's grads reduce-scatter into it instead of materializing a
    # param-sharded fp32 tree (which alone would be ~17 GB/device for 67B).
    if mesh is not None:
        opt_mode = tcfg.sharding_mode if tcfg.sharding_mode == "dp_only" \
            else ("fsdp_pod" if multi_pod else "fsdp")
        gspecs = param_pspecs(cfg, param_specs(cfg), opt_mode, multi_pod,
                              mesh=mesh)
        gshard = named(mesh, gspecs)

        def shard_grads(g):
            return jax.tree.map(jax.lax.with_sharding_constraint, g, gshard)
    else:
        def shard_grads(g):
            return g

    def step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        params = state.params
        k = tcfg.microbatch
        if k and k > 1:
            def mb(carry, mbatch):
                acc = carry
                (loss, mets), grads = grad_fn(params, mbatch)
                grads = shard_grads(grads)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / k, acc, grads)
                return acc, (loss, mets)

            split = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                batch)
            zero = shard_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            grads, (losses, _) = jax.lax.scan(mb, zero, split)
            loss = losses.mean()
        else:
            (loss, _), grads = grad_fn(params, batch)
            grads = shard_grads(grads)

        if tcfg.grad_compression == "int8":
            # quantize-dequantize: the numerics of an int8-payload
            # all-reduce (the bytes saving shows in §Roofline's collective
            # term; on a pure-DP mesh distributed/compression.py runs the
            # real int8 psum under shard_map)
            grads = jax.tree.map(_qdq, grads)

        new_params, new_opt, mets = adamw_update(params, grads, state.opt,
                                                 tcfg)
        mets["loss"] = loss
        return TrainState(new_params, new_opt), mets

    if mesh is None:
        return jax.jit(step, donate_argnums=0)

    state_shardings = named(mesh, state_pspecs(cfg, tcfg, multi_pod, mesh))
    return jax.jit(step, donate_argnums=0,
                   in_shardings=(state_shardings, None),
                   out_shardings=(state_shardings, None))


def state_pspecs(cfg: ModelConfig, tcfg: TrainConfig,
                 multi_pod: bool, mesh: Optional[Mesh] = None
                 ) -> "TrainState":
    """Params follow tcfg.sharding_mode; optimizer states are ALWAYS
    ZeRO-1-sharded over the data axes on top of any TP dims (fp32 m/v
    replicated would blow the 16 GiB/chip budget even for 4B models —
    Megatron's distributed optimizer is the paper-era baseline too)."""
    specs = param_specs(cfg)
    pspecs = param_pspecs(cfg, specs, tcfg.sharding_mode, multi_pod,
                          mesh=mesh)
    opt_mode = tcfg.sharding_mode if tcfg.sharding_mode == "dp_only" \
        else ("fsdp_pod" if multi_pod else "fsdp")
    ospecs = param_pspecs(cfg, specs, opt_mode, multi_pod, mesh=mesh)
    return TrainState(pspecs, AdamWState(P(), ospecs, ospecs))


def init_state(cfg: ModelConfig, tcfg: TrainConfig, key,
               mesh: Optional[Mesh] = None,
               multi_pod: bool = False) -> TrainState:
    params = init_params(cfg, key)
    state = TrainState(params, adamw_init(params))
    if mesh is not None:
        shardings = named(mesh, state_pspecs(cfg, tcfg, multi_pod, mesh))
        state = jax.tree.map(jax.device_put, state, shardings)
    return state


@dataclass
class TrainReport:
    steps_run: int
    final_loss: float
    losses: list
    straggler_events: list
    restarts: int
    median_step_s: float


def train(cfg: ModelConfig, tcfg: TrainConfig, *, steps: int,
          batch_shape: Tuple[int, int], workdir: Optional[str] = None,
          mesh: Optional[Mesh] = None, multi_pod: bool = False,
          ckpt_every: int = 0, injector: Optional[FaultInjector] = None,
          data: Optional[SyntheticLM] = None,
          log_every: int = 10, verbose: bool = True) -> TrainReport:
    """Fault-tolerant driver.  On InjectedFault (or any step failure) the
    loop restores the latest checkpoint — onto a freshly built mesh when
    one is configured — and replays data deterministically."""
    B, S = batch_shape
    data = data or SyntheticLM(cfg.vocab_size, S, B, seed=tcfg.seed)
    step_fn = make_train_step(cfg, tcfg, mesh, multi_pod)
    state = init_state(cfg, tcfg, jax.random.PRNGKey(tcfg.seed), mesh,
                       multi_pod)
    mgr = CheckpointManager(workdir) if (workdir and ckpt_every) else None
    monitor = StragglerMonitor()
    losses, restarts = [], 0
    step = 0
    while step < steps:
        batch = data.batch(step)
        monitor.start()
        try:
            if injector is not None:
                injector.check(step)
            state, mets = step_fn(state, batch)
            loss = float(mets["loss"])
        except InjectedFault:
            if mgr is None:
                raise
            restarts += 1
            if verbose:
                print(f"[fault] step {step}: restoring latest checkpoint")
            # elastic: rebuild the step fn (a real failure changes the
            # device set; here the mesh is rebuilt from what's available)
            template = {"state": jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                state._asdict())}
            try:
                restored_step, trees = mgr.restore(template)
                st = trees["state"]
                state = TrainState(st["params"], st["opt"])
                step = restored_step
            except FileNotFoundError:
                # failed before the first checkpoint: cold restart — same
                # seed + stateless data indexing reproduce the run exactly
                state = init_state(cfg, tcfg, jax.random.PRNGKey(tcfg.seed),
                                   mesh, multi_pod)
                step = 0
            step_fn = make_train_step(cfg, tcfg, mesh, multi_pod)
            continue
        monitor.stop(step)
        losses.append(loss)
        if verbose and (step % log_every == 0 or step == steps - 1):
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(mets['grad_norm']):.3f} "
                  f"lr {float(mets['lr']):.2e}")
        step += 1
        if mgr is not None and step % ckpt_every == 0:
            mgr.save(step, {"state": state._asdict()})
    if mgr is not None:
        mgr.wait()
    return TrainReport(steps_run=len(losses),
                       final_loss=losses[-1] if losses else float("nan"),
                       losses=losses,
                       straggler_events=monitor.events,
                       restarts=restarts,
                       median_step_s=monitor.median_step_s)
