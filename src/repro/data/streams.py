"""Multi-camera stream pipeline: scene sim -> patch-token segments.

Bridges the CrossRoI core to the transformer serving stack: per segment and
camera it emits (a) the RoI keep-mask at patch granularity derived from the
offline set-cover masks, and (b) synthetic patch embeddings (the modality
frontend is a stub per the assignment: ``input_specs()``-style precomputed
embeddings).  The serving engine packs kept patches with
kernels/ops.pack_tokens and prefillss the packed stream.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.pipeline import OfflineResult
from repro.core.scene import Scene


@dataclass
class StreamSegment:
    t0: int
    t1: int
    # per camera: (n_frames, n_patches) bool keep + (n_frames, n_patches, D)
    keep: Dict[int, np.ndarray]
    patches: Dict[int, np.ndarray]

    @property
    def keep_fraction(self) -> float:
        tot = sum(int(k.size) for k in self.keep.values())
        kept = sum(int(k.sum()) for k in self.keep.values())
        return kept / max(tot, 1)


@dataclass
class CameraStreamPipeline:
    scene: Scene
    offline: OfflineResult
    patch_dim: int = 64
    frames_per_segment: int = 10
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        # patch grid == tile grid (one token per RoI tile)
        self._grids = {c.cam_id: self.offline.cam_grids[c.cam_id]
                       for c in self.scene.cameras}

    def num_patches(self, cam: int) -> int:
        return int(self._grids[cam].size)

    def segments(self, t0: int, t1: int) -> Iterator[StreamSegment]:
        step = self.frames_per_segment
        for s in range(t0, t1, step):
            e = min(s + step, t1)
            keep, patches = {}, {}
            for c in self.scene.cameras:
                cid = c.cam_id
                grid = self._grids[cid].reshape(-1)
                n = grid.size
                k = np.broadcast_to(grid, (e - s, n)).copy()
                # embeddings: deterministic per (cam, frame, patch)
                rng = np.random.default_rng(
                    (self.seed, cid, s) if self.seed else (cid, s))
                patches[cid] = rng.standard_normal(
                    (e - s, n, self.patch_dim)).astype(np.float32)
                keep[cid] = k
            yield StreamSegment(s, e, keep, patches)

    def fleet_tokens(self, seg: StreamSegment, frame: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Concatenate all cameras' patch tokens for one frame of a segment.
        Returns (tokens (N, D), keep (N,)) in camera order."""
        toks = np.concatenate([seg.patches[c.cam_id][frame]
                               for c in self.scene.cameras], axis=0)
        keep = np.concatenate([seg.keep[c.cam_id][frame]
                               for c in self.scene.cameras], axis=0)
        return toks, keep
