"""Deterministic synthetic LM data pipeline.

Stateless indexing: batch(step) is a pure function of (seed, step, shard),
so restart-after-failure replays the exact stream from the restored step
counter with no pipeline state to checkpoint — the property the fault
tolerance design relies on (DESIGN.md §5).

Two generators:
  markov  — order-1 Markov chain with a banded transition matrix plus
            repeated spans (induction patterns): a real learnable signal so
            example training losses visibly fall.
  uniform — iid tokens (for pure-throughput benchmarking).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeCell


def lm_batch_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict:
    from repro.models.model import input_specs
    return input_specs(cfg, cell)


@dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    mode: str = "markov"
    seed: int = 0
    band: int = 64          # markov: next token within +-band of current
    repeat_frac: float = 0.25  # fraction of each row that repeats a prefix

    def _keys(self, step: int):
        k = jax.random.PRNGKey(self.seed)
        return jax.random.fold_in(k, step)

    def batch(self, step: int, shard: int = 0, num_shards: int = 1) -> Dict:
        """Global batch for ``step`` (or this shard's slice of it)."""
        B = self.global_batch // num_shards
        key = jax.random.fold_in(self._keys(step), shard)
        if self.mode == "uniform":
            toks = jax.random.randint(key, (B, self.seq_len + 1), 0,
                                      self.vocab_size, jnp.int32)
        else:
            toks = self._markov(key, B)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def _markov(self, key, B: int) -> jax.Array:
        k1, k2, k3 = jax.random.split(key, 3)
        S = self.seq_len + 1
        start = jax.random.randint(k1, (B,), 0, self.vocab_size, jnp.int32)
        steps = jax.random.randint(k2, (B, S - 1), -self.band, self.band + 1,
                                   jnp.int32)

        def walk(tok, st):
            nxt = jnp.mod(tok + st, self.vocab_size)
            return nxt, nxt

        _, path = jax.lax.scan(walk, start, steps.T)
        toks = jnp.concatenate([start[:, None], path.T], axis=1)
        # repeated span: copy the first span_len tokens to a later offset
        span = max(int(S * self.repeat_frac), 1)
        off = S - span - 1
        toks = toks.at[:, off:off + span].set(toks[:, :span])
        return toks
