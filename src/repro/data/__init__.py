from repro.data.lm import SyntheticLM, lm_batch_specs
from repro.data.streams import CameraStreamPipeline

__all__ = ["SyntheticLM", "lm_batch_specs", "CameraStreamPipeline"]
