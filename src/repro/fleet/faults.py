"""Fault injection, liveness detection, and coverage failover for the fleet.

CrossRoI's premise is to REMOVE cross-camera redundancy: the set-cover
mask assigns each ground region to the cheapest camera that sees it, so
the redundancy that would have masked a camera failure is gone by
design.  When a camera dies, its exclusively-assigned tiles go dark and
the >99% coverage guarantee silently breaks — nothing in the head maps
says so.  This module is the missing failure path, in three layers:

* **Injection** (``FaultSchedule`` / ``FaultInjector``) — a seeded,
  scriptable fault layer that mirrors the ``obs`` discipline: default
  OFF, and when off the chaos drivers are **bit-identical** to
  ``fleet_reuse_step`` / ``sharded_fleet_step`` with ZERO added
  dispatches (``benchmarks/bench_chaos.py`` asserts both).  Faults:
  camera blackout (transport dies, pixels freeze), frozen frame
  (transport lives, pixels freeze), noise corruption, uplink outage
  (zero-bandwidth segments — ``net.links.outage_effective`` keeps the
  FIFO finite), and shard loss on the ``fleet/sharded.py`` path.
* **Detection** (``LivenessMonitor`` here, ``net.batcher.
  HeartbeatMonitor`` at the transport level) — per-camera liveness from
  the delta-gate stats the runtime ALREADY computes (no extra
  dispatches): a camera whose gate goes quiet is only declared dead
  when its own history says it should be moving — historical change
  rate and/or the drift adapter's windowed occupancy
  (``DriftAdapter.occupancy_by_camera``) — so a *frozen* camera is
  distinguished from a *genuinely static* one.
* **Failover** (``failover_resolve``) — on confirmed death, ONE warm
  set-cover re-solve (``setcover.solve_warm``) whose seed and
  constraints EXCLUDE the dead camera: coverage is reassigned to
  surviving overlapping cameras, fanned out through the existing
  ``DriftAdapter.add_mask_listener`` -> ``wire_shard_invalidation``
  path (shard-exact cache invalidation).  Holes no surviving camera
  can cover are reported explicitly — ``uncovered_fraction`` through
  ``obs.metrics`` — never silently zero.  Shard loss reuses the
  detect -> restore idiom of ``distributed.fault.ElasticMesh``: the
  lost shard's groups are cold-marked and the next SPMD step recomputes
  them from scratch.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core import setcover
from repro.core.association import AssociationTable, Region
from repro.obs import metrics as obs_metrics, trace as obs_trace

FAULT_KINDS = ("blackout", "freeze", "noise", "uplink", "shard")


# ---------------------------------------------------------------------------
# fault scripting
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultEvent:
    """One scripted fault over the half-open step interval [t0, t1).

    ``kind``:
    * ``"blackout"`` — camera (gid, cam) stops arriving: pixels freeze
      at the last pre-fault frame AND its transport heartbeat stops.
    * ``"freeze"``   — camera keeps arriving but its content is stuck at
      the last pre-fault frame (encoder wedge / stuck sensor).
    * ``"noise"``    — seeded additive noise of amplitude ``amp`` on the
      camera's frames (corruption; the gate sees it as change).
    * ``"uplink"``   — the camera's uplink bandwidth is 0 over the
      interval (transport-level; map through ``uplink_episodes``).
    * ``"shard"``    — device shard ``shard`` is lost at t0: its cached
      activations are gone (restore = cold recompute next step).
    """
    kind: str
    t0: int
    t1: int
    gid: int = 0
    cam: int = 0
    shard: int = 0
    amp: float = 1.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.t1 <= self.t0:
            raise ValueError(f"fault interval must be non-empty, got "
                             f"[{self.t0}, {self.t1})")

    def active(self, step: int) -> bool:
        return self.t0 <= step < self.t1


@dataclass(frozen=True)
class FaultSchedule:
    """A seeded script of fault events.  ``enabled=False`` (or an empty
    event tuple) is the production configuration: the injector returns
    its inputs UNTOUCHED — same objects, so the fault-free chaos drive
    is bit-identical to the plain drive."""
    events: Tuple[FaultEvent, ...] = ()
    enabled: bool = True

    @property
    def off(self) -> bool:
        return not self.enabled or not self.events

    def active(self, step: int) -> List[FaultEvent]:
        if self.off:
            return []
        return [e for e in self.events if e.active(step)]

    def frame_events(self, step: int) -> List[FaultEvent]:
        return [e for e in self.active(step)
                if e.kind in ("blackout", "freeze", "noise")]

    def shard_starts(self, step: int) -> List[FaultEvent]:
        """Shard-loss events whose outage BEGINS at ``step`` (loss is an
        instantaneous state wipe; the interval models the outage
        window for MTTR accounting)."""
        if self.off:
            return []
        return [e for e in self.events
                if e.kind == "shard" and e.t0 == step]

    @classmethod
    def random(cls, seed: int, n_events: int, steps: int,
               n_groups: int, cams_per_group: int, n_shards: int = 1,
               kinds: Sequence[str] = ("blackout", "freeze", "noise"),
               min_len: int = 2) -> "FaultSchedule":
        """A reproducible random schedule — the chaos-harness axis."""
        rng = np.random.default_rng(seed)
        evs = []
        for _ in range(n_events):
            kind = str(rng.choice(list(kinds)))
            t0 = int(rng.integers(1, max(steps - min_len, 2)))
            t1 = int(min(t0 + rng.integers(min_len, steps), steps))
            evs.append(FaultEvent(
                kind, t0, max(t1, t0 + 1),
                gid=int(rng.integers(n_groups)),
                cam=int(rng.integers(cams_per_group)),
                shard=int(rng.integers(n_shards)),
                amp=float(rng.uniform(0.5, 2.0))))
        return cls(tuple(evs))


class FaultInjector:
    """Applies a ``FaultSchedule`` to per-step fleet frames.

    Disabled (``schedule is None`` or ``schedule.off``) the injector is
    inert: ``apply`` returns the caller's dict UNTOUCHED (the very same
    object, not a copy), so the fault-free path cannot diverge by
    construction.  When a frame fault is active, only the targeted
    cameras' entries are replaced — untouched cameras keep their
    original arrays (object identity), which keeps the delta gate's
    bit-static detection exact for them.
    """

    def __init__(self, schedule: Optional[FaultSchedule], seed: int = 0):
        self.schedule = schedule
        self.seed = seed
        self._retained: Dict[Tuple[int, int], np.ndarray] = {}
        self.injected_steps = 0

    @property
    def off(self) -> bool:
        return self.schedule is None or self.schedule.off

    def blacked_out(self, step: int) -> Set[Tuple[int, int]]:
        """(gid, cam) pairs whose transport is down at ``step`` — the
        heartbeat driver skips their beats."""
        if self.off:
            return set()
        return {(e.gid, e.cam) for e in self.schedule.active(step)
                if e.kind == "blackout"}

    def apply(self, step: int, frames: Dict[int, List]) -> Dict[int, List]:
        if self.off:
            return frames
        events = self.schedule.frame_events(step)
        faulted = {(e.gid, e.cam) for e in events}
        # retain the last CLEAN frame per camera (what a wedged encoder
        # keeps re-emitting) before any replacement happens this step
        for gid, fs in frames.items():
            for cam, f in enumerate(fs):
                if (gid, cam) not in faulted:
                    self._retained[(gid, cam)] = f
        if not events:
            return frames
        self.injected_steps += 1
        out = {gid: list(fs) for gid, fs in frames.items()}
        for e in events:
            cur = out[e.gid][e.cam]
            if e.kind in ("blackout", "freeze"):
                # stuck at the last pre-fault content; first-step faults
                # freeze the initial frame itself
                out[e.gid][e.cam] = self._retained.get(
                    (e.gid, e.cam), cur)
            elif e.kind == "noise":
                rng = np.random.default_rng(
                    (self.seed, e.gid, e.cam, step))
                noisy = np.asarray(cur) + e.amp * rng.normal(
                    size=np.shape(cur)).astype(np.float32)
                out[e.gid][e.cam] = noisy.astype(np.float32)
            obs_metrics.FAULT_EVENTS.inc(1, event="injected")
        return out


def uplink_episodes(schedule: Optional[FaultSchedule], segment_s: float,
                    flat_cam: Dict[Tuple[int, int], int]) -> Tuple:
    """Map the schedule's uplink + blackout events to zero-bandwidth
    ``net.links.CongestionEpisode``s (factor 0.0) over the matching wall
    interval — ``outage_effective`` keeps the FIFO finite through them.
    ``flat_cam`` maps (gid, cam) to the transport window's positional
    camera index."""
    from repro.net.links import CongestionEpisode

    if schedule is None or schedule.off:
        return ()
    eps = []
    for e in schedule.events:
        if e.kind not in ("uplink", "blackout"):
            continue
        pos = flat_cam.get((e.gid, e.cam))
        if pos is None:
            continue
        eps.append(CongestionEpisode(e.t0 * segment_s, e.t1 * segment_s,
                                     0.0, cams=(pos,)))
    return tuple(eps)


def flat_cam_index(grids: Dict[int, List]) -> Dict[Tuple[int, int], int]:
    """(gid, cam) -> fleet-flat camera index, matching the
    ``superlaunch_forward_reuse`` flattening contract (gids in dict
    order, cameras in list order) — the key space of the gate-stats
    camera column (``cache.idx_np[:, 0]``)."""
    flat = {}
    pos = 0
    for gid, gs in grids.items():
        for cam in range(len(gs)):
            flat[(gid, cam)] = pos
            pos += 1
    return flat


# ---------------------------------------------------------------------------
# detection: per-camera liveness from the existing gate stats
# ---------------------------------------------------------------------------

def per_camera_changed(gate_stats, threshold, cam_of_row,
                       n_cameras: int) -> np.ndarray:
    """(n_cameras,) int64 count of gate-changed tiles per fleet-flat
    camera this step — pure host math over the ``tile_delta_gate`` stats
    rows the step already produced (``ReuseStats.gate_stats``); ZERO
    extra dispatches.  ``None`` stats (a cold step) count as all-changed
    (the cold step recomputes everything)."""
    from repro.serving.detector import gate_changed_rows

    cam_of_row = np.asarray(cam_of_row)
    if gate_stats is None:
        return np.bincount(cam_of_row, minlength=n_cameras)
    changed = gate_changed_rows(gate_stats, threshold, cam_of_row)
    return np.bincount(cam_of_row[changed], minlength=n_cameras)


@dataclass
class LivenessConfig:
    freeze_window: int = 4        # quiet steps before a camera is suspect
    # expected-activity floor: confirm death only when the camera's
    # historical change rate (EMA of changed tiles/step, snapshotted at
    # the moment it went quiet) clears this — a camera that was ALWAYS
    # quiet is genuinely static, not frozen
    min_expected_rate: float = 0.5
    ema_alpha: float = 0.3
    # second evidence channel: windowed drift-adapter occupancy (recent
    # appearance-regions seen by the camera).  Either channel suffices —
    # a static-background camera with traffic flowing through it has
    # occupancy evidence even if its own gate history is thin.
    min_occupancy: int = 3


class LivenessMonitor:
    """Frozen-vs-static discrimination from per-camera gate activity.

    Feed ``update`` each step with the per-camera changed-tile counts
    (``per_camera_changed`` over the step's gate stats) and, optionally,
    the drift adapter's ``occupancy_by_camera()``.  A camera is
    *suspect* after ``freeze_window`` consecutive zero-change steps and
    *confirmed dead* only if the evidence says it should have been
    changing: pre-quiet EMA change rate >= ``min_expected_rate`` OR
    windowed occupancy >= ``min_occupancy``.  Cameras that are
    genuinely static (zero historical rate, no occupancy) are never
    confirmed, no matter how long they stay quiet."""

    def __init__(self, n_cameras: int,
                 cfg: Optional[LivenessConfig] = None):
        self.cfg = cfg or LivenessConfig()
        self.n_cameras = n_cameras
        self.streak = np.zeros(n_cameras, np.int64)
        self.ema_rate = np.zeros(n_cameras, np.float64)
        self._quiet_rate = np.zeros(n_cameras, np.float64)
        self.confirmed: Set[int] = set()
        self.confirmed_at: Dict[int, int] = {}
        self.suspect_at: Dict[int, int] = {}
        self.steps = 0

    def update(self, step: int, changed_per_cam: np.ndarray,
               occupancy: Optional[Dict[int, int]] = None,
               flat_of_cam: Optional[Dict[int, int]] = None
               ) -> List[int]:
        """Returns fleet-flat camera indices newly CONFIRMED dead this
        step.  ``occupancy``/``flat_of_cam`` translate the drift
        adapter's cam_id-keyed occupancy into flat indices."""
        cfg = self.cfg
        changed = np.asarray(changed_per_cam, np.float64)
        quiet = changed == 0
        # snapshot the pre-quiet rate the moment a streak starts
        starting = quiet & (self.streak == 0)
        self._quiet_rate = np.where(starting, self.ema_rate,
                                    self._quiet_rate)
        self.streak = np.where(quiet, self.streak + 1, 0)
        self.ema_rate = (1 - cfg.ema_alpha) * self.ema_rate \
            + cfg.ema_alpha * changed
        occ_flat = np.zeros(self.n_cameras, np.float64)
        if occupancy:
            for cam_id, n in occupancy.items():
                f = flat_of_cam[cam_id] if flat_of_cam else cam_id
                if 0 <= f < self.n_cameras:
                    occ_flat[f] = n
        newly: List[int] = []
        for c in np.nonzero(self.streak >= cfg.freeze_window)[0]:
            c = int(c)
            if c in self.confirmed:
                continue
            if c not in self.suspect_at:
                self.suspect_at[c] = step - cfg.freeze_window + 1
            expected = (self._quiet_rate[c] >= cfg.min_expected_rate
                        or occ_flat[c] >= cfg.min_occupancy)
            if expected:
                self.confirmed.add(c)
                self.confirmed_at[c] = step
                obs_metrics.FAULT_EVENTS.inc(1, event="detected")
                newly.append(c)
        # recovery: a camera that changes again is alive
        for c in np.nonzero(~quiet)[0]:
            c = int(c)
            self.suspect_at.pop(c, None)
            if c in self.confirmed:
                self.confirmed.discard(c)
                self.confirmed_at.pop(c, None)
                obs_metrics.FAULT_EVENTS.inc(1, event="restored")
        self.steps += 1
        return newly

    def detect_latency_steps(self, cam: int, fault_t0: int) -> int:
        """Steps from fault onset to confirmation (-1 if never)."""
        if cam not in self.confirmed_at:
            return -1
        return self.confirmed_at[cam] - fault_t0


# ---------------------------------------------------------------------------
# failover: warm re-solve excluding the dead camera
# ---------------------------------------------------------------------------

@dataclass
class FailoverEvent:
    t: int                          # step the failover fired
    dead_cams: Tuple[int, ...]      # cam_ids excluded from the solve
    tiles_dropped: int              # dead-camera tiles removed from mask
    tiles_added: int                # surviving-camera tiles the re-solve
    #                                 assigned to take over coverage
    constraints: int                # window constraints handed to solver
    uncoverable: int                # of those, constraints NO surviving
    #                                 camera can cover (the hole)
    uncovered_fraction: float       # uncoverable / constraints
    wall_s: float


def _tile_owner(universe, tiles) -> np.ndarray:
    """Owning camera of each global tile id (prefix-offset decode)."""
    g = np.asarray(sorted(tiles), np.int64)
    if g.size == 0:
        return np.zeros(0, np.int64)
    return np.searchsorted(universe.offsets, g, side="right") - 1


def failover_resolve(adapter, dead_cams: Sequence[int], t: int
                     ) -> FailoverEvent:
    """ONE warm set-cover re-solve that routes a dead camera's coverage
    to surviving overlapping cameras.

    Unlike the drift path, the deployed mask canNOT be the seed
    unmodified — ``solve_warm`` never retracts its seed, and the whole
    point is to retract the dead camera's tiles.  So: (1) the seed is
    the deployed mask MINUS tiles owned by ``dead_cams``; (2) the
    window's buffered constraints are filtered to surviving-camera
    regions only, so greedy completion cannot choose a dead tile; (3)
    constraints with NO surviving region are counted as *uncoverable*
    and reported (``uncovered_fraction`` gauge + the returned event) —
    degraded mode is explicit, never silent.  The mask mutation fans out
    through ``adapter._notify_mask_update()`` — the same listener chain
    (``wire_shard_invalidation``) drift re-solves use, so shard caches
    are invalidated exactly once for exactly the owning shard."""
    wall0 = time.time()
    dead = set(int(c) for c in dead_cams)
    cov_before = adapter.coverage()
    with obs_trace.span("failover_resolve", t=t, dead=len(dead)):
        mask_tiles = np.asarray(sorted(adapter.mask), np.int64)
        owners = _tile_owner(adapter.universe, mask_tiles)
        dead_rows = np.isin(owners, list(dead)) if dead else \
            np.zeros(owners.shape, bool)
        seed = set(int(g) for g in mask_tiles[~dead_rows])
        dropped = int(np.count_nonzero(dead_rows))

        constraints: List[List[Region]] = []
        keys: List[Tuple[int, int]] = []
        uncoverable = 0
        total = 0
        for tt, obj, regions in adapter._regions:
            total += 1
            surv = [Region(c, adapter.universe.globalize(c, tiles))
                    for c, tiles in sorted(regions.items())
                    if c not in dead]
            if not surv:
                if any(c in dead for c in regions):
                    uncoverable += 1
                continue
            constraints.append(surv)
            keys.append((tt, obj))
        table = AssociationTable(adapter.universe, constraints, keys)
        res = setcover.solve_warm(table, seed)
        added = len(res.mask) - len(seed)
        adapter.mask = set(res.mask)
        for c in adapter.cameras:
            adapter.cam_grids[c.cam_id] = adapter.universe.cam_mask_grid(
                c.cam_id, adapter.mask)
    wall = time.time() - wall0
    frac = uncoverable / max(total, 1)
    obs_metrics.FAULT_EVENTS.inc(1, event="failover")
    obs_metrics.UNCOVERED_FRACTION.set(frac)
    obs_metrics.DRIFT_RESOLVE_WALL.observe(wall)
    ev = FailoverEvent(t, tuple(sorted(dead)), dropped, added,
                       len(constraints), uncoverable, frac, wall)
    # bookkeeping mirrors a drift re-solve: the window measured the old
    # mask; cooldown restarts; listeners see the final state once
    adapter._last_resolve_t = t
    adapter._breach_start = None
    adapter._window.clear()
    adapter.residual_counts.clear()
    adapter._notify_mask_update()
    return ev


def degraded_coverage(adapter, detections, dead_cams: Sequence[int]
                     ) -> Tuple[int, int, int]:
    """(covered, coverable, total) ground-truth appearance coverage
    under the CURRENT mask counting only SURVIVING cameras — the
    per-step ``uncovered_fraction`` evidence the chaos harness reports.

    ``coverable`` counts objects at least one surviving camera SEES:
    failover is judged on covered/coverable (reassignable coverage it
    must restore), while total - coverable is the GENUINE hole — objects
    whose only observer died, which no re-solve can fix and which must
    be reported, never silently folded into a denominator.  Uses the
    adapter's own ``_covered`` criterion, so pre-fault (no dead cams)
    covered/total agrees with the drift monitor's coverage exactly."""
    dead = set(int(c) for c in dead_cams)
    by_obj: Dict[int, List] = {}
    for d in detections:
        by_obj.setdefault(d.obj, []).append(d)
    covered = coverable = 0
    for ds in by_obj.values():
        surv = [d for d in ds if d.cam not in dead]
        if surv:
            coverable += 1
        if any(adapter._covered(d) for d in surv):
            covered += 1
    return covered, coverable, len(by_obj)


# ---------------------------------------------------------------------------
# shard loss (detect -> restore on the sharded serving path)
# ---------------------------------------------------------------------------

def shard_failover(runtime, cache, shard: int) -> List[int]:
    """Lose one device shard's serving state: cold-mark every group the
    shard owns (``ShardedActivationCache.invalidate_group``).  The next
    ``sharded_fleet_step`` recomputes those groups from scratch inside
    the SAME SPMD program — that recompute IS the restore
    (``distributed.fault.ElasticMesh``'s detect -> restore idiom applied
    to serving state; there is no checkpoint to reload because packed
    activations are derived state).  Returns the affected gids."""
    gids = runtime.groups_on_shard(shard)
    for gid in gids:
        cache.invalidate_group(gid)
    obs_metrics.FAULT_EVENTS.inc(1, event="shard_lost")
    return list(gids)


# ---------------------------------------------------------------------------
# chaos drivers (production loops + optional fault/liveness hooks)
# ---------------------------------------------------------------------------

def drive_chaos(det, frames_list: Sequence[Dict[int, List]],
                grids: Dict[int, List[np.ndarray]], cache,
                threshold: float = 0.0, qstep: float = 8.0,
                schedule: Optional[FaultSchedule] = None,
                monitor: Optional[LivenessMonitor] = None,
                heartbeat=None, keep_outputs: bool = False,
                seed: int = 0):
    """``obs.loadgen.drive_fleet`` with the fault layer in front.

    With ``schedule`` None/off and no monitor this IS ``drive_fleet``:
    the injector returns the caller's frames untouched and no extra
    work runs — bit-identical outputs, identical dispatch Counter
    (asserted by ``run.py --chaos``).  With faults on, each step is
    (1) inject, (2) the production ``fleet_reuse_step``, (3) feed the
    liveness monitor from the step's OWN gate stats and the heartbeat
    from arrival bookkeeping — still zero added dispatches.

    Returns (reports, outputs, total dispatch Counter, detections:
    {step: [newly confirmed flat cams]})."""
    from repro.fleet.runtime import fleet_reuse_step
    from repro.obs.slo import StepReport

    inj = FaultInjector(schedule, seed=seed)
    flat = flat_cam_index(grids)
    n_cams = len(flat)
    reports: List = []
    outputs = []
    detections: Dict[int, List[int]] = {}
    total: collections.Counter = collections.Counter()
    for i, frames in enumerate(frames_list):
        frames = inj.apply(i, frames)
        t0 = time.perf_counter()
        outs, counts, stats = fleet_reuse_step(det, frames, grids, cache,
                                               threshold, qstep)
        reports.append(StepReport.from_reuse(
            i, time.perf_counter() - t0, counts, stats))
        total += counts
        if keep_outputs:
            outputs.append(outs)
        if heartbeat is not None:
            dark = inj.blacked_out(i)
            for (gid, cam), f in flat.items():
                if (gid, cam) not in dark:
                    heartbeat.beat(float(i), f)
            heartbeat.poll(float(i))
        if monitor is not None and stats.gate_stats is not None:
            # cold steps recompute everything and carry no per-camera
            # delta evidence — feeding them as "all changed" would
            # poison a genuinely static camera's expected-rate history
            changed = per_camera_changed(
                stats.gate_stats, threshold, cache.idx_np[:, 0], n_cams)
            newly = monitor.update(i, changed)
            if newly:
                detections[i] = newly
    return reports, outputs, total, detections


def drive_chaos_sharded(runtime, frames_list: Sequence[Dict[int, List]],
                        cache, threshold: float = 0.0,
                        schedule: Optional[FaultSchedule] = None,
                        keep_outputs: bool = False, seed: int = 0):
    """``obs.loadgen.drive_sharded`` with fault injection + shard loss.

    Shard-loss events fire at their ``t0`` BEFORE that step runs: the
    owning groups are cold-marked and the step itself performs the
    restore (cold recompute inside the same SPMD program — the per-shard
    dispatch ceiling holds throughout, asserted every step by
    ``sharded_fleet_step``).  Fault-free: bit-identical to
    ``drive_sharded``, zero added dispatches.

    Returns (reports, outputs, total Counter, lost: {step: [gids]})."""
    from repro.fleet.runtime import sharded_fleet_step
    from repro.obs.slo import StepReport

    inj = FaultInjector(schedule, seed=seed)
    reports: List = []
    outputs = []
    lost: Dict[int, List[int]] = {}
    total: collections.Counter = collections.Counter()
    for i, frames in enumerate(frames_list):
        frames = inj.apply(i, frames)
        if schedule is not None:
            for e in schedule.shard_starts(i):
                gids = shard_failover(runtime, cache, e.shard)
                lost.setdefault(i, []).extend(gids)
        t0 = time.perf_counter()
        outs, counts, stats = sharded_fleet_step(runtime, frames, cache,
                                                 threshold)
        reports.append(StepReport.from_reuse(
            i, time.perf_counter() - t0, counts, stats))
        total += counts
        if keep_outputs:
            outputs.append(outs)
    return reports, outputs, total, lost
