"""City-scale sharded serving: the super-launch over a device mesh.

``cross_group_leakage == 0`` by construction makes camera groups an
embarrassingly parallel axis: no tile's halo, neighbor table or scatter
target ever crosses a group boundary, so partitioning groups over a 1-D
``jax.sharding.Mesh`` (``launch.mesh.make_fleet_mesh``) needs ZERO
cross-device collectives on the hot path.  ``ShardedSuperlaunch`` is the
fleet runtime's super-launch (``RoIDetector.superlaunch_forward_reuse``)
rebuilt as ONE ``compat.shard_map`` SPMD program over stacked per-shard
state:

* **Placement-free tables + a shard plan.**  ``ops.superlaunch_tables``
  emits flat tables for any group subset; ``ops.shard_plan`` assigns
  groups to shards balanced by ACTIVE-TILE count (LPT greedy — one busy
  intersection cannot straggle a shard).  Per-shard tables are padded to
  a common power-of-two row count with SACRIFICIAL rows: padding rows
  index a zero camera slot appended to every shard's frame stack
  (``idx = (F_max, 0, 0)``, ``nbr = -1``), so ragged shards — including
  entirely empty ones — run the same SPMD program and padding work can
  never corrupt a real output.
* **Per-shard dispatch ceiling.**  Each step is one gate launch plus a
  ≤3-dispatch conv chain (entry, layer-stack megakernel, changed-only
  canvas scatter) — each counted ONCE per step via
  ``ops.record_dispatch`` because SPMD means the single traced program
  IS the per-shard program: one dispatch runs the kernel once on every
  shard.  An ALL-STATIC step is the gate alone: the persistent head
  canvas is served as-is — zero conv/scatter launches, 0 bytes written.
* **Bit-identity.**  Every per-tile quantity (gate stats, entry/stack
  GEMMs, scatter, head matmul) reduces only over its own tile's inputs,
  so re-partitioning tiles across shards cannot change bits: each
  group's head maps are bit-identical to the single-device
  ``superlaunch_forward_reuse`` on the same trace (asserted by
  tests/test_sharded.py and benchmarks/bench_shard.py).
* **Sharded cache + persistent canvas + per-shard invalidation.**  The
  packed activations, the persistent HEAD-MAP CANVAS ((S, F_max + 1, H,
  W, A) — warm steps scatter only changed tiles' head rows into it,
  padding/margin rows land on the sacrificial camera plane) and the
  canvas-resident gate references ((S, F_max + 1, H + 2, W + 2, 3) with
  a host-side (S, n_max) refresh-epoch table) live in a
  ``ShardedActivationCache``, shard axis over the mesh.  A drift
  re-solve invalidates ONLY the owning shard
  (``drift.wire_shard_invalidation``); the next step wipes that shard's
  canvas plane in-program and recomputes its rows while the others keep
  serving warm — cold and warm shards share the one SPMD program (a
  cold shard's rows are simply all marked raw-changed host-side), so
  canvas invalidation is shard-exact.

``AsyncShardedPipeline`` overlaps the host and the device: the gate for
step t is dispatched BEFORE the conv for step t-1, so pulling the gate
stats blocks only on the gate and the host-side thresholding /
``reuse_sets`` dilation / table compaction for step t runs WHILE the
device executes step t-1's conv chain (double-buffered table slots keep
the in-flight step's tables alive; the cache buffers are donated into
each conv dispatch).  ``jax.block_until_ready`` happens only at the
consumer edge (``collect``); the measured host/device overlap fraction
is a first-class output.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.distributed.shardings import fleet_state_sharding
from repro.kernels import ops as kops
from repro.kernels.roi_conv import (roi_conv_entry as _raw_entry,
                                    roi_conv_stack as _raw_stack)
from repro.kernels.sbnet import (sbnet_scatter_changed as
                                 _raw_scatter_changed)
from repro.kernels.tile_delta import (COEF_BITS, RUN_BITS,
                                      tile_delta_gate_canvas as
                                      _raw_gate_canvas)
from repro.launch.mesh import FLEET_AXIS
from repro.obs import trace as obs_trace
from repro.serving.detector import (ShardedActivationCache,
                                    gate_changed_rows, ref_advance_rows,
                                    tile_class_rows)


def _pow2(n: int) -> int:
    """Smallest power of two >= max(n, 1) — the shape-bucketing rule the
    single-device compact path uses, applied per shard dimension."""
    p = 1
    while p < n:
        p *= 2
    return p


@dataclass
class ShardedReuseStats:
    """Per-step accounting of one sharded fleet step (fleet-wide sums;
    ``launched`` counts every convolved row on every shard, padding
    included — honest SPMD accounting: all shards convolve ``k_max``
    rows whenever any shard needs one)."""
    total_tiles: int
    raw_changed: int
    changed_out: int
    computed: int                 # real compact-set tiles, summed
    launched: int                 # S * k_max when the conv launched
    k_max: int                    # per-shard convolved rows this step
    cold_shards: int              # shards that ran a forced recompute
    # bytes scattered into the persistent head canvas this step (real
    # changed-out tiles only; sacrificial-plane padding/margin writes
    # are not counted).  0 on an all-static step — no scatter launch.
    canvas_bytes: int = 0
    per_shard_computed: List[int] = field(default_factory=list)
    # per-shard gate stats over REAL rows (None for cold shards, whose
    # reference content was stale) — feed per-camera slices to
    # net.encoder.static_fraction_from_stats, same shared-dispatch
    # contract as the single-device path
    gate_stats: Optional[List[Optional[np.ndarray]]] = None

    @property
    def cold(self) -> bool:
        return self.cold_shards > 0


@dataclass
class _HostPlan:
    """One step's host-side compaction product (the work the async
    pipeline overlaps with the previous step's device compute)."""
    k_max: int                    # 0 = all-static: gate-only step (the
    #                               persistent canvas is served as-is)
    cidx: Optional[np.ndarray]    # (S, k_max, 3) compact tables
    cnbr: Optional[np.ndarray]    # (S, k_max, 8)
    upd: Optional[np.ndarray]     # (S, k_max) cache row targets (n_max=drop)
    sidx: Optional[np.ndarray]    # (S, k_max, 3) canvas scatter targets:
    #                               changed rows keep their (cam, ty, tx),
    #                               margin/padding rows hit the
    #                               sacrificial camera plane (F_max, 0, 0)
    adv: np.ndarray               # (S, n_max) reference-advance mask
    cold_mask: np.ndarray         # (S,) shards whose canvas plane must be
    #                               wiped to zeros before this step's
    #                               scatter (shard-exact invalidation)
    stats: ShardedReuseStats


class ShardedSuperlaunch:
    """Sharded fleet runtime for a fixed group->shard plan.

    frames/grids are keyed by gid exactly like
    ``RoIDetector.superlaunch_forward_reuse``; the plan (built here via
    ``ops.shard_plan`` unless given) stays valid until a mask re-solve
    calls ``rebuild_group``."""

    def __init__(self, det, grids: Dict[int, List[np.ndarray]], mesh,
                 plan: Optional[kops.ShardPlan] = None):
        self.det = det
        self.mesh = mesh
        self.gids = list(grids)
        self.grids = {g: list(gs) for g, gs in grids.items()}
        n_shards = mesh.shape[FLEET_AXIS]
        self.plan = plan or kops.shard_plan(
            [self.grids[g] for g in self.gids], n_shards)
        if self.plan.n_shards != n_shards:
            raise ValueError(
                f"plan has {self.plan.n_shards} shards, mesh {n_shards}")
        self.sharding = fleet_state_sharding(mesh)
        t = det.cfg.tile
        # canvas: global maxima so head shapes agree across shards (the
        # single-device _stack_frames rule, applied fleet-wide)
        self.canvas_h = max(g.shape[0] * t for gs in self.grids.values()
                            for g in gs)
        self.canvas_w = max(g.shape[1] * t for gs in self.grids.values()
                            for g in gs)
        self._build_tables()
        self._fns: Dict = {}          # jitted shard_map programs

    # -- table construction ------------------------------------------------
    def _build_tables(self) -> None:
        S = self.plan.n_shards
        self._shard_gids = [[self.gids[i] for i in self.plan.shard_groups(s)]
                            for s in range(S)]
        self._idx_np, self._nbr_np, self._n_s, self._F_s = [], [], [], []
        self._group_slot: Dict[int, Tuple[int, int]] = {}
        for s in range(S):
            gs = [self.grids[g] for g in self._shard_gids[s]]
            idx, nbr, _, cam_starts = kops.superlaunch_tables(gs)
            self._idx_np.append(np.asarray(idx))
            self._nbr_np.append(np.asarray(nbr))
            self._n_s.append(int(idx.shape[0]))
            self._F_s.append(int(sum(len(g) for g in gs)))
            for j, gid in enumerate(self._shard_gids[s]):
                self._group_slot[gid] = (s, int(cam_starts[j]))
        self.F_max = max(self._F_s + [1])
        self.n_max = _pow2(max(self._n_s + [1]))
        self.n_total = int(sum(self._n_s))
        # stacked padded tables: padding rows target the SACRIFICIAL zero
        # camera slot F_max (frames carry F_max + 1 slots), neighbors -1
        idx_pad = np.zeros((S, self.n_max, 3), np.int32)
        idx_pad[:, :, 0] = self.F_max
        for s in range(S):
            idx_pad[s, :self._n_s[s]] = self._idx_np[s]
        self._idx_pad_np = idx_pad
        self.idx_pad = jax.device_put(jnp.asarray(idx_pad), self.sharding)
        # per-shard tile classes (body vs halo/boundary rows) for the
        # per-tile-class gate-threshold schedule
        self._cls_np = [tile_class_rows(nbr) for nbr in self._nbr_np]
        self._fns = {}

    def make_cache(self) -> ShardedActivationCache:
        return ShardedActivationCache(self.plan, gids=self.gids)

    def groups_on_shard(self, shard: int) -> List[int]:
        """Group ids placed on ``shard`` — the blast radius of losing
        that shard.  The fault layer walks this to cold-mark every owned
        group (``cache.invalidate_group``); the next step then
        recomputes them from scratch, which IS the restore path (the
        detect -> restore idiom of ``distributed.fault.ElasticMesh``,
        applied to serving state instead of training state)."""
        return list(self._shard_gids[shard])

    def rebuild_group(self, gid: int, new_grids: Sequence[np.ndarray],
                      cache: Optional[ShardedActivationCache] = None
                      ) -> None:
        """Adopt a re-solved mask for one group: rebuild ONLY the owning
        shard's tables (the shard is already cold via
        ``invalidate_group``); other shards' tables, cache rows and
        reference windows survive untouched.  If the new mask overflows
        the shared row bucket, ``n_max`` grows and every shard's stacked
        arrays are re-padded — warm rows are preserved, so growth does
        not cost the other shards a recompute."""
        t = self.det.cfg.tile
        for g in new_grids:
            if g.shape[0] * t > self.canvas_h or \
                    g.shape[1] * t > self.canvas_w:
                raise ValueError("re-solved grid exceeds the built canvas")
        self.grids[gid] = list(new_grids)
        old_n_max, old_f_max = self.n_max, self.F_max
        self._build_tables()
        if cache is None or cache.packed is None:
            return
        if self.F_max != old_f_max:
            # camera-axis shape changed: the stacked canvases cannot be
            # row-preserved — drop them (every shard reseeds next step)
            cache.packed = None
            cache.ref_canvas = None
            cache.canvas = None
            cache.epoch_np = None
            cache.valid[:] = False
            return
        if self.n_max != old_n_max:
            pad = self.n_max - old_n_max

            def repad(a, n_extra_dims):
                a = np.asarray(a)
                if pad > 0:
                    widths = ((0, 0), (0, pad)) + ((0, 0),) * n_extra_dims
                    return np.pad(a, widths)
                return a[:, :self.n_max]

            cache.packed = jax.device_put(
                jnp.asarray(repad(cache.packed, 3)), self.sharding)
            if cache.epoch_np is not None:
                cache.epoch_np = repad(cache.epoch_np, 0)
        # shard-exact canvas invalidation: the owning shard is already
        # cold (invalidate_group); zero its canvas plane host-side too,
        # so tiles the re-solve REMOVED cannot leak stale head bytes
        # (the in-program cold wipe covers the normal case, but a shard
        # rebuilt to an empty mask never reaches the conv dispatch)
        s = cache.owner_shard(gid)
        if cache.canvas is not None:
            cache.canvas = jax.device_put(
                jnp.asarray(cache.canvas).at[s].set(0.0), self.sharding)

    # -- step building blocks ---------------------------------------------
    def _shard_map(self, f, n_in: int, n_out: int, donate=()):
        spec = jax.sharding.PartitionSpec(FLEET_AXIS)
        sm = compat.shard_map(f, mesh=self.mesh, in_specs=(spec,) * n_in,
                              out_specs=(spec,) * n_out if n_out > 1
                              else spec)
        return jax.jit(sm, donate_argnums=donate)

    def _ingest(self, frames: Dict[int, List]) -> jax.Array:
        """Stack per-shard frames onto the common canvas: (S, F_max + 1,
        H, W, 3), slot F_max the sacrificial zero camera."""
        S = self.plan.n_shards
        x = np.zeros((S, self.F_max + 1, self.canvas_h, self.canvas_w, 3),
                     np.float32)
        for gid in self.gids:
            s, c0 = self._group_slot[gid]
            for i, f in enumerate(frames[gid]):
                f = np.asarray(f, np.float32)
                if f.shape[0] > self.canvas_h or f.shape[1] > self.canvas_w:
                    raise ValueError(
                        f"frame {f.shape[:2]} exceeds the grid-derived "
                        f"canvas ({self.canvas_h}, {self.canvas_w})")
                x[s, c0 + i, :f.shape[0], :f.shape[1]] = f
        return jax.device_put(jnp.asarray(x), self.sharding)

    def _gate_fn(self):
        key = ("gate",)
        if key not in self._fns:
            det, t = self.det, self.det.cfg.tile

            def local(x, ref, idx):
                xp = jnp.pad(x[0], ((0, 0), (1, 1), (1, 1), (0, 0)))
                # canvas-resident references: the comparison side is the
                # shard's padded reference canvas, addressed through the
                # same tile rows — no packed window duplication, stats
                # rows are the only output
                stats = _raw_gate_canvas(
                    xp, ref[0], idx[0], t, t, 8.0, COEF_BITS, RUN_BITS,
                    block=det.block, interpret=kops.INTERPRET)
                return stats[None]

            self._fns[key] = self._shard_map(local, 3, 1)
        return self._fns[key]

    def _conv_fn(self, k_max: int):
        key = ("conv", k_max)
        if key not in self._fns:
            det, t = self.det, self.det.cfg.tile
            w0, ws, head = det.weights[0], det.weights[1:], det.head

            def local(x, cidx, cnbr, upd, sidx, wipe, packed, canvas):
                p = _raw_entry(x[0], w0, cidx[0], t, t,
                               block=det.chain_block,
                               interpret=kops.INTERPRET)
                if ws:
                    p = _raw_stack(p, tuple(ws), cnbr[0], block=det.block,
                                   interpret=kops.INTERPRET)
                # only changed-OUTPUT rows graduate; margin and padding
                # rows carry target n_max and drop out of bounds
                new_packed = packed[0].at[upd[0]].set(p, mode="drop")
                # head applied PRE-scatter (bit-identical: per-pixel dot
                # products), then ONLY this step's rows hit the
                # persistent canvas — changed rows at their real
                # (cam, ty, tx), margin/padding rows on the sacrificial
                # camera plane.  A cold shard's plane is wiped to zeros
                # first (shard-exact canvas invalidation, in-program)
                k, C = p.shape[0], p.shape[-1]
                ph = (p.reshape(k * t * t, C) @ head).reshape(
                    k, t, t, head.shape[-1])
                base = jnp.where(wipe[0][0], jnp.zeros_like(canvas[0]),
                                 canvas[0])
                new_canvas = _raw_scatter_changed(
                    ph, sidx[0], base, block=det.chain_block,
                    interpret=kops.INTERPRET)
                return new_packed[None], new_canvas[None]

            # donate the cache's packed buffer (argument 6): the update
            # writes in place of the old activations.  The canvas
            # (argument 7) is NOT donated here: the async pipeline's
            # collect() reads the previous step's heads — which ARE the
            # previous canvas buffer — after this dispatch is queued
            # (real-TPU canvas donation is a carried ROADMAP item)
            self._fns[key] = self._shard_map(local, 8, 2, donate=(6,))
        return self._fns[key]

    def _refadv_fn(self):
        key = ("refadv",)
        if key not in self._fns:

            def local(ref, x, mask):
                xp = jnp.pad(x[0], ((0, 0), (1, 1), (1, 1), (0, 0)))
                return jnp.where(mask[0], xp, ref[0])[None]

            # pure jnp reference advancement (not a counted kernel
            # dispatch, like ops.gather_windows): advanced rows' full
            # window regions take the current frame's content (all
            # writes carry the SAME frame, so window overlap between
            # simultaneously-advanced tiles is harmless); donates the
            # old reference canvas
            self._fns[key] = self._shard_map(local, 3, 1, donate=(0,))
        return self._fns[key]

    def _init_cache_arrays(self, cache: ShardedActivationCache) -> None:
        if cache.packed is not None:
            return
        S, t = self.plan.n_shards, self.det.cfg.tile
        c_last = self.det.cfg.channels[-1]
        a = self.det.head.shape[-1]
        cache.packed = jax.device_put(
            jnp.zeros((S, self.n_max, t, t, c_last), jnp.float32),
            self.sharding)
        cache.ref_canvas = jax.device_put(
            jnp.zeros((S, self.F_max + 1, self.canvas_h + 2,
                       self.canvas_w + 2, 3), jnp.float32), self.sharding)
        cache.canvas = jax.device_put(
            jnp.zeros((S, self.F_max + 1, self.canvas_h, self.canvas_w,
                       a), jnp.float32), self.sharding)
        cache.epoch_np = np.zeros((S, self.n_max), np.int64)
        cache.valid[:] = False

    def _host_plan(self, stats_np: np.ndarray,
                   cache: ShardedActivationCache,
                   threshold=0.0) -> _HostPlan:
        """Gate thresholding + ``reuse_sets`` dilation + table
        compaction for every shard — all host-side numpy on static
        tables (the phase the async pipeline overlaps with device
        compute).  ``threshold``: scalar, or {gid: per-camera (F_g,) or
        per-camera-per-tile-class (F_g, N_TILE_CLASSES) array} (the rate
        controller's schedule; see ``gate_threshold_schedule``)."""
        S = self.plan.n_shards
        n_layers = self.det.num_conv_layers
        per_changed, per_compute = [], []
        raw_total = changed_total = computed_total = 0
        cold_shards = 0
        gate_stats: List[Optional[np.ndarray]] = []
        thr_by_shard = self._shard_thresholds(threshold)
        for s in range(S):
            n_s = self._n_s[s]
            if n_s == 0:
                per_changed.append(np.zeros(0, bool))
                per_compute.append(np.zeros(0, bool))
                gate_stats.append(None)
                continue
            rows = stats_np[s, :n_s]
            if cache.valid[s]:
                raw = np.asarray(gate_changed_rows(
                    rows, thr_by_shard[s], self._idx_np[s][:, 0],
                    self._cls_np[s]), bool)
                gate_stats.append(rows)
            else:
                # cold shard: reference content is stale — force a full
                # recompute of its rows inside the same SPMD step
                raw = np.ones(n_s, bool)
                gate_stats.append(None)
                cold_shards += 1
            changed, compute = kops.reuse_sets(raw, self._nbr_np[s],
                                               n_layers)
            per_changed.append(changed)
            per_compute.append(compute)
            raw_total += int(raw.sum())
            changed_total += int(changed.sum())
            computed_total += int(compute.sum())
        k_max = _pow2(max([int(c.sum()) for c in per_compute] + [0])) \
            if computed_total else 0
        adv = np.zeros((S, self.n_max), bool)
        for s in range(S):
            n_s = self._n_s[s]
            if n_s == 0:
                continue
            if not cache.valid[s]:
                adv[s, :n_s] = True
                continue
            a = ref_advance_rows(thr_by_shard[s], self._idx_np[s][:, 0],
                                 per_changed[s], self._cls_np[s])
            adv[s, :n_s] = True if a is None else a
        cold_mask = ~np.asarray(cache.valid, bool)
        t = self.det.cfg.tile
        tile_bytes = t * t * int(self.det.head.shape[-1]) * 4
        stats = ShardedReuseStats(
            total_tiles=self.n_total, raw_changed=raw_total,
            changed_out=changed_total, computed=computed_total,
            launched=S * k_max if k_max else 0, k_max=k_max,
            cold_shards=cold_shards,
            canvas_bytes=changed_total * tile_bytes,
            per_shard_computed=[int(c.sum()) for c in per_compute],
            gate_stats=gate_stats)
        if k_max == 0:
            return _HostPlan(0, None, None, None, None, adv, cold_mask,
                             stats)
        cidx = np.zeros((S, k_max, 3), np.int32)
        cidx[:, :, 0] = self.F_max                 # sacrificial padding
        cnbr = np.full((S, k_max, 8), -1, np.int32)
        upd = np.full((S, k_max), self.n_max, np.int32)   # n_max = drop
        sidx = np.zeros((S, k_max, 3), np.int32)
        sidx[:, :, 0] = self.F_max                 # sacrificial plane
        for s in range(S):
            compute = per_compute[s]
            k = int(compute.sum())
            if k == 0:
                continue
            ci, cn = kops.compact_tables(self._idx_np[s], self._nbr_np[s],
                                         compute)
            cidx[s, :k] = ci
            cnbr[s, :k] = cn
            slots = np.nonzero(compute)[0]
            ch = per_changed[s][slots]
            upd[s, :k] = np.where(ch, slots, self.n_max).astype(np.int32)
            # canvas targets: only changed-OUTPUT rows write their real
            # tile; margin rows keep the cache's (still-exact) old bytes
            # by writing the sacrificial plane instead
            sidx[s, :k] = np.where(ch[:, None], ci,
                                   np.array([[self.F_max, 0, 0]],
                                            np.int32))
        return _HostPlan(k_max, cidx, cnbr, upd, sidx, adv, cold_mask,
                         stats)

    def _shard_thresholds(self, threshold) -> List:
        """Resolve the scalar / {gid: per-camera or per-camera-per-
        tile-class} threshold into one scalar, (F_s,) or
        (F_s, n_classes) value per shard, flat-camera indexed."""
        if not isinstance(threshold, dict):
            return [threshold] * self.plan.n_shards
        vals = {g: np.asarray(v, np.float64) for g, v in threshold.items()}
        n_cls = max([v.shape[1] for v in vals.values() if v.ndim == 2],
                    default=0)
        out = []
        for s in range(self.plan.n_shards):
            shape = (max(self._F_s[s], 1),) + ((n_cls,) if n_cls else ())
            thr = np.zeros(shape, np.float64)
            for gid in self._shard_gids[s]:
                if gid in vals:
                    _, c0 = self._group_slot[gid]
                    v = vals[gid]
                    if n_cls and v.ndim == 1:
                        v = np.repeat(v[:, None], n_cls, axis=1)
                    thr[c0:c0 + v.shape[0]] = v
            out.append(thr)
        return out

    def _put_tables(self, plan: _HostPlan, parity: int):
        """Stage one step's compact tables into a device slot.  Two
        slots alternate (``parity``): the PREVIOUS step's tables stay
        referenced while its conv chain is still in flight, so staging
        step t+1 can never free buffers step t is reading.  The canvas
        slots ride the same double-buffer discipline: the conv returns a
        fresh canvas buffer each step (no donation — collect() may still
        read the old one), so the in-flight step's heads stay alive."""
        slot = jax.device_put(
            (jnp.asarray(plan.cidx), jnp.asarray(plan.cnbr),
             jnp.asarray(plan.upd), jnp.asarray(plan.sidx),
             jnp.asarray(plan.cold_mask[:, None])), self.sharding)
        if not hasattr(self, "_table_slots"):
            self._table_slots: List = [None, None]
        self._table_slots[parity % 2] = slot
        return slot

    def _adv_canvas_mask(self, adv: np.ndarray) -> np.ndarray:
        """(S, n_max) advance-row mask -> bool (S, F_max + 1, H + 2,
        W + 2, 1) canvas mask over the advanced rows' haloed window
        regions (host-built from the static tables; broadcasts over
        channels)."""
        t = self.det.cfg.tile
        S = self.plan.n_shards
        m = np.zeros((S, self.F_max + 1, self.canvas_h + 2,
                      self.canvas_w + 2, 1), bool)
        for s in range(S):
            for cam, ty, tx in self._idx_np[s][adv[s, :self._n_s[s]]]:
                m[s, cam, ty * t:ty * t + t + 2,
                  tx * t:tx * t + t + 2, 0] = True
        return m

    def _advance_refs(self, cache: ShardedActivationCache, x,
                      plan: _HostPlan) -> None:
        """Advance the reference canvas + epoch table per the plan's
        (S, n_max) advance mask."""
        if not plan.adv.any():
            return
        mask = jax.device_put(
            jnp.asarray(self._adv_canvas_mask(plan.adv)), self.sharding)
        cache.ref_canvas = self._refadv_fn()(cache.ref_canvas, x, mask)
        cache.epoch_np[plan.adv] = cache.steps

    # -- synchronous steps -------------------------------------------------
    def step_reuse(self, frames: Dict[int, List],
                   cache: ShardedActivationCache, threshold=0.0):
        """One sharded delta-gated fleet step, blocking at the end.

        Dispatch structure (counted once per step — SPMD: one launch
        runs on every shard): 1 gate + the ≤3-dispatch conv chain
        (entry, stack, changed-only canvas scatter) on changed steps;
        the gate ALONE on all-static steps — the persistent canvas is
        served as-is, zero conv/scatter launches, 0 bytes written;
        nothing on an all-empty fleet.  NOTE the sharded path gates on
        cold shards too (SPMD uniformity — the single-device cold step
        skips the gate instead); outputs stay bit-identical.  Returns
        ({gid: per-camera head maps (numpy)}, ShardedReuseStats)."""
        if cache.plan is not self.plan:
            raise ValueError("cache was built for a different shard plan")
        cache.steps += 1
        cache.total_tiles += self.n_total
        if self.n_total == 0:
            return self._zero_heads(frames), ShardedReuseStats(
                0, 0, 0, 0, 0, 0, 0)
        self._init_cache_arrays(cache)
        x = self._ingest(frames)
        kops.record_dispatch("tile_delta_gate")
        stats_f = self._gate_fn()(x, cache.ref_canvas, self.idx_pad)
        plan = self._host_plan(np.asarray(stats_f), cache, threshold)
        heads = self._dispatch_conv(x, plan, cache)
        self._advance_refs(cache, x, plan)
        if plan.stats.cold_shards:
            cache.cold_steps += 1
        cache.valid[:] = True
        cache.launched_tiles += plan.stats.launched
        cache.canvas_bytes_last = plan.stats.canvas_bytes
        cache.canvas_bytes_total += plan.stats.canvas_bytes
        heads_np = np.asarray(heads)
        return self._split_heads(heads_np, frames), plan.stats

    def step_full(self, frames: Dict[int, List]):
        """The non-reuse sharded super-launch (cold path / A-B
        baseline): ≤3 dispatches, bit-identical per group to
        ``superlaunch_forward``.  Returns {gid: head maps (numpy)}."""
        if self.n_total == 0:
            return self._zero_heads(frames)
        x = self._ingest(frames)
        plan = self._full_plan()
        kops.record_dispatch("roi_conv_entry")
        if self.det.num_conv_layers > 1:
            kops.record_dispatch("roi_conv_stack")
        kops.record_dispatch("sbnet_scatter_fleet")
        slot = self._put_tables(plan, 0)
        packed0 = jax.device_put(
            jnp.zeros((self.plan.n_shards, self.n_max, self.det.cfg.tile,
                       self.det.cfg.tile, self.det.cfg.channels[-1]),
                      jnp.float32), self.sharding)
        canvas0 = jax.device_put(
            jnp.zeros((self.plan.n_shards, self.F_max + 1, self.canvas_h,
                       self.canvas_w, self.det.head.shape[-1]),
                      jnp.float32), self.sharding)
        _, heads = self._conv_fn(plan.k_max)(x, *slot, packed0, canvas0)
        return self._split_heads(np.asarray(heads), frames)

    def _full_plan(self) -> _HostPlan:
        """An everything-changed plan: compact tables = full tables."""
        S = self.plan.n_shards
        k_max = _pow2(max(self._n_s + [1]))
        cidx = np.zeros((S, k_max, 3), np.int32)
        cidx[:, :, 0] = self.F_max
        cnbr = np.full((S, k_max, 8), -1, np.int32)
        upd = np.full((S, k_max), self.n_max, np.int32)
        sidx = np.zeros((S, k_max, 3), np.int32)
        sidx[:, :, 0] = self.F_max
        for s in range(S):
            n_s = self._n_s[s]
            cidx[s, :n_s] = self._idx_np[s]
            cnbr[s, :n_s] = self._nbr_np[s]
            upd[s, :n_s] = np.arange(n_s)
            sidx[s, :n_s] = self._idx_np[s]
        t = self.det.cfg.tile
        tile_bytes = t * t * int(self.det.head.shape[-1]) * 4
        stats = ShardedReuseStats(self.n_total, self.n_total, self.n_total,
                                  self.n_total, S * k_max, k_max, S,
                                  canvas_bytes=self.n_total * tile_bytes)
        return _HostPlan(k_max, cidx, cnbr, upd, sidx,
                         np.zeros((S, self.n_max), bool),
                         np.ones(S, bool), stats)

    def _dispatch_conv(self, x, plan: _HostPlan,
                       cache: ShardedActivationCache, parity: int = 0):
        """Dispatch the conv chain for one planned step; returns the
        heads future (= the updated persistent canvas).  Counts one
        launch per kernel — the SPMD program runs each once on every
        shard.  ``k_max == 0`` (all-static) is a ZERO-dispatch path:
        nothing is launched, no canvas byte is written, and the cached
        canvas is served directly."""
        if plan.k_max == 0:
            return cache.canvas
        kops.record_dispatch("roi_conv_entry")
        if self.det.num_conv_layers > 1:
            kops.record_dispatch("roi_conv_stack")
        kops.record_dispatch("sbnet_scatter_changed")
        slot = self._put_tables(plan, parity)
        cache.packed, cache.canvas = self._conv_fn(plan.k_max)(
            x, *slot, cache.packed, cache.canvas)
        return cache.canvas

    # -- output plumbing ---------------------------------------------------
    def _split_heads(self, heads_np: np.ndarray, frames: Dict[int, List]
                     ) -> Dict[int, List[np.ndarray]]:
        out: Dict[int, List[np.ndarray]] = {}
        for gid in self.gids:
            s, c0 = self._group_slot[gid]
            outs = []
            for i, f in enumerate(frames[gid]):
                h, w = np.asarray(f).shape[:2]
                outs.append(heads_np[s, c0 + i, :h, :w])
            out[gid] = outs
        return out

    def _zero_heads(self, frames: Dict[int, List]
                    ) -> Dict[int, List[np.ndarray]]:
        a = self.det.head.shape[-1]
        return {gid: [np.zeros(np.asarray(f).shape[:2] + (a,), np.float32)
                      for f in frames[gid]] for gid in self.gids}


class AsyncShardedPipeline:
    """Depth-1 host/device software pipeline over a ShardedSuperlaunch.

    ``submit(frames)`` dispatches step t's GATE first, then step t-1's
    conv chain behind it — so pulling step t's gate stats blocks only on
    the gate, and the host planning for step t (thresholding, dilation,
    compaction, table staging) runs while the device executes step t-1's
    conv.  ``collect()`` is the ONLY place that blocks on head maps (the
    consumer edge).  ``overlap_fraction`` reports how much host planning
    time ran under an in-flight device step."""

    def __init__(self, runtime: ShardedSuperlaunch,
                 cache: ShardedActivationCache, threshold=0.0):
        self.rt = runtime
        self.cache = cache
        self.threshold = threshold
        self._staged = None           # (step, x, plan, frames, t_submit)
        self._ready: deque = deque()  # (step, heads_future, stats,
        #                                frames, t_submit)
        self._step = 0
        self.host_s = 0.0             # total host planning time
        self.overlapped_host_s = 0.0  # ... under an in-flight device step
        self.blocked_s = 0.0          # consumer-edge block time
        self.latencies: List[float] = []

    def submit(self, frames: Dict[int, List]) -> int:
        rt, cache = self.rt, self.cache
        step = self._step
        self._step += 1
        t0 = time.perf_counter()
        cache.steps += 1
        cache.total_tiles += rt.n_total
        if rt.n_total == 0:
            self._ready.append((step, None, ShardedReuseStats(
                0, 0, 0, 0, 0, 0, 0), frames, t0, obs_trace.NULL_SPAN))
            return step
        rt._init_cache_arrays(cache)
        x = rt._ingest(frames)
        # 1. gate for THIS step goes first on the device queue...
        with obs_trace.span("gate", step=step):
            kops.record_dispatch("tile_delta_gate")
            stats_f = rt._gate_fn()(x, cache.ref_canvas, rt.idx_pad)
        # 2. ...then the conv chain of the STAGED previous step, so the
        # stats pull below waits only for the gate while the conv runs on
        h0 = time.perf_counter()
        with obs_trace.span("host_plan", step=step) as hsp:
            self._flush_staged()
            in_flight = bool(self._ready)
            stats_np = np.asarray(stats_f)        # blocks on the gate only
            # 3. host planning for THIS step — overlaps step t-1's conv
            plan = rt._host_plan(stats_np, cache, self.threshold)
            rt._advance_refs(cache, x, plan)
            hsp.set(overlapped=in_flight, k_max=plan.k_max,
                    computed=plan.stats.computed)
        if plan.stats.cold_shards:
            cache.cold_steps += 1
        cache.valid[:] = True
        cache.launched_tiles += plan.stats.launched
        cache.canvas_bytes_last = plan.stats.canvas_bytes
        cache.canvas_bytes_total += plan.stats.canvas_bytes
        host = time.perf_counter() - h0
        self.host_s += host
        if in_flight:
            self.overlapped_host_s += host
        self._staged = (step, x, plan, frames, t0)
        return step

    def _flush_staged(self) -> None:
        if self._staged is None:
            return
        step, x, plan, frames, t0 = self._staged
        self._staged = None
        # the device-compute span opens at dispatch and closes at the
        # collect() fence — in-flight time lands on its own trace track
        # with NO added sync (the fence already exists)
        dspan = obs_trace.begin("device_compute", track="device",
                                step=step, k_max=plan.k_max)
        heads = self.rt._dispatch_conv(x, plan, self.cache,
                                       parity=step % 2)
        self._ready.append((step, heads, plan.stats, frames, t0, dspan))

    def collect(self):
        """Block on the OLDEST completed step (the consumer edge) and
        return (step, {gid: head maps}, stats)."""
        if not self._ready:
            self._flush_staged()
        if not self._ready:
            raise RuntimeError("collect() with no submitted step pending")
        step, heads, stats, frames, t0, dspan = self._ready.popleft()
        b0 = time.perf_counter()
        with obs_trace.span("collect", step=step):
            if heads is None:
                dspan.end()
                out = self.rt._zero_heads(frames)
            else:
                heads = jax.block_until_ready(heads)  # the ONLY fence
                dspan.end()
                out = self.rt._split_heads(np.asarray(heads), frames)
        now = time.perf_counter()
        self.blocked_s += now - b0
        self.latencies.append(now - t0)
        return step, out, stats

    def drain(self) -> List:
        """Collect every outstanding step."""
        out = []
        while self._ready or self._staged is not None:
            out.append(self.collect())
        return out

    @property
    def overlap_fraction(self) -> float:
        """Fraction of host planning time spent while a device step was
        in flight (0 on a fully serial schedule)."""
        return self.overlapped_host_s / self.host_s if self.host_s else 0.0

    @property
    def p99_latency_s(self) -> float:
        return float(np.percentile(self.latencies, 99)) \
            if self.latencies else 0.0
