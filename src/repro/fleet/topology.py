"""Multi-intersection fleet topology (city-scale scene composition).

The paper evaluates one intersection (5 cameras); its pitch is city scale.
This module composes the single-intersection scene (`core/scene.py`) into a
fleet of K intersections laid out on a coarse world grid, each with its own
traffic profile (rush-hour, sparse, bursty — `scene.SPAWN_PROFILES`), seed,
and optional scripted traffic shift.

Two properties the rest of the fleet stack relies on, both by construction:

* **Per-group isolation** — each group's scene is generated in its own
  local frame with the standard camera rig; placing the group at a world
  offset translates cameras and vehicles together, and pinhole projection
  is invariant under that joint translation.  A group's detections are
  therefore *bit-identical* to running the single-intersection scene in
  isolation, so per-group offline results match the standalone pipeline
  exactly (tested in tests/test_fleet.py).
* **Zero cross-group correlation** — with the default spacing (600 m),
  another intersection's vehicles project far below the detector's minimum
  box area in any camera, so no cross-group appearance can enter the
  association table.  `cross_group_leakage` measures this directly by
  projecting every group's vehicles into every *other* group's cameras.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.scene import (Scene, SceneConfig, SPAWN_PROFILES,
                              default_cameras, generate_scene)

TRAFFIC_PROFILES = tuple(SPAWN_PROFILES)


@dataclass
class GroupSpec:
    """One intersection: a traffic profile plus scene-config overrides."""
    profile: str = "uniform"
    seed: int = 0
    overrides: Dict = field(default_factory=dict)   # extra SceneConfig kwargs


@dataclass
class FleetConfig:
    groups: List[GroupSpec]
    duration_s: int = 90
    spacing_m: float = 600.0        # world grid pitch between intersections
    tile: int = 64

    @property
    def num_groups(self) -> int:
        return len(self.groups)


@dataclass
class FleetGroup:
    gid: int
    spec: GroupSpec
    scene: Scene                    # generated in the group's LOCAL frame
    offset_xy: np.ndarray           # world offset of the intersection

    @property
    def num_cameras(self) -> int:
        return len(self.scene.cameras)


@dataclass
class FleetScene:
    cfg: FleetConfig
    groups: List[FleetGroup]

    @property
    def num_groups(self) -> int:
        return len(self.groups)

    @property
    def cams_per_group(self) -> int:
        return self.groups[0].num_cameras if self.groups else 0

    @property
    def num_cameras(self) -> int:
        return sum(g.num_cameras for g in self.groups)

    def global_cam(self, gid: int, local_cam: int) -> int:
        """Flat fleet-wide camera row index (groups are contiguous)."""
        return sum(g.num_cameras for g in self.groups[:gid]) + local_cam

    def all_cameras(self):
        """Flat camera list aligned with ``global_cam`` indices."""
        return [c for g in self.groups for c in g.scene.cameras]


def _grid_offsets(k: int, spacing: float) -> np.ndarray:
    side = int(np.ceil(np.sqrt(max(k, 1))))
    offs = [(spacing * (i % side), spacing * (i // side)) for i in range(k)]
    return np.asarray(offs, np.float64)


def build_fleet(cfg: FleetConfig) -> FleetScene:
    offs = _grid_offsets(cfg.num_groups, cfg.spacing_m)
    groups = []
    for gid, spec in enumerate(cfg.groups):
        if spec.profile not in SPAWN_PROFILES:
            raise ValueError(f"unknown traffic profile {spec.profile!r}; "
                             f"one of {TRAFFIC_PROFILES}")
        kwargs = {"duration_s": cfg.duration_s, "seed": spec.seed,
                  "spawn_profile": spec.profile, **spec.overrides}
        scfg = SceneConfig(**kwargs)    # overrides win on conflicts
        scene = generate_scene(scfg, default_cameras(cfg.tile))
        groups.append(FleetGroup(gid, spec, scene, offs[gid]))
    return FleetScene(cfg, groups)


def cross_group_leakage(fleet: FleetScene, frame_step: int = 25) -> int:
    """Count cross-group appearances: boxes another group's vehicle would
    project into this group's cameras, over a strided frame sample.

    A vehicle of group g at local position ``xy`` sits at ``xy + off_g`` in
    the world, i.e. at ``xy + off_g - off_h`` in group h's local frame —
    so the check needs no world-frame camera rebuild.  Must be 0 at sane
    spacing: distant vehicles fall below the detector's minimum box area
    (the same cull the scene generator applies to its own vehicles)."""
    leaks = 0
    for g in fleet.groups:
        scfg = g.scene.cfg
        for t in range(0, scfg.num_frames, frame_step):
            tt = t / scfg.fps
            for v in g.scene.vehicles:
                pos = v.position(tt, scfg)
                if pos is None:
                    continue
                xy, heading = pos
                for h in fleet.groups:
                    if h.gid == g.gid:
                        continue
                    rel = xy + g.offset_xy - h.offset_xy
                    for cam in h.scene.cameras:
                        bb = cam.project_box(rel, scfg.vehicle_length,
                                             scfg.vehicle_width,
                                             scfg.vehicle_height, heading)
                        if bb is not None and bb.area >= 24 * 24:
                            leaks += 1
    return leaks
