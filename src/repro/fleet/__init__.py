"""Fleet layer: many intersections, one engine.

Sits between the offline solver (`repro.core`) and the serving stack
(`repro.serving`): `topology` composes the single-intersection scene into K
independent camera groups with per-group traffic profiles; `runtime` runs
the fleet online phase as one vectorized evaluation plus one packed conv
launch chain per group per step; `drift` keeps the deployed RoI masks
tracking traffic shifts with warm-started incremental re-solves; `sharded`
partitions camera groups over a device mesh (one shard_map super-launch,
zero hot-path collectives) with an async host/device dispatch pipeline.
"""
from repro.fleet.topology import (FleetConfig, FleetGroup, FleetScene,
                                  GroupSpec, TRAFFIC_PROFILES, build_fleet,
                                  cross_group_leakage)
from repro.fleet.runtime import (FleetOfflineResult, FleetOnlineMetrics,
                                 fleet_inference_step, fleet_reuse_step,
                                 run_fleet_offline, run_fleet_online,
                                 sharded_fleet_step)
from repro.fleet.drift import (AdaptiveRunResult, DriftAdapter, DriftConfig,
                               DriftEvent, ShrinkEvent,
                               run_adaptive_online,
                               wire_shard_invalidation)
from repro.fleet.sharded import (AsyncShardedPipeline, ShardedReuseStats,
                                 ShardedSuperlaunch)

__all__ = [
    "FleetConfig", "FleetGroup", "FleetScene", "GroupSpec",
    "TRAFFIC_PROFILES", "build_fleet", "cross_group_leakage",
    "FleetOfflineResult", "FleetOnlineMetrics", "fleet_inference_step",
    "fleet_reuse_step", "run_fleet_offline", "run_fleet_online",
    "sharded_fleet_step",
    "AdaptiveRunResult", "DriftAdapter", "DriftConfig", "DriftEvent",
    "ShrinkEvent", "run_adaptive_online", "wire_shard_invalidation",
    "AsyncShardedPipeline", "ShardedReuseStats", "ShardedSuperlaunch",
]
