"""Fleet online runtime: K camera groups, one engine, no per-camera loops.

Three jobs:

* ``run_fleet_offline`` — the offline phase per group.  Groups are
  independent by construction (topology), so this is exactly the
  single-intersection pipeline run K times; per-group results are
  bit-identical to isolation.
* ``run_fleet_online`` — the online phase for the whole fleet as ONE
  vectorized evaluation: every detection of every camera of every group is
  flattened into flat arrays and coverage flags come from a single
  ``coverage_flags_batched`` call over the fleet's stacked mask grids
  (replacing ``run_online``'s per-camera Python loop); the (camera x
  segment) network model is the vectorized ``segment_network_bytes``.
  Per-group metrics are numerically identical to ``run_online`` on that
  group alone — the fleet path changes the schedule, not the math.
  Reducto keep masks ride along per group (``frame_keep[gid][cam_id]``)
  with the same last-streamed-result forward-fill semantics as
  ``run_online``, so the transport layer sees filtered ``frames_sent``
  per camera; ``cfg.transport="simulated"`` prices every group through
  the ``repro.net`` streaming runtime and merges the per-frame latency
  distributions fleet-wide.
* ``fleet_inference_step`` — the kernel-level hot path: EVERY camera of
  EVERY group runs in ONE cross-group super-launch over the fleet-flat
  (flat_cam, ty, tx) index space (built per call, digest-cached, by
  ``RoIDetector._fleet_tables``; ``ops.superlaunch_tables`` is the
  standalone builder of the same tables): one fused gather+conv entry
  kernel, one layer-stack megakernel covering all remaining conv
  layers, one scatter — ≤3 Pallas dispatches per fleet step,
  independent of the group count K and layer count N (the old chain
  paid K×(N+1)).  The dispatch ceiling is asserted via
  ``ops.count_kernels`` on every step.  ``fleet_reuse_step`` is the
  delta-gated variant: the same chain compacted to the CHANGED tiles
  (one shared ``tile_delta_gate`` pricing dispatch; unchanged tiles
  composite from the persistent packed-activation cache), keeping the
  conv ceiling while making compute proportional to scene motion.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.pipeline import (OfflineConfig, OfflineResult, OnlineConfig,
                                 OnlineMetrics, bbox_arrays,
                                 coverage_flags_batched,
                                 online_system_metrics, run_offline)
from repro.fleet.topology import FleetScene
from repro.kernels import ops as kops
from repro.net.batcher import TransportStats, merge_transport
from repro.obs import metrics as obs_metrics, trace as obs_trace


# ---------------------------------------------------------------------------
# offline phase
# ---------------------------------------------------------------------------

@dataclass
class FleetOfflineResult:
    per_group: List[OfflineResult]
    wall_s: float = 0.0

    @property
    def fleet_density(self) -> float:
        return float(np.mean([o.fleet_density for o in self.per_group]))


def run_fleet_offline(fleet: FleetScene,
                      cfg: Optional[OfflineConfig] = None
                      ) -> FleetOfflineResult:
    t0 = time.time()
    per_group = [run_offline(g.scene, cfg) for g in fleet.groups]
    return FleetOfflineResult(per_group, wall_s=time.time() - t0)


# ---------------------------------------------------------------------------
# online phase (vectorized across the whole fleet)
# ---------------------------------------------------------------------------

@dataclass
class FleetOnlineMetrics:
    per_group: List[OnlineMetrics]
    accuracy_mean: float
    accuracy_min: float
    network_mbps_total: float
    fleet_server_hz: float        # one engine multiplexing all groups
    camera_fps_min: float
    latency_max_s: float
    wall_s: float = 0.0
    frames_reduced: int = 0       # Reducto-filtered frames, fleet-wide
    # fleet-wide per-frame latency distribution (simulated transport):
    # every group's frames merged into one p50/p99-able population
    transport: Optional[TransportStats] = None


def run_fleet_online(fleet: FleetScene,
                     offlines: Sequence[OfflineResult],
                     cfg: Optional[OnlineConfig] = None,
                     t0: Optional[int] = None, t1: Optional[int] = None,
                     frame_keep: Optional[Dict[int, Dict]] = None
                     ) -> FleetOnlineMetrics:
    """``frame_keep`` maps gid -> {cam_id -> (n_frames,) bool keep mask}
    (groups may be omitted = unfiltered).  ``cfg.frame_keep`` is the
    single-scene field and stays per-camera; pass the fleet-keyed dict
    here instead."""
    cfg = cfg or OnlineConfig()
    if cfg.frame_keep is not None:
        raise ValueError("use the frame_keep argument (keyed by gid) for "
                         "fleet runs; OnlineConfig.frame_keep is "
                         "single-scene")
    frame_keep = frame_keep or {}
    wall0 = time.time()
    t0 = t0 if t0 is not None else 600
    t1 = t1 if t1 is not None else min(len(g.scene.detections)
                                       for g in fleet.groups)
    n_frames = t1 - t0
    fps = fleet.groups[0].scene.cfg.fps

    cameras = fleet.all_cameras()
    grids = [offlines[g.gid].cam_grids[c.cam_id]
             for g in fleet.groups for c in g.scene.cameras]

    # ---- flatten every group's detections into one flat batch ------------
    det_t_parts, det_cam_parts, det_obj_parts, bbox_parts = [], [], [], []
    group_obj_slice = []                 # [o_start, o_end) per group
    obj_base = 0
    cam_base = 0
    for g in fleet.groups:
        rows = [(ti - t0, d) for ti in range(t0, t1)
                for d in g.scene.detections[ti]]
        ng = len(rows)
        gt = np.fromiter((t for t, _ in rows), np.int64, ng)
        gc = np.fromiter((d.cam for _, d in rows), np.int64, ng) + cam_base
        _, ginv = np.unique(
            np.fromiter((d.obj for _, d in rows), np.int64, ng),
            return_inverse=True)
        n_obj = int(ginv.max()) + 1 if ng else 0
        det_t_parts.append(gt)
        det_cam_parts.append(gc)
        det_obj_parts.append(ginv.astype(np.int64) + obj_base)
        bbox_parts.extend(d.bbox for _, d in rows)
        group_obj_slice.append((obj_base, obj_base + n_obj))
        obj_base += n_obj
        cam_base += g.num_cameras

    nd = sum(p.shape[0] for p in det_t_parts)
    C, O = len(cameras), obj_base
    missed_per_group = [np.zeros(n_frames, np.int64) for _ in fleet.groups]
    totals = [0 for _ in fleet.groups]
    if nd:
        det_t = np.concatenate(det_t_parts)
        det_cam = np.concatenate(det_cam_parts)
        det_obj = np.concatenate(det_obj_parts)
        l, tt, rr, bb, area = bbox_arrays(bbox_parts)

        # ONE coverage evaluation for every camera in every group
        flags = coverage_flags_batched(cameras, grids, det_cam, l, tt, rr,
                                       bb, area, cfg.coverage_thresh)

        present = np.zeros((n_frames, O), bool)
        present[det_t, det_obj] = True
        cur = np.zeros((n_frames, C, O), bool)
        cur[det_t[flags], det_cam[flags], det_obj[flags]] = True
        if not frame_keep:
            detected = cur.any(axis=1)
        else:
            # Reducto forward-fill (same semantics as run_online): a
            # filtered frame reuses the detector output of the camera's
            # most recent *streamed* frame, per flat fleet camera
            exists = np.zeros((n_frames, C, O), bool)
            exists[det_t, det_cam, det_obj] = True
            used = np.empty_like(cur)
            ci = 0
            for g in fleet.groups:
                gkeep = frame_keep.get(g.gid)
                for c in g.scene.cameras:
                    if gkeep is None or c.cam_id not in gkeep:
                        used[:, ci, :] = cur[:, ci, :]
                        ci += 1
                        continue
                    km = np.zeros(n_frames, bool)
                    src = np.asarray(gkeep[c.cam_id], bool)[:n_frames]
                    km[:src.shape[0]] = src
                    kt = np.nonzero(km)[0]
                    if kt.size == 0:              # camera never streams
                        used[:, ci, :] = False
                        ci += 1
                        continue
                    j = np.searchsorted(kt, np.arange(n_frames),
                                        side="left") - 1
                    last = cur[kt[np.maximum(j, 0)], ci, :]
                    last[j < 0] = False           # nothing streamed yet
                    used[:, ci, :] = np.where(km[:, None], cur[:, ci, :],
                                              last)
                    ci += 1
            detected = (exists & used).any(axis=1)
        missed_grid = present & ~detected
        for gi, (o0, o1) in enumerate(group_obj_slice):
            missed_per_group[gi] = missed_grid[:, o0:o1].sum(axis=1) \
                .astype(np.int64)
            totals[gi] = int(present[:, o0:o1].sum())

    # ---- per-group system metrics (the exact run_online block, shared) ----
    per_group: List[OnlineMetrics] = []
    frames_reduced = 0
    for g, off in zip(fleet.groups, offlines):
        gkeep = frame_keep.get(g.gid)
        if gkeep is not None:
            # partial per-camera dicts are legal (missing camera =
            # unfiltered, matching the accuracy pass above); the byte/
            # transport model wants a complete dict
            gkeep = {c.cam_id: gkeep.get(c.cam_id,
                                         np.ones(n_frames, bool))
                     for c in g.scene.cameras}
        (network_mbps, server_hz, camera_fps, latency, parts, _, _,
         transport) = online_system_metrics(g.scene.cameras, off, cfg,
                                            fps, n_frames, gkeep)
        missed = int(missed_per_group[g.gid].sum())
        total = totals[g.gid]
        reduced = 0
        if gkeep is not None:
            reduced = int(sum((~np.asarray(gkeep[c.cam_id], bool)).sum()
                              for c in g.scene.cameras
                              if c.cam_id in gkeep))
        frames_reduced += reduced
        per_group.append(OnlineMetrics(
            1.0 - missed / max(total, 1), missed, total,
            missed_per_group[g.gid], network_mbps, server_hz, camera_fps,
            latency, parts, reduced, transport))

    accs = [m.accuracy for m in per_group]
    transports = [m.transport for m in per_group if m.transport]
    return FleetOnlineMetrics(
        per_group=per_group,
        accuracy_mean=float(np.mean(accs)),
        accuracy_min=float(np.min(accs)),
        network_mbps_total=float(sum(m.network_mbps for m in per_group)),
        # one server multiplexing the groups round-robin: rates compose
        # harmonically (time per fleet sweep = sum of per-group times)
        fleet_server_hz=1.0 / sum(1.0 / m.server_hz for m in per_group),
        camera_fps_min=float(min(m.camera_fps for m in per_group)),
        latency_max_s=float(max(m.latency_s for m in per_group)),
        wall_s=time.time() - wall0,
        frames_reduced=frames_reduced,
        transport=merge_transport(transports) if transports else None)


# ---------------------------------------------------------------------------
# kernel-level fleet step (one packed launch chain per group)
# ---------------------------------------------------------------------------

def fleet_inference_step(det, frames: Dict[int, List],
                         grids: Dict[int, List[np.ndarray]]):
    """Run one fleet step: ALL groups' cameras as ONE super-launch chain.

    frames[gid] / grids[gid]: per-camera frame arrays and RoI tile grids of
    group ``gid``.  Returns ({gid: per-camera head maps}, dispatch
    Counter).  Asserts — every step — the constant-dispatch structure the
    super-launch guarantees: one fused gather+conv entry, one layer-stack
    megakernel (absent for a 1-layer net), one scatter — ≤3 dispatches
    for the WHOLE FLEET, regardless of group count and layer count.  An
    all-empty fleet (no active tile anywhere) launches nothing."""
    with kops.count_kernels() as c, obs_trace.span("fleet_step"):
        outs = det.superlaunch_forward(frames, grids)
    total: collections.Counter = collections.Counter(c)
    n_tiles = sum(int(np.count_nonzero(np.asarray(g, bool)))
                  for gs in grids.values() for g in gs)
    expected = {} if n_tiles == 0 else {
        "roi_conv_entry": 1,
        "roi_conv_stack": 1 if det.num_conv_layers > 1 else 0,
        "sbnet_scatter_fleet": 1}
    observed = {k: total[k] for k in expected}
    assert observed == expected and not set(total) - set(expected), \
        f"super-launch dispatch structure broken: {dict(total)}"
    assert sum(total.values()) <= 3, \
        f"fleet step must stay within 3 dispatches: {dict(total)}"
    return outs, total


def fleet_reuse_step(det, frames: Dict[int, List],
                     grids: Dict[int, List[np.ndarray]], cache,
                     threshold: float = 0.0, qstep: float = 8.0):
    """One delta-gated fleet step: compute proportional to CHANGED tiles.

    Like ``fleet_inference_step`` but through
    ``RoIDetector.superlaunch_forward_reuse``: one shared
    ``tile_delta_gate`` dispatch prices every active tile's haloed input
    window against ``cache`` (a ``serving.detector.PackedActivationCache``
    — the SAME stats feed the edge rate controller via
    ``net.encoder.static_fraction_from_stats``, so there is no second
    delta dispatch per step), the surviving compact set runs the blocked
    entry + stack chain, and one ``sbnet_scatter_changed`` writes ONLY
    the refreshed tiles' head rows into the persistent canvas.  Returns
    ({gid: head maps}, dispatch Counter, ReuseStats).  Asserts — every
    step — the delta-gated dispatch structure:

    * the conv chain keeps the super-launch's ≤3-dispatch ceiling
      (entry ≤1, stack ≤1, changed-only scatter = 1);
    * exactly one gate dispatch on warm steps, none on cold steps (a
      cold step IS the plain super-launch: cache + canvas re-seed);
    * an all-static frame dispatches the gate ALONE — zero conv, zero
      scatter, 0 canvas bytes written;
    * an all-empty fleet launches nothing."""
    t0 = time.perf_counter()
    with kops.count_kernels() as c, \
            obs_trace.span("fleet_reuse_step", step=cache.steps) as sp:
        outs, stats = det.superlaunch_forward_reuse(frames, grids, cache,
                                                    threshold, qstep)
        sp.set(computed=stats.computed, cold=stats.cold)
    obs_metrics.observe_fleet_step(stats, time.perf_counter() - t0,
                                   path="fleet_reuse")
    total: collections.Counter = collections.Counter(c)
    n_tiles = sum(int(np.count_nonzero(np.asarray(g, bool)))
                  for gs in grids.values() for g in gs)
    if n_tiles == 0:
        expected = {}
    elif stats.cold:
        expected = {"roi_conv_entry": 1,
                    "roi_conv_stack": 1 if det.num_conv_layers > 1 else 0,
                    "sbnet_scatter_fleet": 1}
    elif stats.computed == 0:
        expected = {"tile_delta_gate": 1}
    else:
        expected = {"tile_delta_gate": 1, "roi_conv_entry": 1,
                    "roi_conv_stack": 1 if det.num_conv_layers > 1 else 0,
                    "sbnet_scatter_changed": 1}
    expected = {k: v for k, v in expected.items() if v}
    observed = {k: total[k] for k in expected}
    assert observed == expected and not set(total) - set(expected), \
        f"delta-gated dispatch structure broken: {dict(total)}"
    conv = sum(v for k, v in total.items() if k != "tile_delta_gate")
    assert conv <= 3, \
        f"reuse step must keep the ≤3-dispatch conv ceiling: {dict(total)}"
    return outs, total, stats


def sharded_fleet_step(runtime, frames: Dict[int, List], cache,
                       threshold=0.0):
    """One delta-gated step of a ``fleet.sharded.ShardedSuperlaunch``,
    with the same every-step dispatch-structure assertion as
    ``fleet_reuse_step`` — the sharded program is ONE SPMD launch per
    kernel, so the per-SHARD ceiling and the fleet-wide dispatch count
    coincide: 1 gate + the ≤3-dispatch conv chain on changed steps, the
    gate ALONE on all-static steps (the persistent canvas is served
    as-is, zero conv/scatter launches and 0 bytes written), nothing on
    an all-empty fleet.  (The sharded path gates on cold steps too —
    SPMD uniformity: cold and warm shards share one program.)  Returns
    ({gid: head maps}, dispatch Counter, ShardedReuseStats)."""
    t0 = time.perf_counter()
    with kops.count_kernels() as c, \
            obs_trace.span("sharded_fleet_step", step=cache.steps) as sp:
        outs, stats = runtime.step_reuse(frames, cache, threshold)
        sp.set(computed=stats.computed, cold_shards=stats.cold_shards)
    obs_metrics.observe_fleet_step(stats, time.perf_counter() - t0,
                                   path="sharded")
    total: collections.Counter = collections.Counter(c)
    if stats.total_tiles == 0:
        expected = {}
    elif stats.k_max == 0:
        expected = {"tile_delta_gate": 1}
    else:
        expected = {"tile_delta_gate": 1, "roi_conv_entry": 1,
                    "roi_conv_stack":
                        1 if runtime.det.num_conv_layers > 1 else 0,
                    "sbnet_scatter_changed": 1}
    expected = {k: v for k, v in expected.items() if v}
    observed = {k: total[k] for k in expected}
    assert observed == expected and not set(total) - set(expected), \
        f"sharded dispatch structure broken: {dict(total)}"
    conv = sum(v for k, v in total.items() if k != "tile_delta_gate")
    assert conv <= 3, \
        f"sharded step must keep the ≤3-dispatch conv ceiling: {dict(total)}"
    return outs, total, stats
