"""Online mask-drift adaptation (paper §5.5, made continuous).

The offline RoI mask encodes where traffic *was* during profiling.  When
traffic shifts — a closed lane, a rerouted approach, rush-hour turning
patterns — appearances start landing outside the mask and accuracy decays
silently.  The paper re-runs the whole offline phase; this adapter instead:

* monitors per-appearance coverage and **per-tile coverage residuals**
  (tiles that uncovered appearances wanted but the mask lacks) over a
  sliding window of the online stream, and
* when windowed coverage drops below target, triggers an **incremental,
  warm-started re-solve**: the window's appearance regions become set-cover
  constraints and ``setcover.solve_warm`` seeds the greedy core with the
  deployed mask, so the solve only pays for the residual core — no full
  offline re-run, no mask churn on covered regions.

The adapter is deliberately engine-agnostic: feed it the per-frame
detections the server already produces (``observe``), read back the updated
mask/grids when it fires.  ``run_adaptive_online`` is the reference driver
used by tests and the fleet benchmark.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core import setcover
from repro.core.association import AssociationTable, Region
from repro.core.pipeline import (OfflineConfig, OfflineResult,
                                 bbox_mask_area, run_offline)
from repro.core.scene import Scene
from repro.obs import metrics as obs_metrics, trace as obs_trace


@dataclass
class DriftConfig:
    window_frames: int = 200       # sliding observation window
    coverage_target: float = 0.95  # re-solve when window coverage dips below
    min_samples: int = 40          # appearances needed before triggering
    cooldown_frames: int = 200     # min frames between re-solves
    # sustained-breach confirmation: coverage must stay below target this
    # many consecutive frames before the re-solve fires.  A transient dip
    # (one occluded platoon) recovers on its own; a real traffic shift
    # keeps breaching while the window fills with the NEW routes — firing
    # only after confirmation means the warm re-solve sees vehicles at
    # every phase of the shifted corridors, so ONE re-solve restores
    # coverage instead of chasing the shift with many partial patches.
    confirm_frames: int = 150
    # detector tolerance, matching OnlineConfig.coverage_thresh: an
    # appearance counts as covered when >= this fraction of its bbox pixel
    # area survives the RoI crop
    coverage_thresh: float = 0.75
    # --- scheduled shrink re-solves (ROADMAP: "drift adapter only grows
    # masks").  Growth re-solves are additive by design; at *detected
    # low-traffic windows* the adapter instead re-profiles a recent slice
    # of the stream with the FULL offline pipeline (run_offline on
    # [t - shrink_profile_frames, t)) and adopts the cold mask — but only
    # when it is smaller AND does not regress coverage on the buffered
    # observation window.  A bad adoption self-heals: the breach monitor
    # keeps running on the shrunk mask and fires a warm grow re-solve.
    shrink_enabled: bool = False
    shrink_check_every: int = 50       # frames between low-traffic checks
    shrink_low_rate: float = 0.5       # appearances/frame; below = lull
    shrink_profile_frames: int = 250   # re-profile window length
    shrink_cooldown_frames: int = 400
    shrink_min_constraints: int = 20   # evidence floor for the re-solve


@dataclass
class DriftEvent:
    t: int                         # frame that triggered the re-solve
    coverage_before: float         # windowed coverage at trigger time
    tiles_added: int               # mask growth from the warm re-solve
    constraints: int               # window constraints handed to the solver
    wall_s: float                  # re-solve wall time


@dataclass
class ShrinkEvent:
    t: int                         # frame the shrink re-solve ran
    mask_before: int               # deployed mask size going in
    mask_after: int                # ... and coming out (== before if
    #                                the candidate was rejected)
    coverage_before: float         # buffered-window coverage, old mask
    coverage_after: float          # ... under the adopted mask
    constraints: int               # offline re-profile constraint count
    adopted: bool
    wall_s: float


class DriftAdapter:
    """Per-group online mask maintainer.

    Holds the group's deployed mask (global tile ids over the group's
    ``TileUniverse``) plus the derived per-camera grids, and mutates both
    when a re-solve fires.  Online (grow) re-solves never retract deployed
    tiles — stopping the accuracy bleed when traffic moves is additive by
    design.  Retraction happens on a separate, slower path: at detected
    low-traffic windows ``maybe_shrink`` re-runs the FULL offline pipeline
    on a recent slice of the stream and adopts the cold (smaller) mask iff
    it does not regress coverage on the buffered observations."""

    def __init__(self, scene: Scene, offline: OfflineResult,
                 cfg: Optional[DriftConfig] = None):
        self.cfg = cfg or DriftConfig()
        self.cameras = scene.cameras
        self.universe = offline.universe
        self.mask = set(offline.mask)
        self.cam_grids = {c.cam_id: offline.cam_grids[c.cam_id].copy()
                          for c in scene.cameras}
        # sliding windows: (t, covered) per appearance; (t, obj, regions)
        # buffered for re-solve constraints
        self._window: Deque[Tuple[int, bool]] = collections.deque()
        self._regions: Deque[Tuple[int, int, Dict[int, frozenset]]] = \
            collections.deque()
        self.residual_counts: collections.Counter = collections.Counter()
        self.events: List[DriftEvent] = []
        self.shrink_events: List[ShrinkEvent] = []
        self._last_resolve_t = -10 ** 9
        self._last_shrink_t = -10 ** 9
        self._breach_start: Optional[int] = None
        # mask-update listeners: called with the adapter after every
        # deployed-mask mutation (grow re-solve or adopted shrink).  The
        # serving layer's temporal-reuse caches register their
        # ``invalidate`` here so a re-solve can never serve stale packed
        # activations (the caches' content keys would miss anyway — the
        # listener makes the invalidation explicit and countable).
        self._mask_listeners: List = []
        self._notifying = False

    def add_mask_listener(self, fn) -> None:
        """Register ``fn(adapter)`` to run after every mask mutation
        (``PackedActivationCache.invalidate`` ignores the argument:
        ``adapter.add_mask_listener(lambda _: cache.invalidate())``, or
        pass any callable accepting one positional argument)."""
        self._mask_listeners.append(fn)

    def _notify_mask_update(self) -> None:
        # Reentrancy guard: a listener (shard invalidation -> table
        # rebuild) may feed back into ``observe``/``failover`` paths that
        # mutate the mask again within the same step.  The inner mutation
        # already left ``self.mask``/``cam_grids`` final, so fanning out
        # a second time from inside the first fan-out would only
        # double-invalidate the shard cache — suppress the nested call;
        # the outer fan-out delivers the final state.
        if self._notifying:
            return
        self._notifying = True
        try:
            for fn in self._mask_listeners:
                fn(self)
        finally:
            self._notifying = False

    # -- monitoring --------------------------------------------------------
    @property
    def resolves(self) -> int:
        return len(self.events)

    def coverage(self) -> float:
        if not self._window:
            return 1.0
        return sum(1 for _, c in self._window if c) / len(self._window)

    def _covered(self, d) -> bool:
        cam = self.cameras[d.cam]
        cov = bbox_mask_area(cam, self.cam_grids[d.cam], d.bbox)
        return cov >= self.cfg.coverage_thresh * max(d.bbox.area, 1.0)

    def observe(self, t: int, detections) -> bool:
        """Feed one frame of server-side detections; returns True when the
        frame triggered a re-solve.  An *appearance* is one (t, object);
        it is covered when any camera's crop keeps enough of its box —
        the same unique-vehicle criterion the online accuracy uses."""
        by_obj: Dict[int, List] = {}
        for d in detections:
            by_obj.setdefault(d.obj, []).append(d)
        for obj, ds in by_obj.items():
            regions: Dict[int, frozenset] = {}
            covered = False
            for d in ds:
                tiles = self.cameras[d.cam].bbox_tiles(d.bbox)
                if tiles:
                    regions[d.cam] = tiles
                covered = covered or self._covered(d)
            if not regions:
                continue
            if not covered:
                for c, tiles in regions.items():
                    for gt in self.universe.globalize(c, tiles):
                        if gt not in self.mask:
                            self.residual_counts[gt] += 1
            self._window.append((t, covered))
            self._regions.append((t, obj, regions))
        horizon = t - self.cfg.window_frames
        while self._window and self._window[0][0] <= horizon:
            self._window.popleft()
        while self._regions and self._regions[0][0] <= horizon:
            self._regions.popleft()

        breached = (len(self._window) >= self.cfg.min_samples
                    and self.coverage() < self.cfg.coverage_target)
        if not breached:
            self._breach_start = None
            return False
        if self._breach_start is None:
            self._breach_start = t
            obs_metrics.DRIFT_EVENTS.inc(1, event="breach_window")
        if (t - self._breach_start >= self.cfg.confirm_frames
                and t - self._last_resolve_t >= self.cfg.cooldown_frames):
            self._resolve(t)
            return True
        return False

    # -- adaptation --------------------------------------------------------
    def _resolve(self, t: int) -> None:
        wall0 = time.time()
        cov_before = self.coverage()
        with obs_trace.span("drift_resolve", t=t,
                            coverage_before=cov_before):
            constraints: List[List[Region]] = []
            keys: List[Tuple[int, int]] = []
            for tt, obj, regions in self._regions:
                constraints.append(
                    [Region(c, self.universe.globalize(c, tiles))
                     for c, tiles in sorted(regions.items())])
                keys.append((tt, obj))
            table = AssociationTable(self.universe, constraints, keys)
            res = setcover.solve_warm(table, self.mask)
            added = len(res.mask) - len(self.mask)
            self.mask = set(res.mask)
            for c in self.cameras:
                self.cam_grids[c.cam_id] = self.universe.cam_mask_grid(
                    c.cam_id, self.mask)
        wall = time.time() - wall0
        obs_metrics.DRIFT_EVENTS.inc(1, event="resolve")
        obs_metrics.DRIFT_RESOLVE_WALL.observe(wall)
        self.events.append(DriftEvent(t, cov_before, added,
                                      len(constraints), wall))
        self._last_resolve_t = t
        self._breach_start = None
        # the window measured the OLD mask; start the next measurement clean
        self._window.clear()
        self.residual_counts.clear()
        self._notify_mask_update()

    # -- scheduled shrink (full offline re-solve at low-traffic windows) ---
    @property
    def shrinks(self) -> int:
        return sum(1 for e in self.shrink_events if e.adopted)

    def _buffer_coverage(self, mask) -> float:
        """Fraction of buffered appearances every one of whose candidate
        regions fits the mask strictly — a conservative (tile-containment)
        criterion, so "no regress" under it implies no regress under the
        looser detector tolerance."""
        if not self._regions:
            return 1.0
        ok = 0
        for _, _, regions in self._regions:
            if any(self.universe.globalize(c, tiles) <= mask
                   for c, tiles in regions.items()):
                ok += 1
        return ok / len(self._regions)

    def traffic_rate(self) -> float:
        """Windowed appearances per frame — the low-traffic detector."""
        return len(self._window) / max(self.cfg.window_frames, 1)

    def occupancy_by_camera(self) -> Dict[int, int]:
        """Buffered appearance-region count per camera over the current
        observation window — how much traffic each camera has recently
        *seen*.  This is the liveness monitor's second evidence channel:
        a camera whose delta gate goes quiet while its windowed occupancy
        says traffic should be flowing is FROZEN, not static."""
        occ: Dict[int, int] = {c.cam_id: 0 for c in self.cameras}
        for _, _, regions in self._regions:
            for cam in regions:
                occ[cam] = occ.get(cam, 0) + 1
        return occ

    def maybe_shrink(self, t: int, scene: Scene) -> bool:
        """At a detected low-traffic window, re-profile the recent stream
        with the FULL offline pipeline and adopt the cold mask iff it is
        smaller and does not regress buffered coverage.  Returns True when
        a shrink was adopted."""
        cfg = self.cfg
        if (not cfg.shrink_enabled
                or t - self._last_shrink_t < cfg.shrink_cooldown_frames
                or t < cfg.shrink_profile_frames
                or self.traffic_rate() >= cfg.shrink_low_rate):
            return False
        wall0 = time.time()
        self._last_shrink_t = t
        with obs_trace.span("drift_shrink", t=t):
            res = run_offline(
                scene,
                OfflineConfig(profile_frames=cfg.shrink_profile_frames,
                              solver="greedy"),
                t0_frame=t - cfg.shrink_profile_frames)
        candidate = frozenset(res.mask)
        n_constraints = len(res.table.constraints)
        cov_before = self._buffer_coverage(self.mask)
        cov_after = self._buffer_coverage(candidate)
        adopted = (n_constraints >= cfg.shrink_min_constraints
                   and len(candidate) < len(self.mask)
                   and cov_after >= cov_before - 1e-12)
        ev = ShrinkEvent(t, len(self.mask),
                         len(candidate) if adopted else len(self.mask),
                         cov_before, cov_after if adopted else cov_before,
                         n_constraints, adopted, time.time() - wall0)
        self.shrink_events.append(ev)
        obs_metrics.DRIFT_EVENTS.inc(
            1, event="shrink_adopted" if adopted else "shrink_rejected")
        if not adopted:
            return False
        self.mask = set(candidate)
        for c in self.cameras:
            self.cam_grids[c.cam_id] = self.universe.cam_mask_grid(
                c.cam_id, self.mask)
        # measurements under the old mask are stale
        self._window.clear()
        self.residual_counts.clear()
        self._breach_start = None
        self._notify_mask_update()
        return True


# ---------------------------------------------------------------------------
# reference driver
# ---------------------------------------------------------------------------

@dataclass
class AdaptiveRunResult:
    adapter: DriftAdapter
    frame_t: np.ndarray            # (F,) absolute frame index
    appearances: np.ndarray        # (F,) unique objects present
    covered: np.ndarray            # (F,) of those, covered under the
    #                                    mask deployed AT THAT FRAME

    def coverage_between(self, t0: int, t1: int) -> float:
        sel = (self.frame_t >= t0) & (self.frame_t < t1)
        tot = int(self.appearances[sel].sum())
        return float(self.covered[sel].sum()) / max(tot, 1)

    @property
    def resolves(self) -> int:
        return self.adapter.resolves


def run_adaptive_online(scene: Scene, offline: OfflineResult,
                        t0: int, t1: int,
                        cfg: Optional[DriftConfig] = None
                        ) -> AdaptiveRunResult:
    """Stream frames [t0, t1) of one group through a DriftAdapter,
    recording per-frame coverage under the mask deployed at that moment —
    the trajectory the acceptance criterion ("recovers >= target coverage
    within one re-solve of a traffic shift") is read off of."""
    adapter = DriftAdapter(scene, offline, cfg)
    frame_t, apps, covs = [], [], []
    for t in range(t0, t1):
        dets = scene.detections[t]
        by_obj: Dict[int, List] = {}
        for d in dets:
            by_obj.setdefault(d.obj, []).append(d)
        n_cov = sum(1 for ds in by_obj.values()
                    if any(adapter._covered(d) for d in ds))
        frame_t.append(t)
        apps.append(len(by_obj))
        covs.append(n_cov)
        adapter.observe(t, dets)
        if (adapter.cfg.shrink_enabled
                and t % adapter.cfg.shrink_check_every == 0):
            adapter.maybe_shrink(t, scene)
    return AdaptiveRunResult(adapter, np.asarray(frame_t),
                             np.asarray(apps), np.asarray(covs))


def wire_shard_invalidation(adapters: Dict[int, DriftAdapter], cache,
                            runtime=None) -> None:
    """Fan drift re-solves out to the SHARDED serving cache: each group's
    ``DriftAdapter`` gets a mask listener that cold-marks ONLY the shard
    owning that group (``ShardedActivationCache.invalidate_group``) — the
    other shards keep serving warm packed activations through the
    re-solve.  With ``runtime`` (a ``fleet.sharded.ShardedSuperlaunch``)
    given, the listener also rebuilds the owning shard's flat tables from
    the adapter's re-solved grids (``rebuild_group`` preserves the other
    shards' cache rows even when the shared row bucket grows).

    adapters: {gid: DriftAdapter} for the groups the sharded runtime
    serves (a subset is fine — unwired groups simply never invalidate)."""
    for gid, ad in adapters.items():
        def _on_update(a, gid=gid):
            cache.invalidate_group(gid)
            if runtime is not None:
                runtime.rebuild_group(
                    gid, [a.cam_grids[c.cam_id] for c in a.cameras],
                    cache=cache)
        ad.add_mask_listener(_on_update)
