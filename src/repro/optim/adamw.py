"""AdamW with sharded states, warmup+cosine schedule, global-norm clipping.

States live in the same PartitionSpec tree as the params (FSDP shards both),
so optimizer memory scales down with the data axis.  No-decay mask covers
norms/biases/1-D params (standard).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array          # () int32
    m: Dict
    v: Dict


def adamw_init(params: Dict) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def adamw_abstract(params: Dict) -> AdamWState:
    """ShapeDtypeStruct state tree (dry-run lowering)."""
    z = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    return AdamWState(jax.ShapeDtypeStruct((), jnp.int32), z,
                      jax.tree.map(lambda x: x, z))


def cosine_schedule(cfg: TrainConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def clip_by_global_norm(grads: Dict, max_norm: float = 1.0
                        ) -> Tuple[Dict, jax.Array]:
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def _decay_mask(params: Dict) -> Dict:
    return jax.tree.map(lambda p: float(p.ndim >= 2), params)


def adamw_update(params: Dict, grads: Dict, state: AdamWState,
                 cfg: TrainConfig, *, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8) -> Tuple[Dict, AdamWState, Dict]:
    grads, gnorm = clip_by_global_norm(grads)
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    mask = _decay_mask(params)

    def upd(p, g, m, v, wd_on):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / (1 - b1 ** step)
        vhat = v_new / (1 - b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + eps) \
            + cfg.weight_decay * wd_on * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_mask = treedef.flatten_up_to(mask)
    outs = [upd(p, g, m, v, w) for p, g, m, v, w in
            zip(flat_p, flat_g, flat_m, flat_v, flat_mask)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
