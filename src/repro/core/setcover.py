"""RoI-mask combinatorial optimization (paper §3.3, Eq. 1-2).

    min |M|   s.t.  every constraint keeps >= 1 appearance region R with
                    all tiles of R inside M.

The paper hands this to Gurobi; we solve it in-repo:

  * ``greedy``   — cost-effectiveness greedy over regions (new-tiles /
                   newly-satisfied-constraints), the classic ln(n) set-cover
                   heuristic adapted to the one-of-many-regions constraint.
  * ``exact``    — branch-and-bound on the region choice of the most
                   constrained unsatisfied constraint, bounded by an
                   LP-relaxation lower bound (scipy HiGHS linprog) and
                   warm-started by the greedy incumbent.
  * ``milp``     — scipy.optimize.milp (HiGHS) on the full ILP; used as the
                   cross-check oracle in tests.

Preprocessing does most of the work on real instances: constraints are
dedup'd, single-region constraints force their tiles in, and constraints
already satisfied by forced tiles are dropped — what survives is a small
core instance.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.association import AssociationTable, Region


@dataclass
class SolveResult:
    mask: FrozenSet[int]          # chosen global tile ids (the union mask M)
    lower_bound: float            # certified LB on |M| (exact => LB == |M|)
    method: str
    nodes: int = 0
    optimal: bool = False
    wall_s: float = 0.0


# ---------------------------------------------------------------------------
# preprocessing
# ---------------------------------------------------------------------------

@dataclass
class CoreInstance:
    forced: Set[int]                       # tiles forced by singleton constraints
    constraints: List[List[FrozenSet[int]]]  # residual tile-sets (forced removed)


def preprocess(constraints: Sequence[Sequence[Region]]) -> CoreInstance:
    # dedup by the multiset of region tile-sets
    seen = set()
    uniq: List[List[FrozenSet[int]]] = []
    for regions in constraints:
        key = frozenset(r.tiles for r in regions)
        if key in seen:
            continue
        seen.add(key)
        # drop dominated regions (a superset of another candidate never helps)
        tsets = sorted((r.tiles for r in regions), key=len)
        kept: List[FrozenSet[int]] = []
        for ts in tsets:
            if not any(k <= ts for k in kept):
                kept.append(ts)
        uniq.append(kept)

    forced: Set[int] = set()
    remaining = uniq
    while True:
        progress = False
        nxt: List[List[FrozenSet[int]]] = []
        for regions in remaining:
            resid = [ts - forced for ts in regions]
            if any(len(r) == 0 for r in resid):
                continue  # already satisfied
            if len(resid) == 1:
                forced |= resid[0]
                progress = True
                continue
            nxt.append([frozenset(r) for r in resid])
        remaining = nxt
        if not progress:
            break
    # final sweep: constraints satisfied by late-forced tiles
    remaining = [
        [ts - forced for ts in regions] for regions in remaining
        if not any(len(ts - forced) == 0 for ts in regions)
    ]
    # re-dedup the residual core
    seen2 = set()
    core: List[List[FrozenSet[int]]] = []
    for regions in remaining:
        key = frozenset(frozenset(ts) for ts in regions)
        if key not in seen2:
            seen2.add(key)
            core.append([frozenset(ts) for ts in regions])
    return CoreInstance(forced, core)


# ---------------------------------------------------------------------------
# greedy
# ---------------------------------------------------------------------------

def _greedy_core(core: CoreInstance,
                 seed: Optional[Set[int]] = None) -> Set[int]:
    """Cost-effectiveness greedy on a bitset representation.

    The set-based formulation recomputed constraint satisfaction for every
    (constraint, region) pair per iteration — O(n^3) Python set ops.  Here
    every region is one row of a bool matrix over the core's tile universe;
    per-constraint satisfaction of a candidate collapses to a vectorized
    "any region's residual ⊆ candidate" matrix reduction, and residuals are
    updated incrementally after each pick instead of rebuilt.  Candidate
    enumeration order (constraint order, then region order) matches the old
    code, so tie-breaking — and therefore the chosen mask — is identical.

    ``seed`` warm-starts the solve from an existing mask (the online
    drift adapter re-solves incrementally): seeded tiles count as already
    chosen — constraints with a fully-seeded region are satisfied up
    front, residuals shrink accordingly, and the greedy only pays for
    tiles the seed doesn't already cover.  The returned set contains ONLY
    the newly chosen tiles (callers union with their seed).  ``seed=None``
    (or empty) is byte-identical to the cold solve."""
    ncons = len(core.constraints)
    if ncons == 0:
        return set()
    tiles = sorted({t for regions in core.constraints
                    for ts in regions for t in ts})
    tidx = {t: i for i, t in enumerate(tiles)}
    nt = len(tiles)
    region_cons: List[int] = []            # region row -> owning constraint
    rows: List[np.ndarray] = []
    for ci, regions in enumerate(core.constraints):
        for ts in regions:
            row = np.zeros(nt, bool)
            row[[tidx[t] for t in ts]] = True
            rows.append(row)
            region_cons.append(ci)
    R = np.stack(rows)                     # (nreg, nt) region membership
    rcons = np.asarray(region_cons)

    resid = R.copy()                       # region tiles still uncovered
    chosen = np.zeros(nt, bool)
    unsat = np.ones(ncons, bool)

    if seed:
        seeded = np.zeros(nt, bool)
        hits = [tidx[t] for t in seed if t in tidx]
        if hits:
            seeded[hits] = True
            resid &= ~seeded               # seeded tiles are free
            unsat[rcons[~resid.any(axis=1)]] = False

    while unsat.any():
        best = None                        # (score, region_row_index)
        # candidates: every region of every unsatisfied constraint, in the
        # original (constraint, region) order
        cand = np.nonzero(unsat[rcons])[0]
        resid_counts = resid.sum(axis=1)
        for ri in cand:
            new = resid[ri]
            n_new = int(resid_counts[ri])
            # regions fully covered once `new` joins chosen: residual ⊆ new
            sat_region = ~np.any(resid & ~new, axis=1)
            nsat = int(np.count_nonzero(
                np.bincount(rcons[sat_region], minlength=ncons)
                .astype(bool) & unsat))
            score = (n_new / max(nsat, 1), n_new)
            if best is None or score < best[0]:
                best = (score, ri)
        new = resid[best[1]].copy()
        chosen |= new
        resid &= ~new                      # incremental residual update
        unsat[rcons[~resid.any(axis=1)]] = False
    return {tiles[i] for i in np.nonzero(chosen)[0]}


def solve_greedy(table: AssociationTable) -> SolveResult:
    t0 = time.time()
    core = preprocess(table.constraints)
    chosen = _greedy_core(core)
    mask = frozenset(core.forced | chosen)
    return SolveResult(mask, float(len(core.forced)), "greedy",
                       wall_s=time.time() - t0)


def solve_warm(table: AssociationTable, seed_mask) -> SolveResult:
    """Incremental greedy re-solve seeded from an existing mask.

    The online drift adapter's path: constraints come from a recent
    observation window, ``seed_mask`` is the currently deployed mask.  The
    result always contains the seed (deployed tiles are not retracted
    mid-stream — shrinking is an offline decision) plus the cheapest greedy
    completion for the constraints the seed no longer covers.  Cost scales
    with the residual core, not the full offline instance."""
    t0 = time.time()
    seed = set(seed_mask)
    core = preprocess(table.constraints)
    chosen = _greedy_core(core, seed=seed)
    mask = frozenset(seed | core.forced | chosen)
    return SolveResult(mask, float(len(core.forced)), "greedy-warm",
                       wall_s=time.time() - t0)


# ---------------------------------------------------------------------------
# LP relaxation (lower bound)
# ---------------------------------------------------------------------------

def _lp_bound(core: CoreInstance) -> float:
    """LP relaxation of the residual core (forced tiles excluded)."""
    from scipy.optimize import linprog
    from scipy.sparse import lil_matrix

    tiles = sorted({t for regions in core.constraints
                    for ts in regions for t in ts})
    if not tiles or not core.constraints:
        return 0.0
    tidx = {t: i for i, t in enumerate(tiles)}
    regions_flat: List[FrozenSet[int]] = []
    cons_regions: List[List[int]] = []
    for regions in core.constraints:
        row = []
        for ts in regions:
            row.append(len(regions_flat))
            regions_flat.append(ts)
        cons_regions.append(row)

    nt, nr, nc = len(tiles), len(regions_flat), len(core.constraints)
    nvar = nt + nr
    # minimize sum x_t ; y_r <= x_t for t in r ; sum_{r in c} y_r >= 1
    c = np.zeros(nvar)
    c[:nt] = 1.0
    n_ineq = sum(len(r) for r in regions_flat) + nc
    A = lil_matrix((n_ineq, nvar))
    b = np.zeros(n_ineq)
    row = 0
    for ri, ts in enumerate(regions_flat):
        for t in ts:
            A[row, nt + ri] = 1.0      # y_r - x_t <= 0
            A[row, tidx[t]] = -1.0
            row += 1
    for ci, rs in enumerate(cons_regions):
        for ri in rs:
            A[row, nt + ri] = -1.0     # -sum y_r <= -1
        b[row] = -1.0
        row += 1
    res = linprog(c, A_ub=A.tocsr(), b_ub=b, bounds=[(0, 1)] * nvar,
                  method="highs")
    return float(res.fun) if res.success else 0.0


# ---------------------------------------------------------------------------
# exact branch & bound
# ---------------------------------------------------------------------------

def solve_exact(table: AssociationTable, *, node_cap: int = 200_000,
                time_cap_s: float = 60.0) -> SolveResult:
    t0 = time.time()
    core = preprocess(table.constraints)
    incumbent = _greedy_core(core)
    best = set(incumbent)
    lb_root = _lp_bound(core)
    nodes = 0
    capped = False

    def bound(chosen: Set[int], unsat: List[int]) -> float:
        """Cheap LB: chosen + max over constraints of min residual tiles."""
        if not unsat:
            return len(chosen)
        need = max(min(len(ts - chosen) for ts in core.constraints[ci])
                   for ci in unsat)
        return len(chosen) + need

    def dfs(chosen: Set[int], unsat: List[int]):
        nonlocal best, nodes, capped
        if capped:
            return
        nodes += 1
        if nodes > node_cap or time.time() - t0 > time_cap_s:
            capped = True
            return
        if not unsat:
            if len(chosen) < len(best):
                best = set(chosen)
            return
        if bound(chosen, unsat) >= len(best):
            return
        # branch on the constraint with fewest candidate regions, trying
        # cheapest-residual regions first
        ci = min(unsat, key=lambda i: (len(core.constraints[i]),
                                       min(len(ts - chosen)
                                           for ts in core.constraints[i])))
        options = sorted(core.constraints[ci], key=lambda ts: len(ts - chosen))
        for ts in options:
            nchosen = chosen | ts
            nunsat = [cj for cj in unsat if cj != ci and
                      not any(t2 <= nchosen for t2 in core.constraints[cj])]
            if len(nchosen) < len(best):
                dfs(nchosen, nunsat)

    unsat0 = [i for i in range(len(core.constraints))]
    dfs(set(), unsat0)
    mask = frozenset(core.forced | best)
    lb = len(core.forced) + lb_root
    optimal = (not capped) or len(mask) <= np.ceil(lb - 1e-6)
    return SolveResult(mask, float(lb), "exact", nodes=nodes,
                       optimal=optimal, wall_s=time.time() - t0)


# ---------------------------------------------------------------------------
# scipy MILP (oracle)
# ---------------------------------------------------------------------------

def solve_milp(table: AssociationTable, *, time_cap_s: float = 120.0
               ) -> SolveResult:
    from scipy.optimize import LinearConstraint, milp
    from scipy.sparse import lil_matrix

    t0 = time.time()
    core = preprocess(table.constraints)
    tiles = sorted({t for regions in core.constraints
                    for ts in regions for t in ts})
    if not tiles:
        return SolveResult(frozenset(core.forced), float(len(core.forced)),
                           "milp", optimal=True, wall_s=time.time() - t0)
    tidx = {t: i for i, t in enumerate(tiles)}
    regions_flat: List[FrozenSet[int]] = []
    cons_regions: List[List[int]] = []
    for regions in core.constraints:
        row = []
        for ts in regions:
            row.append(len(regions_flat))
            regions_flat.append(ts)
        cons_regions.append(row)
    nt, nr = len(tiles), len(regions_flat)
    nvar = nt + nr
    c = np.zeros(nvar)
    c[:nt] = 1.0
    n_rows = sum(len(r) for r in regions_flat) + len(cons_regions)
    A = lil_matrix((n_rows, nvar))
    lo = np.full(n_rows, -np.inf)
    hi = np.zeros(n_rows)
    row = 0
    for ri, ts in enumerate(regions_flat):
        for t in ts:
            A[row, nt + ri] = 1.0
            A[row, tidx[t]] = -1.0
            row += 1
    for ci, rs in enumerate(cons_regions):
        for ri in rs:
            A[row, nt + ri] = 1.0
        lo[row], hi[row] = 1.0, np.inf
        row += 1
    res = milp(c=c,
               constraints=LinearConstraint(A.tocsc(), lo, hi),
               integrality=np.ones(nvar),
               bounds=__import__("scipy.optimize", fromlist=["Bounds"])
               .Bounds(0, 1),
               options={"time_limit": time_cap_s})
    if res.x is None:
        return solve_exact(table)
    chosen = {tiles[i] for i in range(nt) if res.x[i] > 0.5}
    mask = frozenset(core.forced | chosen)
    return SolveResult(mask, len(core.forced) + float(res.fun), "milp",
                       optimal=bool(res.status == 0),
                       wall_s=time.time() - t0)


def solve(table: AssociationTable, method: str = "exact", **kw) -> SolveResult:
    if method == "greedy":
        return solve_greedy(table)
    if method == "exact":
        return solve_exact(table, **kw)
    if method == "milp":
        return solve_milp(table, **kw)
    raise ValueError(method)
