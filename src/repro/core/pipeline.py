"""CrossRoI offline + online phases (paper §4.1) and evaluation metrics.

Offline: synchronized profiling clips -> noisy ReID -> tandem filters ->
association table -> set-cover RoI masks -> tile grouping.  Online: per
segment, cameras crop to their mask, the codec model prices the encoded
groups, the server model prices inference; metrics follow §5.1.2 exactly:
accuracy, network overhead (Mbps), system throughput (server Hz + camera
fps), end-to-end response latency.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.association import (AssociationTable, TileUniverse,
                                    build_association_table)
from repro.core.compression import CodecModel, EncoderModel
from repro.core.filters import FilterConfig, FilterStats, apply_filters
from repro.core.grouping import TileGroup, group_tiles
from repro.core.reid import ReIDNoiseConfig, ReIDRecord, run_noisy_reid
from repro.core.scene import Scene
from repro.core import setcover


# ---------------------------------------------------------------------------
# server inference model (RoI-YOLO / SBNet)
# ---------------------------------------------------------------------------

@dataclass
class ServerModel:
    """Calibrated to the paper: dense YOLOv3 at 540p ~= 52 Hz on their GPU;
    SBNet RoI inference time ~= (gather/scatter overhead + RoI fraction) of
    dense time, giving 1.18x at ~55% density and 1.5-2.5x at 10-20% (§4.4).
    The structural overhead constant matches our Pallas kernel FLOP model
    (kernels/sbnet: gather+scatter move 2x the active bytes)."""
    dense_hz: float = 52.07
    sbnet_overhead: float = 0.30
    switch_density: float = 0.70   # above this, fall back to dense YOLO

    def speedup(self, roi_density: float) -> float:
        if roi_density >= self.switch_density:
            return 1.0
        return 1.0 / (self.sbnet_overhead + roi_density)

    def throughput_hz(self, roi_density: float, roi_inference: bool) -> float:
        if not roi_inference:
            return self.dense_hz
        return self.dense_hz * self.speedup(roi_density)


# ---------------------------------------------------------------------------
# offline phase
# ---------------------------------------------------------------------------

@dataclass
class OfflineConfig:
    profile_frames: int = 600            # 60 s at 10 fps (paper)
    filters: FilterConfig = field(default_factory=FilterConfig)
    reid_noise: ReIDNoiseConfig = field(default_factory=ReIDNoiseConfig)
    solver: str = "exact"                # greedy | exact | milp
    merge_tiles: bool = True             # No-Merging ablation switch


@dataclass
class OfflineResult:
    universe: TileUniverse
    mask: FrozenSet[int]                      # union mask M (global tile ids)
    cam_grids: Dict[int, np.ndarray]          # per-cam bool (ty, tx)
    cam_groups: Dict[int, List[TileGroup]]    # per-cam merged rectangles
    solve: setcover.SolveResult
    filter_stats: FilterStats
    reid_records: List[ReIDRecord]
    table: AssociationTable
    wall_s: float = 0.0

    def mask_fraction(self, cam: int) -> float:
        g = self.cam_grids[cam]
        return float(g.mean())

    def mask_area_px(self, cam: int) -> float:
        c = self.universe.cameras[cam]
        total = 0.0
        for g in self.cam_groups[cam]:
            x0, y0 = g.x0 * c.tile, g.y0 * c.tile
            total += (min(g.w * c.tile, c.width - x0)
                      * min(g.h * c.tile, c.height - y0))
        return total

    @property
    def fleet_density(self) -> float:
        """RoI pixels / total pixels across the fleet."""
        tot = sum(c.width * c.height for c in self.universe.cameras)
        return sum(self.mask_area_px(c.cam_id)
                   for c in self.universe.cameras) / tot


def run_offline(scene: Scene, cfg: Optional[OfflineConfig] = None
                ) -> OfflineResult:
    cfg = cfg or OfflineConfig()
    t0 = time.time()
    universe = TileUniverse.build(scene.cameras)

    records = run_noisy_reid(scene, cfg.reid_noise, 0, cfg.profile_frames)
    cleaned, fstats = apply_filters(records, len(scene.cameras), cfg.filters)
    table = build_association_table(cleaned, universe)
    sres = setcover.solve(table, cfg.solver)

    cam_grids = {c.cam_id: universe.cam_mask_grid(c.cam_id, sres.mask)
                 for c in scene.cameras}
    cam_groups = {}
    for c in scene.cameras:
        grid = cam_grids[c.cam_id]
        if cfg.merge_tiles:
            cam_groups[c.cam_id] = group_tiles(grid)
        else:  # No-Merging: every tile its own group
            ys, xs = np.nonzero(grid)
            cam_groups[c.cam_id] = [TileGroup(int(y), int(x), 1, 1)
                                    for y, x in zip(ys, xs)]
    return OfflineResult(universe, sres.mask, cam_grids, cam_groups, sres,
                         fstats, cleaned, table, wall_s=time.time() - t0)


def full_frame_offline(scene: Scene) -> OfflineResult:
    """Baseline ablation: mask = everything (no CrossRoI)."""
    universe = TileUniverse.build(scene.cameras)
    mask = frozenset(range(universe.num_tiles))
    cam_grids = {c.cam_id: np.ones((c.tiles_y, c.tiles_x), bool)
                 for c in scene.cameras}
    cam_groups = {c.cam_id: [TileGroup(0, 0, c.tiles_y, c.tiles_x)]
                  for c in scene.cameras}
    sres = setcover.SolveResult(mask, 0.0, "baseline")
    return OfflineResult(universe, mask, cam_grids, cam_groups, sres,
                         FilterStats(), [], AssociationTable(universe, [], []))


# ---------------------------------------------------------------------------
# online phase
# ---------------------------------------------------------------------------

@dataclass
class OnlineConfig:
    segment_s: float = 1.0
    bandwidth_mbps: float = 30.0
    rtt_ms: float = 10.0
    roi_inference: bool = True            # No-RoIInf ablation switch
    frame_keep: Optional[Dict[int, np.ndarray]] = None  # Reducto keep masks
    # Detector tolerance: YOLO still finds an object when a thin boundary
    # strip is cropped; a detection counts if >= this fraction of the bbox
    # pixel area survives the RoI crop.  1.0 recovers the strict
    # every-tile-covered criterion the optimizer guarantees for >= 1
    # appearance of every profiled object.
    coverage_thresh: float = 0.75


@dataclass
class OnlineMetrics:
    accuracy: float
    missed: int
    total_appearances: int
    missed_per_t: np.ndarray
    network_mbps: float
    server_hz: float
    camera_fps: float
    latency_s: float
    latency_parts: Dict[str, float]
    frames_reduced: int = 0


def _covered(tiles: FrozenSet[int], mask: FrozenSet[int]) -> bool:
    return tiles <= mask


def bbox_mask_area(cam, grid: np.ndarray, b) -> float:
    """Pixel area of bbox ∩ RoI mask (sum over intersected tile rects)."""
    x0 = max(int(b.left) // cam.tile, 0)
    x1 = min(int(np.ceil(b.right / cam.tile)), cam.tiles_x)
    y0 = max(int(b.top) // cam.tile, 0)
    y1 = min(int(np.ceil(b.bottom / cam.tile)), cam.tiles_y)
    area = 0.0
    for ty in range(y0, y1):
        for tx in range(x0, x1):
            if not grid[ty, tx]:
                continue
            ix = min(b.right, (tx + 1) * cam.tile) - max(b.left, tx * cam.tile)
            iy = min(b.bottom, (ty + 1) * cam.tile) - max(b.top, ty * cam.tile)
            if ix > 0 and iy > 0:
                area += ix * iy
    return area


def _detects(scene: Scene, offline: OfflineResult, d, thresh: float) -> bool:
    """Whether the server's detector finds detection ``d`` after RoI crop."""
    cam = scene.cameras[d.cam]
    if thresh >= 1.0:
        tiles = offline.universe.globalize(d.cam, cam.bbox_tiles(d.bbox))
        return _covered(tiles, offline.mask)
    cov = bbox_mask_area(cam, offline.cam_grids[d.cam], d.bbox)
    return cov >= thresh * max(d.bbox.area, 1.0)


def run_online(scene: Scene, offline: OfflineResult,
               cfg: Optional[OnlineConfig] = None,
               t0: Optional[int] = None, t1: Optional[int] = None
               ) -> OnlineMetrics:
    cfg = cfg or OnlineConfig()
    t0 = t0 if t0 is not None else 600          # eval = last 120 s (paper)
    t1 = t1 if t1 is not None else len(scene.detections)
    n_frames = t1 - t0
    fps = scene.cfg.fps
    universe = offline.universe
    codec = CodecModel.calibrated(scene.cameras, fps)
    encoder = EncoderModel()
    server = ServerModel()

    # ---- accuracy: unique-vehicle detection per timestamp ----------------
    missed_per_t = np.zeros(n_frames, np.int64)
    total = 0
    keep = cfg.frame_keep
    last_counts: Dict[int, set] = {}  # per-camera last streamed detections
    for ti in range(t0, t1):
        dets = scene.detections[ti]
        vis_objs = {d.obj for d in dets}
        total += len(vis_objs)
        detected = set()
        cur_by_cam: Dict[int, set] = {c.cam_id: set() for c in scene.cameras}
        for d in dets:
            if _detects(scene, offline, d, cfg.coverage_thresh):
                cur_by_cam[d.cam].add(d.obj)
        for d in dets:
            if keep is not None and not keep[d.cam][ti - t0]:
                # frame filtered: server reuses the last streamed result
                if d.obj in last_counts.get(d.cam, set()):
                    detected.add(d.obj)
                continue
            if d.obj in cur_by_cam[d.cam]:
                detected.add(d.obj)
        # update last streamed per camera
        for c in scene.cameras:
            if keep is None or keep[c.cam_id][ti - t0]:
                last_counts[c.cam_id] = cur_by_cam[c.cam_id]
        missed_per_t[ti - t0] = len(vis_objs - detected)
    missed = int(missed_per_t.sum())
    accuracy = 1.0 - missed / max(total, 1)

    # ---- network overhead -------------------------------------------------
    frames_per_seg = max(int(round(cfg.segment_s * fps)), 1)
    n_segs = max(n_frames // frames_per_seg, 1)
    # per-frame activity: fraction of streamed content that changed; approx
    # by object bbox area within the mask relative to mask area
    total_bytes = 0.0
    frames_sent_per_cam = np.zeros(len(scene.cameras), np.int64)
    for c in scene.cameras:
        cid = c.cam_id
        groups = offline.cam_groups[cid]
        for si in range(n_segs):
            s0, s1 = t0 + si * frames_per_seg, t0 + (si + 1) * frames_per_seg
            if keep is not None:
                sent = int(keep[cid][s0 - t0:s1 - t0].sum())
            else:
                sent = frames_per_seg
            if sent == 0:
                continue
            frames_sent_per_cam[cid] += sent
            # segment compression efficiency improves with longer segments
            # (more temporal references): activity ~ 1/sqrt(seg frames / 10)
            act = 1.0 / np.sqrt(max(sent, 1) / 10.0) * 0.9 + 0.1
            total_bytes += codec.groups_bytes(cid, groups, sent, act)
    duration_s = n_frames / fps
    network_mbps = total_bytes * 8.0 / duration_s / 1e6

    # ---- throughput ---------------------------------------------------------
    roi_density = offline.fleet_density
    server_hz = server.throughput_hz(roi_density, cfg.roi_inference)
    # camera fps: bounded by encode speed over the cropped area (worst cam)
    worst_area = max(offline.mask_area_px(c.cam_id) for c in scene.cameras)
    camera_fps = min(encoder.throughput_fps(worst_area), 160.0)

    # ---- end-to-end latency -------------------------------------------------
    seg = cfg.segment_s
    wait = seg / 2.0                                     # frame->segment close
    frames_seg = frames_per_seg
    enc = max(offline.mask_area_px(c.cam_id) * frames_seg
              for c in scene.cameras) / encoder.pixels_per_s
    seg_bytes = total_bytes / n_segs
    tx = seg_bytes * 8.0 / (cfg.bandwidth_mbps * 1e6) + cfg.rtt_ms / 2e3
    # the server runs the segment's fleet-frames through the detector in
    # arrival order: the average frame sits behind half the segment, plus
    # one in-flight frame per camera stream.
    avg_sent_per_seg = float(frames_sent_per_cam.sum()) / n_segs
    infer = (avg_sent_per_seg / 2.0 + len(scene.cameras)) / server_hz
    latency = wait + enc + tx + infer
    parts = {"wait": wait, "encode": enc, "network": tx, "inference": infer}

    frames_reduced = 0
    if keep is not None:
        frames_reduced = int(sum((~keep[c.cam_id]).sum()
                                 for c in scene.cameras))
    return OnlineMetrics(accuracy, missed, total, missed_per_t, network_mbps,
                         server_hz, camera_fps, latency, parts, frames_reduced)
