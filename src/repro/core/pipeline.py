"""CrossRoI offline + online phases (paper §4.1) and evaluation metrics.

Offline: synchronized profiling clips -> noisy ReID -> tandem filters ->
association table -> set-cover RoI masks -> tile grouping.  Online: per
segment, cameras crop to their mask, the codec model prices the encoded
groups, the server model prices inference; metrics follow §5.1.2 exactly:
accuracy, network overhead (Mbps), system throughput (server Hz + camera
fps), end-to-end response latency.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.association import (AssociationTable, TileUniverse,
                                    build_association_table)
from repro.core.compression import CodecModel, EncoderModel
from repro.core.filters import FilterConfig, FilterStats, apply_filters
from repro.core.grouping import TileGroup, group_tiles
from repro.core.reid import ReIDNoiseConfig, ReIDRecord, run_noisy_reid
from repro.core.scene import Scene
from repro.core import setcover
# the edge-to-server streaming runtime (numpy-only at import time); the
# analytic byte model delegates to its packetizer so the analytic and
# simulated transport paths cannot drift apart
from repro.net.batcher import NetConfig, TransportStats, simulate_transport
from repro.net.encoder import (camera_coefficients, segment_byte_matrices,
                               sent_matrix)


# ---------------------------------------------------------------------------
# server inference model (RoI-YOLO / SBNet)
# ---------------------------------------------------------------------------

# one gather + one scatter move ~2x the active-tile bytes: the structural
# I/O tax of RoI inference, in dense-time units.  Canonical home; the
# detector's cost model imports it, and tests/test_packed_path.py pins the
# detector and ServerModel speedup curves to each other.
IO_ROUND_TRIP_OVERHEAD = 0.30


@dataclass
class ServerModel:
    """Calibrated to the paper: dense YOLOv3 at 540p ~= 52 Hz on their GPU;
    SBNet RoI inference time ~= (gather/scatter overhead + RoI fraction) of
    dense time, giving 1.18x at ~55% density and 1.5-2.5x at 10-20% (§4.4).

    The paper's SBNet pays the gather/scatter round-trip (moving ~2x the
    active bytes) once *per conv layer*; our packed-resident kernel chain
    (kernels/roi_conv.roi_conv_packed) pays it once *per stack* — gather is
    fused into the first conv, layers stay packed via neighbor-table halos,
    and a single scatter materializes the output.  The structural overhead
    is therefore the round-trip constant amortized over ``num_layers``
    (num_layers=1 recovers the paper's per-layer SBNet regime)."""
    dense_hz: float = 52.07
    io_round_trip: float = IO_ROUND_TRIP_OVERHEAD
    num_layers: int = 3            # conv stack depth the round-trip amortizes over
    switch_density: float = 0.70   # above this, fall back to dense YOLO

    @property
    def sbnet_overhead(self) -> float:
        """Per-layer gather/scatter overhead under packed execution."""
        return self.io_round_trip / max(self.num_layers, 1)

    def speedup(self, roi_density: float) -> float:
        if roi_density >= self.switch_density:
            return 1.0
        return 1.0 / (self.sbnet_overhead + roi_density)

    def throughput_hz(self, roi_density: float, roi_inference: bool) -> float:
        if not roi_inference:
            return self.dense_hz
        return self.dense_hz * self.speedup(roi_density)


# ---------------------------------------------------------------------------
# offline phase
# ---------------------------------------------------------------------------

@dataclass
class OfflineConfig:
    profile_frames: int = 600            # 60 s at 10 fps (paper)
    filters: FilterConfig = field(default_factory=FilterConfig)
    reid_noise: ReIDNoiseConfig = field(default_factory=ReIDNoiseConfig)
    solver: str = "exact"                # greedy | exact | milp
    merge_tiles: bool = True             # No-Merging ablation switch


@dataclass
class OfflineResult:
    universe: TileUniverse
    mask: FrozenSet[int]                      # union mask M (global tile ids)
    cam_grids: Dict[int, np.ndarray]          # per-cam bool (ty, tx)
    cam_groups: Dict[int, List[TileGroup]]    # per-cam merged rectangles
    solve: setcover.SolveResult
    filter_stats: FilterStats
    reid_records: List[ReIDRecord]
    table: AssociationTable
    wall_s: float = 0.0

    def mask_fraction(self, cam: int) -> float:
        g = self.cam_grids[cam]
        return float(g.mean())

    def mask_area_px(self, cam: int) -> float:
        c = self.universe.cameras[cam]
        total = 0.0
        for g in self.cam_groups[cam]:
            x0, y0 = g.x0 * c.tile, g.y0 * c.tile
            total += (min(g.w * c.tile, c.width - x0)
                      * min(g.h * c.tile, c.height - y0))
        return total

    @property
    def fleet_density(self) -> float:
        """RoI pixels / total pixels across the fleet."""
        tot = sum(c.width * c.height for c in self.universe.cameras)
        return sum(self.mask_area_px(c.cam_id)
                   for c in self.universe.cameras) / tot


def run_offline(scene: Scene, cfg: Optional[OfflineConfig] = None,
                t0_frame: int = 0) -> OfflineResult:
    """``t0_frame`` shifts the profiling window to
    [t0_frame, t0_frame + profile_frames) — the drift adapter uses it to
    re-profile on a recent window of the stream (shrink re-solves)."""
    cfg = cfg or OfflineConfig()
    t0 = time.time()
    universe = TileUniverse.build(scene.cameras)

    records = run_noisy_reid(scene, cfg.reid_noise, t0_frame,
                             t0_frame + cfg.profile_frames)
    cleaned, fstats = apply_filters(records, len(scene.cameras), cfg.filters)
    table = build_association_table(cleaned, universe)
    sres = setcover.solve(table, cfg.solver)

    cam_grids = {c.cam_id: universe.cam_mask_grid(c.cam_id, sres.mask)
                 for c in scene.cameras}
    cam_groups = {}
    for c in scene.cameras:
        grid = cam_grids[c.cam_id]
        if cfg.merge_tiles:
            cam_groups[c.cam_id] = group_tiles(grid)
        else:  # No-Merging: every tile its own group
            ys, xs = np.nonzero(grid)
            cam_groups[c.cam_id] = [TileGroup(int(y), int(x), 1, 1)
                                    for y, x in zip(ys, xs)]
    return OfflineResult(universe, sres.mask, cam_grids, cam_groups, sres,
                         fstats, cleaned, table, wall_s=time.time() - t0)


def full_frame_offline(scene: Scene) -> OfflineResult:
    """Baseline ablation: mask = everything (no CrossRoI)."""
    universe = TileUniverse.build(scene.cameras)
    mask = frozenset(range(universe.num_tiles))
    cam_grids = {c.cam_id: np.ones((c.tiles_y, c.tiles_x), bool)
                 for c in scene.cameras}
    cam_groups = {c.cam_id: [TileGroup(0, 0, c.tiles_y, c.tiles_x)]
                  for c in scene.cameras}
    sres = setcover.SolveResult(mask, 0.0, "baseline")
    return OfflineResult(universe, mask, cam_grids, cam_groups, sres,
                         FilterStats(), [], AssociationTable(universe, [], []))


# ---------------------------------------------------------------------------
# online phase
# ---------------------------------------------------------------------------

@dataclass
class OnlineConfig:
    segment_s: float = 1.0
    bandwidth_mbps: float = 30.0
    rtt_ms: float = 10.0
    roi_inference: bool = True            # No-RoIInf ablation switch
    frame_keep: Optional[Dict[int, np.ndarray]] = None  # Reducto keep masks
    # transport pricing: "analytic" is the steady-state scalar formula;
    # "simulated" runs the repro.net edge-to-server runtime (per-camera
    # uplinks, rate control, deadline batching) and yields per-frame
    # latency distributions.  ``net`` configures the simulated path.
    transport: str = "analytic"
    net: Optional[NetConfig] = None
    # Detector tolerance: YOLO still finds an object when a thin boundary
    # strip is cropped; a detection counts if >= this fraction of the bbox
    # pixel area survives the RoI crop.  1.0 recovers the strict
    # every-tile-covered criterion the optimizer guarantees for >= 1
    # appearance of every profiled object.
    coverage_thresh: float = 0.75


@dataclass
class OnlineMetrics:
    accuracy: float
    missed: int
    total_appearances: int
    missed_per_t: np.ndarray
    network_mbps: float
    server_hz: float
    camera_fps: float
    latency_s: float
    latency_parts: Dict[str, float]
    frames_reduced: int = 0
    # per-frame latency distribution (simulated transport only)
    transport: Optional[TransportStats] = None

    @property
    def latency_p50_s(self) -> float:
        return self.transport.p50_s if self.transport else self.latency_s

    @property
    def latency_p99_s(self) -> float:
        return self.transport.p99_s if self.transport else self.latency_s


def _covered(tiles: FrozenSet[int], mask: FrozenSet[int]) -> bool:
    return tiles <= mask


def integral_image(grid: np.ndarray) -> np.ndarray:
    """(H, W) counts -> (H+1, W+1) 2-D prefix sums: rect sums in 4 lookups
    (I[y1+1, x1+1] - I[y0, x1+1] - I[y1+1, x0] + I[y0, x0])."""
    I = np.zeros((grid.shape[0] + 1, grid.shape[1] + 1), np.int64)
    I[1:, 1:] = grid.astype(np.int64).cumsum(0).cumsum(1)
    return I


def _bbox_tile_overlaps(cam, lefts, tops, rights, bottoms):
    """Per-axis bbox/tile-row overlap lengths for a batch of boxes.

    Returns (iy (n, tiles_y), ix (n, tiles_x)): clipped intersection length
    of each bbox with each tile row/column — the separable factors of the
    bbox ∩ tile-rect areas (area[n, ty, tx] = iy[n, ty] * ix[n, tx])."""
    T = cam.tile
    txs = np.arange(cam.tiles_x) * T
    tys = np.arange(cam.tiles_y) * T
    ix = np.clip(np.minimum(rights[:, None], txs[None, :] + T)
                 - np.maximum(lefts[:, None], txs[None, :]), 0.0, None)
    iy = np.clip(np.minimum(bottoms[:, None], tys[None, :] + T)
                 - np.maximum(tops[:, None], tys[None, :]), 0.0, None)
    return iy, ix


def bbox_mask_area(cam, grid: np.ndarray, b) -> float:
    """Pixel area of bbox ∩ RoI mask (sum over intersected tile rects).
    Scalar fast path: touches only the tiles the bbox intersects (callers
    loop per detection; the full-grid form lives in _detects_batch)."""
    T = cam.tile
    x0 = max(int(b.left) // T, 0)
    x1 = min(int(np.ceil(b.right / T)), cam.tiles_x)
    y0 = max(int(b.top) // T, 0)
    y1 = min(int(np.ceil(b.bottom / T)), cam.tiles_y)
    if x1 <= x0 or y1 <= y0:
        return 0.0
    txs = np.arange(x0, x1) * T
    tys = np.arange(y0, y1) * T
    ix = np.clip(np.minimum(b.right, txs + T) - np.maximum(b.left, txs),
                 0.0, None)
    iy = np.clip(np.minimum(b.bottom, tys + T) - np.maximum(b.top, tys),
                 0.0, None)
    return float(iy @ grid[y0:y1, x0:x1].astype(np.float64) @ ix)


def bbox_arrays(bboxes) -> Tuple[np.ndarray, ...]:
    """(left, top, right, bottom, area) float64 arrays for a bbox batch."""
    n = len(bboxes)
    l = np.fromiter((b.left for b in bboxes), np.float64, n)
    t = np.fromiter((b.top for b in bboxes), np.float64, n)
    r = np.fromiter((b.right for b in bboxes), np.float64, n)
    btm = np.fromiter((b.bottom for b in bboxes), np.float64, n)
    area = np.fromiter((b.area for b in bboxes), np.float64, n)
    return l, t, r, btm, area


def coverage_flags_batched(cameras: Sequence, grids: Sequence[np.ndarray],
                           det_cam: np.ndarray, l: np.ndarray, t: np.ndarray,
                           r: np.ndarray, btm: np.ndarray, area: np.ndarray,
                           thresh: float, chunk: int = 8192) -> np.ndarray:
    """Detector coverage flags for a flat detection batch spanning ANY set
    of cameras — one scene's five or a whole fleet's K groups — with no
    per-camera Python loop.  ``det_cam`` indexes positionally into
    ``cameras``/``grids``.  Per-camera grids are laid out on a padded
    (C, TY, TX) canvas; the padding is all-False and every bbox is clipped
    to its own frame, so results are exactly the per-camera evaluation.

    thresh >= 1.0 is the strict every-tile-covered criterion (stacked
    integral images, 4 gathers per bbox); below it, a detection counts if
    >= thresh of its pixel area survives the RoI crop (separable
    bbox/tile-rect overlap, contracted in camera-indexed chunks)."""
    n = det_cam.shape[0]
    if n == 0:
        return np.zeros(0, bool)
    T = cameras[0].tile
    assert all(c.tile == T for c in cameras), "fleet cameras share tile size"
    tiles_x = np.asarray([c.tiles_x for c in cameras], np.int64)
    tiles_y = np.asarray([c.tiles_y for c in cameras], np.int64)
    TY, TX = int(tiles_y.max()), int(tiles_x.max())
    if thresh >= 1.0:
        I = np.zeros((len(cameras), TY + 1, TX + 1), np.int64)
        for ci, g in enumerate(grids):
            I[ci, :g.shape[0] + 1, :g.shape[1] + 1] = integral_image(g)
        cx, cy = tiles_x[det_cam], tiles_y[det_cam]
        x0 = np.clip(l.astype(np.int64) // T, 0, cx)
        y0 = np.clip(t.astype(np.int64) // T, 0, cy)
        x1 = np.minimum(np.ceil(r / T).astype(np.int64) - 1, cx - 1)
        y1 = np.minimum(np.ceil(btm / T).astype(np.int64) - 1, cy - 1)
        empty = (x1 < x0) | (y1 < y0)
        # clamp lookup corners so empty rects stay in-bounds (their cnt is
        # discarded — `empty` short-circuits to covered)
        x1c = np.maximum(x1, x0 - 1)
        y1c = np.maximum(y1, y0 - 1)
        cnt = (I[det_cam, y1c + 1, x1c + 1] - I[det_cam, y0, x1c + 1]
               - I[det_cam, y1c + 1, x0] + I[det_cam, y0, x0])
        full = cnt == (y1c - y0 + 1) * (x1c - x0 + 1)
        return empty | full
    G = np.zeros((len(cameras), TY, TX), np.float64)
    for ci, g in enumerate(grids):
        G[ci, :g.shape[0], :g.shape[1]] = g
    txs = np.arange(TX) * T
    tys = np.arange(TY) * T
    cov = np.empty(n, np.float64)
    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        ix = np.clip(np.minimum(r[s:e, None], txs[None, :] + T)
                     - np.maximum(l[s:e, None], txs[None, :]), 0.0, None)
        iy = np.clip(np.minimum(btm[s:e, None], tys[None, :] + T)
                     - np.maximum(t[s:e, None], tys[None, :]), 0.0, None)
        cov[s:e] = np.einsum("ny,nx,nyx->n", iy, ix, G[det_cam[s:e]])
    return cov >= thresh * np.maximum(area, 1.0)


def _detects_batch(cam, offline: OfflineResult, bboxes, thresh: float
                   ) -> np.ndarray:
    """Vectorized ``_detects`` over all of one camera's detections."""
    grid = offline.cam_grids[cam.cam_id]
    l, t, r, btm, area = bbox_arrays(bboxes)
    det_cam = np.zeros(len(bboxes), np.int64)
    return coverage_flags_batched([cam], [grid], det_cam, l, t, r, btm,
                                  area, thresh)


def segment_network_bytes(cameras: Sequence, cam_groups, codec: CodecModel,
                          keep, n_segs: int, frames_per_seg: int
                          ) -> Tuple[float, np.ndarray]:
    """Vectorized (cameras x segments) streaming model.

    Delegates to the ``repro.net.encoder`` packetizer: per-segment
    sent-frame counts come from one reshape-sum over the keep masks, and
    the codec's group pricing — linear in activity — collapses to
    per-camera (body, halo, header) coefficients times the segment
    activity series.  Headers are charged per shipped segment and ONLY
    for cameras with a nonzero mask: an empty-mask camera streams nothing
    — no container overhead, and its ``frames_sent`` entry is 0 (it used
    to report full frame counts, which leaked phantom frames into the
    fleet latency/transport model).  Returns (total_bytes, frames_sent
    (C,) int64 positional per camera)."""
    coef = camera_coefficients(cameras, cam_groups, codec)
    sent = sent_matrix(cameras, coef, keep, n_segs, frames_per_seg)
    body, halo, headers = segment_byte_matrices(coef, sent)
    return float((body + halo + headers).sum()), sent.sum(axis=1)


def online_system_metrics(cameras: Sequence, offline: OfflineResult,
                          cfg: "OnlineConfig", fps: float, n_frames: int,
                          keep=None):
    """Network / throughput / latency block of the online phase, shared by
    ``run_online`` (one scene) and the fleet runtime (per group) so the
    two stay numerically identical by construction.  Returns
    (network_mbps, server_hz, camera_fps, latency_s, latency_parts,
    total_bytes, frames_sent (C,), transport).

    ``cfg.transport`` selects the pricing: "analytic" keeps the paper's
    steady-state scalar formula; "simulated" runs the ``repro.net``
    edge-to-server runtime (per-camera uplink FIFOs, optional jitter/
    congestion/rate control, deadline group batching) and reports the
    per-frame distribution — ``latency_s`` becomes the per-frame mean,
    which in the uncongested limit equals the analytic value identically,
    and ``transport`` carries p50/p99 and the per-part breakdown."""
    codec = CodecModel.calibrated(cameras, fps)
    encoder = EncoderModel()
    server = ServerModel()
    frames_per_seg = max(int(round(cfg.segment_s * fps)), 1)
    n_segs = max(n_frames // frames_per_seg, 1)
    # packetize once; the simulated transport path reuses coef/sent
    # instead of rebuilding them (same math as segment_network_bytes)
    coef = camera_coefficients(cameras, offline.cam_groups, codec)
    sent = sent_matrix(cameras, coef, keep, n_segs, frames_per_seg)
    body, halo, headers = segment_byte_matrices(coef, sent)
    total_bytes = float((body + halo + headers).sum())
    frames_sent = sent.sum(axis=1)
    duration_s = n_frames / fps
    network_mbps = total_bytes * 8.0 / duration_s / 1e6

    roi_density = offline.fleet_density
    server_hz = server.throughput_hz(roi_density, cfg.roi_inference)
    # camera fps: bounded by encode speed over the cropped area (worst cam)
    worst_area = max(offline.mask_area_px(c.cam_id) for c in cameras)
    camera_fps = min(encoder.throughput_fps(worst_area), 160.0)

    seg = cfg.segment_s
    wait = seg / 2.0                                 # frame->segment close
    enc = max(offline.mask_area_px(c.cam_id) * frames_per_seg
              for c in cameras) / encoder.pixels_per_s
    seg_bytes = total_bytes / n_segs
    tx = seg_bytes * 8.0 / (cfg.bandwidth_mbps * 1e6) + cfg.rtt_ms / 2e3
    # the server runs the segment's fleet-frames through the detector in
    # arrival order: the average frame sits behind half the segment, plus
    # one in-flight frame per camera stream.
    avg_sent_per_seg = float(frames_sent.sum()) / n_segs
    infer = (avg_sent_per_seg / 2.0 + len(cameras)) / server_hz
    latency = wait + enc + tx + infer
    parts = {"wait": wait, "encode": enc, "network": tx, "inference": infer}
    transport = None
    if cfg.transport == "simulated":
        mask_areas = np.asarray([offline.mask_area_px(c.cam_id)
                                 for c in cameras])
        transport = simulate_transport(
            cameras, offline.cam_groups, codec, mask_areas, keep,
            cfg.segment_s, frames_per_seg, n_segs, cfg.bandwidth_mbps,
            cfg.rtt_ms, server_hz, encoder.pixels_per_s, cfg.net,
            coef=coef, sent=sent)
        latency = transport.mean_s
        parts = transport.parts_mean()
        total_bytes = transport.bytes_total
        network_mbps = total_bytes * 8.0 / duration_s / 1e6
    elif cfg.transport != "analytic":
        raise ValueError(f"unknown transport {cfg.transport!r}")
    return (network_mbps, server_hz, camera_fps, latency, parts,
            total_bytes, frames_sent, transport)


def _detects(scene: Scene, offline: OfflineResult, d, thresh: float) -> bool:
    """Whether the server's detector finds detection ``d`` after RoI crop."""
    cam = scene.cameras[d.cam]
    if thresh >= 1.0:
        tiles = offline.universe.globalize(d.cam, cam.bbox_tiles(d.bbox))
        return _covered(tiles, offline.mask)
    cov = bbox_mask_area(cam, offline.cam_grids[d.cam], d.bbox)
    return cov >= thresh * max(d.bbox.area, 1.0)


def run_online(scene: Scene, offline: OfflineResult,
               cfg: Optional[OnlineConfig] = None,
               t0: Optional[int] = None, t1: Optional[int] = None
               ) -> OnlineMetrics:
    cfg = cfg or OnlineConfig()
    t0 = t0 if t0 is not None else 600          # eval = last 120 s (paper)
    t1 = t1 if t1 is not None else len(scene.detections)
    n_frames = t1 - t0
    fps = scene.cfg.fps
    universe = offline.universe

    # ---- accuracy: unique-vehicle detection per timestamp ----------------
    # Vectorized: (1) per-camera batched coverage flags for every detection
    # in the window (the former O(frames * dets * tiles) Python hot spot),
    # then (2) array set-logic over (frame, camera, object) occupancy
    # grids, with the Reducto frame-filter's last-streamed-result reuse
    # expressed as a per-camera forward fill over kept frames.
    missed_per_t = np.zeros(n_frames, np.int64)
    total = 0
    keep = cfg.frame_keep
    dets_flat = [(ti - t0, d) for ti in range(t0, t1)
                 for d in scene.detections[ti]]
    if dets_flat:
        nd = len(dets_flat)
        det_t = np.fromiter((t for t, _ in dets_flat), np.int64, nd)
        det_cam = np.fromiter((d.cam for _, d in dets_flat), np.int64, nd)
        obj_ids, det_obj = np.unique(
            np.fromiter((d.obj for _, d in dets_flat), np.int64, nd),
            return_inverse=True)
        l, tt, rr, bb, area = bbox_arrays([d.bbox for _, d in dets_flat])
        flags = coverage_flags_batched(
            scene.cameras, [offline.cam_grids[c.cam_id]
                            for c in scene.cameras],
            det_cam, l, tt, rr, bb, area, cfg.coverage_thresh)

        C, O = len(scene.cameras), len(obj_ids)
        present = np.zeros((n_frames, O), bool)
        present[det_t, det_obj] = True
        exists = np.zeros((n_frames, C, O), bool)     # a det at (t, cam, obj)
        exists[det_t, det_cam, det_obj] = True
        cur = np.zeros((n_frames, C, O), bool)        # ... that is detected
        cur[det_t[flags], det_cam[flags], det_obj[flags]] = True

        if keep is None:
            detected = cur.any(axis=1)
        else:
            # a filtered frame reuses the detector output of the camera's
            # most recent *streamed* frame (strictly before t)
            used = np.empty_like(cur)
            for ci, c in enumerate(scene.cameras):
                km = np.asarray(keep[c.cam_id][:n_frames], bool)
                kt = np.nonzero(km)[0]
                if kt.size == 0:                      # camera never streams
                    used[:, ci, :] = False
                    continue
                j = np.searchsorted(kt, np.arange(n_frames),
                                    side="left") - 1
                last = cur[kt[np.maximum(j, 0)], ci, :]
                last[j < 0] = False                   # nothing streamed yet
                used[:, ci, :] = np.where(km[:, None], cur[:, ci, :], last)
            detected = (exists & used).any(axis=1)

        missed_per_t = (present & ~detected).sum(axis=1).astype(np.int64)
        total = int(present.sum())
    missed = int(missed_per_t.sum())
    accuracy = 1.0 - missed / max(total, 1)

    # ---- network / throughput / latency -----------------------------------
    # per-frame activity: fraction of streamed content that changed; approx
    # by object bbox area within the mask relative to mask area; segment
    # compression efficiency improves with longer segments (more temporal
    # references): activity ~ 1/sqrt(seg frames / 10)
    (network_mbps, server_hz, camera_fps, latency, parts, _, _,
     transport) = online_system_metrics(scene.cameras, offline, cfg, fps,
                                        n_frames, keep)

    frames_reduced = 0
    if keep is not None:
        frames_reduced = int(sum((~keep[c.cam_id]).sum()
                                 for c in scene.cameras))
    return OnlineMetrics(accuracy, missed, total, missed_per_t, network_mbps,
                         server_hz, camera_fps, latency, parts,
                         frames_reduced, transport)
