"""Synthetic multi-camera traffic-intersection scene.

Reproduces the structure of the paper's evaluation scene (AI City Challenge
S02: 5 cameras around one intersection with complicated viewpoint overlap):
vehicles travel through a 4-way intersection on straight/turning trajectories;
5 cameras with overlapping fields of view observe them. Ground truth is
geometric, so ReID labels are exact and the noise model (core/reid.py) can be
calibrated against the paper's Table 2 error distributions.

Scale mirrors the paper: 10 fps, ~180 s, >30k bounding boxes across cameras.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.geometry import BBox, Camera, look_at_camera


@dataclass(frozen=True)
class Detection:
    cam: int
    t: int            # frame index
    obj: int          # ground-truth vehicle id
    bbox: BBox


@dataclass
class SceneConfig:
    num_cameras: int = 5
    fps: int = 10
    duration_s: int = 180
    spawn_rate: float = 0.55       # vehicles per second
    seed: int = 0
    road_halfwidth: float = 7.0    # two lanes each way
    approach_len: float = 80.0
    speed_range: Tuple[float, float] = (6.0, 14.0)  # m/s
    vehicle_length: float = 4.6
    vehicle_width: float = 1.9
    vehicle_height: float = 1.6
    # --- traffic profile (fleet scenario diversity; see fleet/topology) ---
    # "uniform" keeps the original constant-rate spawn process (and, with
    # the remaining fields at their defaults, the exact legacy RNG stream,
    # so seeded scenes are bit-identical to earlier revisions).
    spawn_profile: str = "uniform"       # uniform | rush_hour | sparse | bursty
    entry_weights: Optional[Tuple[float, ...]] = None  # over N, S, E, W
    turn_probs: Tuple[float, float, float] = (0.6, 0.2, 0.2)
    # --- scripted traffic shift (mask-drift evaluation, paper §5.5) ------
    # From ``shift_at_s`` on, new vehicles spawn with the shifted entry /
    # turn distributions — e.g. profiling on N/S traffic and shifting to
    # E/W traffic moves the occupied corridors, which is exactly the drift
    # the online adapter has to chase.
    shift_at_s: Optional[float] = None
    shift_entry_weights: Optional[Tuple[float, ...]] = None
    shift_turn_probs: Optional[Tuple[float, float, float]] = None

    @property
    def num_frames(self) -> int:
        return self.fps * self.duration_s


def default_cameras(tile: int = 64) -> List[Camera]:
    """5 cameras around the intersection; camera 5 is 1280x960 (as in the
    dataset used by the paper).

    Layout matches real corner-pole deployments (AI City S02 structure):
    each leg camera sits near the intersection core looking *outward* along
    its own street, and a wide center camera overlooks the core box.  Legs
    therefore overlap the center camera (and each other only marginally),
    which reproduces the paper's Table-2 label structure (TN >> FN >= TP >
    FP per ordered pair) instead of an everything-overlaps fleet."""
    specs = [
        # (eye, target, focal, w, h) — leg cameras sit on poles behind the
        # core box looking up their street (coverage: core stub + 0..80 m of
        # the street); the center mast overlooks the core + 20-32 m street
        # stubs, so every leg camera shares its near segment with the center
        # view and the legs share the core with each other.
        ((7.0, -20.0, 10.0), (1.0, 45.0, 0.0), 1600.0, 1920, 1080),   # N leg
        ((-20.0, -7.0, 10.5), (45.0, 1.0, 0.0), 1600.0, 1920, 1080),  # E leg
        ((-7.0, 20.0, 9.5), (-1.0, -45.0, 0.0), 1600.0, 1920, 1080),  # S leg
        ((20.0, 7.0, 11.0), (-45.0, -1.0, 0.0), 1600.0, 1920, 1080),  # W leg
        ((10.0, 10.0, 30.0), (0.0, 0.0, 0.0), 1000.0, 1280, 960),     # center
    ]
    return [look_at_camera(i, np.array(e), np.array(t), f, w, h, tile)
            for i, (e, t, f, w, h) in enumerate(specs)]


# ---------------------------------------------------------------------------
# trajectories
# ---------------------------------------------------------------------------

_DIRS = {  # approach heading unit vectors: N/S/E/W entries into intersection
    "N": np.array([0.0, -1.0]),
    "S": np.array([0.0, 1.0]),
    "E": np.array([-1.0, 0.0]),
    "W": np.array([1.0, 0.0]),
}
_TURNS = {  # (entry, exit) pairs: straight, left, right
    "N": ["S", "E", "W"],
    "S": ["N", "W", "E"],
    "E": ["W", "N", "S"],
    "W": ["E", "S", "N"],
}


@dataclass
class Vehicle:
    vid: int
    t0: float
    speed: float
    entry: str
    exit: str
    lane_offset: float

    def position(self, t: float, cfg: SceneConfig):
        """Returns (xy (2,), heading) or None if outside the scene."""
        s = (t - self.t0) * self.speed
        if s < 0:
            return None
        a = cfg.approach_len
        d_in = _DIRS[self.entry]
        d_out = -_DIRS[self.exit]
        entry_pt = -d_in * a  # spawn point
        # lane offset: right-hand side of travel direction
        perp_in = np.array([-d_in[1], d_in[0]])
        perp_out = np.array([-d_out[1], d_out[0]])
        turn_r = 9.0  # intersection maneuver radius
        leg1 = a - turn_r
        if s <= leg1:  # approach
            xy = entry_pt + d_in * s + perp_in * self.lane_offset
            return xy, float(np.arctan2(d_in[1], d_in[0]))
        # inside intersection: blend headings along an arc (quadratic bezier)
        arc_len = turn_r * (np.pi / 2 if self.entry != _opposite(self.exit)
                            else 2.0)
        s2 = s - leg1
        if s2 <= arc_len:
            u = s2 / arc_len
            p0 = entry_pt + d_in * leg1 + perp_in * self.lane_offset
            p2 = d_out * turn_r + perp_out * self.lane_offset
            # corner control point: intersection of approach & exit lines
            p1 = np.where(np.abs(d_in) > 0.5, p2, p0)
            xy = (1 - u) ** 2 * p0 + 2 * u * (1 - u) * p1 + u ** 2 * p2
            d = 2 * (1 - u) * (p1 - p0) + 2 * u * (p2 - p1)
            n = np.linalg.norm(d)
            if n < 1e-6:
                d = d_out
                n = 1.0
            return xy, float(np.arctan2(d[1] / n, d[0] / n))
        # exit leg
        s3 = s2 - arc_len
        start = d_out * turn_r + perp_out * self.lane_offset
        xy = start + d_out * s3
        if np.max(np.abs(xy)) > a + 5:
            return None
        return xy, float(np.arctan2(d_out[1], d_out[0]))


def _opposite(d: str) -> str:
    return {"N": "S", "S": "N", "E": "W", "W": "E"}[d]


# ---------------------------------------------------------------------------
# spawn-intensity profiles (per-group scenario diversity for fleet scenes)
# ---------------------------------------------------------------------------
# name -> (peak multiplier, intensity(t, duration) in [0, peak]); spawning
# uses Poisson thinning at the peak rate, so any bounded profile is exact.

SPAWN_PROFILES = {
    "uniform": (1.0, lambda t, T: 1.0),
    # commute ramp: quiet shoulders, ~1.6x the base rate at mid-window
    "rush_hour": (1.6, lambda t, T: 0.4 + 1.2 * float(
        np.sin(np.pi * min(max(t / max(T, 1e-9), 0.0), 1.0)))),
    # light overnight traffic
    "sparse": (0.35, lambda t, T: 0.35),
    # platoons: 15 s bursts every 45 s, near-empty gaps between
    "bursty": (1.8, lambda t, T: 1.8 if (t % 45.0) < 15.0 else 0.2),
}


@dataclass
class Scene:
    cfg: SceneConfig
    cameras: List[Camera]
    vehicles: List[Vehicle]
    # detections[t] = list[Detection]; gt_tracks[(cam, obj)] = frames present
    detections: List[List[Detection]] = field(default_factory=list)

    def detections_at(self, t: int) -> List[Detection]:
        return self.detections[t]

    def all_detections(self):
        for frame in self.detections:
            yield from frame


def generate_scene(cfg: Optional[SceneConfig] = None,
                   cameras: Optional[List[Camera]] = None) -> Scene:
    cfg = cfg or SceneConfig()
    cameras = cameras or default_cameras()
    rng = np.random.default_rng(cfg.seed)

    vehicles: List[Vehicle] = []
    vid = 0
    t = 0.0
    legacy = (cfg.spawn_profile == "uniform" and cfg.entry_weights is None
              and cfg.turn_probs == (0.6, 0.2, 0.2)
              and cfg.shift_at_s is None)
    if legacy:
        # original constant-rate process, draw-for-draw (seed stability)
        while t < cfg.duration_s:
            gap = rng.exponential(1.0 / cfg.spawn_rate)
            t += gap
            entry = rng.choice(list(_DIRS))
            exit_ = rng.choice(_TURNS[entry], p=[0.6, 0.2, 0.2])
            vehicles.append(Vehicle(
                vid=vid,
                t0=t,
                speed=float(rng.uniform(*cfg.speed_range)),
                entry=entry,
                exit=exit_,
                lane_offset=float(rng.uniform(2.0,
                                              cfg.road_halfwidth - 1.5)),
            ))
            vid += 1
    else:
        peak, intensity = SPAWN_PROFILES[cfg.spawn_profile]
        dirs = list(_DIRS)
        while t < cfg.duration_s:
            gap = rng.exponential(1.0 / (cfg.spawn_rate * peak))
            t += gap
            # Poisson thinning: accept at the local intensity
            if rng.random() >= intensity(t, cfg.duration_s) / peak:
                continue
            shifted = cfg.shift_at_s is not None and t >= cfg.shift_at_s
            ew = (cfg.shift_entry_weights if shifted
                  and cfg.shift_entry_weights is not None
                  else cfg.entry_weights)
            tp = (cfg.shift_turn_probs if shifted
                  and cfg.shift_turn_probs is not None else cfg.turn_probs)
            entry = rng.choice(dirs, p=ew)
            exit_ = rng.choice(_TURNS[entry], p=list(tp))
            vehicles.append(Vehicle(
                vid=vid,
                t0=t,
                speed=float(rng.uniform(*cfg.speed_range)),
                entry=entry,
                exit=exit_,
                lane_offset=float(rng.uniform(2.0,
                                              cfg.road_halfwidth - 1.5)),
            ))
            vid += 1

    detections: List[List[Detection]] = []
    for fi in range(cfg.num_frames):
        tt = fi / cfg.fps
        frame: List[Detection] = []
        for v in vehicles:
            if tt < v.t0 - 1 or tt > v.t0 + 60:
                continue
            pos = v.position(tt, cfg)
            if pos is None:
                continue
            xy, heading = pos
            for cam in cameras:
                bb = cam.project_box(xy, cfg.vehicle_length,
                                     cfg.vehicle_width, cfg.vehicle_height,
                                     heading)
                if bb is not None and bb.area >= 24 * 24:
                    frame.append(Detection(cam.cam_id, fi, v.vid, bb))
        detections.append(frame)
    return Scene(cfg, cameras, vehicles, detections)
