"""Analytic tile-based video codec model, calibrated to paper Table 3.

No ffmpeg in-container, so we model H.264 size behaviour analytically and
fit it to the paper's own measurements.  The structural fact the paper's
tile-grouping algorithm exists to fight: splitting a video into independent
tiles shrinks each block's reference search window, so bytes-per-pixel grows
as tile area falls.  Model:

    bytes(region) = area_px * rho_cam * activity * (1 + k / sqrt(area_px))
                    + header_bytes

rho_cam is the camera's content density (bytes/pixel, from the 'original'
column of Table 3), k is the boundary-inefficiency constant fitted to the
m x n amplification grid of Table 3, and header_bytes is the per-stream
container overhead.  The fit reproduces the paper's 1.01-1.17x amplification
trend (validated in benchmarks/bench_compression.py).

The same model prices online segments: per segment, per camera, the encoder
compresses each tile-group rectangle independently; per-frame *activity*
scales with how much scene content moved (so RoI cropping saves bytes
roughly in proportion to cropped area, modulated by where the action is).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.geometry import Camera
from repro.core.grouping import TileGroup

# --- paper Table 3 (video sizes in MB, 5 cameras x 6 tilings) --------------
TABLE3_SETTINGS = ["original", "2x2", "2x4", "4x4", "4x8", "8x8"]
TABLE3_SIZES_MB = {
    # cam: [original, 2x2, 2x4, 4x4, 4x8, 8x8]
    0: [82.7, 85.9, 86.2, 89.0, 90.4, 97.3],
    1: [121.2, 124.5, 124.8, 127.6, 129.6, 136.2],
    2: [102.2, 103.3, 103.6, 105.2, 106.4, 112.9],
    3: [97.9, 99.3, 99.5, 100.0, 101.7, 108.6],
    4: [40.9, 41.1, 41.4, 42.0, 43.2, 47.4],
}
TABLE3_RESOLUTIONS = {0: (1920, 1080), 1: (1920, 1080), 2: (1920, 1080),
                      3: (1920, 1080), 4: (1280, 960)}
TABLE3_DURATION_S = 180.0


def _tiling_tile_area(res: Tuple[int, int], setting: str) -> float:
    if setting == "original":
        return float(res[0] * res[1])
    m, n = (int(s) for s in setting.split("x"))
    return res[0] * res[1] / (m * n)


def fit_boundary_constant(cam: int) -> float:
    """Least-squares fit of k to the amplification row of Table 3."""
    res = TABLE3_RESOLUTIONS[cam]
    sizes = TABLE3_SIZES_MB[cam]
    full_area = float(res[0] * res[1])
    s0 = sizes[0]
    num, den = 0.0, 0.0
    for setting, s in zip(TABLE3_SETTINGS[1:], sizes[1:]):
        a = _tiling_tile_area(res, setting)
        # s/s0 = (1 + k/sqrt(a)) / (1 + k/sqrt(A))  ->  linear in k
        r = s / s0
        coeff = 1.0 / np.sqrt(a) - r / np.sqrt(full_area)
        num += coeff * (r - 1.0)
        den += coeff * coeff
    return float(num / den)


@dataclass
class CodecModel:
    cameras: Sequence[Camera]
    boundary_k: Dict[int, float]          # per camera
    rho: Dict[int, float]                 # bytes/pixel/frame content density
    header_bytes: float = 600.0           # per independent stream per segment

    @classmethod
    def calibrated(cls, cameras: Sequence[Camera], fps: float = 10.0
                   ) -> "CodecModel":
        ks, rhos = {}, {}
        for c in cameras:
            tcam = c.cam_id % len(TABLE3_SIZES_MB)
            ks[c.cam_id] = fit_boundary_constant(tcam)
            res = TABLE3_RESOLUTIONS[tcam]
            area = res[0] * res[1]
            n_frames = TABLE3_DURATION_S * fps
            s0 = TABLE3_SIZES_MB[tcam][0] * 1e6
            base = s0 / (n_frames * area * (1 + ks[c.cam_id] / np.sqrt(area)))
            rhos[c.cam_id] = float(base)
        return cls(cameras, ks, rhos)

    # ------------------------------------------------------------------
    def region_bytes(self, cam: int, area_px: float, n_frames: int,
                     activity: float = 1.0) -> float:
        """Bytes to encode one independent rectangular region over a segment."""
        if area_px <= 0:
            return 0.0
        k = self.boundary_k[cam]
        per_frame = area_px * self.rho[cam] * activity * \
            (1.0 + k / np.sqrt(area_px))
        return per_frame * n_frames + self.header_bytes

    def full_frame_bytes(self, cam: int, n_frames: int,
                         activity: float = 1.0) -> float:
        c = self.cameras[cam]
        return self.region_bytes(cam, c.width * c.height, n_frames, activity)

    def groups_bytes(self, cam: int, groups: Sequence[TileGroup],
                     n_frames: int, activity: float = 1.0) -> float:
        c = self.cameras[cam]
        total = 0.0
        for g in groups:
            # pixel area of the rectangle (edge tiles may be clipped)
            x0, y0 = g.x0 * c.tile, g.y0 * c.tile
            w = min(g.w * c.tile, c.width - x0)
            h = min(g.h * c.tile, c.height - y0)
            total += self.region_bytes(cam, w * h, n_frames, activity)
        return total

    def tiles_bytes(self, cam: int, n_tiles: int, n_frames: int,
                    activity: float = 1.0) -> float:
        """No-Merging ablation: every tile encoded independently."""
        c = self.cameras[cam]
        return n_tiles * self.region_bytes(cam, c.tile * c.tile, n_frames,
                                           activity)


# ---------------------------------------------------------------------------
# camera-side encode-time model (for throughput & latency)
# ---------------------------------------------------------------------------

@dataclass
class EncoderModel:
    """Camera H.264 encode throughput ~ pixels/s (paper: 23 fps at 1080p)."""
    pixels_per_s: float = 23.0 * 1920 * 1080

    def encode_time_s(self, area_px: float, n_frames: int) -> float:
        return area_px * n_frames / self.pixels_per_s

    def throughput_fps(self, area_px_per_frame: float) -> float:
        if area_px_per_frame <= 0:
            return float("inf")
        return self.pixels_per_s / area_px_per_frame
