"""Tandem statistical filters over raw ReID results (paper §4.2).

Filter 1 — *regression filter* (kills false positives): for every ordered
camera pair, the positive samples (bbox in src, bbox in dst of the same
assigned id at the same timestamp) must follow the intrinsic physical
region mapping between the two views (observation O1).  A RANSAC regression
on polynomial bbox features exposes associations that violate the mapping;
those are decoupled (fresh id => the sample becomes negative).

Filter 2 — *SVM filter* (kills false negatives): per ordered pair, an RBF
kernel SVM is trained on <bbox, positive/negative> and applied back to the
same samples (the paper trains and tests on the same data on purpose — it is
a filter, not a classifier for future data).  Negative samples landing in the
positive region are false-negative suspects and are removed from the
optimization (the true link exists but ReID missed it; keeping the sample
would force its tiles into the mask forever, §4.2.1).

Both are implemented in-repo (no sklearn): RANSAC over a least-squares
polynomial map, and a kernel SVM trained by dual coordinate ascent.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.reid import ReIDRecord


# ---------------------------------------------------------------------------
# polynomial features
# ---------------------------------------------------------------------------

def poly_features(X: np.ndarray, degree: int = 2) -> np.ndarray:
    """[1, x_i, x_i*x_j (i<=j)] — degree-2 expansion of bbox vectors."""
    n, d = X.shape
    cols = [np.ones((n, 1)), X]
    if degree >= 2:
        for i in range(d):
            for j in range(i, d):
                cols.append((X[:, i] * X[:, j])[:, None])
    return np.concatenate(cols, axis=1)


# ---------------------------------------------------------------------------
# RANSAC regression filter
# ---------------------------------------------------------------------------

@dataclass
class RansacConfig:
    # residual_threshold = theta * mad, the paper's Fig-10 parameterization.
    # The paper picks theta=0.01 for *its* scene; our synthetic intersection
    # has steeper perspective (closer cameras), so the TP/FP residual knee
    # sits higher: TP links fit within 10-120 px, FP links at 220-900 px,
    # and theta=0.2 (~50-100 px) cuts ~99% of false links while keeping
    # 76-99% of true ones (measured; see benchmarks/bench_sensitivity.py
    # for the full theta sweep reproducing the Fig-10 trend).
    theta: float = 0.2
    degree: int = 2
    min_samples: int = 24
    max_trials: int = 256
    seed: int = 0


@dataclass
class RansacResult:
    inlier: np.ndarray           # (n,) bool
    coef: Optional[np.ndarray]   # (F, 4) fitted map, None if degenerate
    threshold: float


def ransac_regression(src: np.ndarray, dst: np.ndarray,
                      cfg: RansacConfig) -> RansacResult:
    """Robustly fit dst_bbox = f(src_bbox); flag outliers.

    Residual is the L1 distance over the 4 bbox dims (sklearn's multi-output
    convention); the inlier threshold is ``theta * mad`` where mad is the
    median absolute deviation of the targets (sklearn RANSAC's default
    scale), exactly the parameterization the paper sweeps in Fig 10.
    """
    n = len(src)
    med = np.median(dst, axis=0)
    mad = float(np.median(np.abs(dst - med).sum(axis=1)))
    thr = max(cfg.theta * mad, 1e-6)
    if n < cfg.min_samples:
        return RansacResult(np.ones(n, bool), None, thr)

    # standardize features for conditioning
    mu, sig = src.mean(0), src.std(0) + 1e-9
    F = poly_features((src - mu) / sig, cfg.degree)
    rng = np.random.default_rng(cfg.seed)
    best_mask = None
    best_count = -1
    for _ in range(cfg.max_trials):
        idx = rng.choice(n, size=cfg.min_samples, replace=False)
        coef, *_ = np.linalg.lstsq(F[idx], dst[idx], rcond=None)
        resid = np.abs(F @ coef - dst).sum(axis=1)
        mask = resid <= thr
        c = int(mask.sum())
        if c > best_count:
            best_count, best_mask = c, mask
            if c == n:
                break
    # refit on the consensus set
    if best_mask is None or best_mask.sum() < cfg.min_samples:
        return RansacResult(np.ones(n, bool), None, thr)
    coef, *_ = np.linalg.lstsq(F[best_mask], dst[best_mask], rcond=None)
    resid = np.abs(F @ coef - dst).sum(axis=1)
    return RansacResult(resid <= thr, coef, thr)


# ---------------------------------------------------------------------------
# kernel SVM by dual coordinate ascent
# ---------------------------------------------------------------------------

@dataclass
class SVMConfig:
    # gamma operates on RAW pixel-scale bbox features (as in the paper:
    # bbox coords are 0..1920, so d2 ~ 1e5-1e6 and the Fig-9 sweep range
    # only makes sense unstandardized).  The paper picks 1e-4 for its
    # scene; our calibration sweep (benchmarks/bench_sensitivity.py) puts
    # the accuracy-preserving knee at 1e-5: FN-flag rate 48% at 3.6% TN
    # cost, which restores the paper's CrossRoI < No-Filters mask ordering.
    gamma: float = 1e-5          # RBF non-linearity (paper Fig 9)
    C: float = 10.0
    passes: int = 12
    max_train: int = 2500        # subsample cap (keeps all positives)
    standardize: bool = False
    # class-balanced penalties (C_i ~ C * n / (2 * n_class)): positives are
    # the minority (Table 2: FN often outnumbers TP several-fold), and
    # without balancing the dense FN mass in the overlap region outvotes
    # the TPs and the filter flags nothing.
    balanced: bool = True
    seed: int = 0


class KernelSVM:
    """RBF-kernel SVM: max_a  sum a - 1/2 a^T Q a,  0 <= a <= C  (no bias;
    an appended constant feature absorbs the offset)."""

    def __init__(self, cfg: SVMConfig):
        self.cfg = cfg
        self.Xs: Optional[np.ndarray] = None
        self.alpha_y: Optional[np.ndarray] = None
        self.mu = self.sig = None

    def _kernel(self, A: np.ndarray, B: np.ndarray) -> np.ndarray:
        d2 = (np.sum(A * A, 1)[:, None] + np.sum(B * B, 1)[None, :]
              - 2.0 * A @ B.T)
        return np.exp(-self.cfg.gamma * np.maximum(d2, 0.0))

    def fit(self, X: np.ndarray, y: np.ndarray) -> "KernelSVM":
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        if cfg.standardize:
            self.mu, self.sig = X.mean(0), X.std(0) + 1e-9
        else:
            self.mu = np.zeros(X.shape[1])
            self.sig = np.ones(X.shape[1])
        Xn = (X - self.mu) / self.sig
        yy = np.where(y > 0, 1.0, -1.0)

        # subsample negatives if large (keep every positive)
        if len(Xn) > cfg.max_train:
            pos = np.nonzero(yy > 0)[0]
            neg = np.nonzero(yy < 0)[0]
            keep_neg = rng.choice(neg, size=max(cfg.max_train - len(pos), 100),
                                  replace=False)
            sel = np.concatenate([pos, keep_neg])
        else:
            sel = np.arange(len(Xn))
        Xt, yt = Xn[sel], yy[sel]
        n = len(Xt)
        if cfg.balanced:
            n_pos = max(int((yt > 0).sum()), 1)
            n_neg = max(n - n_pos, 1)
            Ci = np.where(yt > 0, cfg.C * n / (2.0 * n_pos),
                          cfg.C * n / (2.0 * n_neg))
        else:
            Ci = np.full(n, cfg.C)
        K = self._kernel(Xt, Xt)
        Q = K * (yt[:, None] * yt[None, :])
        alpha = np.zeros(n)
        grad = -np.ones(n)              # grad of 1/2 a^T Q a - sum a
        diag = np.maximum(np.diag(Q), 1e-12)
        for _ in range(cfg.passes):
            order = rng.permutation(n)
            changed = 0.0
            for i in order:
                a_new = np.clip(alpha[i] - grad[i] / diag[i], 0.0, Ci[i])
                delta = a_new - alpha[i]
                if abs(delta) > 1e-12:
                    grad += delta * Q[:, i]
                    alpha[i] = a_new
                    changed += abs(delta)
            if changed < 1e-8 * n:
                break
        self.Xs = Xt
        self.alpha_y = alpha * yt
        return self

    def decision(self, X: np.ndarray) -> np.ndarray:
        Xn = (X - self.mu) / self.sig
        return self._kernel(Xn, self.Xs) @ self.alpha_y

    def predict(self, X: np.ndarray) -> np.ndarray:
        return self.decision(X) > 0


# ---------------------------------------------------------------------------
# the tandem filter pipeline
# ---------------------------------------------------------------------------

@dataclass
class FilterConfig:
    ransac: RansacConfig = field(default_factory=RansacConfig)
    svm: SVMConfig = field(default_factory=SVMConfig)
    enabled: bool = True          # No-Filters ablation switch


@dataclass
class FilterStats:
    fp_decoupled: int = 0
    fn_removed: int = 0
    pairs_fitted: int = 0


def _index_records(records: Sequence[ReIDRecord]):
    by_t_cam: Dict[Tuple[int, int], List[int]] = {}
    for i, r in enumerate(records):
        by_t_cam.setdefault((r.t, r.cam), []).append(i)
    return by_t_cam


def apply_filters(records: List[ReIDRecord], num_cams: int,
                  cfg: Optional[FilterConfig] = None
                  ) -> Tuple[List[ReIDRecord], FilterStats]:
    """Run both filters; return (cleaned records, stats).

    Cleaning = (a) FP links decoupled by reassigning a fresh id to the source
    detection, (b) FN suspects dropped from the list entirely.
    """
    cfg = cfg or FilterConfig()
    stats = FilterStats()
    if not cfg.enabled:
        return list(records), stats

    records = list(records)
    by_t_cam = _index_records(records)
    times = sorted({r.t for r in records})
    next_fresh = max((r.rid for r in records), default=0) + 1_000_000

    # ---- stage 1: regression filter per ordered pair --------------------
    for src_cam in range(num_cams):
        for dst_cam in range(num_cams):
            if src_cam == dst_cam:
                continue
            src_idx: List[int] = []
            dst_vec: List[np.ndarray] = []
            for t in times:
                s_rows = by_t_cam.get((t, src_cam), [])
                d_rows = by_t_cam.get((t, dst_cam), [])
                if not s_rows or not d_rows:
                    continue
                d_by_rid = {records[j].rid: j for j in d_rows}
                for i in s_rows:
                    j = d_by_rid.get(records[i].rid)
                    if j is not None:
                        src_idx.append(i)
                        dst_vec.append(records[j].bbox.as_vec())
            if not src_idx:
                continue
            S = np.stack([records[i].bbox.as_vec() for i in src_idx])
            D = np.stack(dst_vec)
            res = ransac_regression(S, D, cfg.ransac)
            stats.pairs_fitted += 1
            for k in np.nonzero(~res.inlier)[0]:
                i = src_idx[int(k)]
                r = records[i]
                records[i] = ReIDRecord(r.cam, r.t, r.bbox, next_fresh, r.obj)
                next_fresh += 1
                stats.fp_decoupled += 1

    # rebuild the time index after decoupling
    by_t_cam = _index_records(records)

    # ---- stage 2: SVM filter per ordered pair ----------------------------
    to_remove: Set[int] = set()
    for src_cam in range(num_cams):
        for dst_cam in range(num_cams):
            if src_cam == dst_cam:
                continue
            idxs: List[int] = []
            labels: List[int] = []
            for t in times:
                s_rows = by_t_cam.get((t, src_cam), [])
                if not s_rows:
                    continue
                d_rows = by_t_cam.get((t, dst_cam), [])
                d_rids = {records[j].rid for j in d_rows}
                for i in s_rows:
                    idxs.append(i)
                    labels.append(1 if records[i].rid in d_rids else 0)
            if not idxs or sum(labels) < 8:
                continue
            X = np.stack([records[i].bbox.as_vec() for i in idxs])
            y = np.asarray(labels)
            svm = KernelSVM(cfg.svm).fit(X, y)
            pred = svm.predict(X)
            # negative samples inside the positive region -> FN suspects
            fn_mask = (y == 0) & pred
            for k in np.nonzero(fn_mask)[0]:
                to_remove.add(idxs[int(k)])
    stats.fn_removed = len(to_remove)
    cleaned = [r for i, r in enumerate(records) if i not in to_remove]
    return cleaned, stats
