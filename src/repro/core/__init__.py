"""CrossRoI core: the paper's contribution as a composable library.

Offline phase: scene profiling -> noisy ReID -> tandem statistical filters
-> cross-camera association table -> set-cover RoI masks -> tile grouping.
Online phase: mask-cropped tile streaming (codec model) + RoI-based
inference (SBNet-adapted Pallas kernels in repro.kernels) + metrics.
"""
from repro.core.association import (AssociationTable, Region, TileUniverse,
                                    build_association_table)
from repro.core.compression import CodecModel, EncoderModel
from repro.core.filters import (FilterConfig, KernelSVM, RansacConfig,
                                SVMConfig, apply_filters, ransac_regression)
from repro.core.grouping import TileGroup, group_tiles, groups_cover
from repro.core.pipeline import (OfflineConfig, OfflineResult, OnlineConfig,
                                 OnlineMetrics, ServerModel, bbox_arrays,
                                 coverage_flags_batched, full_frame_offline,
                                 run_offline, run_online,
                                 segment_network_bytes)
from repro.core.reducto import ReductoResult, tune_and_run
from repro.core.reid import (ReIDNoiseConfig, ReIDRecord,
                             characterize_pairwise, run_noisy_reid)
from repro.core.scene import Scene, SceneConfig, default_cameras, \
    generate_scene
from repro.core import setcover

__all__ = [
    "AssociationTable", "Region", "TileUniverse", "build_association_table",
    "CodecModel", "EncoderModel", "FilterConfig", "KernelSVM", "RansacConfig",
    "SVMConfig", "apply_filters", "ransac_regression", "TileGroup",
    "group_tiles", "groups_cover", "OfflineConfig", "OfflineResult",
    "OnlineConfig", "OnlineMetrics", "ServerModel", "full_frame_offline",
    "run_offline", "run_online", "bbox_arrays", "coverage_flags_batched",
    "segment_network_bytes", "ReductoResult", "tune_and_run",
    "ReIDNoiseConfig", "ReIDRecord", "characterize_pairwise",
    "run_noisy_reid", "Scene", "SceneConfig", "default_cameras",
    "generate_scene", "setcover",
]
