"""Tile grouping (paper §4.3.2): merge fine-grained RoI tiles into maximal
rectangles to recover video-compression efficacy.

Greedy loop: find the largest inscribed rectangle of the remaining mask
(maximal-rectangle-in-binary-matrix via the histogram/stack DP, O(M) per
iteration), emit it as one group, clear it, repeat — overall O(M^2) worst
case exactly as the paper states.  Runs offline; zero online cost.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np


@dataclass(frozen=True)
class TileGroup:
    """A merged rectangle, in tile units: rows [y0, y0+h), cols [x0, x0+w)."""
    y0: int
    x0: int
    h: int
    w: int

    @property
    def num_tiles(self) -> int:
        return self.h * self.w


def _largest_rectangle(grid: np.ndarray) -> Tuple[int, TileGroup]:
    """Largest all-True axis-aligned rectangle. Returns (area, group)."""
    H, W = grid.shape
    heights = np.zeros(W, np.int64)
    best_area = 0
    best = TileGroup(0, 0, 0, 0)
    for y in range(H):
        heights = np.where(grid[y], heights + 1, 0)
        # classic stack-based largest rectangle in histogram
        stack: List[int] = []
        x = 0
        while x <= W:
            cur = heights[x] if x < W else 0
            if not stack or cur >= heights[stack[-1]]:
                stack.append(x)
                x += 1
            else:
                top = stack.pop()
                left = stack[-1] + 1 if stack else 0
                h = int(heights[top])
                area = h * (x - left)
                if area > best_area:
                    best_area = area
                    best = TileGroup(y - h + 1, left, h, x - left)
        # (x loop consumed the sentinel)
    return best_area, best


def group_tiles(grid: np.ndarray) -> List[TileGroup]:
    """grid: (tiles_y, tiles_x) bool RoI mask -> disjoint covering rectangles."""
    work = grid.copy()
    groups: List[TileGroup] = []
    while work.any():
        area, g = _largest_rectangle(work)
        if area <= 0:   # numerical safety; cannot happen while work.any()
            break
        work[g.y0:g.y0 + g.h, g.x0:g.x0 + g.w] = False
        groups.append(g)
    return groups


def groups_cover(grid: np.ndarray, groups: List[TileGroup]) -> bool:
    """Invariant check: groups exactly tile the mask, disjointly."""
    acc = np.zeros_like(grid, dtype=np.int64)
    for g in groups:
        acc[g.y0:g.y0 + g.h, g.x0:g.x0 + g.w] += 1
    return bool(np.all((acc == 1) == grid) and np.all(acc <= 1))
