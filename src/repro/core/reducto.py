"""Reducto-style frame filtering and the CrossRoI-Reducto integration
(paper §5.4, Fig 12, Table 4).

Reducto keeps a frame only when a cheap low-level difference feature against
the last *sent* frame exceeds a threshold; the threshold is tuned offline on
profiling clips to meet an accuracy target.  Our difference feature is the
symmetric-difference area of (mask-clipped) object boxes between the current
frame and the last sent one — the analytic stand-in for Reducto's pixel/edge
differencing, computed from the same scene ground truth the codec model uses.

CrossRoI-Reducto = the identical machinery run on *mask-cropped* content:
features only see what survives the RoI crop, exactly like Fig 12 (masks
first, frame filter second).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.geometry import BBox
from repro.core.pipeline import OfflineResult, OnlineConfig, OnlineMetrics, \
    bbox_mask_area, run_online
from repro.core.scene import Scene


def _clip_box_to_mask(scene: Scene, offline: OfflineResult, cam: int,
                      b: BBox) -> float:
    """Area of bbox ∩ RoI mask (pixelwise over tile rectangles)."""
    return bbox_mask_area(scene.cameras[cam], offline.cam_grids[cam], b)


def _frame_boxes(scene: Scene, cam: int, t: int) -> Dict[int, BBox]:
    return {d.obj: d.bbox for d in scene.detections[t] if d.cam == cam}


def diff_feature(scene: Scene, offline: OfflineResult, cam: int,
                 t: int, t_last: int, use_mask: bool) -> float:
    """Symmetric-difference area of object content between t and t_last,
    normalized by the (masked) frame area."""
    cur = _frame_boxes(scene, cam, t)
    prev = _frame_boxes(scene, cam, t_last)
    c = scene.cameras[cam]
    denom = offline.mask_area_px(cam) if use_mask else c.width * c.height
    denom = max(denom, 1.0)
    changed = 0.0
    for obj in set(cur) | set(prev):
        b0, b1 = prev.get(obj), cur.get(obj)
        if b0 is None or b1 is None:
            b = b1 or b0
            a = _clip_box_to_mask(scene, offline, cam, b) if use_mask \
                else b.area
            changed += a
            continue
        # moved content: union - intersection of the two boxes
        ix = max(0.0, min(b0.right, b1.right) - max(b0.left, b1.left))
        iy = max(0.0, min(b0.bottom, b1.bottom) - max(b0.top, b1.top))
        if use_mask:
            a0 = _clip_box_to_mask(scene, offline, cam, b0)
            a1 = _clip_box_to_mask(scene, offline, cam, b1)
            inter = min(a0, a1) * (ix * iy) / max(min(b0.area, b1.area), 1.0)
            changed += a0 + a1 - 2 * inter
        else:
            changed += b0.area + b1.area - 2 * ix * iy
    return changed / denom


def keep_masks_for_threshold(scene: Scene, offline: OfflineResult,
                             threshold: float, t0: int, t1: int,
                             use_mask: bool) -> Dict[int, np.ndarray]:
    """Greedy online filtering: keep frame iff diff vs last-kept > threshold.
    The first frame of every segment is always kept (Reducto's anchor)."""
    keep: Dict[int, np.ndarray] = {}
    for c in scene.cameras:
        cid = c.cam_id
        k = np.zeros(t1 - t0, bool)
        last = t0
        k[0] = True
        for t in range(t0 + 1, t1):
            f = diff_feature(scene, offline, cid, t, last, use_mask)
            if f > threshold:
                k[t - t0] = True
                last = t
        keep[cid] = k
    return keep


@dataclass
class ReductoResult:
    target: float
    achieved: float
    threshold: float
    metrics: OnlineMetrics


def tune_and_run(scene: Scene, offline: OfflineResult, target: float,
                 online_cfg: Optional[OnlineConfig] = None,
                 profile: Tuple[int, int] = (0, 600),
                 evalw: Tuple[int, int] = (600, 1800),
                 use_mask: bool = True) -> ReductoResult:
    """Offline: pick the most aggressive threshold meeting the accuracy
    target on the profiling window; online: apply it on the eval window."""
    online_cfg = online_cfg or OnlineConfig()
    if target >= 1.0:  # paper: filtering disabled at 100% target
        m = run_online(scene, offline, online_cfg, *evalw)
        return ReductoResult(target, m.accuracy, 0.0, m)

    # tune with a safety margin: the threshold is chosen on the profiling
    # window but deployed out-of-window, so meeting the bare target during
    # profiling undershoots online (Reducto has the same generalization
    # slack; its paper rows also land a little under/over target)
    margin = 0.015 if target < 1.0 else 0.0
    grid = np.concatenate([[0.0], np.geomspace(1e-4, 0.5, 24)])
    best_thr = 0.0
    for thr in grid:
        keep = keep_masks_for_threshold(scene, offline, thr, *profile,
                                        use_mask=use_mask)
        cfg_p = OnlineConfig(segment_s=online_cfg.segment_s,
                             bandwidth_mbps=online_cfg.bandwidth_mbps,
                             rtt_ms=online_cfg.rtt_ms,
                             roi_inference=online_cfg.roi_inference,
                             frame_keep=keep)
        m = run_online(scene, offline, cfg_p, *profile)
        if m.accuracy >= min(target + margin, 1.0):
            best_thr = float(thr)
        else:
            break
    keep = keep_masks_for_threshold(scene, offline, best_thr, *evalw,
                                    use_mask=use_mask)
    cfg_e = OnlineConfig(segment_s=online_cfg.segment_s,
                         bandwidth_mbps=online_cfg.bandwidth_mbps,
                         rtt_ms=online_cfg.rtt_ms,
                         roi_inference=online_cfg.roi_inference,
                         frame_keep=keep)
    m = run_online(scene, offline, cfg_e, *evalw)
    return ReductoResult(target, m.accuracy, best_thr, m)
