"""Camera geometry for the multi-camera traffic scene.

Cameras are pinhole models looking at a common ground plane; the
ground-to-image mapping is the homography the paper's region associations
implicitly rely on (observation O1: cross-camera region associations are
physical). Bounding boxes come from projecting a 3-D vehicle box and taking
the image-axis-aligned hull, clipped to the frame.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class BBox:
    """<left, top, width, height> in pixels — the paper's ReID record form."""
    left: float
    top: float
    width: float
    height: float

    @property
    def right(self) -> float:
        return self.left + self.width

    @property
    def bottom(self) -> float:
        return self.top + self.height

    @property
    def area(self) -> float:
        return max(self.width, 0.0) * max(self.height, 0.0)

    def as_vec(self) -> np.ndarray:
        return np.array([self.left, self.top, self.width, self.height],
                        np.float64)

    def iou(self, o: "BBox") -> float:
        ix = max(0.0, min(self.right, o.right) - max(self.left, o.left))
        iy = max(0.0, min(self.bottom, o.bottom) - max(self.top, o.top))
        inter = ix * iy
        union = self.area + o.area - inter
        return inter / union if union > 0 else 0.0


@dataclass(frozen=True)
class Camera:
    cam_id: int
    width: int
    height: int
    # 3x4 projection matrix (pinhole): x_img ~ P @ [X Y Z 1]
    P: np.ndarray
    tile: int = 64  # basic tile size (paper: 64x64)

    @property
    def tiles_x(self) -> int:
        return -(-self.width // self.tile)

    @property
    def tiles_y(self) -> int:
        return -(-self.height // self.tile)

    @property
    def num_tiles(self) -> int:
        return self.tiles_x * self.tiles_y

    def project(self, pts: np.ndarray) -> np.ndarray:
        """pts: (N,3) world -> (N,2) pixels (may be outside the frame)."""
        homog = np.concatenate([pts, np.ones((len(pts), 1))], axis=1)
        img = homog @ self.P.T
        return img[:, :2] / np.maximum(img[:, 2:3], 1e-9)

    def in_front(self, pts: np.ndarray) -> np.ndarray:
        homog = np.concatenate([pts, np.ones((len(pts), 1))], axis=1)
        return (homog @ self.P.T)[:, 2] > 0.1

    def project_box(self, center_xy: np.ndarray, length: float, width: float,
                    height: float, heading: float) -> Optional[BBox]:
        """Project a 3-D vehicle box; None if not visible."""
        c, s = np.cos(heading), np.sin(heading)
        dx, dy = length / 2, width / 2
        corners = []
        for ex in (-dx, dx):
            for ey in (-dy, dy):
                wx = center_xy[0] + ex * c - ey * s
                wy = center_xy[1] + ex * s + ey * c
                for z in (0.0, height):
                    corners.append([wx, wy, z])
        corners = np.asarray(corners)
        if not self.in_front(corners).all():
            return None
        uv = self.project(corners)
        left = float(np.min(uv[:, 0]))
        right = float(np.max(uv[:, 0]))
        top = float(np.min(uv[:, 1]))
        bottom = float(np.max(uv[:, 1]))
        # clip to frame
        l = max(left, 0.0)
        t = max(top, 0.0)
        r = min(right, float(self.width))
        b = min(bottom, float(self.height))
        if r - l < 4 or b - t < 4:
            return None
        # visibility: enough of the box inside the frame
        full = (right - left) * (bottom - top)
        if full <= 0 or (r - l) * (b - t) / full < 0.33:
            return None
        return BBox(l, t, r - l, b - t)

    # --- tiles -------------------------------------------------------------
    def bbox_tiles(self, b: BBox) -> frozenset:
        """Least set of tile indices covering the (in-frame part of the)
        bbox (paper §3.2).  Clamped to the frame: a bbox hanging past the
        left/top edge must not wrap to the previous row's tiles."""
        x0 = max(int(b.left) // self.tile, 0)
        x1 = int(np.ceil(b.right / self.tile) - 1)
        y0 = max(int(b.top) // self.tile, 0)
        y1 = int(np.ceil(b.bottom / self.tile) - 1)
        x1 = min(x1, self.tiles_x - 1)
        y1 = min(y1, self.tiles_y - 1)
        return frozenset(
            y * self.tiles_x + x
            for y in range(y0, y1 + 1) for x in range(x0, x1 + 1))

    def tile_pixel_box(self, idx: int) -> Tuple[int, int, int, int]:
        y, x = divmod(idx, self.tiles_x)
        return (x * self.tile, y * self.tile,
                min(self.tile, self.width - x * self.tile),
                min(self.tile, self.height - y * self.tile))


def look_at_camera(cam_id: int, eye: np.ndarray, target: np.ndarray,
                   focal_px: float, width: int = 1920, height: int = 1080,
                   tile: int = 64) -> Camera:
    """Build a pinhole camera from eye/target positions (z-up world)."""
    eye = np.asarray(eye, np.float64)
    fwd = np.asarray(target, np.float64) - eye
    fwd = fwd / np.linalg.norm(fwd)
    up = np.array([0.0, 0.0, 1.0])
    right = np.cross(fwd, up)
    right /= np.linalg.norm(right)
    down = np.cross(fwd, right)  # image y grows downward
    R = np.stack([right, down, fwd])  # world->cam rotation
    t = -R @ eye
    K = np.array([[focal_px, 0, width / 2],
                  [0, focal_px, height / 2],
                  [0, 0, 1.0]])
    P = K @ np.concatenate([R, t[:, None]], axis=1)
    return Camera(cam_id, width, height, P, tile)
