"""ReID noise model — raw (error-prone) re-identification results.

The paper runs DiDi-MTMC over the profiling clips and characterizes its raw
output, per ordered camera pair, into TP / FP / FN / TN (§4.2.1, Table 2).
The dataset is not redistributable, so we reproduce the *error structure*:
starting from exact geometric ground truth (core/scene.py), we corrupt the
ID assignments with pairwise error rates calibrated to Table 2:

  FN: a cross-camera appearance pair is *split* — the two appearances of the
      same object get different IDs.  Table 2: FN usually outweighs TP
      (e.g. C3->C5: 155 TP vs 1871 FN).  We model FN as track-level events
      (ReID loses a track for a stretch, not per-frame coin flips) so the
      SVM filter sees the realistic blobs-of-errors structure.
  FP: a detection is *merged* with a wrong object in the destination camera.
      Table 2: rarer than FN, and concentrated where bbox statistics are
      degenerate (small/far boxes) — we bias FP toward small boxes so the
      regression filter has realistic outliers to find.

The output schema matches the paper's: <left, top, width, height, id> per
detection per frame (§4.1.1 step 1).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.geometry import BBox
from repro.core.scene import Detection, Scene


@dataclass(frozen=True)
class ReIDRecord:
    """One raw ReID output row: a detection plus its *assigned* id."""
    cam: int
    t: int
    bbox: BBox
    rid: int          # id assigned by the (noisy) ReID algorithm
    obj: int          # ground-truth object id (held for evaluation only)


# Table 2 of the paper, used to calibrate pairwise error rates.  Rates are
# aggregated over the table:  FN/(TP+FN) per pair ranges ~0.4..0.95,
# FP/(TP+FP) ranges ~0..0.43.
PAPER_TABLE2_FN_RATE = 0.62   # median FN fraction among positives
PAPER_TABLE2_FP_RATE = 0.30   # FP fraction among positive assignments
                              # (Table 2 ranges 0..43%, e.g. C1->C2 253/588)


@dataclass
class ReIDNoiseConfig:
    fn_rate: float = PAPER_TABLE2_FN_RATE
    fp_rate: float = PAPER_TABLE2_FP_RATE
    fn_burst_len: float = 14.0   # mean frames per FN burst (track-level)
    small_box_bias: float = 2.0  # FP odds multiplier for small boxes
    seed: int = 1


def run_noisy_reid(scene: Scene, cfg: Optional[ReIDNoiseConfig] = None,
                   t0: int = 0, t1: Optional[int] = None) -> List[ReIDRecord]:
    """Produce raw ReID records over frames [t0, t1) of the scene.

    ID space: ground-truth object ids, except where noise splits (FN: fresh
    negative ids) or merges (FP: the id of a different co-visible object).
    """
    cfg = cfg or ReIDNoiseConfig()
    rng = np.random.default_rng(cfg.seed)
    t1 = len(scene.detections) if t1 is None else t1

    # --- FN bursts: per (cam, obj) track, sample stretches where the track's
    # cross-camera link is lost (the detection gets a private id).
    track_frames: Dict[Tuple[int, int], List[int]] = {}
    for fr in scene.detections[t0:t1]:
        for d in fr:
            track_frames.setdefault((d.cam, d.obj), []).append(d.t)

    split_frames: Dict[Tuple[int, int], set] = {}
    next_neg_id = 1_000_000
    split_ids: Dict[Tuple[int, int], int] = {}
    for key, frames in track_frames.items():
        n = len(frames)
        lost = np.zeros(n, bool)
        i = 0
        while i < n:
            if rng.random() < cfg.fn_rate / max(cfg.fn_burst_len, 1.0):
                burst = max(1, int(rng.exponential(cfg.fn_burst_len)))
                lost[i:i + burst] = True
                i += burst
            else:
                i += 1
        if lost.any():
            split_frames[key] = {frames[i] for i in np.nonzero(lost)[0]}
            split_ids[key] = next_neg_id
            next_neg_id += 1

    # --- FP merges: per frame, pick detections (biased toward small boxes)
    # and reassign them the id of another object visible in a different cam.
    records: List[ReIDRecord] = []
    for fr in scene.detections[t0:t1]:
        if not fr:
            continue
        med_area = float(np.median([d.bbox.area for d in fr]))
        by_cam: Dict[int, List[Detection]] = {}
        for d in fr:
            by_cam.setdefault(d.cam, []).append(d)
        for d in fr:
            rid = d.obj
            key = (d.cam, d.obj)
            if key in split_frames and d.t in split_frames[key]:
                rid = split_ids[key]
            else:
                odds = cfg.fp_rate / (1.0 - cfg.fp_rate)
                if d.bbox.area < 0.5 * med_area:
                    odds *= cfg.small_box_bias
                p = odds / (1.0 + odds)
                if rng.random() < p * 0.35:  # only a slice of frames actually FP
                    # merge with a *plausible* wrong object from another
                    # camera: ReID confuses similar-looking (similar-sized)
                    # detections, so bias toward the closest bbox areas
                    others = [o for c, dets in by_cam.items() if c != d.cam
                              for o in dets if o.obj != d.obj]
                    if others:
                        others.sort(key=lambda o: abs(o.bbox.area
                                                      - d.bbox.area))
                        pick = others[:max(3, len(others) // 4)]
                        rid = pick[rng.integers(len(pick))].obj
            records.append(ReIDRecord(d.cam, d.t, d.bbox, rid, d.obj))
    return records


# ---------------------------------------------------------------------------
# Pairwise TP/FP/FN/TN characterization (reproduces paper Table 2)
# ---------------------------------------------------------------------------

def characterize_pairwise(records: List[ReIDRecord], num_cams: int
                          ) -> np.ndarray:
    """counts[src, dst] = (TP, FP, FN, TN) as defined in §4.2.1.

    For each detection in the source camera at time t:
      positive(gt)  = its ground-truth object also appears in dst at t
      positive(rid) = its assigned id matches some assigned id in dst at t
      TP: positive(rid) and the matched dst detection is the same gt object
      FP: positive(rid) but matched to a wrong gt object (or gt-negative)
      FN: positive(gt) but not matched under the assigned ids
      TN: negative(gt) and not matched
    """
    counts = np.zeros((num_cams, num_cams, 4), np.int64)
    by_t_cam: Dict[Tuple[int, int], List[ReIDRecord]] = {}
    for r in records:
        by_t_cam.setdefault((r.t, r.cam), []).append(r)
    times = sorted({r.t for r in records})
    for t in times:
        for src in range(num_cams):
            src_rows = by_t_cam.get((t, src), [])
            if not src_rows:
                continue
            for dst in range(num_cams):
                if dst == src:
                    continue
                dst_rows = by_t_cam.get((t, dst), [])
                dst_rids = {r.rid: r for r in dst_rows}
                dst_objs = {r.obj for r in dst_rows}
                for r in src_rows:
                    gt_pos = r.obj in dst_objs
                    match = dst_rids.get(r.rid)
                    if match is not None:
                        if gt_pos and match.obj == r.obj:
                            counts[src, dst, 0] += 1  # TP
                        else:
                            counts[src, dst, 1] += 1  # FP
                    else:
                        if gt_pos:
                            counts[src, dst, 2] += 1  # FN
                        else:
                            counts[src, dst, 3] += 1  # TN
    return counts
