"""Cross-camera region-association lookup table (paper §3.2, Table 1).

From (filtered) ReID records we build, per timestamp and per object id, the
*appearance regions*: for each camera where the object appears, the least
set of tiles covering its bbox.  The RoI optimization (core/setcover.py)
then requires at least one appearance region per (t, id) to be fully inside
the union mask.

Tiles are referred to by *global* ids: ``offset[cam] + local_tile_index`` so
one flat universe spans the whole camera fleet.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Sequence, Tuple

import numpy as np

from repro.core.geometry import Camera
from repro.core.reid import ReIDRecord


@dataclass(frozen=True)
class Region:
    """One appearance region: a camera plus the covering tile set."""
    cam: int
    tiles: FrozenSet[int]        # *global* tile ids

    def __len__(self) -> int:
        return len(self.tiles)


@dataclass
class TileUniverse:
    cameras: Sequence[Camera]
    offsets: np.ndarray          # (N+1,) prefix offsets into the global space

    @classmethod
    def build(cls, cameras: Sequence[Camera]) -> "TileUniverse":
        offs = np.zeros(len(cameras) + 1, np.int64)
        for i, c in enumerate(cameras):
            offs[i + 1] = offs[i] + c.num_tiles
        return cls(cameras, offs)

    @property
    def num_tiles(self) -> int:
        return int(self.offsets[-1])

    def globalize(self, cam: int, local_tiles: FrozenSet[int]) -> FrozenSet[int]:
        off = int(self.offsets[cam])
        return frozenset(off + t for t in local_tiles)

    def localize(self, gids) -> Dict[int, List[int]]:
        """Split global tile ids back into {cam: [local ids]}."""
        out: Dict[int, List[int]] = {c.cam_id: [] for c in self.cameras}
        for g in gids:
            cam = int(np.searchsorted(self.offsets, g, side="right") - 1)
            out[cam].append(int(g - self.offsets[cam]))
        return out

    def cam_mask_grid(self, cam: int, gids) -> np.ndarray:
        """Binary (tiles_y, tiles_x) grid of a camera's mask tiles."""
        c = self.cameras[cam]
        grid = np.zeros((c.tiles_y, c.tiles_x), bool)
        for t in self.localize(gids)[cam]:
            grid[t // c.tiles_x, t % c.tiles_x] = True
        return grid


@dataclass
class AssociationTable:
    """constraints[i] = candidate appearance regions of one (t, id) pair."""
    universe: TileUniverse
    constraints: List[List[Region]]
    keys: List[Tuple[int, int]]  # (t, rid) per constraint — for debugging


def build_association_table(records: Sequence[ReIDRecord],
                            universe: TileUniverse) -> AssociationTable:
    per_tid: Dict[Tuple[int, int], Dict[int, set]] = {}
    for r in records:
        cam = universe.cameras[r.cam]
        tiles = cam.bbox_tiles(r.bbox)
        if not tiles:
            continue
        slot = per_tid.setdefault((r.t, r.rid), {})
        # same object twice in one camera frame cannot happen in our schema,
        # but unioning is the safe merge if a detector double-fires
        slot[r.cam] = slot.get(r.cam, set()) | set(tiles)

    constraints: List[List[Region]] = []
    keys: List[Tuple[int, int]] = []
    for key, cams in per_tid.items():
        regions = [Region(c, universe.globalize(c, frozenset(ts)))
                   for c, ts in sorted(cams.items())]
        constraints.append(regions)
        keys.append(key)
    return AssociationTable(universe, constraints, keys)
